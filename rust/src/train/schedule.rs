//! Learning-rate schedules, computed host-side and fed to the AOT
//! train-step as a scalar input each step (the paper's recipe: linear
//! warmup then cosine annealing; Sec. 5.2).

/// Warmup + cosine decay to `min_frac * base_lr`.
#[derive(Debug, Clone, Copy)]
pub struct Schedule {
    pub base_lr: f32,
    pub warmup_steps: u64,
    pub total_steps: u64,
    pub min_frac: f32,
}

impl Schedule {
    pub fn new(base_lr: f32, warmup_steps: u64, total_steps: u64) -> Self {
        Self { base_lr, warmup_steps, total_steps, min_frac: 0.0 }
    }

    /// Constant LR (used by short microbench runs).
    pub fn constant(lr: f32) -> Self {
        Self { base_lr: lr, warmup_steps: 0, total_steps: u64::MAX, min_frac: 1.0 }
    }

    pub fn lr(&self, step: u64) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        if self.min_frac >= 1.0 {
            return self.base_lr;
        }
        let t = (step - self.warmup_steps) as f32;
        let total = (self.total_steps.saturating_sub(self.warmup_steps))
            .max(1) as f32;
        let frac = (t / total).min(1.0);
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * frac).cos());
        self.base_lr * (self.min_frac + (1.0 - self.min_frac) * cos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::new(1.0, 10, 100);
        assert!((s.lr(0) - 0.1).abs() < 1e-6);
        assert!((s.lr(4) - 0.5).abs() < 1e-6);
        assert!((s.lr(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_zero() {
        let s = Schedule::new(1.0, 0, 100);
        assert!((s.lr(0) - 1.0).abs() < 1e-5);
        assert!(s.lr(50) < s.lr(10));
        assert!(s.lr(100) < 1e-6);
        // past the end it stays at the floor
        assert!(s.lr(500) < 1e-6);
    }

    #[test]
    fn constant_is_constant() {
        let s = Schedule::constant(0.3);
        assert_eq!(s.lr(0), 0.3);
        assert_eq!(s.lr(1_000_000), 0.3);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = Schedule::new(2.5e-4, 100, 1000);
        let mut prev = f32::MAX;
        for step in (100..1000).step_by(50) {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }
}
