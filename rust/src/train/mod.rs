//! Training orchestration, backend-agnostic.
//!
//! [`TrainBackend`] is the seam: one `train_step(lr) -> loss` plus one
//! `evaluate(batches) -> metric`, and [`run_training`] drives the shared
//! loop (warmup+cosine LR, divergence detection, periodic eval, loss
//! curve) against whichever implementation it is handed:
//!
//! * [`NativeTrainer`] — the default. Pure-Rust end-to-end training on
//!   the in-crate gradient engine (`native::autograd`, DESIGN.md §8) +
//!   [`AdamW`]: hermetic, zero artifacts, deterministic in
//!   `(config, seed)` regardless of pool width. Configs come from the
//!   [`native_specs`] registry (`cat train --backend native`, the table
//!   benches, the examples).
//! * [`Trainer`] — the PJRT path (feature `pjrt`): drives the AOT
//!   `train_step` executables exactly as before; `run`/`run_fused` are
//!   unchanged entry points.

pub mod schedule;

pub use schedule::Schedule;

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, ensure};

use crate::data::{ShapeDataset, TextCorpus};
use crate::json::Json;
use crate::metrics::LossCurve;
use crate::obs::log::{self as obs_log, Level};
use crate::native::{AdamW, Mixer, TaskKind, TrainBatch, TrainConfig,
                    TrainModel};
use crate::Result;

#[cfg(feature = "pjrt")]
use std::sync::Arc;

#[cfg(feature = "pjrt")]
use crate::data::BatchSource;
#[cfg(feature = "pjrt")]
use crate::metrics::EvalAccumulator;
#[cfg(feature = "pjrt")]
use crate::runtime::{Executable, Runtime, TrainState};
#[cfg(feature = "pjrt")]
use crate::tensor::HostTensor;

/// Configuration for one training run.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: u64,
    pub schedule: Schedule,
    /// Schedule offset: step `i` of this run is fed to the schedule as
    /// `start_step + i`. A resumed run passes the checkpoint's optimizer
    /// step here (with a schedule planned over the combined total) so
    /// the LR sequence enters mid-schedule instead of restarting from
    /// step zero (`cat train --resume`).
    pub start_step: u64,
    pub seed: u64,
    pub eval_every: u64,
    pub eval_batches: u64,
    pub log_every: u64,
    /// stop early if the loss goes non-finite (records divergence)
    pub stop_on_divergence: bool,
    /// When set, [`run_training`] appends one JSON object per line to
    /// this file — `{"kind":"step",...}` for every optimizer step,
    /// `{"kind":"eval",...}` per evaluation, and a final
    /// `{"kind":"summary",...}` — so external tooling can tail the run
    /// without scraping log text (`cat train --metrics-out`).
    pub metrics_out: Option<PathBuf>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            steps: 200,
            schedule: Schedule::new(1e-3, 20, 200),
            start_step: 0,
            seed: 0,
            eval_every: 0,
            eval_batches: 8,
            log_every: 25,
            stop_on_divergence: true,
            metrics_out: None,
        }
    }
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub config: String,
    pub curve: LossCurve,
    pub evals: Vec<(u64, &'static str, f64)>,
    pub steps_done: u64,
    pub wall_seconds: f64,
    pub diverged_at: Option<u64>,
}

impl TrainReport {
    pub fn final_metric(&self) -> Option<(&'static str, f64)> {
        self.evals.last().map(|(_, k, v)| (*k, *v))
    }

    pub fn steps_per_sec(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.steps_done as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

// ---------------------------------------------------------------------------
// the backend seam + the shared loop
// ---------------------------------------------------------------------------

/// What a training engine must provide for [`run_training`] to drive it.
pub trait TrainBackend {
    /// Config label for logs/reports.
    fn label(&self) -> &str;
    /// One optimizer step at learning rate `lr`; returns the loss.
    fn train_step(&mut self, lr: f32) -> Result<f32>;
    /// Evaluate on `n_batches` held-out batches →
    /// `("acc", fraction)` or `("ppl", perplexity)`.
    fn evaluate(&mut self, n_batches: u64) -> Result<(&'static str, f64)>;
}

/// Newline-delimited JSON metrics writer behind
/// [`TrainOptions::metrics_out`]. One object per line; non-finite
/// floats serialize as `null` (JSON has no NaN/Inf literal).
struct MetricsSink {
    w: std::io::BufWriter<std::fs::File>,
    path: PathBuf,
}

impl MetricsSink {
    fn open(path: &Path) -> Result<MetricsSink> {
        let f = std::fs::File::create(path).map_err(|e| {
            anyhow::anyhow!("creating metrics file {}: {e}",
                            path.display())
        })?;
        Ok(MetricsSink {
            w: std::io::BufWriter::new(f),
            path: path.to_path_buf(),
        })
    }

    fn emit(&mut self, line: &Json) -> Result<()> {
        writeln!(self.w, "{}", line.to_string()).map_err(|e| {
            anyhow::anyhow!("writing metrics file {}: {e}",
                            self.path.display())
        })
    }

    fn finish(mut self) -> Result<()> {
        self.w.flush().map_err(|e| {
            anyhow::anyhow!("flushing metrics file {}: {e}",
                            self.path.display())
        })
    }
}

/// `f64` → JSON number, with non-finite values mapped to `null`.
fn json_num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// The shared training loop: LR schedule, loss curve, divergence stop,
/// periodic + final eval. Both backends run through here, so reports are
/// comparable across them.
pub fn run_training(backend: &mut dyn TrainBackend, opts: &TrainOptions)
                    -> Result<TrainReport> {
    let label = backend.label().to_string();
    let mut curve = LossCurve::default();
    let mut evals = Vec::new();
    let mut sink = match &opts.metrics_out {
        Some(path) => Some(MetricsSink::open(path)?),
        None => None,
    };
    let t0 = Instant::now();
    let mut diverged_at = None;
    let mut done = 0;
    for step in 0..opts.steps {
        let lr = opts.schedule.lr(opts.start_step + step);
        let loss = backend.train_step(lr)?;
        curve.push(step, loss);
        done = step + 1;
        if let Some(sink) = &mut sink {
            sink.emit(&Json::Obj(vec![
                ("kind".to_string(), Json::from("step")),
                ("step".to_string(), Json::from((step + 1) as usize)),
                ("loss".to_string(), json_num(loss as f64)),
                ("lr".to_string(), json_num(lr as f64)),
            ]))?;
        }
        if opts.log_every > 0 && (step + 1) % opts.log_every == 0 {
            obs_log::log_fields(
                Level::Info, "train", "step",
                &[("config", &label),
                  ("step", &(step + 1).to_string()),
                  ("loss", &format!("{loss:.4}")),
                  ("ema", &format!("{:.4}",
                                   curve.ema().unwrap_or(f64::NAN))),
                  ("lr", &format!("{lr:.2e}"))]);
        }
        if !loss.is_finite() {
            diverged_at = Some(step);
            if opts.stop_on_divergence {
                obs_log::log_fields(
                    Level::Warn, "train", "training diverged",
                    &[("config", &label),
                      ("step", &step.to_string()),
                      ("loss", &loss.to_string())]);
                break;
            }
        }
        if opts.eval_every > 0 && (step + 1) % opts.eval_every == 0 {
            let (k, v) = backend.evaluate(opts.eval_batches)?;
            obs_log::log_fields(
                Level::Info, "train", "eval",
                &[("config", &label),
                  ("step", &(step + 1).to_string()),
                  (k, &format!("{v:.4}"))]);
            evals.push((step + 1, k, v));
            if let Some(sink) = &mut sink {
                sink.emit(&Json::Obj(vec![
                    ("kind".to_string(), Json::from("eval")),
                    ("step".to_string(), Json::from((step + 1) as usize)),
                    ("metric".to_string(), Json::from(k)),
                    ("value".to_string(), json_num(v)),
                ]))?;
            }
        }
    }
    // final eval, unless the last periodic eval already covered `done`
    if diverged_at.is_none() && evals.last().map(|e| e.0) != Some(done) {
        let (k, v) = backend.evaluate(opts.eval_batches)?;
        evals.push((done, k, v));
        if let Some(sink) = &mut sink {
            sink.emit(&Json::Obj(vec![
                ("kind".to_string(), Json::from("eval")),
                ("step".to_string(), Json::from(done as usize)),
                ("metric".to_string(), Json::from(k)),
                ("value".to_string(), json_num(v)),
            ]))?;
        }
    }
    let wall_seconds = t0.elapsed().as_secs_f64();
    if let Some(mut sink) = sink.take() {
        sink.emit(&Json::Obj(vec![
            ("kind".to_string(), Json::from("summary")),
            ("config".to_string(), Json::from(label.as_str())),
            ("steps".to_string(), Json::from(done as usize)),
            ("wall_seconds".to_string(), json_num(wall_seconds)),
            ("diverged_at".to_string(), match diverged_at {
                Some(s) => Json::from(s as usize),
                None => Json::Null,
            }),
        ]))?;
        sink.finish()?;
    }
    Ok(TrainReport {
        config: label,
        curve,
        evals,
        steps_done: done,
        wall_seconds,
        diverged_at,
    })
}

// ---------------------------------------------------------------------------
// the native backend
// ---------------------------------------------------------------------------

/// Offset separating eval streams from train streams (mirrors
/// `data::batch`'s held-out split).
const EVAL_STREAM_BASE: u64 = 1 << 40;

enum NativeData {
    Vit(ShapeDataset),
    Lm(TextCorpus),
}

/// Hermetic trainer: [`TrainModel`] + [`AdamW`] + the synthetic data
/// substrates, behind [`TrainBackend`]. Bit-deterministic in
/// `(config, seed)` — pool width does not change the loss curve.
pub struct NativeTrainer {
    label: String,
    model: TrainModel,
    opt: AdamW,
    data: NativeData,
    cursor: u64,
    seed: u64,
    mask_prob: f64,
    /// Reusable batch container: the ViT path refills its image/label
    /// buffers in place every step (`ShapeDataset::fill_batch` clears +
    /// reuses capacity), keeping the step hot loop allocation-free; the
    /// LM corpus generators return fresh token Vecs by API.
    batch: TrainBatch,
}

impl NativeTrainer {
    /// Build from an explicit config (the table benches construct
    /// ablation shapes directly).
    pub fn from_config(label: &str, cfg: TrainConfig, seed: u64)
                       -> Result<NativeTrainer> {
        let model = TrainModel::new(cfg, seed)?;
        let (data, batch) = match cfg.task {
            TaskKind::Vit { .. } => (
                NativeData::Vit(ShapeDataset::new(seed)),
                TrainBatch::Vit { images: Vec::new(), labels: Vec::new() },
            ),
            TaskKind::Lm { vocab, .. } => (
                NativeData::Lm(TextCorpus::new(vocab, seed)),
                TrainBatch::Lm {
                    tokens: Vec::new(),
                    targets: Vec::new(),
                    weights: Vec::new(),
                },
            ),
        };
        Ok(NativeTrainer {
            label: label.to_string(),
            model,
            opt: AdamW::new(),
            data,
            cursor: 0,
            seed,
            mask_prob: 0.15,
            batch,
        })
    }

    /// Build from the [`native_specs`] registry by name.
    pub fn new(name: &str, seed: u64) -> Result<NativeTrainer> {
        let spec = native_spec(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown native config '{name}'; known: {:?}",
                native_specs().iter().map(|s| s.name).collect::<Vec<_>>())
        })?;
        Self::from_config(name, spec.cfg, seed)
    }

    pub fn model(&self) -> &TrainModel {
        &self.model
    }

    /// Optimizer steps taken so far (continues across checkpoint
    /// resume — the CLI feeds this to `TrainOptions::start_step` so a
    /// resumed run picks the LR schedule up where it left off).
    pub fn opt_steps(&self) -> u64 {
        self.opt.steps()
    }

    pub fn param_count(&self) -> usize {
        self.model.param_count()
    }

    /// Refill `self.batch` in place for stream position `start`.
    fn fill_batch_at(&mut self, start: u64) {
        let cfg = *self.model.cfg();
        let b = cfg.batch_size;
        match (&self.data, &mut self.batch, cfg.task) {
            (NativeData::Vit(ds), TrainBatch::Vit { images, labels },
             TaskKind::Vit { .. }) => {
                ds.fill_batch(start, b, images, labels);
            }
            (NativeData::Lm(corpus),
             TrainBatch::Lm { tokens, targets, weights },
             TaskKind::Lm { causal, seq_len, .. }) => {
                let lb = if causal {
                    corpus.causal_batch(start, b, seq_len)
                } else {
                    corpus.masked_batch(start, b, seq_len, self.mask_prob)
                };
                *tokens = lb.tokens;
                *targets = lb.targets;
                *weights = lb.weights;
            }
            _ => unreachable!("data/batch/task wired together in from_config"),
        }
    }
}

impl TrainBackend for NativeTrainer {
    fn label(&self) -> &str {
        &self.label
    }

    fn train_step(&mut self, lr: f32) -> Result<f32> {
        self.fill_batch_at(self.cursor);
        self.cursor += self.model.cfg().batch_size as u64;
        let loss = self.model.loss_and_grad(&self.batch)?;
        self.opt.step(lr, &mut self.model.opt_tensors())?;
        Ok(loss)
    }

    fn evaluate(&mut self, n_batches: u64) -> Result<(&'static str, f64)> {
        let b = self.model.cfg().batch_size as u64;
        let is_vit = matches!(self.model.cfg().task, TaskKind::Vit { .. });
        let mut correct = 0usize;
        let mut examples = 0usize;
        let mut nll = 0.0f64;
        let mut weight = 0.0f64;
        for i in 0..n_batches {
            self.fill_batch_at(EVAL_STREAM_BASE + i * b);
            let out = self.model.forward_eval(&self.batch)?;
            correct += out.correct;
            examples += out.examples;
            nll += out.nll;
            weight += out.weight;
        }
        if is_vit {
            anyhow::ensure!(examples > 0, "no eval examples accumulated");
            Ok(("acc", correct as f64 / examples as f64))
        } else {
            anyhow::ensure!(weight > 0.0, "no weighted eval tokens");
            Ok(("ppl", (nll / weight).exp()))
        }
    }
}

// ---------------------------------------------------------------------------
// native checkpoints (plain little-endian, hermetic — DESIGN.md §9)
// ---------------------------------------------------------------------------
//
// Layout (all integers u64 LE, all tensors f32 LE):
//
//   magic "CATCKPT2" | seed | cursor | config fingerprint (11 words) |
//   opt step | n_tensors | per tensor: name_len + name bytes + len +
//   len·f32 | m: len + len·f32 | v: len + len·f32 | crc32 (u32 LE over
//   every preceding byte)
//
// The fingerprint + seed + tensor names make resume-into-the-wrong-model
// a hard error instead of silent drift; cursor + moments + step make the
// resumed loss sequence bit-identical to the uninterrupted run. The
// trailing CRC turns silent bit-rot (torn writes, disk corruption) into
// a loud load error; version-1 files ("CATCKPT1", no trailer) still
// load. Saves are atomic: temp file + fsync + rename, so a failed or
// interrupted save never clobbers the previous checkpoint.

/// Magic of the legacy v1 format (no integrity trailer) — read-only.
const CKPT_MAGIC_V1: &[u8; 8] = b"CATCKPT1";
/// Magic of the legacy-config format (trailing CRC32). Still written,
/// byte-identical, for every config that predates the mixer registry.
const CKPT_MAGIC_V2: &[u8; 8] = b"CATCKPT2";
/// Magic of the registry-era format: same layout as v2 except the
/// config fingerprint ends with the `fnet_truncate` word. Written only
/// when [`ckpt_uses_v3`] — a registry-era mixer id (≥ 3) or the
/// truncation knob — so new mixers can never silently load into (or
/// from) a pre-registry `CATCKPT2` file.
const CKPT_MAGIC_V3: &[u8; 8] = b"CATCKPT3";

/// Does this config need the versioned v3 fingerprint? Legacy configs
/// (cat / cat_alter / cat_gather / attention, no truncation) must keep
/// answering `false` forever: their `CATCKPT2` bytes are frozen.
fn ckpt_uses_v3(cfg: &TrainConfig) -> bool {
    cfg.mixer.spec().ckpt_id >= 3 || cfg.fnet_truncate
}

/// CRC32 lookup table (IEEE 802.3, reflected polynomial 0xEDB88320) —
/// the same CRC as gzip/zip/PNG, built at compile time.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 (IEEE) of `bytes`.
fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Write `bytes` to `path` atomically: a sibling `<path>.tmp` is
/// written and fsynced first, then renamed over the target. A crash or
/// failure anywhere before the rename leaves the previous file intact;
/// rename-within-a-directory is atomic on POSIX filesystems.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write as _;
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    let attempt = (|| -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)
    })();
    if let Err(e) = attempt {
        let _ = std::fs::remove_file(&tmp);
        bail!("writing checkpoint {}: {e}", path.display());
    }
    Ok(())
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    put_u64(buf, xs.len() as u64);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked little-endian reader over a checkpoint byte buffer.
struct CkptReader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> CkptReader<'a> {
    /// Checked take: corrupt length words (including ones that would
    /// overflow `off + n`) come back as errors, never as panics.
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.off.checked_add(n);
        ensure!(end.is_some_and(|e| e <= self.buf.len()),
                "checkpoint truncated at byte {} (wanted {n} more)",
                self.off);
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let len = self.u64()?;
        let bytes = usize::try_from(len)
            .ok()
            .and_then(|l| l.checked_mul(4));
        let Some(bytes) = bytes else {
            anyhow::bail!("corrupt checkpoint: tensor length {len} \
                           overflows");
        };
        let b = self.take(bytes)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect())
    }
}

/// Encode a [`TrainConfig`] as a fixed word sequence for the checkpoint
/// header; any structural mismatch fails resume loudly. The mixer word
/// is the registry's stable `ckpt_id` (0–2 reproduce the pre-registry
/// encoding exactly); v3 configs append the `fnet_truncate` word.
fn config_fingerprint(cfg: &TrainConfig) -> Vec<u64> {
    let mixer = cfg.mixer.spec().ckpt_id;
    let (tag, t0, t1, t2, t3) = match cfg.task {
        TaskKind::Vit { image_size, patch_size, n_channels, n_classes } => {
            (0u64, image_size as u64, patch_size as u64, n_channels as u64,
             n_classes as u64)
        }
        TaskKind::Lm { vocab, seq_len, causal } => {
            (1u64, vocab as u64, seq_len as u64, causal as u64, 0)
        }
    };
    let mut words = vec![
        cfg.d_model as u64, cfg.n_heads as u64, cfg.n_layers as u64,
        cfg.batch_size as u64, mixer, cfg.alternate as u64, tag, t0, t1,
        t2, t3,
    ];
    if ckpt_uses_v3(cfg) {
        words.push(cfg.fnet_truncate as u64);
    }
    words
}

impl NativeTrainer {
    /// Current position in the deterministic training stream.
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Serialize the full training state — parameters, AdamW moments and
    /// step count, and the data-stream cursor — to `path` in the plain
    /// little-endian native checkpoint format. A trainer restored with
    /// [`Self::load_checkpoint`] continues with bit-identical losses.
    pub fn save_checkpoint(&mut self, path: &Path) -> Result<()> {
        let mut buf = Vec::new();
        buf.extend_from_slice(if ckpt_uses_v3(self.model.cfg()) {
            CKPT_MAGIC_V3
        } else {
            CKPT_MAGIC_V2
        });
        put_u64(&mut buf, self.seed);
        put_u64(&mut buf, self.cursor);
        for w in config_fingerprint(self.model.cfg()) {
            put_u64(&mut buf, w);
        }
        put_u64(&mut buf, self.opt.steps());
        let tensors = self.model.tensors_for_io();
        put_u64(&mut buf, tensors.len() as u64);
        for (name, t) in &tensors {
            put_u64(&mut buf, name.len() as u64);
            buf.extend_from_slice(name.as_bytes());
            put_f32s(&mut buf, t);
        }
        drop(tensors);
        let (_, m, v) = self.opt.state();
        put_f32s(&mut buf, m);
        put_f32s(&mut buf, v);
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        write_atomic(path, &buf)
    }

    /// Restore state saved by [`Self::save_checkpoint`]. The trainer
    /// must have been built with the same `(config, seed)` — any
    /// mismatch (shape, mixer, task, seed, tensor order) is an error.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let raw = std::fs::read(path).map_err(|e| {
            anyhow::anyhow!("reading checkpoint {}: {e}", path.display())
        })?;
        ensure!(raw.len() >= 8,
                "{} is not a native CAT checkpoint", path.display());
        let file_is_v3 = &raw[..8] == CKPT_MAGIC_V3;
        let payload: &[u8] = if &raw[..8] == CKPT_MAGIC_V2 || file_is_v3 {
            ensure!(raw.len() >= 12,
                    "{} is truncated before the CRC trailer",
                    path.display());
            let body = &raw[..raw.len() - 4];
            let stored = u32::from_le_bytes(
                raw[raw.len() - 4..].try_into().expect("4 bytes"));
            let got = crc32(body);
            ensure!(got == stored,
                    "checkpoint {} failed CRC32 (stored {stored:#010x}, \
                     computed {got:#010x}): the file is corrupt",
                    path.display());
            body
        } else if &raw[..8] == CKPT_MAGIC_V1 {
            // legacy v1: no integrity trailer, payload is the whole file
            &raw
        } else {
            bail!("{} is not a native CAT checkpoint", path.display());
        };
        // version gate: registry-era configs (mixer ckpt_id ≥ 3 or
        // fnet_truncate) only pair with CATCKPT3 files, legacy configs
        // only with CATCKPT1/2 — a cross-version resume is always a
        // config mismatch, caught here with a clear error instead of a
        // confusing fingerprint-word diff
        let want_v3 = ckpt_uses_v3(self.model.cfg());
        ensure!(file_is_v3 == want_v3,
                "checkpoint {} is the {} format but config '{}' {} — \
                 registry-era mixers (fnet, circulant) and fnet_truncate \
                 write CATCKPT3; legacy cat/attention configs keep \
                 CATCKPT2",
                path.display(),
                if file_is_v3 { "CATCKPT3" } else { "CATCKPT1/2" },
                self.model.cfg().mechanism(),
                if want_v3 { "requires CATCKPT3" }
                else { "predates it" });
        let mut r = CkptReader { buf: payload, off: 8 };
        let seed = r.u64()?;
        ensure!(seed == self.seed,
                "checkpoint was trained with seed {seed}, trainer uses {}",
                self.seed);
        let cursor = r.u64()?;
        let want = config_fingerprint(self.model.cfg());
        for (i, &w) in want.iter().enumerate() {
            let got = r.u64()?;
            ensure!(got == w,
                    "checkpoint config mismatch at field {i}: {got} vs {w}");
        }
        let step = r.u64()?;
        let n_tensors = r.u64()? as usize;
        // parse + validate the whole payload into locals first, so an
        // error (truncation, corrupt lengths) leaves the trainer
        // untouched instead of half-restored
        let infos = self.model.tensor_infos();
        ensure!(n_tensors == infos.len(),
                "checkpoint holds {n_tensors} tensors, model has {}",
                infos.len());
        let mut loaded: Vec<Vec<f32>> = Vec::with_capacity(infos.len());
        for (name, len) in &infos {
            let nl = r.u64()? as usize;
            let nb = r.take(nl)?;
            if nb != name.as_bytes() {
                bail!("tensor order mismatch: checkpoint has {:?}, model \
                       expects {name}", String::from_utf8_lossy(nb));
            }
            let data = r.f32s()?;
            ensure!(data.len() == *len,
                    "tensor {name}: checkpoint len {} vs model {len}",
                    data.len());
            loaded.push(data);
        }
        let m = r.f32s()?;
        let v = r.f32s()?;
        ensure!(r.off == payload.len(),
                "{} trailing bytes after checkpoint payload",
                payload.len() - r.off);
        ensure!(m.len() == v.len(),
                "moment vectors disagree: m {} vs v {}", m.len(), v.len());
        // fully validated — commit atomically
        let mut tensors = self.model.tensors_for_io();
        for ((_, t), data) in tensors.iter_mut().zip(loaded) {
            **t = data;
        }
        self.opt.restore(step, m, v)?;
        self.cursor = cursor;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// the native config registry
// ---------------------------------------------------------------------------

/// One named native training config: the hermetic counterpart of the
/// PJRT artifact manifest.
#[derive(Debug, Clone, Copy)]
pub struct TrainSpec {
    pub name: &'static str,
    pub cfg: TrainConfig,
    /// Paper-table key for the reference column (None for extras).
    pub paper_key: Option<&'static str>,
}

/// Every named native config: the Table-1 ViT grid, the Table-2 LM grid
/// (masked + causal), the Table-3 ablation extras, and the CI smoke
/// shape.
pub fn native_specs() -> Vec<TrainSpec> {
    vec![
        TrainSpec {
            name: "native_vit_attention",
            cfg: TrainConfig::vit(Mixer::Attention, false),
            paper_key: Some("vit_b_avg_attention"),
        },
        TrainSpec {
            name: "native_vit_cat",
            cfg: TrainConfig::vit(Mixer::CatFft, false),
            paper_key: Some("vit_b_avg_cat"),
        },
        TrainSpec {
            name: "native_vit_cat_alter",
            cfg: TrainConfig::vit(Mixer::CatFft, true),
            paper_key: Some("vit_b_avg_cat_alter"),
        },
        TrainSpec {
            name: "native_vit_cat_gather",
            cfg: TrainConfig::vit(Mixer::CatGather, false),
            paper_key: None,
        },
        TrainSpec {
            name: "native_vit_fnet",
            cfg: TrainConfig::vit(Mixer::Fnet, false),
            paper_key: None,
        },
        TrainSpec {
            name: "native_vit_circulant",
            cfg: TrainConfig::vit(Mixer::Circulant, false),
            paper_key: None,
        },
        TrainSpec {
            name: "native_vit_cat_conv",
            cfg: TrainConfig::vit(Mixer::CatConv, false),
            paper_key: None,
        },
        TrainSpec {
            name: "native_lm_masked_attention",
            cfg: TrainConfig::lm(Mixer::Attention, false, false),
            paper_key: Some("lm_gpt2_masked_attention"),
        },
        TrainSpec {
            name: "native_lm_masked_cat",
            cfg: TrainConfig::lm(Mixer::CatFft, false, false),
            paper_key: Some("lm_gpt2_masked_cat"),
        },
        TrainSpec {
            name: "native_lm_masked_cat_alter",
            cfg: TrainConfig::lm(Mixer::CatFft, false, true),
            paper_key: Some("lm_gpt2_masked_cat_alter"),
        },
        TrainSpec {
            name: "native_lm_masked_fnet",
            cfg: TrainConfig::lm(Mixer::Fnet, false, false),
            paper_key: None,
        },
        TrainSpec {
            name: "native_lm_masked_circulant",
            cfg: TrainConfig::lm(Mixer::Circulant, false, false),
            paper_key: None,
        },
        TrainSpec {
            name: "native_lm_masked_cat_conv",
            cfg: TrainConfig::lm(Mixer::CatConv, false, false),
            paper_key: None,
        },
        TrainSpec {
            name: "native_lm_causal_attention",
            cfg: TrainConfig::lm(Mixer::Attention, true, false),
            paper_key: Some("lm_gpt2_causal_attention"),
        },
        TrainSpec {
            name: "native_lm_causal_cat",
            cfg: TrainConfig::lm(Mixer::CatFft, true, false),
            paper_key: Some("lm_gpt2_causal_cat"),
        },
        TrainSpec {
            name: "native_tiny",
            cfg: TrainConfig::tiny(),
            paper_key: None,
        },
    ]
}

/// Look up one spec by name.
pub fn native_spec(name: &str) -> Option<TrainSpec> {
    native_specs().into_iter().find(|s| s.name == name)
}

// ---------------------------------------------------------------------------
// the PJRT backend (feature-gated; drives the AOT train-step artifacts)
// ---------------------------------------------------------------------------

/// Orchestrates training + evaluation of one model config through the
/// AOT `train_step` artifacts (PJRT path).
#[cfg(feature = "pjrt")]
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    config: String,
    step_exe: Arc<Executable>,
    forward_exe: Arc<Executable>,
    pub state: TrainState,
    source: BatchSource,
}

#[cfg(feature = "pjrt")]
impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, config: &str, seed: u64) -> Result<Self> {
        let meta = rt.config(config)?.clone();
        let step_exe = rt.load(config, "train_step")?;
        let forward_exe = rt.load(config, "forward")?;
        let state = TrainState::init(rt, config, seed as i32)?;
        let source = BatchSource::new(&meta, seed);
        Ok(Self {
            rt,
            config: config.to_string(),
            step_exe,
            forward_exe,
            state,
            source,
        })
    }

    /// One optimizer step; returns the loss.
    pub fn step(&mut self, lr: f32) -> Result<f32> {
        let batch = self.source.next_train()?;
        let batch_lits: Vec<xla::Literal> = batch
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let lr_lit = HostTensor::scalar_f32(lr).to_literal()?;
        let mut args = self.state.opt_inputs();
        args.extend(batch_lits.iter());
        args.push(&lr_lit);
        let outs = self.step_exe.execute_literals(&args)?;
        let tail = self.state.absorb(outs)?;
        HostTensor::from_literal(&tail[0])?.scalar_value_f32()
    }

    /// Evaluate on `n_batches` held-out batches.
    pub fn eval(&self, n_batches: u64) -> Result<(&'static str, f64)> {
        let mut acc = EvalAccumulator::default();
        for i in 0..n_batches {
            let batch = self.source.eval_batch(i)?;
            // params are already literals — pass by reference, no copies
            let mut refs: Vec<&xla::Literal> =
                self.state.params.iter().collect();
            let input_lits: Vec<xla::Literal> =
                BatchSource::forward_inputs(&batch)
                    .iter()
                    .map(|t| t.to_literal())
                    .collect::<Result<_>>()?;
            refs.extend(input_lits.iter());
            let outs = self.forward_exe.execute_literals(&refs)?;
            let logits = HostTensor::from_literal(&outs[0])?;
            acc.update(&logits, &BatchSource::truth(&batch))?;
        }
        acc.headline()
            .ok_or_else(|| anyhow::anyhow!("no eval batches accumulated"))
    }

    /// Full training loop per `opts` (the shared [`run_training`] loop).
    pub fn run(&mut self, opts: &TrainOptions) -> Result<TrainReport> {
        run_training(self, opts)
    }

    /// Fused K-step loop over the `train_k8` artifact (perf variant).
    /// `opts.steps` is rounded down to a multiple of K.
    pub fn run_fused(&mut self, opts: &TrainOptions, k: usize)
                     -> Result<TrainReport> {
        let fused = self.rt.load(&self.config, &format!("train_k{k}"))?;
        let mut curve = LossCurve::default();
        let t0 = Instant::now();
        let rounds = opts.steps / k as u64;
        let mut step = 0u64;
        for _ in 0..rounds {
            // gather K batches, then stack each tensor along a new leading
            // K axis (manifest order is preserved per batch)
            let mut rounds_batches = Vec::with_capacity(k);
            let mut lrs = Vec::with_capacity(k);
            for j in 0..k {
                rounds_batches.push(self.source.next_train()?);
                lrs.push(opts.schedule.lr(step + j as u64));
            }
            let n_tensors = rounds_batches[0].len();
            let mut stacked: Vec<HostTensor> = Vec::with_capacity(n_tensors);
            for ti in 0..n_tensors {
                let mut shape = vec![k];
                shape.extend(&rounds_batches[0][ti].shape);
                let t = match &rounds_batches[0][ti].data {
                    crate::tensor::TensorData::F32(_) => {
                        let mut data = Vec::new();
                        for rb in &rounds_batches {
                            data.extend_from_slice(rb[ti].as_f32()?);
                        }
                        HostTensor::f32(shape, data)?
                    }
                    crate::tensor::TensorData::I32(_) => {
                        let mut data = Vec::new();
                        for rb in &rounds_batches {
                            data.extend_from_slice(rb[ti].as_i32()?);
                        }
                        HostTensor::i32(shape, data)?
                    }
                };
                stacked.push(t);
            }
            let batch_lits: Vec<xla::Literal> = stacked
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<_>>()?;
            let lr_lit = HostTensor::f32(vec![k], lrs)?.to_literal()?;
            let mut args = self.state.opt_inputs();
            args.extend(batch_lits.iter());
            args.push(&lr_lit);
            let outs = fused.execute_literals(&args)?;
            let tail = self.state.absorb(outs)?;
            let losses = HostTensor::from_literal(&tail[0])?;
            for (j, &l) in losses.as_f32()?.iter().enumerate() {
                curve.push(step + j as u64, l);
            }
            step += k as u64;
        }
        let (key, v) = self.eval(opts.eval_batches)?;
        Ok(TrainReport {
            config: self.config.clone(),
            curve,
            evals: vec![(step, key, v)],
            steps_done: step,
            wall_seconds: t0.elapsed().as_secs_f64(),
            diverged_at: None,
        })
    }

    pub fn source_mut(&mut self) -> &mut BatchSource {
        &mut self.source
    }
}

#[cfg(feature = "pjrt")]
impl TrainBackend for Trainer<'_> {
    fn label(&self) -> &str {
        &self.config
    }

    fn train_step(&mut self, lr: f32) -> Result<f32> {
        self.step(lr)
    }

    fn evaluate(&mut self, n_batches: u64) -> Result<(&'static str, f64)> {
        self.eval(n_batches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_resolvable() {
        let specs = native_specs();
        for (i, a) in specs.iter().enumerate() {
            for b in &specs[i + 1..] {
                assert_ne!(a.name, b.name, "duplicate spec name");
            }
            assert!(native_spec(a.name).is_some());
        }
        assert!(native_spec("no_such_config").is_none());
    }

    #[test]
    fn tiny_native_training_reduces_loss() {
        // the CI smoke contract: ≥20 steps on the tiny config, loss at
        // the end strictly below the start (quartile means for noise)
        let mut t = NativeTrainer::new("native_tiny", 0).unwrap();
        let opts = TrainOptions {
            steps: 24,
            schedule: Schedule::new(3e-3, 2, 24),
            eval_batches: 1,
            log_every: 0,
            ..Default::default()
        };
        let report = run_training(&mut t, &opts).unwrap();
        assert_eq!(report.steps_done, 24);
        assert!(report.diverged_at.is_none());
        assert!(report.curve.is_finite());
        let losses = &report.curve.losses;
        let q = losses.len() / 4;
        let head: f32 = losses[..q].iter().sum::<f32>() / q as f32;
        let tail: f32 = losses[losses.len() - q..].iter().sum::<f32>()
            / q as f32;
        assert!(tail < head,
                "loss did not decrease: first-quartile mean {head:.4} vs \
                 last {tail:.4}");
        let (k, v) = report.final_metric().unwrap();
        assert_eq!(k, "acc");
        assert!((0.0..=1.0).contains(&v));
    }

    #[test]
    fn metrics_out_writes_parseable_jsonl() {
        use crate::json;
        let path = std::env::temp_dir().join(format!(
            "cat_metrics_{}.jsonl", std::process::id()));
        let mut t = NativeTrainer::new("native_tiny", 0).unwrap();
        let opts = TrainOptions {
            steps: 4,
            schedule: Schedule::constant(1e-3),
            eval_every: 2,
            eval_batches: 1,
            log_every: 0,
            metrics_out: Some(path.clone()),
            ..Default::default()
        };
        let report = run_training(&mut t, &opts).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // 4 step lines + evals at steps 2 and 4 (the step-4 eval also
        // serves as the final one) + the summary line
        assert_eq!(lines.len(), 4 + 2 + 1,
                   "unexpected metrics line count:\n{text}");
        for l in &lines {
            json::parse(l).unwrap();
        }
        let first = json::parse(lines[0]).unwrap();
        assert_eq!(first.req("kind").unwrap().as_str().unwrap(), "step");
        assert_eq!(first.req("step").unwrap().as_f64().unwrap() as u64, 1);
        assert!(first.req("loss").unwrap().as_f64().unwrap().is_finite());
        let last = json::parse(lines[lines.len() - 1]).unwrap();
        assert_eq!(last.req("kind").unwrap().as_str().unwrap(), "summary");
        assert_eq!(last.req("steps").unwrap().as_f64().unwrap() as u64,
                   report.steps_done);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn same_seed_runs_are_identical() {
        let opts = TrainOptions {
            steps: 6,
            schedule: Schedule::constant(1e-3),
            eval_batches: 1,
            log_every: 0,
            ..Default::default()
        };
        let run = || {
            let mut t = NativeTrainer::new("native_tiny", 7).unwrap();
            run_training(&mut t, &opts).unwrap().curve.losses
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn lm_trainer_reports_ppl() {
        let mut t = NativeTrainer::new("native_lm_masked_cat", 1).unwrap();
        let (k, v) = t.evaluate(1).unwrap();
        assert_eq!(k, "ppl");
        assert!(v.is_finite() && v > 1.0);
    }

    #[test]
    fn checkpoint_roundtrip_resumes_bit_identically() {
        let path = std::env::temp_dir()
            .join(format!("cat_ckpt_test_{}.bin", std::process::id()));
        // 3 steps, save, one more step → the resumed trainer must
        // reproduce that next-step loss exactly (params + moments +
        // step + cursor all round-trip)
        let mut a = NativeTrainer::new("native_tiny", 3).unwrap();
        for _ in 0..3 {
            a.train_step(1e-3).unwrap();
        }
        a.save_checkpoint(&path).unwrap();
        assert_eq!(a.cursor(), 3 * a.model.cfg().batch_size as u64);
        let la = a.train_step(1e-3).unwrap();

        let mut b = NativeTrainer::new("native_tiny", 3).unwrap();
        b.load_checkpoint(&path).unwrap();
        assert_eq!(b.cursor(), a.cursor() - a.model.cfg().batch_size as u64);
        let lb = b.train_step(1e-3).unwrap();
        assert_eq!(la.to_bits(), lb.to_bits(),
                   "resumed step loss diverged: {la} vs {lb}");
        // and the run stays locked in step after that
        let la2 = a.train_step(1e-3).unwrap();
        let lb2 = b.train_step(1e-3).unwrap();
        assert_eq!(la2.to_bits(), lb2.to_bits());

        // wrong seed and wrong config both refuse to resume
        let mut c = NativeTrainer::new("native_tiny", 4).unwrap();
        assert!(c.load_checkpoint(&path).is_err(), "seed mismatch accepted");
        let mut d = NativeTrainer::new("native_vit_cat", 3).unwrap();
        assert!(d.load_checkpoint(&path).is_err(),
                "config mismatch accepted");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn crc32_matches_the_reference_vector() {
        // the canonical IEEE check value, same as gzip/zip/PNG
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn failed_save_leaves_previous_checkpoint_intact() {
        let path = std::env::temp_dir().join(format!(
            "cat_ckpt_atomic_{}.bin", std::process::id()));
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_name);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&tmp);

        let mut a = NativeTrainer::new("native_tiny", 11).unwrap();
        a.train_step(1e-3).unwrap();
        a.save_checkpoint(&path).unwrap();
        let good = std::fs::read(&path).unwrap();

        // wedge the temp path with a directory: the next save fails at
        // File::create, before the rename — the old file must survive
        std::fs::create_dir(&tmp).unwrap();
        a.train_step(1e-3).unwrap();
        let err = a.save_checkpoint(&path);
        assert!(err.is_err(), "save through a wedged temp must fail");
        assert_eq!(std::fs::read(&path).unwrap(), good,
                   "failed save clobbered the previous checkpoint");

        // and the surviving file still loads
        let mut b = NativeTrainer::new("native_tiny", 11).unwrap();
        b.load_checkpoint(&path).unwrap();
        assert_eq!(b.cursor(), a.model.cfg().batch_size as u64);

        std::fs::remove_dir(&tmp).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupted_checkpoint_fails_crc() {
        let path = std::env::temp_dir().join(format!(
            "cat_ckpt_crc_{}.bin", std::process::id()));
        let mut a = NativeTrainer::new("native_tiny", 5).unwrap();
        a.save_checkpoint(&path).unwrap();

        let mut raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[..8], CKPT_MAGIC_V2);
        // flip one payload bit mid-file: the CRC must catch it before
        // any field validation runs
        let mid = raw.len() / 2;
        raw[mid] ^= 0x40;
        std::fs::write(&path, &raw).unwrap();
        let mut b = NativeTrainer::new("native_tiny", 5).unwrap();
        let err = b.load_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("CRC32"), "wrong error for bit-rot: {err}");

        // truncation is also a load error, never a panic
        raw[mid] ^= 0x40; // restore
        std::fs::write(&path, &raw[..raw.len() - 9]).unwrap();
        assert!(b.load_checkpoint(&path).is_err(),
                "truncated checkpoint accepted");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn legacy_fingerprints_are_frozen() {
        // the exact pre-registry 11-word encodings; any drift here would
        // orphan every existing CATCKPT2 file
        let cases: [(TrainConfig, [u64; 11]); 4] = [
            (TrainConfig::vit(Mixer::CatFft, false),
             [64, 4, 2, 16, 0, 0, 0, 32, 4, 3, 10]),
            (TrainConfig::vit(Mixer::CatFft, true),
             [64, 4, 2, 16, 0, 1, 0, 32, 4, 3, 10]),
            (TrainConfig::vit(Mixer::CatGather, false),
             [64, 4, 2, 16, 1, 0, 0, 32, 4, 3, 10]),
            (TrainConfig::lm(Mixer::Attention, true, false),
             [64, 4, 2, 8, 2, 0, 1, 512, 128, 1, 0]),
        ];
        for (cfg, want) in cases {
            assert!(!ckpt_uses_v3(&cfg), "{} drifted to v3",
                    cfg.mechanism());
            assert_eq!(config_fingerprint(&cfg), want.to_vec(),
                       "legacy fingerprint drifted for {}",
                       cfg.mechanism());
        }
        // registry-era configs get the extra truncation word and v3
        let fnet = TrainConfig::vit(Mixer::Fnet, false);
        assert!(ckpt_uses_v3(&fnet));
        assert_eq!(config_fingerprint(&fnet).len(), 12);
        let mut trunc = fnet;
        trunc.fnet_truncate = true;
        assert_ne!(config_fingerprint(&fnet), config_fingerprint(&trunc));
        assert!(ckpt_uses_v3(&TrainConfig::vit(Mixer::Circulant, false)));
    }

    #[test]
    fn v3_checkpoint_roundtrips_and_rejects_cross_version() {
        let path = std::env::temp_dir().join(format!(
            "cat_ckpt_v3_{}.bin", std::process::id()));
        let cfg = TrainConfig {
            batch_size: 4,
            ..TrainConfig::vit(Mixer::Circulant, false)
        };
        let mut a = NativeTrainer::from_config("circ", cfg, 31).unwrap();
        a.train_step(1e-3).unwrap();
        a.save_checkpoint(&path).unwrap();
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[..8], CKPT_MAGIC_V3,
                   "registry-era mixer must write the v3 magic");

        let mut b = NativeTrainer::from_config("circ", cfg, 31).unwrap();
        b.load_checkpoint(&path).unwrap();
        let la = a.train_step(1e-3).unwrap();
        let lb = b.train_step(1e-3).unwrap();
        assert_eq!(la.to_bits(), lb.to_bits(),
                   "v3-resumed run diverged from the saver");

        // a legacy config must refuse the v3 file with the version error
        let legacy = TrainConfig {
            batch_size: 4,
            ..TrainConfig::vit(Mixer::CatFft, false)
        };
        let mut c = NativeTrainer::from_config("cat", legacy, 31).unwrap();
        let err = c.load_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("CATCKPT3"), "wrong cross-version error: \
                 {err}");

        // and the reverse: a v2 file into a registry-era config
        c.save_checkpoint(&path).unwrap();
        assert_eq!(&std::fs::read(&path).unwrap()[..8], CKPT_MAGIC_V2,
                   "legacy mixer must keep writing the v2 magic");
        let mut d = NativeTrainer::from_config("circ", cfg, 31).unwrap();
        let err = d.load_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("CATCKPT"), "wrong cross-version error: \
                 {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn legacy_v1_checkpoint_still_loads() {
        let path = std::env::temp_dir().join(format!(
            "cat_ckpt_v1_{}.bin", std::process::id()));
        let mut a = NativeTrainer::new("native_tiny", 9).unwrap();
        a.train_step(1e-3).unwrap();
        a.save_checkpoint(&path).unwrap();

        // rewrite the v2 file as v1: old magic, no CRC trailer
        let raw = std::fs::read(&path).unwrap();
        let mut v1 = raw[..raw.len() - 4].to_vec();
        v1[..8].copy_from_slice(CKPT_MAGIC_V1);
        std::fs::write(&path, &v1).unwrap();

        let mut b = NativeTrainer::new("native_tiny", 9).unwrap();
        b.load_checkpoint(&path).unwrap();
        assert_eq!(b.cursor(), a.cursor());
        let la = a.train_step(1e-3).unwrap();
        let lb = b.train_step(1e-3).unwrap();
        assert_eq!(la.to_bits(), lb.to_bits(),
                   "v1-resumed run diverged from the saver");
        let _ = std::fs::remove_file(&path);
    }
}
