//! Training orchestrator: drives the AOT train-step executables from rust.
//!
//! The loop body is: assemble a batch (rust substrates) → execute one
//! `train_step` (params/m/v/step literals + batch + lr) → absorb the new
//! state → log the loss. Evaluation periodically runs the `forward`
//! artifact over held-out batches and computes accuracy/PPL host-side.
//!
//! `run_fused` drives the `train_k8` artifact instead, feeding K stacked
//! batches per call to amortize host<->device round-trips — the L3 perf
//! lever quantified in EXPERIMENTS.md §Perf.

pub mod schedule;

pub use schedule::Schedule;

#[cfg(feature = "pjrt")]
use std::sync::Arc;
#[cfg(feature = "pjrt")]
use std::time::Instant;

#[cfg(feature = "pjrt")]
use crate::data::BatchSource;
use crate::metrics::LossCurve;
#[cfg(feature = "pjrt")]
use crate::metrics::EvalAccumulator;
#[cfg(feature = "pjrt")]
use crate::runtime::{Executable, Runtime, TrainState};
#[cfg(feature = "pjrt")]
use crate::tensor::HostTensor;
#[cfg(feature = "pjrt")]
use crate::Result;

/// Configuration for one training run.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: u64,
    pub schedule: Schedule,
    pub seed: u64,
    pub eval_every: u64,
    pub eval_batches: u64,
    pub log_every: u64,
    /// stop early if the loss goes non-finite (records divergence)
    pub stop_on_divergence: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            steps: 200,
            schedule: Schedule::new(1e-3, 20, 200),
            seed: 0,
            eval_every: 0,
            eval_batches: 8,
            log_every: 25,
            stop_on_divergence: true,
        }
    }
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub config: String,
    pub curve: LossCurve,
    pub evals: Vec<(u64, &'static str, f64)>,
    pub steps_done: u64,
    pub wall_seconds: f64,
    pub diverged_at: Option<u64>,
}

impl TrainReport {
    pub fn final_metric(&self) -> Option<(&'static str, f64)> {
        self.evals.last().map(|(_, k, v)| (*k, *v))
    }

    pub fn steps_per_sec(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.steps_done as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

/// Orchestrates training + evaluation of one model config (PJRT-only:
/// training runs through the AOT `train_step` artifacts).
#[cfg(feature = "pjrt")]
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    config: String,
    step_exe: Arc<Executable>,
    forward_exe: Arc<Executable>,
    pub state: TrainState,
    source: BatchSource,
}

#[cfg(feature = "pjrt")]
impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, config: &str, seed: u64) -> Result<Self> {
        let meta = rt.config(config)?.clone();
        let step_exe = rt.load(config, "train_step")?;
        let forward_exe = rt.load(config, "forward")?;
        let state = TrainState::init(rt, config, seed as i32)?;
        let source = BatchSource::new(&meta, seed);
        Ok(Self {
            rt,
            config: config.to_string(),
            step_exe,
            forward_exe,
            state,
            source,
        })
    }

    /// One optimizer step; returns the loss.
    pub fn step(&mut self, lr: f32) -> Result<f32> {
        let batch = self.source.next_train()?;
        let batch_lits: Vec<xla::Literal> = batch
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let lr_lit = HostTensor::scalar_f32(lr).to_literal()?;
        let mut args = self.state.opt_inputs();
        args.extend(batch_lits.iter());
        args.push(&lr_lit);
        let outs = self.step_exe.execute_literals(&args)?;
        let tail = self.state.absorb(outs)?;
        HostTensor::from_literal(&tail[0])?.scalar_value_f32()
    }

    /// Evaluate on `n_batches` held-out batches.
    pub fn eval(&self, n_batches: u64) -> Result<(&'static str, f64)> {
        let mut acc = EvalAccumulator::default();
        for i in 0..n_batches {
            let batch = self.source.eval_batch(i)?;
            // params are already literals — pass by reference, no copies
            let mut refs: Vec<&xla::Literal> = self.state.params.iter().collect();
            let input_lits: Vec<xla::Literal> =
                BatchSource::forward_inputs(&batch)
                    .iter()
                    .map(|t| t.to_literal())
                    .collect::<Result<_>>()?;
            refs.extend(input_lits.iter());
            let outs = self.forward_exe.execute_literals(&refs)?;
            let logits = HostTensor::from_literal(&outs[0])?;
            acc.update(&logits, &BatchSource::truth(&batch))?;
        }
        acc.headline()
            .ok_or_else(|| anyhow::anyhow!("no eval batches accumulated"))
    }

    /// Full training loop per `opts`.
    pub fn run(&mut self, opts: &TrainOptions) -> Result<TrainReport> {
        let mut curve = LossCurve::default();
        let mut evals = Vec::new();
        let t0 = Instant::now();
        let mut diverged_at = None;
        let mut done = 0;
        for step in 0..opts.steps {
            let lr = opts.schedule.lr(step);
            let loss = self.step(lr)?;
            curve.push(step, loss);
            done = step + 1;
            if opts.log_every > 0 && (step + 1) % opts.log_every == 0 {
                eprintln!("[{}] step {:>5} loss {:.4} (ema {:.4}) lr {:.2e}",
                          self.config, step + 1, loss,
                          curve.ema().unwrap_or(f64::NAN), lr);
            }
            if !loss.is_finite() {
                diverged_at = Some(step);
                if opts.stop_on_divergence {
                    eprintln!("[{}] diverged at step {step} (loss={loss})",
                              self.config);
                    break;
                }
            }
            if opts.eval_every > 0 && (step + 1) % opts.eval_every == 0 {
                let (k, v) = self.eval(opts.eval_batches)?;
                eprintln!("[{}] step {:>5} {k} {:.4}", self.config,
                          step + 1, v);
                evals.push((step + 1, k, v));
            }
        }
        if diverged_at.is_none() {
            let (k, v) = self.eval(opts.eval_batches)?;
            evals.push((done, k, v));
        }
        Ok(TrainReport {
            config: self.config.clone(),
            curve,
            evals,
            steps_done: done,
            wall_seconds: t0.elapsed().as_secs_f64(),
            diverged_at,
        })
    }

    /// Fused K-step loop over the `train_k8` artifact (perf variant).
    /// `opts.steps` is rounded down to a multiple of K.
    pub fn run_fused(&mut self, opts: &TrainOptions, k: usize)
                     -> Result<TrainReport> {
        let fused = self.rt.load(&self.config, &format!("train_k{k}"))?;
        let mut curve = LossCurve::default();
        let t0 = Instant::now();
        let rounds = opts.steps / k as u64;
        let mut step = 0u64;
        for _ in 0..rounds {
            // gather K batches, then stack each tensor along a new leading
            // K axis (manifest order is preserved per batch)
            let mut rounds_batches = Vec::with_capacity(k);
            let mut lrs = Vec::with_capacity(k);
            for j in 0..k {
                rounds_batches.push(self.source.next_train()?);
                lrs.push(opts.schedule.lr(step + j as u64));
            }
            let n_tensors = rounds_batches[0].len();
            let mut stacked: Vec<HostTensor> = Vec::with_capacity(n_tensors);
            for ti in 0..n_tensors {
                let mut shape = vec![k];
                shape.extend(&rounds_batches[0][ti].shape);
                let t = match &rounds_batches[0][ti].data {
                    crate::tensor::TensorData::F32(_) => {
                        let mut data = Vec::new();
                        for rb in &rounds_batches {
                            data.extend_from_slice(rb[ti].as_f32()?);
                        }
                        HostTensor::f32(shape, data)?
                    }
                    crate::tensor::TensorData::I32(_) => {
                        let mut data = Vec::new();
                        for rb in &rounds_batches {
                            data.extend_from_slice(rb[ti].as_i32()?);
                        }
                        HostTensor::i32(shape, data)?
                    }
                };
                stacked.push(t);
            }
            let batch_lits: Vec<xla::Literal> = stacked
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<_>>()?;
            let lr_lit = HostTensor::f32(vec![k], lrs)?.to_literal()?;
            let mut args = self.state.opt_inputs();
            args.extend(batch_lits.iter());
            args.push(&lr_lit);
            let outs = fused.execute_literals(&args)?;
            let tail = self.state.absorb(outs)?;
            let losses = HostTensor::from_literal(&tail[0])?;
            for (j, &l) in losses.as_f32()?.iter().enumerate() {
                curve.push(step + j as u64, l);
            }
            step += k as u64;
        }
        let (key, v) = self.eval(opts.eval_batches)?;
        Ok(TrainReport {
            config: self.config.clone(),
            curve,
            evals: vec![(step, key, v)],
            steps_done: step,
            wall_seconds: t0.elapsed().as_secs_f64(),
            diverged_at: None,
        })
    }

    pub fn source_mut(&mut self) -> &mut BatchSource {
        &mut self.source
    }
}
