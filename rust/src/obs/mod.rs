//! Observability subsystem: request tracing, stage-level latency
//! attribution, a flight recorder, and leveled structured logging
//! (DESIGN.md §13). Hermetic and zero-dependency, like everything else
//! in the crate.
//!
//! * [`trace`] — request IDs, stage spans, the global per-stage atomic
//!   histograms behind `cat_stage_duration_us`, and the thread-local
//!   accumulators that carry kernel time out of `native/cat.rs`;
//! * [`recorder`] — the lock-striped ring of the last K completed
//!   traces plus the slowest-since-boot set (`/debug/traces`,
//!   `/debug/slowest`);
//! * [`log`] — `error`/`warn`/`info`/`debug` with `CAT_LOG` /
//!   `--log-level` control and an optional JSON-lines mode;
//! * [`promlint`] — the test/CI-only Prometheus exposition linter.

pub mod log;
pub mod promlint;
pub mod recorder;
pub mod trace;

pub use recorder::{FlightRecorder, TraceRecord};
pub use trace::{Span, Stage, StageCells, TraceBuilder};
