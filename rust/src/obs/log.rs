//! Leveled structured logging for the whole stack (DESIGN.md §13).
//!
//! Replaces the ad-hoc `eprintln!` call sites with one funnel:
//! `error`/`warn`/`info`/`debug` plus a structured-fields variant
//! ([`log_fields`]) used by the supervisor and the slow-request
//! auto-logger. Hermetic by construction — writes lines to stderr, no
//! subscriber registry, no dependencies.
//!
//! Configuration, in precedence order:
//!
//! 1. `--log-level <error|warn|info|debug>` / `--log-json` on the CLI
//!    ([`set_level`], [`set_json`]);
//! 2. the `CAT_LOG` environment variable, a comma list of a level name
//!    and the `json` token (e.g. `CAT_LOG=debug,json`), read once on
//!    first use;
//! 3. default: `warn`, human-readable text (progress chatter stays
//!    opt-in; benches opt into `info` themselves).
//!
//! Text mode emits `[level target] msg k=v ...`; JSON mode emits one
//! JSON object per line (`ts_ms`, `level`, `target`, `msg`, then one
//! key per field) built with the in-repo [`crate::json`] writer, so
//! field values are always correctly escaped.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::Once;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::json::Json;

/// Log severity, most severe first. The active level admits itself and
/// everything more severe (`Info` admits error/warn/info).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// Stable lower-case name (JSON `level` field, `CAT_LOG` values).
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a level name, case-insensitive. `None` for unknown names.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// `u8::MAX` = not yet configured (first log initialises from `CAT_LOG`).
static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static JSON_MODE: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

fn init_from_env() {
    ENV_INIT.call_once(|| {
        let mut level = Level::Warn;
        let mut json = false;
        if let Ok(spec) = std::env::var("CAT_LOG") {
            for part in spec.split(',') {
                if part.trim().eq_ignore_ascii_case("json") {
                    json = true;
                } else if let Some(l) = Level::parse(part) {
                    level = l;
                }
            }
        }
        // an explicit set_level that ran before the first log wins
        let _ = LEVEL.compare_exchange(u8::MAX, level as u8,
                                       Ordering::Relaxed, Ordering::Relaxed);
        if json {
            JSON_MODE.store(true, Ordering::Relaxed);
        }
    });
}

/// Set the active level (the `--log-level` flag; overrides `CAT_LOG`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Switch to JSON-lines output (the `--log-json` flag).
pub fn set_json(json: bool) {
    JSON_MODE.store(json, Ordering::Relaxed);
}

/// Would a record at `level` be emitted right now? Callers building
/// expensive messages should gate on this first.
pub fn enabled(level: Level) -> bool {
    let mut current = LEVEL.load(Ordering::Relaxed);
    if current == u8::MAX {
        init_from_env();
        current = LEVEL.load(Ordering::Relaxed);
    }
    (level as u8) <= current
}

fn timestamp_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Render one record to its final line (text or JSON), without the
/// trailing newline. Split out so tests can pin both formats without
/// capturing stderr.
fn render_line(json_mode: bool, ts_ms: u64, level: Level, target: &str,
               msg: &str, fields: &[(&str, &str)]) -> String {
    if json_mode {
        let mut pairs = vec![
            ("ts_ms".to_string(), Json::Num(ts_ms as f64)),
            ("level".to_string(), Json::from(level.as_str())),
            ("target".to_string(), Json::from(target)),
            ("msg".to_string(), Json::from(msg)),
        ];
        for (k, v) in fields {
            pairs.push(((*k).to_string(), Json::from(*v)));
        }
        Json::Obj(pairs).to_string()
    } else {
        let mut line = format!("[{} {}] {}", level.as_str(), target, msg);
        for (k, v) in fields {
            line.push(' ');
            line.push_str(k);
            line.push('=');
            line.push_str(v);
        }
        line
    }
}

/// Emit one record with structured fields. Values are plain strings —
/// callers format numbers themselves (logging is off the hot path).
pub fn log_fields(level: Level, target: &str, msg: &str,
                  fields: &[(&str, &str)]) {
    if !enabled(level) {
        return;
    }
    let line = render_line(JSON_MODE.load(Ordering::Relaxed),
                           timestamp_ms(), level, target, msg, fields);
    let stderr = std::io::stderr();
    let mut w = stderr.lock();
    let _ = writeln!(w, "{line}");
}

pub fn error(target: &str, msg: &str) {
    log_fields(Level::Error, target, msg, &[]);
}

pub fn warn(target: &str, msg: &str) {
    log_fields(Level::Warn, target, msg, &[]);
}

pub fn info(target: &str, msg: &str) {
    log_fields(Level::Info, target, msg, &[]);
}

pub fn debug(target: &str, msg: &str) {
    log_fields(Level::Debug, target, msg, &[]);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_names_round_trip() {
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(l.as_str()), Some(l));
        }
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse("verbose"), None);
    }

    #[test]
    fn text_line_appends_fields() {
        let line = render_line(false, 0, Level::Warn, "supervisor",
                               "replica died",
                               &[("replica", "2"), ("epoch", "1")]);
        assert_eq!(line, "[warn supervisor] replica died replica=2 epoch=1");
    }

    #[test]
    fn json_line_is_parseable_and_escaped() {
        let line = render_line(true, 42, Level::Info, "serve",
                               "slow \"request\"", &[("id", "a\\b")]);
        let parsed = crate::json::parse(&line).expect("valid JSON line");
        assert_eq!(parsed.get("ts_ms").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(parsed.get("level").unwrap().as_str().unwrap(), "info");
        assert_eq!(parsed.get("msg").unwrap().as_str().unwrap(),
                   "slow \"request\"");
        assert_eq!(parsed.get("id").unwrap().as_str().unwrap(), "a\\b");
    }

    #[test]
    fn severity_ordering_matches_admission() {
        assert!(Level::Error < Level::Debug);
        // can't assert on the global level (other tests share it), but
        // the admission rule itself is just an ordering check
        assert!((Level::Warn as u8) <= (Level::Info as u8));
    }
}
