//! In-repo Prometheus text-exposition linter (promtool is unavailable
//! in the hermetic build). Test/CI-only: `tests/http_serving.rs` and
//! the CLI smoke run every live `/metrics` scrape through [`lint`];
//! nothing on the serving path calls this.
//!
//! Checks, per the exposition format 0.0.4:
//!
//! * every series has `# HELP` and `# TYPE` for its family *before*
//!   the first sample (histogram `_bucket`/`_sum`/`_count` series
//!   resolve to their base family);
//! * metric and label names are well-formed, label values use only the
//!   legal escapes (`\\`, `\"`, `\n`);
//! * sample values parse as floats (`+Inf`/`-Inf`/`NaN` allowed);
//! * histogram buckets are cumulative-monotone in `le` order and end
//!   with an `+Inf` bucket whose count equals the family's `_count`.

use std::collections::{BTreeMap, HashSet};

/// Lint a full exposition body. `Ok(())` or the first/most-salient
/// violation, with its line for context.
pub fn lint(body: &str) -> Result<(), String> {
    let mut helped: HashSet<String> = HashSet::new();
    let mut typed: BTreeMap<String, String> = BTreeMap::new();
    // (family, non-le labels) -> ordered (le, cumulative count)
    let mut buckets: BTreeMap<(String, String), Vec<(f64, f64)>> =
        BTreeMap::new();
    let mut counts: BTreeMap<(String, String), f64> = BTreeMap::new();

    for (ln, raw) in body.lines().enumerate() {
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        let err = |msg: String| format!("line {}: {msg}: {line}", ln + 1);
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next()
                .ok_or_else(|| err("HELP without a metric name".into()))?;
            check_name(name).map_err(err)?;
            helped.insert(name.to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let name = parts.next()
                .ok_or_else(|| err("TYPE without a metric name".into()))?;
            let kind = parts.next()
                .ok_or_else(|| err("TYPE without a kind".into()))?;
            check_name(name).map_err(err)?;
            if !matches!(kind,
                         "counter" | "gauge" | "histogram" | "summary"
                         | "untyped") {
                return Err(err(format!("unknown TYPE kind '{kind}'")));
            }
            typed.insert(name.to_string(), kind.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue; // plain comment
        }

        let sample = parse_sample(line).map_err(err)?;
        let family = base_family(&sample.name, &typed);
        if !helped.contains(&family) {
            return Err(err(format!(
                "series for '{family}' before its # HELP")));
        }
        let kind = typed.get(&family).ok_or_else(|| {
            err(format!("series for '{family}' before its # TYPE"))
        })?;
        if kind == "histogram" {
            let key = (family.clone(), sample.labels_without_le());
            if sample.name.ends_with("_bucket") {
                let le = sample.label("le").ok_or_else(|| {
                    err("histogram _bucket without an le label".into())
                })?;
                let bound = parse_float(le)
                    .ok_or_else(|| err(format!("bad le value '{le}'")))?;
                buckets.entry(key).or_default()
                    .push((bound, sample.value));
            } else if sample.name.ends_with("_count") {
                counts.insert(key, sample.value);
            } else if !sample.name.ends_with("_sum") {
                return Err(err(format!(
                    "histogram family '{family}' has a bare series")));
            }
        }
    }

    for ((family, labels), series) in &buckets {
        let ctx = if labels.is_empty() {
            family.clone()
        } else {
            format!("{family}{{{labels}}}")
        };
        let mut prev_le = f64::NEG_INFINITY;
        let mut prev_cum = -1.0f64;
        for &(le, cum) in series {
            if le <= prev_le {
                return Err(format!(
                    "{ctx}: bucket le values not increasing \
                     ({prev_le} then {le})"));
            }
            if cum < prev_cum {
                return Err(format!(
                    "{ctx}: cumulative bucket counts decreased \
                     ({prev_cum} then {cum} at le={le})"));
            }
            prev_le = le;
            prev_cum = cum;
        }
        let (last_le, last_cum) = *series.last().expect("non-empty");
        if !last_le.is_infinite() {
            return Err(format!("{ctx}: buckets must end at le=\"+Inf\""));
        }
        match counts.get(&(family.clone(), labels.clone())) {
            None => {
                return Err(format!("{ctx}: histogram without a _count"));
            }
            Some(&count) if count != last_cum => {
                return Err(format!(
                    "{ctx}: +Inf bucket {last_cum} != _count {count}"));
            }
            Some(_) => {}
        }
    }
    Ok(())
}

struct Sample {
    name: String,
    /// `(key, unescaped value)` pairs in series order.
    labels: Vec<(String, String)>,
    value: f64,
}

impl Sample {
    fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Canonical non-`le` label signature (histogram grouping key).
    fn labels_without_le(&self) -> String {
        let mut parts: Vec<String> = self.labels.iter()
            .filter(|(k, _)| k != "le")
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        parts.sort();
        parts.join(",")
    }
}

fn check_name(name: &str) -> Result<(), String> {
    let mut chars = name.chars();
    let ok_first = chars.next().map_or(false, |c| {
        c.is_ascii_alphabetic() || c == '_' || c == ':'
    });
    if !ok_first
        || !name.chars().all(|c| {
            c.is_ascii_alphanumeric() || c == '_' || c == ':'
        })
    {
        return Err(format!("bad metric name '{name}'"));
    }
    Ok(())
}

fn check_label_name(name: &str) -> bool {
    !name.is_empty()
        && name.chars().next()
            .map_or(false, |c| c.is_ascii_alphabetic() || c == '_')
        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Histogram child series fold into their base family for HELP/TYPE
/// lookup; everything else is its own family.
fn base_family(name: &str, typed: &BTreeMap<String, String>) -> String {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            if typed.get(base).map(String::as_str) == Some("histogram") {
                return base.to_string();
            }
        }
    }
    name.to_string()
}

fn parse_float(s: &str) -> Option<f64> {
    match s {
        "+Inf" | "Inf" => Some(f64::INFINITY),
        "-Inf" => Some(f64::NEG_INFINITY),
        "NaN" => Some(f64::NAN),
        _ => s.parse().ok(),
    }
}

fn parse_sample(line: &str) -> Result<Sample, String> {
    let (head, rest) = match line.find('{') {
        Some(brace) => {
            let (name, tail) = line.split_at(brace);
            let close = find_label_close(tail)
                .ok_or("unterminated label set")?;
            let labels = parse_labels(&tail[1..close])?;
            (Sample { name: name.to_string(), labels, value: 0.0 },
             tail[close + 1..].trim_start())
        }
        None => {
            let mut parts = line.splitn(2, char::is_whitespace);
            let name = parts.next().unwrap_or_default().to_string();
            (Sample { name, labels: Vec::new(), value: 0.0 },
             parts.next().unwrap_or_default().trim_start())
        }
    };
    check_name(&head.name)?;
    let value_text = rest.split_whitespace().next()
        .ok_or("sample without a value")?;
    let value = parse_float(value_text)
        .ok_or_else(|| format!("bad sample value '{value_text}'"))?;
    Ok(Sample { value, ..head })
}

/// Index of the `}` closing the label set, honouring quoted values.
fn find_label_close(tail: &str) -> Option<usize> {
    let bytes = tail.as_bytes();
    let mut in_quotes = false;
    let mut escaped = false;
    for (i, &b) in bytes.iter().enumerate().skip(1) {
        if escaped {
            escaped = false;
        } else if in_quotes && b == b'\\' {
            escaped = true;
        } else if b == b'"' {
            in_quotes = !in_quotes;
        } else if !in_quotes && b == b'}' {
            return Some(i);
        }
    }
    None
}

fn parse_labels(body: &str) -> Result<Vec<(String, String)>, String> {
    let mut labels = Vec::new();
    let mut rest = body.trim();
    while !rest.is_empty() {
        let eq = rest.find('=')
            .ok_or_else(|| format!("label without '=': '{rest}'"))?;
        let key = rest[..eq].trim();
        if !check_label_name(key) {
            return Err(format!("bad label name '{key}'"));
        }
        let after = rest[eq + 1..].trim_start();
        if !after.starts_with('"') {
            return Err(format!("label value for '{key}' not quoted"));
        }
        let (value, consumed) = unescape_label_value(&after[1..])
            .map_err(|e| format!("label '{key}': {e}"))?;
        labels.push((key.to_string(), value));
        rest = after[1 + consumed..].trim_start();
        if let Some(stripped) = rest.strip_prefix(',') {
            rest = stripped.trim_start();
        } else if !rest.is_empty() {
            return Err(format!("junk after label value: '{rest}'"));
        }
    }
    Ok(labels)
}

/// Unescape a quoted label value; returns (value, bytes consumed
/// including the closing quote). Only `\\`, `\"`, `\n` are legal.
fn unescape_label_value(s: &str) -> Result<(String, usize), String> {
    let mut out = String::new();
    let mut chars = s.char_indices();
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => return Ok((out, i + 1)),
            '\\' => match chars.next() {
                Some((_, '\\')) => out.push('\\'),
                Some((_, '"')) => out.push('"'),
                Some((_, 'n')) => out.push('\n'),
                Some((_, other)) => {
                    return Err(format!("illegal escape '\\{other}'"));
                }
                None => return Err("dangling backslash".to_string()),
            },
            '\n' => return Err("raw newline in label value".to_string()),
            _ => out.push(c),
        }
    }
    Err("unterminated label value".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
# HELP cat_up whether up
# TYPE cat_up gauge
cat_up 1
# HELP cat_req_total requests
# TYPE cat_req_total counter
cat_req_total{route=\"/v1/classify\"} 12
# HELP cat_lat_us latency
# TYPE cat_lat_us histogram
cat_lat_us_bucket{stage=\"fft\",le=\"1\"} 0
cat_lat_us_bucket{stage=\"fft\",le=\"2\"} 3
cat_lat_us_bucket{stage=\"fft\",le=\"+Inf\"} 5
cat_lat_us_sum{stage=\"fft\"} 9
cat_lat_us_count{stage=\"fft\"} 5
";

    #[test]
    fn accepts_a_wellformed_body() {
        lint(GOOD).expect("well-formed body must lint clean");
    }

    #[test]
    fn rejects_series_before_help_or_type() {
        let body = "cat_up 1\n# HELP cat_up u\n# TYPE cat_up gauge\n";
        let e = lint(body).unwrap_err();
        assert!(e.contains("HELP"), "{e}");
        let body = "# HELP cat_up u\ncat_up 1\n";
        let e = lint(body).unwrap_err();
        assert!(e.contains("TYPE"), "{e}");
    }

    #[test]
    fn rejects_non_monotone_or_unterminated_histograms() {
        let body = "\
# HELP h x
# TYPE h histogram
h_bucket{le=\"1\"} 5
h_bucket{le=\"2\"} 3
h_bucket{le=\"+Inf\"} 6
h_sum 1
h_count 6
";
        let e = lint(body).unwrap_err();
        assert!(e.contains("decreased"), "{e}");

        let body = "\
# HELP h x
# TYPE h histogram
h_bucket{le=\"1\"} 1
h_bucket{le=\"2\"} 2
h_sum 1
h_count 2
";
        let e = lint(body).unwrap_err();
        assert!(e.contains("+Inf"), "{e}");
    }

    #[test]
    fn rejects_inf_count_mismatch() {
        let body = "\
# HELP h x
# TYPE h histogram
h_bucket{le=\"+Inf\"} 4
h_sum 1
h_count 5
";
        let e = lint(body).unwrap_err();
        assert!(e.contains("_count"), "{e}");
    }

    #[test]
    fn rejects_bad_escapes_and_accepts_good_ones() {
        let body = "\
# HELP m x
# TYPE m gauge
m{model=\"a\\\\b\\\"c\\nd\"} 1
";
        lint(body).expect("legal escapes must pass");
        let body = "\
# HELP m x
# TYPE m gauge
m{model=\"a\\qb\"} 1
";
        let e = lint(body).unwrap_err();
        assert!(e.contains("escape"), "{e}");
    }

    #[test]
    fn histogram_groups_split_by_label_set() {
        // two stages interleaved: each group checked independently
        let body = "\
# HELP h x
# TYPE h histogram
h_bucket{stage=\"a\",le=\"1\"} 1
h_bucket{stage=\"b\",le=\"1\"} 9
h_bucket{stage=\"a\",le=\"+Inf\"} 2
h_bucket{stage=\"b\",le=\"+Inf\"} 9
h_sum{stage=\"a\"} 1
h_count{stage=\"a\"} 2
h_sum{stage=\"b\"} 1
h_count{stage=\"b\"} 9
";
        lint(body).expect("per-label-set grouping");
    }

    #[test]
    fn rejects_malformed_samples() {
        let base = "# HELP m x\n# TYPE m gauge\n";
        for bad in ["m{a=\"v\" 1", "m{a=v} 1", "m{1a=\"v\"} 1",
                    "m{a=\"v\"} x", "m"] {
            let body = format!("{base}{bad}\n");
            assert!(lint(&body).is_err(), "should reject: {bad}");
        }
    }
}
