//! Flight recorder: the last K completed request traces plus the
//! slowest-since-boot set, dumpable as JSON (DESIGN.md §13).
//!
//! A fixed-size, lock-striped ring: sequence numbers are handed out by
//! one relaxed atomic, and `seq` picks both the stripe and the slot
//! inside it, so concurrent connection threads committing traces only
//! contend when they land on the same stripe (1/8th of the time).
//! Slots are preallocated and reused in place — the slot's ID string
//! and span vector keep their capacity across wraps, so steady-state
//! commits allocate nothing once warm.
//!
//! Memory bound: `capacity` slots + [`SLOWEST_KEEP`] pinned traces,
//! each holding at most one span per stage — a few KiB total,
//! regardless of uptime.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::json::Json;
use crate::metrics::lock_recovering;

use super::trace::Span;

/// Stripe count: bounds commit contention, not capacity.
const STRIPES: usize = 8;

/// Slowest-since-boot traces pinned outside the ring.
pub const SLOWEST_KEEP: usize = 8;

/// Default ring capacity (`K` last completed traces).
pub const DEFAULT_CAPACITY: usize = 64;

/// One completed request trace as held by the recorder. `seq == 0`
/// marks a never-written slot.
#[derive(Debug, Clone, Default)]
pub struct TraceRecord {
    pub seq: u64,
    pub id: String,
    pub status: u16,
    pub total_us: u64,
    pub spans: Vec<Span>,
}

impl TraceRecord {
    /// JSON shape served by `/debug/traces` and `/debug/slowest`.
    pub fn to_json(&self) -> Json {
        let spans = self.spans.iter().map(|s| {
            Json::Obj(vec![
                ("stage".to_string(), Json::from(s.stage.as_str())),
                ("start_us".to_string(), Json::Num(s.start_us as f64)),
                ("dur_us".to_string(), Json::Num(s.dur_us as f64)),
            ])
        }).collect();
        Json::Obj(vec![
            ("seq".to_string(), Json::Num(self.seq as f64)),
            ("id".to_string(), Json::from(self.id.as_str())),
            ("status".to_string(), Json::Num(self.status as f64)),
            ("total_us".to_string(), Json::Num(self.total_us as f64)),
            ("spans".to_string(), Json::Arr(spans)),
        ])
    }
}

/// Lock-striped ring of the last `capacity` completed traces plus the
/// pinned slowest set. Cheap to clone behind an `Arc` in `AppState`.
#[derive(Debug)]
pub struct FlightRecorder {
    stripes: Vec<Mutex<Vec<TraceRecord>>>,
    per_stripe: usize,
    seq: AtomicU64,
    slowest: Mutex<Vec<TraceRecord>>,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> Arc<FlightRecorder> {
        let capacity = capacity.max(1);
        let stripes = STRIPES.min(capacity);
        let per_stripe = (capacity + stripes - 1) / stripes;
        Arc::new(FlightRecorder {
            stripes: (0..stripes)
                .map(|_| Mutex::new(vec![TraceRecord::default();
                                         per_stripe]))
                .collect(),
            per_stripe,
            seq: AtomicU64::new(0),
            slowest: Mutex::new(Vec::with_capacity(SLOWEST_KEEP)),
        })
    }

    /// Total ring slots (≥ the requested capacity, rounded up to a
    /// whole number of stripes).
    pub fn capacity(&self) -> usize {
        self.stripes.len() * self.per_stripe
    }

    /// Traces committed since boot.
    pub fn committed(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Record one completed request. Returns its sequence number
    /// (1-based). Reuses the target slot's buffers in place.
    pub fn commit(&self, id: &str, status: u16, total_us: u64,
                  spans: &[Span]) -> u64 {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let k = self.stripes.len();
        let stripe = (seq as usize) % k;
        let slot_idx = (seq as usize / k) % self.per_stripe;
        {
            let mut guard = lock_recovering(&self.stripes[stripe]);
            let slot = &mut guard[slot_idx];
            slot.seq = seq;
            slot.id.clear();
            slot.id.push_str(id);
            slot.status = status;
            slot.total_us = total_us;
            slot.spans.clear();
            slot.spans.extend_from_slice(spans);
        }
        self.note_slowest(seq, id, status, total_us, spans);
        seq
    }

    fn note_slowest(&self, seq: u64, id: &str, status: u16, total_us: u64,
                    spans: &[Span]) {
        let mut slow = lock_recovering(&self.slowest);
        if slow.len() >= SLOWEST_KEEP {
            let min = slow.iter().map(|t| t.total_us).min().unwrap_or(0);
            if total_us <= min {
                return;
            }
        }
        slow.push(TraceRecord {
            seq,
            id: id.to_string(),
            status,
            total_us,
            spans: spans.to_vec(),
        });
        slow.sort_by(|a, b| b.total_us.cmp(&a.total_us));
        slow.truncate(SLOWEST_KEEP);
    }

    /// The retained completed traces, oldest first (≤ `capacity`).
    pub fn recent(&self) -> Vec<TraceRecord> {
        let mut out = Vec::with_capacity(self.capacity());
        for stripe in &self.stripes {
            let guard = lock_recovering(stripe);
            out.extend(guard.iter().filter(|t| t.seq != 0).cloned());
        }
        out.sort_by_key(|t| t.seq);
        out
    }

    /// The pinned slowest-since-boot traces, slowest first.
    pub fn slowest(&self) -> Vec<TraceRecord> {
        lock_recovering(&self.slowest).clone()
    }

    /// `{"capacity": K, "committed": n, "traces": [...]}`.
    pub fn dump_json(&self, traces: &[TraceRecord]) -> Json {
        Json::Obj(vec![
            ("capacity".to_string(), Json::Num(self.capacity() as f64)),
            ("committed".to_string(), Json::Num(self.committed() as f64)),
            ("traces".to_string(),
             Json::Arr(traces.iter().map(TraceRecord::to_json).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Stage;

    fn span(stage: Stage, start_us: u64, dur_us: u64) -> Span {
        Span { stage, start_us, dur_us }
    }

    #[test]
    fn ring_retains_exactly_the_last_capacity_traces() {
        let rec = FlightRecorder::new(8); // 8 stripes x 1 slot
        assert_eq!(rec.capacity(), 8);
        for i in 0..24u64 {
            rec.commit(&format!("r{i}"), 200, 10 + i,
                       &[span(Stage::HttpParse, 0, 5)]);
        }
        let recent = rec.recent();
        assert_eq!(recent.len(), 8, "ring must wrap, not grow");
        let seqs: Vec<u64> = recent.iter().map(|t| t.seq).collect();
        assert_eq!(seqs, (17..=24).collect::<Vec<u64>>(),
                   "wraparound must keep the newest traces");
        assert_eq!(recent.last().unwrap().id, "r23");
        assert_eq!(rec.committed(), 24);
    }

    #[test]
    fn slot_reuse_keeps_latest_contents() {
        let rec = FlightRecorder::new(4);
        rec.commit("long-identifier-aaaa", 200, 5,
                   &[span(Stage::HttpParse, 0, 1),
                     span(Stage::Serialize, 1, 1)]);
        for _ in 0..rec.capacity() {
            rec.commit("x", 429, 7, &[span(Stage::HttpParse, 0, 2)]);
        }
        for t in rec.recent() {
            assert_eq!(t.id, "x", "reused slot must not leak old id");
            assert_eq!(t.spans.len(), 1,
                       "reused slot must not leak old spans");
            assert_eq!(t.status, 429);
        }
    }

    #[test]
    fn slowest_set_pins_the_worst_since_boot() {
        let rec = FlightRecorder::new(4);
        // slow early traces must survive any amount of later fast ones
        rec.commit("slow-1", 200, 900_000, &[]);
        rec.commit("slow-2", 200, 800_000, &[]);
        for i in 0..40u64 {
            rec.commit("fast", 200, 100 + i, &[]);
        }
        let slow = rec.slowest();
        assert_eq!(slow[0].id, "slow-1");
        assert_eq!(slow[0].total_us, 900_000);
        assert_eq!(slow[1].id, "slow-2");
        assert!(slow.len() <= SLOWEST_KEEP);
        assert!(!rec.recent().iter().any(|t| t.id == "slow-1"),
                "the ring itself wrapped past the slow trace");
    }

    #[test]
    fn concurrent_writers_never_lose_the_ring_shape() {
        let rec = FlightRecorder::new(16);
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let rec = rec.clone();
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        rec.commit(&format!("t{t}-{i}"), 200, i,
                                   &[span(Stage::QueueWait, 0, 3)]);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(rec.committed(), 800);
        let recent = rec.recent();
        assert_eq!(recent.len(), rec.capacity());
        for t in &recent {
            assert!(t.seq > 0 && t.seq <= 800);
            assert!(t.id.starts_with('t'), "torn record: {t:?}");
            assert_eq!(t.spans.len(), 1);
        }
        // every retained seq is unique
        let mut seqs: Vec<u64> = recent.iter().map(|t| t.seq).collect();
        seqs.dedup();
        assert_eq!(seqs.len(), rec.capacity());
    }

    #[test]
    fn json_dump_shape() {
        let rec = FlightRecorder::new(4);
        rec.commit("abc", 200, 120,
                   &[span(Stage::HttpParse, 0, 30),
                     span(Stage::Serialize, 90, 20)]);
        let dump = rec.dump_json(&rec.recent());
        let traces = dump.get("traces").unwrap().as_arr().unwrap();
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        assert_eq!(t.get("id").unwrap().as_str().unwrap(), "abc");
        assert_eq!(t.get("total_us").unwrap().as_f64().unwrap(), 120.0);
        let spans = t.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans[0].get("stage").unwrap().as_str().unwrap(),
                   "http_parse");
        assert_eq!(spans[1].get("dur_us").unwrap().as_f64().unwrap(), 20.0);
        // round-trips through the in-repo parser
        let text = dump.to_string();
        assert_eq!(crate::json::parse(&text).unwrap(), dump);
    }
}
