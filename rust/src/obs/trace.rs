//! Per-request tracing and stage-level latency attribution
//! (DESIGN.md §13).
//!
//! Every HTTP request owns a trace: a request ID (client-supplied
//! `X-Request-Id` or generated) plus monotonic stage spans covering the
//! wire path (`http_parse`, `serialize`), the router (`queue_wait`),
//! and the kernel (`batch_assembly`, `scatter`, `fft`, `mixer_matmul`,
//! `gather`). Three recording surfaces cooperate:
//!
//! * **global atomic histograms** ([`stage_snapshots`]) — every timed
//!   section lands here regardless of request context; exported as
//!   `cat_stage_duration_us{stage=...}` by `serve/prometheus.rs`.
//!   Buckets mirror [`crate::metrics::LatencyHistogram`] (32
//!   power-of-two µs buckets) so stage and end-to-end histograms line
//!   up in dashboards.
//! * **thread-local accumulators** — kernel seams ([`section`]) run on
//!   the replica worker thread with no request in scope; the batcher's
//!   `flush` reads the per-thread cumulative counters before and after
//!   `infer_batch` and attributes the delta to the batch it just ran.
//! * **per-request [`StageCells`]** — a tiny block of atomics riding on
//!   `InferRequest` that carries worker-side durations back to the HTTP
//!   connection thread, which folds them into the request's span list.
//!
//! Steady state allocates nothing on the timing path: sections are two
//! `Instant::now()` calls plus relaxed atomics, and the per-connection
//! [`TraceBuilder`] reuses its span buffer and ID string across
//! requests (the pooled span buffer of DESIGN.md §13).

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Number of trace stages (the `stage` label cardinality).
pub const N_STAGES: usize = 8;

/// One pipeline stage of a request's life, in execution order. The
/// discriminants index the histogram/accumulator arrays, and the order
/// `QueueWait..=Gather` is the layout order for worker-attributed
/// spans ([`StageCells`] consumers rely on it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    HttpParse = 0,
    QueueWait = 1,
    BatchAssembly = 2,
    Scatter = 3,
    Fft = 4,
    MixerMatmul = 5,
    Gather = 6,
    Serialize = 7,
}

impl Stage {
    /// Stable label value (`cat_stage_duration_us{stage=...}`).
    pub fn as_str(self) -> &'static str {
        match self {
            Stage::HttpParse => "http_parse",
            Stage::QueueWait => "queue_wait",
            Stage::BatchAssembly => "batch_assembly",
            Stage::Scatter => "scatter",
            Stage::Fft => "fft",
            Stage::MixerMatmul => "mixer_matmul",
            Stage::Gather => "gather",
            Stage::Serialize => "serialize",
        }
    }

    /// All stages in execution order.
    pub fn all() -> [Stage; N_STAGES] {
        [Stage::HttpParse, Stage::QueueWait, Stage::BatchAssembly,
         Stage::Scatter, Stage::Fft, Stage::MixerMatmul, Stage::Gather,
         Stage::Serialize]
    }

    pub fn index(self) -> usize {
        self as usize
    }
}

// -- global per-stage histograms -----------------------------------------

/// Lock-free latency histogram: the atomic twin of
/// [`crate::metrics::LatencyHistogram`], same 32 power-of-two µs
/// buckets, recordable from any thread without a mutex (kernel seams
/// must never serialize on observability).
pub struct AtomicHistogram {
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl AtomicHistogram {
    const ZERO: AtomicU64 = AtomicU64::new(0);

    pub const fn new() -> AtomicHistogram {
        AtomicHistogram {
            buckets: [Self::ZERO; 32],
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one observation in microseconds. Same bucket rule as
    /// `LatencyHistogram::record`: bucket `i` holds `(2^(i-1), 2^i]`.
    pub fn record_us(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize).min(31);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; 32];
        for (dst, src) in buckets.iter_mut().zip(&self.buckets) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistSnapshot {
            buckets,
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us.load(Ordering::Relaxed),
        }
    }
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram::new()
    }
}

/// Point-in-time copy of one stage histogram.
#[derive(Debug, Clone, Copy)]
pub struct HistSnapshot {
    pub buckets: [u64; 32],
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
}

impl HistSnapshot {
    /// `(upper_bound_us, cumulative_count)` per bucket, for Prometheus
    /// exposition — same bounds as `LatencyHistogram`.
    pub fn cumulative_buckets(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        let mut acc = 0u64;
        self.buckets.iter().enumerate().map(move |(i, &c)| {
            acc += c;
            (1u64 << i, acc)
        })
    }

    /// Upper bound of the bucket holding quantile `q` (0 when empty).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count as f64) * q.clamp(0.0, 1.0)).ceil() as u64;
        for (bound, cum) in self.cumulative_buckets() {
            if cum >= rank.max(1) {
                return bound;
            }
        }
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }
}

const STAGE_HIST: AtomicHistogram = AtomicHistogram::new();
static STAGE_HISTS: [AtomicHistogram; N_STAGES] = [STAGE_HIST; N_STAGES];

thread_local! {
    /// Cumulative ns this thread has spent in each stage — the seam
    /// that carries kernel time from `native/cat.rs` (no request in
    /// scope) up to the batcher's flush, which diffs it around
    /// `infer_batch`.
    static THREAD_STAGE_NS: Cell<[u64; N_STAGES]> =
        const { Cell::new([0; N_STAGES]) };
}

/// Record one completed stage section: global histogram + this
/// thread's cumulative counter. Allocation-free.
pub fn record_section(stage: Stage, dur: Duration) {
    STAGE_HISTS[stage.index()].record_us(dur.as_micros() as u64);
    THREAD_STAGE_NS.with(|c| {
        let mut v = c.get();
        v[stage.index()] += dur.as_nanos() as u64;
        c.set(v);
    });
}

/// Record a request-level observation (http_parse / queue_wait /
/// serialize) into the global histogram only — these already belong to
/// a known request, so the thread-local accumulator stays kernel-only.
pub fn record_stage_us(stage: Stage, us: u64) {
    STAGE_HISTS[stage.index()].record_us(us);
}

/// Time `f` as one `stage` section.
#[inline]
pub fn section<T>(stage: Stage, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    record_section(stage, t0.elapsed());
    out
}

/// This thread's cumulative per-stage nanoseconds (see module docs).
pub fn thread_stage_ns() -> [u64; N_STAGES] {
    THREAD_STAGE_NS.with(|c| c.get())
}

/// Snapshot every stage histogram, in [`Stage::all`] order.
pub fn stage_snapshots() -> [(Stage, HistSnapshot); N_STAGES] {
    Stage::all().map(|s| (s, STAGE_HISTS[s.index()].snapshot()))
}

// -- per-request carriers -------------------------------------------------

/// Worker-attributed stage durations for one request: filled (relaxed
/// atomics) by the replica worker during `flush`, read by the HTTP
/// connection thread after the response arrives. Rides on
/// `InferRequest` as an `Arc` so the worker never learns about HTTP.
#[derive(Debug, Default)]
pub struct StageCells {
    us: [AtomicU64; N_STAGES],
}

impl StageCells {
    pub fn new() -> Arc<StageCells> {
        Arc::new(StageCells::default())
    }

    pub fn add_us(&self, stage: Stage, us: u64) {
        self.us[stage.index()].fetch_add(us, Ordering::Relaxed);
    }

    pub fn get_us(&self, stage: Stage) -> u64 {
        self.us[stage.index()].load(Ordering::Relaxed)
    }
}

/// One recorded span: stage plus µs offsets relative to trace start.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub stage: Stage,
    pub start_us: u64,
    pub dur_us: u64,
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// A client-supplied request ID is adopted only if it is short and
/// plain ASCII — anything else gets a generated ID (the raw value
/// would otherwise flow into headers and logs).
fn valid_client_id(s: &str) -> bool {
    !s.is_empty()
        && s.len() <= 64
        && s.bytes().all(|b| {
            b.is_ascii_alphanumeric() || matches!(b, b'-' | b'_' | b'.' | b':')
        })
}

/// Per-connection reusable trace builder: the ID string and span buffer
/// keep their capacity across requests, so steady-state tracing is
/// allocation-free once warm.
pub struct TraceBuilder {
    id: String,
    spans: Vec<Span>,
    started: Option<Instant>,
}

impl TraceBuilder {
    pub fn new() -> TraceBuilder {
        TraceBuilder {
            id: String::with_capacity(32),
            spans: Vec::with_capacity(N_STAGES),
            started: None,
        }
    }

    /// Open a trace at `start` (the request's first byte). Adopts a
    /// valid client ID, otherwise generates `req-<seq>`.
    pub fn begin(&mut self, client_id: Option<&str>, start: Instant) {
        self.spans.clear();
        self.id.clear();
        match client_id.filter(|s| valid_client_id(s)) {
            Some(cid) => self.id.push_str(cid),
            None => {
                use std::fmt::Write as _;
                let n = NEXT_ID.fetch_add(1, Ordering::Relaxed);
                let _ = write!(self.id, "req-{n:012x}");
            }
        }
        self.started = Some(start);
    }

    pub fn active(&self) -> bool {
        self.started.is_some()
    }

    pub fn id(&self) -> &str {
        &self.id
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// µs between trace start and `t` (0 when inactive or before start).
    pub fn offset_us(&self, t: Instant) -> u64 {
        match self.started {
            Some(t0) => t.saturating_duration_since(t0).as_micros() as u64,
            None => 0,
        }
    }

    /// Record a span from absolute instants.
    pub fn span(&mut self, stage: Stage, from: Instant, to: Instant) {
        if self.started.is_some() {
            let start_us = self.offset_us(from);
            let dur_us =
                to.saturating_duration_since(from).as_micros() as u64;
            self.spans.push(Span { stage, start_us, dur_us });
        }
    }

    /// Record a span from a µs offset + duration (worker-attributed
    /// stages whose absolute instants the connection thread never saw).
    pub fn span_us(&mut self, stage: Stage, start_us: u64, dur_us: u64) {
        if self.started.is_some() {
            self.spans.push(Span { stage, start_us, dur_us });
        }
    }

    /// Close the trace and return its wall time in µs.
    pub fn finish(&mut self, end: Instant) -> u64 {
        let total = self.offset_us(end);
        self.started = None;
        total
    }
}

impl Default for TraceBuilder {
    fn default() -> Self {
        TraceBuilder::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_match_latency_histogram() {
        let h = AtomicHistogram::new();
        h.record_us(0); // clamps to 1
        h.record_us(1);
        h.record_us(2);
        h.record_us(3);
        h.record_us(1_000_000);
        let snap = h.snapshot();
        assert_eq!(snap.count, 5);
        assert_eq!(snap.max_us, 1_000_000);
        // 0 and 1 land in bucket 0 (bound 1), 2 in bucket 1, 3 in 2
        assert_eq!(snap.buckets[0], 2);
        assert_eq!(snap.buckets[1], 1);
        assert_eq!(snap.buckets[2], 1);
        let last = snap.cumulative_buckets().last().unwrap();
        assert_eq!(last.1, snap.count,
                   "+Inf cumulative must equal count");
        // mirror the metrics::LatencyHistogram rule exactly
        let mut reference = crate::metrics::LatencyHistogram::default();
        for us in [0u64, 1, 2, 3, 1_000_000] {
            reference.record(Duration::from_micros(us));
        }
        let got: Vec<_> = snap.cumulative_buckets().collect();
        let want: Vec<_> = reference.cumulative_buckets().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn section_feeds_thread_accumulator() {
        let before = thread_stage_ns();
        let v = section(Stage::Fft, || {
            std::thread::sleep(Duration::from_millis(2));
            7
        });
        assert_eq!(v, 7);
        let after = thread_stage_ns();
        let idx = Stage::Fft.index();
        assert!(after[idx] > before[idx],
                "section must bump this thread's fft counter");
        assert_eq!(after[Stage::Gather.index()],
                   before[Stage::Gather.index()],
                   "other stages must stay put");
    }

    #[test]
    fn trace_builder_reuses_buffers_and_generates_ids() {
        let mut b = TraceBuilder::new();
        let t0 = Instant::now();
        b.begin(None, t0);
        assert!(b.id().starts_with("req-"), "generated id: {}", b.id());
        b.span_us(Stage::HttpParse, 0, 5);
        b.span_us(Stage::QueueWait, 5, 10);
        assert_eq!(b.spans().len(), 2);
        let total = b.finish(t0 + Duration::from_micros(40));
        assert_eq!(total, 40);
        assert!(!b.active());

        // client id adopted when valid, rejected when hostile
        b.begin(Some("abc-123.x:y"), t0);
        assert_eq!(b.id(), "abc-123.x:y");
        assert!(b.spans().is_empty(), "begin must clear prior spans");
        b.begin(Some("bad id with spaces\n"), t0);
        assert!(b.id().starts_with("req-"));
        let long = "x".repeat(65);
        b.begin(Some(&long), t0);
        assert!(b.id().starts_with("req-"));
    }

    #[test]
    fn spans_from_instants_are_relative_and_clamped() {
        let mut b = TraceBuilder::new();
        let t0 = Instant::now();
        b.begin(None, t0);
        let a = t0 + Duration::from_micros(10);
        let z = t0 + Duration::from_micros(25);
        b.span(Stage::Serialize, a, z);
        let s = b.spans()[0];
        assert_eq!(s.start_us, 10);
        assert_eq!(s.dur_us, 15);
        // a span "before" the trace start clamps to zero, no panic
        b.span(Stage::HttpParse, t0 - Duration::from_micros(5), t0);
        assert_eq!(b.spans()[1].start_us, 0);
    }

    #[test]
    fn stage_cells_accumulate_across_threads() {
        let cells = StageCells::new();
        let c2 = cells.clone();
        let h = std::thread::spawn(move || {
            c2.add_us(Stage::QueueWait, 30);
        });
        cells.add_us(Stage::QueueWait, 12);
        h.join().unwrap();
        assert_eq!(cells.get_us(Stage::QueueWait), 42);
        assert_eq!(cells.get_us(Stage::Fft), 0);
    }

    #[test]
    fn quantiles_and_means_are_sane() {
        let h = AtomicHistogram::new();
        assert_eq!(h.snapshot().quantile_us(0.5), 0);
        for _ in 0..90 {
            h.record_us(100);
        }
        for _ in 0..10 {
            h.record_us(10_000);
        }
        let snap = h.snapshot();
        assert_eq!(snap.quantile_us(0.5), 128, "p50 bucket bound");
        assert_eq!(snap.quantile_us(0.99), 16_384, "p99 bucket bound");
        assert!((snap.mean_us() - 1090.0).abs() < 1e-9);
    }
}
