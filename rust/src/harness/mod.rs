//! Experiment harness: regenerates every table/figure of the paper
//! (DESIGN.md §5 experiment index). Shared by `examples/*` and `benches/*`.
//!
//! Each `run_table*` function trains/evaluates the full grid of that table
//! and returns printable rows; `render_table` formats them the way the
//! paper lays the table out, with the paper's reported numbers alongside
//! for shape comparison (EXPERIMENTS.md records both).

use std::collections::BTreeMap;

use crate::native::{TaskKind, TrainConfig};
use crate::obs::log::{self as obs_log, Level};
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::train::{native_spec, run_training, NativeTrainer, Schedule,
                   TrainOptions};
#[cfg(feature = "pjrt")]
use crate::train::Trainer;
use crate::Result;

/// One result row of a reproduction table.
#[derive(Debug, Clone)]
pub struct Row {
    pub model: String,
    pub setting: String,     // pool type / LM type
    pub mechanism: String,
    pub learnable: String,   // parameter-budget formula
    pub complexity: String,
    pub memory: String,
    pub metric_name: &'static str,
    pub metric: f64,
    pub paper_metric: Option<f64>,
    pub steps_per_sec: f64,
    pub diverged: bool,
    /// Whole-model learnable scalars (0 when unknown).
    pub params: usize,
}

/// Paper-reported numbers for shape comparison (Tables 1-3).
pub fn paper_reference() -> BTreeMap<&'static str, f64> {
    BTreeMap::from([
        ("vit_b_token_attention", 0.574), ("vit_b_token_cat", 0.540),
        ("vit_b_token_cat_alter", 0.582), ("vit_l_token_attention", 0.574),
        ("vit_l_token_cat", 0.559), ("vit_l_token_cat_alter", 0.593),
        ("vit_b_avg_attention", 0.638), ("vit_b_avg_cat", 0.649),
        ("vit_b_avg_cat_alter", 0.662), ("vit_l_avg_attention", 0.646),
        ("vit_l_avg_cat", 0.694), ("vit_l_avg_cat_alter", 0.681),
        ("lm_txl_masked_attention", 13.94), ("lm_txl_masked_cat", 10.28),
        ("lm_txl_masked_cat_alter", 8.51),
        ("lm_gpt2_masked_attention", 9.82), ("lm_gpt2_masked_cat", 8.32),
        ("lm_gpt2_masked_cat_alter", 7.54),
        ("lm_txl_causal_attention", 30.82), ("lm_txl_causal_cat", 36.71),
        ("lm_txl_causal_cat_alter", 30.93),
        ("lm_gpt2_causal_attention", 27.84), ("lm_gpt2_causal_cat", 32.36),
        ("lm_gpt2_causal_cat_alter", 27.68),
        ("vit_l_avg_cat_qkv", 0.696), ("vit_l_avg_cat_q", 0.637),
        ("vit_l_avg_cat_v", 0.625),
    ])
}

// Mechanism labels, paper param-count formulas, and complexity columns
// all come from the mixer registry — the single source of truth shared
// with the trainer, CLI, and serving layer.
use crate::native::mixer::{budget_formula, complexity_cols};

/// Train one config and evaluate; shared by every table driver.
#[cfg(feature = "pjrt")]
pub fn run_one(rt: &Runtime, name: &str, steps: u64, seed: u64,
               eval_batches: u64) -> Result<Row> {
    let meta = rt.config(name)?.clone();
    let base_lr = if meta.is_vit() { 1e-3 } else { 1e-3 };
    let warmup = (steps / 10).max(1);
    let opts = TrainOptions {
        steps,
        schedule: Schedule::new(base_lr, warmup, steps),
        seed,
        eval_every: 0,
        eval_batches,
        log_every: (steps / 4).max(1),
        ..Default::default()
    };
    let mut trainer = Trainer::new(rt, name, seed)?;
    let report = trainer.run(&opts)?;
    let (metric_name, metric) = report
        .final_metric()
        .unwrap_or(("diverged", f64::NAN));
    let (cx, mem) = complexity_cols(&meta.mechanism, meta.causal);
    let parts: Vec<&str> = name.split('_').collect();
    Ok(Row {
        model: parts[..2.min(parts.len())].join("_"),
        setting: if meta.is_vit() { meta.pool.clone() }
                 else { meta.task[3..].to_string() },
        mechanism: meta.mechanism.clone(),
        learnable: budget_formula(&meta.mechanism).to_string(),
        complexity: cx.to_string(),
        memory: mem.to_string(),
        metric_name,
        metric,
        paper_metric: paper_reference().get(name).copied(),
        steps_per_sec: report.steps_per_sec(),
        diverged: report.diverged_at.is_some(),
        params: meta.param_count,
    })
}

/// Train one *native* config (hermetic — no artifacts) and produce a
/// table row. `paper_key` selects the paper-reference column.
pub fn run_native_cfg(label: &str, cfg: TrainConfig,
                      paper_key: Option<&str>, steps: u64, seed: u64,
                      eval_batches: u64) -> Result<Row> {
    let mut trainer = NativeTrainer::from_config(label, cfg, seed)?;
    let params = trainer.param_count();
    let opts = TrainOptions {
        steps,
        schedule: Schedule::new(1e-3, (steps / 10).max(1), steps),
        seed,
        eval_every: 0,
        eval_batches,
        log_every: (steps / 4).max(1),
        ..Default::default()
    };
    let report = run_training(&mut trainer, &opts)?;
    let (metric_name, metric) = report
        .final_metric()
        .unwrap_or(("diverged", f64::NAN));
    let mech = cfg.mechanism();
    let (cx, mem) = complexity_cols(&mech, cfg.causal());
    let (model, setting) = match cfg.task {
        TaskKind::Vit { .. } => ("native_vit".to_string(),
                                 "avg".to_string()),
        TaskKind::Lm { causal, .. } => (
            "native_lm".to_string(),
            if causal { "causal".to_string() } else { "masked".to_string() },
        ),
    };
    Ok(Row {
        model,
        setting,
        mechanism: mech.clone(),
        learnable: budget_formula(&mech).to_string(),
        complexity: cx.to_string(),
        memory: mem.to_string(),
        metric_name,
        metric,
        paper_metric: paper_key
            .and_then(|k| paper_reference().get(k).copied()),
        steps_per_sec: report.steps_per_sec(),
        diverged: report.diverged_at.is_some(),
        params,
    })
}

/// [`run_native_cfg`] via the [`native_spec`] registry.
pub fn run_native_one(name: &str, steps: u64, seed: u64,
                      eval_batches: u64) -> Result<Row> {
    let spec = native_spec(name).ok_or_else(|| {
        anyhow::anyhow!("unknown native config '{name}'")
    })?;
    run_native_cfg(name, spec.cfg, spec.paper_key, steps, seed,
                   eval_batches)
}

/// Run a grid of explicit `(label, config, paper_key)` entries (the
/// ablation benches build custom shapes) and collect rows.
pub fn run_native_cfgs(grid: &[(String, TrainConfig, Option<&str>)],
                       steps: u64, seed: u64, eval_batches: u64)
                       -> Result<Vec<Row>> {
    let mut rows = Vec::with_capacity(grid.len());
    for (label, cfg, paper_key) in grid {
        obs_log::log_fields(Level::Info, "harness", "grid entry",
                            &[("config", label),
                              ("steps", &steps.to_string()),
                              ("backend", "native")]);
        rows.push(run_native_cfg(label, *cfg, *paper_key, steps, seed,
                                 eval_batches)?);
    }
    Ok(rows)
}

/// Run a list of registry-named native configs and collect rows
/// (hermetic grid).
pub fn run_native_grid(names: &[&str], steps: u64, seed: u64,
                       eval_batches: u64) -> Result<Vec<Row>> {
    let grid: Vec<(String, TrainConfig, Option<&str>)> = names
        .iter()
        .map(|name| {
            native_spec(name)
                .map(|s| (name.to_string(), s.cfg, s.paper_key))
                .ok_or_else(|| {
                    anyhow::anyhow!("unknown native config '{name}'")
                })
        })
        .collect::<Result<_>>()?;
    run_native_cfgs(&grid, steps, seed, eval_batches)
}

/// Write the standard table-bench JSON artifact (`BENCH_table*.json`):
/// bench id + run config + [`rows_to_json`] rows. Shared by the three
/// table benches so the schema lives in one place.
pub fn write_bench_json(path: &str, bench: &str, smoke: bool, steps: u64,
                        rows: &[Row]) -> Result<()> {
    use crate::json::Json;
    let out = Json::Obj(vec![
        ("bench".into(), Json::from(bench)),
        ("backend".into(), Json::from("native")),
        ("smoke".into(), Json::Bool(smoke)),
        ("steps".into(), Json::Num(steps as f64)),
        ("rows".into(), rows_to_json(rows)),
    ]);
    std::fs::write(path, out.to_string_pretty())?;
    obs_log::log_fields(Level::Info, "harness", "results written",
                        &[("path", path), ("bench", bench)]);
    Ok(())
}

/// Table 1: ImageNet-proxy ViT grid.
pub fn table1_names(fast: bool) -> Vec<String> {
    let sizes: &[&str] = if fast { &["b"] } else { &["b", "l"] };
    let mut out = Vec::new();
    for size in sizes {
        for pool in ["token", "avg"] {
            for mech in ["attention", "cat", "cat_alter"] {
                out.push(format!("vit_{size}_{pool}_{mech}"));
            }
        }
    }
    out
}

/// Table 2: WikiText-proxy LM grid.
pub fn table2_names(fast: bool) -> Vec<String> {
    let archs: &[&str] = if fast { &["gpt2"] } else { &["txl", "gpt2"] };
    let mut out = Vec::new();
    for arch in archs {
        for task in ["masked", "causal"] {
            for mech in ["attention", "cat", "cat_alter"] {
                out.push(format!("lm_{arch}_{task}_{mech}"));
            }
        }
    }
    out
}

/// Table 3 / Fig. 2: ablation grid (ViT-L proxy, avg pool).
pub fn table3_names() -> Vec<String> {
    vec![
        "vit_l_avg_attention".into(),
        "vit_l_avg_cat_qkv".into(),
        "vit_l_avg_cat".into(),
        "vit_l_avg_cat_q".into(),
        "vit_l_avg_cat_v".into(),
    ]
}

/// Run a list of configs and collect rows.
#[cfg(feature = "pjrt")]
pub fn run_grid(rt: &Runtime, names: &[String], steps: u64, seed: u64,
                eval_batches: u64) -> Result<Vec<Row>> {
    let mut rows = Vec::with_capacity(names.len());
    for name in names {
        obs_log::log_fields(Level::Info, "harness", "grid entry",
                            &[("config", name),
                              ("steps", &steps.to_string()),
                              ("backend", "pjrt")]);
        rows.push(run_one(rt, name, steps, seed, eval_batches)?);
    }
    Ok(rows)
}

/// Render rows in the paper's table layout.
pub fn render_table(title: &str, rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!("\n{title}\n"));
    s.push_str(&format!(
        "{:<10} {:<8} {:<11} {:<11} {:<12} {:<9} {:>9} {:>9} {:>8}\n",
        "model", "setting", "mechanism", "learnable", "complexity",
        "memory", "ours", "paper", "step/s"));
    s.push_str(&"-".repeat(95));
    s.push('\n');
    for r in rows {
        let ours = if r.diverged {
            "NaN".to_string()
        } else if r.metric_name == "ppl" {
            format!("{:.2}", r.metric)
        } else {
            format!("{:.3}", r.metric)
        };
        let paper = r
            .paper_metric
            .map(|p| format!("{p:.3}"))
            .unwrap_or_else(|| "-".into());
        s.push_str(&format!(
            "{:<10} {:<8} {:<11} {:<11} {:<12} {:<9} {:>9} {:>9} {:>8.2}\n",
            r.model, r.setting, r.mechanism, r.learnable, r.complexity,
            r.memory, ours, paper, r.steps_per_sec));
    }
    s
}

/// Serialize rows as JSON for EXPERIMENTS.md tooling.
pub fn rows_to_json(rows: &[Row]) -> crate::json::Json {
    use crate::json::Json;
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::Obj(vec![
                    ("model".into(), Json::from(r.model.as_str())),
                    ("setting".into(), Json::from(r.setting.as_str())),
                    ("mechanism".into(), Json::from(r.mechanism.as_str())),
                    ("metric_name".into(), Json::from(r.metric_name)),
                    ("metric".into(), if r.metric.is_finite() {
                        Json::Num(r.metric)
                    } else {
                        Json::Null
                    }),
                    ("paper".into(), r.paper_metric
                        .map(Json::Num).unwrap_or(Json::Null)),
                    ("steps_per_sec".into(), Json::Num(r.steps_per_sec)),
                    ("diverged".into(), Json::Bool(r.diverged)),
                    ("params".into(), Json::Num(r.params as f64)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_cover_the_paper() {
        assert_eq!(table1_names(false).len(), 12);
        assert_eq!(table2_names(false).len(), 12);
        assert_eq!(table3_names().len(), 5);
        // paper reference covers every grid entry
        let refs = paper_reference();
        for n in table1_names(false)
            .iter()
            .chain(table2_names(false).iter())
            .chain(table3_names().iter()) {
            assert!(refs.contains_key(n.as_str()), "{n} missing");
        }
    }

    #[test]
    fn budget_formulas_come_from_the_registry() {
        assert_eq!(budget_formula("cat"), "(d+h)d");
        assert_eq!(budget_formula("attention"), "3d^2");
        assert_eq!(budget_formula("fnet"), "0");
        assert_eq!(budget_formula("circulant"), "3d^2");
        assert_eq!(budget_formula("cat_alter"), "(2d+h/2)d");
        assert_eq!(complexity_cols("fnet", false), ("O(N log N)", "O(N)"));
        assert_eq!(complexity_cols("cat", true), ("O(N log N)*", "O(N)"));
    }

    #[test]
    fn render_handles_divergence() {
        let row = Row {
            model: "vit_l".into(), setting: "avg".into(),
            mechanism: "linear".into(), learnable: "3d^2".into(),
            complexity: "O(N)".into(), memory: "O(N)".into(),
            metric_name: "acc", metric: f64::NAN, paper_metric: None,
            steps_per_sec: 1.0, diverged: true, params: 0,
        };
        let s = render_table("t", &[row]);
        assert!(s.contains("NaN"));
    }
}
