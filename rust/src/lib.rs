//! # cat-transformer — CAT: Circular-Convolutional Attention
//!
//! Rust + JAX + Pallas reproduction of *"CAT: Circular-Convolutional
//! Attention for Sub-Quadratic Transformers"* (Yamada, NIPS 2025).
//!
//! Three-layer architecture (see `DESIGN.md`):
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the circulant
//!   gather/FFT applies, fused attention baseline, LayerNorm.
//! * **L2** — JAX model zoo (`python/compile/`): ViT + masked/causal LM over
//!   six attention mechanisms, AdamW train step; AOT-lowered to HLO text.
//! * **L3** — this crate: the coordinator. It owns the execution backends
//!   (the PJRT runtime in [`runtime`], feature `pjrt`, and the native
//!   Rust CAT-FFT executor in [`native`]), the synthetic data substrates
//!   the paper's benchmarks need ([`data`]), the training orchestrator
//!   ([`train`]), a serving router + dynamic batcher ([`coordinator`]),
//!   metrics ([`metrics`]), and the analytic complexity models behind
//!   Fig. 1 ([`complexity`]).
//!
//! Python never runs on the request path. With the `pjrt` feature,
//! `make artifacts` lowers every model once and the binaries load
//! `artifacts/*.hlo.txt` through the `xla` crate's PJRT CPU client. The
//! default build has no artifact dependency at all: the native backend
//! ([`native`], selected through [`runtime::Backend`]) computes CAT's
//! forward pass — planned real-FFT circular convolution included — in
//! pure Rust, and since PR 3 also its *backward* pass
//! ([`native::autograd`] + [`native::optim`], DESIGN.md §8), so
//! serving, the scaling benches, and end-to-end training (`cat train`,
//! the table benches) all run in a fresh checkout.

pub mod bench;
pub mod cli;
pub mod complexity;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod json;
pub mod metrics;
pub mod native;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;

/// Crate-wide result type (anyhow for rich error reports).
pub type Result<T> = anyhow::Result<T>;

/// Default artifact directory, overridable with `CAT_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("CAT_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
