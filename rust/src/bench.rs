//! Micro-benchmark harness (criterion replacement for the offline build):
//! warmup + fixed-sample timing with mean/median/p10/p90 reporting and a
//! machine-readable JSON dump.
//!
//! Every `benches/*.rs` target sets `harness = false` and drives this from
//! its `main()`. Methodology: `warmup` untimed iterations, then `samples`
//! timed iterations; the median is the headline number (robust to OS
//! scheduling noise on the single-core testbed).

use std::time::Instant;

use crate::json::Json;

/// Shared `CAT_SKIP_TIMING` gate for wallclock-sensitive assertions —
/// the one parser for the variable (tests/native_backend.rs consults
/// it; the bench `--check` gates deliberately do *not*, since they are
/// the dedicated perf-smoke timing job): any non-empty value other
/// than `0` / `false` (case-insensitive) skips — `CAT_SKIP_TIMING=1`,
/// `=true` and `=yes` all work; unset, empty, `0` and `false` run the
/// timings.
pub fn skip_timing() -> bool {
    match std::env::var("CAT_SKIP_TIMING") {
        Ok(v) => {
            let v = v.trim().to_ascii_lowercase();
            !(v.is_empty() || v == "0" || v == "false")
        }
        Err(_) => false,
    }
}

/// Parse + validate a bench binary's argv: only the given switches and
/// valued flags are accepted (plus cargo's own `--bench` passthrough);
/// anything else — e.g. a `--chekc` typo — prints the usage line and
/// exits 2 instead of silently running the default sweep. Positionals
/// (cargo test-filter strings) pass through untouched.
pub fn bench_args(bench: &str, switches: &[&str], valued: &[&str])
                  -> crate::cli::Args {
    let mut known: Vec<&str> = switches.to_vec();
    known.push("bench");
    // benches are interactive tools: default their log level to info so
    // harness progress banners stay visible (CAT_LOG still wins)
    if std::env::var_os("CAT_LOG").is_none() {
        crate::obs::log::set_level(crate::obs::log::Level::Info);
    }
    let parsed = crate::cli::parse(valued)
        .and_then(|a| a.expect_no_unknown(&known, valued).map(|()| a));
    match parsed {
        Ok(a) => a,
        Err(e) => {
            let mut parts: Vec<String> =
                switches.iter().map(|s| format!("[--{s}]")).collect();
            parts.extend(valued.iter().map(|v| format!("[--{v} N]")));
            eprintln!("error: {e:#}");
            eprintln!("usage: cargo bench --bench {bench} -- {}",
                      parts.join(" "));
            std::process::exit(2);
        }
    }
}

/// Synthesize one literal per input spec of an AOT entry point (shared by
/// the PJRT bench drivers): small-amplitude normal noise, deterministic
/// in `seed`.
#[cfg(feature = "pjrt")]
pub fn entry_inputs(entry: &crate::runtime::EntryMeta, seed: u64)
                    -> Vec<xla::Literal> {
    let mut rng = crate::data::Rng::new(seed);
    entry
        .inputs
        .iter()
        .map(|spec| {
            let data: Vec<f32> = (0..spec.num_elements())
                .map(|_| 0.05 * rng.normal())
                .collect();
            crate::tensor::HostTensor::f32(spec.shape.clone(), data)
                .expect("bench input tensor")
                .to_literal()
                .expect("bench input literal")
        })
        .collect()
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl Sample {
    pub fn median(&self) -> f64 {
        percentile(&self.samples, 0.5)
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    pub fn p10(&self) -> f64 {
        percentile(&self.samples, 0.10)
    }

    pub fn p90(&self) -> f64 {
        percentile(&self.samples, 0.90)
    }
}

fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("finite timings"));
    let idx = ((v.len() - 1) as f64 * q).round() as usize;
    v[idx]
}

/// A group of related benchmark cases, printed as one table.
pub struct Bench {
    title: String,
    pub warmup: usize,
    pub samples: usize,
    results: Vec<Sample>,
}

impl Bench {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            warmup: 2,
            samples: 10,
            results: Vec::new(),
        }
    }

    /// Time `f` (one call = one iteration).
    pub fn case<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Sample {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        eprintln!("  {name:<34} median {:>10.3} ms  (p10 {:>8.3} / p90 \
                   {:>8.3})",
                  percentile(&samples, 0.5) * 1e3,
                  percentile(&samples, 0.1) * 1e3,
                  percentile(&samples, 0.9) * 1e3);
        self.results.push(Sample { name: name.to_string(), samples });
        self.results.last().expect("just pushed")
    }

    pub fn median_of(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|s| s.name == name).map(|s| s.median())
    }

    pub fn results(&self) -> &[Sample] {
        &self.results
    }

    /// Formatted summary table.
    pub fn report(&self) -> String {
        let mut s = format!("\n== {} ==\n", self.title);
        s.push_str(&format!("{:<36} {:>12} {:>12} {:>12}\n",
                            "case", "median ms", "mean ms", "p90 ms"));
        for r in &self.results {
            s.push_str(&format!("{:<36} {:>12.3} {:>12.3} {:>12.3}\n",
                                r.name, r.median() * 1e3, r.mean() * 1e3,
                                r.p90() * 1e3));
        }
        s
    }

    /// JSON dump for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("title".into(), Json::from(self.title.as_str())),
            ("results".into(), Json::Arr(
                self.results
                    .iter()
                    .map(|r| Json::Obj(vec![
                        ("name".into(), Json::from(r.name.as_str())),
                        ("median_s".into(), Json::Num(r.median())),
                        ("mean_s".into(), Json::Num(r.mean())),
                    ]))
                    .collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_ordering() {
        let xs = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
    }

    #[test]
    fn case_runs_expected_iterations() {
        let mut bench = Bench::new("t");
        bench.warmup = 1;
        bench.samples = 5;
        let mut count = 0;
        bench.case("counter", || {
            count += 1;
        });
        assert_eq!(count, 6);
        assert_eq!(bench.results()[0].samples.len(), 5);
        assert!(bench.median_of("counter").is_some());
        assert!(bench.report().contains("counter"));
    }

    #[test]
    fn json_dump_parses() {
        let mut bench = Bench::new("t");
        bench.warmup = 0;
        bench.samples = 2;
        bench.case("x", || {});
        let parsed = crate::json::parse(&bench.to_json().to_string()).unwrap();
        assert_eq!(parsed.req("title").unwrap().as_str().unwrap(), "t");
    }
}
