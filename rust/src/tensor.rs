//! Host-side tensors: the typed currency of the coordinator — batches in,
//! logits out — and (with the `pjrt` feature) the bridge to `xla::Literal`
//! device buffers.
//!
//! Kept deliberately small — shape + flat data, f32 or i32 — because every
//! heavy computation happens inside an execution backend (AOT executables
//! or `crate::native`); the host only assembles batches, reads back
//! logits/losses, and computes metrics.

use crate::Result;
use anyhow::{anyhow, bail};

/// Element type of a [`HostTensor`]. Mirrors the manifest's dtype strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_manifest(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype in manifest: {other}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// Flat data buffer.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor: shape plus contiguous row-major data.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} ({n}) != data len {}", shape, data.len());
        }
        Ok(Self { shape, data: TensorData::F32(data) })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} ({n}) != data len {}", shape, data.len());
        }
        Ok(Self { shape, data: TensorData::I32(data) })
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: TensorData::F32(vec![0.0; n]) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self { shape: vec![], data: TensorData::I32(vec![v]) }
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Convert to an `xla::Literal` (copies into XLA-managed memory).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
            TensorData::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Read a literal back into host memory.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                HostTensor::f32(dims, lit.to_vec::<f32>()?)
            }
            xla::ElementType::S32 => {
                HostTensor::i32(dims, lit.to_vec::<i32>()?)
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    /// Scalar f32 view (loss read-back).
    pub fn scalar_value_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, got shape {:?}", self.shape);
        }
        Ok(v[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_rejected() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(HostTensor::i32(vec![2], vec![1, 2]).is_ok());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_f32(3.5);
        assert_eq!(t.len(), 1);
        assert_eq!(t.scalar_value_f32().unwrap(), 3.5);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::from_manifest("f32").unwrap(), DType::F32);
        assert_eq!(DType::from_manifest("i32").unwrap(), DType::I32);
        assert!(DType::from_manifest("f64").is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], (0..6).map(|i| i as f32).collect())
            .unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(vec![4], vec![1, -2, 3, -4]).unwrap();
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }
}
