//! Analytic cost models behind Fig. 1 and the complexity columns of
//! Tables 1-3: FLOPs and peak activation memory of one token-mixing layer
//! for each mechanism, as a function of (N, D, H).
//!
//! These are the formulas the paper argues from — O(N^2 D) attention vs
//! O(N log N · D) CAT — made concrete so `cargo bench --bench
//! scaling_nlogn` can print the predicted series next to the measured
//! wallclock and EXPERIMENTS.md can report where the crossover falls.

/// Mechanism identifiers shared with the artifact registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mechanism {
    Attention,
    CatGather,
    CatFft,
    Linear,
}

impl Mechanism {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "attention" => Self::Attention,
            "cat_gather" | "gather" => Self::CatGather,
            "cat_fft" | "cat" | "fft" => Self::CatFft,
            "linear" => Self::Linear,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Attention => "attention",
            Self::CatGather => "cat_gather",
            Self::CatFft => "cat_fft",
            Self::Linear => "linear",
        }
    }
}

/// Cost of one mixing layer (forward), in FLOPs and f32 activation bytes.
#[derive(Debug, Clone, Copy)]
pub struct LayerCost {
    pub flops: f64,
    pub mem_bytes: f64,
    pub learnable_params: f64,
}

/// FLOP/memory model for one layer. Conventions: a multiply-add = 2 FLOPs;
/// FFT of length n costs 5 n log2 n FLOPs (standard radix-2 accounting);
/// projections count d->d matmuls at 2 N D^2.
pub fn layer_cost(mech: Mechanism, n: usize, d: usize, h: usize) -> LayerCost {
    let nf = n as f64;
    let df = d as f64;
    let hf = h as f64;
    let proj = 2.0 * nf * df * df; // one D x D projection over N tokens
    match mech {
        Mechanism::Attention => LayerCost {
            // q,k,v projections + QK^T + softmax + PV
            flops: 3.0 * proj + 2.0 * nf * nf * df * 2.0 + 5.0 * nf * nf,
            // N x N attention matrix dominates
            mem_bytes: 4.0 * (nf * nf + 3.0 * nf * df),
            learnable_params: 3.0 * df * df,
        },
        Mechanism::CatGather => LayerCost {
            // W_A (d->h) + W_V + the N x N circulant apply (no qk matmul,
            // no softmax over N^2 — softmax is over N only)
            flops: proj + 2.0 * nf * df * hf + 2.0 * nf * nf * df + 5.0 * nf * hf,
            // the rolled panel is materialized blockwise: block_i x N per
            // program, never the full N x N in HBM; host model counts the
            // VMEM-resident panel
            mem_bytes: 4.0 * (64.0_f64.min(nf) * nf + 2.0 * nf * df),
            learnable_params: (df + hf) * df,
        },
        Mechanism::CatFft => {
            // rfft(z): H transforms of length N; rfft(V)/irfft: D channels
            let fft = 5.0 * nf * (nf.log2().max(1.0)) * (hf + 2.0 * df);
            LayerCost {
                flops: proj + 2.0 * nf * df * hf + fft + 6.0 * nf * df,
                mem_bytes: 4.0 * (3.0 * nf * df),
                learnable_params: (df + hf) * df,
            }
        }
        Mechanism::Linear => LayerCost {
            // q,k,v projections + two N d_h^2 contractions per head
            flops: 3.0 * proj + 4.0 * nf * df * (df / hf),
            mem_bytes: 4.0 * (3.0 * nf * df + df * df / hf),
            learnable_params: 3.0 * df * df,
        },
    }
}

/// The smallest power-of-two N (searched up to 2^23) at which CAT-FFT's
/// modeled FLOPs drop below attention's; `None` if no crossover occurs in
/// that range (sentinel-free by design — callers must handle the miss).
pub fn crossover_n(d: usize, h: usize) -> Option<usize> {
    (3..24).map(|p| 1usize << p).find(|&n| {
        let a = layer_cost(Mechanism::Attention, n, d, h).flops;
        let c = layer_cost(Mechanism::CatFft, n, d, h).flops;
        c < a
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attention_is_quadratic_in_n() {
        let c1 = layer_cost(Mechanism::Attention, 256, 512, 8).flops;
        let c2 = layer_cost(Mechanism::Attention, 1024, 512, 8).flops;
        // x4 N with N^2 term dominant at large N: ratio between 4 and 16
        assert!(c2 / c1 > 4.0 && c2 / c1 <= 16.0, "ratio {}", c2 / c1);
    }

    #[test]
    fn cat_fft_subquadratic() {
        // doubling N should grow CAT-FFT by barely more than 2x at large N
        let c1 = layer_cost(Mechanism::CatFft, 4096, 256, 8).flops;
        let c2 = layer_cost(Mechanism::CatFft, 8192, 256, 8).flops;
        assert!(c2 / c1 < 2.4, "ratio {}", c2 / c1);
    }

    #[test]
    fn cat_beats_attention_at_large_n() {
        let n = 8192;
        let a = layer_cost(Mechanism::Attention, n, 512, 8);
        let c = layer_cost(Mechanism::CatFft, n, 512, 8);
        assert!(c.flops < a.flops);
        assert!(c.mem_bytes < a.mem_bytes);
    }

    #[test]
    fn param_budgets_match_paper() {
        let d = 1024usize;
        let h = 16usize;
        let a = layer_cost(Mechanism::Attention, 256, d, h).learnable_params;
        let c = layer_cost(Mechanism::CatFft, 256, d, h).learnable_params;
        assert_eq!(a, 3.0 * (d * d) as f64);
        assert_eq!(c, ((d + h) * d) as f64);
    }

    #[test]
    fn crossover_is_finite_and_moderate() {
        let n = crossover_n(512, 8).expect("crossover exists for d=512 h=8");
        assert!(n < 16384, "crossover {n}");
        // CAT-FFT must actually be cheaper at (and past) the crossover
        let a = layer_cost(Mechanism::Attention, n, 512, 8).flops;
        let c = layer_cost(Mechanism::CatFft, n, 512, 8).flops;
        assert!(c < a);
    }

    #[test]
    fn mechanism_parse_roundtrip() {
        for m in [Mechanism::Attention, Mechanism::CatGather,
                  Mechanism::CatFft, Mechanism::Linear] {
            assert_eq!(Mechanism::parse(m.name()), Some(m));
        }
        assert_eq!(Mechanism::parse("nope"), None);
    }
}
