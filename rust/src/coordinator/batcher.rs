//! Dynamic batcher: vLLM-router-style request coalescing for the PJRT
//! executables, which are compiled for a fixed batch size.
//!
//! Policy: a batch flushes when (a) it reaches `max_batch` requests, or
//! (b) the oldest queued request has waited `max_delay`. Short batches are
//! padded up to `max_batch` with repeats of the last row (the pad rows'
//! outputs are discarded), so the fixed-shape executable always sees a
//! full batch. FIFO order is preserved end-to-end.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A queued request with its enqueue timestamp and sequence number.
#[derive(Debug, Clone)]
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
    pub seq: u64,
}

/// Decision returned by [`DynamicBatcher::poll`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Flush {
    /// Not enough work and nothing has waited long enough.
    Wait(Duration),
    /// Emit a batch of this many queued requests (<= max_batch).
    Emit(usize),
    /// Queue empty.
    Idle,
}

/// Size+deadline dynamic batcher over opaque payloads.
#[derive(Debug)]
pub struct DynamicBatcher<T> {
    queue: VecDeque<Pending<T>>,
    pub max_batch: usize,
    pub max_delay: Duration,
    next_seq: u64,
    /// statistics
    pub emitted_batches: u64,
    pub emitted_requests: u64,
}

impl<T> DynamicBatcher<T> {
    pub fn new(max_batch: usize, max_delay: Duration) -> Self {
        assert!(max_batch > 0);
        Self {
            queue: VecDeque::new(),
            max_batch,
            max_delay,
            next_seq: 0,
            emitted_batches: 0,
            emitted_requests: 0,
        }
    }

    pub fn push(&mut self, payload: T) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(Pending { payload, enqueued: Instant::now(), seq });
        seq
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should we flush now? (Does not pop.)
    ///
    /// The deadline is **inclusive**: a poll landing exactly on
    /// `oldest.enqueued + max_delay` emits. The `>=` below is
    /// load-bearing — with a strict `>`, the boundary instant would
    /// return `Wait(0)`, and the server loop's `recv_timeout(0)` would
    /// spin on the same instant instead of flushing
    /// (`deadline_exact_boundary_flushes_not_waits` pins this). A
    /// returned `Wait(d)` therefore always has `d > 0`.
    pub fn poll(&self, now: Instant) -> Flush {
        let Some(oldest) = self.queue.front() else {
            return Flush::Idle;
        };
        if self.queue.len() >= self.max_batch {
            return Flush::Emit(self.max_batch);
        }
        let waited = now.duration_since(oldest.enqueued);
        if waited >= self.max_delay {
            return Flush::Emit(self.queue.len());
        }
        Flush::Wait(self.max_delay - waited)
    }

    /// Pop up to `n` requests in FIFO order.
    pub fn take(&mut self, n: usize) -> Vec<Pending<T>> {
        let n = n.min(self.queue.len());
        let out: Vec<Pending<T>> = self.queue.drain(..n).collect();
        self.emitted_batches += 1;
        self.emitted_requests += out.len() as u64;
        out
    }

    /// Mean occupancy of emitted batches (batching efficiency metric).
    pub fn mean_occupancy(&self) -> f64 {
        if self.emitted_batches == 0 {
            0.0
        } else {
            self.emitted_requests as f64
                / (self.emitted_batches as f64 * self.max_batch as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_when_full() {
        let mut b = DynamicBatcher::new(4, Duration::from_secs(3600));
        for i in 0..4 {
            b.push(i);
        }
        assert_eq!(b.poll(Instant::now()), Flush::Emit(4));
        let taken = b.take(4);
        assert_eq!(taken.iter().map(|p| p.payload).collect::<Vec<_>>(),
                   vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn waits_then_deadline_flushes_partial() {
        let mut b = DynamicBatcher::new(8, Duration::from_millis(5));
        b.push("a");
        match b.poll(Instant::now()) {
            Flush::Wait(d) => assert!(d <= Duration::from_millis(5)),
            other => panic!("expected Wait, got {other:?}"),
        }
        let later = Instant::now() + Duration::from_millis(6);
        assert_eq!(b.poll(later), Flush::Emit(1));
    }

    #[test]
    fn deadline_exact_boundary_flushes_not_waits() {
        let mut b = DynamicBatcher::new(8, Duration::from_millis(5));
        b.push(1u8);
        let enq = b.queue.front().expect("just pushed").enqueued;
        // exactly on the deadline: must emit — a zero-duration Wait here
        // would make the serving loop recv_timeout(0) against the same
        // instant forever
        assert_eq!(b.poll(enq + Duration::from_millis(5)), Flush::Emit(1));
        // past the deadline: still emits
        assert_eq!(b.poll(enq + Duration::from_millis(6)), Flush::Emit(1));
        // one tick before: waits, and the wait is strictly positive
        let just_before =
            enq + Duration::from_millis(5) - Duration::from_nanos(1);
        match b.poll(just_before) {
            Flush::Wait(d) => assert!(d > Duration::ZERO,
                                      "zero-duration wait would spin"),
            other => panic!("expected Wait just before deadline, got \
                             {other:?}"),
        }
        // a clock reading from before the enqueue saturates to a full wait
        // (Instant::duration_since clamps negative spans to zero)
        match b.poll(enq - Duration::from_nanos(1)) {
            Flush::Wait(d) => assert_eq!(d, Duration::from_millis(5)),
            other => panic!("expected full Wait before enqueue time, got \
                             {other:?}"),
        }
    }

    #[test]
    fn idle_when_empty() {
        let b: DynamicBatcher<u8> = DynamicBatcher::new(4,
            Duration::from_millis(1));
        assert_eq!(b.poll(Instant::now()), Flush::Idle);
    }

    #[test]
    fn fifo_and_seq_monotone() {
        let mut b = DynamicBatcher::new(2, Duration::from_millis(1));
        let s0 = b.push(10);
        let s1 = b.push(11);
        assert!(s0 < s1);
        let taken = b.take(2);
        assert_eq!(taken[0].seq, s0);
        assert_eq!(taken[1].seq, s1);
    }

    #[test]
    fn occupancy_tracks_emissions() {
        let mut b = DynamicBatcher::new(4, Duration::from_millis(1));
        for i in 0..6 {
            b.push(i);
        }
        b.take(4);
        b.take(2);
        assert!((b.mean_occupancy() - 6.0 / 8.0).abs() < 1e-9);
    }
}
