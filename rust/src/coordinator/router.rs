//! Replica routing: the data-parallel half of sharded serving
//! (DESIGN.md §10).
//!
//! Each model runs R replica workers behind the router, every replica
//! with its own bounded queue. Dispatch is rotating round-robin over the
//! replicas the health monitor considers live, probing with `try_send`
//! so a saturated replica is skipped rather than blocked on:
//!
//! * every live replica full → the request is rejected with
//!   [`ServeError::Busy`] carrying a retry-after hint (the batcher's
//!   flush cadence) — **backpressure is an explicit, immediate signal**,
//!   not an ever-growing queue;
//! * a replica whose queue endpoint is gone (worker thread died) is
//!   marked dead on the spot and never routed to again;
//! * no live replica at all → [`ServeError::Failed`], a terminal error.
//!
//! The health monitor thread pings every replica each `health_every`
//! through the same queue the requests use (so a ping measures real
//! dequeue latency). Pings are only sent to **idle** replicas (queue
//! depth 0): a replica holding queued work is demonstrably accepting
//! requests, and a ping behind its backlog would measure queue length,
//! not health — loaded-but-live replicas must never be routed around
//! (saturation is backpressure's business; a dead replica still
//! surfaces immediately through its disconnected queue endpoint). For
//! an idle replica, a reply within `ping_timeout` marks it healthy and
//! [`MAX_MISSED_PINGS`] consecutive timeouts mark it unhealthy —
//! skipped by dispatch until a later ping succeeds, so slow replicas
//! heal themselves.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::tensor::HostTensor;

use super::server::InferRequest;

/// Consecutive ping timeouts before a replica is routed around.
pub const MAX_MISSED_PINGS: u32 = 3;

/// Typed serving error. The vendored `anyhow` deliberately has no
/// downcasting, so backpressure is a dedicated variant on a dedicated
/// type rather than a string to be sniffed: [`ServeHandle::try_infer`]
/// surfaces it directly, and `ServeHandle::infer` retries `Busy` with
/// the embedded hint.
///
/// [`ServeHandle::try_infer`]: super::server::ServeHandle::try_infer
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Every live replica's queue is full; retry after the hint.
    Busy { retry_after: Duration },
    /// The request failed terminally (unknown model, dead replicas,
    /// executor error).
    Failed(String),
    /// The caller's deadline expired before a result arrived (the HTTP
    /// layer's per-request timeout → 504). The request may still
    /// complete server-side; its response is discarded.
    DeadlineExceeded,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Busy { retry_after } => {
                write!(f, "server busy: every replica queue is full \
                           (retry after {retry_after:?})")
            }
            ServeError::Failed(msg) => f.write_str(msg),
            ServeError::DeadlineExceeded => {
                f.write_str("request deadline exceeded")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// A rejected request: the typed error plus — whenever the rejecting
/// side still owned it — the original input handed back, so retrying
/// callers (`ServeHandle::infer`) never clone tensors on the hot path.
/// `Busy` rejections always return the input; terminal failures may
/// not (an executor error consumed it).
#[derive(Debug)]
pub struct Rejection {
    pub error: ServeError,
    pub input: Option<HostTensor>,
}

impl Rejection {
    pub(crate) fn terminal(error: ServeError) -> Rejection {
        Rejection { error, input: None }
    }
}

/// What flows through a replica's queue: client work or a monitor ping.
pub(crate) enum WorkerMsg {
    Infer(InferRequest),
    /// Health probe; the worker replies on dequeue. The sender is
    /// unbounded so the reply can never block the worker.
    Ping(mpsc::Sender<()>),
}

/// Shared liveness/health state of one replica.
///
/// `alive` is permanent-once-false (the queue endpoint is gone);
/// `healthy` is the monitor's recoverable verdict; `depth` counts
/// router-dispatched requests not yet *completed* — incremented before
/// the dispatch send (and undone if the send fails) and decremented
/// only when the worker finishes the request, so queued **and
/// in-flight** work both register: the monitor must treat a replica
/// mid-way through a long batch as busy, not idle.
#[derive(Debug)]
pub(crate) struct ReplicaState {
    alive: AtomicBool,
    healthy: AtomicBool,
    depth: AtomicUsize,
}

impl ReplicaState {
    pub(crate) fn new() -> Arc<ReplicaState> {
        Arc::new(ReplicaState {
            alive: AtomicBool::new(true),
            healthy: AtomicBool::new(true),
            depth: AtomicUsize::new(0),
        })
    }

    pub(crate) fn is_routable(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
            && self.healthy.load(Ordering::Relaxed)
    }

    pub(crate) fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    pub(crate) fn mark_dead(&self) {
        self.alive.store(false, Ordering::Relaxed);
        self.healthy.store(false, Ordering::Relaxed);
    }

    fn set_healthy(&self, ok: bool) {
        self.healthy.store(ok, Ordering::Relaxed);
    }

    /// Router-dispatched requests this replica has not completed yet
    /// (queued + in-flight).
    pub(crate) fn outstanding(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    fn note_enqueued(&self) {
        self.depth.fetch_add(1, Ordering::Relaxed);
    }

    /// One request finished (responded to) — or an optimistic
    /// `note_enqueued` is being undone after a failed send. Saturating:
    /// the worker completes only what the router counted, but stay
    /// defensive against double-decrement bugs.
    pub(crate) fn note_completed(&self) {
        let _ = self.depth.fetch_update(Ordering::Relaxed,
                                        Ordering::Relaxed,
                                        |d| Some(d.saturating_sub(1)));
    }
}

/// Router/monitor counters, shared across threads and snapshotted into
/// [`RouterStats`].
#[derive(Debug, Default)]
pub(crate) struct RouterCounters {
    pub(crate) dispatched: AtomicU64,
    pub(crate) busy_rejected: AtomicU64,
    pub(crate) replicas_died: AtomicU64,
    pub(crate) pings_ok: AtomicU64,
    pub(crate) pings_missed: AtomicU64,
}

impl RouterCounters {
    pub(crate) fn snapshot(&self) -> RouterStats {
        RouterStats {
            dispatched: self.dispatched.load(Ordering::Relaxed),
            busy_rejected: self.busy_rejected.load(Ordering::Relaxed),
            replicas_died: self.replicas_died.load(Ordering::Relaxed),
            pings_ok: self.pings_ok.load(Ordering::Relaxed),
            pings_missed: self.pings_missed.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time router statistics (`Server::router_stats`).
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterStats {
    /// Requests handed to a replica queue.
    pub dispatched: u64,
    /// Requests rejected with [`ServeError::Busy`] (backpressure).
    pub busy_rejected: u64,
    /// Replicas discovered dead (disconnected queue endpoint).
    pub replicas_died: u64,
    /// Health pings answered in time.
    pub pings_ok: u64,
    /// Health pings that timed out.
    pub pings_missed: u64,
}

/// One model's replica routing table (owned by the router thread).
pub(crate) struct ReplicaSet {
    txs: Vec<SyncSender<WorkerMsg>>,
    states: Vec<Arc<ReplicaState>>,
    /// Rotating round-robin cursor.
    next: usize,
}

impl ReplicaSet {
    pub(crate) fn new(txs: Vec<SyncSender<WorkerMsg>>,
                      states: Vec<Arc<ReplicaState>>) -> ReplicaSet {
        debug_assert_eq!(txs.len(), states.len());
        ReplicaSet { txs, states, next: 0 }
    }

    /// Route `req` to a live replica, or reply `Busy`/`Failed` per the
    /// module docs. Never blocks.
    pub(crate) fn dispatch(&mut self, req: InferRequest,
                           retry_after: Duration,
                           counters: &RouterCounters) {
        let k = self.txs.len();
        let mut msg = WorkerMsg::Infer(req);
        let mut any_alive = false;
        for i in 0..k {
            let idx = (self.next + i) % k;
            if !self.states[idx].is_alive() {
                continue;
            }
            if !self.states[idx].is_routable() {
                // alive but flagged unhealthy: skip, may recover later
                any_alive = true;
                continue;
            }
            // count the request *before* the send: a fast worker could
            // otherwise dequeue (and decrement) before the increment
            // lands, leaving the depth permanently off by one — which
            // would silently disable health pings for this replica
            self.states[idx].note_enqueued();
            match self.txs[idx].try_send(msg) {
                Ok(()) => {
                    self.next = (idx + 1) % k;
                    counters.dispatched.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                Err(TrySendError::Full(back)) => {
                    // saturated but alive: Busy territory
                    self.states[idx].note_completed(); // undo the count
                    any_alive = true;
                    msg = back;
                }
                Err(TrySendError::Disconnected(back)) => {
                    // discovered dead right here: NOT alive — a lone
                    // replica dying must produce Failed, not a Busy the
                    // client would retry forever
                    self.states[idx].note_completed(); // undo the count
                    msg = back;
                    self.states[idx].mark_dead();
                    counters.replicas_died.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        let WorkerMsg::Infer(req) = msg else {
            unreachable!("dispatch only routes Infer messages");
        };
        let InferRequest { model, input, resp, .. } = req;
        let error = if any_alive {
            counters.busy_rejected.fetch_add(1, Ordering::Relaxed);
            ServeError::Busy { retry_after }
        } else {
            ServeError::Failed(format!("model '{model}': no live replicas"))
        };
        // hand the input back so a retrying caller reuses it clone-free
        let _ = resp.send(Err(Rejection { error, input: Some(input) }));
    }
}

/// The health monitor loop (one thread per server). Owns clones of every
/// replica queue sender; exits when `stop` is set, dropping its clones
/// so draining workers can finish.
///
/// Each round fans every ping out first and then collects the replies
/// against **one** shared deadline, so round latency (and therefore
/// shutdown latency and detection time) is `ping_timeout`, not
/// `replicas × ping_timeout`.
pub(crate) fn monitor_loop(
    replicas: Vec<(SyncSender<WorkerMsg>, Arc<ReplicaState>)>,
    stop: Arc<AtomicBool>, health_every: Duration, ping_timeout: Duration,
    counters: Arc<RouterCounters>,
) {
    let mut missed = vec![0u32; replicas.len()];
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(health_every);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // phase 1: fan out pings to every idle, live replica
        let mut waiting: Vec<(usize, mpsc::Receiver<()>)> = Vec::new();
        for (i, (tx, state)) in replicas.iter().enumerate() {
            if !state.is_alive() {
                continue;
            }
            if state.outstanding() > 0 {
                // replica holds queued or in-flight work: it is
                // demonstrably accepting requests, and a ping behind
                // that work would measure load, not health — never
                // route around a loaded-but-live replica (a dead one
                // surfaces via its disconnected endpoint)
                continue;
            }
            let (ping_tx, ping_rx) = mpsc::channel();
            match tx.try_send(WorkerMsg::Ping(ping_tx)) {
                Err(TrySendError::Full(_)) => {
                    // saturated queue: that's backpressure, not death —
                    // don't burn a miss on it
                }
                Err(TrySendError::Disconnected(_)) => {
                    state.mark_dead();
                    counters.replicas_died.fetch_add(1, Ordering::Relaxed);
                }
                Ok(()) => waiting.push((i, ping_rx)),
            }
        }
        // phase 2: collect replies against one shared deadline
        let deadline = Instant::now() + ping_timeout;
        for (i, ping_rx) in waiting {
            let state = &replicas[i].1;
            let left = deadline.saturating_duration_since(Instant::now());
            match ping_rx.recv_timeout(left) {
                Ok(()) => {
                    missed[i] = 0;
                    state.set_healthy(true);
                    counters.pings_ok.fetch_add(1, Ordering::Relaxed);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    missed[i] += 1;
                    counters.pings_missed.fetch_add(1, Ordering::Relaxed);
                    if missed[i] >= MAX_MISSED_PINGS {
                        state.set_healthy(false);
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // the worker dropped the reply sender without
                    // answering: it exited between accept and reply
                    state.mark_dead();
                    counters.replicas_died.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::HostTensor;
    use std::time::Instant;

    fn test_req(model: &str)
                -> (InferRequest,
                    mpsc::Receiver<Result<HostTensor, Rejection>>) {
        let (tx, rx) = mpsc::sync_channel(1);
        let req = InferRequest {
            model: model.to_string(),
            input: HostTensor::scalar_f32(0.0),
            resp: tx,
            enqueued: Instant::now(),
        };
        (req, rx)
    }

    #[test]
    fn serve_error_displays_and_converts() {
        let busy = ServeError::Busy {
            retry_after: Duration::from_millis(4),
        };
        assert!(format!("{busy}").contains("busy"));
        let failed = ServeError::Failed("boom".into());
        let as_anyhow: anyhow::Error = failed.into();
        assert_eq!(format!("{as_anyhow}"), "boom");
    }

    #[test]
    fn dispatch_round_robins_over_replicas() {
        let (tx_a, rx_a) = mpsc::sync_channel(4);
        let (tx_b, rx_b) = mpsc::sync_channel(4);
        let states = vec![ReplicaState::new(), ReplicaState::new()];
        let mut set = ReplicaSet::new(vec![tx_a, tx_b], states);
        let counters = RouterCounters::default();
        for _ in 0..4 {
            let (req, _rx) = test_req("m");
            set.dispatch(req, Duration::from_millis(1), &counters);
        }
        assert_eq!(counters.snapshot().dispatched, 4);
        assert_eq!(rx_a.try_iter().count(), 2);
        assert_eq!(rx_b.try_iter().count(), 2);
    }

    #[test]
    fn dispatch_skips_full_queue_then_rejects_busy() {
        let (tx, _rx_keep) = mpsc::sync_channel(1);
        let mut set = ReplicaSet::new(vec![tx], vec![ReplicaState::new()]);
        let counters = RouterCounters::default();
        let (first, _first_rx) = test_req("m");
        set.dispatch(first, Duration::from_millis(7), &counters);
        // queue of 1 is now full: the next dispatch must reject Busy
        let (second, second_rx) = test_req("m");
        set.dispatch(second, Duration::from_millis(7), &counters);
        let rejection = second_rx.recv().expect("reply").unwrap_err();
        assert_eq!(rejection.error,
                   ServeError::Busy { retry_after: Duration::from_millis(7) });
        assert!(rejection.input.is_some(),
                "Busy must hand the input back for clone-free retries");
        assert_eq!(counters.snapshot().busy_rejected, 1);
        assert_eq!(counters.snapshot().dispatched, 1);
        // the accepted request counts as outstanding; the Busy-rejected
        // one was un-counted when its send failed
        assert_eq!(set.states[0].outstanding(), 1);
    }

    #[test]
    fn dispatch_marks_disconnected_replicas_dead() {
        let (tx_dead, _) = mpsc::sync_channel(1); // receiver dropped
        let states = vec![ReplicaState::new()];
        let dead_state = states[0].clone();
        let mut set = ReplicaSet::new(vec![tx_dead], states);
        let counters = RouterCounters::default();
        let (req, rx) = test_req("m");
        set.dispatch(req, Duration::from_millis(1), &counters);
        let rejection = rx.recv().expect("reply").unwrap_err();
        assert!(matches!(rejection.error, ServeError::Failed(_)),
                "dead replica set must fail, got {:?}", rejection.error);
        assert!(!dead_state.is_alive());
        assert_eq!(counters.snapshot().replicas_died, 1);
        // subsequent dispatches fail immediately without a queue probe
        let (req2, rx2) = test_req("m");
        set.dispatch(req2, Duration::from_millis(1), &counters);
        assert!(matches!(rx2.recv().expect("reply").unwrap_err().error,
                         ServeError::Failed(_)));
    }
}
