//! Replica routing: the data-parallel half of sharded serving
//! (DESIGN.md §10) and the per-replica lifecycle the supervisor drives
//! (DESIGN.md §12).
//!
//! Each model runs R replica workers behind the router, every replica
//! with its own bounded queue. Dispatch is rotating round-robin over the
//! replicas the health monitor considers live, probing with `try_send`
//! so a saturated replica is skipped rather than blocked on:
//!
//! * every live replica full → the request is rejected with
//!   [`ServeError::Busy`] carrying a retry-after hint (the batcher's
//!   flush cadence) — **backpressure is an explicit, immediate signal**,
//!   not an ever-growing queue;
//! * a replica whose queue endpoint is gone (worker thread died) is
//!   marked dead on the spot — permanently when supervision is off,
//!   until the supervisor respawns it when `restart_budget > 0`;
//! * no live replica at all → [`ServeError::Failed`], a terminal error.
//!
//! Every replica carries a [`ReplicaPhase`]: `Live` replicas take
//! traffic; a death moves them to `Dead`, the supervisor's restart
//! delay shows as `Backoff`, and a respawned replica sits in
//! `Probation` — answering health pings but receiving no dispatch —
//! until it has `P` consecutive ping successes, so a crash-looping
//! executor cannot flap live traffic. The router, monitor, and
//! supervisor all share [`ReplicaSlot`]s, whose queue sender is
//! swapped in place on respawn; the routing table itself never changes.
//!
//! The health monitor thread pings every replica each `health_every`
//! through the same queue the requests use (so a ping measures real
//! dequeue latency). Pings are only sent to **idle** replicas (queue
//! depth 0): a replica holding queued work is demonstrably accepting
//! requests, and a ping behind its backlog would measure queue length,
//! not health — loaded-but-live replicas must never be routed around
//! (saturation is backpressure's business; a dead replica still
//! surfaces immediately through its disconnected queue endpoint). For
//! an idle replica, a reply within `ping_timeout` marks it healthy and
//! [`MAX_MISSED_PINGS`] consecutive timeouts mark it unhealthy —
//! skipped by dispatch until a later ping succeeds, so slow replicas
//! heal themselves.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8,
                        AtomicUsize, Ordering};
use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::{lock_recovering, LatencyHistogram};
use crate::tensor::HostTensor;

use super::server::InferRequest;

/// Consecutive ping timeouts before a replica is routed around.
pub const MAX_MISSED_PINGS: u32 = 3;

/// Typed serving error. The vendored `anyhow` deliberately has no
/// downcasting, so backpressure is a dedicated variant on a dedicated
/// type rather than a string to be sniffed: [`ServeHandle::try_infer`]
/// surfaces it directly, and `ServeHandle::infer` retries `Busy` with
/// the embedded hint.
///
/// [`ServeHandle::try_infer`]: super::server::ServeHandle::try_infer
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Every live replica's queue is full; retry after the hint.
    Busy { retry_after: Duration },
    /// The request failed terminally (unknown model, dead replicas,
    /// executor error).
    Failed(String),
    /// The caller's deadline expired before a result arrived (the HTTP
    /// layer's per-request timeout → 504). The request may still
    /// complete server-side; its response is discarded.
    DeadlineExceeded,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Busy { retry_after } => {
                write!(f, "server busy: every replica queue is full \
                           (retry after {retry_after:?})")
            }
            ServeError::Failed(msg) => f.write_str(msg),
            ServeError::DeadlineExceeded => {
                f.write_str("request deadline exceeded")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// A rejected request: the typed error plus — whenever the rejecting
/// side still owned it — the original input handed back, so retrying
/// callers (`ServeHandle::infer`) never clone tensors on the hot path.
/// `Busy` rejections always return the input; terminal failures may
/// not (an executor error consumed it).
#[derive(Debug)]
pub struct Rejection {
    pub error: ServeError,
    pub input: Option<HostTensor>,
}

impl Rejection {
    pub(crate) fn terminal(error: ServeError) -> Rejection {
        Rejection { error, input: None }
    }
}

/// What flows through a replica's queue: client work or a monitor ping.
pub(crate) enum WorkerMsg {
    Infer(InferRequest),
    /// Health probe; the worker replies on dequeue. The sender is
    /// unbounded so the reply can never block the worker.
    Ping(mpsc::Sender<()>),
}

/// Where a replica stands in the supervision lifecycle (DESIGN.md §12).
///
/// `Live` is the only phase dispatch routes to. `Dead` is how every
/// death starts — and where it ends when supervision is off or the
/// restart budget is exhausted. With supervision on, the supervisor
/// moves a dead replica through `Backoff` (waiting out the restart
/// delay) into `Probation` (respawned; serving health pings but no
/// traffic until `P` consecutive successes) and back to `Live`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaPhase {
    Live,
    Probation,
    Backoff,
    Dead,
}

impl ReplicaPhase {
    /// Stable lower-case label (the Prometheus `state` label values).
    pub fn as_str(self) -> &'static str {
        match self {
            ReplicaPhase::Live => "live",
            ReplicaPhase::Probation => "probation",
            ReplicaPhase::Backoff => "backoff",
            ReplicaPhase::Dead => "dead",
        }
    }

    /// All phases, in display order (Prometheus state-gauge series).
    pub fn all() -> [ReplicaPhase; 4] {
        [ReplicaPhase::Live, ReplicaPhase::Probation,
         ReplicaPhase::Backoff, ReplicaPhase::Dead]
    }
}

const PHASE_LIVE: u8 = 0;
const PHASE_PROBATION: u8 = 1;
const PHASE_BACKOFF: u8 = 2;
const PHASE_DEAD: u8 = 3;

/// Shared liveness/health state of one replica.
///
/// `alive` is false exactly while the worker thread is gone (forever,
/// unless the supervisor revives the replica); `healthy` is the
/// monitor's recoverable verdict; `depth` counts router-dispatched
/// requests not yet *completed* — incremented before the dispatch send
/// (and undone if the send fails) and decremented only when the worker
/// finishes the request, so queued **and in-flight** work both
/// register: the monitor must treat a replica mid-way through a long
/// batch as busy, not idle. `phase`/`restarts`/probation counters back
/// the supervision lifecycle ([`ReplicaPhase`]).
#[derive(Debug)]
pub(crate) struct ReplicaState {
    alive: AtomicBool,
    healthy: AtomicBool,
    depth: AtomicUsize,
    phase: AtomicU8,
    restarts: AtomicU64,
    probation_left: AtomicU32,
    probation_need: AtomicU32,
    /// A supervisor watches this replica (restart budget > 0): a fresh
    /// death is *recovering*, not *permanent*, even before the
    /// supervisor's next tick classifies it.
    supervised: AtomicBool,
    /// The supervisor gave up on this replica — terminal.
    exhausted: AtomicBool,
}

impl ReplicaState {
    pub(crate) fn new() -> Arc<ReplicaState> {
        Arc::new(ReplicaState {
            alive: AtomicBool::new(true),
            healthy: AtomicBool::new(true),
            depth: AtomicUsize::new(0),
            phase: AtomicU8::new(PHASE_LIVE),
            restarts: AtomicU64::new(0),
            probation_left: AtomicU32::new(0),
            probation_need: AtomicU32::new(0),
            supervised: AtomicBool::new(false),
            exhausted: AtomicBool::new(false),
        })
    }

    /// Declare that a supervisor watches this replica (set once at
    /// spawn when `restart_budget > 0`).
    pub(crate) fn set_supervised(&self) {
        self.supervised.store(true, Ordering::Relaxed);
    }

    pub(crate) fn is_supervised(&self) -> bool {
        self.supervised.load(Ordering::Relaxed)
    }

    /// True once the restart budget is spent: this death is final.
    pub(crate) fn is_exhausted(&self) -> bool {
        self.exhausted.load(Ordering::Relaxed)
    }

    pub(crate) fn is_routable(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
            && self.healthy.load(Ordering::Relaxed)
            && self.phase.load(Ordering::Relaxed) == PHASE_LIVE
    }

    pub(crate) fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    pub(crate) fn phase(&self) -> ReplicaPhase {
        match self.phase.load(Ordering::Relaxed) {
            PHASE_LIVE => ReplicaPhase::Live,
            PHASE_PROBATION => ReplicaPhase::Probation,
            PHASE_BACKOFF => ReplicaPhase::Backoff,
            _ => ReplicaPhase::Dead,
        }
    }

    /// Times this replica's worker has been respawned.
    pub(crate) fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    /// Flip `alive` off; true only for the caller that saw the
    /// transition, so `replicas_died` counts each death exactly once
    /// even when dispatch and the monitor race on the same corpse.
    pub(crate) fn mark_dead(&self) -> bool {
        let was_alive = self.alive.swap(false, Ordering::Relaxed);
        if was_alive {
            self.healthy.store(false, Ordering::Relaxed);
            self.phase.store(PHASE_DEAD, Ordering::Relaxed);
        }
        was_alive
    }

    /// Supervisor scheduled a respawn: the replica is still down but a
    /// restart is pending (distinguishes recovering from permanent on
    /// `/healthz`).
    pub(crate) fn mark_backoff(&self) {
        debug_assert!(!self.is_alive());
        self.phase.store(PHASE_BACKOFF, Ordering::Relaxed);
    }

    /// Supervisor gave up (restart budget exhausted): terminal dead,
    /// exactly like an unsupervised death.
    pub(crate) fn mark_exhausted(&self) {
        debug_assert!(!self.is_alive());
        self.exhausted.store(true, Ordering::Relaxed);
        self.phase.store(PHASE_DEAD, Ordering::Relaxed);
    }

    /// Supervisor respawned this replica's worker: reset the dispatch
    /// depth (in-flight work died with the old worker — and the
    /// monitor only pings idle replicas, so a stale depth would mute
    /// pings forever), start probation, and only then flip `alive`
    /// back on so observers never see a half-initialised revival.
    pub(crate) fn revive(&self, probation: u32) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
        self.depth.store(0, Ordering::Relaxed);
        self.probation_need.store(probation, Ordering::Relaxed);
        self.probation_left.store(probation, Ordering::Relaxed);
        if probation == 0 {
            self.healthy.store(true, Ordering::Relaxed);
            self.phase.store(PHASE_LIVE, Ordering::Relaxed);
        } else {
            self.healthy.store(false, Ordering::Relaxed);
            self.phase.store(PHASE_PROBATION, Ordering::Relaxed);
        }
        self.alive.store(true, Ordering::Relaxed);
    }

    /// Monitor verdict: a ping answered in time. Marks the replica
    /// healthy and advances probation; the `P`-th consecutive success
    /// readmits it to dispatch.
    pub(crate) fn note_ping_ok(&self) {
        self.healthy.store(true, Ordering::Relaxed);
        if self.phase.load(Ordering::Relaxed) == PHASE_PROBATION {
            let left = self.probation_left.load(Ordering::Relaxed)
                           .saturating_sub(1);
            self.probation_left.store(left, Ordering::Relaxed);
            if left == 0 {
                self.phase.store(PHASE_LIVE, Ordering::Relaxed);
            }
        }
    }

    /// Monitor verdict: a ping timed out. Any miss resets the
    /// probation streak (readmission demands *consecutive* successes);
    /// only a `hard` miss ([`MAX_MISSED_PINGS`] in a row) flags the
    /// replica unhealthy.
    pub(crate) fn note_ping_missed(&self, hard: bool) {
        if hard {
            self.healthy.store(false, Ordering::Relaxed);
        }
        if self.phase.load(Ordering::Relaxed) == PHASE_PROBATION {
            self.probation_left.store(
                self.probation_need.load(Ordering::Relaxed),
                Ordering::Relaxed);
        }
    }

    /// Router-dispatched requests this replica has not completed yet
    /// (queued + in-flight).
    pub(crate) fn outstanding(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    fn note_enqueued(&self) {
        self.depth.fetch_add(1, Ordering::Relaxed);
    }

    /// One request finished (responded to) — or an optimistic
    /// `note_enqueued` is being undone after a failed send. Saturating:
    /// the worker completes only what the router counted, but stay
    /// defensive against double-decrement bugs.
    pub(crate) fn note_completed(&self) {
        let _ = self.depth.fetch_update(Ordering::Relaxed,
                                        Ordering::Relaxed,
                                        |d| Some(d.saturating_sub(1)));
    }
}

/// Router/monitor/supervisor counters, shared across threads and
/// snapshotted into [`RouterStats`].
#[derive(Debug, Default)]
pub(crate) struct RouterCounters {
    pub(crate) dispatched: AtomicU64,
    pub(crate) busy_rejected: AtomicU64,
    pub(crate) replicas_died: AtomicU64,
    pub(crate) replicas_restarted: AtomicU64,
    pub(crate) pings_ok: AtomicU64,
    pub(crate) pings_missed: AtomicU64,
    /// Detected death → readmitted to dispatch, recorded by the
    /// supervisor (`cat_recovery_time_us`).
    pub(crate) recovery: Mutex<LatencyHistogram>,
}

impl RouterCounters {
    /// Record a death iff `state` actually transitioned (first caller
    /// wins; see [`ReplicaState::mark_dead`]).
    pub(crate) fn note_death(&self, state: &ReplicaState) {
        if state.mark_dead() {
            self.replicas_died.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub(crate) fn snapshot(&self) -> RouterStats {
        RouterStats {
            dispatched: self.dispatched.load(Ordering::Relaxed),
            busy_rejected: self.busy_rejected.load(Ordering::Relaxed),
            replicas_died: self.replicas_died.load(Ordering::Relaxed),
            replicas_restarted:
                self.replicas_restarted.load(Ordering::Relaxed),
            pings_ok: self.pings_ok.load(Ordering::Relaxed),
            pings_missed: self.pings_missed.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time router statistics (`Server::router_stats`).
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterStats {
    /// Requests handed to a replica queue.
    pub dispatched: u64,
    /// Requests rejected with [`ServeError::Busy`] (backpressure).
    pub busy_rejected: u64,
    /// Replicas discovered dead (disconnected queue endpoint or
    /// captured worker panic).
    pub replicas_died: u64,
    /// Replica workers respawned by the supervisor.
    pub replicas_restarted: u64,
    /// Health pings answered in time.
    pub pings_ok: u64,
    /// Health pings that timed out.
    pub pings_missed: u64,
}

/// One replica's routing endpoint: shared state plus a swappable queue
/// sender. Router, health monitor, and supervisor hold the same
/// `Arc<ReplicaSlot>`; a respawn swaps the sender in place
/// ([`Self::replace_sender`]) so dispatch picks up the new worker's
/// queue with no routing-table surgery, and shutdown [`Self::close`]s
/// the slot to drop the last sender and let the worker drain out.
#[derive(Debug)]
pub(crate) struct ReplicaSlot {
    state: Arc<ReplicaState>,
    tx: Mutex<Option<SyncSender<WorkerMsg>>>,
}

impl ReplicaSlot {
    pub(crate) fn new(tx: SyncSender<WorkerMsg>,
                      state: Arc<ReplicaState>) -> Arc<ReplicaSlot> {
        Arc::new(ReplicaSlot { state, tx: Mutex::new(Some(tx)) })
    }

    pub(crate) fn state(&self) -> &Arc<ReplicaState> {
        &self.state
    }

    /// `try_send` through the current sender; a closed slot behaves
    /// like a disconnected queue.
    pub(crate) fn try_send(&self, msg: WorkerMsg)
                           -> Result<(), TrySendError<WorkerMsg>> {
        match &*lock_recovering(&self.tx) {
            Some(tx) => tx.try_send(msg),
            None => Err(TrySendError::Disconnected(msg)),
        }
    }

    /// Swap in a freshly spawned worker's queue (supervisor respawn).
    /// The replaced sender drops here; the dead worker's queue loses
    /// its last endpoint.
    pub(crate) fn replace_sender(&self, tx: SyncSender<WorkerMsg>) {
        *lock_recovering(&self.tx) = Some(tx);
    }

    /// Drop the sender for good (shutdown): the worker's receive loop
    /// sees the disconnect and drains out.
    pub(crate) fn close(&self) {
        *lock_recovering(&self.tx) = None;
    }
}

/// One model's replica routing table (owned by the router thread).
pub(crate) struct ReplicaSet {
    slots: Vec<Arc<ReplicaSlot>>,
    /// Rotating round-robin cursor.
    next: usize,
}

impl ReplicaSet {
    pub(crate) fn from_slots(slots: Vec<Arc<ReplicaSlot>>) -> ReplicaSet {
        ReplicaSet { slots, next: 0 }
    }

    /// Route `req` to a live replica, or reply `Busy`/`Failed` per the
    /// module docs. Never blocks.
    pub(crate) fn dispatch(&mut self, req: InferRequest,
                           retry_after: Duration,
                           counters: &RouterCounters) {
        let k = self.slots.len();
        let mut msg = WorkerMsg::Infer(req);
        let mut any_alive = false;
        for i in 0..k {
            let idx = (self.next + i) % k;
            let state = self.slots[idx].state();
            if !state.is_alive() {
                continue;
            }
            if !state.is_routable() {
                // alive but unhealthy or on probation: skip, the
                // monitor readmits it later
                any_alive = true;
                continue;
            }
            // count the request *before* the send: a fast worker could
            // otherwise dequeue (and decrement) before the increment
            // lands, leaving the depth permanently off by one — which
            // would silently disable health pings for this replica
            state.note_enqueued();
            match self.slots[idx].try_send(msg) {
                Ok(()) => {
                    self.next = (idx + 1) % k;
                    counters.dispatched.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                Err(TrySendError::Full(back)) => {
                    // saturated but alive: Busy territory
                    state.note_completed(); // undo the count
                    any_alive = true;
                    msg = back;
                }
                Err(TrySendError::Disconnected(back)) => {
                    // discovered dead right here: NOT alive — a lone
                    // replica dying must produce Failed, not a Busy the
                    // client would retry forever
                    state.note_completed(); // undo the count
                    msg = back;
                    counters.note_death(state);
                }
            }
        }
        let WorkerMsg::Infer(req) = msg else {
            unreachable!("dispatch only routes Infer messages");
        };
        let InferRequest { model, input, resp, .. } = req;
        let error = if any_alive {
            counters.busy_rejected.fetch_add(1, Ordering::Relaxed);
            ServeError::Busy { retry_after }
        } else {
            ServeError::Failed(format!("model '{model}': no live replicas"))
        };
        // hand the input back so a retrying caller reuses it clone-free
        let _ = resp.send(Err(Rejection { error, input: Some(input) }));
    }
}

/// The health monitor loop (one thread per server). Pings through the
/// shared [`ReplicaSlot`]s, so a respawned worker's fresh queue is
/// picked up automatically; exits when `stop` is set.
///
/// Each round fans every ping out first and then collects the replies
/// against **one** shared deadline, so round latency (and therefore
/// shutdown latency and detection time) is `ping_timeout`, not
/// `replicas × ping_timeout`.
///
/// Verdicts carry the replica's restart epoch: a ping sent to a worker
/// that was respawned before the reply deadline is stale — its timeout
/// or disconnect says nothing about the *new* worker, so it must not
/// burn a miss or (worse) re-kill the freshly revived replica.
pub(crate) fn monitor_loop(
    slots: Vec<Arc<ReplicaSlot>>,
    stop: Arc<AtomicBool>, health_every: Duration, ping_timeout: Duration,
    counters: Arc<RouterCounters>,
) {
    let mut missed = vec![0u32; slots.len()];
    let mut epochs: Vec<u64> =
        slots.iter().map(|s| s.state().restarts()).collect();
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(health_every);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // a respawned replica starts its miss count from scratch
        for (i, slot) in slots.iter().enumerate() {
            let r = slot.state().restarts();
            if epochs[i] != r {
                epochs[i] = r;
                missed[i] = 0;
            }
        }
        // phase 1: fan out pings to every idle, live replica
        let mut waiting: Vec<(usize, u64, mpsc::Receiver<()>)> = Vec::new();
        for (i, slot) in slots.iter().enumerate() {
            let state = slot.state();
            if !state.is_alive() {
                continue;
            }
            if state.outstanding() > 0 {
                // replica holds queued or in-flight work: it is
                // demonstrably accepting requests, and a ping behind
                // that work would measure load, not health — never
                // route around a loaded-but-live replica (a dead one
                // surfaces via its disconnected endpoint)
                continue;
            }
            let (ping_tx, ping_rx) = mpsc::channel();
            match slot.try_send(WorkerMsg::Ping(ping_tx)) {
                Err(TrySendError::Full(_)) => {
                    // saturated queue: that's backpressure, not death —
                    // don't burn a miss on it
                }
                Err(TrySendError::Disconnected(_)) => {
                    counters.note_death(state);
                }
                Ok(()) => waiting.push((i, state.restarts(), ping_rx)),
            }
        }
        // phase 2: collect replies against one shared deadline
        let deadline = Instant::now() + ping_timeout;
        for (i, epoch, ping_rx) in waiting {
            let state = slots[i].state();
            let left = deadline.saturating_duration_since(Instant::now());
            let verdict = ping_rx.recv_timeout(left);
            if state.restarts() != epoch {
                // respawned since the ping went out: stale verdict
                continue;
            }
            match verdict {
                Ok(()) => {
                    missed[i] = 0;
                    state.note_ping_ok();
                    counters.pings_ok.fetch_add(1, Ordering::Relaxed);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    missed[i] += 1;
                    counters.pings_missed.fetch_add(1, Ordering::Relaxed);
                    state.note_ping_missed(missed[i] >= MAX_MISSED_PINGS);
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // the worker dropped the reply sender without
                    // answering: it exited between accept and reply
                    counters.note_death(state);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::HostTensor;
    use std::time::Instant;

    fn test_req(model: &str)
                -> (InferRequest,
                    mpsc::Receiver<Result<HostTensor, Rejection>>) {
        let (tx, rx) = mpsc::sync_channel(1);
        let req = InferRequest {
            model: model.to_string(),
            input: HostTensor::scalar_f32(0.0),
            resp: tx,
            enqueued: Instant::now(),
            timing: None,
        };
        (req, rx)
    }

    fn set_of(txs: Vec<SyncSender<WorkerMsg>>)
              -> (ReplicaSet, Vec<Arc<ReplicaState>>) {
        let states: Vec<_> =
            (0..txs.len()).map(|_| ReplicaState::new()).collect();
        let slots = txs.into_iter().zip(&states)
            .map(|(tx, st)| ReplicaSlot::new(tx, st.clone()))
            .collect();
        (ReplicaSet::from_slots(slots), states)
    }

    #[test]
    fn serve_error_displays_and_converts() {
        let busy = ServeError::Busy {
            retry_after: Duration::from_millis(4),
        };
        assert!(format!("{busy}").contains("busy"));
        let failed = ServeError::Failed("boom".into());
        let as_anyhow: anyhow::Error = failed.into();
        assert_eq!(format!("{as_anyhow}"), "boom");
    }

    #[test]
    fn dispatch_round_robins_over_replicas() {
        let (tx_a, rx_a) = mpsc::sync_channel(4);
        let (tx_b, rx_b) = mpsc::sync_channel(4);
        let (mut set, _states) = set_of(vec![tx_a, tx_b]);
        let counters = RouterCounters::default();
        for _ in 0..4 {
            let (req, _rx) = test_req("m");
            set.dispatch(req, Duration::from_millis(1), &counters);
        }
        assert_eq!(counters.snapshot().dispatched, 4);
        assert_eq!(rx_a.try_iter().count(), 2);
        assert_eq!(rx_b.try_iter().count(), 2);
    }

    #[test]
    fn dispatch_skips_full_queue_then_rejects_busy() {
        let (tx, _rx_keep) = mpsc::sync_channel(1);
        let (mut set, states) = set_of(vec![tx]);
        let counters = RouterCounters::default();
        let (first, _first_rx) = test_req("m");
        set.dispatch(first, Duration::from_millis(7), &counters);
        // queue of 1 is now full: the next dispatch must reject Busy
        let (second, second_rx) = test_req("m");
        set.dispatch(second, Duration::from_millis(7), &counters);
        let rejection = second_rx.recv().expect("reply").unwrap_err();
        assert_eq!(rejection.error,
                   ServeError::Busy { retry_after: Duration::from_millis(7) });
        assert!(rejection.input.is_some(),
                "Busy must hand the input back for clone-free retries");
        assert_eq!(counters.snapshot().busy_rejected, 1);
        assert_eq!(counters.snapshot().dispatched, 1);
        // the accepted request counts as outstanding; the Busy-rejected
        // one was un-counted when its send failed
        assert_eq!(states[0].outstanding(), 1);
    }

    #[test]
    fn dispatch_marks_disconnected_replicas_dead() {
        let (tx_dead, _) = mpsc::sync_channel(1); // receiver dropped
        let (mut set, states) = set_of(vec![tx_dead]);
        let dead_state = states[0].clone();
        let counters = RouterCounters::default();
        let (req, rx) = test_req("m");
        set.dispatch(req, Duration::from_millis(1), &counters);
        let rejection = rx.recv().expect("reply").unwrap_err();
        assert!(matches!(rejection.error, ServeError::Failed(_)),
                "dead replica set must fail, got {:?}", rejection.error);
        assert!(!dead_state.is_alive());
        assert_eq!(dead_state.phase(), ReplicaPhase::Dead);
        assert_eq!(counters.snapshot().replicas_died, 1);
        // subsequent dispatches fail immediately without a queue probe
        let (req2, rx2) = test_req("m");
        set.dispatch(req2, Duration::from_millis(1), &counters);
        assert!(matches!(rx2.recv().expect("reply").unwrap_err().error,
                         ServeError::Failed(_)));
    }

    #[test]
    fn closed_slot_dispatch_fails_terminal() {
        let (tx, _rx_keep) = mpsc::sync_channel(4);
        let (mut set, states) = set_of(vec![tx]);
        set.slots[0].close();
        let counters = RouterCounters::default();
        let (req, rx) = test_req("m");
        set.dispatch(req, Duration::from_millis(1), &counters);
        assert!(matches!(rx.recv().expect("reply").unwrap_err().error,
                         ServeError::Failed(_)));
        assert!(!states[0].is_alive());
    }

    #[test]
    fn replace_sender_reroutes_to_new_queue() {
        let (tx_old, rx_old) = mpsc::sync_channel(4);
        let (mut set, states) = set_of(vec![tx_old]);
        drop(rx_old); // old worker dies
        let (req, rx) = test_req("m");
        let counters = RouterCounters::default();
        set.dispatch(req, Duration::from_millis(1), &counters);
        assert!(rx.recv().expect("reply").is_err());
        // supervisor swaps in a fresh queue and revives with P=0
        let (tx_new, rx_new) = mpsc::sync_channel(4);
        set.slots[0].replace_sender(tx_new);
        states[0].revive(0);
        assert!(states[0].is_routable());
        let (req2, _rx2) = test_req("m");
        set.dispatch(req2, Duration::from_millis(1), &counters);
        assert_eq!(rx_new.try_iter().count(), 1,
                   "dispatch must reach the replacement queue");
    }

    #[test]
    fn phase_machine_dead_backoff_probation_live() {
        let state = ReplicaState::new();
        assert_eq!(state.phase(), ReplicaPhase::Live);
        assert!(state.is_routable());

        assert!(state.mark_dead(), "first death reports the transition");
        assert!(!state.mark_dead(), "second death must not double-count");
        assert_eq!(state.phase(), ReplicaPhase::Dead);
        assert!(!state.is_routable());

        state.mark_backoff();
        assert_eq!(state.phase(), ReplicaPhase::Backoff);
        assert!(!state.is_alive());

        state.revive(2);
        assert_eq!(state.phase(), ReplicaPhase::Probation);
        assert!(state.is_alive());
        assert!(!state.is_routable(), "probation takes no traffic");
        assert_eq!(state.restarts(), 1);
        assert_eq!(state.outstanding(), 0, "revive resets depth");

        state.note_ping_ok();
        assert_eq!(state.phase(), ReplicaPhase::Probation,
                   "one ping of two is not enough");
        // a miss resets the consecutive-success streak
        state.note_ping_missed(false);
        state.note_ping_ok();
        assert_eq!(state.phase(), ReplicaPhase::Probation);
        state.note_ping_ok();
        assert_eq!(state.phase(), ReplicaPhase::Live);
        assert!(state.is_routable());
    }

    #[test]
    fn exhausted_budget_is_terminal_dead() {
        let state = ReplicaState::new();
        state.set_supervised();
        let counters = RouterCounters::default();
        counters.note_death(&state);
        counters.note_death(&state); // racing second observer
        assert_eq!(counters.snapshot().replicas_died, 1,
                   "a death is counted exactly once");
        // freshly dead under a supervisor: recoverable, not terminal
        assert!(state.is_supervised());
        assert!(!state.is_exhausted());
        state.mark_backoff();
        state.mark_exhausted();
        assert_eq!(state.phase(), ReplicaPhase::Dead);
        assert!(state.is_exhausted(), "exhaustion is terminal");
        assert!(!state.is_alive());
        assert!(!state.is_routable());
    }

    #[test]
    fn probation_replica_yields_busy_not_failed() {
        let (tx, _rx_keep) = mpsc::sync_channel(4);
        let (mut set, states) = set_of(vec![tx]);
        states[0].mark_dead();
        states[0].revive(3); // alive again, but on probation
        let counters = RouterCounters::default();
        let (req, rx) = test_req("m");
        set.dispatch(req, Duration::from_millis(5), &counters);
        let rejection = rx.recv().expect("reply").unwrap_err();
        assert_eq!(rejection.error,
                   ServeError::Busy { retry_after: Duration::from_millis(5) },
                   "an alive-but-probation replica is Busy, not Failed");
    }
}
