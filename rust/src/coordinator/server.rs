//! The serving loop: thread-based request router + per-model workers over
//! a pluggable execution backend.
//!
//! Architecture (vLLM-router shaped, scaled to one CPU, std-only — the
//! offline vendor snapshot has no async runtime, so the event loop is
//! plain threads + mpsc channels, which on a single core is also the
//! faster choice):
//!
//! ```text
//!   clients ──mpsc──▶ Router thread ──per-model mpsc──▶ ModelWorker
//!      ▲                                        (batcher + BatchExecutor)
//!      └──────────────── oneshot responses ◀─────────────┘
//! ```
//!
//! The router owns a registry of model replica sets keyed by config name
//! and dispatches requests round-robin over each model's R data-parallel
//! replica workers ([`super::router`]); every replica runs a dynamic
//! batcher ([`super::batcher`]) in front of one [`BatchExecutor`]:
//!
//! * [`Backend::Pjrt`] (feature `pjrt`) — the compiled `forward` artifact;
//!   short batches are padded to the artifact's fixed batch size.
//! * [`Backend::Native`] — [`crate::native::NativeCatModel`], the pure-Rust
//!   CAT-FFT executor; shape-flexible, so batches run unpadded and serving
//!   works in a fresh checkout with no artifacts and no XLA runtime. With
//!   `ServeOptions::shards > 1` each replica further splits its model
//!   head-wise across K model-parallel shards ([`super::shard`]).
//!
//! Backpressure: every queue is bounded and the router never blocks —
//! when all of a model's live replicas are saturated the request is
//! rejected with [`ServeError::Busy`] + a retry-after hint
//! ([`ServeHandle::try_infer`] surfaces it, [`ServeHandle::infer`]
//! retries it). A health monitor pings replicas through their queues and
//! routes around the unhealthy ones (DESIGN.md §10). With
//! `ServeOptions::restart_budget > 0` a supervisor thread respawns dead
//! replicas through the executor factory and walks them through
//! probation before they take traffic again (DESIGN.md §12).

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender,
                      TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure};

use super::batcher::{DynamicBatcher, Flush};
use super::retry::BackoffPolicy;
use super::router::{monitor_loop, Rejection, ReplicaPhase, ReplicaSet,
                    ReplicaSlot, ReplicaState, RouterCounters, RouterStats,
                    ServeError, WorkerMsg};
use super::shard::{ShardStatsSnapshot, ShardedNativeModel};
use super::supervisor::{supervisor_loop, SupervisedSlot, Supervisor};
use crate::metrics::{lock_recovering, LatencyHistogram};
use crate::native::{NativeCatModel, NativeVitConfig};
use crate::obs::trace::{self as obs_trace, Stage, StageCells};
use crate::runtime::Backend;
use crate::tensor::HostTensor;
use crate::Result;

/// One model's execution engine: turns a batch of single-example inputs
/// into one output row per example. Implementations live worker-local
/// (PJRT handles are `!Send`), so the trait needs no `Send` bound.
pub trait BatchExecutor {
    /// Largest batch the engine wants per call (the batcher's flush size).
    fn max_batch(&self) -> usize;

    /// Run `inputs` (each a single example, no batch dim) and return one
    /// output row per input, in order.
    fn infer_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;

    /// Model-shard counters, when this executor is sharded (reported
    /// through [`WorkerStats`] at shutdown).
    fn shard_stats(&self) -> Option<ShardStatsSnapshot> {
        None
    }
}

/// Everything a worker thread needs to build its own execution stack.
///
/// The xla crate's handles (`PjRtClient`, `Literal`, executables) hold
/// `Rc`s and raw PJRT pointers — they are `!Send` by design — so each
/// worker thread constructs its *own* executor from the spec; parameters
/// cross the thread boundary as plain [`HostTensor`]s (trained
/// checkpoints) or as a seed (fresh init). The native backend follows the
/// same shape for uniformity.
pub struct WorkerSpec {
    pub model: String,
    /// trained parameters (host copies, manifest order); None -> init(seed).
    /// PJRT-only: the native model always initializes from the seed.
    pub params: Option<Vec<HostTensor>>,
    pub seed: i32,
}

/// One inference request: a single example (no batch dim) for `model`.
/// The response channel is typed ([`Rejection`] wraps a [`ServeError`])
/// so backpressure rejections stay distinguishable from terminal
/// failures without downcasting (the vendored anyhow has none), and so
/// `Busy` rejections can hand the input back for clone-free retries.
pub struct InferRequest {
    pub model: String,
    pub input: HostTensor,
    pub resp: SyncSender<std::result::Result<HostTensor, Rejection>>,
    pub enqueued: Instant,
    /// Optional per-request stage timing cells (DESIGN.md §13): the
    /// worker that executes this request fills in queue-wait and
    /// kernel-stage durations for the tracing HTTP layer. `None` for
    /// untraced callers — the worker then skips attribution entirely.
    pub timing: Option<Arc<StageCells>>,
}

/// Client handle to the router (cheap to clone, thread-safe).
#[derive(Clone)]
pub struct ServeHandle {
    tx: SyncSender<InferRequest>,
    /// The hint embedded in locally-raised `Busy` rejections and the
    /// cadence `infer` retries at (the batcher flush delay).
    retry_after: Duration,
}

/// How long [`ServeHandle::infer`] keeps retrying `Busy` before giving
/// up — generous because the pre-backpressure behaviour was an unbounded
/// blocking send.
const INFER_BUSY_PATIENCE: Duration = Duration::from_secs(60);

/// Seed source for per-call backoff schedules: concurrent retrying
/// clients must jitter *differently* or they re-collide on every tick.
static BACKOFF_SEED: AtomicU64 = AtomicU64::new(0x5E_ED);

fn next_backoff_seed() -> u64 {
    BACKOFF_SEED.fetch_add(1, Ordering::Relaxed)
}

impl ServeHandle {
    /// Submit one example without blocking on a saturated server: a
    /// `Busy` rejection (every live replica's queue full, or the router
    /// intake full) comes back immediately with a retry-after hint.
    /// Blocks only for the actual inference once the request is queued.
    pub fn try_infer(&self, model: &str, input: HostTensor)
                     -> std::result::Result<HostTensor, ServeError> {
        self.try_infer_keep(model, input, None, None).map_err(|(e, _)| e)
    }

    /// [`Self::try_infer`], but rejections that still own the input
    /// hand it back — the clone-free retry primitive behind `infer` —
    /// and an optional deadline bounds the wait for the response:
    /// expiry surfaces [`ServeError::DeadlineExceeded`] (the request
    /// may still complete server-side; its response is discarded when
    /// the channel drops).
    fn try_infer_keep(&self, model: &str, input: HostTensor,
                      deadline: Option<Instant>,
                      timing: Option<Arc<StageCells>>)
                      -> std::result::Result<HostTensor,
                                             (ServeError,
                                              Option<HostTensor>)> {
        if let Some(d) = deadline {
            if Instant::now() >= d {
                return Err((ServeError::DeadlineExceeded, Some(input)));
            }
        }
        let (tx, rx) = mpsc::sync_channel(1);
        let req = InferRequest {
            model: model.to_string(),
            input,
            resp: tx,
            enqueued: Instant::now(),
            timing,
        };
        match self.tx.try_send(req) {
            Ok(()) => {}
            Err(TrySendError::Full(req)) => {
                return Err((ServeError::Busy {
                    retry_after: self.retry_after,
                }, Some(req.input)));
            }
            Err(TrySendError::Disconnected(_)) => {
                return Err((ServeError::Failed("router is down".into()),
                            None));
            }
        }
        let outcome = match deadline {
            None => rx.recv().map_err(|_| None),
            Some(d) => {
                let left = d.saturating_duration_since(Instant::now());
                rx.recv_timeout(left).map_err(|e| match e {
                    RecvTimeoutError::Timeout => {
                        Some(ServeError::DeadlineExceeded)
                    }
                    RecvTimeoutError::Disconnected => None,
                })
            }
        };
        match outcome {
            Ok(Ok(row)) => Ok(row),
            Ok(Err(rejection)) => Err((rejection.error, rejection.input)),
            Err(Some(e)) => Err((e, None)),
            Err(None) => Err((ServeError::Failed(
                "worker dropped request".into()), None)),
        }
    }

    /// Submit one example and block until its logits row is ready,
    /// absorbing backpressure: `Busy` rejections are retried on the
    /// shared jittered-exponential schedule ([`BackoffPolicy`]), which
    /// floors every delay at the server's hint and stops once
    /// [`INFER_BUSY_PATIENCE`] of sleep has been spent — so this
    /// behaves like the old blocking path under load. The input is
    /// never cloned — rejections hand it back for the next attempt.
    /// Terminal failures return immediately; in particular, a request
    /// lost to a worker dying mid-flight surfaces as
    /// `Failed("worker dropped request")` (the input died with the
    /// worker, so no automatic retry is possible) — idempotent callers
    /// may resubmit with a fresh input, and the router routes the retry
    /// around the dead replica.
    pub fn infer(&self, model: &str, input: HostTensor) -> Result<HostTensor> {
        let mut backoff =
            BackoffPolicy::serving(self.retry_after, INFER_BUSY_PATIENCE)
                .start(next_backoff_seed());
        let mut input = input;
        loop {
            match self.try_infer_keep(model, input, None, None) {
                Ok(row) => return Ok(row),
                Err((ServeError::Busy { retry_after }, Some(returned))) => {
                    match backoff.next_delay(Some(retry_after)) {
                        Some(d) => {
                            std::thread::sleep(d);
                            input = returned;
                        }
                        None => {
                            return Err(ServeError::Busy {
                                retry_after,
                            }.into());
                        }
                    }
                }
                Err((e, _)) => return Err(e.into()),
            }
        }
    }

    /// [`Self::infer`] bounded by an absolute deadline: `Busy` is
    /// retried (jittered, hint-floored) only while the deadline allows,
    /// and the wait for an accepted request's response is capped at the
    /// deadline too. On expiry the caller sees either
    /// [`ServeError::DeadlineExceeded`] (accepted but not answered in
    /// time → HTTP 504) or the last [`ServeError::Busy`] (never
    /// accepted → HTTP 429); terminal failures surface immediately.
    pub fn infer_deadline(&self, model: &str, input: HostTensor,
                          deadline: Instant)
                          -> std::result::Result<HostTensor, ServeError> {
        self.infer_deadline_traced(model, input, deadline, None)
    }

    /// [`Self::infer_deadline`] with per-request stage attribution: the
    /// executing worker fills `timing` (queue wait + kernel stages)
    /// before the response is sent, so the HTTP layer can fold the
    /// durations into the request's trace. The cells survive `Busy`
    /// retries — only the attempt that is actually executed writes them.
    pub fn infer_deadline_traced(&self, model: &str, input: HostTensor,
                                 deadline: Instant,
                                 timing: Option<Arc<StageCells>>)
                                 -> std::result::Result<HostTensor,
                                                        ServeError> {
        let budget = deadline.saturating_duration_since(Instant::now());
        let mut backoff = BackoffPolicy::serving(self.retry_after, budget)
            .start(next_backoff_seed());
        let mut input = input;
        loop {
            match self.try_infer_keep(model, input, Some(deadline),
                                      timing.clone()) {
                Ok(row) => return Ok(row),
                Err((ServeError::Busy { retry_after }, Some(returned))) => {
                    match backoff.next_delay(Some(retry_after)) {
                        Some(d) if Instant::now() + d < deadline => {
                            std::thread::sleep(d);
                            input = returned;
                        }
                        _ => return Err(ServeError::Busy { retry_after }),
                    }
                }
                Err((e, _)) => return Err(e),
            }
        }
    }
}

/// Final statistics from one drained replica worker.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub model: String,
    /// Which of the model's R replicas this worker was.
    pub replica: usize,
    pub requests: u64,
    pub batches: u64,
    pub mean_occupancy: f64,
    pub latency: LatencyHistogram,
    /// Present when the replica ran a sharded executor.
    pub shard: Option<ShardStatsSnapshot>,
}

/// Per-model aggregate over replica [`WorkerStats`].
#[derive(Debug, Clone)]
pub struct ModelStats {
    pub model: String,
    /// Replicas that reported stats (a replica that died mid-run is
    /// missing from the aggregate).
    pub replicas: usize,
    pub requests: u64,
    pub batches: u64,
    /// Batch-weighted mean occupancy across replicas.
    pub mean_occupancy: f64,
    /// Merged latency histogram across replicas.
    pub latency: LatencyHistogram,
}

/// Aggregate per-replica worker stats into per-model totals, sorted by
/// model name.
pub fn aggregate_stats(per_replica: &[WorkerStats]) -> Vec<ModelStats> {
    let mut by_model: HashMap<&str, ModelStats> = HashMap::new();
    for w in per_replica {
        let entry = by_model.entry(&w.model).or_insert_with(|| ModelStats {
            model: w.model.clone(),
            replicas: 0,
            requests: 0,
            batches: 0,
            mean_occupancy: 0.0,
            latency: LatencyHistogram::default(),
        });
        entry.replicas += 1;
        entry.requests += w.requests;
        // accumulate batch-weighted occupancy; normalized below
        entry.mean_occupancy += w.mean_occupancy * w.batches as f64;
        entry.batches += w.batches;
        entry.latency.merge(&w.latency);
    }
    let mut out: Vec<ModelStats> = by_model
        .into_values()
        .map(|mut m| {
            if m.batches > 0 {
                m.mean_occupancy /= m.batches as f64;
            }
            m
        })
        .collect();
    out.sort_by(|a, b| a.model.cmp(&b.model));
    out
}

/// Per-replica counters updated **while serving** (under a mutex the
/// worker touches once per flush), so `/metrics` can report request
/// totals and latency without waiting for shutdown-time
/// [`WorkerStats`]. The final stats are derived from the same counters
/// — one bookkeeping path, two read sides.
#[derive(Debug, Default)]
pub(crate) struct LiveCounters {
    pub(crate) requests: u64,
    pub(crate) batches: u64,
    pub(crate) latency: LatencyHistogram,
}

fn lock_live(live: &Mutex<LiveCounters>)
             -> std::sync::MutexGuard<'_, LiveCounters> {
    // a poisoned lock only means a worker panicked outside the guarded
    // section; the counters themselves are always consistent — recover
    // the guard and count it (`cat_lock_poison_recoveries_total`)
    lock_recovering(live)
}

/// One replica's identity + shared observability state.
struct ReplicaRef {
    model: String,
    replica: usize,
    state: Arc<ReplicaState>,
    live: Arc<Mutex<LiveCounters>>,
}

/// Point-in-time view of one replica for `/metrics` and `/healthz`.
#[derive(Debug, Clone)]
pub struct ReplicaSnapshot {
    pub model: String,
    pub replica: usize,
    /// False while the replica's queue endpoint is gone (worker died
    /// and has not been respawned).
    pub alive: bool,
    /// Where the replica stands in the supervision lifecycle.
    pub phase: ReplicaPhase,
    /// Times the supervisor respawned this replica's worker.
    pub restarts: u64,
    /// Dispatched-but-uncompleted requests (queued + in-flight).
    pub outstanding: usize,
    pub requests: u64,
    pub batches: u64,
    pub latency: LatencyHistogram,
}

/// Cloneable, lock-cheap observability handle over a running
/// [`Server`]: router counters + per-replica live state. The HTTP
/// layer holds one of these; unlike [`Server`] it is `Send + Sync` and
/// does not keep the intake open.
#[derive(Clone)]
pub struct StatsHandle {
    counters: Arc<RouterCounters>,
    replicas: Arc<Vec<ReplicaRef>>,
}

impl StatsHandle {
    pub fn router(&self) -> RouterStats {
        self.counters.snapshot()
    }

    pub fn replicas(&self) -> Vec<ReplicaSnapshot> {
        self.replicas
            .iter()
            .map(|r| {
                let live = lock_live(&r.live);
                ReplicaSnapshot {
                    model: r.model.clone(),
                    replica: r.replica,
                    alive: r.state.is_alive(),
                    phase: r.state.phase(),
                    restarts: r.state.restarts(),
                    outstanding: r.state.outstanding(),
                    requests: live.requests,
                    batches: live.batches,
                    latency: live.latency.clone(),
                }
            })
            .collect()
    }

    /// Degraded = at least one replica is out of dispatch rotation
    /// (`/healthz` → 503): the server still serves from survivors, but
    /// capacity is reduced. [`Self::degraded_permanent`] vs
    /// [`Self::degraded_recovering`] tells an orchestrator whether to
    /// rotate the instance or just wait out the supervisor.
    pub fn degraded(&self) -> bool {
        self.degraded_permanent() || self.degraded_recovering()
    }

    /// At least one replica is terminally dead — supervision off, or
    /// its restart budget is exhausted. Capacity will not come back on
    /// its own; rotate the instance.
    pub fn degraded_permanent(&self) -> bool {
        self.replicas.iter().any(|r| {
            r.state.phase() == ReplicaPhase::Dead
                && (!r.state.is_supervised() || r.state.is_exhausted())
        })
    }

    /// At least one replica is mid-recovery: restart backoff or
    /// probation — or freshly dead under an unexhausted supervisor
    /// (the next supervisor tick schedules its respawn). Capacity is
    /// reduced but comes back on its own.
    pub fn degraded_recovering(&self) -> bool {
        self.replicas.iter().any(|r| match r.state.phase() {
            ReplicaPhase::Backoff | ReplicaPhase::Probation => true,
            ReplicaPhase::Dead => {
                r.state.is_supervised() && !r.state.is_exhausted()
            }
            ReplicaPhase::Live => false,
        })
    }

    /// Merged time-to-recovery histogram: detected replica death →
    /// readmitted to dispatch, one sample per completed recovery
    /// (`cat_recovery_time_us`).
    pub fn recovery_latency(&self) -> LatencyHistogram {
        lock_recovering(&self.counters.recovery).clone()
    }
}

/// Options for batching behaviour, backend selection, and the sharded
/// serving topology.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    pub max_delay: Duration,
    pub queue_depth: usize,
    /// Which engine each worker builds ([`Backend::detect_env`] default).
    pub backend: Backend,
    /// Shape of the native model when `backend == Native`.
    pub native: NativeVitConfig,
    /// Batcher flush size for the (shape-flexible) native engine.
    pub native_max_batch: usize,
    /// Model-parallel head shards per replica (native backend only;
    /// 1 = unsharded). Must divide into `native.n_heads` slots.
    pub shards: usize,
    /// Data-parallel replica workers per model (each with its own
    /// bounded queue). 1 = the pre-shard single-worker topology.
    pub replicas: usize,
    /// Health-check cadence (the monitor pings every replica this often).
    pub health_every: Duration,
    /// How long a ping may take before it counts as missed.
    pub ping_timeout: Duration,
    /// Respawn attempts the supervisor may spend per replica before it
    /// declares the replica permanently dead. 0 disables supervision
    /// entirely (the pre-§12 behaviour: a dead replica stays dead).
    pub restart_budget: u32,
    /// Base delay of the supervisor's jittered exponential backoff
    /// between respawn attempts.
    pub restart_base: Duration,
    /// Consecutive successful health pings a respawned replica must
    /// answer before it is readmitted to dispatch (floored at 1).
    pub probation_pings: u32,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            max_delay: Duration::from_millis(4),
            queue_depth: 256,
            backend: Backend::detect_env(),
            native: NativeVitConfig::default(),
            native_max_batch: 8,
            shards: 1,
            replicas: 1,
            health_every: Duration::from_millis(250),
            ping_timeout: Duration::from_millis(250),
            restart_budget: 0,
            restart_base: Duration::from_millis(50),
            probation_pings: 2,
        }
    }
}

/// How a replica worker thread builds its execution engine. Overridable
/// via [`Server::spawn_with`] so tests and benches can serve custom
/// executors (slow, failing, instrumented) through the full router
/// stack; `None` builds the backend selected in [`ServeOptions`].
pub type ExecutorFactory =
    Arc<dyn Fn(&WorkerSpec, &ServeOptions) -> Result<Box<dyn BatchExecutor>>
            + Send + Sync>;

/// Serving coordinator: router thread + health monitor + optional
/// supervisor + R replica worker threads per model.
pub struct Server {
    handle: ServeHandle,
    stats_rx: Receiver<WorkerStats>,
    router: std::thread::JoinHandle<()>,
    monitor: Option<std::thread::JoinHandle<()>>,
    /// The supervisor returns the handles of every worker it respawned
    /// so shutdown can join them too.
    supervisor: Option<std::thread::JoinHandle<Vec<std::thread::JoinHandle<()>>>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Every replica's routing endpoint; closed at shutdown to drop the
    /// last queue senders so workers drain out.
    slots: Vec<Arc<ReplicaSlot>>,
    stop: Arc<AtomicBool>,
    counters: Arc<RouterCounters>,
    replicas: Arc<Vec<ReplicaRef>>,
}

impl Server {
    /// Spawn workers for `models` with freshly-initialized parameters.
    /// Production serving passes trained parameters via
    /// [`Server::spawn_specs`] (see `examples/serve.rs`).
    pub fn spawn(artifacts: PathBuf, models: &[String], opts: ServeOptions,
                 seed: i32) -> Result<Self> {
        let specs = models
            .iter()
            .map(|m| WorkerSpec { model: m.clone(), params: None, seed })
            .collect();
        Self::spawn_specs(artifacts, specs, opts)
    }

    /// Spawn `opts.replicas` worker threads per spec. Each worker builds
    /// its own executor over `artifacts` per `opts.backend` (PJRT
    /// handles are `!Send`; see [`WorkerSpec`]).
    pub fn spawn_specs(artifacts: PathBuf, specs: Vec<WorkerSpec>,
                       opts: ServeOptions) -> Result<Self> {
        Self::spawn_with(artifacts, specs, opts, None)
    }

    /// [`Server::spawn_specs`] with an optional executor factory (see
    /// [`ExecutorFactory`]). Every replica invokes the factory on its
    /// own thread.
    pub fn spawn_with(artifacts: PathBuf, specs: Vec<WorkerSpec>,
                      opts: ServeOptions, factory: Option<ExecutorFactory>)
                      -> Result<Self> {
        ensure!(opts.replicas >= 1, "need at least one replica per model");
        ensure!(opts.shards >= 1, "need at least one shard per replica");
        if opts.backend == Backend::Pjrt && factory.is_none() {
            ensure!(opts.shards == 1,
                    "model-parallel sharding is a native-backend feature");
        }
        let retry_after = opts.max_delay.max(Duration::from_micros(100));
        let (tx, rx) = mpsc::sync_channel::<InferRequest>(opts.queue_depth);
        let (stats_tx, stats_rx) = mpsc::channel();
        let counters = Arc::new(RouterCounters::default());
        let stop = Arc::new(AtomicBool::new(false));

        // one concrete factory for initial workers AND supervisor
        // respawns — a respawned replica runs the exact stack the
        // original did, fault-injection wrappers included
        let factory =
            factory.unwrap_or_else(|| default_factory(artifacts));

        let mut sets: HashMap<String, ReplicaSet> = HashMap::new();
        let mut all_slots: Vec<Arc<ReplicaSlot>> = Vec::new();
        let mut sup_slots: Vec<SupervisedSlot> = Vec::new();
        let mut workers = Vec::new();
        let mut replica_refs: Vec<ReplicaRef> = Vec::new();
        // workers report readiness so spawn() fails fast on bad configs
        let (ready_tx, ready_rx) = mpsc::channel::<Result<String>>();
        for spec in specs {
            let spec = Arc::new(spec);
            let mut model_slots = Vec::with_capacity(opts.replicas);
            for replica in 0..opts.replicas {
                let (wtx, wrx) = mpsc::sync_channel(opts.queue_depth);
                let state = ReplicaState::new();
                if opts.restart_budget > 0 {
                    state.set_supervised();
                }
                let live = Arc::new(Mutex::new(LiveCounters::default()));
                replica_refs.push(ReplicaRef {
                    model: spec.model.clone(),
                    replica,
                    state: state.clone(),
                    live: live.clone(),
                });
                let slot = ReplicaSlot::new(wtx, state.clone());
                model_slots.push(slot.clone());
                sup_slots.push(SupervisedSlot {
                    slot,
                    spec: spec.clone(),
                    live: live.clone(),
                    replica,
                });
                let wstate = state;
                let spec = spec.clone();
                let stats_tx = stats_tx.clone();
                let ready_tx = ready_tx.clone();
                let factory = factory.clone();
                let wcounters = counters.clone();
                workers.push(std::thread::spawn(move || {
                    match factory(spec.as_ref(), &opts) {
                        Ok(exec) => {
                            let _ = ready_tx.send(Ok(spec.model.clone()));
                            drop(ready_tx);
                            worker_loop(spec.model.clone(), replica, exec,
                                        wrx, wstate, opts, stats_tx, live,
                                        wcounters);
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e.context(format!(
                                "{} replica {replica}", spec.model))));
                        }
                    }
                }));
            }
            all_slots.extend(model_slots.iter().cloned());
            sets.insert(spec.model.clone(),
                        ReplicaSet::from_slots(model_slots));
        }
        drop(ready_tx);
        for _ in 0..workers.len() {
            match ready_rx.recv() {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => return Err(e.context("worker startup")),
                Err(_) => bail!("worker thread died during startup"),
            }
        }

        let router_counters = counters.clone();
        let router = std::thread::spawn(move || {
            let mut sets = sets;
            while let Ok(req) = rx.recv() {
                match sets.get_mut(&req.model) {
                    Some(set) => {
                        set.dispatch(req, retry_after, &router_counters);
                    }
                    None => {
                        let model = req.model.clone();
                        let _ = req.resp.send(Err(Rejection::terminal(
                            ServeError::Failed(format!(
                                "unknown model '{model}'")))));
                    }
                }
            }
            // rx closed: the replica senders drop here, workers drain
        });

        let monitor = {
            let stop = stop.clone();
            let counters = counters.clone();
            let slots = all_slots.clone();
            let (every, timeout) = (opts.health_every, opts.ping_timeout);
            Some(std::thread::spawn(move || {
                monitor_loop(slots, stop, every, timeout, counters);
            }))
        };

        let supervisor = if opts.restart_budget > 0 {
            let sup = Supervisor {
                slots: sup_slots,
                factory,
                opts,
                stats_tx,
                counters: counters.clone(),
                stop: stop.clone(),
                seed: next_backoff_seed(),
            };
            Some(std::thread::spawn(move || supervisor_loop(sup)))
        } else {
            None
        };

        Ok(Self {
            handle: ServeHandle { tx, retry_after },
            stats_rx,
            router,
            monitor,
            supervisor,
            workers,
            slots: all_slots,
            stop,
            counters,
            replicas: Arc::new(replica_refs),
        })
    }

    pub fn handle(&self) -> ServeHandle {
        self.handle.clone()
    }

    /// Point-in-time router/monitor counters (dispatches, backpressure
    /// rejections, ping outcomes). Callable while serving.
    pub fn router_stats(&self) -> RouterStats {
        self.counters.snapshot()
    }

    /// Cloneable observability handle ([`StatsHandle`]) for `/metrics`
    /// and `/healthz`: live per-replica counters + router stats,
    /// readable concurrently with serving and safe to hold across
    /// [`Server::shutdown`] (it keeps no queue open).
    pub fn stats_handle(&self) -> StatsHandle {
        StatsHandle {
            counters: self.counters.clone(),
            replicas: self.replicas.clone(),
        }
    }

    /// Close the intake, join every thread, collect per-replica worker
    /// statistics (see [`aggregate_stats`] for per-model totals). All
    /// outstanding `ServeHandle` clones must be dropped first.
    pub fn shutdown(self) -> Vec<WorkerStats> {
        // order matters: stop the monitor/supervisor loops and close
        // the intake so the router exits; join the monitor, then the
        // supervisor (it may be mid-respawn and hands back the worker
        // threads it spawned); only then close every slot — dropping
        // the last queue senders — so the workers drain out and the
        // final joins are bounded.
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
        drop(self.handle);
        let _ = self.router.join();
        if let Some(m) = self.monitor {
            let _ = m.join();
        }
        let mut workers = self.workers;
        if let Some(s) = self.supervisor {
            if let Ok(mut respawned) = s.join() {
                workers.append(&mut respawned);
            }
        }
        for slot in &self.slots {
            slot.close();
        }
        for w in workers {
            let _ = w.join();
        }
        let mut out = Vec::new();
        while let Ok(s) = self.stats_rx.try_recv() {
            out.push(s);
        }
        out
    }
}

/// The production executor factory as a composable [`ExecutorFactory`]:
/// what `spawn_with(..., None)` builds, but wrappable — the
/// fault-injection seam (`serve::fault::injected_factory`) decorates
/// this to delay/poison/kill real executors mid-stream.
pub fn default_factory(artifacts: PathBuf) -> ExecutorFactory {
    Arc::new(move |spec: &WorkerSpec, opts: &ServeOptions| {
        build_worker(&artifacts, spec, opts)
    })
}

/// Build a worker's thread-local executor from its spec and the backend
/// selection in `opts`.
fn build_worker(dir: &std::path::Path, spec: &WorkerSpec,
                opts: &ServeOptions) -> Result<Box<dyn BatchExecutor>> {
    match opts.backend {
        Backend::Native => {
            ensure!(spec.params.is_none(),
                    "{}: the native backend initializes from the seed; \
                     checkpoint loading is a PJRT feature", spec.model);
            if opts.shards > 1 {
                // size each shard's dedicated pool against the whole
                // serving topology: R replicas × K shards all compute
                // concurrently, so dividing the hardware budget by
                // shards alone would oversubscribe the cores R-fold
                let per_shard = (crate::native::pool::hardware_workers()
                                 / (opts.shards * opts.replicas))
                    .max(1);
                return Ok(Box::new(ShardedWorker {
                    model: ShardedNativeModel::new(
                        opts.native, spec.seed as u64, opts.shards,
                        Some(per_shard))?,
                    max_batch: opts.native_max_batch.max(1),
                    assembly: std::cell::RefCell::new(Vec::new()),
                }));
            }
            Ok(Box::new(NativeWorker {
                model: NativeCatModel::new(opts.native, spec.seed as u64),
                max_batch: opts.native_max_batch.max(1),
                assembly: std::cell::RefCell::new(Vec::new()),
            }))
        }
        #[cfg(feature = "pjrt")]
        Backend::Pjrt => Ok(Box::new(PjrtWorker::build(dir, spec)?)),
        #[cfg(not(feature = "pjrt"))]
        Backend::Pjrt => {
            let _ = dir;
            bail!("{}: built without the `pjrt` feature — rebuild with \
                   `--features pjrt` or serve with the native backend",
                  spec.model)
        }
    }
}

// ---------------------------------------------------------------------------
// native executor
// ---------------------------------------------------------------------------

/// Native CAT executor: shape-flexible, so batches run unpadded.
///
/// The forward fans out over the persistent worker pool and runs its
/// activations from per-thread bump arenas (DESIGN.md §7), so a
/// steady-state request spawns zero threads and its tensor storage is
/// all reused — what it allocates is the response tensors plus the
/// pool's small per-section dispatch state. The batch-assembly buffer
/// below is reused across flushes for the same reason (executors are
/// worker-thread-local, hence the `RefCell`).
struct NativeWorker {
    model: NativeCatModel,
    max_batch: usize,
    assembly: std::cell::RefCell<Vec<f32>>,
}

/// Validate + flatten a batch of CHW image tensors into `data` (shared
/// by the unsharded and sharded native executors).
fn assemble_images(cfg: &NativeVitConfig, inputs: &[&HostTensor],
                   data: &mut Vec<f32>) -> Result<()> {
    obs_trace::section(Stage::BatchAssembly, || {
        let row_shape = [cfg.n_channels, cfg.image_size, cfg.image_size];
        data.clear();
        for t in inputs {
            if t.shape != row_shape {
                bail!("request shape {:?} != expected {:?}",
                      t.shape, row_shape);
            }
            data.extend_from_slice(t.as_f32()?);
        }
        Ok(())
    })
}

impl BatchExecutor for NativeWorker {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn infer_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let cfg = self.model.cfg;
        let mut data = self.assembly.borrow_mut();
        assemble_images(&cfg, inputs, &mut data)?;
        let logits = self.model.forward_batch(&data, inputs.len())?;
        let all = HostTensor::f32(vec![inputs.len(), cfg.n_classes],
                                  logits)?;
        split_rows(&all, inputs.len())
    }
}

/// Sharded native CAT executor: one model split head-wise across K
/// dedicated-pool shards ([`super::shard`]); bit-identical outputs to
/// [`NativeWorker`] on the same `(config, seed)`.
struct ShardedWorker {
    model: ShardedNativeModel,
    max_batch: usize,
    assembly: std::cell::RefCell<Vec<f32>>,
}

impl BatchExecutor for ShardedWorker {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn infer_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let cfg = *self.model.cfg();
        let mut data = self.assembly.borrow_mut();
        assemble_images(&cfg, inputs, &mut data)?;
        let logits = self.model.forward_batch(&data, inputs.len())?;
        let all = HostTensor::f32(vec![inputs.len(), cfg.n_classes],
                                  logits)?;
        split_rows(&all, inputs.len())
    }

    fn shard_stats(&self) -> Option<ShardStatsSnapshot> {
        Some(self.model.stats())
    }
}

// ---------------------------------------------------------------------------
// PJRT executor (feature `pjrt`)
// ---------------------------------------------------------------------------

/// PJRT executor: compiled `forward` artifact + parameter literals; pads
/// short batches to the artifact's fixed batch size.
#[cfg(feature = "pjrt")]
struct PjrtWorker {
    exe: std::sync::Arc<crate::runtime::Executable>,
    params: Vec<xla::Literal>,
}

#[cfg(feature = "pjrt")]
impl PjrtWorker {
    fn build(dir: &std::path::Path, spec: &WorkerSpec) -> Result<PjrtWorker> {
        use crate::runtime::{Runtime, TrainState};
        let rt = Runtime::new(dir.to_path_buf())?;
        let exe = rt.load(&spec.model, "forward")?;
        let params = match &spec.params {
            Some(host) => host
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<Vec<_>>>()?,
            None => TrainState::init(&rt, &spec.model, spec.seed)?.params,
        };
        Ok(PjrtWorker { exe, params })
    }
}

#[cfg(feature = "pjrt")]
impl BatchExecutor for PjrtWorker {
    fn max_batch(&self) -> usize {
        self.exe.meta.inputs.last()
            .map(|s| s.shape.first().copied().unwrap_or(1))
            .unwrap_or(1)
    }

    /// Pad examples to the executable's batch size, run, split logits rows.
    fn infer_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        use crate::tensor::TensorData;

        let spec = self.exe.meta.inputs.last().expect("input spec");
        let max_batch = spec.shape[0];
        let row_shape: Vec<usize> = spec.shape[1..].to_vec();
        let row_len: usize = row_shape.iter().product();

        let n = inputs.len();
        if n == 0 || n > max_batch {
            bail!("bad flush size {n} (max {max_batch})");
        }
        let mut full_shape = vec![max_batch];
        full_shape.extend(&row_shape);

        // assemble + pad with repeats of the last row, preserving dtype
        let batch_t = match spec.dtype.as_str() {
            "i32" => {
                let mut data: Vec<i32> =
                    Vec::with_capacity(max_batch * row_len);
                for t in inputs {
                    if t.shape != row_shape {
                        bail!("request shape {:?} != expected {:?}",
                              t.shape, row_shape);
                    }
                    data.extend_from_slice(t.as_i32()?);
                }
                let last: Vec<i32> = data[data.len() - row_len..].to_vec();
                for _ in n..max_batch {
                    data.extend_from_slice(&last);
                }
                HostTensor::i32(full_shape, data)?
            }
            _ => {
                let mut data: Vec<f32> =
                    Vec::with_capacity(max_batch * row_len);
                for t in inputs {
                    if t.shape != row_shape {
                        bail!("request shape {:?} != expected {:?}",
                              t.shape, row_shape);
                    }
                    match &t.data {
                        TensorData::F32(v) => data.extend_from_slice(v),
                        TensorData::I32(v) => {
                            data.extend(v.iter().map(|&x| x as f32))
                        }
                    }
                }
                let last: Vec<f32> = data[data.len() - row_len..].to_vec();
                for _ in n..max_batch {
                    data.extend_from_slice(&last);
                }
                HostTensor::f32(full_shape, data)?
            }
        };

        // argument list: params (closed over by the worker) then the batch
        let batch_lit = batch_t.to_literal()?;
        let mut refs: Vec<&xla::Literal> = self.params.iter().collect();
        refs.push(&batch_lit);
        let outs = self.exe.execute_literals(&refs)?;
        let logits = HostTensor::from_literal(&outs[0])?;
        split_rows(&logits, n)
    }
}

// ---------------------------------------------------------------------------
// worker loop (backend-agnostic)
// ---------------------------------------------------------------------------

/// Accept one queue message: batch client work, answer pings on the
/// spot (the reply channel is unbounded and the monitor may have timed
/// out, so replying never blocks). The replica's outstanding-work
/// counter is decremented at request *completion* (in [`flush`]), not
/// here — a replica mid-way through a long batch must still read as
/// busy to the health monitor.
fn accept(msg: WorkerMsg, batcher: &mut DynamicBatcher<InferRequest>) {
    match msg {
        WorkerMsg::Infer(req) => {
            batcher.push(req);
        }
        WorkerMsg::Ping(reply) => {
            let _ = reply.send(());
        }
    }
}

/// Replica worker thread: dynamic batcher in front of one executor.
/// Request/latency counters live in the shared `live` cell (one lock
/// per flush) so `/metrics` observes them while serving; the
/// shutdown-time [`WorkerStats`] is derived from the same counters.
///
/// An executor panic is caught in [`flush`]: the batch's clients get a
/// typed `Failed` response, and the worker marks its replica dead,
/// answers everything still queued (a client must never hang on a
/// corpse), and exits **without** reporting [`WorkerStats`] — exactly
/// like the pre-§12 unwinding death, so shutdown aggregation keeps
/// counting survivors only. Dropping the executor on the way out tears
/// down its dedicated shard pools; the supervisor (if any) rebuilds
/// them on respawn.
#[allow(clippy::too_many_arguments)]
pub(crate) fn worker_loop(
    model: String, replica: usize, exec: Box<dyn BatchExecutor>,
    rx: Receiver<WorkerMsg>, state: Arc<ReplicaState>,
    opts: ServeOptions, stats_tx: mpsc::Sender<WorkerStats>,
    live: Arc<Mutex<LiveCounters>>, counters: Arc<RouterCounters>,
) {
    let mut batcher: DynamicBatcher<InferRequest> =
        DynamicBatcher::new(exec.max_batch(), opts.max_delay);
    let mut open = true;
    let mut fatal: Option<String> = None;

    while fatal.is_none() && (open || !batcher.is_empty()) {
        // fill: block when empty, then drain whatever is ready
        if open && batcher.is_empty() {
            match rx.recv() {
                Ok(msg) => accept(msg, &mut batcher),
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        while open && batcher.len() < batcher.max_batch {
            match rx.try_recv() {
                Ok(msg) => accept(msg, &mut batcher),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        match batcher.poll(Instant::now()) {
            Flush::Emit(n) => {
                fatal = flush(exec.as_ref(), &mut batcher, n, &state,
                              &live).err();
            }
            Flush::Wait(d) if open => {
                // wait out the deadline, absorbing new arrivals
                match rx.recv_timeout(d) {
                    Ok(msg) => accept(msg, &mut batcher),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        open = false;
                    }
                }
            }
            Flush::Wait(_) => {
                // intake closed: flush the remainder immediately
                let n = batcher.len();
                fatal = flush(exec.as_ref(), &mut batcher, n, &state,
                              &live).err();
            }
            Flush::Idle => {}
        }
    }

    if let Some(msg) = fatal {
        counters.note_death(&state);
        let reject = |req: InferRequest| {
            state.note_completed();
            let _ = req.resp.send(Err(Rejection::terminal(
                ServeError::Failed(msg.clone()))));
        };
        let n = batcher.len();
        for p in batcher.take(n) {
            reject(p.payload);
        }
        for m in rx.try_iter() {
            match m {
                WorkerMsg::Infer(req) => reject(req),
                WorkerMsg::Ping(_) => {}
            }
        }
        return;
    }

    let (requests, latency) = {
        let live = lock_live(&live);
        (live.requests, live.latency.clone())
    };
    let _ = stats_tx.send(WorkerStats {
        model,
        replica,
        requests,
        batches: batcher.emitted_batches,
        mean_occupancy: batcher.mean_occupancy(),
        latency,
        shard: exec.shard_stats(),
    });
}

/// Render a `catch_unwind` payload (panics carry `&str` or `String`).
fn panic_text(payload: &(dyn std::any::Any + Send)) -> &str {
    payload.downcast_ref::<&str>().copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// Execute one batch through the executor and fan results back out,
/// marking each request completed in the replica's outstanding-work
/// counter (success and failure alike). A *returned* executor error is
/// recoverable (the replica keeps serving: poison clears); a *panic*
/// is captured so every client in the batch still gets a typed
/// response, then surfaced as `Err` — the worker treats the executor
/// as dead and exits.
fn flush(exec: &dyn BatchExecutor, batcher: &mut DynamicBatcher<InferRequest>,
         n: usize, state: &ReplicaState, live: &Mutex<LiveCounters>)
         -> std::result::Result<(), String> {
    if n == 0 {
        return Ok(());
    }
    let pending = batcher.take(n);
    let inputs: Vec<&HostTensor> =
        pending.iter().map(|p| &p.payload.input).collect();
    let ns_before = obs_trace::thread_stage_ns();
    let t_exec = Instant::now();
    let result = catch_unwind(AssertUnwindSafe(|| exec.infer_batch(&inputs)));
    let ns_after = obs_trace::thread_stage_ns();
    drop(inputs);
    // Attribute queue wait plus the batch's kernel-stage time to every
    // traced request: each request waited for the whole batch, so the
    // batch-wide stage durations still sum within its own wall time.
    // (Sharded shards time fft/matmul on their own threads; those land
    // in the global stage histograms and fold into this thread's
    // scatter/gather deltas here.)
    for p in &pending {
        let wait_us = t_exec
            .saturating_duration_since(p.payload.enqueued)
            .as_micros() as u64;
        obs_trace::record_stage_us(Stage::QueueWait, wait_us);
        if let Some(cells) = &p.payload.timing {
            cells.add_us(Stage::QueueWait, wait_us);
            for stage in Stage::all() {
                let i = stage.index();
                let d_us = ns_after[i].saturating_sub(ns_before[i]) / 1_000;
                if d_us > 0 {
                    cells.add_us(stage, d_us);
                }
            }
        }
    }
    let result = match result {
        Ok(r) => r,
        Err(payload) => {
            let msg = format!("replica worker panicked: {}",
                              panic_text(payload.as_ref()));
            for p in pending {
                state.note_completed();
                let _ = p.payload.resp
                    .send(Err(Rejection::terminal(
                        ServeError::Failed(msg.clone()))));
            }
            return Err(msg);
        }
    };
    match result {
        // an executor returning the wrong row count is a bug, but zip()
        // would hide it: the unmatched clients' response senders were
        // silently dropped and they saw a bare "worker dropped request"
        // with no cause. Turn it into an explicit error for everyone.
        Ok(rows) if rows.len() != pending.len() => {
            let msg = format!("executor returned {} rows for a batch of {}",
                              rows.len(), pending.len());
            for p in pending {
                state.note_completed();
                let _ = p.payload.resp
                    .send(Err(Rejection::terminal(
                        ServeError::Failed(msg.clone()))));
            }
        }
        Ok(rows) => {
            let mut counters = lock_live(live);
            counters.batches += 1;
            for (p, row) in pending.into_iter().zip(rows) {
                state.note_completed();
                counters.latency.record(p.payload.enqueued.elapsed());
                counters.requests += 1;
                let _ = p.payload.resp.send(Ok(row));
            }
        }
        Err(e) => {
            let msg = format!("batch execute failed: {e:#}");
            for p in pending {
                state.note_completed();
                let _ = p.payload.resp
                    .send(Err(Rejection::terminal(
                        ServeError::Failed(msg.clone()))));
            }
        }
    }
    Ok(())
}

/// Split a (B, ...) logits tensor into the first n rows.
pub fn split_rows(logits: &HostTensor, n: usize) -> Result<Vec<HostTensor>> {
    let b = *logits.shape.first()
        .ok_or_else(|| anyhow!("logits must have a batch dim"))?;
    if n > b {
        bail!("asked for {n} rows of a batch of {b}");
    }
    let row_shape: Vec<usize> = logits.shape[1..].to_vec();
    let row_len: usize = row_shape.iter().product();
    let data = logits.as_f32()?;
    (0..n)
        .map(|i| HostTensor::f32(row_shape.clone(),
                                 data[i * row_len..(i + 1) * row_len]
                                     .to_vec()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_rows_basic() {
        let t = HostTensor::f32(vec![3, 2],
                                vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let rows = split_rows(&t, 2).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(rows[1].as_f32().unwrap(), &[3.0, 4.0]);
        assert!(split_rows(&t, 4).is_err());
    }

    #[test]
    fn split_rows_error_paths() {
        // asking for more rows than the batch holds
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]).unwrap();
        assert!(split_rows(&t, 3).is_err());
        // rank-0 tensor: no batch dimension to split
        let scalar = HostTensor::scalar_f32(1.0);
        assert!(split_rows(&scalar, 1).is_err());
        // non-f32 logits are rejected, not transmuted
        let ints = HostTensor::i32(vec![2, 2], vec![1, 2, 3, 4]).unwrap();
        assert!(split_rows(&ints, 1).is_err());
        // empty batch: n = 0 is fine (no rows), n > 0 is not
        let empty = HostTensor::f32(vec![0, 4], vec![]).unwrap();
        assert_eq!(split_rows(&empty, 0).unwrap().len(), 0);
        assert!(split_rows(&empty, 1).is_err());
        // rank-1 batch degenerates to scalar rows
        let flat = HostTensor::f32(vec![3], vec![7.0, 8.0, 9.0]).unwrap();
        let rows = split_rows(&flat, 2).unwrap();
        assert_eq!(rows[1].as_f32().unwrap(), &[8.0]);
        assert_eq!(rows[1].shape, Vec::<usize>::new());
    }

    #[test]
    fn native_worker_round_trips_a_batch() {
        let cfg = NativeVitConfig::default();
        let worker = NativeWorker {
            model: NativeCatModel::new(cfg, 0),
            max_batch: 4,
            assembly: std::cell::RefCell::new(Vec::new()),
        };
        let image_len = cfg.n_channels * cfg.image_size * cfg.image_size;
        let a = HostTensor::f32(
            vec![cfg.n_channels, cfg.image_size, cfg.image_size],
            vec![0.1; image_len]).unwrap();
        let b = HostTensor::f32(
            vec![cfg.n_channels, cfg.image_size, cfg.image_size],
            vec![-0.2; image_len]).unwrap();
        let rows = worker.infer_batch(&[&a, &b]).unwrap();
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert_eq!(row.shape, vec![cfg.n_classes]);
            assert!(row.as_f32().unwrap().iter().all(|v| v.is_finite()));
        }
        // different inputs -> different logits
        assert_ne!(rows[0], rows[1]);
        // wrong shape rejected
        let bad = HostTensor::f32(vec![1, 2], vec![0.0, 0.0]).unwrap();
        assert!(worker.infer_batch(&[&bad]).is_err());
    }
}
