//! Head-parallel model shards: the model-parallel half of sharded
//! serving (DESIGN.md §10).
//!
//! CAT's mixer is *separable over heads*: each head's softmax weight
//! vector and circular cross-correlation touch only that head's slice of
//! `W_A`/`W_V` and its own FFT stripes, and heads meet again only at the
//! merge that interleaves their `dh`-wide outputs (Fast-FNet makes the
//! same observation for Fourier-mixing layers). [`ShardedNativeModel`]
//! exploits that: it splits a [`NativeCatModel`] head-wise into K shards,
//! each owning head-sliced copies of every block's mixing weights
//! ([`ServeMixer::head_slice`]) and computing its heads' stripes on a
//! **dedicated worker pool** ([`Pool::dedicated`]), so shards never
//! contend for one task queue. Only mixers whose registry spec says
//! `head_separable` (CAT and the circulant-attention variant) admit
//! K > 1; FNet and softmax attention mix across the full hidden axis, so
//! construction rejects sharding them with a clear error.
//!
//! Per block the router (the replica worker thread driving
//! [`NativeCatModel::forward_batch_with`]):
//!
//! 1. **scatters** the LN'd activations once — each shard job borrows the
//!    same `x` slice, no per-shard input copies;
//! 2. shards compute `(b, n, hs·dh)` mixer outputs concurrently into
//!    disjoint per-shard gather buffers (grow-only, reused across
//!    requests);
//! 3. **gathers** the head outputs back into the `(b, n, d)` `mixed`
//!    buffer — a pure column concat — before the residual add, MLP, and
//!    (at the top of the stack) the merged output projection.
//!
//! Everything non-separable (patchify, LayerNorms, residuals, MLPs,
//! classifier head) runs on the replica thread exactly as unsharded.
//! Because the head slices preserve every per-element accumulation order
//! (`CatLayer::head_slice` docs), sharded and unsharded forwards are
//! **bit-identical** — pinned by `tests/sharded_serving.rs` and the
//! coordinator bench.
//!
//! Threading: each shard owns one long-lived dispatch thread (spawned at
//! construction, never at request time) that installs its dedicated pool
//! via [`pool::set_thread_pool`] and executes scatter jobs from a small
//! channel. Job closures borrow the caller's frame; the dispatch follows
//! `pool::run_scoped`'s erase-then-wait discipline (a latch blocks the
//! caller until every shard finished or unwound), which is what makes the
//! lifetime erasure sound. A dead dispatch thread degrades to inline
//! execution on the caller — requests slow down, they never hang.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, SyncSender};
use std::sync::Arc;

use anyhow::ensure;

use crate::native::pool::{self, CountGuard, Latch, Pool};
use crate::native::{NativeCatModel, NativeVitConfig, ServeMixer};
use crate::obs::trace::{self as obs_trace, Stage};
use crate::Result;

/// One shard's erased scatter job (see module docs for why 'static).
type ShardJob = Box<dyn FnOnce() + Send + 'static>;

/// Erase a scoped shard job to feed the dispatch channel.
///
/// # Safety
/// The caller must block on the section's latch before its frame ends
/// (every job carries a [`CountGuard`] that fires on completion *and* on
/// unwind), so no borrow captured by `job` survives the erasing frame.
unsafe fn erase_job<'scope>(job: Box<dyn FnOnce() + Send + 'scope>)
                            -> ShardJob {
    std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, ShardJob>(job)
}

/// Per-instance shard counters (atomics so shard jobs and the driving
/// replica thread can bump them without locks).
#[derive(Default)]
struct ShardCounters {
    threads_spawned: AtomicU64,
    jobs: AtomicU64,
    scatters: AtomicU64,
    gathers: AtomicU64,
    inline_fallbacks: AtomicU64,
}

/// Snapshot of one sharded model's counters, surfaced through
/// [`crate::coordinator::WorkerStats`] and the coordinator bench JSON.
/// `threads_spawned` counts this instance's dispatch threads plus its
/// dedicated pool workers — it moves only during construction, so
/// "steady-state serving spawns zero threads" is asserted as this field
/// staying flat across traffic.
#[derive(Debug, Clone, Copy)]
pub struct ShardStatsSnapshot {
    /// Model-parallel shard count K.
    pub shards: usize,
    /// Dedicated pool workers per shard.
    pub workers_per_shard: usize,
    /// OS threads this instance ever spawned (dispatch + pool workers).
    pub threads_spawned: u64,
    /// Shard jobs dispatched (K per block per forward).
    pub jobs: u64,
    /// Scatter fan-outs performed (one per block per forward).
    pub scatters: u64,
    /// Gather concats performed (one per block per forward).
    pub gathers: u64,
    /// Jobs run inline on the caller because a dispatch thread was gone.
    pub inline_fallbacks: u64,
}

/// A shard's long-lived dispatch thread. Jobs are erased closures; the
/// thread installs its dedicated pool so the CAT forward's parallel
/// sections fan out over shard-private workers.
struct ShardWorker {
    tx: Option<SyncSender<ShardJob>>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ShardWorker {
    fn spawn(shard_idx: usize, pool_workers: usize,
             counters: Arc<ShardCounters>) -> Result<ShardWorker> {
        // dispatch thread + its dedicated pool workers, all at startup
        counters.threads_spawned
            .fetch_add(1 + pool_workers as u64, Ordering::Relaxed);
        let (tx, rx) = mpsc::sync_channel::<ShardJob>(4);
        let join = std::thread::Builder::new()
            .name(format!("cat-shard-{shard_idx}"))
            .spawn(move || {
                let dedicated = Pool::dedicated(pool_workers);
                pool::set_thread_pool(Some(dedicated));
                while let Ok(job) = rx.recv() {
                    // a panicking job must not kill the dispatch thread;
                    // its CountGuard has already flagged the latch
                    let _ = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(job));
                }
                // thread exit drops the thread-local pool handle, which
                // closes the dedicated queue and releases its workers
            })?;
        Ok(ShardWorker { tx: Some(tx), join: Some(join) })
    }
}

impl Drop for ShardWorker {
    fn drop(&mut self) {
        drop(self.tx.take()); // hang up: the dispatch loop ends
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// A [`NativeCatModel`] split head-wise into K model-parallel shards.
///
/// Construction slices the (seed-deterministic) full model's mixing
/// weights per shard and spawns the shard substrate; `forward_batch`
/// then matches `NativeCatModel::forward_batch` bit-for-bit (see module
/// docs). The full model is retained for the non-separable trunk.
pub struct ShardedNativeModel {
    model: NativeCatModel,
    /// Head range `[start, end)` owned by each shard.
    ranges: Vec<(usize, usize)>,
    /// `slices[s][block]`: shard `s`'s head-sliced mixing layer.
    slices: Vec<Vec<ServeMixer>>,
    workers: Vec<ShardWorker>,
    /// Per-shard gather buffers, grow-only, reused across requests.
    outs: RefCell<Vec<Vec<f32>>>,
    counters: Arc<ShardCounters>,
    workers_per_shard: usize,
}

impl ShardedNativeModel {
    /// Split the `(cfg, seed)` model into `shards` head shards. Head
    /// counts not divisible by K are split as evenly as possible (the
    /// first `h % K` shards own one extra head). `workers_per_shard`
    /// defaults to the machine's pool budget divided across shards.
    pub fn new(cfg: NativeVitConfig, seed: u64, shards: usize,
               workers_per_shard: Option<usize>)
               -> Result<ShardedNativeModel> {
        ensure!(shards >= 1, "need at least one shard");
        ensure!(shards <= cfg.n_heads,
                "cannot split {} heads into {} shards", cfg.n_heads, shards);
        ensure!(shards == 1 || cfg.mixer.spec().head_separable,
                "mixer '{}' is not head-separable and cannot be split \
                 into {} model-parallel shards; serve it with --shards 1",
                cfg.mixer.name(), shards);
        let workers_per_shard = workers_per_shard
            .unwrap_or_else(|| (pool::hardware_workers() / shards).max(1))
            .max(1);
        let mut model = NativeCatModel::new(cfg, seed);
        let counters = Arc::new(ShardCounters::default());

        let (h, base, rem) = (cfg.n_heads, cfg.n_heads / shards,
                              cfg.n_heads % shards);
        let mut ranges = Vec::with_capacity(shards);
        let mut start = 0usize;
        for s in 0..shards {
            let len = base + usize::from(s < rem);
            ranges.push((start, start + len));
            start += len;
        }
        debug_assert_eq!(start, h);

        let slices: Vec<Vec<ServeMixer>> = ranges
            .iter()
            .map(|&(h0, h1)| model.sliced_mixer_layers(h0, h1))
            .collect();
        // the shards now hold the only copies of the mixing weights;
        // keeping them in the trunk too would double per-replica memory
        // on exactly the axis sharding is meant to scale
        model.strip_mixer_weights();
        let workers = (0..shards)
            .map(|s| ShardWorker::spawn(s, workers_per_shard,
                                        counters.clone()))
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardedNativeModel {
            model,
            ranges,
            slices,
            workers,
            outs: RefCell::new(vec![Vec::new(); shards]),
            counters,
            workers_per_shard,
        })
    }

    pub fn cfg(&self) -> &NativeVitConfig {
        &self.model.cfg
    }

    /// The underlying trunk model. Its per-block mixing weights are
    /// **stripped** (they live in the head slices instead); drive it
    /// only through `forward_batch_with`.
    pub fn model(&self) -> &NativeCatModel {
        &self.model
    }

    pub fn shards(&self) -> usize {
        self.ranges.len()
    }

    pub fn stats(&self) -> ShardStatsSnapshot {
        ShardStatsSnapshot {
            shards: self.ranges.len(),
            workers_per_shard: self.workers_per_shard,
            threads_spawned:
                self.counters.threads_spawned.load(Ordering::Relaxed),
            jobs: self.counters.jobs.load(Ordering::Relaxed),
            scatters: self.counters.scatters.load(Ordering::Relaxed),
            gathers: self.counters.gathers.load(Ordering::Relaxed),
            inline_fallbacks:
                self.counters.inline_fallbacks.load(Ordering::Relaxed),
        }
    }

    /// Classify a batch of CHW images; bit-identical to the unsharded
    /// `NativeCatModel::forward_batch` on the same `(cfg, seed)`.
    pub fn forward_batch(&self, images: &[f32], b: usize)
                         -> Result<Vec<f32>> {
        self.model.forward_batch_with(images, b, |li, x, bb, n, mixed| {
            self.mix_sharded(li, x, bb, n, mixed)
        })
    }

    /// One block's mixer, scattered across the shards and gathered back
    /// into `mixed: (b, n, d)`.
    fn mix_sharded(&self, li: usize, x: &[f32], b: usize, n: usize,
                   mixed: &mut [f32]) -> Result<()> {
        let k = self.ranges.len();
        let cfg = &self.model.cfg;
        let (d, dh) = (cfg.d_model, cfg.d_model / cfg.n_heads);
        let mode = cfg.cat_impl;

        let mut outs = self.outs.borrow_mut();
        for (&(h0, h1), out) in self.ranges.iter().zip(outs.iter_mut()) {
            let need = b * n * (h1 - h0) * dh;
            if out.len() < need {
                out.resize(need, 0.0);
            }
        }

        self.counters.scatters.fetch_add(1, Ordering::Relaxed);
        let latch = Arc::new(Latch::new(k));
        // traced as `scatter` on the driving replica thread: fan-out plus
        // the wait for every shard's mixer compute (the shard-side fft/
        // matmul sections land on the shard threads' own accumulators
        // and the global stage histograms — DESIGN.md §13)
        obs_trace::section(Stage::Scatter, || {
            for ((layer, worker), out) in self.slices.iter()
                .map(|layers| &layers[li])
                .zip(&self.workers)
                .zip(outs.iter_mut())
            {
                let ws = layer.width();
                let dst = &mut out[..b * n * ws];
                let guard_latch = latch.clone();
                let job = Box::new(move || {
                    let _guard = CountGuard::new(guard_latch);
                    // the slice layer re-validates shapes; a failure here
                    // is a construction bug, and the panic is surfaced to
                    // the caller through the latch flag below
                    layer.forward_into(x, b, n, mode, dst)
                        .expect("shard mixer forward");
                });
                // SAFETY: same discipline as pool::Pool::run_scoped — the
                // latch.wait() below blocks this frame until every job has
                // completed or unwound (CountGuard fires in both cases),
                // so the borrows of `x`, `dst`, and the slice layer never
                // outlive this call even though the channel stores the job
                // as 'static. The job moves to exactly one dispatch
                // thread.
                let job: ShardJob = unsafe { erase_job(job) };
                self.counters.jobs.fetch_add(1, Ordering::Relaxed);
                match worker.tx.as_ref().expect("live worker tx").send(job) {
                    Ok(()) => {}
                    Err(send_err) => {
                        // dispatch thread is gone: run the job inline so
                        // the request still completes (and the latch still
                        // counts down via the job's own guard)
                        self.counters.inline_fallbacks
                            .fetch_add(1, Ordering::Relaxed);
                        (send_err.0)();
                    }
                }
            }
            latch.wait();
        });
        ensure!(!latch.panicked(),
                "block {li}: a model shard panicked during the mixer \
                 scatter");

        // gather: concat each shard's head columns into (b, n, d)
        obs_trace::section(Stage::Gather, || {
            for (&(h0, h1), out) in self.ranges.iter().zip(outs.iter()) {
                let ws = (h1 - h0) * dh;
                let c0 = h0 * dh;
                for row in 0..b * n {
                    mixed[row * d + c0..row * d + c0 + ws]
                        .copy_from_slice(&out[row * ws..(row + 1) * ws]);
                }
            }
        });
        self.counters.gathers.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::native::{CatImpl, Mixer};

    fn test_images(cfg: &NativeVitConfig, b: usize, seed: u64) -> Vec<f32> {
        let len = b * cfg.n_channels * cfg.image_size * cfg.image_size;
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect()
    }

    #[test]
    fn sharded_matches_unsharded_bitwise() {
        let cfg = NativeVitConfig::default(); // d=64 h=4 L=2, FFT
        let full = NativeCatModel::new(cfg, 7);
        let images = test_images(&cfg, 2, 11);
        let want = full.forward_batch(&images, 2).unwrap();
        for k in [1usize, 2, 3, 4] {
            let sharded =
                ShardedNativeModel::new(cfg, 7, k, Some(1)).unwrap();
            let got = sharded.forward_batch(&images, 2).unwrap();
            assert_eq!(got, want, "K={k} diverged from unsharded");
            let stats = sharded.stats();
            assert_eq!(stats.shards, k);
            // one scatter+gather per block per forward, K jobs each
            assert_eq!(stats.scatters, cfg.n_layers as u64);
            assert_eq!(stats.jobs, (cfg.n_layers * k) as u64);
            assert_eq!(stats.inline_fallbacks, 0);
        }
    }

    #[test]
    fn sharded_gather_mode_and_uneven_heads() {
        let cfg = NativeVitConfig {
            cat_impl: CatImpl::Gather,
            ..Default::default()
        };
        let images = test_images(&cfg, 1, 13);
        let want = NativeCatModel::new(cfg, 3).forward_batch(&images, 1)
            .unwrap();
        // 4 heads over 3 shards: ranges (0,2) (2,3) (3,4)
        let sharded = ShardedNativeModel::new(cfg, 3, 3, Some(1)).unwrap();
        assert_eq!(sharded.ranges, vec![(0, 2), (2, 3), (3, 4)]);
        let got = sharded.forward_batch(&images, 1).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn steady_state_forwards_spawn_no_threads() {
        let cfg = NativeVitConfig::default();
        let sharded = ShardedNativeModel::new(cfg, 2, 2, Some(1)).unwrap();
        let images = test_images(&cfg, 1, 17);
        sharded.forward_batch(&images, 1).unwrap(); // warmup
        let spawned = sharded.stats().threads_spawned;
        // 2 dispatch threads + 2 pools × 1 worker
        assert_eq!(spawned, 4);
        for _ in 0..8 {
            sharded.forward_batch(&images, 1).unwrap();
        }
        assert_eq!(sharded.stats().threads_spawned, spawned,
                   "steady-state sharded forwards spawned threads");
    }

    #[test]
    fn too_many_shards_rejected() {
        let cfg = NativeVitConfig::default(); // 4 heads
        assert!(ShardedNativeModel::new(cfg, 0, 5, None).is_err());
        assert!(ShardedNativeModel::new(cfg, 0, 0, None).is_err());
        assert!(ShardedNativeModel::new(cfg, 0, 4, Some(1)).is_ok());
    }

    #[test]
    fn non_separable_mixer_rejected_at_k_above_one() {
        let cfg = NativeVitConfig {
            mixer: Mixer::Fnet,
            ..Default::default()
        };
        let err = ShardedNativeModel::new(cfg, 0, 2, Some(1)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("not head-separable")
                    && msg.contains("fnet"),
                "unexpected error: {msg}");
        let cfg = NativeVitConfig {
            mixer: Mixer::Attention,
            ..Default::default()
        };
        assert!(ShardedNativeModel::new(cfg, 0, 2, Some(1)).is_err());
    }

    #[test]
    fn non_separable_mixer_serves_at_k_equals_one() {
        let cfg = NativeVitConfig {
            mixer: Mixer::Fnet,
            ..Default::default()
        };
        let images = test_images(&cfg, 2, 19);
        let want = NativeCatModel::new(cfg, 5).forward_batch(&images, 2)
            .unwrap();
        let sharded = ShardedNativeModel::new(cfg, 5, 1, Some(1)).unwrap();
        let got = sharded.forward_batch(&images, 2).unwrap();
        assert_eq!(got, want, "K=1 fnet diverged from unsharded");
    }

    #[test]
    fn circulant_sharded_matches_unsharded_bitwise() {
        let cfg = NativeVitConfig {
            mixer: Mixer::Circulant,
            ..Default::default()
        }; // d=64 h=4 L=2, N=64 (power of two)
        let full = NativeCatModel::new(cfg, 23);
        let images = test_images(&cfg, 2, 29);
        let want = full.forward_batch(&images, 2).unwrap();
        for k in [1usize, 2, 4] {
            let sharded =
                ShardedNativeModel::new(cfg, 23, k, Some(1)).unwrap();
            let got = sharded.forward_batch(&images, 2).unwrap();
            assert_eq!(got, want, "circulant K={k} diverged");
        }
    }

    #[test]
    fn cat_conv_sharded_matches_unsharded_bitwise() {
        let cfg = NativeVitConfig {
            mixer: Mixer::CatConv,
            ..Default::default()
        };
        let full = NativeCatModel::new(cfg, 31);
        let images = test_images(&cfg, 2, 37);
        let want = full.forward_batch(&images, 2).unwrap();
        for k in [1usize, 2, 4] {
            let sharded =
                ShardedNativeModel::new(cfg, 31, k, Some(1)).unwrap();
            let got = sharded.forward_batch(&images, 2).unwrap();
            assert_eq!(got, want, "cat_conv K={k} diverged");
        }
    }
}
