//! Replica supervision (DESIGN.md §12): the self-healing loop over the
//! router's replica slots.
//!
//! One supervisor thread per server (spawned when
//! `ServeOptions::restart_budget > 0`) polls every replica's shared
//! [`ReplicaState`] a few times per health interval. When a replica is
//! found dead — queue disconnect, [`MAX_MISSED_PINGS`] hard misses
//! escalating to a disconnect, or a `catch_unwind`-captured executor
//! panic — the supervisor:
//!
//! 1. moves it to `Backoff` and waits out a jittered exponential delay
//!    ([`BackoffPolicy`], base `restart_base`, doubling, capped), so a
//!    crash-looping executor cannot hot-spin respawns;
//! 2. respawns the worker through the **same** [`ExecutorFactory`] the
//!    original was built with (fault-injection wrappers included) on a
//!    fresh thread with a fresh bounded queue, swapping the queue
//!    sender into the replica's [`ReplicaSlot`] in place;
//! 3. revives the state into `Probation`: the health monitor pings it,
//!    and only `probation_pings` *consecutive* successes readmit it to
//!    dispatch ([`ReplicaState::note_ping_ok`]);
//! 4. records detected-death → readmission into the recovery histogram
//!    (`cat_recovery_time_us`).
//!
//! Respawn attempts are budgeted per replica across its whole lifetime:
//! once `restart_budget` attempts are spent the replica is marked
//! terminally dead ([`ReplicaState::mark_exhausted`]) — exactly the
//! pre-supervision behaviour, and what `/healthz` reports as
//! degraded-permanent.
//!
//! Thread teardown is leak-free by construction: the dead worker's
//! executor `Box` is dropped when its thread unwinds out of
//! `worker_loop`, which releases any dedicated shard pools
//! (`Drop for ShardWorker` joins the pool threads); the respawned
//! worker builds fresh ones. The supervisor returns every `JoinHandle`
//! it spawned so `Server::shutdown` joins respawned workers exactly
//! like original ones.
//!
//! [`ReplicaState`]: super::router::ReplicaState
//! [`ReplicaState::note_ping_ok`]: super::router::ReplicaState::note_ping_ok
//! [`ReplicaState::mark_exhausted`]:
//!     super::router::ReplicaState::mark_exhausted
//! [`MAX_MISSED_PINGS`]: super::router::MAX_MISSED_PINGS

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::retry::{Backoff, BackoffPolicy};
use super::router::{ReplicaPhase, ReplicaSlot, RouterCounters};
use super::server::{worker_loop, ExecutorFactory, LiveCounters,
                    ServeOptions, WorkerSpec, WorkerStats};
use crate::metrics::lock_recovering;
use crate::obs::log::{self as obs_log, Level};
use crate::Result;

/// Everything the supervisor needs to rebuild one replica: its routing
/// slot (shared with router + monitor), the worker spec the factory
/// consumes, and the live-counter cell the respawned worker keeps
/// appending to (restart survivors keep their request totals).
pub(crate) struct SupervisedSlot {
    pub(crate) slot: Arc<ReplicaSlot>,
    pub(crate) spec: Arc<WorkerSpec>,
    pub(crate) live: Arc<Mutex<LiveCounters>>,
    pub(crate) replica: usize,
}

/// The supervisor thread's working set, built by `Server::spawn_with`.
pub(crate) struct Supervisor {
    pub(crate) slots: Vec<SupervisedSlot>,
    pub(crate) factory: ExecutorFactory,
    pub(crate) opts: ServeOptions,
    pub(crate) stats_tx: mpsc::Sender<WorkerStats>,
    pub(crate) counters: Arc<RouterCounters>,
    pub(crate) stop: Arc<AtomicBool>,
    /// Jitter seed for the restart backoff schedules.
    pub(crate) seed: u64,
}

/// Per-replica bookkeeping private to the supervisor thread.
#[derive(Default)]
struct SlotWatch {
    /// Restart backoff schedule for the current outage (fresh per
    /// outage; attempts accumulate across outages via `attempts`).
    backoff: Option<Backoff>,
    /// Respawn attempts spent over this replica's lifetime — the
    /// restart budget is cumulative, so a crash-looping executor
    /// eventually goes terminally dead instead of flapping forever.
    attempts: u32,
    /// When the pending respawn fires.
    resume_at: Option<Instant>,
    /// When the current outage was first observed (time-to-recovery
    /// anchor; spans repeated crash loops until dispatch readmission).
    died_at: Option<Instant>,
    /// Respawned and waiting for probation to complete.
    awaiting_live: bool,
    /// Budget spent: never look at this replica again.
    exhausted: bool,
}

/// Restart delays: exponential from `base`, ±30% jitter, capped at 2s
/// per attempt. The budget only bounds the schedule object — attempt
/// counting (and exhaustion) is the supervisor's `restart_budget`.
fn restart_policy(base: Duration) -> BackoffPolicy {
    BackoffPolicy {
        base: base.max(Duration::from_millis(1)),
        factor: 2.0,
        max_delay: Duration::from_secs(2),
        jitter: 0.3,
        budget: Duration::from_secs(86_400),
    }
}

/// The supervisor loop. Returns the join handles of every worker
/// thread it spawned (for `Server::shutdown`).
pub(crate) fn supervisor_loop(sup: Supervisor)
                              -> Vec<std::thread::JoinHandle<()>> {
    // poll a few times per health interval: death detection is bounded
    // by the monitor's cadence anyway, so finer polling buys nothing
    let tick = (sup.opts.health_every / 4).max(Duration::from_millis(2));
    let probation = sup.opts.probation_pings.max(1);
    let mut watches: Vec<SlotWatch> =
        sup.slots.iter().map(|_| SlotWatch::default()).collect();
    let mut spawned = Vec::new();
    let mut seed = sup.seed;
    while !sup.stop.load(Ordering::Relaxed) {
        std::thread::sleep(tick);
        if sup.stop.load(Ordering::Relaxed) {
            break;
        }
        for (s, w) in sup.slots.iter().zip(watches.iter_mut()) {
            if w.exhausted {
                continue;
            }
            let state = s.slot.state();
            if w.awaiting_live {
                if state.phase() == ReplicaPhase::Live {
                    // probation served: the outage is over
                    if let Some(t0) = w.died_at.take() {
                        let dt = t0.elapsed();
                        lock_recovering(&sup.counters.recovery).record(dt);
                        obs_log::log_fields(
                            Level::Info, "supervisor",
                            "replica readmitted after probation",
                            &[("replica", &s.replica.to_string()),
                              ("model", &s.spec.model),
                              ("epoch", &state.restarts().to_string()),
                              ("recovery_ms",
                               &dt.as_millis().to_string())]);
                    }
                    w.awaiting_live = false;
                    w.backoff = None; // next outage gets a fresh schedule
                } else if !state.is_alive() {
                    // died again (in probation or right after): fall
                    // through to the outage handling below
                    w.awaiting_live = false;
                } else {
                    continue;
                }
            }
            if state.is_alive() {
                continue;
            }
            // replica is down
            if w.died_at.is_none() {
                w.died_at = Some(Instant::now());
                obs_log::log_fields(
                    Level::Warn, "supervisor", "replica death observed",
                    &[("replica", &s.replica.to_string()),
                      ("model", &s.spec.model),
                      ("epoch", &state.restarts().to_string()),
                      ("restarts_remaining",
                       &sup.opts.restart_budget
                            .saturating_sub(w.attempts).to_string())]);
            }
            match w.resume_at {
                None => {
                    if w.attempts >= sup.opts.restart_budget {
                        state.mark_exhausted();
                        w.exhausted = true;
                        obs_log::log_fields(
                            Level::Error, "supervisor",
                            "restart budget exhausted; replica is \
                             terminally dead",
                            &[("replica", &s.replica.to_string()),
                              ("model", &s.spec.model),
                              ("attempts", &w.attempts.to_string())]);
                        continue;
                    }
                    let b = w.backoff.get_or_insert_with(|| {
                        seed = seed.wrapping_add(0x9E37_79B9);
                        restart_policy(sup.opts.restart_base).start(seed)
                    });
                    let delay = b.next_delay(None)
                        .unwrap_or(Duration::from_secs(2));
                    state.mark_backoff();
                    w.resume_at = Some(Instant::now() + delay);
                    obs_log::log_fields(
                        Level::Debug, "supervisor", "respawn scheduled",
                        &[("replica", &s.replica.to_string()),
                          ("model", &s.spec.model),
                          ("delay_ms", &delay.as_millis().to_string()),
                          ("attempt", &(w.attempts + 1).to_string())]);
                }
                Some(at) if Instant::now() >= at => {
                    w.resume_at = None;
                    w.attempts += 1;
                    match respawn(&sup, s) {
                        Ok(handle) => {
                            spawned.push(handle);
                            state.revive(probation);
                            sup.counters.replicas_restarted
                                .fetch_add(1, Ordering::Relaxed);
                            w.awaiting_live = true;
                            obs_log::log_fields(
                                Level::Info, "supervisor",
                                "replica respawned; entering probation",
                                &[("replica", &s.replica.to_string()),
                                  ("model", &s.spec.model),
                                  ("epoch", &state.restarts().to_string()),
                                  ("restarts_remaining",
                                   &sup.opts.restart_budget
                                        .saturating_sub(w.attempts)
                                        .to_string())]);
                        }
                        Err(e) => {
                            // factory refused (or the thread died in
                            // startup): the attempt is spent; the next
                            // tick schedules the grown backoff delay
                            obs_log::log_fields(
                                Level::Warn, "supervisor",
                                "respawn attempt failed",
                                &[("replica", &s.replica.to_string()),
                                  ("model", &s.spec.model),
                                  ("attempt", &w.attempts.to_string()),
                                  ("error", &format!("{e:#}"))]);
                        }
                    }
                }
                Some(_) => {} // still backing off
            }
        }
    }
    spawned
}

/// Spawn a replacement worker for `s`: fresh bounded queue, executor
/// built by the factory **on the new thread** (PJRT handles are
/// `!Send`), readiness confirmed before the slot's sender is swapped —
/// a failed build leaves the slot untouched (still disconnected) and
/// costs one budget attempt. The caller revives the replica state.
fn respawn(sup: &Supervisor, s: &SupervisedSlot)
           -> Result<std::thread::JoinHandle<()>> {
    let (wtx, wrx) = mpsc::sync_channel(sup.opts.queue_depth);
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let spec = s.spec.clone();
    let opts = sup.opts;
    let factory = sup.factory.clone();
    let stats_tx = sup.stats_tx.clone();
    let live = s.live.clone();
    let state = s.slot.state().clone();
    let counters = sup.counters.clone();
    let replica = s.replica;
    let handle = std::thread::spawn(move || {
        match factory(spec.as_ref(), &opts) {
            Ok(exec) => {
                let _ = ready_tx.send(Ok(()));
                drop(ready_tx);
                worker_loop(spec.model.clone(), replica, exec, wrx, state,
                            opts, stats_tx, live, counters);
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e));
            }
        }
    });
    match ready_rx.recv() {
        Ok(Ok(())) => {
            s.slot.replace_sender(wtx);
            Ok(handle)
        }
        Ok(Err(e)) => {
            let _ = handle.join();
            Err(e.context(format!("respawn {} replica {replica}",
                                  s.spec.model)))
        }
        Err(_) => {
            let _ = handle.join();
            Err(anyhow!("respawned worker for {} replica {replica} died \
                         during startup", s.spec.model))
        }
    }
}
