//! Jittered exponential backoff with a retry budget — the one retry
//! schedule every `Busy`-absorbing client shares (DESIGN.md §11).
//!
//! Both retrying surfaces route through here: `ServeHandle::infer`'s
//! in-process loop and the HTTP integration tests' 429 recovery client.
//! The schedule honors the server's `retry_after` hint as a **floor**
//! (never retry sooner than the server asked), grows exponentially from
//! there, and jitters multiplicatively so a thundering herd of rejected
//! clients decorrelates instead of re-colliding on the next flush tick.
//!
//! The scheduler is split from the sleeper: [`Backoff::next_delay`]
//! *computes* the schedule and tracks the budget, the caller sleeps.
//! Tests drive the schedule directly — deterministically, with no
//! wall-clock sleeps.

use std::time::Duration;

use crate::data::Rng;

/// Shape of a backoff schedule. All fields are plain data so call sites
/// can build variants from one base policy.
#[derive(Debug, Clone, Copy)]
pub struct BackoffPolicy {
    /// First delay (before jitter), also the growth origin.
    pub base: Duration,
    /// Multiplier per attempt (≥ 1.0; 2.0 = classic doubling).
    pub factor: f64,
    /// Per-attempt ceiling (before jitter).
    pub max_delay: Duration,
    /// Multiplicative jitter half-width in [0, 1): each delay is scaled
    /// by a uniform factor in `[1 - jitter, 1 + jitter]`. 0 disables.
    pub jitter: f64,
    /// Total sleep budget: once the accumulated delays would exceed
    /// this, the schedule ends (`next_delay` returns `None`).
    pub budget: Duration,
}

impl BackoffPolicy {
    /// The serving default: start at the router's flush cadence, double
    /// per attempt, cap per-delay at 100ms, ±50% jitter.
    pub fn serving(base: Duration, budget: Duration) -> BackoffPolicy {
        BackoffPolicy {
            base: base.max(Duration::from_micros(100)),
            factor: 2.0,
            max_delay: Duration::from_millis(100),
            jitter: 0.5,
            budget,
        }
    }

    /// Start a schedule; `seed` decorrelates concurrent clients.
    pub fn start(self, seed: u64) -> Backoff {
        Backoff {
            policy: self,
            attempt: 0,
            slept: Duration::ZERO,
            rng: Rng::new(seed),
        }
    }
}

/// One in-progress retry schedule (one per request attempt sequence).
#[derive(Debug)]
pub struct Backoff {
    policy: BackoffPolicy,
    attempt: u32,
    slept: Duration,
    rng: Rng,
}

impl Backoff {
    /// Next delay to sleep before retrying, or `None` when the budget
    /// is exhausted (the caller surfaces the last error).
    ///
    /// `hint` is the server's `retry_after` — a floor on the raw delay,
    /// so backoff never undercuts explicit server guidance.
    pub fn next_delay(&mut self, hint: Option<Duration>)
                      -> Option<Duration> {
        let p = &self.policy;
        let growth = p.factor.max(1.0).powi(self.attempt as i32);
        let mut raw = p.base.as_secs_f64() * growth;
        raw = raw.min(p.max_delay.as_secs_f64());
        if let Some(h) = hint {
            raw = raw.max(h.as_secs_f64());
        }
        let jitter = p.jitter.clamp(0.0, 0.999);
        let scale = if jitter > 0.0 {
            1.0 - jitter + 2.0 * jitter * self.rng.uniform()
        } else {
            1.0
        };
        let delay = Duration::from_secs_f64(raw * scale);
        if self.slept + delay > p.budget {
            return None;
        }
        self.attempt = self.attempt.saturating_add(1);
        self.slept += delay;
        Some(delay)
    }

    /// Attempts granted so far (delays returned, not counting the
    /// initial try).
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Total sleep granted so far.
    pub fn slept(&self) -> Duration {
        self.slept
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BackoffPolicy {
        BackoffPolicy {
            base: Duration::from_millis(1),
            factor: 2.0,
            max_delay: Duration::from_millis(100),
            jitter: 0.5,
            budget: Duration::from_secs(1),
        }
    }

    #[test]
    fn delays_stay_inside_jitter_bounds_and_grow() {
        let mut b = policy().start(7);
        let mut raws = Vec::new();
        for attempt in 0..8 {
            let d = b.next_delay(None).expect("inside budget");
            let raw = 0.001 * 2f64.powi(attempt).min(100.0);
            let raw = raw.min(0.1);
            let secs = d.as_secs_f64();
            assert!(secs >= raw * 0.5 - 1e-9 && secs <= raw * 1.5 + 1e-9,
                    "attempt {attempt}: {secs}s outside [{}, {}]",
                    raw * 0.5, raw * 1.5);
            raws.push(raw);
        }
        // the raw schedule is monotone until the cap
        assert!(raws.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(b.attempts(), 8);
    }

    #[test]
    fn per_delay_cap_applies() {
        let mut p = policy();
        p.jitter = 0.0;
        let mut b = p.start(0);
        // attempt 10 raw = 1ms * 2^10 = 1.024s, capped at 100ms
        let mut last = Duration::ZERO;
        for _ in 0..9 {
            last = b.next_delay(None).unwrap();
        }
        assert_eq!(last, Duration::from_millis(100));
    }

    #[test]
    fn hint_floors_the_delay() {
        let mut p = policy();
        p.jitter = 0.0;
        let mut b = p.start(0);
        // base 1ms but the server said 50ms: honor the server
        let d = b.next_delay(Some(Duration::from_millis(50))).unwrap();
        assert_eq!(d, Duration::from_millis(50));
        // once growth passes the hint, growth wins
        for _ in 0..6 {
            b.next_delay(None).unwrap();
        }
        let d = b.next_delay(Some(Duration::from_millis(50))).unwrap();
        assert!(d > Duration::from_millis(50), "{d:?}");
    }

    #[test]
    fn budget_exhausts_and_accounts() {
        let mut p = policy();
        p.jitter = 0.0;
        p.budget = Duration::from_millis(10);
        let mut b = p.start(0);
        // 1 + 2 + 4 = 7ms granted; +8ms would blow the 10ms budget
        assert!(b.next_delay(None).is_some());
        assert!(b.next_delay(None).is_some());
        assert!(b.next_delay(None).is_some());
        assert!(b.next_delay(None).is_none(), "budget must exhaust");
        assert_eq!(b.slept(), Duration::from_millis(7));
        assert_eq!(b.attempts(), 3);
        // exhausted stays exhausted
        assert!(b.next_delay(None).is_none());
    }

    #[test]
    fn schedules_are_deterministic_per_seed() {
        let run = |seed| {
            let mut b = policy().start(seed);
            (0..6).map(|_| b.next_delay(None).unwrap()).collect::<Vec<_>>()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43), "seeds must decorrelate schedules");
    }

    #[test]
    fn zero_jitter_zero_growth_is_constant() {
        let p = BackoffPolicy {
            base: Duration::from_millis(5),
            factor: 1.0,
            max_delay: Duration::from_millis(100),
            jitter: 0.0,
            budget: Duration::from_millis(50),
        };
        let mut b = p.start(0);
        for _ in 0..10 {
            assert_eq!(b.next_delay(None), Some(Duration::from_millis(5)));
        }
        assert!(b.next_delay(None).is_none());
    }
}
