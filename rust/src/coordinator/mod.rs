//! L3 serving coordinator: request router, dynamic batcher, data-parallel
//! replica sets with health checks + backpressure ([`router`]),
//! head-parallel model shards ([`shard`]), replica supervision with
//! respawn + probation ([`supervisor`]), and per-replica workers over a
//! pluggable [`BatchExecutor`] — PJRT artifacts or the native Rust CAT
//! executor, per [`crate::runtime::Backend`] (vLLM-router shaped; the
//! paper's contribution lives at L1/L2 so this layer is a
//! production-grade driver, per DESIGN.md §3, §6, §10 and §12).

pub mod batcher;
pub mod retry;
pub mod router;
pub mod server;
pub mod shard;
pub mod supervisor;
pub mod workload;

pub use batcher::{DynamicBatcher, Flush, Pending};
pub use retry::{Backoff, BackoffPolicy};
pub use router::{Rejection, ReplicaPhase, RouterStats, ServeError,
                 MAX_MISSED_PINGS};
pub use server::{aggregate_stats, default_factory, split_rows,
                 BatchExecutor, ExecutorFactory, InferRequest, ModelStats,
                 ReplicaSnapshot, ServeHandle, ServeOptions, Server,
                 StatsHandle, WorkerSpec, WorkerStats};
pub use shard::{ShardStatsSnapshot, ShardedNativeModel};
pub use workload::{ArrivalSampler, Arrivals};
