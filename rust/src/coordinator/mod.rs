//! L3 serving coordinator: request router, dynamic batcher, per-model
//! workers over a pluggable [`BatchExecutor`] — PJRT artifacts or the
//! native Rust CAT executor, per [`crate::runtime::Backend`] (vLLM-router
//! shaped; the paper's contribution lives at L1/L2 so this layer is a
//! production-grade driver, per DESIGN.md §3 and §6).

pub mod batcher;
pub mod server;
pub mod workload;

pub use batcher::{DynamicBatcher, Flush, Pending};
pub use server::{split_rows, BatchExecutor, InferRequest, ServeHandle,
                 ServeOptions, Server, WorkerSpec, WorkerStats};
pub use workload::{ArrivalSampler, Arrivals};
