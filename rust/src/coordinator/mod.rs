//! L3 serving coordinator: request router, dynamic batcher, per-model
//! workers over the PJRT executables (vLLM-router shaped; the paper's
//! contribution lives at L1/L2 so this layer is a production-grade driver,
//! per DESIGN.md §3).

pub mod batcher;
pub mod server;
pub mod workload;

pub use batcher::{DynamicBatcher, Flush, Pending};
pub use server::{InferRequest, ServeHandle, ServeOptions, Server,
                 WorkerStats};
pub use workload::{ArrivalSampler, Arrivals};
