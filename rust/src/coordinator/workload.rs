//! Serving workload generators: arrival processes for driving the router
//! under realistic traffic shapes (steady Poisson, diurnal ramp, bursts).
//!
//! The paper's efficiency claims are about per-op cost; a serving
//! deployment cares how that interacts with batching under load. The
//! `serve` example and the coordinator bench use these generators so the
//! reported latency/occupancy numbers come from a principled arrival
//! process rather than a closed loop.

use std::time::Duration;

use crate::data::rng::Rng;

/// An arrival process: yields successive inter-arrival gaps.
#[derive(Debug, Clone)]
pub enum Arrivals {
    /// Poisson process with constant rate (req/s).
    Poisson { rate: f64 },
    /// Poisson modulated by a sinusoid: rate * (1 + depth*sin(2πt/period)).
    Diurnal { rate: f64, depth: f64, period: Duration },
    /// Markov-modulated on/off bursts: `burst_rate` while on, `idle_rate`
    /// while off; exponential dwell times.
    Bursty {
        burst_rate: f64,
        idle_rate: f64,
        mean_burst: Duration,
        mean_idle: Duration,
    },
}

/// Stateful sampler over an [`Arrivals`] spec.
#[derive(Debug, Clone)]
pub struct ArrivalSampler {
    spec: Arrivals,
    rng: Rng,
    /// elapsed virtual time (seconds)
    t: f64,
    /// Bursty: in-burst flag + remaining dwell
    burst_on: bool,
    dwell_left: f64,
}

impl ArrivalSampler {
    pub fn new(spec: Arrivals, seed: u64) -> Self {
        Self { spec, rng: Rng::new(seed), t: 0.0, burst_on: true,
               dwell_left: 0.0 }
    }

    fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.rng.uniform()).ln() / rate
    }

    /// Next inter-arrival gap.
    pub fn next_gap(&mut self) -> Duration {
        let gap = match self.spec.clone() {
            Arrivals::Poisson { rate } => self.exp(rate),
            Arrivals::Diurnal { rate, depth, period } => {
                let phase = std::f64::consts::TAU * self.t
                    / period.as_secs_f64().max(1e-9);
                let r = (rate * (1.0 + depth * phase.sin())).max(1e-3);
                self.exp(r)
            }
            Arrivals::Bursty { burst_rate, idle_rate, mean_burst,
                               mean_idle } => {
                if self.dwell_left <= 0.0 {
                    self.burst_on = !self.burst_on;
                    let mean = if self.burst_on { mean_burst } else { mean_idle };
                    self.dwell_left = self.exp(1.0 / mean.as_secs_f64()
                        .max(1e-9));
                }
                let rate = if self.burst_on { burst_rate } else { idle_rate };
                let g = self.exp(rate.max(1e-3));
                self.dwell_left -= g;
                g
            }
        };
        self.t += gap;
        Duration::from_secs_f64(gap)
    }

    /// Generate the full schedule of `n` arrival offsets from t=0.
    pub fn schedule(&mut self, n: usize) -> Vec<Duration> {
        let mut t = Duration::ZERO;
        (0..n)
            .map(|_| {
                t += self.next_gap();
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate_is_right() {
        let mut s = ArrivalSampler::new(Arrivals::Poisson { rate: 100.0 }, 1);
        let n = 20_000;
        let total: f64 = (0..n).map(|_| s.next_gap().as_secs_f64()).sum();
        let rate = n as f64 / total;
        assert!((rate - 100.0).abs() < 5.0, "measured rate {rate}");
    }

    #[test]
    fn schedule_is_monotone() {
        let mut s = ArrivalSampler::new(Arrivals::Poisson { rate: 50.0 }, 2);
        let sched = s.schedule(500);
        assert_eq!(sched.len(), 500);
        for w in sched.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn diurnal_rate_varies() {
        let mut s = ArrivalSampler::new(
            Arrivals::Diurnal { rate: 100.0, depth: 0.9,
                                period: Duration::from_secs(1) }, 3);
        // gaps drawn near the trough should on average exceed gaps at peak;
        // just sanity-check dispersion is wider than flat Poisson
        let gaps: Vec<f64> = (0..5000)
            .map(|_| s.next_gap().as_secs_f64())
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>()
            / gaps.len() as f64;
        let cv2 = var / (mean * mean);
        assert!(cv2 > 1.05, "coefficient of variation^2 {cv2} not >1 \
                             (modulated Poisson is over-dispersed)");
    }

    #[test]
    fn bursty_alternates_phases() {
        let mut s = ArrivalSampler::new(
            Arrivals::Bursty {
                burst_rate: 1000.0,
                idle_rate: 1.0,
                mean_burst: Duration::from_millis(50),
                mean_idle: Duration::from_millis(50),
            }, 4);
        let gaps: Vec<f64> = (0..2000)
            .map(|_| s.next_gap().as_secs_f64())
            .collect();
        let tiny = gaps.iter().filter(|g| **g < 0.005).count();
        let large = gaps.iter().filter(|g| **g > 0.05).count();
        assert!(tiny > 100, "no burst gaps ({tiny})");
        assert!(large > 5, "no idle gaps ({large})");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = ArrivalSampler::new(Arrivals::Poisson { rate: 10.0 }, 9)
            .schedule(50);
        let b = ArrivalSampler::new(Arrivals::Poisson { rate: 10.0 }, 9)
            .schedule(50);
        assert_eq!(a, b);
    }
}
