//! Data substrates: everything the paper's evaluation consumes, built from
//! scratch (DESIGN.md §Substitutions maps each to its paper counterpart).
//!
//! * [`rng`] — deterministic SplitMix64 PRNG + Zipf sampler
//! * [`images`] — procedural 10-class ImageNet substitute
//! * [`text`] — Zipf-Markov corpus with planted long-range copies
//!   (WikiText-103 substitute), masked/causal batch preparation
//! * [`tokenizer`] — word-level tokenizer with byte fallback (serving path)
//! * [`batch`] — manifest-ordered batch assembly per task

pub mod augment;
pub mod batch;
pub mod images;
pub mod rng;
pub mod text;
pub mod tokenizer;

pub use augment::AugmentConfig;
pub use batch::{BatchSource, Truth};
pub use images::ShapeDataset;
pub use rng::{Rng, Zipf};
pub use text::TextCorpus;
pub use tokenizer::Tokenizer;
