//! Synthetic WikiText-103 substitute: a Zipf-Markov corpus with planted
//! long-range copy dependencies (DESIGN.md §Substitutions).
//!
//! Construction per token stream:
//!  * a Zipf(1.1) unigram backbone over `vocab` word ids (natural-language
//!    unigram statistics are approximately Zipfian);
//!  * a first-order Markov overlay: each token deterministically biases a
//!    small successor set (hash-derived), giving local bigram structure a
//!    causal LM can learn;
//!  * planted *copy spans*: with small probability, a marker token is
//!    emitted followed by a copy of the tokens from `offset` positions
//!    back — long-range structure that rewards global token mixing (what
//!    masked-LM evaluation probes in Table 2).
//!
//! All generation is deterministic in (seed, position); train/valid splits
//! use disjoint seed forks. Masked-LM corruption (BERT-style 15%) and
//! causal next-token batch preparation both live here so every LM artifact
//! sees the same uniform (tokens, targets, weights) signature.

use super::rng::{Rng, Zipf};

/// Reserved token ids at the bottom of the vocabulary.
pub const PAD: i32 = 0;
pub const MASK: i32 = 1;
pub const COPY_MARK: i32 = 2;
pub const FIRST_WORD: i32 = 3;

/// Corpus generator. `vocab` includes the reserved ids.
#[derive(Debug, Clone)]
pub struct TextCorpus {
    vocab: usize,
    zipf: Zipf,
    seed: u64,
    /// probability of starting a copy span at any position
    pub copy_prob: f64,
    /// copy span length
    pub copy_len: usize,
    /// how far back the copied span starts
    pub copy_offset: usize,
    /// weight of the Markov successor overlay
    pub markov_prob: f64,
}

impl TextCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab > FIRST_WORD as usize + 8, "vocab too small");
        Self {
            vocab,
            zipf: Zipf::new(vocab - FIRST_WORD as usize, 1.1),
            seed,
            copy_prob: 0.04,
            copy_len: 8,
            copy_offset: 32,
            markov_prob: 0.5,
        }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab
    }

    /// Deterministic Markov successor of a word id (hash-derived).
    fn successor(&self, tok: i32, rng: &mut Rng) -> i32 {
        let h = (tok as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let base = FIRST_WORD as u64
            + (h % (self.vocab as u64 - FIRST_WORD as u64));
        // one of 4 successors of the deterministic base
        let succ = base.wrapping_add(rng.below(4) as u64)
            % (self.vocab as u64 - FIRST_WORD as u64);
        FIRST_WORD + succ as i32
    }

    /// Generate a fresh token sequence of length `len` for stream `stream`.
    pub fn sequence(&self, stream: u64, len: usize) -> Vec<i32> {
        let mut rng = Rng::new(
            self.seed ^ stream.wrapping_mul(0xD134_2543_DE82_EF95));
        let mut out: Vec<i32> = Vec::with_capacity(len);
        let mut copy_remaining = 0usize;
        while out.len() < len {
            if copy_remaining > 0 && out.len() >= self.copy_offset {
                let src = out.len() - self.copy_offset;
                let tok = out[src];
                copy_remaining -= 1;
                if tok != COPY_MARK {
                    out.push(tok);
                } else {
                    // never replicate a marker (it would make the
                    // "marker => span follows" semantics ambiguous); draw
                    // a plain word for this slot instead
                    out.push(FIRST_WORD + self.zipf.sample(&mut rng) as i32);
                }
                continue;
            }
            if out.len() >= self.copy_offset && rng.bernoulli(self.copy_prob) {
                out.push(COPY_MARK);
                copy_remaining = self.copy_len;
                continue;
            }
            let tok = if !out.is_empty() && rng.bernoulli(self.markov_prob) {
                self.successor(*out.last().expect("nonempty"), &mut rng)
            } else {
                FIRST_WORD + self.zipf.sample(&mut rng) as i32
            };
            out.push(tok);
        }
        out
    }

    /// Causal-LM batch: inputs are tokens, targets the next token, all
    /// positions weighted 1 (last position predicts the following stream
    /// token, included in the generated length + 1).
    pub fn causal_batch(&self, start_stream: u64, batch: usize, n: usize)
                        -> LmBatch {
        let mut tokens = Vec::with_capacity(batch * n);
        let mut targets = Vec::with_capacity(batch * n);
        let weights = vec![1.0f32; batch * n];
        for b in 0..batch {
            let seq = self.sequence(start_stream + b as u64, n + 1);
            tokens.extend_from_slice(&seq[..n]);
            targets.extend_from_slice(&seq[1..=n]);
        }
        LmBatch { tokens, targets, weights, batch, n }
    }

    /// Masked-LM batch (BERT-style): 15% of positions selected; of those
    /// 80% replaced with MASK, 10% random word, 10% kept; loss weights are
    /// 1 exactly on the selected positions.
    pub fn masked_batch(&self, start_stream: u64, batch: usize, n: usize,
                        mask_prob: f64) -> LmBatch {
        let mut tokens = Vec::with_capacity(batch * n);
        let mut targets = Vec::with_capacity(batch * n);
        let mut weights = vec![0.0f32; batch * n];
        for b in 0..batch {
            let seq = self.sequence(start_stream + b as u64, n);
            let mut rng = Rng::new(
                self.seed ^ (start_stream + b as u64)
                    .wrapping_mul(0xA076_1D64_78BD_642F) ^ 0x6d61736b);
            for (i, &t) in seq.iter().enumerate() {
                targets.push(t);
                if rng.bernoulli(mask_prob) {
                    weights[b * n + i] = 1.0;
                    let r = rng.uniform();
                    if r < 0.8 {
                        tokens.push(MASK);
                    } else if r < 0.9 {
                        tokens.push(FIRST_WORD
                            + rng.below(self.vocab - FIRST_WORD as usize)
                                as i32);
                    } else {
                        tokens.push(t);
                    }
                } else {
                    tokens.push(t);
                }
            }
        }
        LmBatch { tokens, targets, weights, batch, n }
    }
}

/// A uniform LM batch matching the AOT train_step signature.
#[derive(Debug, Clone)]
pub struct LmBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub weights: Vec<f32>,
    pub batch: usize,
    pub n: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let c = TextCorpus::new(1024, 7);
        assert_eq!(c.sequence(3, 100), c.sequence(3, 100));
        assert_ne!(c.sequence(3, 100), c.sequence(4, 100));
    }

    #[test]
    fn tokens_in_vocab() {
        let c = TextCorpus::new(512, 1);
        for &t in &c.sequence(0, 1000) {
            assert!((0..512).contains(&t));
            assert!(t != PAD && t != MASK);
        }
    }

    #[test]
    fn copy_spans_planted() {
        let mut c = TextCorpus::new(1024, 2);
        c.copy_prob = 0.2;
        let seq = c.sequence(0, 2000);
        // after every COPY_MARK the next copy_len tokens replicate the
        // window copy_offset back
        let mut found = 0;
        for i in 0..seq.len() {
            if seq[i] == COPY_MARK && i + c.copy_len < seq.len()
                && i >= c.copy_offset {
                for k in 1..=c.copy_len.min(3) {
                    // markers are never replicated (a fresh token is drawn
                    // instead), so only check non-marker sources
                    if seq[i + k - c.copy_offset] != COPY_MARK {
                        assert_eq!(seq[i + k], seq[i + k - c.copy_offset],
                                   "span at {i}, k={k}");
                    }
                }
                found += 1;
            }
        }
        assert!(found > 5, "only {found} copy spans in 2000 tokens");
    }

    #[test]
    fn zipf_head_dominates() {
        let c = TextCorpus::new(1024, 3);
        let seq = c.sequence(0, 20_000);
        let mut counts = vec![0usize; 1024];
        for &t in &seq {
            counts[t as usize] += 1;
        }
        let head: usize = counts[3..23].iter().sum();
        let tail: usize = counts[523..543].iter().sum();
        assert!(head > 5 * (tail + 1));
    }

    #[test]
    fn causal_batch_is_shifted() {
        let c = TextCorpus::new(256, 4);
        let b = c.causal_batch(0, 2, 32);
        assert_eq!(b.tokens.len(), 64);
        assert_eq!(b.targets.len(), 64);
        assert!(b.weights.iter().all(|&w| w == 1.0));
        // target[i] == token[i+1] within each row
        for row in 0..2 {
            for i in 0..31 {
                assert_eq!(b.targets[row * 32 + i], b.tokens[row * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn masked_batch_statistics() {
        let c = TextCorpus::new(1024, 5);
        let b = c.masked_batch(0, 8, 256, 0.15);
        let selected: f32 = b.weights.iter().sum();
        let frac = selected / (8.0 * 256.0);
        assert!((0.10..0.20).contains(&frac), "mask fraction {frac}");
        // positions with weight 0 are unchanged
        for i in 0..b.tokens.len() {
            if b.weights[i] == 0.0 {
                assert_eq!(b.tokens[i], b.targets[i]);
            }
        }
        // some masked positions actually show MASK
        let masked = b.tokens.iter().filter(|&&t| t == MASK).count();
        assert!(masked > 100, "{masked}");
    }
}
