//! Word-level tokenizer with frequency-built vocabulary and byte-level
//! fallback — the serving-path substrate (`examples/serve.rs`) that maps
//! user strings onto the synthetic-corpus id space.
//!
//! Ids 0..3 are reserved (PAD/MASK/COPY_MARK, matching `data::text`);
//! unknown words degrade to per-byte ids hashed into a fixed fallback
//! band so tokenization is total (never fails) and deterministic.

use std::collections::HashMap;

use super::text::FIRST_WORD;

/// Frequency-ranked word vocabulary.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: Vec<String>,
    index: HashMap<String, i32>,
    max_id: i32,
    /// first id of the byte-fallback band (top 256 ids)
    fallback_base: i32,
}

impl Tokenizer {
    /// Build from a corpus of text: rank words by frequency, keep the top
    /// `vocab_size - FIRST_WORD - 256` as real words, reserve the top 256
    /// ids as the byte-fallback band.
    pub fn build(texts: &[&str], vocab_size: usize) -> Self {
        assert!(vocab_size > FIRST_WORD as usize + 256 + 16,
                "vocab too small for fallback band");
        let mut freq: HashMap<String, u64> = HashMap::new();
        for t in texts {
            for w in t.split_whitespace() {
                let w = normalize(w);
                if !w.is_empty() {
                    *freq.entry(w).or_insert(0) += 1;
                }
            }
        }
        let mut words: Vec<(String, u64)> = freq.into_iter().collect();
        words.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        let fallback_base = (vocab_size - 256) as i32;
        let keep = (fallback_base - FIRST_WORD) as usize;
        words.truncate(keep);
        let vocab: Vec<String> = words.into_iter().map(|(w, _)| w).collect();
        let index = vocab
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), FIRST_WORD + i as i32))
            .collect();
        Self { vocab, index, max_id: vocab_size as i32 - 1, fallback_base }
    }

    pub fn vocab_len(&self) -> usize {
        self.vocab.len()
    }

    /// Tokenize a string; unknown words emit one byte-band id per byte.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::new();
        for w in text.split_whitespace() {
            let w = normalize(w);
            if w.is_empty() {
                continue;
            }
            if let Some(&id) = self.index.get(&w) {
                out.push(id);
            } else {
                for b in w.bytes() {
                    out.push(self.fallback_base + b as i32);
                }
            }
        }
        out
    }

    /// Best-effort decode (fallback ids render as `<bXX>`).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut parts = Vec::with_capacity(ids.len());
        for &id in ids {
            if id >= self.fallback_base && id <= self.max_id {
                parts.push(format!("<b{:02x}>", id - self.fallback_base));
            } else if id >= FIRST_WORD
                && ((id - FIRST_WORD) as usize) < self.vocab.len() {
                parts.push(self.vocab[(id - FIRST_WORD) as usize].clone());
            } else {
                parts.push(format!("<{id}>"));
            }
        }
        parts.join(" ")
    }

    /// Pad/truncate ids to exactly `n` (PAD = 0 on the right).
    pub fn fit(&self, mut ids: Vec<i32>, n: usize) -> Vec<i32> {
        ids.truncate(n);
        ids.resize(n, 0);
        ids
    }
}

fn normalize(w: &str) -> String {
    w.chars()
        .filter(|c| c.is_alphanumeric())
        .flat_map(|c| c.to_lowercase())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::build(
            &["the cat sat on the mat", "the dog sat on the log",
              "cat and dog and cat"],
            1024)
    }

    #[test]
    fn frequent_words_get_small_ids() {
        let t = tok();
        let the = t.encode("the")[0];
        let log = t.encode("log")[0];
        assert!(the < log, "the={the} log={log}");
        assert!(the >= FIRST_WORD);
    }

    #[test]
    fn roundtrip_known_words() {
        let t = tok();
        let ids = t.encode("the cat sat");
        assert_eq!(t.decode(&ids), "the cat sat");
    }

    #[test]
    fn unknown_words_fall_back_to_bytes() {
        let t = tok();
        let ids = t.encode("zebra");
        assert_eq!(ids.len(), "zebra".len());
        assert!(ids.iter().all(|&i| i >= 1024 - 256 && i < 1024));
    }

    #[test]
    fn encode_total_and_deterministic() {
        let t = tok();
        assert_eq!(t.encode("Hello, WORLD!"), t.encode("hello world"));
        assert!(t.encode("").is_empty());
    }

    #[test]
    fn fit_pads_and_truncates() {
        let t = tok();
        assert_eq!(t.fit(vec![5, 6], 4), vec![5, 6, 0, 0]);
        assert_eq!(t.fit(vec![5, 6, 7, 8, 9], 3), vec![5, 6, 7]);
    }

    #[test]
    fn ids_within_vocab() {
        let t = tok();
        for &id in t.encode("the unknownword cat qq").iter() {
            assert!((0..1024).contains(&id));
        }
    }
}
