//! Deterministic PRNG for every data substrate: SplitMix64 core with
//! normal / uniform / Zipf helpers. No external crates, fully reproducible
//! across runs and platforms — the property every experiment in
//! EXPERIMENTS.md relies on.

use crate::Result;
use anyhow::ensure;

/// SplitMix64: tiny, fast, passes BigCrush as a 64-bit mixer.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent stream (for per-shard / per-epoch splits).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58_476D_1CE4_E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

/// Zipf(s) sampler over {0..n-1} using a precomputed CDF — the token
/// frequency model for the synthetic WikiText substitute (natural-language
/// unigram frequencies are approximately Zipfian).
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Infallible constructor for in-tree literal parameters. Panics (with
    /// the [`Zipf::try_new`] error) on invalid `(n, s)` — use `try_new`
    /// for anything user- or data-derived.
    pub fn new(n: usize, s: f64) -> Self {
        Self::try_new(n, s).expect("valid Zipf parameters")
    }

    /// Build the CDF, rejecting any parameterization whose weights are not
    /// strictly positive and finite. Without this, a degenerate `s` (e.g.
    /// a large negative exponent underflowing `k^s` to 0) produced
    /// `inf/inf = NaN` CDF entries, and `sample`'s comparator `unwrap`
    /// aborted the process at the first draw instead of erroring here.
    pub fn try_new(n: usize, s: f64) -> Result<Self> {
        ensure!(n > 0, "Zipf needs a non-empty support, got n=0");
        ensure!(s.is_finite(), "Zipf exponent must be finite, got s={s}");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            let w = 1.0 / (k as f64).powf(s);
            ensure!(w.is_finite() && w > 0.0,
                    "Zipf weight 1/{k}^{s} = {w} is not a positive finite \
                     number; pick a tamer exponent");
            acc += w;
            cdf.push(acc);
        }
        ensure!(acc.is_finite() && acc > 0.0,
                "Zipf total mass {acc} is not positive and finite (n={n}, \
                 s={s})");
        for c in cdf.iter_mut() {
            *c /= acc;
        }
        Ok(Self { cdf })
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        // total_cmp: a total order even on non-finite values, so a
        // corrupted CDF can misreport a bucket but can never abort the
        // process the way the old `partial_cmp(..).unwrap()` did
        match self.cdf.binary_search_by(|c| c.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = Rng::new(42);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_head_heavy() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Rng::new(9);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > 50 * counts[900].max(1) / 10);
    }

    #[test]
    fn zipf_rejects_degenerate_parameters() {
        // n = 0: no support
        assert!(Zipf::try_new(0, 1.1).is_err());
        // non-finite exponent
        assert!(Zipf::try_new(10, f64::NAN).is_err());
        assert!(Zipf::try_new(10, f64::INFINITY).is_err());
        // s = -9000: k^s underflows to 0 for k >= 2, so the weight 1/k^s
        // is +inf — the zero-mass shape that used to surface as a NaN CDF
        // and an abort inside sample()
        let err = Zipf::try_new(10, -9000.0).unwrap_err();
        assert!(format!("{err}").contains("not a positive finite"),
                "unexpected message: {err}");
        // s = 9000 underflows the *tail* weights to zero instead
        assert!(Zipf::try_new(10, 9000.0).is_err());
    }

    #[test]
    fn zipf_sample_never_panics_and_stays_in_range() {
        let z = Zipf::try_new(64, 1.1).unwrap();
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 64);
        }
        // single-element support always returns 0
        let one = Zipf::try_new(1, 2.0).unwrap();
        assert_eq!(one.sample(&mut r), 0);
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
