//! Batch assembly: turns the synthetic substrates into the exact
//! `HostTensor` argument lists the AOT train/forward entries expect.
//!
//! One `BatchSource` per task; `next_train` / `eval_batch` return tensors
//! in manifest input order (images+labels for ViT, tokens+targets+weights
//! for LMs). Train and eval draw from disjoint index/stream ranges so the
//! reported metrics are held-out.

use super::augment::{augment_batch, AugmentConfig};
use super::images::{ShapeDataset, CHANNELS, IMAGE_SIZE};
use super::text::TextCorpus;
use crate::runtime::ConfigMeta;
use crate::tensor::HostTensor;
use crate::Result;

/// Offset separating eval streams from train streams.
const EVAL_STREAM_BASE: u64 = 1 << 40;

/// Task-aware batch generator bound to one model config.
pub struct BatchSource {
    meta: ConfigMeta,
    images: Option<ShapeDataset>,
    text: Option<TextCorpus>,
    cursor: u64,
    mask_prob: f64,
    seed: u64,
    /// train-time image augmentation (paper recipe: random crop + hflip);
    /// disabled by default so short table runs stay comparable
    augment: AugmentConfig,
}

impl BatchSource {
    pub fn new(meta: &ConfigMeta, seed: u64) -> Self {
        let images = meta.is_vit().then(|| ShapeDataset::new(seed));
        let text = meta.is_lm()
            .then(|| TextCorpus::new(meta.vocab_size, seed));
        Self {
            meta: meta.clone(),
            images,
            text,
            cursor: 0,
            mask_prob: 0.15,
            seed,
            augment: AugmentConfig::disabled(),
        }
    }

    /// Enable the paper's train-time augmentation (eval stays clean).
    pub fn set_augment(&mut self, cfg: AugmentConfig) {
        self.augment = cfg;
    }

    /// Next training batch (advances the cursor). Image batches get the
    /// train-time augmentation if enabled; eval batches never do.
    pub fn next_train(&mut self) -> Result<Vec<HostTensor>> {
        let b = self.meta.batch_size;
        let mut out = self.batch_at(self.cursor, b)?;
        if self.augment.enabled && self.images.is_some() {
            if let crate::tensor::TensorData::F32(pixels) = &mut out[0].data {
                augment_batch(pixels, b, CHANNELS, IMAGE_SIZE,
                              &self.augment, self.seed,
                              self.cursor / b.max(1) as u64);
            }
        }
        self.cursor += b as u64;
        Ok(out)
    }

    /// Deterministic held-out batch `i` (disjoint from the train range).
    pub fn eval_batch(&self, i: u64) -> Result<Vec<HostTensor>> {
        let b = self.meta.batch_size;
        self.batch_at(EVAL_STREAM_BASE + i * b as u64, b)
    }

    fn batch_at(&self, start: u64, b: usize) -> Result<Vec<HostTensor>> {
        if let Some(ds) = &self.images {
            let mut pixels = Vec::new();
            let mut labels = Vec::new();
            ds.fill_batch(start, b, &mut pixels, &mut labels);
            return Ok(vec![
                HostTensor::f32(
                    vec![b, CHANNELS, IMAGE_SIZE, IMAGE_SIZE], pixels)?,
                HostTensor::i32(vec![b], labels)?,
            ]);
        }
        let corpus = self.text.as_ref().expect("lm batch source");
        let n = self.meta.seq_len;
        let lb = if self.meta.causal {
            corpus.causal_batch(start, b, n)
        } else {
            corpus.masked_batch(start, b, n, self.mask_prob)
        };
        Ok(vec![
            HostTensor::i32(vec![b, n], lb.tokens)?,
            HostTensor::i32(vec![b, n], lb.targets)?,
            HostTensor::f32(vec![b, n], lb.weights)?,
        ])
    }

    /// Ground-truth labels/targets+weights of an assembled batch, for
    /// host-side metric computation against `forward` logits.
    pub fn truth(batch: &[HostTensor]) -> Truth<'_> {
        if batch.len() == 2 {
            Truth::Labels(batch[1].as_i32().expect("labels i32"))
        } else {
            Truth::Tokens {
                targets: batch[1].as_i32().expect("targets i32"),
                weights: batch[2].as_f32().expect("weights f32"),
            }
        }
    }

    /// Inputs for the `forward` entry: everything except labels/targets.
    pub fn forward_inputs(batch: &[HostTensor]) -> &[HostTensor] {
        &batch[..1]
    }

    pub fn meta(&self) -> &ConfigMeta {
        &self.meta
    }
}

/// Ground truth view for metrics.
pub enum Truth<'a> {
    Labels(&'a [i32]),
    Tokens { targets: &'a [i32], weights: &'a [f32] },
}
