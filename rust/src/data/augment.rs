//! Image augmentation: random resized crop + horizontal flip — the paper's
//! ImageNet training recipe (Sec. 5.2: "random cropping and horizontal
//! flipping").
//!
//! Operates on CHW f32 buffers host-side, before upload. Off by default in
//! the table harness: the proxy runs are a few hundred steps on an
//! infinite generator (no overfitting to fight), and enabling it would
//! change the recorded tables; it exists for recipe fidelity and for
//! longer runs (`cat train --augment`).

use super::rng::Rng;

/// Augmentation configuration.
#[derive(Debug, Clone, Copy)]
pub struct AugmentConfig {
    /// probability of a horizontal flip
    pub flip_prob: f64,
    /// minimum crop scale (area fraction); 1.0 disables cropping
    pub min_crop_scale: f32,
    pub enabled: bool,
}

impl Default for AugmentConfig {
    fn default() -> Self {
        Self { flip_prob: 0.5, min_crop_scale: 0.7, enabled: true }
    }
}

impl AugmentConfig {
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::default() }
    }
}

/// Augment one CHW image of side `size` in place (via a scratch buffer).
pub fn augment_image(img: &mut [f32], channels: usize, size: usize,
                     cfg: &AugmentConfig, rng: &mut Rng) {
    debug_assert_eq!(img.len(), channels * size * size);
    if !cfg.enabled {
        return;
    }
    if cfg.min_crop_scale < 1.0 {
        let scale = cfg.min_crop_scale
            + (1.0 - cfg.min_crop_scale) * rng.uniform() as f32;
        let crop = ((size as f32) * scale.sqrt()).round().max(1.0) as usize;
        if crop < size {
            let max_off = size - crop;
            let ox = rng.below(max_off + 1);
            let oy = rng.below(max_off + 1);
            random_crop_resize(img, channels, size, crop, ox, oy);
        }
    }
    if rng.bernoulli(cfg.flip_prob) {
        hflip(img, channels, size);
    }
}

/// Crop a `crop`x`crop` window at (ox, oy) and bilinearly resize back to
/// `size`x`size`, per channel, in place.
fn random_crop_resize(img: &mut [f32], channels: usize, size: usize,
                      crop: usize, ox: usize, oy: usize) {
    let pix = size * size;
    let mut out = vec![0f32; img.len()];
    let ratio = crop as f32 / size as f32;
    for c in 0..channels {
        let src = &img[c * pix..(c + 1) * pix];
        let dst = &mut out[c * pix..(c + 1) * pix];
        for y in 0..size {
            // sample position inside the crop window
            let fy = oy as f32 + (y as f32 + 0.5) * ratio - 0.5;
            let y0 = fy.floor().max(0.0) as usize;
            let y1 = (y0 + 1).min(size - 1);
            let wy = (fy - y0 as f32).clamp(0.0, 1.0);
            for x in 0..size {
                let fx = ox as f32 + (x as f32 + 0.5) * ratio - 0.5;
                let x0 = fx.floor().max(0.0) as usize;
                let x1 = (x0 + 1).min(size - 1);
                let wx = (fx - x0 as f32).clamp(0.0, 1.0);
                let v00 = src[y0 * size + x0];
                let v01 = src[y0 * size + x1];
                let v10 = src[y1 * size + x0];
                let v11 = src[y1 * size + x1];
                dst[y * size + x] = v00 * (1.0 - wy) * (1.0 - wx)
                    + v01 * (1.0 - wy) * wx
                    + v10 * wy * (1.0 - wx)
                    + v11 * wy * wx;
            }
        }
    }
    img.copy_from_slice(&out);
}

/// Mirror each row, per channel, in place.
fn hflip(img: &mut [f32], channels: usize, size: usize) {
    let pix = size * size;
    for c in 0..channels {
        let plane = &mut img[c * pix..(c + 1) * pix];
        for y in 0..size {
            plane[y * size..(y + 1) * size].reverse();
        }
    }
}

/// Augment a whole CHW batch buffer; one independent rng stream per image
/// (deterministic in (seed, batch index)).
pub fn augment_batch(pixels: &mut [f32], batch: usize, channels: usize,
                     size: usize, cfg: &AugmentConfig, seed: u64,
                     batch_index: u64) {
    if !cfg.enabled {
        return;
    }
    let stride = channels * size * size;
    for i in 0..batch {
        let mut rng = Rng::new(seed ^ (batch_index.wrapping_mul(0x9E37)
            .wrapping_add(i as u64)).wrapping_mul(0x2545_F491_4F6C_DD1D));
        augment_image(&mut pixels[i * stride..(i + 1) * stride], channels,
                      size, cfg, &mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gradient_image(size: usize) -> Vec<f32> {
        let mut img = vec![0f32; 3 * size * size];
        for c in 0..3 {
            for y in 0..size {
                for x in 0..size {
                    img[c * size * size + y * size + x] =
                        x as f32 / size as f32 + c as f32;
                }
            }
        }
        img
    }

    #[test]
    fn disabled_is_identity() {
        let mut img = gradient_image(16);
        let orig = img.clone();
        augment_image(&mut img, 3, 16, &AugmentConfig::disabled(),
                      &mut Rng::new(1));
        assert_eq!(img, orig);
    }

    #[test]
    fn hflip_mirrors_and_is_involutive() {
        let mut img = gradient_image(16);
        let orig = img.clone();
        hflip(&mut img, 3, 16);
        assert!((img[0] - orig[15]).abs() < 1e-6);
        hflip(&mut img, 3, 16);
        for (a, b) in img.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn crop_resize_preserves_range_and_shape() {
        let mut img = gradient_image(16);
        random_crop_resize(&mut img, 3, 16, 12, 2, 1);
        assert_eq!(img.len(), 3 * 16 * 16);
        for c in 0..3 {
            for &v in &img[c * 256..(c + 1) * 256] {
                assert!(v >= c as f32 - 1e-4 && v <= c as f32 + 1.0 + 1e-4,
                        "value {v} outside channel range");
            }
        }
    }

    #[test]
    fn full_crop_is_near_identity() {
        // crop == size with offset 0 should reproduce the image
        let mut img = gradient_image(8);
        let orig = img.clone();
        random_crop_resize(&mut img, 3, 8, 8, 0, 0);
        for (a, b) in img.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn augment_deterministic_per_index() {
        let mut a = gradient_image(16);
        let mut b = gradient_image(16);
        let cfg = AugmentConfig::default();
        augment_batch(&mut a, 1, 3, 16, &cfg, 7, 3);
        augment_batch(&mut b, 1, 3, 16, &cfg, 7, 3);
        assert_eq!(a, b);
        let mut c = gradient_image(16);
        augment_batch(&mut c, 1, 3, 16, &cfg, 7, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn augment_changes_most_images() {
        let mut changed = 0;
        for i in 0..20 {
            let mut img = gradient_image(16);
            let orig = img.clone();
            augment_batch(&mut img, 1, 3, 16, &AugmentConfig::default(),
                          11, i);
            if img != orig {
                changed += 1;
            }
        }
        assert!(changed >= 15, "only {changed}/20 augmented");
    }
}
