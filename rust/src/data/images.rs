//! Synthetic ImageNet substitute: a procedural 10-class shape/texture
//! dataset (DESIGN.md §Substitutions).
//!
//! Why this preserves the paper's Table-1 contrast: the ViT pipeline
//! (patchify → token mixing → pool → classify) is identical to the
//! ImageNet one; what the table measures is the *relative* accuracy of
//! attention vs CAT vs CAT-Alter within a fixed backbone. The classes are
//! designed so that global token mixing matters: some are local-texture
//! classes (checker, dots), some need long-range aggregation (gradients,
//! large shapes spanning many patches), so a mixer that cannot move
//! information across the whole sequence measurably underperforms.
//!
//! Every image is generated from (seed, index) — infinite, deterministic,
//! no storage. Class-balanced by construction: `label = index % 10`.

use super::rng::Rng;

pub const IMAGE_SIZE: usize = 32;
pub const CHANNELS: usize = 3;
pub const N_CLASSES: usize = 10;
const PIX: usize = IMAGE_SIZE * IMAGE_SIZE;

/// Names for reporting.
pub const CLASS_NAMES: [&str; N_CLASSES] = [
    "disk", "square", "cross", "h-stripes", "v-stripes",
    "checker", "diagonal", "dots", "h-gradient", "radial",
];

/// One labeled sample: CHW f32 image in [-1, 1] plus class id.
pub struct ImageSample {
    pub pixels: Vec<f32>,
    pub label: i32,
}

/// Deterministic generator: `sample(i)` is pure in (seed, i).
#[derive(Debug, Clone)]
pub struct ShapeDataset {
    seed: u64,
    /// additive pixel noise amplitude (makes the task non-trivial)
    pub noise: f32,
}

impl ShapeDataset {
    pub fn new(seed: u64) -> Self {
        Self { seed, noise: 0.35 }
    }

    pub fn sample(&self, index: u64) -> ImageSample {
        let label = (index % N_CLASSES as u64) as usize;
        let mut rng = Rng::new(self.seed ^ index.wrapping_mul(0x9E37_79B9));
        let pixels = self.render(label, &mut rng);
        ImageSample { pixels, label: label as i32 }
    }

    /// Render one CHW image of class `label` with randomized pose/colors.
    fn render(&self, label: usize, rng: &mut Rng) -> Vec<f32> {
        let s = IMAGE_SIZE as f32;
        // random foreground/background colors, kept separated
        let bg: [f32; 3] = [rng.range_f32(-0.8, 0.0),
                            rng.range_f32(-0.8, 0.0),
                            rng.range_f32(-0.8, 0.0)];
        let fg: [f32; 3] = [rng.range_f32(0.2, 1.0),
                            rng.range_f32(0.2, 1.0),
                            rng.range_f32(0.2, 1.0)];
        let cx = rng.range_f32(0.35 * s, 0.65 * s);
        let cy = rng.range_f32(0.35 * s, 0.65 * s);
        let r = rng.range_f32(0.2 * s, 0.38 * s);
        let period = 2 + rng.below(4);           // stripe/checker period
        let phase = rng.below(period);
        let thick = 1.0 + rng.range_f32(0.0, 2.5);
        let mut img = vec![0f32; CHANNELS * PIX];
        for y in 0..IMAGE_SIZE {
            for x in 0..IMAGE_SIZE {
                let fx = x as f32 + 0.5;
                let fy = y as f32 + 0.5;
                let dx = fx - cx;
                let dy = fy - cy;
                let inside = match label {
                    0 => dx * dx + dy * dy <= r * r,                // disk
                    1 => dx.abs() <= r * 0.8 && dy.abs() <= r * 0.8, // square
                    2 => dx.abs() <= thick || dy.abs() <= thick,     // cross
                    3 => (y / period + phase) % 2 == 0,              // h-stripes
                    4 => (x / period + phase) % 2 == 0,              // v-stripes
                    5 => ((x / period) + (y / period) + phase) % 2 == 0, // checker
                    6 => (dx - dy).abs() <= thick * 1.5,             // diagonal
                    7 => {
                        // dot lattice
                        let gx = (x % 8) as f32 - 4.0;
                        let gy = (y % 8) as f32 - 4.0;
                        gx * gx + gy * gy <= 4.0
                    }
                    8 => false,                                      // gradient
                    9 => false,                                      // radial
                    _ => unreachable!(),
                };
                let t = match label {
                    8 => fx / s,                                     // h-gradient
                    9 => 1.0 - ((dx * dx + dy * dy).sqrt() / (0.7 * s)).min(1.0),
                    _ => inside as u8 as f32,
                };
                for c in 0..CHANNELS {
                    img[c * PIX + y * IMAGE_SIZE + x] =
                        bg[c] + (fg[c] - bg[c]) * t;
                }
            }
        }
        // additive noise
        for v in img.iter_mut() {
            *v = (*v + self.noise * rng.normal()).clamp(-1.5, 1.5);
        }
        img
    }

    /// Fill flat CHW batch buffers starting at sample `start`.
    pub fn fill_batch(&self, start: u64, batch: usize,
                      pixels: &mut Vec<f32>, labels: &mut Vec<i32>) {
        pixels.clear();
        labels.clear();
        pixels.reserve(batch * CHANNELS * PIX);
        for i in 0..batch {
            let s = self.sample(start + i as u64);
            pixels.extend_from_slice(&s.pixels);
            labels.push(s.label);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let d = ShapeDataset::new(1);
        let a = d.sample(12);
        let b = d.sample(12);
        assert_eq!(a.pixels, b.pixels);
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn labels_balanced() {
        let d = ShapeDataset::new(1);
        for i in 0..30 {
            assert_eq!(d.sample(i).label, (i % 10) as i32);
        }
    }

    #[test]
    fn pixel_range_and_size() {
        let d = ShapeDataset::new(2);
        let s = d.sample(5);
        assert_eq!(s.pixels.len(), 3 * 32 * 32);
        assert!(s.pixels.iter().all(|p| p.is_finite() && p.abs() <= 1.5));
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean-pixel statistics differ between e.g. stripes and gradient
        let d = ShapeDataset::new(3);
        let var = |class: u64| -> f32 {
            let s = d.sample(class);
            let m = s.pixels.iter().sum::<f32>() / s.pixels.len() as f32;
            s.pixels.iter().map(|p| (p - m).powi(2)).sum::<f32>()
                / s.pixels.len() as f32
        };
        // different draws of the same class with different seeds differ too
        assert!((var(3) - var(8)).abs() > 1e-4);
    }

    #[test]
    fn different_seeds_differ() {
        let a = ShapeDataset::new(1).sample(0);
        let b = ShapeDataset::new(2).sample(0);
        assert_ne!(a.pixels, b.pixels);
    }

    #[test]
    fn fill_batch_layout() {
        let d = ShapeDataset::new(4);
        let mut px = Vec::new();
        let mut lb = Vec::new();
        d.fill_batch(10, 4, &mut px, &mut lb);
        assert_eq!(px.len(), 4 * 3 * 32 * 32);
        assert_eq!(lb, vec![0, 1, 2, 3]);
        assert_eq!(&px[..3 * 32 * 32], &d.sample(10).pixels[..]);
    }
}
