//! `cat` — the CAT coordinator CLI.
//!
//! Subcommands map 1:1 onto the paper's evaluation (DESIGN.md §5):
//!
//! ```text
//! cat list                      # artifact registry            [pjrt]
//! cat train  --config NAME      # train one model              [pjrt]
//! cat eval   --config NAME      # evaluate from a checkpoint   [pjrt]
//! cat serve  [--backend B]      # batched inference over the router
//! cat table1 [--fast]           # ImageNet-proxy grid          [pjrt]
//! cat table2 [--fast]           # WikiText-proxy grid          [pjrt]
//! cat table3                    # ablation grid                [pjrt]
//! cat complexity                # analytic Fig.-1 series
//! ```
//!
//! `serve`, `train`, `list` and `complexity` run in the default
//! (hermetic) build: `serve` picks its backend per
//! [`cat::runtime::Backend::detect_env`] — the native Rust CAT executor
//! when no artifacts are present — and `train` defaults to the native
//! training subsystem (`native::autograd` + AdamW, DESIGN.md §8), which
//! trains end-to-end through the FFT with zero artifacts. Both accept
//! `--backend native|pjrt` to force a path. Everything else drives the
//! PJRT runtime and needs `--features pjrt` plus `make artifacts`.

use cat::cli;
use cat::complexity::{crossover_n, layer_cost, Mechanism};
use cat::coordinator::{ServeOptions, Server};
use cat::data::ShapeDataset;
use cat::native::{CatImpl, Mixer, NativeVitConfig};
use cat::obs::log::{self as obs_log, Level};
use cat::runtime::Backend;
use cat::tensor::HostTensor;
use cat::train::{native_specs, run_training, NativeTrainer, Schedule,
                 TrainOptions};

#[cfg(feature = "pjrt")]
use cat::harness;
#[cfg(feature = "pjrt")]
use cat::runtime::{Runtime, TrainState};
#[cfg(feature = "pjrt")]
use cat::train::Trainer;

const USAGE: &str = "usage: cat <command> [flags]
commands:
  list         list native training configs (+ artifact manifest [pjrt])
  train        [--config NAME] [--backend native|pjrt] [--steps N]
               [--lr F] [--seed N] [--assert-improves]
               [--save PATH] [--resume PATH]
               (native: hermetic, default config native_vit_cat;
                --save/--resume checkpoint the full training state —
                params, AdamW moments, data cursor — and a resumed run
                re-plans warmup+cosine over the combined past+new steps,
                entering at the stored optimizer step;
                pjrt extras: [--checkpoint PATH] [--fused] [--augment])
  eval         --config NAME [--checkpoint PATH] [--batches N]  [pjrt]
  serve        [--config NAME] [--requests N] [--backend pjrt|native]
               [--shards K] [--replicas R] [--mixer NAME]
               (--mixer picks the native demo model's token mixer from
                the registry — cat, cat_gather, attention, fnet,
                circulant; non-head-separable mixers need --shards 1)
               (K>1 splits each native model head-wise across K
                model-parallel shards on dedicated pools; R>1 runs R
                data-parallel replicas behind the router with health
                checks + Busy backpressure — DESIGN.md §10)
               [--listen ADDR] serve over HTTP instead of the built-in
               demo loop: POST /v1/classify, GET /healthz, GET /metrics;
               SIGINT drains in-flight requests then exits
               (extras: [--max-conns N] [--request-timeout-ms MS]
                [--queue-depth N] [--drain-timeout-ms MS]
                [--fault-delay-ms MS] — DESIGN.md §11)
               [--restart-budget N] dead replicas are respawned by the
               supervisor (jittered backoff + probation) up to N times
               each; 0 (default) disables self-healing — DESIGN.md §12
               observability (DESIGN.md §13): every HTTP request is
               traced (X-Request-Id echoed, per-stage spans); GET
               /debug/traces and /debug/slowest dump the flight
               recorder; [--slow-request-ms MS] logs requests slower
               than MS with their span breakdown (default 1000, 0 off)
  table1       [--fast] [--steps N] [--json PATH]    (Table 1)  [pjrt]
  table2       [--fast] [--steps N] [--json PATH]    (Table 2)  [pjrt]
  table3       [--steps N] [--json PATH]   (Table 3 / Fig 2)    [pjrt]
  complexity                                          (paper Fig 1)
  validate     [--deep]   check manifest/artifact consistency   [pjrt]
global: --artifacts DIR (or env CAT_ARTIFACTS)
        --log-level error|warn|info|debug (or env CAT_LOG; default warn)
        --log-json  structured JSON-lines logs on stderr
        train extra: [--metrics-out PATH] append per-step training
        metrics as JSON lines (step/loss/lr, evals, final summary)
[pjrt] commands need a build with `--features pjrt` + `make artifacts`;
serve/train/list/complexity run hermetically on the native backend
(hermetic table runs: `cargo bench --bench table1_imagenet` etc.).";

const VALUED: &[&str] = &["config", "steps", "lr", "seed", "checkpoint",
                          "batches", "requests", "json", "artifacts",
                          "backend", "save", "resume", "shards",
                          "replicas", "listen", "max-conns",
                          "request-timeout-ms", "queue-depth",
                          "drain-timeout-ms", "fault-delay-ms",
                          "restart-budget", "slow-request-ms",
                          "log-level", "metrics-out", "mixer"];

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        eprintln!("\n{USAGE}");
        std::process::exit(1);
    }
}

fn run() -> cat::Result<()> {
    let args = cli::parse(VALUED)?;
    if let Some(dir) = args.get("artifacts") {
        std::env::set_var("CAT_ARTIFACTS", dir);
    }
    // explicit flags beat the CAT_LOG env (obs::log lazily reads the
    // env on first use; a set_level/set_json here wins that race)
    if let Some(lv) = args.get("log-level") {
        let level = Level::parse(lv).ok_or_else(|| anyhow::anyhow!(
            "unknown log level '{lv}' (expected error|warn|info|debug)"))?;
        obs_log::set_level(level);
    }
    if args.has("log-json") {
        obs_log::set_json(true);
    }
    let cmd = args.expect_command(
        &["list", "train", "eval", "serve", "table1", "table2", "table3",
          "complexity", "validate"])?;
    match cmd {
        "serve" => cmd_serve(&args),
        "complexity" => cmd_complexity(),
        "list" => cmd_list(),
        "train" => cmd_train(&args),
        #[cfg(feature = "pjrt")]
        "validate" => {
            let report = cat::runtime::validate(&cat::artifacts_dir(),
                                                args.has("deep"))?;
            print!("{}", report.render());
            anyhow::ensure!(report.ok(), "artifact validation failed");
            Ok(())
        }
        #[cfg(feature = "pjrt")]
        "eval" => cmd_eval(&args),
        #[cfg(feature = "pjrt")]
        "table1" => cmd_table(&args, 1),
        #[cfg(feature = "pjrt")]
        "table2" => cmd_table(&args, 2),
        #[cfg(feature = "pjrt")]
        "table3" => cmd_table(&args, 3),
        #[cfg(feature = "pjrt")]
        _ => unreachable!("validated above"),
        #[cfg(not(feature = "pjrt"))]
        other => anyhow::bail!(
            "command '{other}' drives the PJRT runtime; rebuild with \
             `cargo build --features pjrt`, or use the hermetic commands \
             (serve/train/list/complexity)"),
    }
}

fn cmd_list() -> cat::Result<()> {
    println!("mixer zoo (registry; `cat serve --backend native --mixer \
              NAME`):");
    for s in cat::native::REGISTRY {
        println!("{:<12} params={:<8} time={:<11} mem={:<7} causal={:<5} \
                  head_separable={}",
                 s.name, s.params_formula, s.complexity, s.memory,
                 s.causal, s.head_separable);
    }
    println!("\nnative training configs (hermetic, `cat train`):");
    for spec in native_specs() {
        let cfg = spec.cfg;
        let mech = cfg.mechanism();
        println!("{:<28} mech={:<12} params={:<10} causal={:<5} d={} \
                  h={} L={} N={} batch={}",
                 spec.name, mech,
                 cat::native::mixer::budget_formula(&mech),
                 cfg.causal(), cfg.d_model, cfg.n_heads,
                 cfg.n_layers, cfg.n_tokens(), cfg.batch_size);
    }
    #[cfg(feature = "pjrt")]
    if let Ok(rt) = Runtime::from_env() {
        println!("\nartifact manifest ({}):", rt.platform());
        for name in rt.manifest.names() {
            let c = rt.manifest.config(name)?;
            println!("{name:<28} task={:<10} mech={:<10} d={} h={} L={} \
                      params={}",
                     c.task, c.mechanism, c.d_model, c.n_heads, c.n_layers,
                     c.param_count);
        }
    }
    Ok(())
}

fn cmd_train(args: &cli::Args) -> cat::Result<()> {
    let backend = match args.get("backend") {
        Some(s) => Backend::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown backend '{s}' (expected pjrt|native)")
        })?,
        // train defaults to the hermetic native subsystem unless a PJRT
        // build has artifacts on disk AND names a manifest config
        None => {
            if cfg!(feature = "pjrt") && args.get("config").is_some()
                && cat::train::native_spec(
                    args.get("config").unwrap_or_default()).is_none() {
                Backend::detect_env()
            } else {
                Backend::Native
            }
        }
    };
    match backend {
        Backend::Native => cmd_train_native(args),
        #[cfg(feature = "pjrt")]
        Backend::Pjrt => cmd_train_pjrt(args),
        #[cfg(not(feature = "pjrt"))]
        Backend::Pjrt => anyhow::bail!(
            "built without the `pjrt` feature — use --backend native"),
    }
}

/// Hermetic training: native gradient engine + AdamW, zero artifacts.
fn cmd_train_native(args: &cli::Args) -> cat::Result<()> {
    for flag in ["checkpoint", "fused", "augment"] {
        anyhow::ensure!(!args.has(flag),
                        "--{flag} is a PJRT-path option; add --backend \
                         pjrt (build with `--features pjrt` + `make \
                         artifacts`) or drop the flag");
    }
    let config = args.get_or("config", "native_vit_cat");
    let steps: u64 = args.parse_or("steps", 200)?;
    let lr: f32 = args.parse_or("lr", 1e-3)?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let mut trainer = NativeTrainer::new(config, seed)?;
    obs_log::log_fields(
        Level::Info, "train", "native training starting",
        &[("config", config),
          ("params", &trainer.param_count().to_string()),
          ("steps", &steps.to_string())]);
    if let Some(path) = args.get("resume") {
        trainer.load_checkpoint(std::path::Path::new(path))?;
        obs_log::log_fields(
            Level::Info, "train", "resumed from checkpoint",
            &[("path", path),
              ("opt_step", &trainer.opt_steps().to_string()),
              ("cursor", &trainer.cursor().to_string())]);
    }
    // a resumed run re-plans the warmup+cosine schedule over the
    // combined past+new step count and enters it at the checkpoint's
    // optimizer step — it never restarts the schedule from step zero
    // (whether any warmup remains depends on the combined horizon)
    let start = trainer.opt_steps();
    let total = start + steps;
    let opts = TrainOptions {
        steps,
        schedule: Schedule::new(lr, (total / 10).max(1), total),
        start_step: start,
        seed,
        eval_every: (steps / 4).max(1),
        eval_batches: args.parse_or("batches", 8)?,
        metrics_out: args.get("metrics-out").map(std::path::PathBuf::from),
        ..Default::default()
    };
    let report = run_training(&mut trainer, &opts)?;
    println!("steps: {} wall: {:.1}s ({:.2} steps/s)",
             report.steps_done, report.wall_seconds,
             report.steps_per_sec());
    if let Some((k, v)) = report.final_metric() {
        println!("final {k}: {v:.4}");
    }
    anyhow::ensure!(report.diverged_at.is_none(),
                    "training diverged at step {:?}", report.diverged_at);
    if args.has("assert-improves") {
        // CI smoke gate: last-quartile mean loss strictly below the first
        let losses = &report.curve.losses;
        anyhow::ensure!(losses.len() >= 4,
                        "--assert-improves needs at least 4 recorded steps, \
                         got {}", losses.len());
        let q = (losses.len() / 4).max(1);
        let head: f32 =
            losses[..q].iter().sum::<f32>() / q as f32;
        let tail: f32 =
            losses[losses.len() - q..].iter().sum::<f32>() / q as f32;
        anyhow::ensure!(tail < head,
                        "loss did not decrease over {} steps: first-quartile \
                         mean {head:.4} vs last {tail:.4}",
                        report.steps_done);
        println!("loss improved: {head:.4} -> {tail:.4} (quartile means)");
    }
    if let Some(path) = args.get("save") {
        trainer.save_checkpoint(std::path::Path::new(path))?;
        println!("checkpoint -> {path}");
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_train_pjrt(args: &cli::Args) -> cat::Result<()> {
    for flag in ["save", "resume"] {
        anyhow::ensure!(!args.has(flag),
                        "--{flag} is a native-backend option (the PJRT \
                         path uses --checkpoint); drop --backend pjrt or \
                         use --checkpoint");
    }
    let config = args.require("config")?;
    let steps: u64 = args.parse_or("steps", 200)?;
    let lr: f32 = args.parse_or("lr", 1e-3)?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let rt = Runtime::from_env()?;
    let mut trainer = Trainer::new(&rt, config, seed)?;
    if args.has("augment") {
        trainer.source_mut()
            .set_augment(cat::data::AugmentConfig::default());
    }
    let opts = TrainOptions {
        steps,
        schedule: Schedule::new(lr, (steps / 10).max(1), steps),
        seed,
        eval_every: (steps / 4).max(1),
        ..Default::default()
    };
    let report = if args.has("fused") {
        trainer.run_fused(&opts, 8)?
    } else {
        trainer.run(&opts)?
    };
    println!("steps: {} wall: {:.1}s ({:.2} steps/s)",
             report.steps_done, report.wall_seconds,
             report.steps_per_sec());
    if let Some((k, v)) = report.final_metric() {
        println!("final {k}: {v:.4}");
    }
    if let Some(path) = args.get("checkpoint") {
        trainer.state.save(std::path::Path::new(path))?;
        println!("checkpoint -> {path}");
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_eval(args: &cli::Args) -> cat::Result<()> {
    let config = args.require("config")?;
    let batches: u64 = args.parse_or("batches", 16)?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let rt = Runtime::from_env()?;
    let mut trainer = Trainer::new(&rt, config, seed)?;
    if let Some(path) = args.get("checkpoint") {
        trainer.state = TrainState::load(std::path::Path::new(path))?;
    }
    let (k, v) = trainer.eval(batches)?;
    println!("{k}: {v:.4}");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_table(args: &cli::Args, which: u8) -> cat::Result<()> {
    let rt = Runtime::from_env()?;
    let default_steps = if which == 2 { 200 } else { 300 };
    let steps: u64 = args.parse_or("steps", default_steps)?;
    let (names, title, evals) = match which {
        1 => (harness::table1_names(args.has("fast")),
              "Table 1 — ImageNet-proxy, ViT (accuracy up)", 16),
        2 => (harness::table2_names(args.has("fast")),
              "Table 2 — WikiText-proxy LM (word PPL down)", 8),
        _ => (harness::table3_names(),
              "Table 3 / Fig. 2 — circular qkv ablation (ViT-L proxy, avg)",
              16),
    };
    let rows = harness::run_grid(&rt, &names, steps, 0, evals)?;
    print!("{}", harness::render_table(title, &rows));
    if let Some(path) = args.get("json") {
        std::fs::write(path,
                       harness::rows_to_json(&rows).to_string_pretty())?;
        obs_log::log_fields(Level::Info, "table", "rows written",
                            &[("path", path)]);
    }
    Ok(())
}

fn cmd_complexity() -> cat::Result<()> {
    println!("Fig. 1 analytic series (d=512, h=8): FLOPs per layer");
    println!("{:>6} {:>14} {:>14} {:>14} {:>8}",
             "N", "attention", "cat_gather", "cat_fft", "ratio");
    for p in 6..13 {
        let n = 1usize << p;
        let a = layer_cost(Mechanism::Attention, n, 512, 8).flops;
        let g = layer_cost(Mechanism::CatGather, n, 512, 8).flops;
        let c = layer_cost(Mechanism::CatFft, n, 512, 8).flops;
        println!("{n:>6} {a:>14.3e} {g:>14.3e} {c:>14.3e} {:>8.2}", a / c);
    }
    match crossover_n(512, 8) {
        Some(n) => println!("modeled FLOP crossover (cat_fft < attention): \
                             N = {n}"),
        None => println!("modeled FLOP crossover: none below 2^23"),
    }
    Ok(())
}

/// Spin the router + one worker, fire `requests` single-image requests
/// from client threads, report latency/throughput and batching efficiency.
/// Works on either backend; the native path needs no artifacts at all.
fn cmd_serve(args: &cli::Args) -> cat::Result<()> {
    let explicit_backend = args.get("backend").is_some();
    let backend = match args.get("backend") {
        Some(s) => Backend::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown backend '{s}' (expected pjrt|native)")
        })?,
        None => Backend::detect_env(),
    };
    let default_model = match backend {
        Backend::Pjrt => "vit_b_avg_cat",
        Backend::Native => "native_cat_vit",
    };
    let config = args.get_or("config", default_model).to_string();
    let requests: usize = args.parse_or("requests", 256)?;
    let shards: usize = args.parse_or("shards", 1)?;
    let replicas: usize = args.parse_or("replicas", 1)?;
    let restart_budget: u32 = args.parse_or("restart-budget", 0)?;
    anyhow::ensure!(shards >= 1 && replicas >= 1,
                    "--shards and --replicas must be at least 1");
    anyhow::ensure!(backend == Backend::Native || shards == 1,
                    "--shards is a native-backend feature (head-parallel \
                     model shards); drop it or add --backend native");

    // --mixer: pick the native demo model's token mixer from the registry
    let native_cfg = match args.get("mixer") {
        Some(name) => {
            anyhow::ensure!(backend == Backend::Native,
                            "--mixer picks the native demo model's token \
                             mixer; add --backend native");
            let mixer = Mixer::parse(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown mixer '{name}' (expected one of: {})",
                    cat::native::REGISTRY.iter().map(|s| s.name)
                        .collect::<Vec<_>>().join(", "))
            })?;
            NativeVitConfig {
                mixer,
                // cat_gather is CAT routed through the O(N²) apply
                cat_impl: if mixer == Mixer::CatGather {
                    CatImpl::Gather
                } else {
                    CatImpl::Fft
                },
                ..Default::default()
            }
        }
        None => NativeVitConfig::default(),
    };

    // Fail fast on the silent-misconfiguration path: a named config with
    // no artifacts would otherwise serve the untrained native demo model
    // under that label. Explicit --backend native opts back in.
    if backend == Backend::Native && !explicit_backend
        && args.get("config").is_some() {
        anyhow::bail!(
            "--config {config} requested but no artifacts were found, so \
             the backend auto-detected as native (which serves the \
             hermetic demo model, not this config); run `make artifacts` \
             for the PJRT model, or pass --backend native explicitly to \
             serve the native demo under this name");
    }

    #[cfg(feature = "pjrt")]
    if backend == Backend::Pjrt {
        let rt = Runtime::from_env()?;
        let meta = rt.config(&config)?.clone();
        anyhow::ensure!(meta.is_vit(), "serve demo expects a ViT config");
        drop(rt); // the worker thread builds its own runtime (xla is !Send)
    }
    #[cfg(not(feature = "pjrt"))]
    anyhow::ensure!(backend == Backend::Native,
                    "built without the `pjrt` feature — use --backend \
                     native");

    if let Some(listen) = args.get("listen") {
        return cmd_serve_http(args, backend, &config, native_cfg, shards,
                              replicas, restart_budget, listen);
    }

    let note = match backend {
        Backend::Native => format!(
            "serving hermetic demo model (untrained {} ViT, d=64 h=4 \
             L=2)", native_cfg.mixer.name()),
        Backend::Pjrt => "serving pjrt model".to_string(),
    };
    obs_log::log_fields(
        Level::Info, "serve", &note,
        &[("backend", &format!("{backend:?}")),
          ("model", &config),
          ("mixer", &native_cfg.mixer.name().to_string()),
          ("shards", &shards.to_string()),
          ("replicas", &replicas.to_string())]);
    let opts = ServeOptions { backend, shards, replicas, restart_budget,
                              native: native_cfg,
                              ..Default::default() };
    let server = Server::spawn(cat::artifacts_dir(), &[config.clone()],
                               opts, 0)?;
    let handle = server.handle();
    let ds = ShapeDataset::new(123);
    let t0 = std::time::Instant::now();
    let n_clients = 8usize;
    let per_client = requests / n_clients;
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let h = handle.clone();
        let ds = ds.clone();
        let model = config.clone();
        clients.push(std::thread::spawn(move || -> cat::Result<usize> {
            let mut correct = 0usize;
            for i in 0..per_client {
                let sample = ds.sample((c * per_client + i) as u64);
                let input = HostTensor::f32(vec![3, 32, 32], sample.pixels)?;
                let logits = h.infer(&model, input)?;
                let row = logits.as_f32()?;
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).expect("finite"))
                    .map(|(j, _)| j as i32)
                    .expect("nonempty");
                correct += (pred == sample.label) as usize;
            }
            Ok(correct)
        }));
    }
    let mut correct = 0usize;
    for c in clients {
        correct += c.join().expect("client thread")?;
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(handle);
    let router = server.router_stats();
    let stats = server.shutdown();
    let served = n_clients * per_client;
    println!("served {served} requests in {wall:.2}s ({:.1} req/s)",
             served as f64 / wall);
    println!("accuracy (untrained init): {:.3}",
             correct as f64 / served as f64);
    println!("router: {} dispatched, {} busy-rejected, {} replicas died, \
              pings {} ok / {} missed",
             router.dispatched, router.busy_rejected, router.replicas_died,
             router.pings_ok, router.pings_missed);
    for m in cat::coordinator::aggregate_stats(&stats) {
        println!("model {}: {} reqs / {} batches over {} replicas, \
                  occupancy {:.2}, p50 {}us p99 {}us max {}us",
                 m.model, m.requests, m.batches, m.replicas,
                 m.mean_occupancy, m.latency.quantile_us(0.5),
                 m.latency.quantile_us(0.99), m.latency.max_us());
    }
    for s in stats {
        let shard_note = s.shard
            .map(|sh| format!(" [{} shards x {} workers, {} scatters]",
                              sh.shards, sh.workers_per_shard, sh.scatters))
            .unwrap_or_default();
        println!("  replica {}/{}: {} reqs / {} batches, occupancy \
                  {:.2}{shard_note}",
                 s.model, s.replica, s.requests, s.batches,
                 s.mean_occupancy);
    }
    Ok(())
}

/// `cat serve --listen ADDR`: the HTTP front end over the same router
/// (DESIGN.md §11). Serves `POST /v1/classify`, `GET /healthz`, and
/// `GET /metrics` until SIGINT, then drains in-flight requests and
/// reports the usual serving stats.
#[allow(clippy::too_many_arguments)]
fn cmd_serve_http(args: &cli::Args, backend: Backend, config: &str,
                  native_cfg: NativeVitConfig, shards: usize,
                  replicas: usize, restart_budget: u32, listen: &str)
                  -> cat::Result<()> {
    use cat::coordinator::{default_factory, WorkerSpec};
    use cat::serve::fault::{injected_factory, FaultPlan};
    use cat::serve::routes::AppState;
    use cat::serve::{HttpCounters, HttpServer, HttpServerConfig};
    use std::time::Duration;

    let max_conns: usize = args.parse_or("max-conns", 64)?;
    let request_timeout_ms: u64 =
        args.parse_or("request-timeout-ms", 10_000)?;
    let queue_depth: usize = args.parse_or("queue-depth", 256)?;
    let drain_timeout_ms: u64 = args.parse_or("drain-timeout-ms", 5_000)?;
    let fault_delay_ms: u64 = args.parse_or("fault-delay-ms", 0)?;
    let slow_request_ms: u64 = args.parse_or("slow-request-ms", 1_000)?;
    anyhow::ensure!(max_conns >= 1, "--max-conns must be at least 1");
    anyhow::ensure!(queue_depth >= 1, "--queue-depth must be at least 1");
    anyhow::ensure!(request_timeout_ms >= 1,
                    "--request-timeout-ms must be at least 1");

    let opts = ServeOptions { backend, shards, replicas, queue_depth,
                              restart_budget, native: native_cfg,
                              ..Default::default() };
    let mut factory = default_factory(cat::artifacts_dir());
    if fault_delay_ms > 0 {
        // test/bench hook: every batch sleeps this long in the executor,
        // which makes 429 backpressure reproducible from the CLI
        let plan = FaultPlan::new();
        plan.set_delay(Duration::from_millis(fault_delay_ms));
        obs_log::log_fields(
            Level::Warn, "serve", "fault injection armed",
            &[("delay_ms", &fault_delay_ms.to_string())]);
        factory = injected_factory(&plan, factory);
    }
    let specs = vec![WorkerSpec { model: config.to_string(),
                                  params: None, seed: 0 }];
    let server = Server::spawn_with(cat::artifacts_dir(), specs, opts,
                                    Some(factory))?;
    let request_timeout = Duration::from_millis(request_timeout_ms);
    let state = AppState {
        handle: server.handle(),
        stats: server.stats_handle(),
        http: HttpCounters::new(),
        model: config.to_string(),
        input_shape: vec![3, 32, 32],
        request_timeout,
        recorder: cat::obs::FlightRecorder::new(
            cat::obs::recorder::DEFAULT_CAPACITY),
        slow_request: Duration::from_millis(slow_request_ms),
    };
    let mut cfg = HttpServerConfig::new(listen);
    cfg.max_conns = max_conns;
    cfg.request_timeout = request_timeout;
    cfg.drain_timeout = Duration::from_millis(drain_timeout_ms);
    let http = HttpServer::start(cfg, state)?;
    obs_log::log_fields(
        Level::Info, "serve", "http serving; SIGINT drains",
        &[("backend", &format!("{backend:?}")),
          ("model", config),
          ("shards", &shards.to_string()),
          ("replicas", &replicas.to_string())]);
    // parents (CI smoke, benches) poll stdout for this exact line
    println!("listening on {}", http.addr());
    use std::io::Write as _;
    let _ = std::io::stdout().flush();

    install_sigint_handler();
    while !sigint_received() {
        std::thread::sleep(Duration::from_millis(50));
    }
    obs_log::info("serve", "SIGINT: draining in-flight requests");
    // order matters: joining the HTTP layer drops every ServeHandle
    // clone held by connection threads, which Server::shutdown requires
    http.shutdown();
    let router = server.router_stats();
    let stats = server.shutdown();
    println!("router: {} dispatched, {} busy-rejected, {} replicas died, \
              pings {} ok / {} missed",
             router.dispatched, router.busy_rejected, router.replicas_died,
             router.pings_ok, router.pings_missed);
    for m in cat::coordinator::aggregate_stats(&stats) {
        println!("model {}: {} reqs / {} batches over {} replicas, \
                  occupancy {:.2}, p50 {}us p99 {}us max {}us",
                 m.model, m.requests, m.batches, m.replicas,
                 m.mean_occupancy, m.latency.quantile_us(0.5),
                 m.latency.quantile_us(0.99), m.latency.max_us());
    }
    Ok(())
}

static SIGINT_FLAG: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

fn sigint_received() -> bool {
    SIGINT_FLAG.load(std::sync::atomic::Ordering::Relaxed)
}

/// Route SIGINT into [`SIGINT_FLAG`]. The crate stays dependency-free:
/// instead of the `libc` crate this binds the C `signal` symbol
/// directly (the handler only stores to an atomic, which is
/// async-signal-safe).
#[cfg(unix)]
fn install_sigint_handler() {
    extern "C" fn on_sigint(_sig: i32) {
        SIGINT_FLAG.store(true, std::sync::atomic::Ordering::Relaxed);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    unsafe {
        signal(SIGINT, on_sigint as usize);
    }
}

#[cfg(not(unix))]
fn install_sigint_handler() {
    // no signal plumbing here; the process runs until killed
    obs_log::warn("serve", "SIGINT handling is unix-only");
}
