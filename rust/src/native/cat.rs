//! Native (pure-Rust) CAT executor: the paper's token-mixing mechanism
//! computed directly on the host, with no PJRT artifacts in the loop.
//!
//! The forward pass mirrors `python/compile/kernels/ref.py` exactly:
//!
//! ```text
//!   z  = x @ W_A                      (B, N, H)   merged d→h projection
//!   p  = softmax(z) over N            (B, H, N)   one weight vector/head
//!   v  = split_heads(x @ W_V)         (B, H, N, dh)
//!   o[i] = Σ_k p[k] · v[(i+k) % N]                circular cross-correlation
//!        = irfft(conj(rfft(p)) ⊙ rfft(v))         — O(N log N) per channel
//!   out = merge_heads(o)              (B, N, D)
//! ```
//!
//! [`CatImpl::Gather`] computes the same contraction as the naive O(N²)
//! rolled gather — the correctness reference and the paper's Fig.-1
//! baseline. Per the paper's parameter accounting (Tables 1–3) the
//! mechanism has no output projection: the learnable budget is exactly
//! `(d + h)·d` ([`CatLayer::param_count`]); the model-level output
//! projection lives in [`NativeCatModel`]'s classifier head.
//!
//! Work is parallelized across batch×head (and across rows for the large
//! projections) with scoped threads; each worker owns its scratch buffers,
//! so the per-channel FFT loop is allocation-free.

use std::sync::Arc;

use anyhow::ensure;

use super::fft::{rfft_plan, Complex, RfftPlan};
use crate::data::Rng;
use crate::Result;

/// Which circulant apply computes the mixing contraction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CatImpl {
    /// O(N log N): planned rfft → conjugate pointwise multiply → irfft.
    Fft,
    /// O(N²): naive rolled gather (correctness + crossover baseline).
    Gather,
}

impl CatImpl {
    pub fn name(self) -> &'static str {
        match self {
            CatImpl::Fft => "fft",
            CatImpl::Gather => "gather",
        }
    }
}

// ---------------------------------------------------------------------------
// small dense linear algebra (shared by both native layers)
// ---------------------------------------------------------------------------

/// Upper bound on worker threads for one parallel section.
fn worker_count(tasks: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1);
    cores.min(tasks).min(16).max(1)
}

/// `out = x @ w` with `x: (rows, inner)`, `w: (inner, cols)`, row-major.
/// Splits across row blocks when the FLOP count justifies threads.
pub fn matmul(x: &[f32], rows: usize, inner: usize, w: &[f32], cols: usize,
              out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * inner);
    debug_assert_eq!(w.len(), inner * cols);
    debug_assert_eq!(out.len(), rows * cols);
    let workers = worker_count(rows);
    if workers <= 1 || rows * inner * cols < (1 << 21) {
        matmul_rows(x, inner, w, cols, out);
        return;
    }
    let chunk_rows = (rows + workers - 1) / workers;
    std::thread::scope(|s| {
        for (ci, ochunk) in out.chunks_mut(chunk_rows * cols).enumerate() {
            let r0 = ci * chunk_rows;
            let nrows = ochunk.len() / cols;
            let xchunk = &x[r0 * inner..(r0 + nrows) * inner];
            s.spawn(move || {
                matmul_rows(xchunk, inner, w, cols, ochunk);
            });
        }
    });
}

/// Serial row-major matmul kernel (ikj order: streams `w` rows).
fn matmul_rows(x: &[f32], inner: usize, w: &[f32], cols: usize,
               out: &mut [f32]) {
    out.fill(0.0);
    for (xrow, orow) in x.chunks_exact(inner).zip(out.chunks_exact_mut(cols)) {
        for (k, &xv) in xrow.iter().enumerate() {
            let wrow = &w[k * cols..(k + 1) * cols];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// Numerically stable in-place softmax over one row.
pub fn softmax_in_place(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// `(b, n, h·dh)` → head-major `(b, h, n, dh)`.
fn split_heads(src: &[f32], b: usize, n: usize, h: usize, dh: usize,
               dst: &mut [f32]) {
    let d = h * dh;
    for bi in 0..b {
        for head in 0..h {
            for i in 0..n {
                let s = (bi * n + i) * d + head * dh;
                let t = ((bi * h + head) * n + i) * dh;
                dst[t..t + dh].copy_from_slice(&src[s..s + dh]);
            }
        }
    }
}

/// Head-major `(b, h, n, dh)` → `(b, n, h·dh)`.
fn merge_heads(src: &[f32], b: usize, n: usize, h: usize, dh: usize,
               dst: &mut [f32]) {
    let d = h * dh;
    for bi in 0..b {
        for head in 0..h {
            for i in 0..n {
                let s = ((bi * h + head) * n + i) * dh;
                let t = (bi * n + i) * d + head * dh;
                dst[t..t + dh].copy_from_slice(&src[s..s + dh]);
            }
        }
    }
}

/// Run one closure per task across scoped worker threads; every worker
/// builds its scratch once and processes its bucket serially.
/// `est_flops_per_task` gates threading: tiny workloads run serially so
/// thread-spawn latency never dominates (important for the small-N
/// crossover measurements and single-image serving).
fn par_for_tasks<T, S, NS, F>(tasks: Vec<T>, est_flops_per_task: usize,
                              new_scratch: NS, run: F)
where
    T: Send,
    NS: Fn() -> S + Sync,
    F: Fn(T, &mut S) + Sync,
{
    let total_work = tasks.len().saturating_mul(est_flops_per_task);
    let workers = if total_work >= (1 << 20) {
        worker_count(tasks.len())
    } else {
        1
    };
    if workers <= 1 {
        let mut scratch = new_scratch();
        for t in tasks {
            run(t, &mut scratch);
        }
        return;
    }
    let mut buckets: Vec<Vec<T>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, t) in tasks.into_iter().enumerate() {
        buckets[i % workers].push(t);
    }
    let run_ref = &run;
    let scratch_ref = &new_scratch;
    std::thread::scope(|s| {
        for bucket in buckets {
            s.spawn(move || {
                let mut scratch = scratch_ref();
                for t in bucket {
                    run_ref(t, &mut scratch);
                }
            });
        }
    });
}

// ---------------------------------------------------------------------------
// the CAT mixing layer
// ---------------------------------------------------------------------------

/// One CAT mixing layer: merged `W_A: (d, h)` plus `W_V: (d, d)`.
pub struct CatLayer {
    pub d: usize,
    pub h: usize,
    w_a: Vec<f32>,
    w_v: Vec<f32>,
}

/// Per-worker FFT scratch: spectrum buffers + one column strip.
struct ConvScratch {
    plan: Option<Arc<RfftPlan>>,
    zf: Vec<Complex>,
    vf: Vec<Complex>,
    col: Vec<f32>,
}

impl ConvScratch {
    fn new(n: usize, mode: CatImpl) -> ConvScratch {
        match mode {
            CatImpl::Fft => {
                let plan = rfft_plan(n);
                let f = plan.spectrum_len();
                ConvScratch {
                    plan: Some(plan),
                    zf: vec![Complex::ZERO; f],
                    vf: vec![Complex::ZERO; f],
                    col: vec![0.0; n],
                }
            }
            CatImpl::Gather => ConvScratch {
                plan: None,
                zf: Vec::new(),
                vf: Vec::new(),
                col: Vec::new(),
            },
        }
    }
}

/// One (batch, head) circulant apply: `o[i] = Σ_k zs[k] v[(i+k)%n]`.
fn apply_circulant(zs: &[f32], v: &[f32], o: &mut [f32], n: usize,
                   dh: usize, mode: CatImpl, scratch: &mut ConvScratch) {
    match mode {
        CatImpl::Fft => {
            let plan = scratch.plan.as_ref().expect("fft scratch").clone();
            let f = plan.spectrum_len();
            plan.forward(zs, &mut scratch.zf);
            for c in 0..dh {
                for i in 0..n {
                    scratch.col[i] = v[i * dh + c];
                }
                plan.forward(&scratch.col, &mut scratch.vf);
                for k in 0..f {
                    scratch.vf[k] = scratch.zf[k].conj() * scratch.vf[k];
                }
                plan.inverse(&mut scratch.vf, &mut scratch.col);
                for i in 0..n {
                    o[i * dh + c] = scratch.col[i];
                }
            }
        }
        CatImpl::Gather => {
            for i in 0..n {
                let orow = &mut o[i * dh..(i + 1) * dh];
                orow.fill(0.0);
                for k in 0..n {
                    let w = zs[k];
                    let vrow = &v[((i + k) % n) * dh..((i + k) % n) * dh + dh];
                    for (ov, &vv) in orow.iter_mut().zip(vrow) {
                        *ov += w * vv;
                    }
                }
            }
        }
    }
}

impl CatLayer {
    /// Deterministic init (0.02-scaled normal, matching `_dense_init` in
    /// `python/compile/mechanisms.py`).
    pub fn init(d: usize, h: usize, rng: &mut Rng) -> CatLayer {
        assert!(h > 0 && d % h == 0, "d ({d}) must divide into h ({h}) heads");
        let w_a = (0..d * h).map(|_| 0.02 * rng.normal()).collect();
        let w_v = (0..d * d).map(|_| 0.02 * rng.normal()).collect();
        CatLayer { d, h, w_a, w_v }
    }

    /// Learnable parameters: `(d + h)·d`, the paper's CAT budget.
    pub fn param_count(&self) -> usize {
        (self.d + self.h) * self.d
    }

    /// Mix tokens: `x: (b, n, d)` row-major → `(b, n, d)`.
    pub fn forward(&self, x: &[f32], b: usize, n: usize, mode: CatImpl)
                   -> Result<Vec<f32>> {
        let (d, h) = (self.d, self.h);
        let dh = d / h;
        ensure!(x.len() == b * n * d,
                "x has {} elements, expected {}x{}x{}", x.len(), b, n, d);
        if mode == CatImpl::Fft {
            ensure!(n.is_power_of_two(),
                    "CAT-FFT needs power-of-two N, got {n}");
        }

        // z = x @ W_A, then head-major softmaxed weights (b, h, n)
        let mut z = vec![0.0f32; b * n * h];
        matmul(x, b * n, d, &self.w_a, h, &mut z);
        let mut zs = vec![0.0f32; b * h * n];
        for bi in 0..b {
            for head in 0..h {
                for i in 0..n {
                    zs[(bi * h + head) * n + i] = z[(bi * n + i) * h + head];
                }
            }
        }
        for row in zs.chunks_mut(n) {
            softmax_in_place(row);
        }

        // v = x @ W_V, head-major (b, h, n, dh)
        let mut v = vec![0.0f32; b * n * d];
        matmul(x, b * n, d, &self.w_v, d, &mut v);
        let mut vh = vec![0.0f32; b * h * n * dh];
        split_heads(&v, b, n, h, dh, &mut vh);

        // per-(batch, head) circulant apply into head-major output
        let mut oh = vec![0.0f32; b * h * n * dh];
        let tasks: Vec<(&[f32], &[f32], &mut [f32])> = zs
            .chunks(n)
            .zip(vh.chunks(n * dh))
            .zip(oh.chunks_mut(n * dh))
            .map(|((zc, vc), oc)| (zc, vc, oc))
            .collect();
        let est = match mode {
            CatImpl::Fft => 5 * n * (n.trailing_zeros() as usize + 1) * dh,
            CatImpl::Gather => 2 * n * n * dh,
        };
        par_for_tasks(
            tasks,
            est,
            || ConvScratch::new(n, mode),
            |(zc, vc, oc), scratch| {
                apply_circulant(zc, vc, oc, n, dh, mode, scratch);
            },
        );

        let mut out = vec![0.0f32; b * n * d];
        merge_heads(&oh, b, n, h, dh, &mut out);
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// native softmax attention (the O(N²) wallclock baseline)
// ---------------------------------------------------------------------------

/// Standard multi-head softmax attention, row-streamed (O(N) scratch).
pub struct AttentionLayer {
    pub d: usize,
    pub h: usize,
    w_q: Vec<f32>,
    w_k: Vec<f32>,
    w_v: Vec<f32>,
}

impl AttentionLayer {
    pub fn init(d: usize, h: usize, rng: &mut Rng) -> AttentionLayer {
        assert!(h > 0 && d % h == 0, "d ({d}) must divide into h ({h}) heads");
        let mut mk = |len: usize| -> Vec<f32> {
            (0..len).map(|_| 0.02 * rng.normal()).collect()
        };
        AttentionLayer {
            d,
            h,
            w_q: mk(d * d),
            w_k: mk(d * d),
            w_v: mk(d * d),
        }
    }

    /// Paper accounting: `3·d²` learnables.
    pub fn param_count(&self) -> usize {
        3 * self.d * self.d
    }

    /// `x: (b, n, d)` → `(b, n, d)` via softmax(QKᵀ/√dh)·V per head.
    pub fn forward(&self, x: &[f32], b: usize, n: usize) -> Result<Vec<f32>> {
        let (d, h) = (self.d, self.h);
        let dh = d / h;
        ensure!(x.len() == b * n * d,
                "x has {} elements, expected {}x{}x{}", x.len(), b, n, d);
        let mut proj = vec![0.0f32; b * n * d];
        let mut heads = vec![vec![0.0f32; b * h * n * dh]; 3];
        for (w, dst) in [&self.w_q, &self.w_k, &self.w_v]
            .into_iter()
            .zip(heads.iter_mut()) {
            matmul(x, b * n, d, w, d, &mut proj);
            split_heads(&proj, b, n, h, dh, dst);
        }
        let (qh, rest) = heads.split_at(1);
        let (kh, vh) = rest.split_at(1);
        let scale = 1.0 / (dh as f32).sqrt();

        let mut oh = vec![0.0f32; b * h * n * dh];
        let tasks: Vec<(&[f32], &[f32], &[f32], &mut [f32])> = qh[0]
            .chunks(n * dh)
            .zip(kh[0].chunks(n * dh))
            .zip(vh[0].chunks(n * dh))
            .zip(oh.chunks_mut(n * dh))
            .map(|(((qc, kc), vc), oc)| (qc, kc, vc, oc))
            .collect();
        par_for_tasks(
            tasks,
            4 * n * n * dh,
            || vec![0.0f32; n],
            |(qc, kc, vc, oc), row| {
                for i in 0..n {
                    let q = &qc[i * dh..(i + 1) * dh];
                    for j in 0..n {
                        let k = &kc[j * dh..(j + 1) * dh];
                        let mut dot = 0.0f32;
                        for c in 0..dh {
                            dot += q[c] * k[c];
                        }
                        row[j] = dot * scale;
                    }
                    softmax_in_place(row);
                    let orow = &mut oc[i * dh..(i + 1) * dh];
                    orow.fill(0.0);
                    for j in 0..n {
                        let w = row[j];
                        let vrow = &vc[j * dh..(j + 1) * dh];
                        for (ov, &vv) in orow.iter_mut().zip(vrow) {
                            *ov += w * vv;
                        }
                    }
                }
            },
        );

        let mut out = vec![0.0f32; b * n * d];
        merge_heads(&oh, b, n, h, dh, &mut out);
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// the native serving model (ViT-shaped CAT classifier)
// ---------------------------------------------------------------------------

/// Shape of the hermetic serving model (defaults match the ShapeDataset
/// substrate: 3×32×32 images, 10 classes, 64 tokens).
#[derive(Debug, Clone, Copy)]
pub struct NativeVitConfig {
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub image_size: usize,
    pub patch_size: usize,
    pub n_channels: usize,
    pub n_classes: usize,
    pub cat_impl: CatImpl,
}

impl Default for NativeVitConfig {
    fn default() -> Self {
        NativeVitConfig {
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            image_size: 32,
            patch_size: 4,
            n_channels: 3,
            n_classes: 10,
            cat_impl: CatImpl::Fft,
        }
    }
}

impl NativeVitConfig {
    pub fn n_tokens(&self) -> usize {
        let per_side = self.image_size / self.patch_size;
        per_side * per_side
    }

    pub fn patch_dim(&self) -> usize {
        self.patch_size * self.patch_size * self.n_channels
    }
}

/// Learned scale/shift of a LayerNorm.
struct LayerNorm {
    gamma: Vec<f32>,
    beta: Vec<f32>,
}

impl LayerNorm {
    fn identity(d: usize) -> LayerNorm {
        LayerNorm { gamma: vec![1.0; d], beta: vec![0.0; d] }
    }

    /// Normalize each `d`-sized row of `src` into `dst`.
    fn apply(&self, src: &[f32], dst: &mut [f32]) {
        let d = self.gamma.len();
        for (srow, drow) in src.chunks_exact(d).zip(dst.chunks_exact_mut(d)) {
            let mean = srow.iter().sum::<f32>() / d as f32;
            let var = srow.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
                / d as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for c in 0..d {
                drow[c] = (srow[c] - mean) * inv * self.gamma[c]
                    + self.beta[c];
            }
        }
    }
}

/// One transformer block: pre-LN CAT mixing + pre-LN 2×-wide ReLU MLP.
struct Block {
    ln1: LayerNorm,
    cat: CatLayer,
    ln2: LayerNorm,
    mlp_w1: Vec<f32>,
    mlp_b1: Vec<f32>,
    mlp_w2: Vec<f32>,
    mlp_b2: Vec<f32>,
}

/// Hermetic CAT image classifier served by the native backend: patch
/// embedding + learned positions + [`Block`] stack + mean pool + linear
/// head. Entirely deterministic in `(config, seed)`.
pub struct NativeCatModel {
    pub cfg: NativeVitConfig,
    embed_w: Vec<f32>,
    embed_b: Vec<f32>,
    pos: Vec<f32>,
    blocks: Vec<Block>,
    ln_f: LayerNorm,
    head_w: Vec<f32>,
    head_b: Vec<f32>,
}

impl NativeCatModel {
    pub fn new(cfg: NativeVitConfig, seed: u64) -> NativeCatModel {
        let d = cfg.d_model;
        let n = cfg.n_tokens();
        let pd = cfg.patch_dim();
        let mut rng = Rng::new(seed ^ 0xCA7_F00D);
        let mut mk = |len: usize| -> Vec<f32> {
            (0..len).map(|_| 0.02 * rng.normal()).collect()
        };
        let embed_w = mk(pd * d);
        let pos = mk(n * d);
        let head_w = mk(d * cfg.n_classes);
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for layer in 0..cfg.n_layers {
            let mut brng = rng.fork(layer as u64);
            blocks.push(Block {
                ln1: LayerNorm::identity(d),
                cat: CatLayer::init(d, cfg.n_heads, &mut brng),
                ln2: LayerNorm::identity(d),
                mlp_w1: (0..d * 2 * d).map(|_| 0.02 * brng.normal()).collect(),
                mlp_b1: vec![0.0; 2 * d],
                mlp_w2: (0..2 * d * d).map(|_| 0.02 * brng.normal()).collect(),
                mlp_b2: vec![0.0; d],
            });
        }
        NativeCatModel {
            cfg,
            embed_w,
            embed_b: vec![0.0; d],
            pos,
            blocks,
            ln_f: LayerNorm::identity(d),
            head_w,
            head_b: vec![0.0; cfg.n_classes],
        }
    }

    /// Total learnable scalars (diagnostics, `cat list --backend native`).
    pub fn param_count(&self) -> usize {
        let d = self.cfg.d_model;
        let per_block = self.blocks.first().map_or(0, |b| {
            b.cat.param_count()
                + b.mlp_w1.len() + b.mlp_b1.len()
                + b.mlp_w2.len() + b.mlp_b2.len()
                + 2 * 2 * d
        });
        self.embed_w.len() + self.embed_b.len() + self.pos.len()
            + self.blocks.len() * per_block
            + 2 * d
            + self.head_w.len() + self.head_b.len()
    }

    /// Classify a batch of CHW images: `(b, C·H·W)` flat → `(b, classes)`.
    pub fn forward_batch(&self, images: &[f32], b: usize) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, n, pd) = (cfg.d_model, cfg.n_tokens(), cfg.patch_dim());
        let image_len = cfg.n_channels * cfg.image_size * cfg.image_size;
        ensure!(images.len() == b * image_len,
                "images have {} elements, expected {}x{}", images.len(), b,
                image_len);

        // patchify: (b, n, patch_dim)
        let per_side = cfg.image_size / cfg.patch_size;
        let (ps, is) = (cfg.patch_size, cfg.image_size);
        let mut patches = vec![0.0f32; b * n * pd];
        for bi in 0..b {
            let img = &images[bi * image_len..(bi + 1) * image_len];
            for py in 0..per_side {
                for px in 0..per_side {
                    let tok = py * per_side + px;
                    let dst = &mut patches[(bi * n + tok) * pd..][..pd];
                    let mut w = 0;
                    for c in 0..cfg.n_channels {
                        for dy in 0..ps {
                            for dx in 0..ps {
                                dst[w] = img[c * is * is
                                    + (py * ps + dy) * is
                                    + px * ps + dx];
                                w += 1;
                            }
                        }
                    }
                }
            }
        }

        // embed + positions
        let mut x = vec![0.0f32; b * n * d];
        matmul(&patches, b * n, pd, &self.embed_w, d, &mut x);
        for bi in 0..b {
            for tok in 0..n {
                let row = &mut x[(bi * n + tok) * d..][..d];
                for c in 0..d {
                    row[c] += self.embed_b[c] + self.pos[tok * d + c];
                }
            }
        }

        // block stack
        let mut norm = vec![0.0f32; b * n * d];
        for block in &self.blocks {
            block.ln1.apply(&x, &mut norm);
            let mixed = block.cat.forward(&norm, b, n, cfg.cat_impl)?;
            for (xv, mv) in x.iter_mut().zip(&mixed) {
                *xv += mv;
            }
            block.ln2.apply(&x, &mut norm);
            let mut hid = vec![0.0f32; b * n * 2 * d];
            matmul(&norm, b * n, d, &block.mlp_w1, 2 * d, &mut hid);
            for row in hid.chunks_exact_mut(2 * d) {
                for (v, &bias) in row.iter_mut().zip(&block.mlp_b1) {
                    *v = (*v + bias).max(0.0);
                }
            }
            let mut mlp = vec![0.0f32; b * n * d];
            matmul(&hid, b * n, 2 * d, &block.mlp_w2, d, &mut mlp);
            for (row, xrow) in mlp
                .chunks_exact(d)
                .zip(x.chunks_exact_mut(d)) {
                for (xv, (&mv, &bias)) in
                    xrow.iter_mut().zip(row.iter().zip(&block.mlp_b2)) {
                    *xv += mv + bias;
                }
            }
        }

        // final LN, mean pool over tokens, classifier head
        self.ln_f.apply(&x, &mut norm);
        let mut pooled = vec![0.0f32; b * d];
        for bi in 0..b {
            let prow = &mut pooled[bi * d..(bi + 1) * d];
            for tok in 0..n {
                let row = &norm[(bi * n + tok) * d..][..d];
                for c in 0..d {
                    prow[c] += row[c];
                }
            }
            for v in prow.iter_mut() {
                *v /= n as f32;
            }
        }
        let mut logits = vec![0.0f32; b * cfg.n_classes];
        matmul(&pooled, b, d, &self.head_w, cfg.n_classes, &mut logits);
        for row in logits.chunks_exact_mut(cfg.n_classes) {
            for (v, &bias) in row.iter_mut().zip(&self.head_b) {
                *v += bias;
            }
        }
        Ok(logits)
    }

    /// Classify one CHW image (serving single-example path).
    pub fn forward_image(&self, image: &[f32]) -> Result<Vec<f32>> {
        self.forward_batch(image, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_x(b: usize, n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..b * n * d).map(|_| rng.normal()).collect()
    }

    #[test]
    fn fft_matches_gather() {
        let (b, n, d, h) = (2, 16, 12, 3);
        let mut rng = Rng::new(7);
        let layer = CatLayer::init(d, h, &mut rng);
        let x = random_x(b, n, d, 9);
        let fft = layer.forward(&x, b, n, CatImpl::Fft).unwrap();
        let gather = layer.forward(&x, b, n, CatImpl::Gather).unwrap();
        assert_eq!(fft.len(), gather.len());
        for (i, (a, g)) in fft.iter().zip(&gather).enumerate() {
            assert!((a - g).abs() < 1e-4, "element {i}: {a} vs {g}");
        }
    }

    #[test]
    fn cat_param_budget() {
        let mut rng = Rng::new(0);
        let layer = CatLayer::init(64, 4, &mut rng);
        assert_eq!(layer.param_count(), (64 + 4) * 64);
        let attn = AttentionLayer::init(64, 4, &mut rng);
        assert_eq!(attn.param_count(), 3 * 64 * 64);
        assert!(layer.param_count() < attn.param_count());
    }

    #[test]
    fn gather_on_non_power_of_two_fft_rejected() {
        let mut rng = Rng::new(1);
        let layer = CatLayer::init(12, 3, &mut rng);
        let x = random_x(1, 12, 12, 2);
        assert!(layer.forward(&x, 1, 12, CatImpl::Gather).is_ok());
        assert!(layer.forward(&x, 1, 12, CatImpl::Fft).is_err());
    }

    #[test]
    fn zero_query_attention_averages_values() {
        // W_Q = 0 -> uniform softmax -> every output row is mean_j(v_j)
        let (b, n, d, h) = (1, 8, 8, 2);
        let mut rng = Rng::new(3);
        let mut layer = AttentionLayer::init(d, h, &mut rng);
        layer.w_q.fill(0.0);
        let x = random_x(b, n, d, 4);
        let out = layer.forward(&x, b, n).unwrap();
        for i in 1..n {
            for c in 0..d {
                assert!((out[i * d + c] - out[c]).abs() < 1e-5,
                        "row {i} ch {c} differs under uniform attention");
            }
        }
    }

    #[test]
    fn model_forward_is_deterministic_and_finite() {
        let cfg = NativeVitConfig::default();
        let model = NativeCatModel::new(cfg, 42);
        let image_len = cfg.n_channels * cfg.image_size * cfg.image_size;
        let mut rng = Rng::new(5);
        let images: Vec<f32> =
            (0..2 * image_len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let a = model.forward_batch(&images, 2).unwrap();
        let b = model.forward_batch(&images, 2).unwrap();
        assert_eq!(a.len(), 2 * cfg.n_classes);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.is_finite()));
        // same seed -> same model; different seed -> different logits
        let same = NativeCatModel::new(cfg, 42).forward_batch(&images, 2)
            .unwrap();
        assert_eq!(a, same);
        let other = NativeCatModel::new(cfg, 43).forward_batch(&images, 2)
            .unwrap();
        assert_ne!(a, other);
        assert!(model.param_count() > 0);
    }

    #[test]
    fn model_fft_matches_gather_end_to_end() {
        let mut cfg = NativeVitConfig::default();
        cfg.n_layers = 1;
        let image_len = cfg.n_channels * cfg.image_size * cfg.image_size;
        let mut rng = Rng::new(11);
        let images: Vec<f32> =
            (0..image_len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let fft_logits = NativeCatModel::new(cfg, 1)
            .forward_image(&images).unwrap();
        cfg.cat_impl = CatImpl::Gather;
        let gather_logits = NativeCatModel::new(cfg, 1)
            .forward_image(&images).unwrap();
        for (a, g) in fft_logits.iter().zip(&gather_logits) {
            assert!((a - g).abs() < 1e-3, "{a} vs {g}");
        }
    }
}
