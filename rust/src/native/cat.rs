//! Native (pure-Rust) CAT executor: the paper's token-mixing mechanism
//! computed directly on the host, with no PJRT artifacts in the loop.
//!
//! The forward pass mirrors `python/compile/kernels/ref.py` exactly:
//!
//! ```text
//!   z  = x @ W_A                      (B, N, H)   merged d→h projection
//!   p  = softmax(z) over N            (B, H, N)   one weight vector/head
//!   v  = split_heads(x @ W_V)         (B, H, N, dh)
//!   o[i] = Σ_k p[k] · v[(i+k) % N]                circular cross-correlation
//!        = irfft(conj(rfft(p)) ⊙ rfft(v))         — O(N log N) per channel
//!   out = merge_heads(o)              (B, N, D)
//! ```
//!
//! [`CatImpl::Gather`] computes the same contraction as the naive O(N²)
//! rolled gather — the correctness reference and the paper's Fig.-1
//! baseline. Per the paper's parameter accounting (Tables 1–3) the
//! mechanism has no output projection: the learnable budget is exactly
//! `(d + h)·d` ([`CatLayer::param_count`]); the model-level output
//! projection lives in [`NativeCatModel`]'s classifier head.
//!
//! Execution model (DESIGN.md §7): parallel sections fan out over the
//! persistent worker pool ([`super::pool`]) — no scoped threads, zero
//! spawns at steady state — and every intermediate lives in the
//! per-thread bump arenas ([`super::arena`]). The FFT path stores values
//! **stripe-transposed**: each `(batch, head)` stripe holds its `dh`
//! channels as contiguous length-`N` rows, so one
//! [`SplitRfftPlan::rfft_many`] call transforms a whole stripe with no
//! per-channel gather/scatter and cache-hot twiddle tables.
//!
//! [`SplitRfftPlan::rfft_many`]: super::fft::SplitRfftPlan::rfft_many

use anyhow::ensure;

use super::arena;
use super::fft::split_rfft_plan;
use super::mixer::{serve::ServeMixer, Mixer};
use super::pool;
use super::simd;
use crate::data::Rng;
use crate::obs::trace::{self as obs_trace, Stage};
use crate::Result;

/// Which circulant apply computes the mixing contraction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CatImpl {
    /// O(N log N): planned batched rfft → conjugate pointwise multiply →
    /// irfft, split-complex across each head stripe.
    Fft,
    /// O(N²): naive rolled gather (correctness + crossover baseline).
    Gather,
}

impl CatImpl {
    pub fn name(self) -> &'static str {
        match self {
            CatImpl::Fft => "fft",
            CatImpl::Gather => "gather",
        }
    }
}

// ---------------------------------------------------------------------------
// small dense linear algebra (shared by both native layers)
// ---------------------------------------------------------------------------

/// `out = x @ w` with `x: (rows, inner)`, `w: (inner, cols)`, row-major.
/// Splits across row blocks on the worker pool when the FLOP count
/// justifies fanning out.
pub fn matmul(x: &[f32], rows: usize, inner: usize, w: &[f32], cols: usize,
              out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * inner);
    debug_assert_eq!(w.len(), inner * cols);
    debug_assert_eq!(out.len(), rows * cols);
    let chunks = pool::max_parallel_tasks().min(rows).max(1);
    if chunks <= 1 || rows * inner * cols < (1 << 21) {
        matmul_rows(x, inner, w, cols, out);
        return;
    }
    let chunk_rows = (rows + chunks - 1) / chunks;
    let tasks: Vec<(&[f32], &mut [f32])> = out
        .chunks_mut(chunk_rows * cols)
        .enumerate()
        .map(|(ci, oc)| {
            let r0 = ci * chunk_rows;
            let nrows = oc.len() / cols;
            (&x[r0 * inner..(r0 + nrows) * inner], oc)
        })
        .collect();
    pool::run(tasks, 2 * chunk_rows * inner * cols, |(xc, oc)| {
        matmul_rows(xc, inner, w, cols, oc);
    });
}

/// Serial row-major matmul kernel (ikj order: streams `w` rows). Each
/// output row accumulates rank-1 updates via [`simd::axpy`] — per-slot
/// accumulation order matches the scalar oracle, so the kernel is
/// bit-identical across dispatch tiers.
fn matmul_rows(x: &[f32], inner: usize, w: &[f32], cols: usize,
               out: &mut [f32]) {
    out.fill(0.0);
    for (xrow, orow) in x.chunks_exact(inner).zip(out.chunks_exact_mut(cols)) {
        for (k, &xv) in xrow.iter().enumerate() {
            simd::axpy(orow, &w[k * cols..(k + 1) * cols], xv);
        }
    }
}

/// Numerically stable in-place softmax over one row. The max scan and
/// the final rescale run through [`simd`]; the exp+sum pass stays a
/// fused scalar loop (`exp` has no vector form here, and fusing keeps
/// the running sum's accumulation order identical to the oracle).
pub fn softmax_in_place(row: &mut [f32]) {
    let max = simd::max(row);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    simd::scale(row, 1.0 / sum);
}

/// `(b, n, h·dh)` → head-major `(b, h, n, dh)`.
fn split_heads(src: &[f32], b: usize, n: usize, h: usize, dh: usize,
               dst: &mut [f32]) {
    let d = h * dh;
    for bi in 0..b {
        for head in 0..h {
            for i in 0..n {
                let s = (bi * n + i) * d + head * dh;
                let t = ((bi * h + head) * n + i) * dh;
                dst[t..t + dh].copy_from_slice(&src[s..s + dh]);
            }
        }
    }
}

/// Head-major `(b, h, n, dh)` → `(b, n, h·dh)`.
fn merge_heads(src: &[f32], b: usize, n: usize, h: usize, dh: usize,
               dst: &mut [f32]) {
    let d = h * dh;
    for bi in 0..b {
        for head in 0..h {
            for i in 0..n {
                let s = ((bi * h + head) * n + i) * dh;
                let t = (bi * n + i) * d + head * dh;
                dst[t..t + dh].copy_from_slice(&src[s..s + dh]);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the CAT mixing layer
// ---------------------------------------------------------------------------

/// One CAT mixing layer: merged `W_A: (d, h)` plus `W_V: (d, h·dh)`.
///
/// A *full* layer has `h·dh == d` (so `W_V` is the paper's `(d, d)`
/// projection). A **head slice** ([`CatLayer::head_slice`]) keeps the
/// input dim `d` and per-head width `dh` but owns only a contiguous run
/// of heads — the model-parallel unit of sharded serving: per-head
/// spectra never interact before the merge, so a slice computes columns
/// `[h0·dh, h1·dh)` of the full layer's output bit-for-bit.
#[derive(Clone)]
pub struct CatLayer {
    /// Input dim (always the full model width, even for a slice).
    pub d: usize,
    /// Heads owned by this layer (the full head count, or a slice of it).
    pub h: usize,
    /// Channels per head (`d_model / n_heads` of the *full* layer).
    pub dh: usize,
    w_a: Vec<f32>,
    w_v: Vec<f32>,
}

impl CatLayer {
    /// Deterministic init (0.02-scaled normal, matching `_dense_init` in
    /// `python/compile/mechanisms.py`).
    pub fn init(d: usize, h: usize, rng: &mut Rng) -> CatLayer {
        assert!(h > 0 && d % h == 0, "d ({d}) must divide into h ({h}) heads");
        let w_a = (0..d * h).map(|_| 0.02 * rng.normal()).collect();
        let w_v = (0..d * d).map(|_| 0.02 * rng.normal()).collect();
        CatLayer { d, h, dh: d / h, w_a, w_v }
    }

    /// Output width of this layer: `h·dh` (`== d` for a full layer).
    pub fn width(&self) -> usize {
        self.h * self.dh
    }

    /// Copy out heads `[h0, h1)` as a standalone slice layer: its `W_A`
    /// keeps columns `h0..h1`, its `W_V` keeps columns
    /// `h0·dh..h1·dh`. Every per-output-element accumulation order is
    /// unchanged (matmuls sum over the input dim, softmax/FFT act per
    /// head), so a slice's output equals the matching columns of the
    /// full forward **bit-exactly** — the invariant the sharded serving
    /// tests pin.
    pub fn head_slice(&self, h0: usize, h1: usize) -> CatLayer {
        assert!(h0 < h1 && h1 <= self.h,
                "bad head slice [{h0}, {h1}) of {} heads", self.h);
        let (d, dh, w) = (self.d, self.dh, self.width());
        let hs = h1 - h0;
        let mut w_a = Vec::with_capacity(d * hs);
        let mut w_v = Vec::with_capacity(d * hs * dh);
        for k in 0..d {
            w_a.extend_from_slice(&self.w_a[k * self.h + h0..
                                            k * self.h + h1]);
            w_v.extend_from_slice(&self.w_v[k * w + h0 * dh..
                                            k * w + h1 * dh]);
        }
        CatLayer { d, h: hs, dh, w_a, w_v }
    }

    /// Learnable parameters: `(d + h)·d` for a full layer, the paper's
    /// CAT budget (a head slice counts only its own columns).
    pub fn param_count(&self) -> usize {
        self.w_a.len() + self.w_v.len()
    }

    /// Drop the mixing weights (sharded serving trunk); a stripped layer
    /// errors cleanly from [`Self::forward_into`].
    pub(crate) fn strip(&mut self) {
        self.w_a = Vec::new();
        self.w_v = Vec::new();
    }

    /// Mix tokens: `x: (b, n, d)` row-major → freshly allocated
    /// `(b, n, width)`. Benchmark/test convenience over
    /// [`Self::forward_into`].
    pub fn forward(&self, x: &[f32], b: usize, n: usize, mode: CatImpl)
                   -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; b * n * self.width()];
        self.forward_into(x, b, n, mode, &mut out)?;
        Ok(out)
    }

    /// Mix tokens into `out: (b, n, width)` (fully overwritten; for a
    /// full layer `width == d`, for a head slice it is the slice's
    /// `h·dh` columns). All tensor intermediates come from the
    /// thread-local arenas, so after warmup the only heap traffic is the
    /// pool's small per-section dispatch state (task list + one boxed
    /// job per chunk) when a section fans out — nothing proportional to
    /// the tensor sizes.
    pub fn forward_into(&self, x: &[f32], b: usize, n: usize, mode: CatImpl,
                        out: &mut [f32]) -> Result<()> {
        let (d, w) = (self.d, self.width());
        ensure!(x.len() == b * n * d,
                "x has {} elements, expected {}x{}x{}", x.len(), b, n, d);
        ensure!(out.len() == b * n * w,
                "out has {} elements, expected {}x{}x{}", out.len(), b, n, w);
        ensure!(self.w_a.len() == d * self.h && self.w_v.len() == d * w,
                "CAT mixing weights are absent — this layer was stripped \
                 (sharded serving trunk) and cannot mix tokens itself");
        if mode == CatImpl::Fft {
            ensure!(n.is_power_of_two(),
                    "CAT-FFT needs power-of-two N, got {n}");
            self.forward_fft_into(x, b, n, out);
        } else {
            self.forward_gather_into(x, b, n, out);
        }
        Ok(())
    }

    /// Shared projection preamble of both paths: `z = x @ W_A` transposed
    /// into head-major weight rows `zs` (pre-softmax — the FFT path fuses
    /// softmax into its first parallel section), `v = x @ W_V`. Keeping
    /// this single keeps the FFT-vs-gather equivalence tests meaningful:
    /// the two paths can only diverge in the circulant apply itself.
    fn project(&self, x: &[f32], b: usize, n: usize, z: &mut [f32],
               zs: &mut [f32], v: &mut [f32]) {
        let (d, h) = (self.d, self.h);
        matmul(x, b * n, d, &self.w_a, h, z);
        for bi in 0..b {
            for head in 0..h {
                for i in 0..n {
                    zs[(bi * h + head) * n + i] = z[(bi * n + i) * h + head];
                }
            }
        }
        matmul(x, b * n, d, &self.w_v, self.width(), v);
    }

    /// O(N log N) path: stripe-transposed values, batched split-complex
    /// real FFTs, frequency-domain conjugate product.
    fn forward_fft_into(&self, x: &[f32], b: usize, n: usize,
                        out: &mut [f32]) {
        let h = self.h;
        let (dh, w) = (self.dh, self.width());
        let plan = split_rfft_plan(n);
        let f = plan.spectrum_len();
        let log_term = n.trailing_zeros() as usize + 1;
        arena::with_layer_arena(|la| {
            let [z, zs, v, vt, zf_re, zf_im] = la.frame([
                b * n * h, // z: (b·n, h) projection
                b * h * n, // zs: head-major softmax stripes
                b * n * w, // v: (b·n, w) projection
                b * n * w, // vt: stripe-transposed (b·h, dh, n) values
                b * h * f, // zf: weight spectra, split re/im
                b * h * f,
            ]);

            obs_trace::section(Stage::MixerMatmul,
                               || self.project(x, b, n, z, zs, v));

            // stripe-transpose v: channel c of stripe (bi, head) becomes
            // one contiguous length-n row, the layout rfft_many consumes
            // directly (traced as the `scatter` stage, DESIGN.md §13)
            obs_trace::section(Stage::Scatter, || {
                let v = &*v;
                let tasks: Vec<(usize, &mut [f32])> =
                    vt.chunks_mut(dh * n).enumerate().collect();
                pool::run(tasks, 4 * n * dh, |(si, stripe)| {
                    let (bi, head) = (si / h, si % h);
                    for (c, row) in stripe.chunks_exact_mut(n).enumerate() {
                        let base = bi * n * w + head * dh + c;
                        for (i, slot) in row.iter_mut().enumerate() {
                            *slot = v[base + i * w];
                        }
                    }
                });
            });

            // softmax each weight row, then one batched rfft per chunk
            obs_trace::section(Stage::Fft, || {
                let tasks: Vec<((&mut [f32], &mut [f32]), &mut [f32])> = zs
                    .chunks_mut(n)
                    .zip(zf_re.chunks_mut(f))
                    .zip(zf_im.chunks_mut(f))
                    .collect();
                pool::run(tasks, 6 * n * log_term, |((row, sre), sim)| {
                    softmax_in_place(row);
                    arena::with_task_arena(|ta| {
                        let [scratch] = ta.frame([plan.scratch_len()]);
                        plan.rfft(row, sre, sim, scratch);
                    });
                });
            });

            // per-stripe: batched rfft over the dh value rows, conjugate
            // pointwise product with the stripe's weight spectrum, batched
            // irfft back into the stripe in place
            obs_trace::section(Stage::Fft, || {
                let zf_re = &*zf_re;
                let zf_im = &*zf_im;
                let tasks: Vec<(usize, &mut [f32])> =
                    vt.chunks_mut(dh * n).enumerate().collect();
                pool::run(tasks, 5 * n * log_term * dh, |(si, stripe)| {
                    arena::with_task_arena(|ta| {
                        let [vre, vim, scratch] = ta.frame(
                            [dh * f, dh * f, plan.scratch_len()]);
                        plan.rfft_many(stripe, dh, vre, vim, scratch);
                        let zr = &zf_re[si * f..(si + 1) * f];
                        let zi = &zf_im[si * f..(si + 1) * f];
                        for c in 0..dh {
                            // conj(zf) ⊙ vf
                            simd::cmul_conj_a_rows(
                                zr, zi,
                                &mut vre[c * f..(c + 1) * f],
                                &mut vim[c * f..(c + 1) * f]);
                        }
                        plan.irfft_many(vre, vim, dh, stripe, scratch);
                    });
                });
            });

            // un-transpose the stripes into (b, n, w)
            obs_trace::section(Stage::Gather, || {
                let vt = &*vt;
                let tasks: Vec<(usize, &mut [f32])> =
                    out.chunks_mut(n * w).enumerate().collect();
                pool::run(tasks, 4 * n * w, |(bi, obatch)| {
                    for head in 0..h {
                        for c in 0..dh {
                            let row = &vt[((bi * h + head) * dh + c) * n..]
                                [..n];
                            let off = head * dh + c;
                            for (i, &val) in row.iter().enumerate() {
                                obatch[i * w + off] = val;
                            }
                        }
                    }
                });
            });
        });
    }

    /// O(N²) path: the naive rolled gather, head-major.
    fn forward_gather_into(&self, x: &[f32], b: usize, n: usize,
                           out: &mut [f32]) {
        let h = self.h;
        let (dh, w) = (self.dh, self.width());
        arena::with_layer_arena(|la| {
            let [z, zs, v, vh, oh] = la.frame([
                b * n * h,
                b * h * n,
                b * n * w,
                b * n * w,
                b * n * w,
            ]);
            obs_trace::section(Stage::MixerMatmul,
                               || self.project(x, b, n, z, zs, v));
            for row in zs.chunks_mut(n) {
                softmax_in_place(row);
            }
            split_heads(v, b, n, h, dh, vh);

            let zs = &*zs;
            let vh = &*vh;
            let tasks: Vec<((&[f32], &[f32]), &mut [f32])> = zs
                .chunks(n)
                .zip(vh.chunks(n * dh))
                .zip(oh.chunks_mut(n * dh))
                .collect();
            // the rolled O(N²) apply is this path's whole mixing stage
            obs_trace::section(Stage::Gather, || {
                pool::run(tasks, 2 * n * n * dh, |((zc, vc), oc)| {
                    for i in 0..n {
                        let orow = &mut oc[i * dh..(i + 1) * dh];
                        orow.fill(0.0);
                        for k in 0..n {
                            let j = (i + k) % n;
                            simd::axpy(orow, &vc[j * dh..j * dh + dh], zc[k]);
                        }
                    }
                });
            });

            merge_heads(oh, b, n, h, dh, out);
        });
    }
}

// ---------------------------------------------------------------------------
// native softmax attention (the O(N²) wallclock baseline)
// ---------------------------------------------------------------------------

/// Standard multi-head softmax attention, row-streamed (O(N) scratch).
#[derive(Clone)]
pub struct AttentionLayer {
    pub d: usize,
    pub h: usize,
    w_q: Vec<f32>,
    w_k: Vec<f32>,
    w_v: Vec<f32>,
}

impl AttentionLayer {
    pub fn init(d: usize, h: usize, rng: &mut Rng) -> AttentionLayer {
        assert!(h > 0 && d % h == 0, "d ({d}) must divide into h ({h}) heads");
        let mut mk = |len: usize| -> Vec<f32> {
            (0..len).map(|_| 0.02 * rng.normal()).collect()
        };
        AttentionLayer {
            d,
            h,
            w_q: mk(d * d),
            w_k: mk(d * d),
            w_v: mk(d * d),
        }
    }

    /// Paper accounting: `3·d²` learnables.
    pub fn param_count(&self) -> usize {
        self.w_q.len() + self.w_k.len() + self.w_v.len()
    }

    /// Drop the mixing weights (sharded serving trunk).
    pub(crate) fn strip(&mut self) {
        self.w_q = Vec::new();
        self.w_k = Vec::new();
        self.w_v = Vec::new();
    }

    /// `x: (b, n, d)` → freshly allocated `(b, n, d)` via
    /// softmax(QKᵀ/√dh)·V per head.
    pub fn forward(&self, x: &[f32], b: usize, n: usize) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; b * n * self.d];
        self.forward_into(x, b, n, &mut out)?;
        Ok(out)
    }

    /// Attention into `out` (fully overwritten); layer-arena backed.
    pub fn forward_into(&self, x: &[f32], b: usize, n: usize,
                        out: &mut [f32]) -> Result<()> {
        let (d, h) = (self.d, self.h);
        let dh = d / h;
        ensure!(x.len() == b * n * d,
                "x has {} elements, expected {}x{}x{}", x.len(), b, n, d);
        ensure!(out.len() == b * n * d,
                "out has {} elements, expected {}x{}x{}", out.len(), b, n, d);
        ensure!(self.w_q.len() == d * d && self.w_k.len() == d * d
                    && self.w_v.len() == d * d,
                "attention mixing weights are absent — this layer was \
                 stripped (sharded serving trunk) and cannot mix tokens \
                 itself");
        let scale = 1.0 / (dh as f32).sqrt();
        arena::with_layer_arena(|la| {
            let [proj, qh, kh, vh, oh] = la.frame([
                b * n * d,
                b * n * d,
                b * n * d,
                b * n * d,
                b * n * d,
            ]);
            matmul(x, b * n, d, &self.w_q, d, proj);
            split_heads(proj, b, n, h, dh, qh);
            matmul(x, b * n, d, &self.w_k, d, proj);
            split_heads(proj, b, n, h, dh, kh);
            matmul(x, b * n, d, &self.w_v, d, proj);
            split_heads(proj, b, n, h, dh, vh);

            let (qh, kh, vh) = (&*qh, &*kh, &*vh);
            let tasks: Vec<(((&[f32], &[f32]), &[f32]), &mut [f32])> = qh
                .chunks(n * dh)
                .zip(kh.chunks(n * dh))
                .zip(vh.chunks(n * dh))
                .zip(oh.chunks_mut(n * dh))
                .collect();
            pool::run(tasks, 4 * n * n * dh, |(((qc, kc), vc), oc)| {
                arena::with_task_arena(|ta| {
                    let [row] = ta.frame([n]);
                    for i in 0..n {
                        let q = &qc[i * dh..(i + 1) * dh];
                        for j in 0..n {
                            let k = &kc[j * dh..(j + 1) * dh];
                            row[j] = simd::dot(q, k) * scale;
                        }
                        softmax_in_place(row);
                        let orow = &mut oc[i * dh..(i + 1) * dh];
                        orow.fill(0.0);
                        for j in 0..n {
                            simd::axpy(orow, &vc[j * dh..(j + 1) * dh],
                                       row[j]);
                        }
                    }
                });
            });

            merge_heads(oh, b, n, h, dh, out);
        });
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// the native serving model (ViT-shaped CAT classifier)
// ---------------------------------------------------------------------------

/// Shape of the hermetic serving model (defaults match the ShapeDataset
/// substrate: 3×32×32 images, 10 classes, 64 tokens).
#[derive(Debug, Clone, Copy)]
pub struct NativeVitConfig {
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub image_size: usize,
    pub patch_size: usize,
    pub n_channels: usize,
    pub n_classes: usize,
    pub cat_impl: CatImpl,
    /// Token mixer of every block (registry id; `--mixer` on the CLI).
    /// `cat_impl` only routes the CAT variant's apply, as before.
    pub mixer: Mixer,
}

impl Default for NativeVitConfig {
    fn default() -> Self {
        NativeVitConfig {
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            image_size: 32,
            patch_size: 4,
            n_channels: 3,
            n_classes: 10,
            cat_impl: CatImpl::Fft,
            mixer: Mixer::CatFft,
        }
    }
}

impl NativeVitConfig {
    pub fn n_tokens(&self) -> usize {
        let per_side = self.image_size / self.patch_size;
        per_side * per_side
    }

    pub fn patch_dim(&self) -> usize {
        self.patch_size * self.patch_size * self.n_channels
    }
}

/// Learned scale/shift of a LayerNorm.
struct LayerNorm {
    gamma: Vec<f32>,
    beta: Vec<f32>,
}

impl LayerNorm {
    fn identity(d: usize) -> LayerNorm {
        LayerNorm { gamma: vec![1.0; d], beta: vec![0.0; d] }
    }

    /// Normalize each `d`-sized row of `src` into `dst`. The mean and
    /// variance passes are [`simd`] reductions (tolerance-pinned); the
    /// normalize itself is element-wise.
    fn apply(&self, src: &[f32], dst: &mut [f32]) {
        let d = self.gamma.len();
        for (srow, drow) in src.chunks_exact(d).zip(dst.chunks_exact_mut(d)) {
            let mean = simd::sum(srow) / d as f32;
            let var = simd::sumsq_diff(srow, mean) / d as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for c in 0..d {
                drow[c] = (srow[c] - mean) * inv * self.gamma[c]
                    + self.beta[c];
            }
        }
    }
}

/// One transformer block: pre-LN token mixing + pre-LN 2×-wide ReLU MLP.
struct Block {
    ln1: LayerNorm,
    mixer: ServeMixer,
    ln2: LayerNorm,
    mlp_w1: Vec<f32>,
    mlp_b1: Vec<f32>,
    mlp_w2: Vec<f32>,
    mlp_b2: Vec<f32>,
}

/// Hermetic CAT image classifier served by the native backend: patch
/// embedding + learned positions + [`Block`] stack + mean pool + linear
/// head. Entirely deterministic in `(config, seed)`. Activations live in
/// the model arena, so after warmup a same-shape `forward_batch`
/// allocates nothing tensor-sized beyond the returned logits.
pub struct NativeCatModel {
    pub cfg: NativeVitConfig,
    embed_w: Vec<f32>,
    embed_b: Vec<f32>,
    pos: Vec<f32>,
    blocks: Vec<Block>,
    ln_f: LayerNorm,
    head_w: Vec<f32>,
    head_b: Vec<f32>,
}

impl NativeCatModel {
    pub fn new(cfg: NativeVitConfig, seed: u64) -> NativeCatModel {
        let d = cfg.d_model;
        let n = cfg.n_tokens();
        let pd = cfg.patch_dim();
        let mut rng = Rng::new(seed ^ 0xCA7_F00D);
        let mut mk = |len: usize| -> Vec<f32> {
            (0..len).map(|_| 0.02 * rng.normal()).collect()
        };
        let embed_w = mk(pd * d);
        let pos = mk(n * d);
        let head_w = mk(d * cfg.n_classes);
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for layer in 0..cfg.n_layers {
            let mut brng = rng.fork(layer as u64);
            blocks.push(Block {
                ln1: LayerNorm::identity(d),
                mixer: ServeMixer::init(cfg.mixer, d, cfg.n_heads,
                                        &mut brng),
                ln2: LayerNorm::identity(d),
                mlp_w1: (0..d * 2 * d).map(|_| 0.02 * brng.normal()).collect(),
                mlp_b1: vec![0.0; 2 * d],
                mlp_w2: (0..2 * d * d).map(|_| 0.02 * brng.normal()).collect(),
                mlp_b2: vec![0.0; d],
            });
        }
        NativeCatModel {
            cfg,
            embed_w,
            embed_b: vec![0.0; d],
            pos,
            blocks,
            ln_f: LayerNorm::identity(d),
            head_w,
            head_b: vec![0.0; cfg.n_classes],
        }
    }

    /// Total learnable scalars (diagnostics, `cat list --backend native`).
    pub fn param_count(&self) -> usize {
        let d = self.cfg.d_model;
        let per_block = self.blocks.first().map_or(0, |b| {
            b.mixer.param_count()
                + b.mlp_w1.len() + b.mlp_b1.len()
                + b.mlp_w2.len() + b.mlp_b2.len()
                + 2 * 2 * d
        });
        self.embed_w.len() + self.embed_b.len() + self.pos.len()
            + self.blocks.len() * per_block
            + 2 * d
            + self.head_w.len() + self.head_b.len()
    }

    /// Number of transformer blocks in the stack.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Head-sliced copies of every block's mixing layer for heads
    /// `[h0, h1)` — the per-shard weights of sharded serving
    /// (`coordinator::shard`). Slice `i` of the returned vec pairs with
    /// block `i` of this model. Non-head-separable mixers only admit the
    /// degenerate full-range slice (the shard planner enforces this).
    pub fn sliced_mixer_layers(&self, h0: usize, h1: usize)
                               -> Vec<ServeMixer> {
        self.blocks.iter().map(|bl| bl.mixer.head_slice(h0, h1)).collect()
    }

    /// Drop every block's mixing weights, keeping only the trunk (patch
    /// embed, LayerNorms, MLPs, classifier head). Sharded serving calls
    /// this after slicing so each replica's mixing weights exist exactly
    /// once — in the head slices — instead of twice. A stripped model
    /// must be driven through [`Self::forward_batch_with`]; the built-in
    /// mixer path errors cleanly (`forward_into` checks weight lengths).
    pub(crate) fn strip_mixer_weights(&mut self) {
        for block in &mut self.blocks {
            block.mixer.strip();
        }
    }

    /// Classify a batch of CHW images: `(b, C·H·W)` flat → `(b, classes)`.
    pub fn forward_batch(&self, images: &[f32], b: usize) -> Result<Vec<f32>> {
        self.forward_batch_with(images, b, |li, norm, bb, n, mixed| {
            self.blocks[li].mixer.forward_into(norm, bb, n,
                                               self.cfg.cat_impl, mixed)
        })
    }

    /// The trunk with a pluggable token mixer: identical to
    /// [`Self::forward_batch`] except that each block's CAT mixing is
    /// delegated to `mix(block_idx, normed_x, b, n, mixed_out)`, which
    /// must fully overwrite `mixed_out: (b, n, d)`. This is the seam the
    /// sharded serving path uses to scatter the mixer across
    /// model-parallel head shards while the non-separable parts
    /// (patchify, LayerNorms, residuals, MLPs, classifier head) run
    /// unchanged — keeping sharded and unsharded forwards bit-identical
    /// by construction.
    pub fn forward_batch_with<F>(&self, images: &[f32], b: usize, mut mix: F)
                                 -> Result<Vec<f32>>
    where
        F: FnMut(usize, &[f32], usize, usize, &mut [f32]) -> Result<()>,
    {
        let cfg = &self.cfg;
        let (d, n, pd) = (cfg.d_model, cfg.n_tokens(), cfg.patch_dim());
        let image_len = cfg.n_channels * cfg.image_size * cfg.image_size;
        ensure!(images.len() == b * image_len,
                "images have {} elements, expected {}x{}", images.len(), b,
                image_len);

        arena::with_model_arena(|ma| {
            let [patches, x, norm, mixed, hid, mlp, pooled] = ma.frame([
                b * n * pd,
                b * n * d,
                b * n * d,
                b * n * d,
                b * n * 2 * d,
                b * n * d,
                b * d,
            ]);

            // patchify: (b, n, patch_dim)
            let per_side = cfg.image_size / cfg.patch_size;
            let (ps, is) = (cfg.patch_size, cfg.image_size);
            for bi in 0..b {
                let img = &images[bi * image_len..(bi + 1) * image_len];
                for py in 0..per_side {
                    for px in 0..per_side {
                        let tok = py * per_side + px;
                        let dst = &mut patches[(bi * n + tok) * pd..][..pd];
                        let mut w = 0;
                        for c in 0..cfg.n_channels {
                            for dy in 0..ps {
                                for dx in 0..ps {
                                    dst[w] = img[c * is * is
                                        + (py * ps + dy) * is
                                        + px * ps + dx];
                                    w += 1;
                                }
                            }
                        }
                    }
                }
            }

            // embed + positions
            matmul(patches, b * n, pd, &self.embed_w, d, x);
            for bi in 0..b {
                for tok in 0..n {
                    let row = &mut x[(bi * n + tok) * d..][..d];
                    for c in 0..d {
                        row[c] += self.embed_b[c] + self.pos[tok * d + c];
                    }
                }
            }

            // block stack (buffers reused across blocks)
            for (li, block) in self.blocks.iter().enumerate() {
                block.ln1.apply(x, norm);
                mix(li, norm, b, n, mixed)?;
                for (xv, mv) in x.iter_mut().zip(mixed.iter()) {
                    *xv += mv;
                }
                block.ln2.apply(x, norm);
                matmul(norm, b * n, d, &block.mlp_w1, 2 * d, hid);
                for row in hid.chunks_exact_mut(2 * d) {
                    for (v, &bias) in row.iter_mut().zip(&block.mlp_b1) {
                        *v = (*v + bias).max(0.0);
                    }
                }
                matmul(hid, b * n, 2 * d, &block.mlp_w2, d, mlp);
                for (row, xrow) in mlp
                    .chunks_exact(d)
                    .zip(x.chunks_exact_mut(d)) {
                    for (xv, (&mv, &bias)) in
                        xrow.iter_mut().zip(row.iter().zip(&block.mlp_b2)) {
                        *xv += mv + bias;
                    }
                }
            }

            // final LN, mean pool over tokens, classifier head
            self.ln_f.apply(x, norm);
            pooled.fill(0.0);
            for bi in 0..b {
                let prow = &mut pooled[bi * d..(bi + 1) * d];
                for tok in 0..n {
                    let row = &norm[(bi * n + tok) * d..][..d];
                    for c in 0..d {
                        prow[c] += row[c];
                    }
                }
                for v in prow.iter_mut() {
                    *v /= n as f32;
                }
            }
            let mut logits = vec![0.0f32; b * cfg.n_classes];
            matmul(pooled, b, d, &self.head_w, cfg.n_classes, &mut logits);
            for row in logits.chunks_exact_mut(cfg.n_classes) {
                for (v, &bias) in row.iter_mut().zip(&self.head_b) {
                    *v += bias;
                }
            }
            Ok(logits)
        })
    }

    /// Classify one CHW image (serving single-example path).
    pub fn forward_image(&self, image: &[f32]) -> Result<Vec<f32>> {
        self.forward_batch(image, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_x(b: usize, n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..b * n * d).map(|_| rng.normal()).collect()
    }

    #[test]
    fn fft_matches_gather() {
        let (b, n, d, h) = (2, 16, 12, 3);
        let mut rng = Rng::new(7);
        let layer = CatLayer::init(d, h, &mut rng);
        let x = random_x(b, n, d, 9);
        let fft = layer.forward(&x, b, n, CatImpl::Fft).unwrap();
        let gather = layer.forward(&x, b, n, CatImpl::Gather).unwrap();
        assert_eq!(fft.len(), gather.len());
        for (i, (a, g)) in fft.iter().zip(&gather).enumerate() {
            assert!((a - g).abs() < 1e-4, "element {i}: {a} vs {g}");
        }
    }

    #[test]
    fn fft_matches_gather_at_pool_scale() {
        // large enough that every parallel section actually fans out
        let (b, n, d, h) = (2, 512, 64, 4);
        let mut rng = Rng::new(17);
        let layer = CatLayer::init(d, h, &mut rng);
        let x = random_x(b, n, d, 19);
        let fft = layer.forward(&x, b, n, CatImpl::Fft).unwrap();
        let gather = layer.forward(&x, b, n, CatImpl::Gather).unwrap();
        for (i, (a, g)) in fft.iter().zip(&gather).enumerate() {
            assert!((a - g).abs() < 1e-3, "element {i}: {a} vs {g}");
        }
    }

    #[test]
    fn serial_forward_is_allocation_free_after_warmup() {
        // small shape => every section runs inline on this thread, so the
        // arena growth counter is deterministic
        let (b, n, d, h) = (1, 32, 16, 4);
        let mut rng = Rng::new(23);
        let layer = CatLayer::init(d, h, &mut rng);
        let x = random_x(b, n, d, 29);
        let mut out = vec![0.0f32; b * n * d];
        layer.forward_into(&x, b, n, CatImpl::Fft, &mut out).unwrap();
        let caps = arena::thread_arena_capacities();
        for _ in 0..10 {
            layer.forward_into(&x, b, n, CatImpl::Fft, &mut out).unwrap();
        }
        assert_eq!(arena::thread_arena_capacities(), caps,
                   "steady-state forward_into grew this thread's arenas");
    }

    #[test]
    fn head_slice_matches_full_forward_bitwise() {
        // the sharding invariant: a head slice's output equals the
        // matching columns of the full forward bit-for-bit, on both
        // circulant applies — uneven and single-head slices included
        let (b, n, d, h) = (2, 32, 24, 4);
        let dh = d / h;
        let mut rng = Rng::new(31);
        let layer = CatLayer::init(d, h, &mut rng);
        let x = random_x(b, n, d, 37);
        for mode in [CatImpl::Fft, CatImpl::Gather] {
            let full = layer.forward(&x, b, n, mode).unwrap();
            for (h0, h1) in [(0, 1), (1, 3), (2, 4), (0, 4)] {
                let slice = layer.head_slice(h0, h1);
                assert_eq!(slice.width(), (h1 - h0) * dh);
                assert_eq!(slice.param_count(),
                           (h1 - h0) * d + (h1 - h0) * dh * d);
                let part = slice.forward(&x, b, n, mode).unwrap();
                let ws = slice.width();
                for row in 0..b * n {
                    assert_eq!(
                        &part[row * ws..(row + 1) * ws],
                        &full[row * d + h0 * dh..row * d + h1 * dh],
                        "{} slice [{h0},{h1}) row {row} diverged",
                        mode.name());
                }
            }
        }
    }

    #[test]
    fn cat_param_budget() {
        let mut rng = Rng::new(0);
        let layer = CatLayer::init(64, 4, &mut rng);
        assert_eq!(layer.param_count(), (64 + 4) * 64);
        let attn = AttentionLayer::init(64, 4, &mut rng);
        assert_eq!(attn.param_count(), 3 * 64 * 64);
        assert!(layer.param_count() < attn.param_count());
    }

    #[test]
    fn gather_on_non_power_of_two_fft_rejected() {
        let mut rng = Rng::new(1);
        let layer = CatLayer::init(12, 3, &mut rng);
        let x = random_x(1, 12, 12, 2);
        assert!(layer.forward(&x, 1, 12, CatImpl::Gather).is_ok());
        assert!(layer.forward(&x, 1, 12, CatImpl::Fft).is_err());
    }

    #[test]
    fn zero_query_attention_averages_values() {
        // W_Q = 0 -> uniform softmax -> every output row is mean_j(v_j)
        let (b, n, d, h) = (1, 8, 8, 2);
        let mut rng = Rng::new(3);
        let mut layer = AttentionLayer::init(d, h, &mut rng);
        layer.w_q.fill(0.0);
        let x = random_x(b, n, d, 4);
        let out = layer.forward(&x, b, n).unwrap();
        for i in 1..n {
            for c in 0..d {
                assert!((out[i * d + c] - out[c]).abs() < 1e-5,
                        "row {i} ch {c} differs under uniform attention");
            }
        }
    }

    #[test]
    fn model_forward_is_deterministic_and_finite() {
        let cfg = NativeVitConfig::default();
        let model = NativeCatModel::new(cfg, 42);
        let image_len = cfg.n_channels * cfg.image_size * cfg.image_size;
        let mut rng = Rng::new(5);
        let images: Vec<f32> =
            (0..2 * image_len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let a = model.forward_batch(&images, 2).unwrap();
        let b = model.forward_batch(&images, 2).unwrap();
        assert_eq!(a.len(), 2 * cfg.n_classes);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.is_finite()));
        // same seed -> same model; different seed -> different logits
        let same = NativeCatModel::new(cfg, 42).forward_batch(&images, 2)
            .unwrap();
        assert_eq!(a, same);
        let other = NativeCatModel::new(cfg, 43).forward_batch(&images, 2)
            .unwrap();
        assert_ne!(a, other);
        assert!(model.param_count() > 0);
    }

    #[test]
    fn model_fft_matches_gather_end_to_end() {
        let mut cfg = NativeVitConfig::default();
        cfg.n_layers = 1;
        let image_len = cfg.n_channels * cfg.image_size * cfg.image_size;
        let mut rng = Rng::new(11);
        let images: Vec<f32> =
            (0..image_len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let fft_logits = NativeCatModel::new(cfg, 1)
            .forward_image(&images).unwrap();
        cfg.cat_impl = CatImpl::Gather;
        let gather_logits = NativeCatModel::new(cfg, 1)
            .forward_image(&images).unwrap();
        for (a, g) in fft_logits.iter().zip(&gather_logits) {
            assert!((a - g).abs() < 1e-3, "{a} vs {g}");
        }
    }
}
