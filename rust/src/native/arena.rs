//! Bump arenas for the native hot path: zero-allocation forward passes.
//!
//! PR 1's `CatLayer::forward` allocated every intermediate (`z`, the
//! softmax stripes, the split heads, the output halves) per call. At
//! serving rates that is megabytes of malloc/free per request. This
//! module replaces those with per-thread bump arenas: one contiguous
//! `Vec<f32>` per arena that only ever grows, carved into disjoint `&mut`
//! slices per frame with `split_at_mut` — after warmup, a same-shape
//! forward performs **zero** tensor-sized heap allocation (asserted by
//! `steady_state_does_not_grow` below and the serial-path test in
//! `cat.rs`; what remains on fanned-out shapes is the pool's small
//! per-section dispatch state, see `super::pool`).
//!
//! Three arenas per thread, one per nesting level, so a frame at one
//! level can stay borrowed while an inner level opens its own:
//!
//! * **model** ([`with_model_arena`]) — `NativeCatModel::forward_batch`
//!   intermediates (patches, activations, MLP buffers);
//! * **layer** ([`with_layer_arena`]) — one mixing layer's frame
//!   (projections, softmax stripes, spectra, transposed heads);
//! * **task** ([`with_task_arena`]) — leaf scratch inside one parallel
//!   task (FFT ping-pong buffers, per-stripe spectra, attention rows).
//!   Pool workers persist ([`super::pool`]), so their task arenas warm
//!   once and are reused for every chunk they ever run.
//!
//! Strict nesting contract: model ⊃ layer ⊃ task, each level entered at
//! most once per thread at a time (the `RefCell` panics on violation
//! rather than corrupting a frame). Slices come back **unzeroed** — every
//! consumer must fully overwrite (all current users are matmul outputs,
//! transposes, or FFT outputs, which do).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Cumulative count of arena backing-store growths across all threads;
/// flat counter == allocation-free steady state.
static GROWS: AtomicU64 = AtomicU64::new(0);

/// Total arena backing-store growths so far (all threads, all arenas).
pub fn arena_grows() -> u64 {
    GROWS.load(Ordering::Relaxed)
}

/// Largest single-arena backing store ever reached, in bytes, across
/// all threads and levels. Only moves when an arena grows, so the
/// gauge (`cat_arena_high_water_bytes`) is flat at steady state.
static HIGH_WATER_BYTES: AtomicU64 = AtomicU64::new(0);

/// High-water arena size in bytes (see [`HIGH_WATER_BYTES`]).
pub fn arena_high_water_bytes() -> u64 {
    HIGH_WATER_BYTES.load(Ordering::Relaxed)
}

/// A grow-only f32 bump arena. One [`Arena::frame`] call carves the
/// backing store into disjoint mutable slices for one logical frame.
#[derive(Default)]
pub struct Arena {
    buf: Vec<f32>,
}

impl Arena {
    pub const fn new() -> Arena {
        Arena { buf: Vec::new() }
    }

    /// Current backing capacity in f32 elements.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Borrow `K` disjoint mutable slices of the given lengths, growing
    /// the backing store only if this frame is larger than any before it.
    /// Contents are unspecified (previous frame's data) — callers must
    /// fully overwrite. Heap-free at steady state: the carve-up itself
    /// allocates nothing.
    ///
    /// Alignment contract (DESIGN.md §15): every returned slice starts
    /// on a 32-byte boundary, so `simd::F32xN` loads over arena frames
    /// hit the aligned fast path at any lane width. Each requested
    /// length is carved with up-to-7-element padding after it; the
    /// padding is never handed out. The vector kernels use
    /// unaligned-tolerant loads, so this is throughput, not safety.
    pub fn frame<const K: usize>(&mut self, lens: [usize; K])
                                 -> [&mut [f32]; K] {
        // 32 bytes = 8 f32 lanes, the widest compiled-in vector tier.
        const ALIGN_F32: usize = 8;
        let pad = |len: usize| (len + ALIGN_F32 - 1) & !(ALIGN_F32 - 1);
        let total: usize =
            lens.iter().map(|&l| pad(l)).sum::<usize>() + ALIGN_F32 - 1;
        if self.buf.len() < total {
            GROWS.fetch_add(1, Ordering::Relaxed);
            self.buf.resize(total, 0.0);
            HIGH_WATER_BYTES.fetch_max(
                (total * std::mem::size_of::<f32>()) as u64,
                Ordering::Relaxed);
        }
        // Vec<f32> only guarantees 4-byte alignment; skip to the first
        // 32-byte boundary (≤ 7 elements, covered by the slack above).
        let addr = self.buf.as_ptr() as usize;
        let base = (addr.wrapping_neg() & (4 * ALIGN_F32 - 1))
            / std::mem::size_of::<f32>();
        let mut rest = &mut self.buf[base..];
        lens.map(|len| {
            let (head, tail) =
                std::mem::take(&mut rest).split_at_mut(pad(len));
            rest = tail;
            &mut head[..len]
        })
    }
}

thread_local! {
    static MODEL: RefCell<Arena> = const { RefCell::new(Arena::new()) };
    static LAYER: RefCell<Arena> = const { RefCell::new(Arena::new()) };
    static TASK: RefCell<Arena> = const { RefCell::new(Arena::new()) };
}

/// This thread's model-level arena (`NativeCatModel::forward_batch`).
pub fn with_model_arena<R>(f: impl FnOnce(&mut Arena) -> R) -> R {
    MODEL.with(|a| f(&mut a.borrow_mut()))
}

/// This thread's layer-level arena (one mixing-layer forward).
pub fn with_layer_arena<R>(f: impl FnOnce(&mut Arena) -> R) -> R {
    LAYER.with(|a| f(&mut a.borrow_mut()))
}

/// This thread's leaf task arena (kernel scratch inside parallel tasks).
pub fn with_task_arena<R>(f: impl FnOnce(&mut Arena) -> R) -> R {
    TASK.with(|a| f(&mut a.borrow_mut()))
}

/// Capacities of this thread's (model, layer, task) arenas — flat across
/// same-shape serial forwards proves the allocation-free steady state
/// without racing other threads' growth (unlike [`arena_grows`]).
pub fn thread_arena_capacities() -> (usize, usize, usize) {
    (
        MODEL.with(|a| a.borrow().capacity()),
        LAYER.with(|a| a.borrow().capacity()),
        TASK.with(|a| a.borrow().capacity()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_slices_are_disjoint_and_sized() {
        let mut arena = Arena::new();
        let [a, b, c] = arena.frame([4, 0, 7]);
        assert_eq!((a.len(), b.len(), c.len()), (4, 0, 7));
        a.fill(1.0);
        c.fill(2.0);
        assert!(a.iter().all(|&v| v == 1.0));
        assert!(c.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn frame_slices_are_32_byte_aligned() {
        let mut arena = Arena::new();
        // odd lengths force padding between slices
        let [a, b, c, d] = arena.frame([1, 5, 13, 64]);
        for (name, s) in [("a", &*a), ("b", &*b), ("c", &*c), ("d", &*d)] {
            if s.is_empty() {
                continue;
            }
            assert_eq!(s.as_ptr() as usize % 32, 0,
                       "slice {name} not 32-byte aligned");
        }
        assert_eq!((a.len(), b.len(), c.len(), d.len()), (1, 5, 13, 64));
    }

    #[test]
    fn steady_state_does_not_grow() {
        let mut arena = Arena::new();
        let _ = arena.frame([256, 512]);
        let cap = arena.capacity();
        let before = arena_grows();
        for _ in 0..100 {
            let [a, b] = arena.frame([256, 512]);
            a[0] = 1.0;
            b[511] = 2.0;
            // smaller frames reuse the same store too
            let [_c] = arena.frame([100]);
        }
        assert_eq!(arena.capacity(), cap);
        assert_eq!(arena_grows(), before,
                   "same-shape frames must not reallocate");
    }

    #[test]
    fn high_water_tracks_largest_frame() {
        // 4 MiB: larger than any arena the other unit tests build, so
        // the global max is ours even with tests running in parallel
        let mut arena = Arena::new();
        let _ = arena.frame([1 << 20]);
        let mark = arena_high_water_bytes();
        assert!(mark >= (1u64 << 22),
                "high water must cover the largest frame, got {mark}");
        for _ in 0..10 {
            let _ = arena.frame([1 << 20]);
        }
        assert!(arena_high_water_bytes() >= mark,
                "high water is monotone");
    }

    #[test]
    fn nested_levels_coexist() {
        with_layer_arena(|layer| {
            let [frame] = layer.frame([64]);
            frame.fill(3.0);
            // a task-level borrow while the layer frame is live
            with_task_arena(|task| {
                let [scratch] = task.frame([16]);
                scratch.fill(4.0);
                assert_eq!(scratch[0], 4.0);
            });
            assert_eq!(frame[0], 3.0);
        });
    }
}
