//! The mixer registry: the single source of truth for every token-mixing
//! mechanism the native backend knows how to train and serve.
//!
//! The paper's EIT framing treats CAT as one member of a family of
//! sub-quadratic mixers; this module makes that family a first-class
//! axis. One [`MixerSpec`] row per mixer carries everything the rest of
//! the codebase used to hardcode in scattered `match` statements:
//!
//! * identity — enum variant, display name, checkpoint id;
//! * accounting — the paper-style param-count formula and the
//!   complexity/memory columns of the result tables;
//! * capabilities — causal support, head separability (whether sharded
//!   serving may split it), power-of-two shape requirements.
//!
//! The per-layer schedule (CAT-Alter's odd-layer attention swap) and the
//! mechanism label ("cat_alter") also live here, so `TrainConfig`,
//! the harness, the CLI, checkpointing, and the shard planner all
//! consult one table. **Adding a mixer** means: one enum variant, one
//! `REGISTRY` row, one arm in [`train::init_params`] /
//! [`train::fwd`] / [`train::bwd`], one arm in
//! [`serve::ServeMixer`] — all in this directory (DESIGN.md §14).

pub mod kernels;
pub(crate) mod serve;
pub(crate) mod train;

use crate::Result;
use anyhow::{bail, ensure};

/// Which token-mixing mechanism a layer runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mixer {
    /// CAT via batched real FFTs — the paper's O(N log N) mechanism.
    CatFft,
    /// CAT via the naive rolled gather — the O(N²) reference.
    CatGather,
    /// Standard softmax attention — the quality/wallclock baseline.
    Attention,
    /// FNet-style parameter-free 2D Fourier mixer (real part of the
    /// token×hidden DFT), with an optional half-spectrum truncation
    /// knob (`TrainConfig::fnet_truncate`).
    Fnet,
    /// Circulant attention (ViT variant): one shared softmax row of
    /// relative-offset scores per head, applied as a circular
    /// cross-correlation — O(N log N) with attention's 3d² budget.
    Circulant,
    /// Convolution-augmented CAT (Li et al., "On the Power of
    /// Convolution Augmented Transformer"): the CAT circular
    /// cross-correlation mix plus a learnable per-channel short
    /// circular convolution ([`CONV_TAPS`] taps) over the value
    /// stripes — O(N log N) + O(N·k) with a `(d+h)d + kd` budget.
    CatConv,
}

/// Tap count `k` of the [`Mixer::CatConv`] per-channel convolution
/// branch (the short-filter regime of Li et al.; `k ≪ N`).
pub const CONV_TAPS: usize = 9;

/// One registry row: everything the harness, trainer, server, CLI, and
/// checkpoint format need to know about a mixer.
#[derive(Debug, Clone, Copy)]
pub struct MixerSpec {
    pub mixer: Mixer,
    /// CLI / spec / table name ("cat", "fnet", ...).
    pub name: &'static str,
    /// Stable id written into checkpoint config fingerprints. Ids 0–2
    /// predate the registry and are frozen by the `CATCKPT2` format;
    /// ids ≥ 3 force the versioned `CATCKPT3` fingerprint.
    pub ckpt_id: u64,
    /// Paper-style learnable-parameter formula (Tables 1–3 accounting).
    pub params_formula: &'static str,
    /// Time-complexity column of the result tables.
    pub complexity: &'static str,
    /// Memory column of the result tables.
    pub memory: &'static str,
    /// Does the mixer support causal (autoregressive) training?
    pub causal: bool,
    /// May sharded serving split this mixer head-wise? True only when a
    /// head's output depends on nothing outside that head's weight
    /// columns (the bit-exact column-slicing invariant).
    pub head_separable: bool,
    /// Does the fast path need a power-of-two token count N?
    pub needs_pow2_n: bool,
    /// Does the fast path need a power-of-two model width d?
    pub needs_pow2_d: bool,
}

/// The mixer zoo. Exactly one row per [`Mixer`] variant (pinned by a
/// test); row order is display order for `cat list` and the README.
pub const REGISTRY: &[MixerSpec] = &[
    MixerSpec {
        mixer: Mixer::CatFft,
        name: "cat",
        ckpt_id: 0,
        params_formula: "(d+h)d",
        complexity: "O(N log N)",
        memory: "O(N)",
        causal: true,
        head_separable: true,
        needs_pow2_n: true,
        needs_pow2_d: false,
    },
    MixerSpec {
        mixer: Mixer::CatGather,
        name: "cat_gather",
        ckpt_id: 1,
        params_formula: "(d+h)d",
        complexity: "O(N^2)",
        memory: "O(N^2)",
        causal: false,
        head_separable: true,
        needs_pow2_n: false,
        needs_pow2_d: false,
    },
    MixerSpec {
        mixer: Mixer::Attention,
        name: "attention",
        ckpt_id: 2,
        params_formula: "3d^2",
        complexity: "O(N^2)",
        memory: "O(N^2)",
        causal: true,
        head_separable: false,
        needs_pow2_n: false,
        needs_pow2_d: false,
    },
    MixerSpec {
        mixer: Mixer::Fnet,
        name: "fnet",
        ckpt_id: 3,
        params_formula: "0",
        complexity: "O(N log N)",
        memory: "O(N)",
        causal: false,
        head_separable: false,
        needs_pow2_n: true,
        needs_pow2_d: true,
    },
    MixerSpec {
        mixer: Mixer::Circulant,
        name: "circulant",
        ckpt_id: 4,
        params_formula: "3d^2",
        complexity: "O(N log N)",
        memory: "O(N)",
        causal: false,
        head_separable: true,
        needs_pow2_n: true,
        needs_pow2_d: false,
    },
    MixerSpec {
        mixer: Mixer::CatConv,
        name: "cat_conv",
        ckpt_id: 5,
        params_formula: "(d+h)d + kd",
        complexity: "O(N log N)",
        memory: "O(N)",
        causal: false,
        head_separable: true,
        needs_pow2_n: true,
        needs_pow2_d: false,
    },
];

impl Mixer {
    /// This mixer's registry row.
    pub fn spec(self) -> &'static MixerSpec {
        REGISTRY
            .iter()
            .find(|s| s.mixer == self)
            .expect("every Mixer variant has a REGISTRY row")
    }

    /// Display / CLI / spec name.
    pub fn name(self) -> &'static str {
        self.spec().name
    }

    /// Resolve a registry name ("cat", "fnet", ...) back to a mixer.
    pub fn parse(name: &str) -> Option<Mixer> {
        REGISTRY.iter().find(|s| s.name == name).map(|s| s.mixer)
    }
}

/// The per-layer mixer schedule: CAT-Alter (and any `*_alter` config)
/// swaps odd layers to softmax attention, even layers keep the base
/// mixer.
pub fn schedule_at(base: Mixer, alternate: bool, layer: usize) -> Mixer {
    if alternate && layer % 2 == 1 {
        Mixer::Attention
    } else {
        base
    }
}

/// Mechanism label for tables and specs ("cat", "cat_alter", ...).
pub fn mechanism_label(base: Mixer, alternate: bool) -> String {
    if alternate {
        format!("{}_alter", base.name())
    } else {
        base.name().to_string()
    }
}

/// Paper-style learnable-parameter formula for a mechanism label.
/// Registered mixers come straight from their spec; the remaining arms
/// cover schedule labels (`cat_alter` averages the two budgets per the
/// paper) and PJRT-side mechanisms that have no native mixer.
pub fn budget_formula(mech: &str) -> &'static str {
    if let Some(m) = Mixer::parse(mech) {
        return m.spec().params_formula;
    }
    match mech {
        "cat_alter" => "(2d+h/2)d",
        "cat_q" => "(n+h)d",
        "cat_v" => "(n+d)d",
        "cat_qkv" | "linear" => "3d^2",
        _ => "?",
    }
}

/// `(complexity, memory)` table columns for a mechanism label.
/// Registered mixers come from their spec (causal CAT-FFT is starred:
/// the zero-padded linear convolution doubles the transform length).
pub fn complexity_cols(mech: &str, causal: bool) -> (&'static str, &'static str) {
    if let Some(m) = Mixer::parse(mech) {
        let spec = m.spec();
        if m == Mixer::CatFft && causal {
            return ("O(N log N)*", "O(N)");
        }
        return (spec.complexity, spec.memory);
    }
    match (mech, causal) {
        ("cat_qkv", false) | ("cat_q", false) | ("cat_v", false) => {
            ("O(N log N)", "O(N)")
        }
        ("linear", _) => ("O(N)", "O(N)"),
        _ => ("O(N^2)", "O(N^2)"),
    }
}

/// Validate a `(base, alternate)` schedule against the registry's
/// capability flags for every layer: power-of-two shape requirements
/// and causal support. The single mixer-capability gate behind
/// `TrainConfig::validate`.
pub fn validate_schedule(base: Mixer, alternate: bool, n_layers: usize,
                         n_tokens: usize, d_model: usize, causal: bool)
                         -> Result<()> {
    for layer in 0..n_layers {
        let m = schedule_at(base, alternate, layer);
        let spec = m.spec();
        if spec.needs_pow2_n {
            ensure!(n_tokens.is_power_of_two(),
                    "{} training needs power-of-two N, got {n_tokens}",
                    spec.name);
        }
        if spec.needs_pow2_d {
            ensure!(d_model.is_power_of_two(),
                    "{} training needs power-of-two d_model, got {d_model}",
                    spec.name);
        }
        if causal && !spec.causal {
            bail!("causal training supports cat (zero-padded FFT) and \
                   attention mixers; '{}' has no causal form", spec.name);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [Mixer; 6] = [Mixer::CatFft, Mixer::CatGather,
                             Mixer::Attention, Mixer::Fnet,
                             Mixer::Circulant, Mixer::CatConv];

    #[test]
    fn registry_covers_every_mixer_exactly_once() {
        assert_eq!(REGISTRY.len(), ALL.len());
        for m in ALL {
            assert_eq!(REGISTRY.iter().filter(|s| s.mixer == m).count(), 1,
                       "{m:?} must have exactly one registry row");
            // name round-trips through parse
            assert_eq!(Mixer::parse(m.name()), Some(m));
        }
        // names and checkpoint ids are unique
        for (i, a) in REGISTRY.iter().enumerate() {
            for b in &REGISTRY[i + 1..] {
                assert_ne!(a.name, b.name);
                assert_ne!(a.ckpt_id, b.ckpt_id);
            }
        }
        assert_eq!(Mixer::parse("nope"), None);
    }

    #[test]
    fn every_mixer_has_a_param_formula_matching_the_paper() {
        for spec in REGISTRY {
            assert_ne!(spec.params_formula, "?",
                       "{} lacks a param-count formula", spec.name);
            assert_ne!(spec.params_formula, "",
                       "{} lacks a param-count formula", spec.name);
        }
        // the paper's Table 1-3 budgets for the pre-registry mixers
        assert_eq!(budget_formula("cat"), "(d+h)d");
        assert_eq!(budget_formula("cat_gather"), "(d+h)d");
        assert_eq!(budget_formula("attention"), "3d^2");
        assert_eq!(budget_formula("cat_alter"), "(2d+h/2)d");
        // the new zoo members
        assert_eq!(budget_formula("fnet"), "0");
        assert_eq!(budget_formula("circulant"), "3d^2");
        assert_eq!(budget_formula("cat_conv"), "(d+h)d + kd");
        // PJRT-side mechanisms keep their formulas
        assert_eq!(budget_formula("cat_q"), "(n+h)d");
        assert_eq!(budget_formula("cat_qkv"), "3d^2");
        assert_eq!(budget_formula("unknown"), "?");
    }

    #[test]
    fn complexity_columns_come_from_the_registry() {
        assert_eq!(complexity_cols("cat", false), ("O(N log N)", "O(N)"));
        assert_eq!(complexity_cols("cat", true), ("O(N log N)*", "O(N)"));
        assert_eq!(complexity_cols("cat_gather", false),
                   ("O(N^2)", "O(N^2)"));
        assert_eq!(complexity_cols("attention", true),
                   ("O(N^2)", "O(N^2)"));
        assert_eq!(complexity_cols("fnet", false), ("O(N log N)", "O(N)"));
        assert_eq!(complexity_cols("circulant", false),
                   ("O(N log N)", "O(N)"));
        assert_eq!(complexity_cols("cat_conv", false),
                   ("O(N log N)", "O(N)"));
        assert_eq!(complexity_cols("linear", true), ("O(N)", "O(N)"));
        assert_eq!(complexity_cols("cat_alter", false),
                   ("O(N^2)", "O(N^2)"));
    }

    #[test]
    fn schedule_alternates_odd_layers_to_attention() {
        for m in ALL {
            assert_eq!(schedule_at(m, false, 0), m);
            assert_eq!(schedule_at(m, false, 1), m);
            assert_eq!(schedule_at(m, true, 0), m);
            assert_eq!(schedule_at(m, true, 1), Mixer::Attention);
            assert_eq!(schedule_at(m, true, 2), m);
        }
        assert_eq!(mechanism_label(Mixer::CatFft, true), "cat_alter");
        assert_eq!(mechanism_label(Mixer::Fnet, false), "fnet");
    }

    #[test]
    fn schedule_validation_enforces_capability_flags() {
        // fnet: pow2 N and pow2 d, no causal
        assert!(validate_schedule(Mixer::Fnet, false, 2, 64, 64, false)
            .is_ok());
        assert!(validate_schedule(Mixer::Fnet, false, 2, 48, 64, false)
            .is_err());
        assert!(validate_schedule(Mixer::Fnet, false, 2, 64, 48, false)
            .is_err());
        assert!(validate_schedule(Mixer::Fnet, false, 2, 64, 64, true)
            .is_err());
        // circulant: pow2 N, non-pow2 d fine, no causal
        assert!(validate_schedule(Mixer::Circulant, false, 1, 32, 24, false)
            .is_ok());
        assert!(validate_schedule(Mixer::Circulant, false, 1, 32, 24, true)
            .is_err());
        // cat_conv: pow2 N (FFT branch), no causal form (circular taps)
        assert!(validate_schedule(Mixer::CatConv, false, 1, 32, 24, false)
            .is_ok());
        assert!(validate_schedule(Mixer::CatConv, false, 1, 48, 24, false)
            .is_err());
        assert!(validate_schedule(Mixer::CatConv, false, 1, 32, 24, true)
            .is_err());
        // the legacy rules are unchanged
        assert!(validate_schedule(Mixer::CatFft, false, 2, 48, 64, false)
            .is_err());
        assert!(validate_schedule(Mixer::CatFft, true, 2, 64, 64, true)
            .is_ok());
        assert!(validate_schedule(Mixer::CatGather, false, 1, 48, 64, true)
            .is_err());
        assert!(validate_schedule(Mixer::Attention, false, 2, 48, 48, true)
            .is_ok());
    }
}
