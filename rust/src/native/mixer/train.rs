//! Training-side mixer dispatch: parameter layout, deterministic init,
//! and the forward/backward of every registered mixer.
//!
//! This is the single `match` over [`Mixer`] on the training path.
//! Each arm obeys the determinism contract (DESIGN.md §8): parallel
//! sections write disjoint outputs with fixed-order accumulation, so
//! loss curves are bit-identical regardless of pool width.

use anyhow::{bail, ensure};

use super::super::arena;
use super::super::autograd::{
    attn_bwd_stripe_panels, attn_bwd_stripe_rows, causal_bwd_stripe,
    causal_bwd_stripe_batched, causal_fwd_stripe_batched, corr_bwd_stripe,
    corr_fwd_stripe, ensure_len, from_head_rows, from_stripes, matmul_wt,
    matmul_xt_acc, naive_backward, softmax_bwd_in_place, to_head_rows,
    to_stripes, LayerCache, TrainConfig,
};
use super::super::cat::{matmul, softmax_in_place};
use super::super::fft::split_rfft_plan;
use super::super::pool;
use super::{kernels, Mixer, CONV_TAPS};
use crate::Result;

/// Mixing-layer parameters; the variant must match
/// [`TrainConfig::mixer_at`] (see [`init_params`]).
pub(crate) enum MixerParams {
    /// Merged CAT projections: `w_a: (d, h)`, `w_v: (d, d)` — the
    /// paper's `(d+h)·d` budget.
    Cat { w_a: Vec<f32>, w_v: Vec<f32> },
    /// Q/K/V projections (`3·d²`): softmax attention and the circulant
    /// attention variant share this layout (and tensor names, so their
    /// checkpoints stay shape-compatible per mechanism).
    Qkv { w_q: Vec<f32>, w_k: Vec<f32>, w_v: Vec<f32> },
    /// Convolution-augmented CAT: the CAT projections plus tap-major
    /// `(CONV_TAPS, d)` per-channel circular-convolution filters —
    /// the `(d+h)·d + k·d` budget.
    CatConv { w_a: Vec<f32>, w_v: Vec<f32>, taps: Vec<f32> },
    /// Parameter-free mixers (FNet).
    None,
}

impl MixerParams {
    /// Same tree shape, all zeros (the gradient mirror).
    pub(crate) fn zeros_like(&self) -> MixerParams {
        let z = |v: &Vec<f32>| vec![0.0f32; v.len()];
        match self {
            MixerParams::Cat { w_a, w_v } => {
                MixerParams::Cat { w_a: z(w_a), w_v: z(w_v) }
            }
            MixerParams::Qkv { w_q, w_k, w_v } => MixerParams::Qkv {
                w_q: z(w_q),
                w_k: z(w_k),
                w_v: z(w_v),
            },
            MixerParams::CatConv { w_a, w_v, taps } => MixerParams::CatConv {
                w_a: z(w_a),
                w_v: z(w_v),
                taps: z(taps),
            },
            MixerParams::None => MixerParams::None,
        }
    }

    /// `(name, tensor, decays)` triples in the fixed visitor order the
    /// optimizer and checkpoint serializer key off.
    pub(crate) fn tensors_mut(&mut self)
                              -> Vec<(&'static str, &mut Vec<f32>, bool)> {
        match self {
            MixerParams::Cat { w_a, w_v } => {
                vec![("w_a", w_a, true), ("w_v", w_v, true)]
            }
            MixerParams::Qkv { w_q, w_k, w_v } => vec![
                ("w_q", w_q, true),
                ("w_k", w_k, true),
                ("w_v", w_v, true),
            ],
            MixerParams::CatConv { w_a, w_v, taps } => vec![
                ("w_a", w_a, true),
                ("w_v", w_v, true),
                ("taps", taps, true),
            ],
            MixerParams::None => Vec::new(),
        }
    }
}

/// Deterministic per-layer mixer init. `bmk` is the block's
/// 0.02-scaled-normal draw closure; the draw order per variant is
/// frozen (checkpoints and the serving model's same-seed equivalence
/// depend on it).
pub(crate) fn init_params(mixer: Mixer, d: usize, h: usize,
                          bmk: &mut dyn FnMut(usize) -> Vec<f32>)
                          -> MixerParams {
    match mixer {
        Mixer::CatFft | Mixer::CatGather => MixerParams::Cat {
            w_a: bmk(d * h),
            w_v: bmk(d * d),
        },
        Mixer::Attention | Mixer::Circulant => MixerParams::Qkv {
            w_q: bmk(d * d),
            w_k: bmk(d * d),
            w_v: bmk(d * d),
        },
        Mixer::CatConv => MixerParams::CatConv {
            w_a: bmk(d * h),
            w_v: bmk(d * d),
            taps: bmk(CONV_TAPS * d),
        },
        Mixer::Fnet => MixerParams::None,
    }
}

/// Mixer forward for one block: reads `lc.xn1`, fills the mixer caches,
/// writes the mixed output into `out`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fwd(cfg: &TrainConfig, layer: usize, mp: &MixerParams,
                  lc: &mut LayerCache, b: usize, tmp1: &mut [f32],
                  znh: &mut [f32], tmp2: &mut [f32], out: &mut [f32])
                  -> Result<()> {
    let d = cfg.d_model;
    let n = cfg.n_tokens();
    let h = cfg.n_heads;
    let dh = d / h;
    let bn = b * n;
    let mixer = cfg.mixer_at(layer);
    match mp {
        MixerParams::Cat { w_a, w_v } => {
            matmul(&lc.xn1, bn, d, w_a, h, znh);
            ensure_len(&mut lc.p, b * h * n);
            for bi in 0..b {
                for head in 0..h {
                    for i in 0..n {
                        lc.p[(bi * h + head) * n + i] =
                            znh[(bi * n + i) * h + head];
                    }
                }
            }
            for row in lc.p.chunks_exact_mut(n) {
                softmax_in_place(row);
            }
            matmul(&lc.xn1, bn, d, w_v, d, tmp1);
            ensure_len(&mut lc.vt, bn * d);
            to_stripes(tmp1, b, n, h, dh, &mut lc.vt);

            let p = &lc.p;
            let vt = &lc.vt;
            let log_term = n.trailing_zeros() as usize + 1;
            let tasks: Vec<(usize, &mut [f32])> =
                tmp2.chunks_mut(dh * n).enumerate().collect();
            match mixer {
                Mixer::CatFft if !cfg.causal() => {
                    let plan = split_rfft_plan(n);
                    let f = plan.spectrum_len();
                    pool::run(tasks, 8 * n * log_term * dh, |(si, os)| {
                        arena::with_task_arena(|ta| {
                            let [zre, zim, vre, vim, scratch] = ta.frame(
                                [f, f, dh * f, dh * f, plan.scratch_len()]);
                            corr_fwd_stripe(
                                &plan, &p[si * n..(si + 1) * n],
                                &vt[si * dh * n..(si + 1) * dh * n], dh,
                                os, zre, zim, vre, vim, scratch);
                        });
                    });
                }
                Mixer::CatFft => {
                    let plan2 = split_rfft_plan(2 * n);
                    let f2 = plan2.spectrum_len();
                    pool::run(tasks, 16 * n * log_term * dh, |(si, os)| {
                        arena::with_task_arena(|ta| {
                            let [pad2, out2, zre, zim, vre, vim, scratch] =
                                ta.frame([2 * n * dh, 2 * n * dh, f2, f2,
                                          dh * f2, dh * f2,
                                          plan2.scratch_len()]);
                            causal_fwd_stripe_batched(
                                &plan2, &p[si * n..(si + 1) * n],
                                &vt[si * dh * n..(si + 1) * dh * n], dh,
                                os, pad2, zre, zim, vre, vim, out2,
                                scratch);
                        });
                    });
                }
                Mixer::CatGather => {
                    pool::run(tasks, 2 * n * n * dh, |(si, os)| {
                        let prow = &p[si * n..(si + 1) * n];
                        let vs = &vt[si * dh * n..(si + 1) * dh * n];
                        for (c, orow) in os.chunks_exact_mut(n).enumerate() {
                            let vrow = &vs[c * n..(c + 1) * n];
                            for (i, o) in orow.iter_mut().enumerate() {
                                let mut acc = 0.0f32;
                                for (k, &pv) in prow.iter().enumerate() {
                                    acc += pv * vrow[(i + k) % n];
                                }
                                *o = acc;
                            }
                        }
                    });
                }
                _ => bail!("mixer/params mismatch"),
            }
            from_stripes(tmp2, b, n, h, dh, out);
        }
        MixerParams::CatConv { w_a, w_v, taps } => {
            ensure!(mixer == Mixer::CatConv, "mixer/params mismatch");
            // CAT correlation mix plus the learnable per-channel short
            // circular convolution of the value stripes (Li et al.);
            // the conv accumulates onto the correlation output inside
            // the same stripe task, ascending-tap order.
            matmul(&lc.xn1, bn, d, w_a, h, znh);
            ensure_len(&mut lc.p, b * h * n);
            for bi in 0..b {
                for head in 0..h {
                    for i in 0..n {
                        lc.p[(bi * h + head) * n + i] =
                            znh[(bi * n + i) * h + head];
                    }
                }
            }
            for row in lc.p.chunks_exact_mut(n) {
                softmax_in_place(row);
            }
            matmul(&lc.xn1, bn, d, w_v, d, tmp1);
            ensure_len(&mut lc.vt, bn * d);
            to_stripes(tmp1, b, n, h, dh, &mut lc.vt);

            let p = &lc.p;
            let vt = &lc.vt;
            let k = CONV_TAPS;
            let log_term = n.trailing_zeros() as usize + 1;
            let plan = split_rfft_plan(n);
            let f = plan.spectrum_len();
            let tasks: Vec<(usize, &mut [f32])> =
                tmp2.chunks_mut(dh * n).enumerate().collect();
            pool::run(tasks, (8 * log_term + 2 * k) * n * dh, |(si, os)| {
                arena::with_task_arena(|ta| {
                    let [zre, zim, vre, vim, scratch] = ta.frame(
                        [f, f, dh * f, dh * f, plan.scratch_len()]);
                    let vs = &vt[si * dh * n..(si + 1) * dh * n];
                    corr_fwd_stripe(&plan, &p[si * n..(si + 1) * n], vs,
                                    dh, os, zre, zim, vre, vim, scratch);
                    kernels::conv_acc_stripe(taps, k, d, (si % h) * dh,
                                             vs, dh, n, os);
                });
            });
            from_stripes(tmp2, b, n, h, dh, out);
        }
        MixerParams::Qkv { w_q, w_k, w_v } if mixer == Mixer::Attention => {
            ensure_len(&mut lc.qh, bn * d);
            ensure_len(&mut lc.kh, bn * d);
            ensure_len(&mut lc.vh, bn * d);
            ensure_len(&mut lc.aprobs, b * h * n * n);
            matmul(&lc.xn1, bn, d, w_q, d, tmp1);
            to_head_rows(tmp1, b, n, h, dh, &mut lc.qh);
            matmul(&lc.xn1, bn, d, w_k, d, tmp1);
            to_head_rows(tmp1, b, n, h, dh, &mut lc.kh);
            matmul(&lc.xn1, bn, d, w_v, d, tmp1);
            to_head_rows(tmp1, b, n, h, dh, &mut lc.vh);
            let scale = 1.0 / (dh as f32).sqrt();
            let causal = cfg.causal();
            let (qh, kh, vh) = (&lc.qh, &lc.kh, &lc.vh);
            let tasks: Vec<((usize, &mut [f32]), &mut [f32])> = tmp2
                .chunks_mut(n * dh)
                .enumerate()
                .zip(lc.aprobs.chunks_mut(n * n))
                .collect();
            pool::run(tasks, 4 * n * n * dh, |((si, os), ps)| {
                let q = &qh[si * n * dh..(si + 1) * n * dh];
                let k = &kh[si * n * dh..(si + 1) * n * dh];
                let v = &vh[si * n * dh..(si + 1) * n * dh];
                for i in 0..n {
                    let lim = if causal { i + 1 } else { n };
                    let qi = &q[i * dh..(i + 1) * dh];
                    let prow = &mut ps[i * n..(i + 1) * n];
                    for (j, slot) in prow.iter_mut().take(lim).enumerate() {
                        let kj = &k[j * dh..(j + 1) * dh];
                        let mut dot = 0.0f32;
                        for (qv, kv) in qi.iter().zip(kj) {
                            dot += qv * kv;
                        }
                        *slot = dot * scale;
                    }
                    softmax_in_place(&mut prow[..lim]);
                    prow[lim..].fill(0.0);
                    let orow = &mut os[i * dh..(i + 1) * dh];
                    orow.fill(0.0);
                    for (j, &w) in prow.iter().take(lim).enumerate() {
                        let vrow = &v[j * dh..(j + 1) * dh];
                        for (ov, &vv) in orow.iter_mut().zip(vrow) {
                            *ov += w * vv;
                        }
                    }
                }
            });
            from_head_rows(tmp2, b, n, h, dh, out);
        }
        MixerParams::Qkv { w_q, w_k, w_v } => {
            ensure!(mixer == Mixer::Circulant, "mixer/params mismatch");
            // circulant attention: one shared softmax score row per
            // stripe (channel-summed circular cross-correlation of the
            // q/k projections), applied with the CAT correlation kernel
            ensure_len(&mut lc.qt, bn * d);
            ensure_len(&mut lc.kt, bn * d);
            ensure_len(&mut lc.vt, bn * d);
            ensure_len(&mut lc.p, b * h * n);
            matmul(&lc.xn1, bn, d, w_q, d, tmp1);
            to_stripes(tmp1, b, n, h, dh, &mut lc.qt);
            matmul(&lc.xn1, bn, d, w_k, d, tmp1);
            to_stripes(tmp1, b, n, h, dh, &mut lc.kt);
            matmul(&lc.xn1, bn, d, w_v, d, tmp1);
            to_stripes(tmp1, b, n, h, dh, &mut lc.vt);
            let scale = kernels::circ_scale(dh, n);
            let (qt, kt, vt) = (&lc.qt, &lc.kt, &lc.vt);
            let plan = split_rfft_plan(n);
            let f = plan.spectrum_len();
            let log_term = n.trailing_zeros() as usize + 1;
            let tasks: Vec<((usize, &mut [f32]), &mut [f32])> = tmp2
                .chunks_mut(dh * n)
                .enumerate()
                .zip(lc.p.chunks_mut(n))
                .collect();
            pool::run(tasks, 16 * n * log_term * dh, |((si, os), prow)| {
                arena::with_task_arena(|ta| {
                    let [b1, b2, b3, b4, s1, s2, scratch] = ta.frame([
                        dh * f, dh * f, dh * f, dh * f, f, f,
                        plan.scratch_len(),
                    ]);
                    let q = &qt[si * dh * n..(si + 1) * dh * n];
                    let k = &kt[si * dh * n..(si + 1) * dh * n];
                    let v = &vt[si * dh * n..(si + 1) * dh * n];
                    kernels::circ_scores_stripe(&plan, q, k, dh, prow, b1,
                                                b2, b3, b4, s1, s2,
                                                scratch);
                    for sv in prow.iter_mut() {
                        *sv *= scale;
                    }
                    softmax_in_place(prow);
                    corr_fwd_stripe(&plan, prow, v, dh, os, s1, s2, b1, b2,
                                    scratch);
                });
            });
            from_stripes(tmp2, b, n, h, dh, out);
        }
        MixerParams::None => {
            ensure!(mixer == Mixer::Fnet, "mixer/params mismatch");
            // parameter-free 2D Fourier mix, one task per batch slab;
            // no caches: the operator is self-adjoint (kernels docs)
            let truncate = cfg.fnet_truncate;
            let xn1 = &lc.xn1;
            let log_n = n.trailing_zeros() as usize + 1;
            let log_d = d.trailing_zeros() as usize + 1;
            let tasks: Vec<(usize, &mut [f32])> =
                out[..bn * d].chunks_mut(n * d).enumerate().collect();
            pool::run(tasks, 6 * n * d * (log_n + log_d), |(bi, oslab)| {
                kernels::fnet_slab(&xn1[bi * n * d..(bi + 1) * n * d], n, d,
                                   truncate, oslab);
            });
        }
    }
    Ok(())
}

/// Mixer backward for one block: consumes the upstream gradient `dx`
/// (the mix output's gradient), accumulates mixer parameter grads into
/// `gmp`, and writes the gradient w.r.t. the mixer *input* (`lc.xn1`)
/// into `dxn`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn bwd(cfg: &TrainConfig, layer: usize, mp: &MixerParams,
                  gmp: &mut MixerParams, lc: &LayerCache, b: usize,
                  dx: &[f32], dxn: &mut [f32], tmp1: &mut [f32],
                  tmp3: &mut [f32], zs: &mut [f32], znh: &mut [f32],
                  dqh: &mut Vec<f32>, dkh: &mut Vec<f32>,
                  dvh: &mut Vec<f32>) -> Result<()> {
    let d = cfg.d_model;
    let n = cfg.n_tokens();
    let h = cfg.n_heads;
    let dh = d / h;
    let bn = b * n;
    let mixer = cfg.mixer_at(layer);
    match (mp, gmp) {
        (MixerParams::Cat { w_a, w_v },
         MixerParams::Cat { w_a: gw_a, w_v: gw_v }) => {
            to_stripes(dx, b, n, h, dh, tmp3);
            let p = &lc.p;
            let vt = &lc.vt;
            let dout_s = &*tmp3;
            let naive = naive_backward();
            let log_term = n.trailing_zeros() as usize + 1;
            let tasks: Vec<((usize, &mut [f32]), &mut [f32])> = tmp1
                .chunks_mut(dh * n)
                .enumerate()
                .zip(zs.chunks_mut(n))
                .collect();
            match mixer {
                Mixer::CatFft if !cfg.causal() => {
                    let plan = split_rfft_plan(n);
                    let f = plan.spectrum_len();
                    pool::run(tasks, 12 * n * log_term * dh,
                              |((si, dvs), dps)| {
                        arena::with_task_arena(|ta| {
                            let [zre, zim, vre, vim, gre, gim, are, aim,
                                 scratch] = ta.frame(
                                [f, f, dh * f, dh * f, dh * f, dh * f, f,
                                 f, plan.scratch_len()]);
                            corr_bwd_stripe(
                                &plan, &p[si * n..(si + 1) * n],
                                &vt[si * dh * n..(si + 1) * dh * n],
                                &dout_s[si * dh * n..(si + 1) * dh * n],
                                dh, dps, dvs, zre, zim, vre, vim, gre,
                                gim, are, aim, scratch);
                        });
                        if !naive {
                            // fused: the p row is still cache-hot
                            softmax_bwd_in_place(
                                &p[si * n..(si + 1) * n], dps);
                        }
                    });
                }
                Mixer::CatFft => {
                    let plan2 = split_rfft_plan(2 * n);
                    let f2 = plan2.spectrum_len();
                    pool::run(tasks, 24 * n * log_term * dh,
                              |((si, dvs), dps)| {
                        if naive {
                            arena::with_task_arena(|ta| {
                                let [pad, row2, zre, zim, vre, vim, gre,
                                     gim, tre, tim, are, aim, scratch] =
                                    ta.frame(
                                    [2 * n, 2 * n, f2, f2, f2, f2, f2,
                                     f2, f2, f2, f2, f2,
                                     plan2.scratch_len()]);
                                causal_bwd_stripe(
                                    &plan2, &p[si * n..(si + 1) * n],
                                    &vt[si * dh * n..(si + 1) * dh * n],
                                    &dout_s[si * dh * n..(si + 1) * dh * n],
                                    dh, dps, dvs, pad, zre, zim, vre,
                                    vim, gre, gim, tre, tim, are, aim,
                                    row2, scratch);
                            });
                        } else {
                            arena::with_task_arena(|ta| {
                                let [pad2, out2, zre, zim, vre, vim, gre,
                                     gim, are, aim, scratch] = ta.frame(
                                    [2 * n * dh, 2 * n * dh, f2, f2,
                                     dh * f2, dh * f2, dh * f2, dh * f2,
                                     f2, f2, plan2.scratch_len()]);
                                causal_bwd_stripe_batched(
                                    &plan2, &p[si * n..(si + 1) * n],
                                    &vt[si * dh * n..(si + 1) * dh * n],
                                    &dout_s[si * dh * n..(si + 1) * dh * n],
                                    dh, dps, dvs, pad2, zre, zim, vre,
                                    vim, gre, gim, are, aim, out2,
                                    scratch);
                            });
                            softmax_bwd_in_place(
                                &p[si * n..(si + 1) * n], dps);
                        }
                    });
                }
                Mixer::CatGather => {
                    pool::run(tasks, 4 * n * n * dh, |((si, dvs), dps)| {
                        let prow = &p[si * n..(si + 1) * n];
                        let vs = &vt[si * dh * n..(si + 1) * dh * n];
                        let dos = &dout_s[si * dh * n..(si + 1) * dh * n];
                        for (c, dvrow) in
                            dvs.chunks_exact_mut(n).enumerate() {
                            let dorow = &dos[c * n..(c + 1) * n];
                            for (j, slot) in dvrow.iter_mut().enumerate() {
                                let mut acc = 0.0f32;
                                for (i, &dov) in dorow.iter().enumerate() {
                                    acc += dov * prow[(j + n - i) % n];
                                }
                                *slot = acc;
                            }
                        }
                        for (kk, slot) in dps.iter_mut().enumerate() {
                            let mut acc = 0.0f32;
                            for c in 0..dh {
                                let dorow = &dos[c * n..(c + 1) * n];
                                let vrow = &vs[c * n..(c + 1) * n];
                                for (i, &dov) in dorow.iter().enumerate() {
                                    acc += dov * vrow[(i + kk) % n];
                                }
                            }
                            *slot = acc;
                        }
                        if !naive {
                            softmax_bwd_in_place(prow, dps);
                        }
                    });
                }
                _ => bail!("mixer/params mismatch"),
            }
            from_stripes(tmp1, b, n, h, dh, tmp3); // dV in (b, n, d)
            matmul_xt_acc(&lc.xn1, bn, d, tmp3, d, gw_v);
            matmul_wt(tmp3, bn, d, w_v, d, dxn, false);
            if naive {
                // reference path: separate softmax-backward sweep
                for (prow, dprow) in
                    lc.p.chunks_exact(n).zip(zs.chunks_exact_mut(n)) {
                    softmax_bwd_in_place(prow, dprow);
                }
            }
            for bi in 0..b {
                for head in 0..h {
                    for i in 0..n {
                        znh[(bi * n + i) * h + head] =
                            zs[(bi * h + head) * n + i];
                    }
                }
            }
            matmul_xt_acc(&lc.xn1, bn, d, znh, h, gw_a);
            matmul_wt(znh, bn, h, w_a, d, dxn, true);
        }
        (MixerParams::CatConv { w_a, w_v, taps },
         MixerParams::CatConv { w_a: gw_a, w_v: gw_v, taps: gtaps }) => {
            ensure!(mixer == Mixer::CatConv, "mixer/params mismatch");
            to_stripes(dx, b, n, h, dh, tmp3);
            let p = &lc.p;
            let vt = &lc.vt;
            let dout_s = &*tmp3;
            let k = CONV_TAPS;
            let naive = naive_backward();
            let log_term = n.trailing_zeros() as usize + 1;
            let plan = split_rfft_plan(n);
            let f = plan.spectrum_len();
            let tasks: Vec<((usize, &mut [f32]), &mut [f32])> = tmp1
                .chunks_mut(dh * n)
                .enumerate()
                .zip(zs.chunks_mut(n))
                .collect();
            pool::run(tasks, 12 * n * log_term * dh, |((si, dvs), dps)| {
                arena::with_task_arena(|ta| {
                    let [zre, zim, vre, vim, gre, gim, are, aim, scratch] =
                        ta.frame([f, f, dh * f, dh * f, dh * f, dh * f, f,
                                  f, plan.scratch_len()]);
                    corr_bwd_stripe(
                        &plan, &p[si * n..(si + 1) * n],
                        &vt[si * dh * n..(si + 1) * dh * n],
                        &dout_s[si * dh * n..(si + 1) * dh * n], dh, dps,
                        dvs, zre, zim, vre, vim, gre, gim, are, aim,
                        scratch);
                });
                if !naive {
                    softmax_bwd_in_place(&p[si * n..(si + 1) * n], dps);
                }
            });
            // conv branch: dv[c] += taps_c ⋆ dout[c] per stripe, and the
            // tap gradient. Stripes walk serially in ascending order so
            // the shared `gtaps` accumulation is pool-width invariant.
            for si in 0..b * h {
                kernels::conv_bwd_stripe(
                    taps, k, d, (si % h) * dh,
                    &vt[si * dh * n..(si + 1) * dh * n],
                    &dout_s[si * dh * n..(si + 1) * dh * n], dh, n,
                    &mut tmp1[si * dh * n..(si + 1) * dh * n], gtaps);
            }
            from_stripes(tmp1, b, n, h, dh, tmp3); // dV in (b, n, d)
            matmul_xt_acc(&lc.xn1, bn, d, tmp3, d, gw_v);
            matmul_wt(tmp3, bn, d, w_v, d, dxn, false);
            if naive {
                for (prow, dprow) in
                    lc.p.chunks_exact(n).zip(zs.chunks_exact_mut(n)) {
                    softmax_bwd_in_place(prow, dprow);
                }
            }
            for bi in 0..b {
                for head in 0..h {
                    for i in 0..n {
                        znh[(bi * n + i) * h + head] =
                            zs[(bi * h + head) * n + i];
                    }
                }
            }
            matmul_xt_acc(&lc.xn1, bn, d, znh, h, gw_a);
            matmul_wt(znh, bn, h, w_a, d, dxn, true);
        }
        (MixerParams::Qkv { w_q, w_k, w_v },
         MixerParams::Qkv { w_q: gw_q, w_k: gw_k, w_v: gw_v })
            if mixer == Mixer::Attention =>
        {
            to_head_rows(dx, b, n, h, dh, tmp3);
            ensure_len(dqh, bn * d);
            ensure_len(dkh, bn * d);
            ensure_len(dvh, bn * d);
            let (qh, kh, vh) = (&lc.qh, &lc.kh, &lc.vh);
            let probs = &lc.aprobs;
            let dos = &*tmp3;
            let scale = 1.0 / (dh as f32).sqrt();
            let causal = cfg.causal();
            let tasks: Vec<(((usize, &mut [f32]), &mut [f32]),
                            &mut [f32])> = dqh
                .chunks_mut(n * dh)
                .enumerate()
                .zip(dkh.chunks_mut(n * dh))
                .zip(dvh.chunks_mut(n * dh))
                .collect();
            let naive = naive_backward();
            pool::run(tasks, 6 * n * n * dh, |(((si, dqs), dks), dvs)| {
                let q = &qh[si * n * dh..(si + 1) * n * dh];
                let k = &kh[si * n * dh..(si + 1) * n * dh];
                let v = &vh[si * n * dh..(si + 1) * n * dh];
                let ps = &probs[si * n * n..(si + 1) * n * n];
                let dost = &dos[si * n * dh..(si + 1) * n * dh];
                if naive {
                    attn_bwd_stripe_rows(q, k, v, ps, dost, n, dh, scale,
                                         causal, dqs, dks, dvs);
                } else {
                    attn_bwd_stripe_panels(q, k, v, ps, dost, n, dh, scale,
                                           causal, dqs, dks, dvs);
                }
            });
            from_head_rows(dqh, b, n, h, dh, tmp1);
            matmul_xt_acc(&lc.xn1, bn, d, tmp1, d, gw_q);
            matmul_wt(tmp1, bn, d, w_q, d, dxn, false);
            from_head_rows(dkh, b, n, h, dh, tmp1);
            matmul_xt_acc(&lc.xn1, bn, d, tmp1, d, gw_k);
            matmul_wt(tmp1, bn, d, w_k, d, dxn, true);
            from_head_rows(dvh, b, n, h, dh, tmp1);
            matmul_xt_acc(&lc.xn1, bn, d, tmp1, d, gw_v);
            matmul_wt(tmp1, bn, d, w_v, d, dxn, true);
        }
        (MixerParams::Qkv { w_q, w_k, w_v },
         MixerParams::Qkv { w_q: gw_q, w_k: gw_k, w_v: gw_v }) => {
            ensure!(mixer == Mixer::Circulant, "mixer/params mismatch");
            to_stripes(dx, b, n, h, dh, tmp3);
            ensure_len(dqh, bn * d);
            ensure_len(dkh, bn * d);
            ensure_len(dvh, bn * d);
            let (p, qt, kt, vt) = (&lc.p, &lc.qt, &lc.kt, &lc.vt);
            let dout_s = &*tmp3;
            let scale = kernels::circ_scale(dh, n);
            let plan = split_rfft_plan(n);
            let f = plan.spectrum_len();
            let log_term = n.trailing_zeros() as usize + 1;
            let tasks: Vec<((((usize, &mut [f32]), &mut [f32]),
                             &mut [f32]), &mut [f32])> = dqh
                .chunks_mut(dh * n)
                .enumerate()
                .zip(dkh.chunks_mut(dh * n))
                .zip(dvh.chunks_mut(dh * n))
                .zip(zs.chunks_mut(n))
                .collect();
            pool::run(tasks, 24 * n * log_term * dh,
                      |((((si, dqs), dks), dvs), dps)| {
                arena::with_task_arena(|ta| {
                    let [s1, s2, b1, b2, b3, b4, a1, a2, scratch] =
                        ta.frame([f, f, dh * f, dh * f, dh * f, dh * f,
                                  f, f, plan.scratch_len()]);
                    let prow = &p[si * n..(si + 1) * n];
                    let q = &qt[si * dh * n..(si + 1) * dh * n];
                    let k = &kt[si * dh * n..(si + 1) * dh * n];
                    let v = &vt[si * dh * n..(si + 1) * dh * n];
                    let dos = &dout_s[si * dh * n..(si + 1) * dh * n];
                    // value/score halves reuse the CAT correlation bwd
                    corr_bwd_stripe(&plan, prow, v, dos, dh, dps, dvs, s1,
                                    s2, b1, b2, b3, b4, a1, a2, scratch);
                    softmax_bwd_in_place(prow, dps);
                    for dv in dps.iter_mut() {
                        *dv *= scale;
                    }
                    kernels::circ_scores_bwd_stripe(&plan, q, k, dps, dh,
                                                    dqs, dks, s1, s2, b1,
                                                    b2, b3, b4, scratch);
                });
            });
            from_stripes(dvh, b, n, h, dh, tmp1);
            matmul_xt_acc(&lc.xn1, bn, d, tmp1, d, gw_v);
            matmul_wt(tmp1, bn, d, w_v, d, dxn, false);
            from_stripes(dqh, b, n, h, dh, tmp1);
            matmul_xt_acc(&lc.xn1, bn, d, tmp1, d, gw_q);
            matmul_wt(tmp1, bn, d, w_q, d, dxn, true);
            from_stripes(dkh, b, n, h, dh, tmp1);
            matmul_xt_acc(&lc.xn1, bn, d, tmp1, d, gw_k);
            matmul_wt(tmp1, bn, d, w_k, d, dxn, true);
        }
        (MixerParams::None, MixerParams::None) => {
            ensure!(mixer == Mixer::Fnet, "mixer/params mismatch");
            // self-adjoint: dxn = F(mask(dx)); the mask is the
            // truncation's own backward (forward = mask ∘ F)
            let truncate = cfg.fnet_truncate;
            let dxn = &mut dxn[..bn * d];
            let src: &[f32] = if truncate {
                let masked = &mut tmp1[..bn * d];
                masked.copy_from_slice(&dx[..bn * d]);
                for row in masked.chunks_exact_mut(d) {
                    row[d / 2 + 1..].fill(0.0);
                }
                masked
            } else {
                &dx[..bn * d]
            };
            let log_n = n.trailing_zeros() as usize + 1;
            let log_d = d.trailing_zeros() as usize + 1;
            let tasks: Vec<(usize, &mut [f32])> =
                dxn.chunks_mut(n * d).enumerate().collect();
            pool::run(tasks, 6 * n * d * (log_n + log_d), |(bi, dslab)| {
                kernels::fnet_slab(&src[bi * n * d..(bi + 1) * n * d], n,
                                   d, false, dslab);
            });
        }
        _ => bail!("mixer params/grads variant mismatch"),
    }
    Ok(())
}
