//! Serving-side mixer dispatch: [`ServeMixer`] is the single `match`
//! over [`Mixer`] on the inference path. `NativeCatModel` holds one per
//! block, and sharded serving slices/strips it through the same API the
//! CAT layer always had ([`ServeMixer::head_slice`] /
//! [`ServeMixer::strip`]), so the shard planner never names a mixer.
//!
//! The circulant-attention layer ([`QkvLayer`]) is head-separable the
//! same way CAT is: each head's score row is the channel-summed circular
//! cross-correlation of that head's own q/k projections, so a column
//! slice of `W_Q`/`W_K`/`W_V` computes the matching output columns
//! bit-for-bit. FNet mixes across the full hidden axis and is therefore
//! not separable (the registry's `head_separable: false`); attention's
//! serving layer predates slicing and keeps the same flag.

use anyhow::ensure;

use super::super::arena;
use super::super::autograd::{corr_fwd_stripe, from_stripes, to_stripes};
use super::super::cat::{
    matmul, softmax_in_place, AttentionLayer, CatImpl, CatLayer,
};
use super::super::fft::split_rfft_plan;
use super::super::pool;
use super::{kernels, Mixer, CONV_TAPS};
use crate::data::Rng;
use crate::obs::trace::{self as obs_trace, Stage};
use crate::Result;

/// Q/K/V projections driving the circulant-attention serving forward.
/// Like [`CatLayer`], a *full* layer has `h·dh == d`; a head slice owns
/// a contiguous run of heads' weight columns.
#[derive(Clone)]
pub struct QkvLayer {
    /// Input dim (always the full model width, even for a slice).
    pub d: usize,
    /// Heads owned by this layer.
    pub h: usize,
    /// Channels per head (`d_model / n_heads` of the *full* layer).
    pub dh: usize,
    w_q: Vec<f32>,
    w_k: Vec<f32>,
    w_v: Vec<f32>,
}

impl QkvLayer {
    /// Deterministic init; the q→k→v draw order matches
    /// [`super::train::init_params`].
    pub fn init(d: usize, h: usize, rng: &mut Rng) -> QkvLayer {
        assert!(h > 0 && d % h == 0,
                "d ({d}) must divide into h ({h}) heads");
        let mut mk = |len: usize| -> Vec<f32> {
            (0..len).map(|_| 0.02 * rng.normal()).collect()
        };
        QkvLayer {
            d,
            h,
            dh: d / h,
            w_q: mk(d * d),
            w_k: mk(d * d),
            w_v: mk(d * d),
        }
    }

    /// Output width of this layer: `h·dh` (`== d` for a full layer).
    pub fn width(&self) -> usize {
        self.h * self.dh
    }

    /// Learnable parameters (`3·d²` for a full layer; a slice counts
    /// only its own columns).
    pub fn param_count(&self) -> usize {
        self.w_q.len() + self.w_k.len() + self.w_v.len()
    }

    /// Copy out heads `[h0, h1)` as a standalone slice layer: each
    /// projection keeps columns `h0·dh..h1·dh`. Accumulation orders are
    /// unchanged (matmuls sum over the input dim; scores, softmax and
    /// the correlation apply act per head), so the slice's output equals
    /// the matching columns of the full forward bit-exactly.
    pub fn head_slice(&self, h0: usize, h1: usize) -> QkvLayer {
        assert!(h0 < h1 && h1 <= self.h,
                "bad head slice [{h0}, {h1}) of {} heads", self.h);
        let (d, dh, w) = (self.d, self.dh, self.width());
        let hs = h1 - h0;
        let slice_cols = |src: &[f32]| -> Vec<f32> {
            let mut out = Vec::with_capacity(d * hs * dh);
            for k in 0..d {
                out.extend_from_slice(&src[k * w + h0 * dh..
                                           k * w + h1 * dh]);
            }
            out
        };
        QkvLayer {
            d,
            h: hs,
            dh,
            w_q: slice_cols(&self.w_q),
            w_k: slice_cols(&self.w_k),
            w_v: slice_cols(&self.w_v),
        }
    }

    pub(crate) fn strip(&mut self) {
        self.w_q = Vec::new();
        self.w_k = Vec::new();
        self.w_v = Vec::new();
    }

    /// Circulant-attention mix into `out: (b, n, width)` (fully
    /// overwritten): per `(batch, head)` stripe one shared softmax score
    /// row from the q/k circular cross-correlation, applied to v with
    /// the CAT correlation kernel — O(N log N).
    pub fn forward_into(&self, x: &[f32], b: usize, n: usize,
                        out: &mut [f32]) -> Result<()> {
        let (d, h) = (self.d, self.h);
        let (dh, w) = (self.dh, self.width());
        ensure!(x.len() == b * n * d,
                "x has {} elements, expected {}x{}x{}", x.len(), b, n, d);
        ensure!(out.len() == b * n * w,
                "out has {} elements, expected {}x{}x{}", out.len(), b, n,
                w);
        ensure!(self.w_q.len() == d * w && self.w_k.len() == d * w
                    && self.w_v.len() == d * w,
                "circulant mixing weights are absent — this layer was \
                 stripped (sharded serving trunk) and cannot mix tokens \
                 itself");
        ensure!(n.is_power_of_two(),
                "circulant attention needs power-of-two N, got {n}");
        let plan = split_rfft_plan(n);
        let f = plan.spectrum_len();
        let scale = kernels::circ_scale(dh, n);
        let log_term = n.trailing_zeros() as usize + 1;
        arena::with_layer_arena(|la| {
            let [proj, qt, kt, vt, ot] = la.frame([
                b * n * w, // (b·n, w) projection staging
                b * n * w, // stripe-transposed (b·h, dh, n) q
                b * n * w, // k
                b * n * w, // v
                b * n * w, // mixed stripes before the un-transpose
            ]);
            for (wm, dst) in [(&self.w_q, &mut *qt), (&self.w_k, &mut *kt),
                              (&self.w_v, &mut *vt)] {
                obs_trace::section(Stage::MixerMatmul,
                                   || matmul(x, b * n, d, wm, w, proj));
                obs_trace::section(Stage::Scatter,
                                   || to_stripes(proj, b, n, h, dh, dst));
            }
            let (qt, kt, vt) = (&*qt, &*kt, &*vt);
            obs_trace::section(Stage::Fft, || {
                let tasks: Vec<(usize, &mut [f32])> =
                    ot.chunks_mut(dh * n).enumerate().collect();
                pool::run(tasks, 16 * n * log_term * dh, |(si, os)| {
                    arena::with_task_arena(|ta| {
                        let [b1, b2, b3, b4, s1, s2, prow, scratch] =
                            ta.frame([dh * f, dh * f, dh * f, dh * f, f,
                                      f, n, plan.scratch_len()]);
                        let q = &qt[si * dh * n..(si + 1) * dh * n];
                        let k = &kt[si * dh * n..(si + 1) * dh * n];
                        let v = &vt[si * dh * n..(si + 1) * dh * n];
                        kernels::circ_scores_stripe(&plan, q, k, dh, prow,
                                                    b1, b2, b3, b4, s1,
                                                    s2, scratch);
                        for sv in prow.iter_mut() {
                            *sv *= scale;
                        }
                        softmax_in_place(prow);
                        corr_fwd_stripe(&plan, prow, v, dh, os, s1, s2,
                                        b1, b2, scratch);
                    });
                });
            });
            obs_trace::section(Stage::Gather,
                               || from_stripes(ot, b, n, h, dh, out));
        });
        Ok(())
    }
}

/// Convolution-augmented CAT serving layer: the CAT correlation mix
/// plus a learnable per-channel short circular convolution
/// ([`CONV_TAPS`] taps, tap-major `(k, width)`) of the value stripes.
/// Head-separable exactly like CAT: a head's output touches only that
/// head's `w_a` column, `w_v` columns, and taps columns, and the conv
/// accumulates per channel in ascending-tap order, so a column slice
/// reproduces the matching full-forward columns bit-exactly.
#[derive(Clone)]
pub struct CatConvLayer {
    /// Input dim (always the full model width, even for a slice).
    pub d: usize,
    /// Heads owned by this layer.
    pub h: usize,
    /// Channels per head (`d_model / n_heads` of the *full* layer).
    pub dh: usize,
    w_a: Vec<f32>,
    w_v: Vec<f32>,
    taps: Vec<f32>,
}

impl CatConvLayer {
    /// Deterministic init; the `w_a → w_v → taps` draw order matches
    /// [`super::train::init_params`].
    pub fn init(d: usize, h: usize, rng: &mut Rng) -> CatConvLayer {
        assert!(h > 0 && d % h == 0,
                "d ({d}) must divide into h ({h}) heads");
        let mut mk = |len: usize| -> Vec<f32> {
            (0..len).map(|_| 0.02 * rng.normal()).collect()
        };
        CatConvLayer {
            d,
            h,
            dh: d / h,
            w_a: mk(d * h),
            w_v: mk(d * d),
            taps: mk(CONV_TAPS * d),
        }
    }

    /// Output width of this layer: `h·dh` (`== d` for a full layer).
    pub fn width(&self) -> usize {
        self.h * self.dh
    }

    /// Learnable parameters (`(d+h)·d + k·d` for a full layer).
    pub fn param_count(&self) -> usize {
        self.w_a.len() + self.w_v.len() + self.taps.len()
    }

    /// Copy out heads `[h0, h1)` as a standalone slice layer: `w_a`
    /// keeps head columns `h0..h1`, `w_v` and the taps keep channel
    /// columns `h0·dh..h1·dh`.
    pub fn head_slice(&self, h0: usize, h1: usize) -> CatConvLayer {
        assert!(h0 < h1 && h1 <= self.h,
                "bad head slice [{h0}, {h1}) of {} heads", self.h);
        let (d, h, dh, w) = (self.d, self.h, self.dh, self.width());
        let hs = h1 - h0;
        let mut w_a = Vec::with_capacity(d * hs);
        for r in 0..d {
            w_a.extend_from_slice(&self.w_a[r * h + h0..r * h + h1]);
        }
        let slice_chans = |src: &[f32], rows: usize| -> Vec<f32> {
            let mut out = Vec::with_capacity(rows * hs * dh);
            for r in 0..rows {
                out.extend_from_slice(&src[r * w + h0 * dh..
                                           r * w + h1 * dh]);
            }
            out
        };
        CatConvLayer {
            d,
            h: hs,
            dh,
            w_a,
            w_v: slice_chans(&self.w_v, d),
            taps: slice_chans(&self.taps, CONV_TAPS),
        }
    }

    pub(crate) fn strip(&mut self) {
        self.w_a = Vec::new();
        self.w_v = Vec::new();
        self.taps = Vec::new();
    }

    /// CAT-plus-conv mix into `out: (b, n, width)` (fully overwritten):
    /// per `(batch, head)` stripe one softmax attention row applied with
    /// the CAT correlation kernel, then the per-channel tap convolution
    /// accumulated on top — O(N log N) + O(N·k).
    pub fn forward_into(&self, x: &[f32], b: usize, n: usize,
                        out: &mut [f32]) -> Result<()> {
        let (d, h) = (self.d, self.h);
        let (dh, w) = (self.dh, self.width());
        let k = CONV_TAPS;
        ensure!(x.len() == b * n * d,
                "x has {} elements, expected {}x{}x{}", x.len(), b, n, d);
        ensure!(out.len() == b * n * w,
                "out has {} elements, expected {}x{}x{}", out.len(), b, n,
                w);
        ensure!(self.w_a.len() == d * h && self.w_v.len() == d * w
                    && self.taps.len() == k * w,
                "cat_conv mixing weights are absent — this layer was \
                 stripped (sharded serving trunk) and cannot mix tokens \
                 itself");
        ensure!(n.is_power_of_two(),
                "cat_conv needs power-of-two N, got {n}");
        let plan = split_rfft_plan(n);
        let f = plan.spectrum_len();
        let log_term = n.trailing_zeros() as usize + 1;
        arena::with_layer_arena(|la| {
            let [proj_a, p, proj, vt, ot] = la.frame([
                b * n * h, // (b·n, h) attention-logit staging
                b * h * n, // stripe rows (b·h, n): softmaxed scores
                b * n * w, // (b·n, w) value projection staging
                b * n * w, // stripe-transposed (b·h, dh, n) v
                b * n * w, // mixed stripes before the un-transpose
            ]);
            obs_trace::section(Stage::MixerMatmul,
                               || matmul(x, b * n, d, &self.w_a, h,
                                         proj_a));
            for bi in 0..b {
                for head in 0..h {
                    for i in 0..n {
                        p[(bi * h + head) * n + i] =
                            proj_a[(bi * n + i) * h + head];
                    }
                }
            }
            for row in p.chunks_exact_mut(n) {
                softmax_in_place(row);
            }
            obs_trace::section(Stage::MixerMatmul,
                               || matmul(x, b * n, d, &self.w_v, w, proj));
            obs_trace::section(Stage::Scatter,
                               || to_stripes(proj, b, n, h, dh, vt));
            let (p, vt, taps) = (&*p, &*vt, &self.taps);
            obs_trace::section(Stage::Fft, || {
                let tasks: Vec<(usize, &mut [f32])> =
                    ot.chunks_mut(dh * n).enumerate().collect();
                pool::run(tasks, (8 * log_term + 2 * k) * n * dh,
                          |(si, os)| {
                    arena::with_task_arena(|ta| {
                        let [zre, zim, vre, vim, scratch] = ta.frame(
                            [f, f, dh * f, dh * f, plan.scratch_len()]);
                        let vs = &vt[si * dh * n..(si + 1) * dh * n];
                        corr_fwd_stripe(&plan, &p[si * n..(si + 1) * n],
                                        vs, dh, os, zre, zim, vre, vim,
                                        scratch);
                        kernels::conv_acc_stripe(taps, k, w,
                                                 (si % h) * dh, vs, dh,
                                                 n, os);
                    });
                });
            });
            obs_trace::section(Stage::Gather,
                               || from_stripes(ot, b, n, h, dh, out));
        });
        Ok(())
    }
}

/// One block's serving-side token mixer: the per-[`Mixer`] dispatch the
/// trunk ([`super::super::NativeCatModel`]) and the shard planner drive.
#[derive(Clone)]
pub enum ServeMixer {
    /// CAT (both the FFT and gather applies; [`CatImpl`] picks at call
    /// time, exactly as before the registry).
    Cat(CatLayer),
    /// Softmax attention (O(N²) baseline).
    Attention(AttentionLayer),
    /// Circulant attention (O(N log N), 3d² budget).
    Circulant(QkvLayer),
    /// Convolution-augmented CAT (CAT correlation + per-channel taps).
    CatConv(CatConvLayer),
    /// Parameter-free FNet Fourier mixer (width is always the full `d`).
    Fnet { d: usize },
}

impl ServeMixer {
    /// Deterministic init. For CAT configs the weight draw stream is
    /// identical to the pre-registry `CatLayer::init` call, so every
    /// `(config, seed)` model is bit-identical to before.
    pub fn init(mixer: Mixer, d: usize, h: usize, rng: &mut Rng)
                -> ServeMixer {
        match mixer {
            Mixer::CatFft | Mixer::CatGather => {
                ServeMixer::Cat(CatLayer::init(d, h, rng))
            }
            Mixer::Attention => {
                ServeMixer::Attention(AttentionLayer::init(d, h, rng))
            }
            Mixer::Circulant => {
                ServeMixer::Circulant(QkvLayer::init(d, h, rng))
            }
            Mixer::CatConv => {
                ServeMixer::CatConv(CatConvLayer::init(d, h, rng))
            }
            Mixer::Fnet => ServeMixer::Fnet { d },
        }
    }

    /// Output width: `h·dh` for separable layers (`== d` when unsliced),
    /// always `d` for FNet.
    pub fn width(&self) -> usize {
        match self {
            ServeMixer::Cat(l) => l.width(),
            ServeMixer::Attention(l) => l.d,
            ServeMixer::Circulant(l) => l.width(),
            ServeMixer::CatConv(l) => l.width(),
            ServeMixer::Fnet { d } => *d,
        }
    }

    /// Learnable parameters of this mixer.
    pub fn param_count(&self) -> usize {
        match self {
            ServeMixer::Cat(l) => l.param_count(),
            ServeMixer::Attention(l) => l.param_count(),
            ServeMixer::Circulant(l) => l.param_count(),
            ServeMixer::CatConv(l) => l.param_count(),
            ServeMixer::Fnet { .. } => 0,
        }
    }

    /// Head slice `[h0, h1)` for sharded serving. Only head-separable
    /// mixers (registry flag) support proper sub-slices; the shard
    /// planner rejects K>1 for the rest, so they only ever see the
    /// degenerate full-range slice (shards=1), which is a clone.
    pub fn head_slice(&self, h0: usize, h1: usize) -> ServeMixer {
        match self {
            ServeMixer::Cat(l) => ServeMixer::Cat(l.head_slice(h0, h1)),
            ServeMixer::Circulant(l) => {
                ServeMixer::Circulant(l.head_slice(h0, h1))
            }
            ServeMixer::CatConv(l) => {
                ServeMixer::CatConv(l.head_slice(h0, h1))
            }
            ServeMixer::Attention(l) => {
                assert!(h0 == 0 && h1 == l.h,
                        "attention serving is not head-separable; only \
                         the full-range slice exists");
                self.clone()
            }
            ServeMixer::Fnet { .. } => {
                assert!(h0 == 0,
                        "fnet is not head-separable; only the full-range \
                         slice exists");
                self.clone()
            }
        }
    }

    /// Drop the mixing weights (sharded serving trunk); parameter-free
    /// mixers have nothing to strip.
    pub(crate) fn strip(&mut self) {
        match self {
            ServeMixer::Cat(l) => l.strip(),
            ServeMixer::Attention(l) => l.strip(),
            ServeMixer::Circulant(l) => l.strip(),
            ServeMixer::CatConv(l) => l.strip(),
            ServeMixer::Fnet { .. } => {}
        }
    }

    /// Mix tokens into `out: (b, n, width)` (fully overwritten).
    /// `cat_impl` only routes the CAT variant, exactly as before.
    pub fn forward_into(&self, x: &[f32], b: usize, n: usize,
                        cat_impl: CatImpl, out: &mut [f32]) -> Result<()> {
        match self {
            ServeMixer::Cat(l) => l.forward_into(x, b, n, cat_impl, out),
            ServeMixer::Attention(l) => l.forward_into(x, b, n, out),
            ServeMixer::Circulant(l) => l.forward_into(x, b, n, out),
            ServeMixer::CatConv(l) => l.forward_into(x, b, n, out),
            ServeMixer::Fnet { d } => {
                let d = *d;
                ensure!(x.len() == b * n * d,
                        "x has {} elements, expected {}x{}x{}", x.len(),
                        b, n, d);
                ensure!(out.len() == b * n * d,
                        "out has {} elements, expected {}x{}x{}",
                        out.len(), b, n, d);
                ensure!(n.is_power_of_two() && d.is_power_of_two(),
                        "fnet needs power-of-two N and d, got N={n} \
                         d={d}");
                let log_n = n.trailing_zeros() as usize + 1;
                let log_d = d.trailing_zeros() as usize + 1;
                obs_trace::section(Stage::Fft, || {
                    let tasks: Vec<(usize, &mut [f32])> =
                        out.chunks_mut(n * d).enumerate().collect();
                    pool::run(tasks, 6 * n * d * (log_n + log_d),
                              |(bi, oslab)| {
                        kernels::fnet_slab(
                            &x[bi * n * d..(bi + 1) * n * d], n, d,
                            false, oslab);
                    });
                });
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_x(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.normal()).collect()
    }

    /// Direct O(N²) circulant-attention oracle: per-stripe naive scores,
    /// softmax, rolled gather apply.
    fn circulant_naive(layer: &QkvLayer, x: &[f32], b: usize, n: usize)
                       -> Vec<f32> {
        let (d, h, dh) = (layer.d, layer.h, layer.dh);
        let w = layer.width();
        let mut proj = vec![0.0f32; b * n * w];
        let mut qt = vec![0.0f32; b * n * w];
        let mut kt = vec![0.0f32; b * n * w];
        let mut vt = vec![0.0f32; b * n * w];
        matmul(x, b * n, d, &layer.w_q, w, &mut proj);
        to_stripes(&proj, b, n, h, dh, &mut qt);
        matmul(x, b * n, d, &layer.w_k, w, &mut proj);
        to_stripes(&proj, b, n, h, dh, &mut kt);
        matmul(x, b * n, d, &layer.w_v, w, &mut proj);
        to_stripes(&proj, b, n, h, dh, &mut vt);
        let scale = kernels::circ_scale(dh, n);
        let mut ot = vec![0.0f32; b * n * w];
        for si in 0..b * h {
            let q = &qt[si * dh * n..(si + 1) * dh * n];
            let k = &kt[si * dh * n..(si + 1) * dh * n];
            let v = &vt[si * dh * n..(si + 1) * dh * n];
            let mut s = kernels::circ_scores_naive(q, k, dh, n);
            for sv in s.iter_mut() {
                *sv *= scale;
            }
            softmax_in_place(&mut s);
            let os = &mut ot[si * dh * n..(si + 1) * dh * n];
            for c in 0..dh {
                for i in 0..n {
                    let mut acc = 0.0f32;
                    for (t, &sv) in s.iter().enumerate() {
                        acc += sv * v[c * n + (i + t) % n];
                    }
                    os[c * n + i] = acc;
                }
            }
        }
        let mut out = vec![0.0f32; b * n * w];
        from_stripes(&ot, b, n, h, dh, &mut out);
        out
    }

    #[test]
    fn circulant_serve_matches_naive_oracle() {
        let (b, n, d, h) = (2usize, 16usize, 12usize, 3usize);
        let mut rng = Rng::new(41);
        let layer = QkvLayer::init(d, h, &mut rng);
        let x = random_x(b * n * d, 43);
        let want = circulant_naive(&layer, &x, b, n);
        let mut got = vec![0.0f32; b * n * d];
        layer.forward_into(&x, b, n, &mut got).unwrap();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4, "elem {i}: {g} vs {w}");
        }
    }

    #[test]
    fn circulant_head_slice_matches_full_forward_bitwise() {
        let (b, n, d, h) = (2usize, 32usize, 24usize, 4usize);
        let dh = d / h;
        let mut rng = Rng::new(47);
        let layer = QkvLayer::init(d, h, &mut rng);
        let x = random_x(b * n * d, 53);
        let mut full = vec![0.0f32; b * n * d];
        layer.forward_into(&x, b, n, &mut full).unwrap();
        for (h0, h1) in [(0, 1), (1, 3), (2, 4), (0, 4)] {
            let slice = layer.head_slice(h0, h1);
            let ws = slice.width();
            assert_eq!(ws, (h1 - h0) * dh);
            let mut part = vec![0.0f32; b * n * ws];
            slice.forward_into(&x, b, n, &mut part).unwrap();
            for row in 0..b * n {
                assert_eq!(&part[row * ws..(row + 1) * ws],
                           &full[row * d + h0 * dh..row * d + h1 * dh],
                           "slice [{h0},{h1}) row {row} diverged");
            }
        }
    }

    /// Direct cat_conv oracle: naive CAT correlation apply plus the
    /// rolled-index conv oracle from `kernels`.
    fn cat_conv_naive(layer: &CatConvLayer, x: &[f32], b: usize, n: usize)
                      -> Vec<f32> {
        let (d, h, dh) = (layer.d, layer.h, layer.dh);
        let w = layer.width();
        let k = CONV_TAPS;
        let mut proj_a = vec![0.0f32; b * n * h];
        matmul(x, b * n, d, &layer.w_a, h, &mut proj_a);
        let mut p = vec![0.0f32; b * h * n];
        for bi in 0..b {
            for head in 0..h {
                for i in 0..n {
                    p[(bi * h + head) * n + i] =
                        proj_a[(bi * n + i) * h + head];
                }
            }
        }
        for row in p.chunks_exact_mut(n) {
            softmax_in_place(row);
        }
        let mut proj = vec![0.0f32; b * n * w];
        let mut vt = vec![0.0f32; b * n * w];
        matmul(x, b * n, d, &layer.w_v, w, &mut proj);
        to_stripes(&proj, b, n, h, dh, &mut vt);
        let mut ot = vec![0.0f32; b * n * w];
        for si in 0..b * h {
            let prow = &p[si * n..(si + 1) * n];
            let v = &vt[si * dh * n..(si + 1) * dh * n];
            let conv = kernels::conv_naive(&layer.taps, k, w,
                                           (si % h) * dh, v, dh, n);
            let os = &mut ot[si * dh * n..(si + 1) * dh * n];
            for c in 0..dh {
                for i in 0..n {
                    let mut acc = 0.0f32;
                    for (t, &pv) in prow.iter().enumerate() {
                        acc += pv * v[c * n + (i + t) % n];
                    }
                    os[c * n + i] = acc + conv[c * n + i];
                }
            }
        }
        let mut out = vec![0.0f32; b * n * w];
        from_stripes(&ot, b, n, h, dh, &mut out);
        out
    }

    #[test]
    fn cat_conv_serve_matches_naive_oracle() {
        let (b, n, d, h) = (2usize, 16usize, 12usize, 3usize);
        let mut rng = Rng::new(61);
        let layer = CatConvLayer::init(d, h, &mut rng);
        assert_eq!(layer.param_count(), d * h + d * d + CONV_TAPS * d);
        let x = random_x(b * n * d, 62);
        let want = cat_conv_naive(&layer, &x, b, n);
        let mut got = vec![0.0f32; b * n * d];
        layer.forward_into(&x, b, n, &mut got).unwrap();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4, "elem {i}: {g} vs {w}");
        }
    }

    #[test]
    fn cat_conv_head_slice_matches_full_forward_bitwise() {
        let (b, n, d, h) = (2usize, 32usize, 24usize, 4usize);
        let dh = d / h;
        let mut rng = Rng::new(67);
        let layer = CatConvLayer::init(d, h, &mut rng);
        let x = random_x(b * n * d, 71);
        let mut full = vec![0.0f32; b * n * d];
        layer.forward_into(&x, b, n, &mut full).unwrap();
        for (h0, h1) in [(0, 1), (1, 3), (2, 4), (0, 4)] {
            let slice = layer.head_slice(h0, h1);
            let ws = slice.width();
            assert_eq!(ws, (h1 - h0) * dh);
            let mut part = vec![0.0f32; b * n * ws];
            slice.forward_into(&x, b, n, &mut part).unwrap();
            for row in 0..b * n {
                assert_eq!(&part[row * ws..(row + 1) * ws],
                           &full[row * d + h0 * dh..row * d + h1 * dh],
                           "slice [{h0},{h1}) row {row} diverged");
            }
        }
    }

    #[test]
    fn stripped_cat_conv_layer_errors_cleanly() {
        let (b, n, d, h) = (1usize, 8usize, 8usize, 2usize);
        let mut layer = CatConvLayer::init(d, h, &mut Rng::new(5));
        layer.strip();
        let x = random_x(b * n * d, 6);
        let mut out = vec![0.0f32; b * n * d];
        let err = layer.forward_into(&x, b, n, &mut out).unwrap_err();
        assert!(err.to_string().contains("stripped"), "{err}");
    }

    #[test]
    fn fnet_serve_matches_naive_per_slab() {
        let (b, n, d) = (2usize, 16usize, 8usize);
        let mixer = ServeMixer::init(Mixer::Fnet, d, 2, &mut Rng::new(1));
        assert_eq!(mixer.param_count(), 0);
        let x = random_x(b * n * d, 59);
        let mut got = vec![0.0f32; b * n * d];
        mixer.forward_into(&x, b, n, CatImpl::Fft, &mut got).unwrap();
        for bi in 0..b {
            let want = kernels::fnet_naive(
                &x[bi * n * d..(bi + 1) * n * d], n, d, false);
            for (i, (g, w)) in got[bi * n * d..(bi + 1) * n * d]
                .iter().zip(&want).enumerate() {
                assert!((g - w).abs() < 1e-3,
                        "slab {bi} elem {i}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn stripped_circulant_layer_errors_cleanly() {
        let (b, n, d, h) = (1usize, 8usize, 8usize, 2usize);
        let mut rng = Rng::new(2);
        let mut layer = QkvLayer::init(d, h, &mut rng);
        layer.strip();
        let x = random_x(b * n * d, 3);
        let mut out = vec![0.0f32; b * n * d];
        let err = layer.forward_into(&x, b, n, &mut out).unwrap_err();
        assert!(err.to_string().contains("stripped"), "{err}");
    }

    #[test]
    fn fnet_serve_rejects_bad_shapes() {
        let mixer = ServeMixer::init(Mixer::Fnet, 12, 2, &mut Rng::new(4));
        let x = vec![0.0f32; 8 * 12];
        let mut out = vec![0.0f32; 8 * 12];
        assert!(mixer
            .forward_into(&x, 1, 8, CatImpl::Fft, &mut out)
            .is_err());
    }
}
