//! Fast-path kernels of the new zoo mixers, each pinned to a naive
//! oracle in the unit tests below (and to finite differences in
//! `tests/proptests.rs`).
//!
//! **FNet slab** ([`fnet_slab`]): the parameter-free 2D Fourier mixer.
//! For one batch element's `(n, d)` activation slab,
//!
//! ```text
//!   y[i, c] = s · Re( Σ_{j,e} x[j, e] · exp(-2πi·(ij/n + ce/d)) )
//!           = s · Σ_{j,e} x[j, e] · cos(2π·(ij/n + ce/d)),
//!   s = 1 / sqrt(n·d)
//! ```
//!
//! The `1/sqrt(n·d)` output scale keeps the residual stream at unit
//! order inside the pre-LN trunk (an unnormalized 2D DFT would inflate
//! it by ~sqrt(n·d)); the naive oracle and the backward use the same
//! scale. The cosine kernel is symmetric under `(i,c) ↔ (j,e)`, so the
//! operator is **self-adjoint**: the backward is the same transform
//! applied to the output gradient — no activation cache at all. The
//! optional half-spectrum truncation knob zeroes output channels
//! `c > d/2` (forward = mask∘F); by self-adjointness its backward is
//! F∘mask.
//!
//! The fast path runs entirely on split-complex real FFTs: one batched
//! hidden-axis rfft over the slab's rows, then per hidden bin a
//! token-axis rfft of the (complex) spectrum column via FFT linearity —
//! `FFT(a + ib) = FFT(a) + i·FFT(b)` — keeping every buffer a plain
//! `&mut [f32]` arena frame. Only the real part is ever materialized.
//!
//! **Circulant attention scores** ([`circ_scores_stripe`]): per
//! `(batch, head)` stripe with channel-major `(dh, n)` projections, one
//! shared relative-offset score row
//!
//! ```text
//!   s_raw[t] = Σ_c Σ_j q_c[j] · k_c[(j+t) % n]
//!            = irfft( Σ_c conj(Qf_c) ⊙ Kf_c )[t]
//! ```
//!
//! i.e. the channel-summed circular cross-correlation of q with k —
//! O(N log N) instead of attention's O(N²) score matrix. The caller
//! scales by `1/sqrt(dh·n)` (the summand-count analog of attention's
//! `1/sqrt(dh)`), softmaxes the row, and applies it with the existing
//! CAT correlation kernel. [`circ_scores_bwd_stripe`] is the exact
//! reverse: `dq_c = corr(ds, k_c)` (spectrum `conj(DSf)⊙Kf`) and
//! `dk_c = conv(ds, q_c)` (spectrum `DSf⊙Qf`).

use super::super::arena;
use super::super::fft::{split_rfft_plan, SplitRfftPlan};
use super::super::simd;

/// FNet 2D Fourier mix of one `(n, d)` slab into `out` (fully
/// overwritten). `n` and `d` must be powers of two. With `truncate`,
/// output channels `c > d/2` are zeroed (half-spectrum truncation).
/// All intermediates live in the calling thread's task arena.
pub fn fnet_slab(x: &[f32], n: usize, d: usize, truncate: bool,
                 out: &mut [f32]) {
    assert!(n.is_power_of_two() && d.is_power_of_two(),
            "fnet needs power-of-two n and d, got n={n} d={d}");
    assert_eq!(x.len(), n * d);
    assert_eq!(out.len(), n * d);
    let plan_d = split_rfft_plan(d);
    let plan_n = split_rfft_plan(n);
    let fd = plan_d.spectrum_len(); // d/2 + 1
    let fnh = plan_n.spectrum_len(); // n/2 + 1
    let scale = 1.0 / ((n * d) as f32).sqrt();
    arena::with_task_arena(|ta| {
        let [hre, him, col_a, col_b, ar, ai, br, bi, g, scratch] = ta.frame([
            n * fd,
            n * fd,
            n,
            n,
            fnh,
            fnh,
            fnh,
            fnh,
            fd * n,
            plan_d.scratch_len().max(plan_n.scratch_len()),
        ]);
        // hidden-axis spectrum H: (n, fd) — one batched rfft per slab
        plan_d.rfft_many(x, n, hre, him, scratch);
        // token-axis DFT of each hidden bin's (complex) column via
        // linearity: G[·, f] = FFT(a) + i·FFT(b). Only Re G survives;
        // the upper token half comes from Hermitian symmetry of the
        // real columns a and b.
        for f in 0..fd {
            for i in 0..n {
                col_a[i] = hre[i * fd + f];
                col_b[i] = him[i * fd + f];
            }
            plan_n.rfft(col_a, ar, ai, scratch);
            plan_n.rfft(col_b, br, bi, scratch);
            let grow = &mut g[f * n..(f + 1) * n];
            for (k, slot) in grow.iter_mut().enumerate() {
                *slot = if k <= n / 2 {
                    ar[k] - bi[k]
                } else {
                    ar[n - k] + bi[n - k]
                };
            }
        }
        // scatter Re G back to (n, d): hidden bins above d/2 mirror the
        // conjugate bin at the negated token frequency
        for i in 0..n {
            let yrow = &mut out[i * d..(i + 1) * d];
            for (c, slot) in yrow.iter_mut().enumerate() {
                *slot = if c <= d / 2 {
                    scale * g[c * n + i]
                } else if truncate {
                    0.0
                } else {
                    scale * g[(d - c) * n + (n - i) % n]
                };
            }
        }
    });
}

/// Direct O(n²·d²) FNet oracle — the definition, term by term.
pub fn fnet_naive(x: &[f32], n: usize, d: usize, truncate: bool)
                  -> Vec<f32> {
    assert_eq!(x.len(), n * d);
    let scale = 1.0 / ((n * d) as f32).sqrt();
    let mut out = vec![0.0f32; n * d];
    for i in 0..n {
        for c in 0..d {
            if truncate && c > d / 2 {
                continue;
            }
            let mut acc = 0.0f64;
            for j in 0..n {
                for e in 0..d {
                    let theta = 2.0 * std::f64::consts::PI
                        * (i as f64 * j as f64 / n as f64
                            + c as f64 * e as f64 / d as f64);
                    acc += x[j * d + e] as f64 * theta.cos();
                }
            }
            out[i * d + c] = (scale as f64 * acc) as f32;
        }
    }
    out
}

/// Score scale shared by the circulant train and serve paths:
/// `1/sqrt(dh·n)`, the summand-count analog of attention's `1/sqrt(dh)`.
pub(crate) fn circ_scale(dh: usize, n: usize) -> f32 {
    1.0 / ((dh * n) as f32).sqrt()
}

/// Circulant-attention raw score row of one stripe:
/// `s[t] = Σ_c Σ_j q_c[j]·k_c[(j+t)%n]` via the frequency domain.
/// `q`, `k`: channel-major `(dh, n)`; `s`: length `n` (overwritten).
/// Buffers: `qre/qim/kre/kim` hold `dh·f`, `acc_re/acc_im` hold `f`,
/// `scratch` holds `plan.scratch_len()`, where `f = n/2 + 1`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn circ_scores_stripe(plan: &SplitRfftPlan, q: &[f32], k: &[f32],
                                 dh: usize, s: &mut [f32],
                                 qre: &mut [f32], qim: &mut [f32],
                                 kre: &mut [f32], kim: &mut [f32],
                                 acc_re: &mut [f32], acc_im: &mut [f32],
                                 scratch: &mut [f32]) {
    let f = plan.spectrum_len();
    plan.rfft_many(q, dh, qre, qim, scratch);
    plan.rfft_many(k, dh, kre, kim, scratch);
    acc_re.fill(0.0);
    acc_im.fill(0.0);
    // fixed ascending-channel accumulation: pool-width invariant
    for c in 0..dh {
        let (qr, qi) = (&qre[c * f..(c + 1) * f], &qim[c * f..(c + 1) * f]);
        let (kr, ki) = (&kre[c * f..(c + 1) * f], &kim[c * f..(c + 1) * f]);
        simd::cmul_conj_a_acc_rows(qr, qi, kr, ki, acc_re, acc_im);
    }
    plan.irfft(acc_re, acc_im, s, scratch);
}

/// Backward of [`circ_scores_stripe`]: given `ds` (gradient w.r.t. the
/// raw score row), write `dq`, `dk` (channel-major `(dh, n)`, fully
/// overwritten). Same buffer contract as the forward plus `sre/sim`
/// of length `f` for the `ds` spectrum.
#[allow(clippy::too_many_arguments)]
pub(crate) fn circ_scores_bwd_stripe(plan: &SplitRfftPlan, q: &[f32],
                                     k: &[f32], ds: &[f32], dh: usize,
                                     dq: &mut [f32], dk: &mut [f32],
                                     sre: &mut [f32], sim: &mut [f32],
                                     qre: &mut [f32], qim: &mut [f32],
                                     kre: &mut [f32], kim: &mut [f32],
                                     scratch: &mut [f32]) {
    let f = plan.spectrum_len();
    plan.rfft(ds, sre, sim, scratch);
    plan.rfft_many(q, dh, qre, qim, scratch);
    plan.rfft_many(k, dh, kre, kim, scratch);
    for c in 0..dh {
        let (qr, qi) =
            (&mut qre[c * f..(c + 1) * f], &mut qim[c * f..(c + 1) * f]);
        let (kr, ki) =
            (&mut kre[c * f..(c + 1) * f], &mut kim[c * f..(c + 1) * f]);
        // dq_c = corr(ds, k_c): spectrum conj(DS)·K, in place over K
        simd::cmul_conj_a_rows(sre, sim, kr, ki);
        // dk_c = conv(ds, q_c): spectrum DS·Q, in place over Q
        simd::cmul_rows(sre, sim, qr, qi);
    }
    plan.irfft_many(kre, kim, dh, dq, scratch);
    plan.irfft_many(qre, qim, dh, dk, scratch);
}

/// Direct O(n²·dh) circulant-score oracle.
pub fn circ_scores_naive(q: &[f32], k: &[f32], dh: usize, n: usize)
                         -> Vec<f32> {
    assert_eq!(q.len(), dh * n);
    assert_eq!(k.len(), dh * n);
    let mut s = vec![0.0f32; n];
    for (t, slot) in s.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for c in 0..dh {
            let (qc, kc) = (&q[c * n..(c + 1) * n], &k[c * n..(c + 1) * n]);
            for (j, &qv) in qc.iter().enumerate() {
                acc += qv * kc[(j + t) % n];
            }
        }
        *slot = acc;
    }
    s
}

/// Per-channel short circular convolution of the `cat_conv` hybrid,
/// accumulated onto channel-major `(dh, n)` stripes:
///
/// ```text
///   out[c, i] += Σ_{t<k} taps[t·stride + c0 + c] · v[c, (i−t) mod n]
/// ```
///
/// `taps` is tap-major `(k, stride)` over the layer's full channel axis;
/// `c0` is this stripe's first global channel (head offset). Each tap is
/// two contiguous [`simd::axpy`] runs over the rotation's split point,
/// so the per-element op order (ascending `t` after the base value) is
/// identical between the train-stripe and serve paths.
pub fn conv_acc_stripe(taps: &[f32], k: usize, stride: usize,
                       c0: usize, v: &[f32], dh: usize, n: usize,
                       out: &mut [f32]) {
    assert_eq!(v.len(), dh * n);
    assert_eq!(out.len(), dh * n);
    for c in 0..dh {
        let vrow = &v[c * n..(c + 1) * n];
        let orow = &mut out[c * n..(c + 1) * n];
        for t in 0..k {
            let w = taps[t * stride + c0 + c];
            let r = t % n;
            simd::axpy(&mut orow[r..], &vrow[..n - r], w);
            simd::axpy(&mut orow[..r], &vrow[n - r..], w);
        }
    }
}

/// Backward of [`conv_acc_stripe`]: given `dout` (gradient w.r.t. the
/// stripe output), **accumulate** the value gradient
/// `dv[c, j] += Σ_t taps[t]·dout[c, (j+t) mod n]` and the tap gradient
/// `dtaps[t·stride + c0 + c] += Σ_i dout[c, i]·v[c, (i−t) mod n]`.
/// Callers keep the `dtaps` accumulation deterministic by walking
/// stripes serially in ascending order (pool-width invariance).
#[allow(clippy::too_many_arguments)]
pub fn conv_bwd_stripe(taps: &[f32], k: usize, stride: usize,
                       c0: usize, v: &[f32], dout: &[f32],
                       dh: usize, n: usize, dv: &mut [f32],
                       dtaps: &mut [f32]) {
    assert_eq!(v.len(), dh * n);
    assert_eq!(dout.len(), dh * n);
    assert_eq!(dv.len(), dh * n);
    for c in 0..dh {
        let vrow = &v[c * n..(c + 1) * n];
        let dorow = &dout[c * n..(c + 1) * n];
        let dvrow = &mut dv[c * n..(c + 1) * n];
        for t in 0..k {
            let w = taps[t * stride + c0 + c];
            let r = t % n;
            simd::axpy(&mut dvrow[..n - r], &dorow[r..], w);
            simd::axpy(&mut dvrow[n - r..], &dorow[..r], w);
            dtaps[t * stride + c0 + c] +=
                simd::dot(&dorow[r..], &vrow[..n - r])
                + simd::dot(&dorow[..r], &vrow[n - r..]);
        }
    }
}

/// Direct O(dh·k·n) rolled-index oracle of [`conv_acc_stripe`].
pub fn conv_naive(taps: &[f32], k: usize, stride: usize, c0: usize,
                  v: &[f32], dh: usize, n: usize) -> Vec<f32> {
    assert_eq!(v.len(), dh * n);
    let mut out = vec![0.0f32; dh * n];
    for c in 0..dh {
        for i in 0..n {
            let mut acc = 0.0f32;
            for t in 0..k {
                acc += taps[t * stride + c0 + c]
                    * v[c * n + (i + n - t % n) % n];
            }
            out[c * n + i] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    fn randv(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.normal()).collect()
    }

    #[test]
    fn fnet_fast_path_matches_naive_dft() {
        for (n, d, seed) in [(8usize, 8usize, 1u64), (16, 8, 2), (8, 16, 3),
                             (16, 32, 4), (4, 2, 5)] {
            let x = randv(n * d, seed);
            for truncate in [false, true] {
                let want = fnet_naive(&x, n, d, truncate);
                let mut got = vec![0.0f32; n * d];
                fnet_slab(&x, n, d, truncate, &mut got);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert!((g - w).abs() < 1e-3,
                            "n={n} d={d} trunc={truncate} elem {i}: \
                             {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn fnet_is_self_adjoint() {
        // <F(x), y> == <x, F(y)>: the property the backward relies on
        let (n, d) = (16usize, 8usize);
        let x = randv(n * d, 7);
        let y = randv(n * d, 8);
        let mut fx = vec![0.0f32; n * d];
        let mut fy = vec![0.0f32; n * d];
        fnet_slab(&x, n, d, false, &mut fx);
        fnet_slab(&y, n, d, false, &mut fy);
        let a: f64 = fx.iter().zip(&y).map(|(&u, &v)| (u * v) as f64).sum();
        let b: f64 = x.iter().zip(&fy).map(|(&u, &v)| (u * v) as f64).sum();
        assert!((a - b).abs() < 1e-3 * a.abs().max(b.abs()).max(1.0),
                "<Fx,y>={a} vs <x,Fy>={b}");
    }

    #[test]
    fn fnet_rejects_non_power_of_two() {
        let x = vec![0.0f32; 12 * 8];
        let mut out = vec![0.0f32; 12 * 8];
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || fnet_slab(&x, 12, 8, false, &mut out)));
        assert!(res.is_err());
    }

    #[test]
    fn circ_scores_match_naive() {
        let (n, dh) = (16usize, 3usize);
        let plan = split_rfft_plan(n);
        let f = plan.spectrum_len();
        let q = randv(dh * n, 11);
        let k = randv(dh * n, 12);
        let want = circ_scores_naive(&q, &k, dh, n);
        let mut s = vec![0.0f32; n];
        let mut qre = vec![0.0f32; dh * f];
        let mut qim = vec![0.0f32; dh * f];
        let mut kre = vec![0.0f32; dh * f];
        let mut kim = vec![0.0f32; dh * f];
        let mut are = vec![0.0f32; f];
        let mut aim = vec![0.0f32; f];
        let mut scratch = vec![0.0f32; plan.scratch_len()];
        circ_scores_stripe(&plan, &q, &k, dh, &mut s, &mut qre, &mut qim,
                           &mut kre, &mut kim, &mut are, &mut aim,
                           &mut scratch);
        for (t, (g, w)) in s.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-3, "t={t}: {g} vs {w}");
        }
    }

    #[test]
    fn circ_scores_backward_matches_direct_adjoint() {
        // dq_c[j] = Σ_t ds[t]·k_c[(j+t)%n]; dk_c[m] = Σ_t ds[t]·q_c[(m-t)%n]
        let (n, dh) = (8usize, 2usize);
        let plan = split_rfft_plan(n);
        let f = plan.spectrum_len();
        let q = randv(dh * n, 21);
        let k = randv(dh * n, 22);
        let ds = randv(n, 23);
        let mut dq = vec![0.0f32; dh * n];
        let mut dk = vec![0.0f32; dh * n];
        let mut sre = vec![0.0f32; f];
        let mut sim = vec![0.0f32; f];
        let mut qre = vec![0.0f32; dh * f];
        let mut qim = vec![0.0f32; dh * f];
        let mut kre = vec![0.0f32; dh * f];
        let mut kim = vec![0.0f32; dh * f];
        let mut scratch = vec![0.0f32; plan.scratch_len()];
        circ_scores_bwd_stripe(&plan, &q, &k, &ds, dh, &mut dq, &mut dk,
                               &mut sre, &mut sim, &mut qre, &mut qim,
                               &mut kre, &mut kim, &mut scratch);
        for c in 0..dh {
            for j in 0..n {
                let mut want_q = 0.0f32;
                let mut want_k = 0.0f32;
                for (t, &dv) in ds.iter().enumerate() {
                    want_q += dv * k[c * n + (j + t) % n];
                    want_k += dv * q[c * n + (j + n - t % n) % n];
                }
                assert!((dq[c * n + j] - want_q).abs() < 1e-4,
                        "dq c={c} j={j}: {} vs {want_q}", dq[c * n + j]);
                assert!((dk[c * n + j] - want_k).abs() < 1e-4,
                        "dk c={c} j={j}: {} vs {want_k}", dk[c * n + j]);
            }
        }
    }

    #[test]
    fn conv_stripe_matches_naive_oracle() {
        // k > n exercises the t % n rotation wrap of short rows
        for (dh, n, k, c0, stride) in [(3usize, 16usize, 9usize, 0usize,
                                        3usize),
                                       (2, 8, 9, 2, 6), (1, 4, 9, 0, 1),
                                       (2, 16, 3, 4, 8)] {
            let taps = randv(k * stride, 31);
            let v = randv(dh * n, 32);
            let want = conv_naive(&taps, k, stride, c0, &v, dh, n);
            let mut got = vec![0.0f32; dh * n];
            conv_acc_stripe(&taps, k, stride, c0, &v, dh, n, &mut got);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!((g - w).abs() < 1e-4,
                        "dh={dh} n={n} k={k} elem {i}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn conv_backward_matches_direct_adjoint() {
        let (dh, n, k, stride, c0) = (2usize, 16usize, 9usize, 4usize,
                                      1usize);
        let taps = randv(k * stride, 41);
        let v = randv(dh * n, 42);
        let dout = randv(dh * n, 43);
        let mut dv = vec![0.0f32; dh * n];
        let mut dtaps = vec![0.0f32; k * stride];
        conv_bwd_stripe(&taps, k, stride, c0, &v, &dout, dh, n, &mut dv,
                        &mut dtaps);
        for c in 0..dh {
            for j in 0..n {
                let mut want = 0.0f32;
                for t in 0..k {
                    want += taps[t * stride + c0 + c]
                        * dout[c * n + (j + t) % n];
                }
                assert!((dv[c * n + j] - want).abs() < 1e-4,
                        "dv c={c} j={j}: {} vs {want}", dv[c * n + j]);
            }
            for t in 0..k {
                let mut want = 0.0f32;
                for i in 0..n {
                    want += dout[c * n + i] * v[c * n + (i + n - t % n) % n];
                }
                let got = dtaps[t * stride + c0 + c];
                assert!((got - want).abs() < 1e-3,
                        "dtaps c={c} t={t}: {got} vs {want}");
            }
        }
    }
}
