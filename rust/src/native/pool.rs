//! Persistent worker pool for the native hot path.
//!
//! PR 1 parallelized every forward with `std::thread::scope`, which spawns
//! (and joins, and frees) one OS thread per worker per parallel section —
//! fine for a one-shot bench, hostile to serving throughput where a single
//! request crosses several parallel sections (two projections, the FFT
//! stripe sweep, the merge). This module replaces all of that with one
//! lazily-started global pool:
//!
//! * workers are spawned **once** ([`Pool::global`]) and live for the
//!   process — steady-state serving spawns zero threads (asserted via
//!   [`stats`] in `benches/coordinator.rs` and `tests/native_backend.rs`);
//! * a parallel section chops its task list into contiguous chunks (one
//!   per worker plus one for the caller, which participates instead of
//!   idling) and feeds them through the shared task channel — workers
//!   grab whatever chunk comes off the queue next, so load balances
//!   across concurrent sections work-stealing-ishly;
//! * per-worker scratch lives in the thread-local arenas of
//!   [`super::arena`], which persist across jobs precisely because the
//!   threads do.
//!
//! Scoped borrows: [`run`] erases task lifetimes to feed the 'static job
//! queue, then blocks on a latch until every chunk has finished (normal
//! return *or* unwind), which is exactly the guarantee that made
//! `thread::scope` sound. A section issued from inside a pool worker runs
//! inline — workers never wait on workers, so the pool cannot deadlock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Below this estimated per-section FLOP count a section runs inline:
/// channel + wakeup latency would dominate (important for the small-N
/// crossover measurements and single-image serving).
const PAR_THRESHOLD: usize = 1 << 20;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    available: Condvar,
    /// Dedicated pools flip this on drop so their workers exit; the
    /// global pool's queue never closes.
    closed: AtomicBool,
}

static THREADS_SPAWNED: AtomicU64 = AtomicU64::new(0);
static DEDICATED_THREADS_SPAWNED: AtomicU64 = AtomicU64::new(0);
static CHUNKS_EXECUTED: AtomicU64 = AtomicU64::new(0);
static PAR_SECTIONS: AtomicU64 = AtomicU64::new(0);
static INLINE_SECTIONS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Set inside pool workers; sections issued from a worker run inline.
    static IS_POOL_WORKER: std::cell::Cell<bool> = const {
        std::cell::Cell::new(false)
    };
    /// Per-thread override: force every section issued from this thread
    /// to run inline (see [`set_force_inline`]).
    static FORCE_INLINE: std::cell::Cell<bool> = const {
        std::cell::Cell::new(false)
    };
    /// Per-thread pool override: sections issued from this thread fan out
    /// over this pool instead of the global one (see [`set_thread_pool`]).
    static CURRENT_POOL: std::cell::RefCell<Option<Arc<Pool>>> = const {
        std::cell::RefCell::new(None)
    };
}

/// Route every parallel section issued from the *calling thread* to
/// `pool` (or back to the global pool with `None`). The sharded serving
/// path installs one dedicated pool per model shard on that shard's
/// dispatch thread, so concurrent shards never contend for the same
/// worker queue (`coordinator::shard`). Thread-local on purpose, like
/// [`set_force_inline`].
pub fn set_thread_pool(pool: Option<Arc<Pool>>) {
    CURRENT_POOL.with(|p| *p.borrow_mut() = pool);
}

/// Force (or stop forcing) every parallel section issued from the
/// *calling thread* to run inline, pool untouched. Thread-local on
/// purpose: the determinism tests in `tests/native_backend.rs` compare a
/// pool-width-1 run against a fanned-out run from different test threads
/// without perturbing unrelated tests in the same process.
pub fn set_force_inline(on: bool) {
    FORCE_INLINE.with(|f| f.set(on));
}

/// Cumulative pool counters ([`stats`]). `threads_spawned` moves only
/// while the pool is warming up — the serving benches assert it is flat
/// across steady-state requests.
#[derive(Debug, Clone, Copy)]
pub struct PoolStats {
    /// Worker threads the global pool runs (0 until first use).
    pub workers: usize,
    /// OS threads ever spawned by the *global* pool (== `workers` after
    /// warmup).
    pub threads_spawned: u64,
    /// OS threads ever spawned by dedicated pools ([`Pool::dedicated`]).
    /// Moves only while a dedicated pool is being constructed (server /
    /// shard startup) — steady-state serving keeps it flat.
    pub dedicated_threads_spawned: u64,
    /// Task chunks executed on pool workers.
    pub chunks_executed: u64,
    /// Parallel sections that engaged the pool.
    pub par_sections: u64,
    /// Sections that ran inline (tiny work, lone task, or nested).
    pub inline_sections: u64,
}

/// Snapshot the pool counters without forcing pool startup.
pub fn stats() -> PoolStats {
    PoolStats {
        workers: POOL.get().map_or(0, |p| p.workers),
        threads_spawned: THREADS_SPAWNED.load(Ordering::Relaxed),
        dedicated_threads_spawned:
            DEDICATED_THREADS_SPAWNED.load(Ordering::Relaxed),
        chunks_executed: CHUNKS_EXECUTED.load(Ordering::Relaxed),
        par_sections: PAR_SECTIONS.load(Ordering::Relaxed),
        inline_sections: INLINE_SECTIONS.load(Ordering::Relaxed),
    }
}

/// Upper bound on concurrent chunks one section should produce (pool
/// workers + the participating caller). Chunk-count sizing for `matmul`
/// and the CAT stripe sweep; honours the calling thread's dedicated-pool
/// override so a shard sizes its sections to its own pool.
pub fn max_parallel_tasks() -> usize {
    let dedicated =
        CURRENT_POOL.with(|p| p.borrow().as_ref().map(|p| p.workers));
    match dedicated {
        Some(w) => w + 1,
        None => hardware_workers() + 1,
    }
}

/// Worker-thread budget the global pool uses (capped hardware
/// parallelism); dedicated pools size themselves against this.
pub fn hardware_workers() -> usize {
    static WORKERS: OnceLock<usize> = OnceLock::new();
    // effectively immutable for the process; cache to keep the per-section
    // gate check syscall-free on the hot path
    *WORKERS.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(1)
            .min(16)
    })
}

/// Completion latch for one parallel section. Counted down by every
/// chunk's drop guard, so unwinding chunks still release the caller.
/// Shared with `coordinator::shard`, whose scatter/gather dispatch uses
/// the same erase-then-wait discipline.
pub(crate) struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    pub(crate) fn new(count: usize) -> Latch {
        Latch {
            remaining: Mutex::new(count),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().expect("latch poisoned");
        *r -= 1;
        if *r == 0 {
            self.done.notify_all();
        }
    }

    pub(crate) fn wait(&self) {
        let mut r = self.remaining.lock().expect("latch poisoned");
        while *r > 0 {
            r = self.done.wait(r).expect("latch poisoned");
        }
    }

    /// Did any guarded chunk unwind? Valid after [`Latch::wait`] returns.
    pub(crate) fn panicked(&self) -> bool {
        self.panicked.load(Ordering::Relaxed)
    }
}

/// Fires `count_down` on normal completion and on unwind; records the
/// panic so the caller can re-raise after `wait`.
pub(crate) struct CountGuard(Arc<Latch>);

impl CountGuard {
    pub(crate) fn new(latch: Arc<Latch>) -> CountGuard {
        CountGuard(latch)
    }
}

impl Drop for CountGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.panicked.store(true, Ordering::Relaxed);
        }
        self.0.count_down();
    }
}

/// The process-wide pool. Obtain through [`Pool::global`].
pub struct Pool {
    queue: Arc<Queue>,
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

impl Pool {
    /// The lazily-started global pool; first call spawns the workers.
    pub fn global() -> &'static Pool {
        POOL.get_or_init(|| {
            let workers = hardware_workers();
            let queue = Arc::new(Queue {
                jobs: Mutex::new(VecDeque::new()),
                available: Condvar::new(),
                closed: AtomicBool::new(false),
            });
            for _ in 0..workers {
                let q = queue.clone();
                THREADS_SPAWNED.fetch_add(1, Ordering::Relaxed);
                std::thread::spawn(move || worker_loop(&q));
            }
            Pool { queue, workers }
        })
    }

    /// A dedicated pool with its own workers and task queue, independent
    /// of the global one — the per-shard compute substrate for sharded
    /// serving. Spawned **once** at construction (startup, not request
    /// time; tracked by `dedicated_threads_spawned` in [`stats`]); the
    /// workers exit when the last `Arc` drops. Install it on a thread
    /// with [`set_thread_pool`] to route that thread's sections here.
    pub fn dedicated(workers: usize) -> Arc<Pool> {
        let workers = workers.max(1);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            closed: AtomicBool::new(false),
        });
        for _ in 0..workers {
            let q = queue.clone();
            DEDICATED_THREADS_SPAWNED.fetch_add(1, Ordering::Relaxed);
            std::thread::spawn(move || worker_loop(&q));
        }
        Arc::new(Pool { queue, workers })
    }

    /// Worker threads this pool runs (excluding the participating caller).
    pub fn worker_count(&self) -> usize {
        self.workers
    }

    fn enqueue(&self, job: Job) {
        self.queue.jobs.lock().expect("pool queue poisoned").push_back(job);
        self.queue.available.notify_one();
    }

    /// Run `f` over every task, fanning contiguous chunks across the
    /// workers while the caller executes the first chunk itself. Returns
    /// only after every task has completed; panics from worker chunks are
    /// re-raised here.
    pub fn run_scoped<'scope, T, F>(&self, tasks: Vec<T>, f: &'scope F)
    where
        T: Send + 'scope,
        F: Fn(T) + Sync + 'scope,
    {
        let len = tasks.len();
        let chunks = (self.workers + 1).min(len);
        if chunks <= 1 {
            INLINE_SECTIONS.fetch_add(1, Ordering::Relaxed);
            for t in tasks {
                f(t);
            }
            return;
        }
        PAR_SECTIONS.fetch_add(1, Ordering::Relaxed);
        let mut iter = tasks.into_iter();
        let mut own: Option<Vec<T>> = None;
        let latch = Arc::new(Latch::new(chunks - 1));
        for ci in 0..chunks {
            let take = len / chunks + usize::from(ci < len % chunks);
            let bucket: Vec<T> = iter.by_ref().take(take).collect();
            if ci == 0 {
                own = Some(bucket);
                continue;
            }
            let guard_latch = latch.clone();
            let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                let _guard = CountGuard(guard_latch);
                for t in bucket {
                    f(t);
                }
            });
            // SAFETY: the latch below blocks this call until every queued
            // chunk has run to completion or unwound (CountGuard fires in
            // both cases), so nothing borrowed for 'scope survives past
            // this stack frame even though the queue holds the job as
            // 'static. Tasks and closure state are Send; the queue moves
            // them to exactly one worker.
            let job: Job = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'scope>,
                    Job,
                >(job)
            };
            self.enqueue(job);
        }
        // the caller's own chunk must not unwind past the latch: queued
        // chunks still borrow this frame until the wait completes
        let own_result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                for t in own.expect("caller chunk") {
                    f(t);
                }
            }));
        latch.wait();
        if let Err(payload) = own_result {
            std::panic::resume_unwind(payload);
        }
        if latch.panicked.load(Ordering::Relaxed) {
            panic!("pool worker chunk panicked");
        }
    }
}

/// Dropping the last handle to a *dedicated* pool closes its queue so
/// the workers exit instead of parking forever (the global pool lives in
/// a `OnceLock` and is never dropped, so its queue never closes). Any
/// queued job still runs first: `run_scoped` waits on its latch before
/// returning, so a closing queue is always already drained of live
/// borrows.
impl Drop for Pool {
    fn drop(&mut self) {
        self.queue.closed.store(true, Ordering::SeqCst);
        self.queue.available.notify_all();
    }
}

fn worker_loop(queue: &Queue) {
    IS_POOL_WORKER.with(|w| w.set(true));
    loop {
        let job = {
            let mut jobs = queue.jobs.lock().expect("pool queue poisoned");
            loop {
                if let Some(job) = jobs.pop_front() {
                    break job;
                }
                if queue.closed.load(Ordering::SeqCst) {
                    return;
                }
                jobs = queue.available.wait(jobs).expect("pool queue");
            }
        };
        CHUNKS_EXECUTED.fetch_add(1, Ordering::Relaxed);
        // keep the worker alive across panicking chunks; the section's
        // CountGuard has already flagged the latch
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
    }
}

/// Parallel-for over `tasks`: the section entry point the native layers
/// use. Tiny sections (under [`PAR_THRESHOLD`] estimated FLOPs), lone
/// tasks, and sections issued from inside a pool worker run inline on the
/// caller; everything else fans out through the calling thread's
/// dedicated pool ([`set_thread_pool`]) when one is installed, else
/// [`Pool::global`].
pub fn run<'scope, T, F>(tasks: Vec<T>, est_flops_per_task: usize, f: F)
where
    T: Send + 'scope,
    F: Fn(T) + Sync + 'scope,
{
    let total = tasks.len().saturating_mul(est_flops_per_task);
    let nested = IS_POOL_WORKER.with(|w| w.get());
    let forced = FORCE_INLINE.with(|f| f.get());
    if tasks.len() <= 1 || total < PAR_THRESHOLD || nested || forced {
        INLINE_SECTIONS.fetch_add(1, Ordering::Relaxed);
        for t in tasks {
            f(t);
        }
        return;
    }
    let dedicated = CURRENT_POOL.with(|p| p.borrow().clone());
    match dedicated {
        Some(pool) => pool.run_scoped(tasks, &f),
        None if hardware_workers() <= 1 => {
            INLINE_SECTIONS.fetch_add(1, Ordering::Relaxed);
            for t in tasks {
                f(t);
            }
        }
        None => Pool::global().run_scoped(tasks, &f),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn run_executes_every_task_exactly_once() {
        let n = 512usize;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0))
            .collect();
        let tasks: Vec<usize> = (0..n).collect();
        run(tasks, PAR_THRESHOLD, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn scoped_borrows_are_written_disjointly() {
        let mut out = vec![0u64; 1024];
        let tasks: Vec<(usize, &mut [u64])> =
            out.chunks_mut(64).enumerate().collect();
        run(tasks, PAR_THRESHOLD, |(ci, chunk)| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = (ci * 64 + i) as u64;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64);
        }
    }

    #[test]
    fn small_sections_run_inline_without_touching_the_pool() {
        let before = stats().inline_sections;
        let acc = std::sync::atomic::AtomicU64::new(0);
        // single task => inline regardless of estimate
        run(vec![7u64], usize::MAX, |v| {
            acc.fetch_add(v, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), 7);
        assert!(stats().inline_sections > before);
    }

    #[test]
    fn pool_spawns_threads_once() {
        if hardware_workers() <= 1 {
            // single-core machine: every section runs inline by design
            // and the pool never starts, so there is nothing to assert
            eprintln!("single core: pool stays cold, skipping");
            return;
        }
        // force startup, then hammer sections: spawn counter must be flat
        let tasks: Vec<usize> = (0..64).collect();
        run(tasks, PAR_THRESHOLD, |_| {});
        let spawned = stats().threads_spawned;
        assert!(spawned > 0, "pool never started");
        for _ in 0..32 {
            let tasks: Vec<usize> = (0..64).collect();
            run(tasks, PAR_THRESHOLD, |_| {});
        }
        assert_eq!(stats().threads_spawned, spawned,
                   "steady-state sections spawned new threads");
        assert_eq!(stats().workers as u64, spawned);
    }

    #[test]
    fn dedicated_pool_runs_sections_then_shuts_down() {
        // NOTE: the global dedicated-spawn counter is process-wide and
        // other tests construct dedicated pools concurrently, so only
        // monotonicity is asserted against it — exact accounting is
        // pinned per-instance by `coordinator::shard`'s tests.
        let before = stats().dedicated_threads_spawned;
        let pool = Pool::dedicated(2);
        assert_eq!(pool.worker_count(), 2);
        assert!(stats().dedicated_threads_spawned >= before + 2,
                "dedicated workers spawn at construction");
        set_thread_pool(Some(pool.clone()));
        // while the override is installed, section sizing follows the
        // dedicated pool, not the machine
        assert_eq!(max_parallel_tasks(), 3);
        let mut out = vec![0usize; 256];
        let tasks: Vec<(usize, &mut [usize])> =
            out.chunks_mut(16).enumerate().collect();
        run(tasks, PAR_THRESHOLD, |(ci, chunk)| {
            chunk.fill(ci);
        });
        set_thread_pool(None);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i / 16, "element {i}");
        }
        // dropping the last handle closes the queue; the workers exit on
        // their own (nothing to join — just must not wedge the process)
        drop(pool);
    }

    #[test]
    fn worker_panic_propagates_to_caller_and_pool_survives() {
        let result = std::panic::catch_unwind(|| {
            let tasks: Vec<usize> = (0..64).collect();
            run(tasks, PAR_THRESHOLD, |i| {
                assert!(i != 63, "deliberate task failure");
            });
        });
        assert!(result.is_err(), "panic in a chunk must reach the caller");
        // pool still functional afterwards
        let mut out = vec![0usize; 128];
        let tasks: Vec<(usize, &mut [usize])> =
            out.chunks_mut(16).enumerate().collect();
        run(tasks, PAR_THRESHOLD, |(ci, chunk)| {
            chunk.fill(ci);
        });
        assert_eq!(out[127], 7);
    }
}
