//! AdamW for the native training subsystem (decoupled weight decay,
//! Loshchilov & Hutter), with global-norm gradient clipping — the
//! paper's training recipe (Sec. 5.2), host-side.
//!
//! State layout (DESIGN.md §8): one flat `m` and one flat `v` moment
//! vector, laid out by concatenating the model's tensors in the fixed
//! [`TrainModel::opt_tensors`] visitor order. The optimizer never learns
//! the model's structure — it walks the `(param, grad, decays)` pairs the
//! model hands it, and the order is the contract. Everything here is
//! serial and fixed-order, so updates are bit-deterministic.
//!
//! [`TrainModel::opt_tensors`]: super::autograd::TrainModel::opt_tensors

use crate::Result;
use anyhow::ensure;

/// AdamW with warmup-friendly bias correction and global-norm clipping.
pub struct AdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Decoupled weight-decay coefficient; applied only to tensors whose
    /// `decays` flag is set (matrices — not biases, norms or positions).
    pub weight_decay: f32,
    /// Global-norm clip threshold (0 disables clipping).
    pub clip: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    step: u64,
}

impl AdamW {
    /// Paper-recipe defaults: β=(0.9, 0.999), ε=1e-8, wd=0.01, clip=1.0.
    pub fn new() -> AdamW {
        AdamW {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            clip: 1.0,
            m: Vec::new(),
            v: Vec::new(),
            step: 0,
        }
    }

    /// Steps taken so far.
    pub fn steps(&self) -> u64 {
        self.step
    }

    /// Optimizer-state snapshot for checkpointing: `(step, m, v)` in the
    /// flat visitor-order layout (empty before the first step).
    pub fn state(&self) -> (u64, &[f32], &[f32]) {
        (self.step, &self.m, &self.v)
    }

    /// Restore a snapshot captured by [`Self::state`]. The moment
    /// vectors must agree with each other; the next [`Self::step`] call
    /// still validates them against the model's parameter count.
    pub fn restore(&mut self, step: u64, m: Vec<f32>, v: Vec<f32>)
                   -> Result<()> {
        ensure!(m.len() == v.len(),
                "moment vectors disagree: m {} vs v {}", m.len(), v.len());
        self.step = step;
        self.m = m;
        self.v = v;
        Ok(())
    }

    /// One update over `(param, grad, decays)` tensors in the model's
    /// fixed visitor order. Returns the pre-clip global gradient norm.
    /// The first call sizes the moment vectors; later calls must pass
    /// the same total parameter count.
    pub fn step(&mut self, lr: f32,
                tensors: &mut [(&mut Vec<f32>, &mut Vec<f32>, bool)])
                -> Result<f32> {
        let total: usize = tensors.iter().map(|(p, _, _)| p.len()).sum();
        if self.m.is_empty() {
            self.m = vec![0.0; total];
            self.v = vec![0.0; total];
        }
        ensure!(self.m.len() == total,
                "optimizer state holds {} params, model has {total}",
                self.m.len());
        let mut norm_sq = 0.0f64;
        for (_, g, _) in tensors.iter() {
            for &gv in g.iter() {
                norm_sq += (gv as f64) * (gv as f64);
            }
        }
        let norm = norm_sq.sqrt() as f32;
        ensure!(norm.is_finite(), "non-finite gradient norm {norm}");
        let scale = if self.clip > 0.0 && norm > self.clip {
            self.clip / norm
        } else {
            1.0
        };
        self.step += 1;
        let bc1 = 1.0 - self.beta1.powi(self.step as i32);
        let bc2 = 1.0 - self.beta2.powi(self.step as i32);
        let mut off = 0usize;
        for (p, g, decays) in tensors.iter_mut() {
            let wd = if *decays { self.weight_decay } else { 0.0 };
            let m = &mut self.m[off..off + p.len()];
            let v = &mut self.v[off..off + p.len()];
            off += p.len();
            for (((pv, gv), mv), vv) in
                p.iter_mut().zip(g.iter()).zip(m.iter_mut()).zip(v.iter_mut())
            {
                let gc = gv * scale;
                *mv = self.beta1 * *mv + (1.0 - self.beta1) * gc;
                *vv = self.beta2 * *vv + (1.0 - self.beta2) * gc * gc;
                let mhat = *mv / bc1;
                let vhat = *vv / bc2;
                *pv -= lr * (mhat / (vhat.sqrt() + self.eps) + wd * *pv);
            }
        }
        Ok(norm)
    }
}

impl Default for AdamW {
    fn default() -> Self {
        AdamW::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize `f(x) = Σ (x_i − t_i)²` — AdamW must converge.
    #[test]
    fn adamw_minimizes_quadratic() {
        let target = [1.5f32, -2.0, 0.25, 3.0];
        let mut x = vec![0.0f32; 4];
        let mut g = vec![0.0f32; 4];
        let mut opt = AdamW { weight_decay: 0.0, ..AdamW::new() };
        let mut last = f32::MAX;
        for it in 0..400 {
            for ((gv, &xv), &tv) in
                g.iter_mut().zip(x.iter()).zip(target.iter()) {
                *gv = 2.0 * (xv - tv);
            }
            opt.step(0.05, &mut [(&mut x, &mut g, false)]).unwrap();
            let loss: f32 = x
                .iter()
                .zip(target.iter())
                .map(|(a, t)| (a - t) * (a - t))
                .sum();
            if it % 100 == 99 {
                assert!(loss < last, "loss not improving at iter {it}");
                last = loss;
            }
        }
        for (a, t) in x.iter().zip(target.iter()) {
            assert!((a - t).abs() < 0.05, "{a} vs {t}");
        }
        assert_eq!(opt.steps(), 400);
    }

    #[test]
    fn clipping_bounds_the_applied_update() {
        let mut x = vec![0.0f32; 2];
        let mut g = vec![1e6f32, -1e6];
        let mut opt = AdamW { weight_decay: 0.0, clip: 1.0, ..AdamW::new() };
        let norm = opt.step(0.1, &mut [(&mut x, &mut g, false)]).unwrap();
        assert!(norm > 1e6, "returned norm must be pre-clip");
        // with clip the effective |g| per element is ≤ 1, so the Adam
        // update magnitude stays ≤ lr·(1/(√(v̂)+ε)) ≈ lr/√(1) bounded
        for v in x.iter() {
            assert!(v.abs() < 1.0, "update exploded: {v}");
        }
    }

    #[test]
    fn weight_decay_only_where_flagged() {
        let mut w = vec![1.0f32];
        let mut b = vec![1.0f32];
        let mut gw = vec![0.0f32];
        let mut gb = vec![0.0f32];
        let mut opt = AdamW { weight_decay: 0.1, ..AdamW::new() };
        opt.step(0.1, &mut [(&mut w, &mut gw, true),
                            (&mut b, &mut gb, false)]).unwrap();
        assert!(w[0] < 1.0, "decayed weight should shrink");
        assert_eq!(b[0], 1.0, "no-decay tensor with zero grad must hold");
    }

    #[test]
    fn state_size_mismatch_is_an_error() {
        let mut x = vec![0.0f32; 2];
        let mut g = vec![0.0f32; 2];
        let mut opt = AdamW::new();
        opt.step(0.1, &mut [(&mut x, &mut g, false)]).unwrap();
        let mut y = vec![0.0f32; 3];
        let mut gy = vec![0.0f32; 3];
        assert!(opt.step(0.1, &mut [(&mut y, &mut gy, false)]).is_err());
    }
}
