//! Planned FFTs for the native CAT backend: an iterative in-place radix-2
//! complex FFT plus a packed real FFT (rfft/irfft), with all twiddle
//! factors and bit-reversal permutations precomputed once per length in an
//! [`FftPlan`] / [`RfftPlan`] and shared through a global plan cache
//! ([`rfft_plan`]). The hot loops perform **zero allocation**: every
//! transform runs in place over caller-provided buffers, so repeated
//! same-length calls touch only the cached plan (see
//! `plan_cache_stats`, asserted in `tests/native_backend.rs`).
//!
//! Conventions match `numpy.fft` (and therefore the JAX reference kernels
//! in `python/compile/kernels/ref.py`):
//!
//! * `forward` computes `X[k] = Σ_j x[j]·exp(-2πi jk/n)` (no scaling);
//! * `inverse` applies the `1/n` factor;
//! * the real FFT of length `n` returns `n/2 + 1` spectrum bins, computed
//!   through one complex FFT of length `n/2` (even/odd packing + an O(n)
//!   untangle pass) — the "planned real-FFT" half of the CAT speedup.
//!
//! Lengths must be powers of two (the paper's sequence lengths all are;
//! `CatLayer` validates before dispatching here).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Single-precision complex number (kept minimal: the offline build has no
/// num-complex crate, and the FFT needs only ring operations).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Complex {
    pub re: f32,
    pub im: f32,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };

    #[inline]
    pub fn new(re: f32, im: f32) -> Complex {
        Complex { re, im }
    }

    #[inline]
    pub fn conj(self) -> Complex {
        Complex { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn scale(self, s: f32) -> Complex {
        Complex { re: self.re * s, im: self.im * s }
    }

    /// Squared magnitude (diagnostics / tests).
    #[inline]
    pub fn norm_sq(self) -> f32 {
        self.re * self.re + self.im * self.im
    }
}

impl std::ops::Add for Complex {
    type Output = Complex;
    #[inline]
    fn add(self, o: Complex) -> Complex {
        Complex { re: self.re + o.re, im: self.im + o.im }
    }
}

impl std::ops::Sub for Complex {
    type Output = Complex;
    #[inline]
    fn sub(self, o: Complex) -> Complex {
        Complex { re: self.re - o.re, im: self.im - o.im }
    }
}

impl std::ops::Mul for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, o: Complex) -> Complex {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

/// Twiddle `exp(-2πi k / n)` computed in f64 and rounded once.
fn twiddle(k: usize, n: usize) -> Complex {
    let angle = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
    Complex::new(angle.cos() as f32, angle.sin() as f32)
}

/// Precomputed radix-2 complex FFT of one power-of-two length.
pub struct FftPlan {
    n: usize,
    /// bit-reversal permutation over 0..n
    bitrev: Vec<u32>,
    /// `twiddle[k] = exp(-2πi k / n)` for `k < max(n/2, 1)`
    twiddle: Vec<Complex>,
}

impl FftPlan {
    pub fn new(n: usize) -> FftPlan {
        assert!(n >= 1 && n.is_power_of_two(),
                "FFT length must be a power of two, got {n}");
        let log2n = n.trailing_zeros();
        let mut bitrev = vec![0u32; n];
        for i in 1..n {
            bitrev[i] = (bitrev[i >> 1] >> 1)
                | (((i as u32) & 1) << (log2n - 1));
        }
        let twiddle = (0..(n / 2).max(1)).map(|k| twiddle(k, n)).collect();
        FftPlan { n, bitrev, twiddle }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// In-place forward DFT (no scaling).
    pub fn forward(&self, buf: &mut [Complex]) {
        self.transform(buf, false);
    }

    /// In-place inverse DFT (scales by `1/n`).
    pub fn inverse(&self, buf: &mut [Complex]) {
        self.transform(buf, true);
    }

    fn transform(&self, buf: &mut [Complex], inverse: bool) {
        let n = self.n;
        assert_eq!(buf.len(), n, "buffer length != plan length");
        for i in 0..n {
            let j = self.bitrev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        let mut m = 2;
        while m <= n {
            let half = m / 2;
            let stride = n / m;
            let mut base = 0;
            while base < n {
                for j in 0..half {
                    let mut w = self.twiddle[j * stride];
                    if inverse {
                        w = w.conj();
                    }
                    let t = w * buf[base + j + half];
                    let u = buf[base + j];
                    buf[base + j] = u + t;
                    buf[base + j + half] = u - t;
                }
                base += m;
            }
            m *= 2;
        }
        if inverse {
            let inv_n = 1.0 / n as f32;
            for v in buf.iter_mut() {
                *v = v.scale(inv_n);
            }
        }
    }
}

/// Planned real FFT of length `n` via one complex FFT of length `n/2`.
pub struct RfftPlan {
    n: usize,
    half: FftPlan,
    /// `omega[k] = exp(-2πi k / n)` for `k <= n/4` (the untangle pass
    /// touches pairs `(k, n/2 - k)`, so only the first quarter is needed)
    omega: Vec<Complex>,
}

impl RfftPlan {
    pub fn new(n: usize) -> RfftPlan {
        assert!(n >= 1 && n.is_power_of_two(),
                "rFFT length must be a power of two, got {n}");
        RfftPlan {
            n,
            half: FftPlan::new((n / 2).max(1)),
            omega: (0..=n / 4).map(|k| twiddle(k, n)).collect(),
        }
    }

    /// Real input length.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Spectrum bins: `n/2 + 1`.
    pub fn spectrum_len(&self) -> usize {
        self.n / 2 + 1
    }

    /// Real forward FFT: `x` (length n) → `spec` (length n/2 + 1).
    /// Allocation-free; `spec` doubles as the packed work buffer.
    pub fn forward(&self, x: &[f32], spec: &mut [Complex]) {
        let n = self.n;
        assert_eq!(x.len(), n, "input length != plan length");
        assert_eq!(spec.len(), self.spectrum_len(), "bad spectrum length");
        if n == 1 {
            spec[0] = Complex::new(x[0], 0.0);
            return;
        }
        let h = n / 2;
        // pack x[2k] + i·x[2k+1] and transform at half length
        for k in 0..h {
            spec[k] = Complex::new(x[2 * k], x[2 * k + 1]);
        }
        self.half.forward(&mut spec[..h]);
        // untangle: X[k] = E_k + ω^k O_k over symmetric pairs (k, h-k)
        let z0 = spec[0];
        spec[0] = Complex::new(z0.re + z0.im, 0.0);
        spec[h] = Complex::new(z0.re - z0.im, 0.0);
        for k in 1..=h / 2 {
            let zk = spec[k];
            let zmk = spec[h - k];
            let e = (zk + zmk.conj()).scale(0.5);
            let d = zk - zmk.conj();
            let o = Complex::new(d.im * 0.5, -d.re * 0.5); // d · (-i/2)
            let w = self.omega[k];
            spec[k] = e + w * o;
            if k != h - k {
                // ω^{h-k} = -conj(ω^k)
                let whk = Complex::new(-w.re, w.im);
                spec[h - k] = e.conj() + whk * o.conj();
            }
        }
    }

    /// Real inverse FFT: `spec` (length n/2 + 1, **destroyed**) → `out`
    /// (length n). Allocation-free; includes the `1/n` scaling.
    pub fn inverse(&self, spec: &mut [Complex], out: &mut [f32]) {
        let n = self.n;
        assert_eq!(out.len(), n, "output length != plan length");
        assert_eq!(spec.len(), self.spectrum_len(), "bad spectrum length");
        if n == 1 {
            out[0] = spec[0].re;
            return;
        }
        let h = n / 2;
        // retangle: recover the packed half-length spectrum Z in place
        let x0 = spec[0];
        let xh = spec[h];
        spec[0] = Complex::new((x0.re + xh.re) * 0.5,
                               (x0.re - xh.re) * 0.5);
        for k in 1..=h / 2 {
            let xk = spec[k];
            let xmk = spec[h - k];
            let e = (xk + xmk.conj()).scale(0.5);
            let d = (xk - xmk.conj()).scale(0.5);
            let w = self.omega[k];
            let o = w.conj() * d;
            // Z[k] = E + i·O; Z[h-k] = conj(E) + i·conj(O)
            spec[k] = Complex::new(e.re - o.im, e.im + o.re);
            if k != h - k {
                spec[h - k] = Complex::new(e.re + o.im, -e.im + o.re);
            }
        }
        self.half.inverse(&mut spec[..h]);
        for k in 0..h {
            out[2 * k] = spec[k].re;
            out[2 * k + 1] = spec[k].im;
        }
    }
}

// ---------------------------------------------------------------------------
// plan cache
// ---------------------------------------------------------------------------

static PLAN_CACHE: OnceLock<Mutex<HashMap<usize, Arc<RfftPlan>>>> =
    OnceLock::new();
static PLAN_HITS: AtomicU64 = AtomicU64::new(0);
static PLAN_MISSES: AtomicU64 = AtomicU64::new(0);

/// Fetch (or build once) the shared real-FFT plan for length `n`.
///
/// Plans are immutable after construction, so one `Arc` serves every
/// thread; repeat calls of the same length never allocate a new plan.
pub fn rfft_plan(n: usize) -> Arc<RfftPlan> {
    let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = cache.lock().expect("plan cache poisoned");
    if let Some(plan) = map.get(&n) {
        PLAN_HITS.fetch_add(1, Ordering::Relaxed);
        return plan.clone();
    }
    PLAN_MISSES.fetch_add(1, Ordering::Relaxed);
    let plan = Arc::new(RfftPlan::new(n));
    map.insert(n, plan.clone());
    plan
}

/// Cumulative (hits, misses) of the plan cache — misses is exactly the
/// number of plans ever constructed through [`rfft_plan`].
pub fn plan_cache_stats() -> (u64, u64) {
    (PLAN_HITS.load(Ordering::Relaxed), PLAN_MISSES.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// O(n²) reference DFT in f64 (ground truth for the butterflies).
    fn naive_dft(x: &[Complex]) -> Vec<Complex> {
        let n = x.len();
        (0..n)
            .map(|k| {
                let mut re = 0.0f64;
                let mut im = 0.0f64;
                for (j, v) in x.iter().enumerate() {
                    let ang = -2.0 * std::f64::consts::PI
                        * ((k * j) % n) as f64
                        / n as f64;
                    let (s, c) = ang.sin_cos();
                    re += v.re as f64 * c - v.im as f64 * s;
                    im += v.re as f64 * s + v.im as f64 * c;
                }
                Complex::new(re as f32, im as f32)
            })
            .collect()
    }

    fn signal(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::data::Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn fft_matches_naive_dft() {
        for n in [1usize, 2, 4, 8, 32, 128] {
            let plan = FftPlan::new(n);
            let re = signal(n, 1);
            let im = signal(n, 2);
            let x: Vec<Complex> = re
                .iter()
                .zip(&im)
                .map(|(&r, &i)| Complex::new(r, i))
                .collect();
            let mut buf = x.clone();
            plan.forward(&mut buf);
            let want = naive_dft(&x);
            for (a, b) in buf.iter().zip(&want) {
                assert!((*a - *b).norm_sq().sqrt() < 1e-3 * (n as f32).max(1.0),
                        "n={n}: {a:?} vs {b:?}");
            }
            plan.inverse(&mut buf);
            for (a, b) in buf.iter().zip(&x) {
                assert!((*a - *b).norm_sq().sqrt() < 1e-4, "n={n} roundtrip");
            }
        }
    }

    #[test]
    fn rfft_matches_complex_fft() {
        for n in [1usize, 2, 4, 16, 64, 512] {
            let x = signal(n, 3);
            let rplan = RfftPlan::new(n);
            let mut spec = vec![Complex::ZERO; rplan.spectrum_len()];
            rplan.forward(&x, &mut spec);
            let full: Vec<Complex> =
                x.iter().map(|&v| Complex::new(v, 0.0)).collect();
            let want = naive_dft(&full);
            for k in 0..rplan.spectrum_len() {
                assert!((spec[k] - want[k]).norm_sq().sqrt() < 2e-3,
                        "n={n} bin {k}: {:?} vs {:?}", spec[k], want[k]);
            }
        }
    }

    #[test]
    fn rfft_irfft_roundtrip() {
        for n in [1usize, 2, 8, 64, 1024, 4096] {
            let x = signal(n, 5);
            let plan = RfftPlan::new(n);
            let mut spec = vec![Complex::ZERO; plan.spectrum_len()];
            let mut back = vec![0.0f32; n];
            plan.forward(&x, &mut spec);
            plan.inverse(&mut spec, &mut back);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-5, "n={n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn plan_cache_reuses_plans() {
        // repeat calls must hand back the same Arc (pointer identity is
        // immune to other tests concurrently caching different lengths)
        let first = rfft_plan(2048);
        let hits_before = plan_cache_stats().0;
        for _ in 0..64 {
            let p = rfft_plan(2048);
            assert_eq!(p.len(), 2048);
            assert!(Arc::ptr_eq(&first, &p),
                    "repeat rfft_plan(2048) constructed a new plan");
        }
        assert!(plan_cache_stats().0 >= hits_before + 64);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_rejected() {
        let _ = FftPlan::new(12);
    }
}
