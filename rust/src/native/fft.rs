//! Planned FFTs for the native CAT backend, two tiers:
//!
//! * **Reference tier** — the PR-1 iterative in-place radix-2 complex FFT
//!   ([`FftPlan`]) plus a packed real FFT ([`RfftPlan`]) over AoS
//!   [`Complex`] values. Kept as the bit-exactness oracle: the property
//!   tests pin the fast tier against it.
//! * **Throughput tier** — [`SplitRfftPlan`]: a **split-complex** (SoA,
//!   separate re/im `f32` slices) Stockham autosort FFT with a radix-4
//!   main kernel and one radix-2 fallback stage when log₂N is odd. No
//!   bit-reversal pass (Stockham self-sorts through ping-pong buffers),
//!   flat `f32` inner loops the compiler auto-vectorizes, and a batched
//!   API ([`SplitRfftPlan::rfft_many`] / [`SplitRfftPlan::irfft_many`])
//!   that applies one plan across a whole `batch×head` stripe of
//!   contiguous rows, so one plan fetch and one scratch frame serve the
//!   stripe and the per-stage twiddle tables stay cache-hot from row to
//!   row.
//!
//! All twiddle factors are precomputed per length in the plans and shared
//! through global plan caches ([`rfft_plan`], [`split_rfft_plan`]). The
//! hot loops perform **zero allocation**: transforms run over
//! caller-provided buffers (the task arenas of [`super::arena`] in the
//! CAT hot path), so repeated same-length calls touch only the cached
//! plan (see [`plan_cache_stats`], asserted in `tests/native_backend.rs`).
//!
//! Conventions match `numpy.fft` (and therefore the JAX reference kernels
//! in `python/compile/kernels/ref.py`):
//!
//! * `forward` computes `X[k] = Σ_j x[j]·exp(-2πi jk/n)` (no scaling);
//! * `inverse` applies the `1/n` factor;
//! * the real FFT of length `n` returns `n/2 + 1` spectrum bins, computed
//!   through one complex FFT of length `n/2` (even/odd packing + an O(n)
//!   untangle pass) — the "planned real-FFT" half of the CAT speedup.
//!
//! Lengths must be powers of two (the paper's sequence lengths all are;
//! `CatLayer` validates before dispatching here).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Single-precision complex number (kept minimal: the offline build has no
/// num-complex crate, and the FFT needs only ring operations).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Complex {
    pub re: f32,
    pub im: f32,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };

    #[inline]
    pub fn new(re: f32, im: f32) -> Complex {
        Complex { re, im }
    }

    #[inline]
    pub fn conj(self) -> Complex {
        Complex { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn scale(self, s: f32) -> Complex {
        Complex { re: self.re * s, im: self.im * s }
    }

    /// Squared magnitude (diagnostics / tests).
    #[inline]
    pub fn norm_sq(self) -> f32 {
        self.re * self.re + self.im * self.im
    }
}

impl std::ops::Add for Complex {
    type Output = Complex;
    #[inline]
    fn add(self, o: Complex) -> Complex {
        Complex { re: self.re + o.re, im: self.im + o.im }
    }
}

impl std::ops::Sub for Complex {
    type Output = Complex;
    #[inline]
    fn sub(self, o: Complex) -> Complex {
        Complex { re: self.re - o.re, im: self.im - o.im }
    }
}

impl std::ops::Mul for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, o: Complex) -> Complex {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

/// Twiddle `exp(-2πi k / n)` computed in f64 and rounded once.
fn twiddle(k: usize, n: usize) -> Complex {
    let angle = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
    Complex::new(angle.cos() as f32, angle.sin() as f32)
}

/// Precomputed radix-2 complex FFT of one power-of-two length
/// (reference tier; the hot path uses [`SplitRfftPlan`]).
pub struct FftPlan {
    n: usize,
    /// bit-reversal permutation over 0..n
    bitrev: Vec<u32>,
    /// `twiddle[k] = exp(-2πi k / n)` for `k < max(n/2, 1)`
    twiddle: Vec<Complex>,
}

impl FftPlan {
    pub fn new(n: usize) -> FftPlan {
        assert!(n >= 1 && n.is_power_of_two(),
                "FFT length must be a power of two, got {n}");
        let log2n = n.trailing_zeros();
        let mut bitrev = vec![0u32; n];
        for i in 1..n {
            bitrev[i] = (bitrev[i >> 1] >> 1)
                | (((i as u32) & 1) << (log2n - 1));
        }
        let twiddle = (0..(n / 2).max(1)).map(|k| twiddle(k, n)).collect();
        FftPlan { n, bitrev, twiddle }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// In-place forward DFT (no scaling).
    pub fn forward(&self, buf: &mut [Complex]) {
        self.transform(buf, false);
    }

    /// In-place inverse DFT (scales by `1/n`).
    pub fn inverse(&self, buf: &mut [Complex]) {
        self.transform(buf, true);
    }

    fn transform(&self, buf: &mut [Complex], inverse: bool) {
        let n = self.n;
        assert_eq!(buf.len(), n, "buffer length != plan length");
        for i in 0..n {
            let j = self.bitrev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        let mut m = 2;
        while m <= n {
            let half = m / 2;
            let stride = n / m;
            let mut base = 0;
            while base < n {
                for j in 0..half {
                    let mut w = self.twiddle[j * stride];
                    if inverse {
                        w = w.conj();
                    }
                    let t = w * buf[base + j + half];
                    let u = buf[base + j];
                    buf[base + j] = u + t;
                    buf[base + j + half] = u - t;
                }
                base += m;
            }
            m *= 2;
        }
        if inverse {
            let inv_n = 1.0 / n as f32;
            for v in buf.iter_mut() {
                *v = v.scale(inv_n);
            }
        }
    }
}

/// Planned real FFT of length `n` via one complex FFT of length `n/2`
/// (reference tier).
pub struct RfftPlan {
    n: usize,
    half: FftPlan,
    /// `omega[k] = exp(-2πi k / n)` for `k <= n/4` (the untangle pass
    /// touches pairs `(k, n/2 - k)`, so only the first quarter is needed)
    omega: Vec<Complex>,
}

impl RfftPlan {
    pub fn new(n: usize) -> RfftPlan {
        assert!(n >= 1 && n.is_power_of_two(),
                "rFFT length must be a power of two, got {n}");
        RfftPlan {
            n,
            half: FftPlan::new((n / 2).max(1)),
            omega: (0..=n / 4).map(|k| twiddle(k, n)).collect(),
        }
    }

    /// Real input length.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Spectrum bins: `n/2 + 1`.
    pub fn spectrum_len(&self) -> usize {
        self.n / 2 + 1
    }

    /// Real forward FFT: `x` (length n) → `spec` (length n/2 + 1).
    /// Allocation-free; `spec` doubles as the packed work buffer.
    pub fn forward(&self, x: &[f32], spec: &mut [Complex]) {
        let n = self.n;
        assert_eq!(x.len(), n, "input length != plan length");
        assert_eq!(spec.len(), self.spectrum_len(), "bad spectrum length");
        if n == 1 {
            spec[0] = Complex::new(x[0], 0.0);
            return;
        }
        let h = n / 2;
        // pack x[2k] + i·x[2k+1] and transform at half length
        for k in 0..h {
            spec[k] = Complex::new(x[2 * k], x[2 * k + 1]);
        }
        self.half.forward(&mut spec[..h]);
        // untangle: X[k] = E_k + ω^k O_k over symmetric pairs (k, h-k)
        let z0 = spec[0];
        spec[0] = Complex::new(z0.re + z0.im, 0.0);
        spec[h] = Complex::new(z0.re - z0.im, 0.0);
        for k in 1..=h / 2 {
            let zk = spec[k];
            let zmk = spec[h - k];
            let e = (zk + zmk.conj()).scale(0.5);
            let d = zk - zmk.conj();
            let o = Complex::new(d.im * 0.5, -d.re * 0.5); // d · (-i/2)
            let w = self.omega[k];
            spec[k] = e + w * o;
            if k != h - k {
                // ω^{h-k} = -conj(ω^k)
                let whk = Complex::new(-w.re, w.im);
                spec[h - k] = e.conj() + whk * o.conj();
            }
        }
    }

    /// Real inverse FFT: `spec` (length n/2 + 1, **destroyed**) → `out`
    /// (length n). Allocation-free; includes the `1/n` scaling.
    pub fn inverse(&self, spec: &mut [Complex], out: &mut [f32]) {
        let n = self.n;
        assert_eq!(out.len(), n, "output length != plan length");
        assert_eq!(spec.len(), self.spectrum_len(), "bad spectrum length");
        if n == 1 {
            out[0] = spec[0].re;
            return;
        }
        let h = n / 2;
        // retangle: recover the packed half-length spectrum Z in place
        let x0 = spec[0];
        let xh = spec[h];
        spec[0] = Complex::new((x0.re + xh.re) * 0.5,
                               (x0.re - xh.re) * 0.5);
        for k in 1..=h / 2 {
            let xk = spec[k];
            let xmk = spec[h - k];
            let e = (xk + xmk.conj()).scale(0.5);
            let d = (xk - xmk.conj()).scale(0.5);
            let w = self.omega[k];
            let o = w.conj() * d;
            // Z[k] = E + i·O; Z[h-k] = conj(E) + i·conj(O)
            spec[k] = Complex::new(e.re - o.im, e.im + o.re);
            if k != h - k {
                spec[h - k] = Complex::new(e.re + o.im, -e.im + o.re);
            }
        }
        self.half.inverse(&mut spec[..h]);
        for k in 0..h {
            out[2 * k] = spec[k].re;
            out[2 * k + 1] = spec[k].im;
        }
    }
}

// ---------------------------------------------------------------------------
// split-complex Stockham tier (the serving hot path)
// ---------------------------------------------------------------------------

/// One Stockham stage: all butterflies of one radix pass, twiddles
/// precomputed in SoA form so the `q` inner loop is flat f32 arithmetic.
struct SplitStage {
    /// sub-transform length at this stage
    n_cur: usize,
    /// stride (number of interleaved sub-transforms completed so far)
    s: usize,
    /// 4 for the main kernel, 2 for the final fallback pass
    radix: u8,
    /// `w1[p] = exp(-2πi p / n_cur)` for `p < n_cur/radix`
    w1re: Vec<f32>,
    w1im: Vec<f32>,
    /// `w1²` / `w1³` (radix-4 stages only)
    w2re: Vec<f32>,
    w2im: Vec<f32>,
    w3re: Vec<f32>,
    w3im: Vec<f32>,
}

/// Planned split-complex real FFT: SoA buffers, radix-4 Stockham main
/// kernel (radix-2 fallback for odd log₂), batched row API. This is what
/// `CatLayer` drives; [`RfftPlan`] remains the correctness oracle.
pub struct SplitRfftPlan {
    n: usize,
    /// half length (the packed complex transform length)
    h: usize,
    /// Stockham schedule for the length-`h` complex FFT
    stages: Vec<SplitStage>,
    /// untangle twiddles `exp(-2πi k / n)` for `k <= h/2`
    om_re: Vec<f32>,
    om_im: Vec<f32>,
}

impl SplitRfftPlan {
    pub fn new(n: usize) -> SplitRfftPlan {
        assert!(n >= 1 && n.is_power_of_two(),
                "rFFT length must be a power of two, got {n}");
        let h = n / 2;
        let mut stages = Vec::new();
        let mut n_cur = h;
        let mut s = 1usize;
        while n_cur >= 4 {
            let m = n_cur / 4;
            let mut st = SplitStage {
                n_cur,
                s,
                radix: 4,
                w1re: Vec::with_capacity(m),
                w1im: Vec::with_capacity(m),
                w2re: Vec::with_capacity(m),
                w2im: Vec::with_capacity(m),
                w3re: Vec::with_capacity(m),
                w3im: Vec::with_capacity(m),
            };
            for p in 0..m {
                let w1 = twiddle(p, n_cur);
                let w2 = w1 * w1;
                let w3 = w2 * w1;
                st.w1re.push(w1.re);
                st.w1im.push(w1.im);
                st.w2re.push(w2.re);
                st.w2im.push(w2.im);
                st.w3re.push(w3.re);
                st.w3im.push(w3.im);
            }
            stages.push(st);
            n_cur /= 4;
            s *= 4;
        }
        if n_cur == 2 {
            // final radix-2 pass: n_cur == 2 means its only twiddle is
            // ω⁰ = 1, so no tables are needed (stage_apply specializes)
            stages.push(SplitStage {
                n_cur: 2,
                s,
                radix: 2,
                w1re: Vec::new(),
                w1im: Vec::new(),
                w2re: Vec::new(),
                w2im: Vec::new(),
                w3re: Vec::new(),
                w3im: Vec::new(),
            });
        }
        let omega: Vec<Complex> =
            (0..=h / 2).map(|k| twiddle(k, n)).collect();
        SplitRfftPlan {
            n,
            h,
            stages,
            om_re: omega.iter().map(|w| w.re).collect(),
            om_im: omega.iter().map(|w| w.im).collect(),
        }
    }

    /// Real input length.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Spectrum bins per row: `n/2 + 1`.
    pub fn spectrum_len(&self) -> usize {
        self.n / 2 + 1
    }

    /// Required scratch length (f32 elements) for either direction:
    /// two re/im ping-pong buffers of the half length.
    pub fn scratch_len(&self) -> usize {
        4 * self.h
    }

    /// Batched real forward FFT: `xs` is `rows` contiguous rows of length
    /// `n`; spectra land in `spec_re`/`spec_im` as `rows` contiguous rows
    /// of length `n/2 + 1`. `scratch` needs [`Self::scratch_len`]
    /// elements. Allocation-free; rows are transformed back to back, so
    /// the stage twiddle tables stay cache-hot across the whole batch.
    pub fn rfft_many(&self, xs: &[f32], rows: usize, spec_re: &mut [f32],
                     spec_im: &mut [f32], scratch: &mut [f32]) {
        let (n, f) = (self.n, self.spectrum_len());
        assert_eq!(xs.len(), rows * n, "input rows mismatch");
        assert_eq!(spec_re.len(), rows * f, "spectrum re rows mismatch");
        assert_eq!(spec_im.len(), rows * f, "spectrum im rows mismatch");
        assert!(scratch.len() >= self.scratch_len(), "scratch too small");
        let (scr_re, scr_im) = scratch[..2 * self.h].split_at_mut(self.h);
        for r in 0..rows {
            self.rfft_row(&xs[r * n..(r + 1) * n],
                          &mut spec_re[r * f..(r + 1) * f],
                          &mut spec_im[r * f..(r + 1) * f],
                          scr_re, scr_im);
        }
    }

    /// Batched real inverse FFT (with the `1/n` scaling): `rows`
    /// contiguous spectrum rows → `rows` contiguous time rows in `out`.
    /// Spectra are read-only. `scratch` needs [`Self::scratch_len`]
    /// elements.
    pub fn irfft_many(&self, spec_re: &[f32], spec_im: &[f32], rows: usize,
                      out: &mut [f32], scratch: &mut [f32]) {
        let (n, f) = (self.n, self.spectrum_len());
        assert_eq!(spec_re.len(), rows * f, "spectrum re rows mismatch");
        assert_eq!(spec_im.len(), rows * f, "spectrum im rows mismatch");
        assert_eq!(out.len(), rows * n, "output rows mismatch");
        assert!(scratch.len() >= self.scratch_len(), "scratch too small");
        let h = self.h;
        let (ping, pong) = scratch[..4 * h].split_at_mut(2 * h);
        let (ping_re, ping_im) = ping.split_at_mut(h);
        let (pong_re, pong_im) = pong.split_at_mut(h);
        for r in 0..rows {
            self.irfft_row(&spec_re[r * f..(r + 1) * f],
                           &spec_im[r * f..(r + 1) * f],
                           &mut out[r * n..(r + 1) * n],
                           ping_re, ping_im, pong_re, pong_im);
        }
    }

    /// Single-row forward convenience (`rfft_many` with `rows = 1`).
    pub fn rfft(&self, x: &[f32], spec_re: &mut [f32], spec_im: &mut [f32],
                scratch: &mut [f32]) {
        self.rfft_many(x, 1, spec_re, spec_im, scratch);
    }

    /// Single-row inverse convenience (`irfft_many` with `rows = 1`).
    pub fn irfft(&self, spec_re: &[f32], spec_im: &[f32], out: &mut [f32],
                 scratch: &mut [f32]) {
        self.irfft_many(spec_re, spec_im, 1, out, scratch);
    }

    fn rfft_row(&self, x: &[f32], sre: &mut [f32], sim: &mut [f32],
                scr_re: &mut [f32], scr_im: &mut [f32]) {
        let h = self.h;
        if self.n == 1 {
            sre[0] = x[0];
            sim[0] = 0.0;
            return;
        }
        {
            // ping-pong the Stockham stages so the result lands in the
            // spectrum row: even stage count starts there, odd starts in
            // the scratch pair
            let (are, aim) = (&mut sre[..h], &mut sim[..h]);
            let even = self.stages.len() % 2 == 0;
            let (mut src_re, mut src_im, mut dst_re, mut dst_im) = if even {
                (are, aim, scr_re, scr_im)
            } else {
                (scr_re, scr_im, are, aim)
            };
            for k in 0..h {
                src_re[k] = x[2 * k];
                src_im[k] = x[2 * k + 1];
            }
            for st in &self.stages {
                stage_apply(st, src_re, src_im, dst_re, dst_im);
                std::mem::swap(&mut src_re, &mut dst_re);
                std::mem::swap(&mut src_im, &mut dst_im);
            }
        }
        // untangle in place over the h+1 spectrum bins
        let (z0r, z0i) = (sre[0], sim[0]);
        sre[0] = z0r + z0i;
        sim[0] = 0.0;
        sre[h] = z0r - z0i;
        sim[h] = 0.0;
        for k in 1..=h / 2 {
            let (zkr, zki) = (sre[k], sim[k]);
            let (zmr, zmi) = (sre[h - k], sim[h - k]);
            let er = (zkr + zmr) * 0.5;
            let ei = (zki - zmi) * 0.5;
            let dr = zkr - zmr;
            let di = zki + zmi;
            let or_ = di * 0.5; // d · (-i/2)
            let oi_ = -dr * 0.5;
            let (wr, wi) = (self.om_re[k], self.om_im[k]);
            sre[k] = er + or_ * wr - oi_ * wi;
            sim[k] = ei + or_ * wi + oi_ * wr;
            if k != h - k {
                // ω^{h-k} = -conj(ω^k); spec[h-k] = conj(e) + ω^{h-k}·conj(o)
                sre[h - k] = er - or_ * wr + oi_ * wi;
                sim[h - k] = -ei + or_ * wi + oi_ * wr;
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn irfft_row(&self, sre: &[f32], sim: &[f32], out: &mut [f32],
                 ping_re: &mut [f32], ping_im: &mut [f32],
                 pong_re: &mut [f32], pong_im: &mut [f32]) {
        let h = self.h;
        if self.n == 1 {
            out[0] = sre[0];
            return;
        }
        // retangle into the packed half-length spectrum Z, storing the
        // conjugate (negated im): the inverse transform runs the forward
        // kernel on conj(Z) and conjugates back during the unpack
        let (x0r, xhr) = (sre[0], sre[h]);
        ping_re[0] = (x0r + xhr) * 0.5;
        ping_im[0] = -((x0r - xhr) * 0.5);
        for k in 1..=h / 2 {
            let (xkr, xki) = (sre[k], sim[k]);
            let (xmr, xmi) = (sre[h - k], sim[h - k]);
            let er = (xkr + xmr) * 0.5;
            let ei = (xki - xmi) * 0.5;
            let dr = (xkr - xmr) * 0.5;
            let di = (xki + xmi) * 0.5;
            let (wr, wi) = (self.om_re[k], self.om_im[k]);
            let or_ = wr * dr + wi * di; // conj(ω^k) · d
            let oi_ = wr * di - wi * dr;
            // Z[k] = E + i·O, stored conjugated
            ping_re[k] = er - oi_;
            ping_im[k] = -(ei + or_);
            if k != h - k {
                // Z[h-k] = conj(E) + i·conj(O), stored conjugated
                ping_re[h - k] = er + oi_;
                ping_im[h - k] = ei - or_;
            }
        }
        let (mut src_re, mut src_im, mut dst_re, mut dst_im) =
            (ping_re, ping_im, pong_re, pong_im);
        for st in &self.stages {
            stage_apply(st, src_re, src_im, dst_re, dst_im);
            std::mem::swap(&mut src_re, &mut dst_re);
            std::mem::swap(&mut src_im, &mut dst_im);
        }
        let inv = 1.0 / h as f32;
        for k in 0..h {
            out[2 * k] = src_re[k] * inv;
            out[2 * k + 1] = -src_im[k] * inv;
        }
    }
}

/// One Stockham pass `src → dst`. For radix 4 with sub-length `n_cur`,
/// stride `s`, `m = n_cur/4`: reads lanes `src[s·(p + m·r) ..][..s]`,
/// writes lanes `dst[s·(4p + r) ..][..s]` with the DIF butterfly
///
/// ```text
///   t0 = a + c   t1 = a − c   t2 = b + d   t3 = −i·(b − d)
///   y0 = t0 + t2          y1 = ω¹ᵖ·(t1 + t3)
///   y2 = ω²ᵖ·(t0 − t2)    y3 = ω³ᵖ·(t1 − t3)
/// ```
///
/// The `q` inner loops run over equal-length `f32` slices: the stride-`s`
/// lanes map straight onto [`simd::F32xN`] vectors (twiddles are scalar
/// per `p`, broadcast across the lane). Both the vector body and the
/// scalar tail/oracle perform the identical mul/add sequence with no
/// hardware FMA, so the stage is bit-exact across dispatch tiers.
fn stage_apply(st: &SplitStage, src_re: &[f32], src_im: &[f32],
               dst_re: &mut [f32], dst_im: &mut [f32]) {
    use super::simd::{self, F32xN, LANES};
    let s = st.s;
    let vector = !simd::force_scalar() && s >= LANES;
    if st.radix == 4 {
        let m = st.n_cur / 4;
        for p in 0..m {
            let (w1r, w1i) = (st.w1re[p], st.w1im[p]);
            let (w2r, w2i) = (st.w2re[p], st.w2im[p]);
            let (w3r, w3i) = (st.w3re[p], st.w3im[p]);
            let a_r = &src_re[s * p..s * (p + 1)];
            let a_i = &src_im[s * p..s * (p + 1)];
            let b_r = &src_re[s * (p + m)..s * (p + m + 1)];
            let b_i = &src_im[s * (p + m)..s * (p + m + 1)];
            let c_r = &src_re[s * (p + 2 * m)..s * (p + 2 * m + 1)];
            let c_i = &src_im[s * (p + 2 * m)..s * (p + 2 * m + 1)];
            let d_r = &src_re[s * (p + 3 * m)..s * (p + 3 * m + 1)];
            let d_i = &src_im[s * (p + 3 * m)..s * (p + 3 * m + 1)];
            let o = 4 * p * s;
            let (y0r, rest) = dst_re[o..o + 4 * s].split_at_mut(s);
            let (y1r, rest) = rest.split_at_mut(s);
            let (y2r, y3r) = rest.split_at_mut(s);
            let (y0i, rest) = dst_im[o..o + 4 * s].split_at_mut(s);
            let (y1i, rest) = rest.split_at_mut(s);
            let (y2i, y3i) = rest.split_at_mut(s);
            let mut q = 0;
            if vector {
                let v1r = F32xN::splat(w1r);
                let v1i = F32xN::splat(w1i);
                let v2r = F32xN::splat(w2r);
                let v2i = F32xN::splat(w2i);
                let v3r = F32xN::splat(w3r);
                let v3i = F32xN::splat(w3i);
                while q + LANES <= s {
                    let ar = F32xN::load(&a_r[q..]);
                    let ai = F32xN::load(&a_i[q..]);
                    let br = F32xN::load(&b_r[q..]);
                    let bi = F32xN::load(&b_i[q..]);
                    let cr = F32xN::load(&c_r[q..]);
                    let ci = F32xN::load(&c_i[q..]);
                    let dr = F32xN::load(&d_r[q..]);
                    let di = F32xN::load(&d_i[q..]);
                    let t0r = ar.add(cr);
                    let t0i = ai.add(ci);
                    let t1r = ar.sub(cr);
                    let t1i = ai.sub(ci);
                    let t2r = br.add(dr);
                    let t2i = bi.add(di);
                    // t3 = -i·(b - d)
                    let t3r = bi.sub(di);
                    let t3i = dr.sub(br);
                    t0r.add(t2r).store(&mut y0r[q..]);
                    t0i.add(t2i).store(&mut y0i[q..]);
                    let u1r = t1r.add(t3r);
                    let u1i = t1i.add(t3i);
                    u1r.mul(v1r).sub(u1i.mul(v1i)).store(&mut y1r[q..]);
                    u1r.mul(v1i).add(u1i.mul(v1r)).store(&mut y1i[q..]);
                    let u2r = t0r.sub(t2r);
                    let u2i = t0i.sub(t2i);
                    u2r.mul(v2r).sub(u2i.mul(v2i)).store(&mut y2r[q..]);
                    u2r.mul(v2i).add(u2i.mul(v2r)).store(&mut y2i[q..]);
                    let u3r = t1r.sub(t3r);
                    let u3i = t1i.sub(t3i);
                    u3r.mul(v3r).sub(u3i.mul(v3i)).store(&mut y3r[q..]);
                    u3r.mul(v3i).add(u3i.mul(v3r)).store(&mut y3i[q..]);
                    q += LANES;
                }
            }
            while q < s {
                let (ar, ai) = (a_r[q], a_i[q]);
                let (br, bi) = (b_r[q], b_i[q]);
                let (cr, ci) = (c_r[q], c_i[q]);
                let (dr, di) = (d_r[q], d_i[q]);
                let (t0r, t0i) = (ar + cr, ai + ci);
                let (t1r, t1i) = (ar - cr, ai - ci);
                let (t2r, t2i) = (br + dr, bi + di);
                // t3 = -i·(b - d)
                let (t3r, t3i) = (bi - di, dr - br);
                y0r[q] = t0r + t2r;
                y0i[q] = t0i + t2i;
                let (u1r, u1i) = (t1r + t3r, t1i + t3i);
                y1r[q] = u1r * w1r - u1i * w1i;
                y1i[q] = u1r * w1i + u1i * w1r;
                let (u2r, u2i) = (t0r - t2r, t0i - t2i);
                y2r[q] = u2r * w2r - u2i * w2i;
                y2i[q] = u2r * w2i + u2i * w2r;
                let (u3r, u3i) = (t1r - t3r, t1i - t3i);
                y3r[q] = u3r * w3r - u3i * w3i;
                y3i[q] = u3r * w3i + u3i * w3r;
                q += 1;
            }
        }
    } else {
        // radix-2 fallback pass: in this schedule it only ever runs as
        // the final stage, where n_cur == 2 so the single twiddle is
        // ω⁰ = 1 and the butterfly is a bare add/sub
        debug_assert_eq!(st.n_cur, 2, "radix-2 pass is the final stage");
        let a_r = &src_re[..s];
        let a_i = &src_im[..s];
        let b_r = &src_re[s..2 * s];
        let b_i = &src_im[s..2 * s];
        let (y0r, y1r) = dst_re[..2 * s].split_at_mut(s);
        let (y0i, y1i) = dst_im[..2 * s].split_at_mut(s);
        let mut q = 0;
        if vector {
            while q + LANES <= s {
                let ar = F32xN::load(&a_r[q..]);
                let ai = F32xN::load(&a_i[q..]);
                let br = F32xN::load(&b_r[q..]);
                let bi = F32xN::load(&b_i[q..]);
                ar.add(br).store(&mut y0r[q..]);
                ai.add(bi).store(&mut y0i[q..]);
                ar.sub(br).store(&mut y1r[q..]);
                ai.sub(bi).store(&mut y1i[q..]);
                q += LANES;
            }
        }
        while q < s {
            let (ar, ai) = (a_r[q], a_i[q]);
            let (br, bi) = (b_r[q], b_i[q]);
            y0r[q] = ar + br;
            y0i[q] = ai + bi;
            y1r[q] = ar - br;
            y1i[q] = ai - bi;
            q += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// plan caches
// ---------------------------------------------------------------------------

static PLAN_CACHE: OnceLock<Mutex<HashMap<usize, Arc<RfftPlan>>>> =
    OnceLock::new();
static SPLIT_CACHE: OnceLock<Mutex<HashMap<usize, Arc<SplitRfftPlan>>>> =
    OnceLock::new();
static PLAN_HITS: AtomicU64 = AtomicU64::new(0);
static PLAN_MISSES: AtomicU64 = AtomicU64::new(0);

/// Fetch (or build once) the shared reference real-FFT plan for length
/// `n`.
///
/// Plans are immutable after construction, so one `Arc` serves every
/// thread; repeat calls of the same length never allocate a new plan.
pub fn rfft_plan(n: usize) -> Arc<RfftPlan> {
    let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = cache.lock().expect("plan cache poisoned");
    if let Some(plan) = map.get(&n) {
        PLAN_HITS.fetch_add(1, Ordering::Relaxed);
        return plan.clone();
    }
    PLAN_MISSES.fetch_add(1, Ordering::Relaxed);
    let plan = Arc::new(RfftPlan::new(n));
    map.insert(n, plan.clone());
    plan
}

/// Fetch (or build once) the shared split-complex real-FFT plan for
/// length `n` — the hot-path sibling of [`rfft_plan`], same caching
/// contract, same hit/miss counters.
pub fn split_rfft_plan(n: usize) -> Arc<SplitRfftPlan> {
    let cache = SPLIT_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = cache.lock().expect("split plan cache poisoned");
    if let Some(plan) = map.get(&n) {
        PLAN_HITS.fetch_add(1, Ordering::Relaxed);
        return plan.clone();
    }
    PLAN_MISSES.fetch_add(1, Ordering::Relaxed);
    let plan = Arc::new(SplitRfftPlan::new(n));
    map.insert(n, plan.clone());
    plan
}

/// Cumulative (hits, misses) across both plan caches — misses is exactly
/// the number of plans ever constructed through [`rfft_plan`] /
/// [`split_rfft_plan`].
pub fn plan_cache_stats() -> (u64, u64) {
    (PLAN_HITS.load(Ordering::Relaxed), PLAN_MISSES.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// O(n²) reference DFT in f64 (ground truth for the butterflies).
    fn naive_dft(x: &[Complex]) -> Vec<Complex> {
        let n = x.len();
        (0..n)
            .map(|k| {
                let mut re = 0.0f64;
                let mut im = 0.0f64;
                for (j, v) in x.iter().enumerate() {
                    let ang = -2.0 * std::f64::consts::PI
                        * ((k * j) % n) as f64
                        / n as f64;
                    let (s, c) = ang.sin_cos();
                    re += v.re as f64 * c - v.im as f64 * s;
                    im += v.re as f64 * s + v.im as f64 * c;
                }
                Complex::new(re as f32, im as f32)
            })
            .collect()
    }

    fn signal(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::data::Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn fft_matches_naive_dft() {
        for n in [1usize, 2, 4, 8, 32, 128] {
            let plan = FftPlan::new(n);
            let re = signal(n, 1);
            let im = signal(n, 2);
            let x: Vec<Complex> = re
                .iter()
                .zip(&im)
                .map(|(&r, &i)| Complex::new(r, i))
                .collect();
            let mut buf = x.clone();
            plan.forward(&mut buf);
            let want = naive_dft(&x);
            for (a, b) in buf.iter().zip(&want) {
                assert!((*a - *b).norm_sq().sqrt() < 1e-3 * (n as f32).max(1.0),
                        "n={n}: {a:?} vs {b:?}");
            }
            plan.inverse(&mut buf);
            for (a, b) in buf.iter().zip(&x) {
                assert!((*a - *b).norm_sq().sqrt() < 1e-4, "n={n} roundtrip");
            }
        }
    }

    #[test]
    fn rfft_matches_complex_fft() {
        for n in [1usize, 2, 4, 16, 64, 512] {
            let x = signal(n, 3);
            let rplan = RfftPlan::new(n);
            let mut spec = vec![Complex::ZERO; rplan.spectrum_len()];
            rplan.forward(&x, &mut spec);
            let full: Vec<Complex> =
                x.iter().map(|&v| Complex::new(v, 0.0)).collect();
            let want = naive_dft(&full);
            for k in 0..rplan.spectrum_len() {
                assert!((spec[k] - want[k]).norm_sq().sqrt() < 2e-3,
                        "n={n} bin {k}: {:?} vs {:?}", spec[k], want[k]);
            }
        }
    }

    #[test]
    fn rfft_irfft_roundtrip() {
        for n in [1usize, 2, 8, 64, 1024, 4096] {
            let x = signal(n, 5);
            let plan = RfftPlan::new(n);
            let mut spec = vec![Complex::ZERO; plan.spectrum_len()];
            let mut back = vec![0.0f32; n];
            plan.forward(&x, &mut spec);
            plan.inverse(&mut spec, &mut back);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-5, "n={n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn split_rfft_matches_radix2_reference() {
        // every schedule shape: pure radix-4 (h = 4^k), radix-2-capped
        // (h = 2·4^k), the degenerate lengths, and a large stripe
        for n in [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 1024, 4096, 8192] {
            let x = signal(n, 11);
            let rplan = RfftPlan::new(n);
            let mut want = vec![Complex::ZERO; rplan.spectrum_len()];
            rplan.forward(&x, &mut want);

            let splan = SplitRfftPlan::new(n);
            assert_eq!(splan.spectrum_len(), rplan.spectrum_len());
            let f = splan.spectrum_len();
            let mut sre = vec![0.0f32; f];
            let mut sim = vec![0.0f32; f];
            let mut scratch = vec![0.0f32; splan.scratch_len()];
            splan.rfft(&x, &mut sre, &mut sim, &mut scratch);
            for k in 0..f {
                let tol = 1e-5 * (1.0 + want[k].norm_sq().sqrt());
                assert!((sre[k] - want[k].re).abs() < tol
                            && (sim[k] - want[k].im).abs() < tol,
                        "n={n} bin {k}: split ({}, {}) vs radix-2 {:?}",
                        sre[k], sim[k], want[k]);
            }

            let mut back = vec![0.0f32; n];
            splan.irfft(&sre, &sim, &mut back, &mut scratch);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-5, "n={n} roundtrip: {a} vs {b}");
            }
        }
    }

    #[test]
    fn split_rfft_many_equals_per_row() {
        let (n, rows) = (256usize, 7usize);
        let plan = SplitRfftPlan::new(n);
        let f = plan.spectrum_len();
        let xs = signal(n * rows, 13);
        let mut scratch = vec![0.0f32; plan.scratch_len()];

        let mut bre = vec![0.0f32; rows * f];
        let mut bim = vec![0.0f32; rows * f];
        plan.rfft_many(&xs, rows, &mut bre, &mut bim, &mut scratch);

        for r in 0..rows {
            let mut sre = vec![0.0f32; f];
            let mut sim = vec![0.0f32; f];
            plan.rfft(&xs[r * n..(r + 1) * n], &mut sre, &mut sim,
                      &mut scratch);
            assert_eq!(&bre[r * f..(r + 1) * f], &sre[..], "row {r} re");
            assert_eq!(&bim[r * f..(r + 1) * f], &sim[..], "row {r} im");
        }

        let mut back = vec![0.0f32; rows * n];
        plan.irfft_many(&bre, &bim, rows, &mut back, &mut scratch);
        for (a, b) in back.iter().zip(&xs) {
            assert!((a - b).abs() < 1e-5, "batched roundtrip: {a} vs {b}");
        }
    }

    #[test]
    fn plan_cache_reuses_plans() {
        // repeat calls must hand back the same Arc (pointer identity is
        // immune to other tests concurrently caching different lengths)
        let first = rfft_plan(2048);
        let sfirst = split_rfft_plan(2048);
        let hits_before = plan_cache_stats().0;
        for _ in 0..64 {
            let p = rfft_plan(2048);
            assert_eq!(p.len(), 2048);
            assert!(Arc::ptr_eq(&first, &p),
                    "repeat rfft_plan(2048) constructed a new plan");
            let sp = split_rfft_plan(2048);
            assert!(Arc::ptr_eq(&sfirst, &sp),
                    "repeat split_rfft_plan(2048) constructed a new plan");
        }
        assert!(plan_cache_stats().0 >= hits_before + 128);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_rejected() {
        let _ = FftPlan::new(12);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn split_non_power_of_two_rejected() {
        let _ = SplitRfftPlan::new(24);
    }
}
