//! Portable SIMD kernel layer: the one vector abstraction every hot
//! loop in the native backend runs through (DESIGN.md §15).
//!
//! Two things live here:
//!
//! * [`F32xN`] — a fixed-width f32 vector chosen at *compile time*:
//!   AVX2 (`__m256`, 8 lanes) when the build enables it
//!   (`RUSTFLAGS="-C target-cpu=native"`), SSE2 (`__m128`, 4 lanes) on
//!   baseline x86_64, NEON (`float32x4_t`, 4 lanes) on aarch64, and an
//!   always-available `[f32; 4]` scalar-array fallback elsewhere. All
//!   loads/stores are unaligned-tolerant, so correctness never depends
//!   on alignment — the arena's 32-byte alignment (`arena.rs`) is a
//!   throughput contract, not a safety one.
//! * The row kernels (`axpy`, `dot`, `scale`, the `cmul_*_rows` complex
//!   family, the reduction helpers) — each one carries its own scalar
//!   loop, kept as the equivalence oracle and bench baseline, and
//!   dispatches per call on [`force_scalar`].
//!
//! Dispatch tiers (mirroring `pool::set_force_inline`):
//!
//! * `CAT_FORCE_SCALAR=1` in the environment flips the process-global
//!   default, so a whole test/bench run exercises the scalar oracles
//!   (the CI forced-scalar variant);
//! * [`set_force_scalar`] is a thread-local override for targeted
//!   equivalence tests on the calling thread;
//! * [`set_force_scalar_global`] flips the process-global default at
//!   runtime — pool workers see it too, which is what the
//!   simd-vs-scalar bench columns use.
//!
//! Numerics contract (the bit-identical-or-pinned discipline of
//! PRs 2/4): every *element-wise* kernel performs exactly the same
//! scalar operations in the same per-element order as its scalar loop —
//! no hardware FMA anywhere, mul and add round separately — so those
//! paths are bit-identical across all dispatch tiers and lane widths.
//! *Reductions* (`dot`, `sum`, `sumsq_diff`, the tail of `max` on NaN
//! inputs) fold LANES partial accumulators and therefore reassociate;
//! they are pinned to the scalar oracle by tolerance proptests instead
//! (`tests/proptests.rs`). `max` over finite floats is exact under any
//! association.

use std::sync::atomic::{AtomicBool, Ordering};

// ---------------------------------------------------------------------------
// dispatch: forced-scalar tiers
// ---------------------------------------------------------------------------

/// Process-global forced-scalar default, seeded once from
/// `CAT_FORCE_SCALAR` (any non-empty value other than `0`).
static FORCE_SCALAR_GLOBAL: AtomicBool = AtomicBool::new(false);
static FORCE_SCALAR_ENV: std::sync::OnceLock<bool> = std::sync::OnceLock::new();

thread_local! {
    /// Per-thread override: `None` defers to the global default.
    static FORCE_SCALAR_TLS: std::cell::Cell<Option<bool>> =
        const { std::cell::Cell::new(None) };
}

fn env_force_scalar() -> bool {
    *FORCE_SCALAR_ENV.get_or_init(|| {
        match std::env::var("CAT_FORCE_SCALAR") {
            Ok(v) => !(v.is_empty() || v == "0"),
            Err(_) => false,
        }
    })
}

/// Force every simd kernel on *this thread* onto its scalar oracle
/// (equivalence tests). Mirrors `pool::set_force_inline`; pass `false`
/// to drop back to the global default.
pub fn set_force_scalar(on: bool) {
    FORCE_SCALAR_TLS.with(|f| f.set(if on { Some(true) } else { None }));
}

/// Flip the process-global default — pool workers included. This is
/// what the bench simd-vs-scalar columns toggle; tests that only need
/// the calling thread should prefer [`set_force_scalar`].
pub fn set_force_scalar_global(on: bool) {
    FORCE_SCALAR_GLOBAL.store(on, Ordering::Relaxed);
}

/// Should kernels take their scalar path on this thread right now?
#[inline]
pub fn force_scalar() -> bool {
    FORCE_SCALAR_TLS.with(|f| f.get()).unwrap_or_else(|| {
        FORCE_SCALAR_GLOBAL.load(Ordering::Relaxed) || env_force_scalar()
    })
}

/// Which vector backend this build compiled in (bench/report labels).
pub fn backend_name() -> &'static str {
    #[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
    {
        "avx2_f32x8"
    }
    #[cfg(all(target_arch = "x86_64", not(target_feature = "avx2")))]
    {
        "sse2_f32x4"
    }
    #[cfg(target_arch = "aarch64")]
    {
        "neon_f32x4"
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        "scalar_f32x4"
    }
}

// ---------------------------------------------------------------------------
// F32xN: the compile-time-width vector type
// ---------------------------------------------------------------------------

/// Lanes per [`F32xN`]. Arena frames are padded so every handed-out
/// slice starts `LANES`-aligned (32 bytes at the widest tier).
#[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
pub const LANES: usize = 8;
#[cfg(not(all(target_arch = "x86_64", target_feature = "avx2")))]
pub const LANES: usize = 4;

/// A `LANES`-wide f32 vector. Operations never use hardware FMA so that
/// element-wise kernels stay bit-identical to their scalar oracles.
#[derive(Clone, Copy)]
pub struct F32xN(Repr);

#[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
type Repr = std::arch::x86_64::__m256;
#[cfg(all(target_arch = "x86_64", not(target_feature = "avx2")))]
type Repr = std::arch::x86_64::__m128;
#[cfg(target_arch = "aarch64")]
type Repr = std::arch::aarch64::float32x4_t;
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
type Repr = [f32; LANES];

#[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
impl F32xN {
    #[inline]
    pub fn splat(x: f32) -> Self {
        unsafe { F32xN(std::arch::x86_64::_mm256_set1_ps(x)) }
    }

    /// Load the first `LANES` elements of `xs` (unaligned-tolerant).
    #[inline]
    pub fn load(xs: &[f32]) -> Self {
        debug_assert!(xs.len() >= LANES);
        unsafe { F32xN(std::arch::x86_64::_mm256_loadu_ps(xs.as_ptr())) }
    }

    /// Store into the first `LANES` elements of `out`.
    #[inline]
    pub fn store(self, out: &mut [f32]) {
        debug_assert!(out.len() >= LANES);
        unsafe { std::arch::x86_64::_mm256_storeu_ps(out.as_mut_ptr(), self.0) }
    }

    #[inline]
    pub fn add(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::x86_64::_mm256_add_ps(self.0, o.0)) }
    }

    #[inline]
    pub fn sub(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::x86_64::_mm256_sub_ps(self.0, o.0)) }
    }

    #[inline]
    pub fn mul(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::x86_64::_mm256_mul_ps(self.0, o.0)) }
    }

    #[inline]
    pub fn max(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::x86_64::_mm256_max_ps(self.0, o.0)) }
    }

    /// Lane values as an array (reduction folds run in lane order).
    #[inline]
    pub fn to_array(self) -> [f32; LANES] {
        let mut a = [0.0f32; LANES];
        self.store(&mut a);
        a
    }
}

#[cfg(all(target_arch = "x86_64", not(target_feature = "avx2")))]
impl F32xN {
    #[inline]
    pub fn splat(x: f32) -> Self {
        unsafe { F32xN(std::arch::x86_64::_mm_set1_ps(x)) }
    }

    /// Load the first `LANES` elements of `xs` (unaligned-tolerant).
    #[inline]
    pub fn load(xs: &[f32]) -> Self {
        debug_assert!(xs.len() >= LANES);
        unsafe { F32xN(std::arch::x86_64::_mm_loadu_ps(xs.as_ptr())) }
    }

    /// Store into the first `LANES` elements of `out`.
    #[inline]
    pub fn store(self, out: &mut [f32]) {
        debug_assert!(out.len() >= LANES);
        unsafe { std::arch::x86_64::_mm_storeu_ps(out.as_mut_ptr(), self.0) }
    }

    #[inline]
    pub fn add(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::x86_64::_mm_add_ps(self.0, o.0)) }
    }

    #[inline]
    pub fn sub(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::x86_64::_mm_sub_ps(self.0, o.0)) }
    }

    #[inline]
    pub fn mul(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::x86_64::_mm_mul_ps(self.0, o.0)) }
    }

    #[inline]
    pub fn max(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::x86_64::_mm_max_ps(self.0, o.0)) }
    }

    /// Lane values as an array (reduction folds run in lane order).
    #[inline]
    pub fn to_array(self) -> [f32; LANES] {
        let mut a = [0.0f32; LANES];
        self.store(&mut a);
        a
    }
}

#[cfg(target_arch = "aarch64")]
impl F32xN {
    #[inline]
    pub fn splat(x: f32) -> Self {
        unsafe { F32xN(std::arch::aarch64::vdupq_n_f32(x)) }
    }

    /// Load the first `LANES` elements of `xs` (unaligned-tolerant).
    #[inline]
    pub fn load(xs: &[f32]) -> Self {
        debug_assert!(xs.len() >= LANES);
        unsafe { F32xN(std::arch::aarch64::vld1q_f32(xs.as_ptr())) }
    }

    /// Store into the first `LANES` elements of `out`.
    #[inline]
    pub fn store(self, out: &mut [f32]) {
        debug_assert!(out.len() >= LANES);
        unsafe { std::arch::aarch64::vst1q_f32(out.as_mut_ptr(), self.0) }
    }

    #[inline]
    pub fn add(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::aarch64::vaddq_f32(self.0, o.0)) }
    }

    #[inline]
    pub fn sub(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::aarch64::vsubq_f32(self.0, o.0)) }
    }

    #[inline]
    pub fn mul(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::aarch64::vmulq_f32(self.0, o.0)) }
    }

    #[inline]
    pub fn max(self, o: Self) -> Self {
        unsafe { F32xN(std::arch::aarch64::vmaxq_f32(self.0, o.0)) }
    }

    /// Lane values as an array (reduction folds run in lane order).
    #[inline]
    pub fn to_array(self) -> [f32; LANES] {
        let mut a = [0.0f32; LANES];
        self.store(&mut a);
        a
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
impl F32xN {
    #[inline]
    pub fn splat(x: f32) -> Self {
        F32xN([x; LANES])
    }

    /// Load the first `LANES` elements of `xs`.
    #[inline]
    pub fn load(xs: &[f32]) -> Self {
        let mut a = [0.0f32; LANES];
        a.copy_from_slice(&xs[..LANES]);
        F32xN(a)
    }

    /// Store into the first `LANES` elements of `out`.
    #[inline]
    pub fn store(self, out: &mut [f32]) {
        out[..LANES].copy_from_slice(&self.0);
    }

    #[inline]
    pub fn add(self, o: Self) -> Self {
        let mut a = self.0;
        for (v, w) in a.iter_mut().zip(&o.0) {
            *v += w;
        }
        F32xN(a)
    }

    #[inline]
    pub fn sub(self, o: Self) -> Self {
        let mut a = self.0;
        for (v, w) in a.iter_mut().zip(&o.0) {
            *v -= w;
        }
        F32xN(a)
    }

    #[inline]
    pub fn mul(self, o: Self) -> Self {
        let mut a = self.0;
        for (v, w) in a.iter_mut().zip(&o.0) {
            *v *= w;
        }
        F32xN(a)
    }

    #[inline]
    pub fn max(self, o: Self) -> Self {
        let mut a = self.0;
        for (v, w) in a.iter_mut().zip(&o.0) {
            *v = v.max(*w);
        }
        F32xN(a)
    }

    /// Lane values as an array (reduction folds run in lane order).
    #[inline]
    pub fn to_array(self) -> [f32; LANES] {
        self.0
    }
}

impl F32xN {
    /// Horizontal sum, folding lanes in ascending order (one fixed
    /// reassociation vs the scalar loop — tolerance-pinned).
    #[inline]
    pub fn hsum(self) -> f32 {
        self.to_array().iter().sum()
    }

    /// Horizontal max in ascending lane order.
    #[inline]
    pub fn hmax(self) -> f32 {
        self.to_array()
            .iter()
            .fold(f32::NEG_INFINITY, |m, &v| m.max(v))
    }
}

// ---------------------------------------------------------------------------
// scalar complex helpers (the one true definition — moved from autograd)
// ---------------------------------------------------------------------------

/// `a · b` on split-complex scalars.
#[inline]
pub fn cmul(ar: f32, ai: f32, br: f32, bi: f32) -> (f32, f32) {
    (ar * br - ai * bi, ar * bi + ai * br)
}

/// `conj(a) · b` on split-complex scalars.
#[inline]
pub fn cmul_conj_a(ar: f32, ai: f32, br: f32, bi: f32) -> (f32, f32) {
    (ar * br + ai * bi, ar * bi - ai * br)
}

// ---------------------------------------------------------------------------
// real row kernels
// ---------------------------------------------------------------------------

/// `out[i] += a * x[i]` — element-wise, bit-identical across tiers.
pub fn axpy(out: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(out.len(), x.len());
    if force_scalar() {
        for (o, &xv) in out.iter_mut().zip(x) {
            *o += a * xv;
        }
        return;
    }
    let n = out.len();
    let av = F32xN::splat(a);
    let mut i = 0;
    while i + LANES <= n {
        let r = F32xN::load(&out[i..]).add(av.mul(F32xN::load(&x[i..])));
        r.store(&mut out[i..]);
        i += LANES;
    }
    for (o, &xv) in out[i..].iter_mut().zip(&x[i..]) {
        *o += a * xv;
    }
}

/// `out[i] += x[i]` — element-wise, bit-identical across tiers.
pub fn add_assign(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    if force_scalar() {
        for (o, &xv) in out.iter_mut().zip(x) {
            *o += xv;
        }
        return;
    }
    let n = out.len();
    let mut i = 0;
    while i + LANES <= n {
        let r = F32xN::load(&out[i..]).add(F32xN::load(&x[i..]));
        r.store(&mut out[i..]);
        i += LANES;
    }
    for (o, &xv) in out[i..].iter_mut().zip(&x[i..]) {
        *o += xv;
    }
}

/// `out[i] += a[i] * b[i]` — element-wise, bit-identical across tiers.
pub fn mul_acc(out: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    if force_scalar() {
        for (o, (&av, &bv)) in out.iter_mut().zip(a.iter().zip(b)) {
            *o += av * bv;
        }
        return;
    }
    let n = out.len();
    let mut i = 0;
    while i + LANES <= n {
        let r = F32xN::load(&out[i..])
            .add(F32xN::load(&a[i..]).mul(F32xN::load(&b[i..])));
        r.store(&mut out[i..]);
        i += LANES;
    }
    for (o, (&av, &bv)) in out[i..].iter_mut().zip(a[i..].iter().zip(&b[i..]))
    {
        *o += av * bv;
    }
}

/// `xs[i] *= s` — element-wise, bit-identical across tiers.
pub fn scale(xs: &mut [f32], s: f32) {
    if force_scalar() {
        for v in xs.iter_mut() {
            *v *= s;
        }
        return;
    }
    let n = xs.len();
    let sv = F32xN::splat(s);
    let mut i = 0;
    while i + LANES <= n {
        F32xN::load(&xs[i..]).mul(sv).store(&mut xs[i..]);
        i += LANES;
    }
    for v in xs[i..].iter_mut() {
        *v *= s;
    }
}

/// `Σ a[i]·b[i]` — LANES partial accumulators + ordered horizontal sum;
/// reassociates vs the scalar fold, tolerance-pinned.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    if force_scalar() || a.len() < LANES {
        let mut s = 0.0f32;
        for (&av, &bv) in a.iter().zip(b) {
            s += av * bv;
        }
        return s;
    }
    let n = a.len();
    let mut acc = F32xN::splat(0.0);
    let mut i = 0;
    while i + LANES <= n {
        acc = acc.add(F32xN::load(&a[i..]).mul(F32xN::load(&b[i..])));
        i += LANES;
    }
    let mut s = acc.hsum();
    for (&av, &bv) in a[i..].iter().zip(&b[i..]) {
        s += av * bv;
    }
    s
}

/// `Σ a[i]·b[i]·c[i]` — the LayerNorm-backward second moment.
/// Reassociates vs the scalar fold, tolerance-pinned.
pub fn dot3(a: &[f32], b: &[f32], c: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), c.len());
    if force_scalar() || a.len() < LANES {
        let mut s = 0.0f32;
        for ((&av, &bv), &cv) in a.iter().zip(b).zip(c) {
            s += av * bv * cv;
        }
        return s;
    }
    let n = a.len();
    let mut acc = F32xN::splat(0.0);
    let mut i = 0;
    while i + LANES <= n {
        acc = acc.add(F32xN::load(&a[i..])
            .mul(F32xN::load(&b[i..]))
            .mul(F32xN::load(&c[i..])));
        i += LANES;
    }
    let mut s = acc.hsum();
    for ((&av, &bv), &cv) in a[i..].iter().zip(&b[i..]).zip(&c[i..]) {
        s += av * bv * cv;
    }
    s
}

/// `Σ xs[i]` — reassociates vs the scalar fold, tolerance-pinned.
pub fn sum(xs: &[f32]) -> f32 {
    if force_scalar() || xs.len() < LANES {
        return xs.iter().sum();
    }
    let n = xs.len();
    let mut acc = F32xN::splat(0.0);
    let mut i = 0;
    while i + LANES <= n {
        acc = acc.add(F32xN::load(&xs[i..]));
        i += LANES;
    }
    let mut s = acc.hsum();
    for &v in &xs[i..] {
        s += v;
    }
    s
}

/// `Σ (xs[i] − mean)²` — reassociates, tolerance-pinned (LayerNorm
/// variance pass).
pub fn sumsq_diff(xs: &[f32], mean: f32) -> f32 {
    if force_scalar() || xs.len() < LANES {
        let mut s = 0.0f32;
        for &v in xs {
            let t = v - mean;
            s += t * t;
        }
        return s;
    }
    let n = xs.len();
    let mv = F32xN::splat(mean);
    let mut acc = F32xN::splat(0.0);
    let mut i = 0;
    while i + LANES <= n {
        let t = F32xN::load(&xs[i..]).sub(mv);
        acc = acc.add(t.mul(t));
        i += LANES;
    }
    let mut s = acc.hsum();
    for &v in &xs[i..] {
        let t = v - mean;
        s += t * t;
    }
    s
}

/// Row maximum (`NEG_INFINITY` on empty). Exact under reassociation for
/// the finite inputs the softmax path feeds it.
pub fn max(xs: &[f32]) -> f32 {
    if force_scalar() || xs.len() < LANES {
        return xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    }
    let n = xs.len();
    let mut acc = F32xN::load(xs);
    let mut i = LANES;
    while i + LANES <= n {
        acc = acc.max(F32xN::load(&xs[i..]));
        i += LANES;
    }
    let mut m = acc.hmax();
    for &v in &xs[i..] {
        m = m.max(v);
    }
    m
}

// ---------------------------------------------------------------------------
// split-complex row kernels (the pointwise spectra products)
// ---------------------------------------------------------------------------

/// `b[k] ← a[k] · b[k]` on split-complex rows — element-wise,
/// bit-identical across tiers.
pub fn cmul_rows(ar: &[f32], ai: &[f32], br: &mut [f32], bi: &mut [f32]) {
    let f = br.len();
    debug_assert!(ar.len() == f && ai.len() == f && bi.len() == f);
    if force_scalar() {
        for k in 0..f {
            let (re, im) = cmul(ar[k], ai[k], br[k], bi[k]);
            br[k] = re;
            bi[k] = im;
        }
        return;
    }
    let mut k = 0;
    while k + LANES <= f {
        let are = F32xN::load(&ar[k..]);
        let aim = F32xN::load(&ai[k..]);
        let bre = F32xN::load(&br[k..]);
        let bim = F32xN::load(&bi[k..]);
        are.mul(bre).sub(aim.mul(bim)).store(&mut br[k..]);
        are.mul(bim).add(aim.mul(bre)).store(&mut bi[k..]);
        k += LANES;
    }
    while k < f {
        let (re, im) = cmul(ar[k], ai[k], br[k], bi[k]);
        br[k] = re;
        bi[k] = im;
        k += 1;
    }
}

/// `b[k] ← conj(a[k]) · b[k]` on split-complex rows — element-wise,
/// bit-identical across tiers.
pub fn cmul_conj_a_rows(ar: &[f32], ai: &[f32], br: &mut [f32],
                        bi: &mut [f32]) {
    let f = br.len();
    debug_assert!(ar.len() == f && ai.len() == f && bi.len() == f);
    if force_scalar() {
        for k in 0..f {
            let (re, im) = cmul_conj_a(ar[k], ai[k], br[k], bi[k]);
            br[k] = re;
            bi[k] = im;
        }
        return;
    }
    let mut k = 0;
    while k + LANES <= f {
        let are = F32xN::load(&ar[k..]);
        let aim = F32xN::load(&ai[k..]);
        let bre = F32xN::load(&br[k..]);
        let bim = F32xN::load(&bi[k..]);
        are.mul(bre).add(aim.mul(bim)).store(&mut br[k..]);
        are.mul(bim).sub(aim.mul(bre)).store(&mut bi[k..]);
        k += LANES;
    }
    while k < f {
        let (re, im) = cmul_conj_a(ar[k], ai[k], br[k], bi[k]);
        br[k] = re;
        bi[k] = im;
        k += 1;
    }
}

/// `acc[k] += a[k] · b[k]` on split-complex rows — element-wise,
/// bit-identical across tiers.
pub fn cmul_acc_rows(ar: &[f32], ai: &[f32], br: &[f32], bi: &[f32],
                     acc_re: &mut [f32], acc_im: &mut [f32]) {
    let f = acc_re.len();
    debug_assert!(ar.len() == f && ai.len() == f && br.len() == f
                  && bi.len() == f && acc_im.len() == f);
    if force_scalar() {
        for k in 0..f {
            let (re, im) = cmul(ar[k], ai[k], br[k], bi[k]);
            acc_re[k] += re;
            acc_im[k] += im;
        }
        return;
    }
    let mut k = 0;
    while k + LANES <= f {
        let are = F32xN::load(&ar[k..]);
        let aim = F32xN::load(&ai[k..]);
        let bre = F32xN::load(&br[k..]);
        let bim = F32xN::load(&bi[k..]);
        F32xN::load(&acc_re[k..])
            .add(are.mul(bre).sub(aim.mul(bim)))
            .store(&mut acc_re[k..]);
        F32xN::load(&acc_im[k..])
            .add(are.mul(bim).add(aim.mul(bre)))
            .store(&mut acc_im[k..]);
        k += LANES;
    }
    while k < f {
        let (re, im) = cmul(ar[k], ai[k], br[k], bi[k]);
        acc_re[k] += re;
        acc_im[k] += im;
        k += 1;
    }
}

/// `acc[k] += conj(a[k]) · b[k]` on split-complex rows — element-wise,
/// bit-identical across tiers.
pub fn cmul_conj_a_acc_rows(ar: &[f32], ai: &[f32], br: &[f32], bi: &[f32],
                            acc_re: &mut [f32], acc_im: &mut [f32]) {
    let f = acc_re.len();
    debug_assert!(ar.len() == f && ai.len() == f && br.len() == f
                  && bi.len() == f && acc_im.len() == f);
    if force_scalar() {
        for k in 0..f {
            let (re, im) = cmul_conj_a(ar[k], ai[k], br[k], bi[k]);
            acc_re[k] += re;
            acc_im[k] += im;
        }
        return;
    }
    let mut k = 0;
    while k + LANES <= f {
        let are = F32xN::load(&ar[k..]);
        let aim = F32xN::load(&ai[k..]);
        let bre = F32xN::load(&br[k..]);
        let bim = F32xN::load(&bi[k..]);
        F32xN::load(&acc_re[k..])
            .add(are.mul(bre).add(aim.mul(bim)))
            .store(&mut acc_re[k..]);
        F32xN::load(&acc_im[k..])
            .add(are.mul(bim).sub(aim.mul(bre)))
            .store(&mut acc_im[k..]);
        k += LANES;
    }
    while k < f {
        let (re, im) = cmul_conj_a(ar[k], ai[k], br[k], bi[k]);
        acc_re[k] += re;
        acc_im[k] += im;
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adversarial lengths around the lane width, plus zero and one.
    fn shapes() -> Vec<usize> {
        vec![0, 1, LANES - 1, LANES, LANES + 1, 2 * LANES + 3, 37]
    }

    fn randv(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::data::Rng::new(seed);
        (0..len).map(|_| rng.normal()).collect()
    }

    /// Run `f` once under vector dispatch and once forced-scalar,
    /// returning both results.
    fn both<T>(mut f: impl FnMut() -> T) -> (T, T) {
        set_force_scalar(false);
        let fast = f();
        set_force_scalar(true);
        let slow = f();
        set_force_scalar(false);
        (fast, slow)
    }

    #[test]
    fn elementwise_kernels_bit_match_scalar() {
        for n in shapes() {
            let x = randv(n, 1);
            let y = randv(n, 2);
            let (a, b) = both(|| {
                let mut o = y.clone();
                axpy(&mut o, &x, 1.5);
                o
            });
            assert_eq!(a, b, "axpy n={n}");
            let (a, b) = both(|| {
                let mut o = y.clone();
                add_assign(&mut o, &x);
                o
            });
            assert_eq!(a, b, "add_assign n={n}");
            let (a, b) = both(|| {
                let mut o = x.clone();
                scale(&mut o, -0.37);
                o
            });
            assert_eq!(a, b, "scale n={n}");
            let (a, b) = both(|| {
                let mut o = y.clone();
                mul_acc(&mut o, &x, &y);
                o
            });
            assert_eq!(a, b, "mul_acc n={n}");
        }
    }

    #[test]
    fn reductions_match_scalar_within_tolerance() {
        for n in shapes() {
            let x = randv(n, 3);
            let y = randv(n, 4);
            let (a, b) = both(|| dot(&x, &y));
            assert!((a - b).abs() <= 1e-5 * a.abs().max(b.abs()).max(1.0),
                    "dot n={n}: {a} vs {b}");
            let (a, b) = both(|| dot3(&x, &y, &x));
            assert!((a - b).abs() <= 1e-5 * a.abs().max(b.abs()).max(1.0),
                    "dot3 n={n}: {a} vs {b}");
            let (a, b) = both(|| sum(&x));
            assert!((a - b).abs() <= 1e-5 * a.abs().max(b.abs()).max(1.0),
                    "sum n={n}: {a} vs {b}");
            let (a, b) = both(|| sumsq_diff(&x, 0.25));
            assert!((a - b).abs() <= 1e-5 * a.abs().max(b.abs()).max(1.0),
                    "sumsq n={n}: {a} vs {b}");
            let (a, b) = both(|| max(&x));
            assert_eq!(a.to_bits(), b.to_bits(), "max n={n}");
        }
    }

    #[test]
    fn complex_rows_bit_match_scalar() {
        for n in shapes() {
            let ar = randv(n, 5);
            let ai = randv(n, 6);
            let br = randv(n, 7);
            let bi = randv(n, 8);
            let (a, b) = both(|| {
                let (mut r, mut i) = (br.clone(), bi.clone());
                cmul_rows(&ar, &ai, &mut r, &mut i);
                (r, i)
            });
            assert_eq!(a, b, "cmul_rows n={n}");
            let (a, b) = both(|| {
                let (mut r, mut i) = (br.clone(), bi.clone());
                cmul_conj_a_rows(&ar, &ai, &mut r, &mut i);
                (r, i)
            });
            assert_eq!(a, b, "cmul_conj_a_rows n={n}");
            let (a, b) = both(|| {
                let (mut r, mut i) = (vec![0.1f32; n], vec![-0.2f32; n]);
                cmul_acc_rows(&ar, &ai, &br, &bi, &mut r, &mut i);
                (r, i)
            });
            assert_eq!(a, b, "cmul_acc_rows n={n}");
            let (a, b) = both(|| {
                let (mut r, mut i) = (vec![0.1f32; n], vec![-0.2f32; n]);
                cmul_conj_a_acc_rows(&ar, &ai, &br, &bi, &mut r, &mut i);
                (r, i)
            });
            assert_eq!(a, b, "cmul_conj_a_acc_rows n={n}");
        }
    }

    #[test]
    fn negative_zero_and_subnormals_bit_match() {
        // adversarial values: −0.0, subnormals, mixed tiny magnitudes
        let tiny = f32::from_bits(1); // smallest positive subnormal
        let x = vec![-0.0, tiny, -tiny, 1.0e-38, -1.0e-38, 0.0, 2.5,
                     -0.0, tiny, -0.0, 1.5e-39];
        let y = vec![-0.0, -tiny, tiny, -1.0e-38, 1.0e-38, -0.0, -2.5,
                     tiny, -0.0, 0.0, -1.5e-39];
        let (a, b) = both(|| {
            let mut o = y.clone();
            axpy(&mut o, &x, -0.0);
            o.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        });
        assert_eq!(a, b, "axpy on -0/subnormals");
        let (a, b) = both(|| {
            let (mut r, mut i) = (x.clone(), y.clone());
            cmul_rows(&x, &y, &mut r, &mut i);
            (r.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
             i.iter().map(|v| v.to_bits()).collect::<Vec<_>>())
        });
        assert_eq!(a, b, "cmul_rows on -0/subnormals");
        let (a, b) = both(|| max(&x));
        assert_eq!(a.to_bits(), b.to_bits(), "max on -0/subnormals");
    }

    #[test]
    fn global_force_scalar_reaches_other_threads() {
        set_force_scalar_global(true);
        let seen = std::thread::spawn(force_scalar).join().unwrap();
        set_force_scalar_global(false);
        assert!(seen, "global forced-scalar must reach spawned threads");
        assert!(!force_scalar());
    }
}
