//! Native backend: CAT computed in pure Rust, no PJRT artifacts required.
//!
//! Two layers:
//!
//! * [`fft`] — planned radix-2 complex FFT + packed real FFT with a global
//!   per-length plan cache (twiddles and bit-reversal computed once, zero
//!   allocation in the transform hot loops);
//! * [`cat`] — the CAT mixing layer (FFT and O(N²) gather reference), a
//!   native softmax-attention baseline, and the hermetic serving model
//!   ([`NativeCatModel`]).
//!
//! This is the `Backend::Native` half of the backend story (DESIGN.md §6):
//! the coordinator serves and the benches measure real CAT wallclock even
//! in a fresh checkout with no `artifacts/` directory and no XLA runtime.

pub mod cat;
pub mod fft;

pub use cat::{matmul, softmax_in_place, AttentionLayer, CatImpl, CatLayer,
              NativeCatModel, NativeVitConfig};
pub use fft::{plan_cache_stats, rfft_plan, Complex, FftPlan, RfftPlan};
