//! Native backend: CAT computed in pure Rust, no PJRT artifacts required.
//!
//! Four layers:
//!
//! * [`pool`] — the persistent worker pool every parallel section runs
//!   on: spawned once, channel-fed task chunks, zero thread spawns at
//!   steady state ([`pool::stats`] is asserted by the serving benches);
//! * [`arena`] — per-thread bump arenas (model / layer / task levels) so
//!   forwards are allocation-free after warmup; frames hand out
//!   32-byte-aligned slices for the vector tier;
//! * [`simd`] — the portable vector layer ([`simd::F32xN`] +
//!   forced-scalar dispatch): every hot inner loop in [`fft`], [`cat`],
//!   [`autograd`] and [`mixer::kernels`] runs through it, with the
//!   scalar loops retained as equivalence oracles (DESIGN.md §15);
//! * [`fft`] — planned FFTs: the radix-2 reference tier ([`FftPlan`],
//!   [`RfftPlan`]) plus the split-complex Stockham radix-4 throughput
//!   tier ([`SplitRfftPlan`]) with batched `rfft_many`/`irfft_many`,
//!   both behind global per-length plan caches;
//! * [`cat`] — the CAT mixing layer (batched-FFT and O(N²) gather
//!   reference), a native softmax-attention baseline, and the hermetic
//!   serving model ([`NativeCatModel`]);
//! * [`mixer`] — the mixer registry ([`REGISTRY`]): ids, param-count
//!   formulas, capability flags, per-layer schedules, and the single
//!   train/serve dispatch over every registered mixer (FNet and the
//!   circulant-attention variant live here);
//! * [`autograd`] — reverse-mode gradients for the full CAT block
//!   (frequency-domain circular-correlation backward, softmax-over-N,
//!   LayerNorm/MLP/attention backwards) and the trainable
//!   [`TrainModel`] behind `cat train --backend native` (DESIGN.md §8);
//! * [`optim`] — [`AdamW`] with global-norm clipping, flat moment
//!   vectors in the model's tensor visitor order.
//!
//! This is the `Backend::Native` half of the backend story (DESIGN.md §6):
//! the coordinator serves, the benches measure, and the trainer *trains*
//! real CAT models even in a fresh checkout with no `artifacts/`
//! directory and no XLA runtime.

pub mod arena;
pub mod autograd;
pub mod cat;
pub mod fft;
pub mod mixer;
pub mod optim;
pub mod pool;
pub mod simd;

pub use autograd::{attention_backward, causal_corr_backward,
                   causal_corr_backward_batched, causal_corr_forward,
                   causal_corr_forward_batched, colsum_acc,
                   colsum_acc_naive, corr_backward, corr_forward,
                   matmul_xt_acc, matmul_xt_acc_naive, naive_backward,
                   set_naive_backward, EvalOut, TaskKind, TrainBatch,
                   TrainConfig, TrainModel};
pub use cat::{matmul, softmax_in_place, AttentionLayer, CatImpl, CatLayer,
              NativeCatModel, NativeVitConfig};
pub use mixer::{Mixer, MixerSpec, CONV_TAPS, REGISTRY};
pub(crate) use mixer::serve::ServeMixer;
pub use fft::{plan_cache_stats, rfft_plan, split_rfft_plan, Complex,
              FftPlan, RfftPlan, SplitRfftPlan};
pub use optim::AdamW;
