//! Native reverse-mode autograd for the full CAT block — the gradient
//! engine behind `cat train --backend native`.
//!
//! The paper's headline claims (Tables 1–3) are *training* results, but
//! until this module everything trainable lived behind `--features pjrt`
//! and AOT artifacts that do not exist in a fresh checkout. Here the
//! backward of every op in the forward stack is computed directly on the
//! host, reusing the PR-1/2 machinery (planned split-complex rFFTs, the
//! persistent pool, the task arenas) at the same O(N log N) cost as the
//! forward:
//!
//! * **Circular cross-correlation** (the CAT mix `o[i] = Σ_k p[k]·v[i+k]`):
//!   the gradient of a circular correlation is itself circular —
//!   `dv = conv(do, p) = irfft(dOf ⊙ Zf)` and
//!   `dp = corr(do, v) = irfft(Σ_c conj(dOf_c) ⊙ Vf_c)` — so backward is
//!   two more batched rFFT sweeps over the same `(batch·head)` stripes.
//! * **Causal CAT** (this repo's sub-quadratic extension): forward is the
//!   zero-padded length-2N linear convolution `o[i] = Σ_{j≤i} p[i−j]·v[j]`;
//!   backward mirrors it with conjugate products at 2N.
//! * **Softmax-over-N**, **LayerNorm**, the merged **W_A/W_V projections**,
//!   the 2×-wide **ReLU MLP**, mean-pool/classifier and LM heads, and a
//!   row-streamed **softmax-attention** mixer (the parity baseline, full
//!   and causal) all have hand-derived backwards below.
//!
//! Every formula is validated by finite-difference property tests in
//! `tests/proptests.rs` (central differences, f32) and was cross-checked
//! against a numpy mirror during development.
//!
//! Determinism contract: every parallel section writes disjoint outputs
//! and performs its accumulations in a fixed serial order *inside* one
//! task, so loss curves are bit-identical regardless of pool width
//! (asserted in `tests/native_backend.rs`).
//!
//! Memory model (DESIGN.md §8): parameters and gradients are mirrored
//! [`ModelParams`] trees of plain `Vec<f32>` tensors; activation caches
//! live in a grow-only `Scratch` owned by the [`TrainModel`] — they must
//! survive from forward to backward, so they cannot use the per-thread
//! frame arenas — while per-task FFT scratch inside parallel sections
//! still comes from [`super::arena::with_task_arena`]. After the first
//! step, a same-shape train step performs zero tensor-sized heap
//! allocation.

use std::cell::{Cell, RefCell};

use anyhow::{bail, ensure};

use super::arena;
use super::cat::{matmul, softmax_in_place};
use super::fft::{split_rfft_plan, SplitRfftPlan};
use super::mixer::{self, train::MixerParams, Mixer};
use super::pool;
use super::simd;
use crate::data::Rng;
use crate::Result;

/// Serial-fallback threshold, matching [`matmul`]'s sizing logic.
const PAR_FLOPS: usize = 1 << 21;

/// Row-tile height of the blocked `xᵀ·dy` kernel: inside one k-chunk the
/// row walk advances in tiles this tall so the `dy` tile stays cache-hot
/// across the whole k sweep. Blocks only regroup the loop nest — for any
/// fixed `(k, j)` the row accumulation stays flat-ascending.
const XT_ROW_TILE: usize = 64;
/// Column-tile width of the blocked `xᵀ·dy` kernel.
const XT_COL_TILE: usize = 64;
/// Minimum `inner` (k-rows of `dw`) before the k-parallel strategy can
/// feed the pool; narrower weight gradients parallelize over row blocks
/// with partial accumulators instead.
const XT_K_PAR_MIN: usize = 64;
/// Row-block length of the partial-accumulator strategies (the narrow
/// `matmul_xt_acc` path and parallel [`colsum_acc`]). Shape-only by
/// design: the 2-level summation tree it induces (flat-ascending inside
/// a block, blocks reduced in ascending order) is a pure function of the
/// operand shapes, never of the pool width (DESIGN.md §9).
const ROW_BLOCK: usize = 256;
/// Row-panel height of the stripe-blocked attention backward.
const ATTN_PANEL: usize = 32;
/// Column-tile width of the stripe-blocked attention backward.
const ATTN_COL_TILE: usize = 64;

thread_local! {
    /// When set (always consulted on the *calling* thread — kernel
    /// strategy is chosen before any parallel section fans out), the
    /// backward pass routes through the pre-tiling PR-3 reference
    /// kernels. Those references are the equivalence oracles for the
    /// tiled paths (`tests/proptests.rs`) and the baseline that
    /// `benches/trainstep.rs --check` must beat.
    static NAIVE_BACKWARD: Cell<bool> = const { Cell::new(false) };
    /// Caller-side grow-only buffer for the partial-accumulator
    /// reductions; parallel tasks borrow disjoint `chunks_mut` of it.
    static PARTIALS: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Route backward passes issued from this thread through the naive
/// reference kernels (`true`) or the tiled production kernels (`false`,
/// the default). Thread-local so concurrent tests cannot perturb each
/// other.
pub fn set_naive_backward(on: bool) {
    NAIVE_BACKWARD.with(|f| f.set(on));
}

/// Is this thread currently routing backwards through the naive
/// reference kernels?
pub fn naive_backward() -> bool {
    NAIVE_BACKWARD.with(|f| f.get())
}

/// Borrow this thread's partial-reduction buffer at `len` elements
/// (grow-only; contents are stale — tasks must overwrite).
fn with_partials<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    PARTIALS.with(|p| {
        let mut p = p.borrow_mut();
        if p.len() < len {
            p.resize(len, 0.0);
        }
        f(&mut p[..len])
    })
}

pub(crate) fn ensure_len(buf: &mut Vec<f32>, len: usize) {
    if buf.len() != len {
        buf.resize(len, 0.0);
    }
}

// ---------------------------------------------------------------------------
// dense linear-algebra backwards
// ---------------------------------------------------------------------------

/// `dx = dy @ wᵀ` (or `dx +=` when `accumulate`): `dy: (rows, cols)`,
/// `w: (inner, cols)` row-major as in the forward [`matmul`],
/// `dx: (rows, inner)`. Row-parallel; each output row is a fixed-order
/// dot-product sweep, so results are pool-width invariant.
pub fn matmul_wt(dy: &[f32], rows: usize, cols: usize, w: &[f32],
                 inner: usize, dx: &mut [f32], accumulate: bool) {
    debug_assert_eq!(dy.len(), rows * cols);
    debug_assert_eq!(w.len(), inner * cols);
    debug_assert_eq!(dx.len(), rows * inner);
    let body = |dyrow: &[f32], dxrow: &mut [f32]| {
        for (k, slot) in dxrow.iter_mut().enumerate() {
            let s = simd::dot(dyrow, &w[k * cols..(k + 1) * cols]);
            if accumulate {
                *slot += s;
            } else {
                *slot = s;
            }
        }
    };
    if rows * inner * cols < PAR_FLOPS {
        for (dyrow, dxrow) in
            dy.chunks_exact(cols).zip(dx.chunks_exact_mut(inner)) {
            body(dyrow, dxrow);
        }
        return;
    }
    let chunks = pool::max_parallel_tasks().min(rows).max(1);
    let chunk_rows = (rows + chunks - 1) / chunks;
    let tasks: Vec<(&[f32], &mut [f32])> = dx
        .chunks_mut(chunk_rows * inner)
        .enumerate()
        .map(|(ci, dc)| {
            let r0 = ci * chunk_rows;
            let nrows = dc.len() / inner;
            (&dy[r0 * cols..(r0 + nrows) * cols], dc)
        })
        .collect();
    pool::run(tasks, 2 * chunk_rows * inner * cols, |(dyc, dxc)| {
        for (dyrow, dxrow) in
            dyc.chunks_exact(cols).zip(dxc.chunks_exact_mut(inner)) {
            body(dyrow, dxrow);
        }
    });
}

/// Accumulate rows `r0..r0+rb` of the `xᵀ·dy` product into the k-rows
/// `k0..k0+dwc.len()/cols` of `dw` (`dwc`), walking (k, j) tiles so one
/// `dy` tile stays cache-hot across the whole k sweep. For any fixed
/// `(k, j)` slot the row accumulation runs ascending.
fn xt_block(x: &[f32], inner: usize, dy: &[f32], cols: usize, r0: usize,
            rb: usize, k0: usize, dwc: &mut [f32]) {
    let kb = dwc.len() / cols;
    let mut j0 = 0;
    while j0 < cols {
        let jb = XT_COL_TILE.min(cols - j0);
        for ki in 0..kb {
            let k = k0 + ki;
            let dwrow = &mut dwc[ki * cols + j0..ki * cols + j0 + jb];
            for r in r0..r0 + rb {
                let xv = x[r * inner + k];
                if xv != 0.0 {
                    simd::axpy(dwrow,
                               &dy[r * cols + j0..r * cols + j0 + jb], xv);
                }
            }
        }
        j0 += jb;
    }
}

/// [`xt_block`] over every row of `x`/`dy` in [`XT_ROW_TILE`] tiles —
/// the serial and k-parallel tiled bodies. Per-slot summation order is
/// flat row-ascending (tiles only partition the loop), i.e. identical
/// to [`matmul_xt_acc_naive`].
fn xt_tile_body(x: &[f32], rows: usize, inner: usize, dy: &[f32],
                cols: usize, k0: usize, dwc: &mut [f32]) {
    let mut r0 = 0;
    while r0 < rows {
        let rb = XT_ROW_TILE.min(rows - r0);
        xt_block(x, inner, dy, cols, r0, rb, k0, dwc);
        r0 += rb;
    }
}

/// `dw += xᵀ @ dy`: `x: (rows, inner)`, `dy: (rows, cols)`,
/// `dw: (inner, cols)` — the weight-gradient hot spot of every dense
/// layer. Tiled (DESIGN.md §9): the kernel walks (k, j) tiles inside
/// [`XT_ROW_TILE`]-row blocks so the `dy` tile is reused across the k
/// sweep instead of re-streamed once per k-row. Two parallel strategies,
/// chosen by shape alone:
///
/// * `inner ≥ XT_K_PAR_MIN`: parallel over k-row chunks of `dw`; every
///   `dw[k, j]` still sums its rows flat-ascending, so the result is
///   bit-identical to the naive reference *and* across pool widths;
/// * narrow `dw` with many rows: parallel over [`ROW_BLOCK`]-row blocks
///   into per-task partial accumulators, reduced serially in ascending
///   block order — a fixed 2-level summation tree, bit-identical across
///   pool widths (though not to the flat naive order; equivalence vs the
///   oracle is pinned at f32 tolerance in `tests/proptests.rs`).
pub fn matmul_xt_acc(x: &[f32], rows: usize, inner: usize, dy: &[f32],
                     cols: usize, dw: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * inner);
    debug_assert_eq!(dy.len(), rows * cols);
    debug_assert_eq!(dw.len(), inner * cols);
    if naive_backward() {
        matmul_xt_acc_naive(x, rows, inner, dy, cols, dw);
        return;
    }
    if rows * inner * cols < PAR_FLOPS {
        xt_tile_body(x, rows, inner, dy, cols, 0, dw);
        return;
    }
    if inner >= XT_K_PAR_MIN {
        let chunks = pool::max_parallel_tasks().min(inner).max(1);
        let chunk_k = (inner + chunks - 1) / chunks;
        let tasks: Vec<(usize, &mut [f32])> =
            dw.chunks_mut(chunk_k * cols).enumerate().collect();
        pool::run(tasks, 2 * chunk_k * rows * cols, |(ci, dwc)| {
            xt_tile_body(x, rows, inner, dy, cols, ci * chunk_k, dwc);
        });
        return;
    }
    // narrow dw, many rows: per-row-block partial accumulators
    let n_blocks = (rows + ROW_BLOCK - 1) / ROW_BLOCK;
    let tile = inner * cols;
    with_partials(n_blocks * tile, |partials| {
        let tasks: Vec<(usize, &mut [f32])> =
            partials.chunks_mut(tile).enumerate().collect();
        pool::run(tasks, 2 * ROW_BLOCK * tile, |(bi, part)| {
            part.fill(0.0);
            let r0 = bi * ROW_BLOCK;
            let rb = ROW_BLOCK.min(rows - r0);
            let mut sub = r0;
            while sub < r0 + rb {
                let sb = XT_ROW_TILE.min(r0 + rb - sub);
                xt_block(x, inner, dy, cols, sub, sb, 0, part);
                sub += sb;
            }
        });
        // fixed-order reduction: ascending block index, serial
        for part in partials.chunks_exact(tile) {
            simd::add_assign(dw, part);
        }
    });
}

/// The PR-3 reference `xᵀ·dy`: each k-row of `dw` walks all `rows`
/// serially (k-chunk parallel, no tiling). Kept as the equivalence
/// oracle for [`matmul_xt_acc`] and as the baseline the `trainstep`
/// bench's `--check` gate must beat.
pub fn matmul_xt_acc_naive(x: &[f32], rows: usize, inner: usize,
                           dy: &[f32], cols: usize, dw: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * inner);
    debug_assert_eq!(dy.len(), rows * cols);
    debug_assert_eq!(dw.len(), inner * cols);
    let body = |k0: usize, dwc: &mut [f32]| {
        for (ki, dwrow) in dwc.chunks_exact_mut(cols).enumerate() {
            let k = k0 + ki;
            for (xrow, dyrow) in
                x.chunks_exact(inner).zip(dy.chunks_exact(cols)) {
                let xv = xrow[k];
                if xv != 0.0 {
                    simd::axpy(dwrow, dyrow, xv);
                }
            }
        }
    };
    if rows * inner * cols < PAR_FLOPS {
        body(0, dw);
        return;
    }
    let chunks = pool::max_parallel_tasks().min(inner).max(1);
    let chunk_k = (inner + chunks - 1) / chunks;
    let tasks: Vec<(usize, &mut [f32])> =
        dw.chunks_mut(chunk_k * cols).enumerate().collect();
    pool::run(tasks, 2 * chunk_k * rows * cols, |(ci, dwc)| {
        body(ci * chunk_k, dwc);
    });
}

/// `db[j] += Σ_r dy[r, j]` (bias gradients). Large shapes parallelize
/// over [`ROW_BLOCK`]-row blocks into per-task partial sums reduced in
/// ascending block order (the same fixed 2-level tree as
/// [`matmul_xt_acc`]'s narrow strategy); small shapes run serial
/// flat-ascending. Both orders are functions of the shape alone.
pub fn colsum_acc(dy: &[f32], cols: usize, db: &mut [f32]) {
    debug_assert_eq!(db.len(), cols);
    let rows = if cols == 0 { 0 } else { dy.len() / cols };
    if naive_backward() || rows * cols < (1 << 20) || rows < 2 * ROW_BLOCK {
        colsum_acc_naive(dy, cols, db);
        return;
    }
    let n_blocks = (rows + ROW_BLOCK - 1) / ROW_BLOCK;
    with_partials(n_blocks * cols, |partials| {
        let tasks: Vec<(usize, &mut [f32])> =
            partials.chunks_mut(cols).enumerate().collect();
        pool::run(tasks, 2 * ROW_BLOCK * cols, |(bi, part)| {
            part.fill(0.0);
            let r0 = bi * ROW_BLOCK;
            let rb = ROW_BLOCK.min(rows - r0);
            for dyrow in
                dy[r0 * cols..(r0 + rb) * cols].chunks_exact(cols) {
                simd::add_assign(part, dyrow);
            }
        });
        for part in partials.chunks_exact(cols) {
            simd::add_assign(db, part);
        }
    });
}

/// The PR-3 reference column sum: fully serial, flat-ascending. Oracle
/// for [`colsum_acc`] and the `trainstep` naive baseline.
pub fn colsum_acc_naive(dy: &[f32], cols: usize, db: &mut [f32]) {
    debug_assert_eq!(db.len(), cols);
    for dyrow in dy.chunks_exact(cols) {
        simd::add_assign(db, dyrow);
    }
}

// ---------------------------------------------------------------------------
// layernorm + softmax backwards
// ---------------------------------------------------------------------------

/// Per-row normalization cache: `xhat` (rows·d) and `1/σ` (rows).
#[derive(Default)]
struct LnCache {
    xhat: Vec<f32>,
    inv: Vec<f32>,
}

const LN_EPS: f32 = 1e-5;

/// `y = x̂·γ + β` per `d`-row, caching `x̂` and `1/σ` for backward.
fn layernorm_fwd(x: &[f32], gamma: &[f32], beta: &[f32], y: &mut [f32],
                 cache: &mut LnCache) {
    let d = gamma.len();
    let rows = x.len() / d;
    ensure_len(&mut cache.xhat, rows * d);
    ensure_len(&mut cache.inv, rows);
    for (((xrow, yrow), hrow), inv) in x
        .chunks_exact(d)
        .zip(y.chunks_exact_mut(d))
        .zip(cache.xhat.chunks_exact_mut(d))
        .zip(cache.inv.iter_mut())
    {
        let mean = simd::sum(xrow) / d as f32;
        let var = simd::sumsq_diff(xrow, mean) / d as f32;
        *inv = 1.0 / (var + LN_EPS).sqrt();
        for c in 0..d {
            hrow[c] = (xrow[c] - mean) * *inv;
            yrow[c] = hrow[c] * gamma[c] + beta[c];
        }
    }
}

/// LayerNorm backward: `dx = σ⁻¹·(dŷ − mean(dŷ) − x̂·mean(dŷ⊙x̂))` with
/// `dŷ = dy⊙γ`; accumulates `dγ += Σ dy⊙x̂`, `dβ += Σ dy`.
fn layernorm_bwd(dy: &[f32], gamma: &[f32], cache: &LnCache,
                 dgamma: &mut [f32], dbeta: &mut [f32], dx: &mut [f32]) {
    let d = gamma.len();
    for (((dyrow, hrow), inv), dxrow) in dy
        .chunks_exact(d)
        .zip(cache.xhat.chunks_exact(d))
        .zip(cache.inv.iter())
        .zip(dx.chunks_exact_mut(d))
    {
        simd::mul_acc(dgamma, dyrow, hrow);
        simd::add_assign(dbeta, dyrow);
        let m1 = simd::dot(dyrow, gamma) / d as f32;
        let m2 = simd::dot3(dyrow, gamma, hrow) / d as f32;
        for c in 0..d {
            let dh = dyrow[c] * gamma[c];
            dxrow[c] = inv * (dh - m1 - hrow[c] * m2);
        }
    }
}

/// In-place softmax backward over one row: `dp ← p ⊙ (dp − p·dp)`.
pub(crate) fn softmax_bwd_in_place(p: &[f32], dp: &mut [f32]) {
    let dot = simd::dot(p, dp);
    for (pv, dv) in p.iter().zip(dp.iter_mut()) {
        *dv = pv * (*dv - dot);
    }
}

// ---------------------------------------------------------------------------
// circular-correlation stripe kernels (forward + backward, FFT domain)
// ---------------------------------------------------------------------------

/// One stripe of the non-causal CAT apply:
/// `out[c,i] = Σ_k p[k]·v[c,(i+k)%n]` over `dh` channel rows, one batched
/// rFFT sweep. Buffer lengths: `zre/zim: f`, `vre/vim: dh·f`,
/// `scratch`: [`SplitRfftPlan::scratch_len`] where `f = n/2+1`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn corr_fwd_stripe(plan: &SplitRfftPlan, p: &[f32], v: &[f32],
                              dh: usize,
                   out: &mut [f32], zre: &mut [f32], zim: &mut [f32],
                   vre: &mut [f32], vim: &mut [f32], scratch: &mut [f32]) {
    let f = plan.spectrum_len();
    plan.rfft(p, zre, zim, scratch);
    plan.rfft_many(v, dh, vre, vim, scratch);
    for c in 0..dh {
        simd::cmul_conj_a_rows(zre, zim, &mut vre[c * f..(c + 1) * f],
                               &mut vim[c * f..(c + 1) * f]);
    }
    plan.irfft_many(vre, vim, dh, out, scratch);
}

/// Backward of [`corr_fwd_stripe`]: given upstream `dout` (`dh` rows),
/// `dv[c] = conv(dout[c], p) = irfft(dOf_c ⊙ Zf)` and
/// `dp = Σ_c corr(dout[c], v[c]) = irfft(Σ_c conj(dOf_c) ⊙ Vf_c)`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn corr_bwd_stripe(plan: &SplitRfftPlan, p: &[f32], v: &[f32],
                   dout: &[f32], dh: usize, dp: &mut [f32],
                   dv: &mut [f32], zre: &mut [f32], zim: &mut [f32],
                   vre: &mut [f32], vim: &mut [f32], gre: &mut [f32],
                   gim: &mut [f32], acc_re: &mut [f32], acc_im: &mut [f32],
                   scratch: &mut [f32]) {
    let f = plan.spectrum_len();
    plan.rfft(p, zre, zim, scratch);
    plan.rfft_many(v, dh, vre, vim, scratch);
    plan.rfft_many(dout, dh, gre, gim, scratch);
    acc_re.fill(0.0);
    acc_im.fill(0.0);
    for c in 0..dh {
        let gr = &mut gre[c * f..(c + 1) * f];
        let gi = &mut gim[c * f..(c + 1) * f];
        let vr = &vre[c * f..(c + 1) * f];
        let vi = &vim[c * f..(c + 1) * f];
        // dp spectrum += conj(dOf_c) ⊙ Vf_c, then dOf_c ← dOf_c ⊙ Zf
        simd::cmul_conj_a_acc_rows(gr, gi, vr, vi, acc_re, acc_im);
        simd::cmul_rows(zre, zim, gr, gi);
    }
    plan.irfft_many(gre, gim, dh, dv, scratch);
    plan.irfft(acc_re, acc_im, dp, scratch);
}

/// One stripe of the **causal** CAT apply (zero-padded length-2N linear
/// convolution): `out[c,i] = Σ_{j≤i} p[i−j]·v[c,j]`. `plan2` is the 2n
/// plan; `pad`/`row2` hold one length-2n row, spectra buffers hold
/// `f₂ = n+1` bins.
#[allow(clippy::too_many_arguments)]
fn causal_fwd_stripe(plan2: &SplitRfftPlan, p: &[f32], v: &[f32], dh: usize,
                     out: &mut [f32], pad: &mut [f32], zre: &mut [f32],
                     zim: &mut [f32], vre: &mut [f32], vim: &mut [f32],
                     row2: &mut [f32], scratch: &mut [f32]) {
    let n = p.len();
    let f = plan2.spectrum_len();
    pad[..n].copy_from_slice(p);
    pad[n..].fill(0.0);
    plan2.rfft(pad, zre, zim, scratch);
    for c in 0..dh {
        pad[..n].copy_from_slice(&v[c * n..(c + 1) * n]);
        pad[n..].fill(0.0);
        plan2.rfft(pad, vre, vim, scratch);
        simd::cmul_rows(zre, zim, vre, vim);
        plan2.irfft(vre, vim, row2, scratch);
        out[c * n..(c + 1) * n].copy_from_slice(&row2[..n]);
    }
}

/// Backward of [`causal_fwd_stripe`]: with zero-padded spectra,
/// `dv[c] = irfft(conj(Zf₂) ⊙ dOf₂_c)[..n]` and
/// `dp = irfft(Σ_c conj(Vf₂_c) ⊙ dOf₂_c)[..n]`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn causal_bwd_stripe(plan2: &SplitRfftPlan, p: &[f32], v: &[f32],
                     dout: &[f32], dh: usize, dp: &mut [f32],
                     dv: &mut [f32], pad: &mut [f32], zre: &mut [f32],
                     zim: &mut [f32], vre: &mut [f32], vim: &mut [f32],
                     gre: &mut [f32], gim: &mut [f32], tre: &mut [f32],
                     tim: &mut [f32], acc_re: &mut [f32],
                     acc_im: &mut [f32], row2: &mut [f32],
                     scratch: &mut [f32]) {
    let n = p.len();
    let f = plan2.spectrum_len();
    pad[..n].copy_from_slice(p);
    pad[n..].fill(0.0);
    plan2.rfft(pad, zre, zim, scratch);
    acc_re.fill(0.0);
    acc_im.fill(0.0);
    for c in 0..dh {
        pad[..n].copy_from_slice(&dout[c * n..(c + 1) * n]);
        pad[n..].fill(0.0);
        plan2.rfft(pad, gre, gim, scratch);
        pad[..n].copy_from_slice(&v[c * n..(c + 1) * n]);
        pad[n..].fill(0.0);
        plan2.rfft(pad, vre, vim, scratch);
        simd::cmul_conj_a_acc_rows(vre, vim, gre, gim, acc_re, acc_im);
        tre.copy_from_slice(gre);
        tim.copy_from_slice(gim);
        simd::cmul_conj_a_rows(zre, zim, tre, tim);
        plan2.irfft(tre, tim, row2, scratch);
        dv[c * n..(c + 1) * n].copy_from_slice(&row2[..n]);
    }
    plan2.irfft(acc_re, acc_im, row2, scratch);
    dp.copy_from_slice(&row2[..n]);
}

/// Batched causal forward stripe: all `dh` channel rows are zero-padded
/// into one `(dh, 2n)` block and swept with a single
/// `rfft_many`/`irfft_many` pair, so the 2n plan's twiddle tables stay
/// hot across the whole stripe instead of being re-walked per channel.
/// Bit-identical to [`causal_fwd_stripe`] (`rfft_many` is a fixed
/// per-row loop). Buffers: `pad2`/`out2`: `dh·2n`, `zre/zim`: `f₂`,
/// `vre/vim`: `dh·f₂` where `f₂ = n + 1`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn causal_fwd_stripe_batched(
    plan2: &SplitRfftPlan, p: &[f32], v: &[f32],
                             dh: usize, out: &mut [f32], pad2: &mut [f32],
                             zre: &mut [f32], zim: &mut [f32],
                             vre: &mut [f32], vim: &mut [f32],
                             out2: &mut [f32], scratch: &mut [f32]) {
    let n = p.len();
    let n2 = 2 * n;
    let f = plan2.spectrum_len();
    out2[..n].copy_from_slice(p);
    out2[n..n2].fill(0.0);
    plan2.rfft(&out2[..n2], zre, zim, scratch);
    for c in 0..dh {
        let row = &mut pad2[c * n2..(c + 1) * n2];
        row[..n].copy_from_slice(&v[c * n..(c + 1) * n]);
        row[n..].fill(0.0);
    }
    plan2.rfft_many(pad2, dh, vre, vim, scratch);
    for c in 0..dh {
        simd::cmul_rows(zre, zim, &mut vre[c * f..(c + 1) * f],
                        &mut vim[c * f..(c + 1) * f]);
    }
    plan2.irfft_many(vre, vim, dh, out2, scratch);
    for c in 0..dh {
        out[c * n..(c + 1) * n].copy_from_slice(&out2[c * n2..c * n2 + n]);
    }
}

/// Batched backward of the causal stripe: the `dh` padded `dout` and `v`
/// rows each go through one `rfft_many` sweep, the conjugate products
/// run per bin, and one `irfft_many` brings every `dv` row back.
/// Bit-identical to [`causal_bwd_stripe`] (same per-row math, same
/// ascending-channel accumulation into the `dp` spectrum).
#[allow(clippy::too_many_arguments)]
pub(crate) fn causal_bwd_stripe_batched(
    plan2: &SplitRfftPlan, p: &[f32], v: &[f32],
                             dout: &[f32], dh: usize, dp: &mut [f32],
                             dv: &mut [f32], pad2: &mut [f32],
                             zre: &mut [f32], zim: &mut [f32],
                             vre: &mut [f32], vim: &mut [f32],
                             gre: &mut [f32], gim: &mut [f32],
                             acc_re: &mut [f32], acc_im: &mut [f32],
                             out2: &mut [f32], scratch: &mut [f32]) {
    let n = p.len();
    let n2 = 2 * n;
    let f = plan2.spectrum_len();
    out2[..n].copy_from_slice(p);
    out2[n..n2].fill(0.0);
    plan2.rfft(&out2[..n2], zre, zim, scratch);
    for c in 0..dh {
        let row = &mut pad2[c * n2..(c + 1) * n2];
        row[..n].copy_from_slice(&dout[c * n..(c + 1) * n]);
        row[n..].fill(0.0);
    }
    plan2.rfft_many(pad2, dh, gre, gim, scratch);
    for c in 0..dh {
        let row = &mut pad2[c * n2..(c + 1) * n2];
        row[..n].copy_from_slice(&v[c * n..(c + 1) * n]);
        row[n..].fill(0.0);
    }
    plan2.rfft_many(pad2, dh, vre, vim, scratch);
    acc_re.fill(0.0);
    acc_im.fill(0.0);
    for c in 0..dh {
        let gr = &mut gre[c * f..(c + 1) * f];
        let gi = &mut gim[c * f..(c + 1) * f];
        let vr = &vre[c * f..(c + 1) * f];
        let vi = &vim[c * f..(c + 1) * f];
        // dp spectrum += conj(Vf₂_c) ⊙ dOf₂_c, then dOf₂_c ← conj(Zf₂) ⊙ dOf₂_c
        simd::cmul_conj_a_acc_rows(vr, vi, gr, gi, acc_re, acc_im);
        simd::cmul_conj_a_rows(zre, zim, gr, gi);
    }
    plan2.irfft_many(gre, gim, dh, out2, scratch);
    for c in 0..dh {
        dv[c * n..(c + 1) * n].copy_from_slice(&out2[c * n2..c * n2 + n]);
    }
    plan2.irfft(acc_re, acc_im, &mut out2[..n2], scratch);
    dp.copy_from_slice(&out2[..n]);
}

// ---------------------------------------------------------------------------
// public reference API for the stripe kernels (grad-check tests)
// ---------------------------------------------------------------------------

/// Reference/test entry: circular-correlation stripe forward
/// (`v`: `dh` channel rows of length `n = p.len()`, power of two).
pub fn corr_forward(p: &[f32], v: &[f32], dh: usize) -> Vec<f32> {
    let n = p.len();
    assert_eq!(v.len(), dh * n);
    let plan = split_rfft_plan(n);
    let f = plan.spectrum_len();
    let mut out = vec![0.0f32; dh * n];
    let (mut zre, mut zim) = (vec![0.0f32; f], vec![0.0f32; f]);
    let (mut vre, mut vim) = (vec![0.0f32; dh * f], vec![0.0f32; dh * f]);
    let mut scratch = vec![0.0f32; plan.scratch_len()];
    corr_fwd_stripe(&plan, p, v, dh, &mut out, &mut zre, &mut zim,
                    &mut vre, &mut vim, &mut scratch);
    out
}

/// Reference/test entry: circular-correlation stripe backward; returns
/// `(dp, dv)` for upstream gradient `dout` (`dh` rows of length `n`).
pub fn corr_backward(p: &[f32], v: &[f32], dout: &[f32], dh: usize)
                     -> (Vec<f32>, Vec<f32>) {
    let n = p.len();
    assert_eq!(v.len(), dh * n);
    assert_eq!(dout.len(), dh * n);
    let plan = split_rfft_plan(n);
    let f = plan.spectrum_len();
    let mut dp = vec![0.0f32; n];
    let mut dv = vec![0.0f32; dh * n];
    let (mut zre, mut zim) = (vec![0.0f32; f], vec![0.0f32; f]);
    let (mut vre, mut vim) = (vec![0.0f32; dh * f], vec![0.0f32; dh * f]);
    let (mut gre, mut gim) = (vec![0.0f32; dh * f], vec![0.0f32; dh * f]);
    let (mut are, mut aim) = (vec![0.0f32; f], vec![0.0f32; f]);
    let mut scratch = vec![0.0f32; plan.scratch_len()];
    corr_bwd_stripe(&plan, p, v, dout, dh, &mut dp, &mut dv, &mut zre,
                    &mut zim, &mut vre, &mut vim, &mut gre, &mut gim,
                    &mut are, &mut aim, &mut scratch);
    (dp, dv)
}

/// Reference/test entry: causal (zero-padded) stripe forward.
pub fn causal_corr_forward(p: &[f32], v: &[f32], dh: usize) -> Vec<f32> {
    let n = p.len();
    assert_eq!(v.len(), dh * n);
    let plan2 = split_rfft_plan(2 * n);
    let f = plan2.spectrum_len();
    let mut out = vec![0.0f32; dh * n];
    let mut pad = vec![0.0f32; 2 * n];
    let mut row2 = vec![0.0f32; 2 * n];
    let (mut zre, mut zim) = (vec![0.0f32; f], vec![0.0f32; f]);
    let (mut vre, mut vim) = (vec![0.0f32; f], vec![0.0f32; f]);
    let mut scratch = vec![0.0f32; plan2.scratch_len()];
    causal_fwd_stripe(&plan2, p, v, dh, &mut out, &mut pad, &mut zre,
                      &mut zim, &mut vre, &mut vim, &mut row2,
                      &mut scratch);
    out
}

/// Reference/test entry: causal stripe backward; returns `(dp, dv)`.
pub fn causal_corr_backward(p: &[f32], v: &[f32], dout: &[f32], dh: usize)
                            -> (Vec<f32>, Vec<f32>) {
    let n = p.len();
    let plan2 = split_rfft_plan(2 * n);
    let f = plan2.spectrum_len();
    let mut dp = vec![0.0f32; n];
    let mut dv = vec![0.0f32; dh * n];
    let mut pad = vec![0.0f32; 2 * n];
    let mut row2 = vec![0.0f32; 2 * n];
    let mk = || (vec![0.0f32; f], vec![0.0f32; f]);
    let ((mut zre, mut zim), (mut vre, mut vim)) = (mk(), mk());
    let ((mut gre, mut gim), (mut tre, mut tim)) = (mk(), mk());
    let (mut are, mut aim) = mk();
    let mut scratch = vec![0.0f32; plan2.scratch_len()];
    causal_bwd_stripe(&plan2, p, v, dout, dh, &mut dp, &mut dv, &mut pad,
                      &mut zre, &mut zim, &mut vre, &mut vim, &mut gre,
                      &mut gim, &mut tre, &mut tim, &mut are, &mut aim,
                      &mut row2, &mut scratch);
    (dp, dv)
}

/// Test entry: the batched causal stripe forward ([`causal_fwd_stripe_batched`],
/// the production training path); must be bit-identical to
/// [`causal_corr_forward`].
pub fn causal_corr_forward_batched(p: &[f32], v: &[f32], dh: usize)
                                   -> Vec<f32> {
    let n = p.len();
    assert_eq!(v.len(), dh * n);
    let plan2 = split_rfft_plan(2 * n);
    let f = plan2.spectrum_len();
    let mut out = vec![0.0f32; dh * n];
    let mut pad2 = vec![0.0f32; dh * 2 * n];
    let mut out2 = vec![0.0f32; dh * 2 * n];
    let (mut zre, mut zim) = (vec![0.0f32; f], vec![0.0f32; f]);
    let (mut vre, mut vim) = (vec![0.0f32; dh * f], vec![0.0f32; dh * f]);
    let mut scratch = vec![0.0f32; plan2.scratch_len()];
    causal_fwd_stripe_batched(&plan2, p, v, dh, &mut out, &mut pad2,
                              &mut zre, &mut zim, &mut vre, &mut vim,
                              &mut out2, &mut scratch);
    out
}

/// Test entry: the batched causal stripe backward
/// ([`causal_bwd_stripe_batched`], the production training path); must
/// be bit-identical to [`causal_corr_backward`].
pub fn causal_corr_backward_batched(p: &[f32], v: &[f32], dout: &[f32],
                                    dh: usize) -> (Vec<f32>, Vec<f32>) {
    let n = p.len();
    assert_eq!(v.len(), dh * n);
    assert_eq!(dout.len(), dh * n);
    let plan2 = split_rfft_plan(2 * n);
    let f = plan2.spectrum_len();
    let mut dp = vec![0.0f32; n];
    let mut dv = vec![0.0f32; dh * n];
    let mut pad2 = vec![0.0f32; dh * 2 * n];
    let mut out2 = vec![0.0f32; dh * 2 * n];
    let (mut zre, mut zim) = (vec![0.0f32; f], vec![0.0f32; f]);
    let (mut vre, mut vim) = (vec![0.0f32; dh * f], vec![0.0f32; dh * f]);
    let (mut gre, mut gim) = (vec![0.0f32; dh * f], vec![0.0f32; dh * f]);
    let (mut are, mut aim) = (vec![0.0f32; f], vec![0.0f32; f]);
    let mut scratch = vec![0.0f32; plan2.scratch_len()];
    causal_bwd_stripe_batched(&plan2, p, v, dout, dh, &mut dp, &mut dv,
                              &mut pad2, &mut zre, &mut zim, &mut vre,
                              &mut vim, &mut gre, &mut gim, &mut are,
                              &mut aim, &mut out2, &mut scratch);
    (dp, dv)
}

// ---------------------------------------------------------------------------
// layout shuffles between (b, n, d) and per-(batch·head) stripes
// ---------------------------------------------------------------------------

/// `(b, n, d)` → channel-major stripes `(b·h, dh, n)` (the rFFT layout).
pub(crate) fn to_stripes(src: &[f32], b: usize, n: usize, h: usize,
                         dh: usize, dst: &mut [f32]) {
    let d = h * dh;
    for bi in 0..b {
        for head in 0..h {
            let stripe = &mut dst[(bi * h + head) * dh * n..][..dh * n];
            for (c, row) in stripe.chunks_exact_mut(n).enumerate() {
                let base = bi * n * d + head * dh + c;
                for (i, slot) in row.iter_mut().enumerate() {
                    *slot = src[base + i * d];
                }
            }
        }
    }
}

/// Channel-major stripes `(b·h, dh, n)` → `(b, n, d)`.
pub(crate) fn from_stripes(src: &[f32], b: usize, n: usize, h: usize,
                           dh: usize, dst: &mut [f32]) {
    let d = h * dh;
    for bi in 0..b {
        for head in 0..h {
            let stripe = &src[(bi * h + head) * dh * n..][..dh * n];
            for (c, row) in stripe.chunks_exact(n).enumerate() {
                let base = bi * n * d + head * dh + c;
                for (i, &val) in row.iter().enumerate() {
                    dst[base + i * d] = val;
                }
            }
        }
    }
}

/// `(b, n, d)` → token-major head rows `(b·h, n, dh)` (attention layout).
pub(crate) fn to_head_rows(src: &[f32], b: usize, n: usize, h: usize,
                           dh: usize, dst: &mut [f32]) {
    let d = h * dh;
    for bi in 0..b {
        for head in 0..h {
            for i in 0..n {
                let s = (bi * n + i) * d + head * dh;
                let t = ((bi * h + head) * n + i) * dh;
                dst[t..t + dh].copy_from_slice(&src[s..s + dh]);
            }
        }
    }
}

/// Token-major head rows `(b·h, n, dh)` → `(b, n, d)`.
pub(crate) fn from_head_rows(src: &[f32], b: usize, n: usize, h: usize,
                             dh: usize, dst: &mut [f32]) {
    let d = h * dh;
    for bi in 0..b {
        for head in 0..h {
            for i in 0..n {
                let s = ((bi * h + head) * n + i) * dh;
                let t = (bi * n + i) * d + head * dh;
                dst[t..t + dh].copy_from_slice(&src[s..s + dh]);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// attention backward stripe kernels
// ---------------------------------------------------------------------------

/// PR-3 reference attention backward for one `(batch·head)` stripe:
/// row-streamed — every row re-walks K, V, dK and dV end to end. Kept
/// as the equivalence oracle for [`attn_bwd_stripe_panels`] and the
/// `trainstep` naive baseline. `q`/`k`/`v`/`dost`: `(n, dh)`;
/// `ps`: `(n, n)` softmax rows (zero above the diagonal when causal).
#[allow(clippy::too_many_arguments)]
pub(crate) fn attn_bwd_stripe_rows(
    q: &[f32], k: &[f32], v: &[f32], ps: &[f32],
                        dost: &[f32], n: usize, dh: usize, scale: f32,
                        causal: bool, dqs: &mut [f32], dks: &mut [f32],
                        dvs: &mut [f32]) {
    dks.fill(0.0);
    dvs.fill(0.0);
    arena::with_task_arena(|ta| {
        let [dprow] = ta.frame([n]);
        for i in 0..n {
            let lim = if causal { i + 1 } else { n };
            let doi = &dost[i * dh..(i + 1) * dh];
            let pi = &ps[i * n..(i + 1) * n];
            let mut dsum = 0.0f32;
            for (j, slot) in dprow.iter_mut().take(lim).enumerate() {
                let dot = simd::dot(doi, &v[j * dh..(j + 1) * dh]);
                *slot = dot;
                dsum += dot * pi[j];
            }
            let qi = &q[i * dh..(i + 1) * dh];
            let dqi = &mut dqs[i * dh..(i + 1) * dh];
            dqi.fill(0.0);
            for j in 0..lim {
                let pj = pi[j];
                let ds = pj * (dprow[j] - dsum) * scale;
                simd::axpy(dqi, &k[j * dh..(j + 1) * dh], ds);
                simd::axpy(&mut dks[j * dh..(j + 1) * dh], qi, ds);
                simd::axpy(&mut dvs[j * dh..(j + 1) * dh], doi, pj);
            }
        }
    });
}

/// Stripe-blocked attention backward for one `(batch·head)` stripe:
/// rows advance in [`ATTN_PANEL`]-row panels whose dS panel lives in
/// task-arena scratch, with the softmax backward fused into the panel
/// pass and K/V/dK/dV walked in [`ATTN_COL_TILE`]-column tiles — the
/// O(N²) row work streams those operands once per *panel* instead of
/// once per row. Per-slot accumulation order is flat row-ascending, so
/// the outputs are bit-identical to [`attn_bwd_stripe_rows`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn attn_bwd_stripe_panels(
    q: &[f32], k: &[f32], v: &[f32], ps: &[f32],
                          dost: &[f32], n: usize, dh: usize, scale: f32,
                          causal: bool, dqs: &mut [f32], dks: &mut [f32],
                          dvs: &mut [f32]) {
    dqs.fill(0.0);
    dks.fill(0.0);
    dvs.fill(0.0);
    arena::with_task_arena(|ta| {
        let [ds] = ta.frame([ATTN_PANEL * n]);
        let mut i0 = 0;
        while i0 < n {
            let rb = ATTN_PANEL.min(n - i0);
            // 1. dS panel = dO·Vᵀ over column tiles (j < lim per row)
            let mut j0 = 0;
            while j0 < n && !(causal && j0 >= i0 + rb) {
                let jb = ATTN_COL_TILE.min(n - j0);
                for r in 0..rb {
                    let i = i0 + r;
                    let lim = if causal { i + 1 } else { n };
                    if j0 >= lim {
                        continue;
                    }
                    let je = jb.min(lim - j0);
                    let doi = &dost[i * dh..(i + 1) * dh];
                    let dsrow = &mut ds[r * n + j0..r * n + j0 + je];
                    for (jj, slot) in dsrow.iter_mut().enumerate() {
                        *slot = simd::dot(
                            doi, &v[(j0 + jj) * dh..(j0 + jj + 1) * dh]);
                    }
                }
                j0 += jb;
            }
            // 2. fused softmax backward per row (+ the q·k scale)
            for r in 0..rb {
                let i = i0 + r;
                let lim = if causal { i + 1 } else { n };
                let pi = &ps[i * n..i * n + lim];
                let dsrow = &mut ds[r * n..r * n + lim];
                let mut dsum = 0.0f32;
                for (pv, dv) in pi.iter().zip(dsrow.iter()) {
                    dsum += pv * dv;
                }
                for (pv, dv) in pi.iter().zip(dsrow.iter_mut()) {
                    *dv = pv * (*dv - dsum) * scale;
                }
            }
            // 3. dQ/dK/dV over column tiles: the (jb, dh) K, dK and dV
            // tiles stay hot across the panel's row sweep
            let mut j0 = 0;
            while j0 < n && !(causal && j0 >= i0 + rb) {
                let jb = ATTN_COL_TILE.min(n - j0);
                for r in 0..rb {
                    let i = i0 + r;
                    let lim = if causal { i + 1 } else { n };
                    if j0 >= lim {
                        continue;
                    }
                    let je = jb.min(lim - j0);
                    let qi = &q[i * dh..(i + 1) * dh];
                    let doi = &dost[i * dh..(i + 1) * dh];
                    let dqi = &mut dqs[i * dh..(i + 1) * dh];
                    let pirow = &ps[i * n..(i + 1) * n];
                    let dsrow = &ds[r * n..(r + 1) * n];
                    for j in j0..j0 + je {
                        let dsv = dsrow[j];
                        simd::axpy(dqi, &k[j * dh..(j + 1) * dh], dsv);
                        simd::axpy(&mut dks[j * dh..(j + 1) * dh], qi, dsv);
                        simd::axpy(&mut dvs[j * dh..(j + 1) * dh], doi,
                                   pirow[j]);
                    }
                }
                j0 += jb;
            }
            i0 += rb;
        }
    });
}

/// Reference/test entry: softmax-attention backward over one stripe.
/// `q`/`k`/`v`/`dout`: `(n, dh)` token rows; `probs`: `(n, n)` softmax
/// rows (zero above the diagonal when `causal`). Returns
/// `(dq, dk, dv)`. `tiled` selects the stripe-blocked production
/// kernel; `false` runs the row-streamed reference oracle.
#[allow(clippy::too_many_arguments)]
pub fn attention_backward(q: &[f32], k: &[f32], v: &[f32], probs: &[f32],
                          dout: &[f32], n: usize, dh: usize, causal: bool,
                          tiled: bool) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(q.len(), n * dh);
    assert_eq!(k.len(), n * dh);
    assert_eq!(v.len(), n * dh);
    assert_eq!(probs.len(), n * n);
    assert_eq!(dout.len(), n * dh);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut dq = vec![0.0f32; n * dh];
    let mut dk = vec![0.0f32; n * dh];
    let mut dv = vec![0.0f32; n * dh];
    if tiled {
        attn_bwd_stripe_panels(q, k, v, probs, dout, n, dh, scale, causal,
                               &mut dq, &mut dk, &mut dv);
    } else {
        attn_bwd_stripe_rows(q, k, v, probs, dout, n, dh, scale, causal,
                             &mut dq, &mut dk, &mut dv);
    }
    (dq, dk, dv)
}

// ---------------------------------------------------------------------------
// configuration
// ---------------------------------------------------------------------------

/// What the model is trained on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// ViT classifier on the procedural ImageNet substitute.
    Vit {
        image_size: usize,
        patch_size: usize,
        n_channels: usize,
        n_classes: usize,
    },
    /// Masked / causal LM on the Zipf-Markov WikiText substitute.
    Lm { vocab: usize, seq_len: usize, causal: bool },
}

/// Shape + mechanism of one trainable native model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainConfig {
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub batch_size: usize,
    pub mixer: Mixer,
    /// CAT-Alter: odd layers swap to softmax attention.
    pub alternate: bool,
    /// FNet half-spectrum truncation: zero hidden channels above `d/2`
    /// (Fast-FNet-style low-pass; ignored by every other mixer).
    pub fnet_truncate: bool,
    pub task: TaskKind,
}

impl TrainConfig {
    /// Table-1-shaped ViT proxy (d=64, h=4, L=2, 64 tokens, batch 16).
    pub fn vit(mixer: Mixer, alternate: bool) -> TrainConfig {
        TrainConfig {
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            batch_size: 16,
            mixer,
            alternate,
            fnet_truncate: false,
            task: TaskKind::Vit {
                image_size: 32,
                patch_size: 4,
                n_channels: 3,
                n_classes: 10,
            },
        }
    }

    /// Table-2-shaped LM proxy (d=64, h=4, L=2, N=128, batch 8).
    pub fn lm(mixer: Mixer, causal: bool, alternate: bool) -> TrainConfig {
        TrainConfig {
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            batch_size: 8,
            mixer,
            alternate,
            fnet_truncate: false,
            task: TaskKind::Lm { vocab: 512, seq_len: 128, causal },
        }
    }

    /// Minimal smoke-test shape (CI's 20-step loss-decreases gate).
    pub fn tiny() -> TrainConfig {
        TrainConfig {
            d_model: 32,
            n_heads: 2,
            n_layers: 1,
            batch_size: 16,
            mixer: Mixer::CatFft,
            alternate: false,
            fnet_truncate: false,
            task: TaskKind::Vit {
                image_size: 32,
                patch_size: 8,
                n_channels: 3,
                n_classes: 10,
            },
        }
    }

    /// Sequence length the trunk runs at.
    pub fn n_tokens(&self) -> usize {
        match self.task {
            TaskKind::Vit { image_size, patch_size, .. } => {
                let per_side = image_size / patch_size;
                per_side * per_side
            }
            TaskKind::Lm { seq_len, .. } => seq_len,
        }
    }

    /// Causal masking / causal convolution?
    pub fn causal(&self) -> bool {
        matches!(self.task, TaskKind::Lm { causal: true, .. })
    }

    /// The mixer of layer `l` (CAT-Alter alternates CAT and attention).
    pub fn mixer_at(&self, layer: usize) -> Mixer {
        mixer::schedule_at(self.mixer, self.alternate, layer)
    }

    /// Mechanism label for tables ("cat", "cat_alter", "attention", ...).
    pub fn mechanism(&self) -> String {
        mixer::mechanism_label(self.mixer, self.alternate)
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.n_heads > 0 && self.d_model % self.n_heads == 0,
                "d_model {} must divide into {} heads", self.d_model,
                self.n_heads);
        ensure!(self.n_layers > 0 && self.batch_size > 0,
                "need at least one layer and a nonempty batch");
        let n = self.n_tokens();
        ensure!(n >= 2, "need at least 2 tokens, got {n}");
        mixer::validate_schedule(self.mixer, self.alternate, self.n_layers,
                                 n, self.d_model, self.causal())?;
        if let TaskKind::Vit { image_size, patch_size, .. } = self.task {
            ensure!(patch_size > 0 && image_size % patch_size == 0,
                    "patch size {patch_size} must divide image {image_size}");
        }
        if let TaskKind::Lm { vocab, .. } = self.task {
            ensure!(vocab > 16, "vocab {vocab} too small");
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// parameters (and their mirrored gradients)
// ---------------------------------------------------------------------------

struct BlockParams {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    mixer: MixerParams,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    mlp_w1: Vec<f32>,
    mlp_b1: Vec<f32>,
    mlp_w2: Vec<f32>,
    mlp_b2: Vec<f32>,
}

/// Input embedding parameters per task.
enum EmbedParams {
    /// Patch embedding `(patch_dim, d)` + bias.
    Vit { embed_w: Vec<f32>, embed_b: Vec<f32> },
    /// Token-embedding table `(vocab, d)`.
    Lm { tok_emb: Vec<f32> },
}

/// The full parameter tree; a second instance of the same shape holds the
/// gradients ([`ModelParams::zeros_like`]).
struct ModelParams {
    embed: EmbedParams,
    pos: Vec<f32>,
    blocks: Vec<BlockParams>,
    ln_f_g: Vec<f32>,
    ln_f_b: Vec<f32>,
    head_w: Vec<f32>,
    head_b: Vec<f32>,
}

impl ModelParams {
    fn init(cfg: &TrainConfig, seed: u64) -> ModelParams {
        let d = cfg.d_model;
        let n = cfg.n_tokens();
        let mut rng = Rng::new(seed ^ 0x7EA1_CA7);
        let mut mk = |len: usize| -> Vec<f32> {
            (0..len).map(|_| 0.02 * rng.normal()).collect()
        };
        let (embed, head_cols) = match cfg.task {
            TaskKind::Vit { patch_size, n_channels, n_classes, .. } => {
                let pd = patch_size * patch_size * n_channels;
                (EmbedParams::Vit { embed_w: mk(pd * d),
                                    embed_b: vec![0.0; d] },
                 n_classes)
            }
            TaskKind::Lm { vocab, .. } => {
                (EmbedParams::Lm { tok_emb: mk(vocab * d) }, vocab)
            }
        };
        let pos = mk(n * d);
        let head_w = mk(d * head_cols);
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for layer in 0..cfg.n_layers {
            let mut brng = rng.fork(layer as u64);
            let mut bmk = |len: usize| -> Vec<f32> {
                (0..len).map(|_| 0.02 * brng.normal()).collect()
            };
            let mixer = mixer::train::init_params(cfg.mixer_at(layer), d,
                                                  cfg.n_heads, &mut bmk);
            blocks.push(BlockParams {
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                mixer,
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
                mlp_w1: bmk(d * 2 * d),
                mlp_b1: vec![0.0; 2 * d],
                mlp_w2: bmk(2 * d * d),
                mlp_b2: vec![0.0; d],
            });
        }
        ModelParams {
            embed,
            pos,
            blocks,
            ln_f_g: vec![1.0; d],
            ln_f_b: vec![0.0; d],
            head_w,
            head_b: vec![0.0; head_cols],
        }
    }

    /// Same tree shape, all zeros (the gradient mirror).
    fn zeros_like(&self) -> ModelParams {
        let z = |v: &Vec<f32>| vec![0.0f32; v.len()];
        ModelParams {
            embed: match &self.embed {
                EmbedParams::Vit { embed_w, embed_b } => EmbedParams::Vit {
                    embed_w: z(embed_w),
                    embed_b: z(embed_b),
                },
                EmbedParams::Lm { tok_emb } => EmbedParams::Lm {
                    tok_emb: z(tok_emb),
                },
            },
            pos: z(&self.pos),
            blocks: self
                .blocks
                .iter()
                .map(|b| BlockParams {
                    ln1_g: z(&b.ln1_g),
                    ln1_b: z(&b.ln1_b),
                    mixer: b.mixer.zeros_like(),
                    ln2_g: z(&b.ln2_g),
                    ln2_b: z(&b.ln2_b),
                    mlp_w1: z(&b.mlp_w1),
                    mlp_b1: z(&b.mlp_b1),
                    mlp_w2: z(&b.mlp_w2),
                    mlp_b2: z(&b.mlp_b2),
                })
                .collect(),
            ln_f_g: z(&self.ln_f_g),
            ln_f_b: z(&self.ln_f_b),
            head_w: z(&self.head_w),
            head_b: z(&self.head_b),
        }
    }

    /// Visit every tensor in a fixed order: `(name, tensor, decays)`.
    /// `decays` marks matrices (weight decay applies) vs biases / norms /
    /// positions (it does not). The optimizer's state layout and the
    /// grad-check indices both key off this order.
    fn tensors_mut(&mut self) -> Vec<(&'static str, &mut Vec<f32>, bool)> {
        let mut out: Vec<(&'static str, &mut Vec<f32>, bool)> = Vec::new();
        match &mut self.embed {
            EmbedParams::Vit { embed_w, embed_b } => {
                out.push(("embed_w", embed_w, true));
                out.push(("embed_b", embed_b, false));
            }
            EmbedParams::Lm { tok_emb } => {
                out.push(("tok_emb", tok_emb, true));
            }
        }
        out.push(("pos", &mut self.pos, false));
        for b in self.blocks.iter_mut() {
            out.push(("ln1_g", &mut b.ln1_g, false));
            out.push(("ln1_b", &mut b.ln1_b, false));
            out.extend(b.mixer.tensors_mut());
            out.push(("ln2_g", &mut b.ln2_g, false));
            out.push(("ln2_b", &mut b.ln2_b, false));
            out.push(("mlp_w1", &mut b.mlp_w1, true));
            out.push(("mlp_b1", &mut b.mlp_b1, false));
            out.push(("mlp_w2", &mut b.mlp_w2, true));
            out.push(("mlp_b2", &mut b.mlp_b2, false));
        }
        out.push(("ln_f_g", &mut self.ln_f_g, false));
        out.push(("ln_f_b", &mut self.ln_f_b, false));
        out.push(("head_w", &mut self.head_w, true));
        out.push(("head_b", &mut self.head_b, false));
        out
    }

    fn n_params(&mut self) -> usize {
        self.tensors_mut().iter().map(|(_, t, _)| t.len()).sum()
    }
}

// ---------------------------------------------------------------------------
// activation caches + step scratch
// ---------------------------------------------------------------------------

/// Per-block forward caches consumed by the backward pass. Only the
/// buffers the block's mixer actually uses ever grow. The mixer-facing
/// fields are `pub(crate)` for `super::mixer::train`, the single match
/// over [`Mixer`] on the training path.
#[derive(Default)]
pub(crate) struct LayerCache {
    /// LN1 output — the mixer input (b·n·d).
    pub(crate) xn1: Vec<f32>,
    ln1: LnCache,
    /// CAT / circulant: softmax weight stripes (b·h·n).
    pub(crate) p: Vec<f32>,
    /// CAT / circulant: stripe-transposed values (b·h, dh, n).
    pub(crate) vt: Vec<f32>,
    /// Circulant: stripe-transposed q/k projections (b·h, dh, n).
    pub(crate) qt: Vec<f32>,
    pub(crate) kt: Vec<f32>,
    /// Attention: token-major head rows (b·h, n, dh) each.
    pub(crate) qh: Vec<f32>,
    pub(crate) kh: Vec<f32>,
    pub(crate) vh: Vec<f32>,
    /// Attention: softmax rows (b·h, n, n); zero above the diagonal when
    /// causal.
    pub(crate) aprobs: Vec<f32>,
    /// LN2 output — the MLP input (b·n·d).
    xn2: Vec<f32>,
    ln2: LnCache,
    /// Post-ReLU hidden activations (b·n·2d).
    hid: Vec<f32>,
}

/// Grow-only step workspace owned by the [`TrainModel`]: activation
/// caches (forward → backward lifetime) plus backward temporaries and
/// the stashed batch ground truth. Zero tensor-sized allocation after
/// the first same-shape step.
#[derive(Default)]
struct Scratch {
    patches: Vec<f32>,
    x: Vec<f32>,
    norm: Vec<f32>,
    pooled: Vec<f32>,
    /// Head softmax rows; the LM backward overwrites them with dlogits.
    probs: Vec<f32>,
    dlogits: Vec<f32>,
    dpooled: Vec<f32>,
    dx: Vec<f32>,
    tmp1: Vec<f32>,
    tmp2: Vec<f32>,
    tmp3: Vec<f32>,
    dhid: Vec<f32>,
    zs: Vec<f32>,
    znh: Vec<f32>,
    dqh: Vec<f32>,
    dkh: Vec<f32>,
    dvh: Vec<f32>,
    layers: Vec<LayerCache>,
    lnf: LnCache,
    labels: Vec<i32>,
    tokens: Vec<i32>,
    targets: Vec<i32>,
    weights: Vec<f32>,
    wsum: f32,
    b: usize,
}

/// One training batch in the task's native layout.
pub enum TrainBatch {
    /// CHW image batch + class labels.
    Vit { images: Vec<f32>, labels: Vec<i32> },
    /// Token batch: `(tokens, targets, weights)`, each `b·n`.
    Lm { tokens: Vec<i32>, targets: Vec<i32>, weights: Vec<f32> },
}

/// Loss plus the metric ingredients of one forward pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalOut {
    pub loss: f32,
    /// ViT: correctly classified examples out of `examples`.
    pub correct: usize,
    pub examples: usize,
    /// LM: weighted negative log likelihood and total weight
    /// (`ppl = exp(nll / weight)`).
    pub nll: f64,
    pub weight: f64,
}

/// `(b, C, H, W)` flat images → `(b, n_tokens, patch_dim)` patches.
fn patchify(images: &[f32], b: usize, image_size: usize, patch_size: usize,
            n_channels: usize, out: &mut [f32]) {
    let per_side = image_size / patch_size;
    let n = per_side * per_side;
    let pd = patch_size * patch_size * n_channels;
    let image_len = n_channels * image_size * image_size;
    let (ps, is) = (patch_size, image_size);
    for bi in 0..b {
        let img = &images[bi * image_len..(bi + 1) * image_len];
        for py in 0..per_side {
            for px in 0..per_side {
                let tok = py * per_side + px;
                let dst = &mut out[(bi * n + tok) * pd..][..pd];
                let mut w = 0;
                for c in 0..n_channels {
                    for dy in 0..ps {
                        for dx in 0..ps {
                            dst[w] = img[c * is * is + (py * ps + dy) * is
                                + px * ps + dx];
                            w += 1;
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// forward pass
// ---------------------------------------------------------------------------

fn forward_pass(cfg: &TrainConfig, params: &ModelParams, s: &mut Scratch,
                batch: &TrainBatch) -> Result<EvalOut> {
    let d = cfg.d_model;
    let n = cfg.n_tokens();
    let h = cfg.n_heads;

    // 1. embedding
    let b = match (&cfg.task, batch) {
        (&TaskKind::Vit { image_size, patch_size, n_channels, .. },
         TrainBatch::Vit { images, labels }) => {
            let b = labels.len();
            let image_len = n_channels * image_size * image_size;
            ensure!(b > 0 && images.len() == b * image_len,
                    "images have {} elements, expected {b}x{image_len}",
                    images.len());
            let pd = patch_size * patch_size * n_channels;
            ensure_len(&mut s.patches, b * n * pd);
            patchify(images, b, image_size, patch_size, n_channels,
                     &mut s.patches);
            let EmbedParams::Vit { embed_w, embed_b } = &params.embed
            else { bail!("embed/task mismatch") };
            ensure_len(&mut s.x, b * n * d);
            matmul(&s.patches, b * n, pd, embed_w, d, &mut s.x);
            for bi in 0..b {
                for tok in 0..n {
                    let row = &mut s.x[(bi * n + tok) * d..][..d];
                    for c in 0..d {
                        row[c] += embed_b[c] + params.pos[tok * d + c];
                    }
                }
            }
            s.labels.clear();
            s.labels.extend_from_slice(labels);
            b
        }
        (&TaskKind::Lm { vocab, .. },
         TrainBatch::Lm { tokens, targets, weights }) => {
            ensure!(!tokens.is_empty() && tokens.len() % n == 0,
                    "token batch length {} not a multiple of N={n}",
                    tokens.len());
            let b = tokens.len() / n;
            ensure!(targets.len() == b * n && weights.len() == b * n,
                    "targets/weights must match tokens");
            let EmbedParams::Lm { tok_emb } = &params.embed
            else { bail!("embed/task mismatch") };
            ensure_len(&mut s.x, b * n * d);
            for (row_i, (&tok, xrow)) in tokens
                .iter()
                .zip(s.x.chunks_exact_mut(d))
                .enumerate()
            {
                let t = tok as usize;
                ensure!(t < vocab, "token id {t} outside vocab {vocab}");
                let erow = &tok_emb[t * d..(t + 1) * d];
                let prow = &params.pos[(row_i % n) * d..][..d];
                for c in 0..d {
                    xrow[c] = erow[c] + prow[c];
                }
            }
            s.tokens.clear();
            s.tokens.extend_from_slice(tokens);
            s.targets.clear();
            s.targets.extend_from_slice(targets);
            s.weights.clear();
            s.weights.extend_from_slice(weights);
            b
        }
        _ => bail!("batch kind does not match the configured task"),
    };
    s.b = b;
    let bn = b * n;
    ensure_len(&mut s.norm, bn * d);
    ensure_len(&mut s.tmp1, bn * d);
    ensure_len(&mut s.tmp2, bn * d);
    ensure_len(&mut s.tmp3, bn * d);
    ensure_len(&mut s.dhid, bn * 2 * d);
    ensure_len(&mut s.zs, b * h * n);
    ensure_len(&mut s.znh, bn * h);
    if s.layers.len() != cfg.n_layers {
        s.layers.resize_with(cfg.n_layers, LayerCache::default);
    }

    // 2. block stack
    for (l, bp) in params.blocks.iter().enumerate() {
        let lc = &mut s.layers[l];
        ensure_len(&mut lc.xn1, bn * d);
        layernorm_fwd(&s.x, &bp.ln1_g, &bp.ln1_b, &mut lc.xn1, &mut lc.ln1);
        mixer::train::fwd(cfg, l, &bp.mixer, lc, b, &mut s.tmp1,
                          &mut s.znh, &mut s.tmp2, &mut s.tmp3)?;
        for (xv, mv) in s.x.iter_mut().zip(s.tmp3.iter()) {
            *xv += mv;
        }
        ensure_len(&mut lc.xn2, bn * d);
        layernorm_fwd(&s.x, &bp.ln2_g, &bp.ln2_b, &mut lc.xn2, &mut lc.ln2);
        ensure_len(&mut lc.hid, bn * 2 * d);
        matmul(&lc.xn2, bn, d, &bp.mlp_w1, 2 * d, &mut lc.hid);
        for row in lc.hid.chunks_exact_mut(2 * d) {
            for (v, &bias) in row.iter_mut().zip(&bp.mlp_b1) {
                *v = (*v + bias).max(0.0);
            }
        }
        matmul(&lc.hid, bn, 2 * d, &bp.mlp_w2, d, &mut s.tmp3);
        for (row, xrow) in
            s.tmp3.chunks_exact(d).zip(s.x.chunks_exact_mut(d)) {
            for (xv, (&mv, &bias)) in
                xrow.iter_mut().zip(row.iter().zip(&bp.mlp_b2)) {
                *xv += mv + bias;
            }
        }
    }

    // 3. final LN + head + loss
    layernorm_fwd(&s.x, &params.ln_f_g, &params.ln_f_b, &mut s.norm,
                  &mut s.lnf);
    head_fwd(cfg, params, s, b)
}

/// Head forward: pooled classifier (ViT) or per-token LM logits, loss +
/// metric ingredients. Softmax rows are cached in `s.probs` for backward.
fn head_fwd(cfg: &TrainConfig, params: &ModelParams, s: &mut Scratch,
            b: usize) -> Result<EvalOut> {
    let d = cfg.d_model;
    let n = cfg.n_tokens();
    match cfg.task {
        TaskKind::Vit { n_classes, .. } => {
            ensure_len(&mut s.pooled, b * d);
            s.pooled.fill(0.0);
            for bi in 0..b {
                let prow = &mut s.pooled[bi * d..(bi + 1) * d];
                for tok in 0..n {
                    let row = &s.norm[(bi * n + tok) * d..][..d];
                    for (pv, &rv) in prow.iter_mut().zip(row) {
                        *pv += rv;
                    }
                }
                for v in prow.iter_mut() {
                    *v /= n as f32;
                }
            }
            ensure_len(&mut s.probs, b * n_classes);
            matmul(&s.pooled, b, d, &params.head_w, n_classes, &mut s.probs);
            let mut loss = 0.0f64;
            let mut correct = 0usize;
            for (bi, row) in s.probs.chunks_exact_mut(n_classes).enumerate() {
                for (v, &bias) in row.iter_mut().zip(&params.head_b) {
                    *v += bias;
                }
                let label = s.labels[bi] as usize;
                ensure!(label < n_classes,
                        "label {label} outside {n_classes} classes");
                let mut m = f32::NEG_INFINITY;
                let mut arg = 0usize;
                for (j, &v) in row.iter().enumerate() {
                    if v > m {
                        m = v;
                        arg = j;
                    }
                }
                let mut sum = 0.0f32;
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                    sum += *v;
                }
                loss -= (row[label].ln() - sum.ln()) as f64;
                let inv = 1.0 / sum;
                for v in row.iter_mut() {
                    *v *= inv;
                }
                correct += usize::from(arg == label);
            }
            Ok(EvalOut {
                loss: (loss / b as f64) as f32,
                correct,
                examples: b,
                nll: 0.0,
                weight: 0.0,
            })
        }
        TaskKind::Lm { vocab, .. } => {
            let bn = b * n;
            ensure_len(&mut s.probs, bn * vocab);
            matmul(&s.norm, bn, d, &params.head_w, vocab, &mut s.probs);
            let mut nll = 0.0f64;
            let mut wsum = 0.0f64;
            for (i, row) in s.probs.chunks_exact_mut(vocab).enumerate() {
                for (v, &bias) in row.iter_mut().zip(&params.head_b) {
                    *v += bias;
                }
                let w = s.weights[i];
                if w == 0.0 {
                    continue;
                }
                let t = s.targets[i] as usize;
                ensure!(t < vocab, "target {t} outside vocab {vocab}");
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut sum = 0.0f32;
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                    sum += *v;
                }
                nll -= w as f64 * (row[t].ln() - sum.ln()) as f64;
                wsum += w as f64;
                let inv = 1.0 / sum;
                for v in row.iter_mut() {
                    *v *= inv;
                }
            }
            ensure!(wsum > 0.0, "LM batch carries zero loss weight");
            s.wsum = wsum as f32;
            Ok(EvalOut {
                loss: (nll / wsum) as f32,
                correct: 0,
                examples: 0,
                nll,
                weight: wsum,
            })
        }
    }
}

// ---------------------------------------------------------------------------
// backward pass
// ---------------------------------------------------------------------------

fn backward_pass(cfg: &TrainConfig, params: &ModelParams,
                 grads: &mut ModelParams, s: &mut Scratch) -> Result<()> {
    let d = cfg.d_model;
    let n = cfg.n_tokens();
    let b = s.b;
    ensure!(b > 0, "backward called before a forward pass");
    let bn = b * n;
    ensure_len(&mut s.dx, bn * d);

    // head + final-LN backward → s.dx
    match cfg.task {
        TaskKind::Vit { n_classes, .. } => {
            ensure_len(&mut s.dlogits, b * n_classes);
            let inv_b = 1.0 / b as f32;
            for ((row, dlrow), &label) in s
                .probs
                .chunks_exact(n_classes)
                .zip(s.dlogits.chunks_exact_mut(n_classes))
                .zip(&s.labels)
            {
                for (dv, &pv) in dlrow.iter_mut().zip(row) {
                    *dv = pv * inv_b;
                }
                dlrow[label as usize] -= inv_b;
            }
            matmul_xt_acc(&s.pooled, b, d, &s.dlogits, n_classes,
                          &mut grads.head_w);
            colsum_acc(&s.dlogits, n_classes, &mut grads.head_b);
            ensure_len(&mut s.dpooled, b * d);
            matmul_wt(&s.dlogits, b, n_classes, &params.head_w, d,
                      &mut s.dpooled, false);
            let inv_n = 1.0 / n as f32;
            for bi in 0..b {
                let prow = &s.dpooled[bi * d..(bi + 1) * d];
                for tok in 0..n {
                    let row = &mut s.tmp1[(bi * n + tok) * d..][..d];
                    for (rv, &pv) in row.iter_mut().zip(prow) {
                        *rv = pv * inv_n;
                    }
                }
            }
        }
        TaskKind::Lm { vocab, .. } => {
            // probs → dlogits in place: w·(p − onehot)/Σw, zero where w=0
            for ((row, &w), &t) in s
                .probs
                .chunks_exact_mut(vocab)
                .zip(&s.weights)
                .zip(&s.targets)
            {
                if w == 0.0 {
                    row.fill(0.0);
                    continue;
                }
                let scalef = w / s.wsum;
                for v in row.iter_mut() {
                    *v *= scalef;
                }
                row[t as usize] -= scalef;
            }
            matmul_xt_acc(&s.norm, bn, d, &s.probs, vocab,
                          &mut grads.head_w);
            colsum_acc(&s.probs, vocab, &mut grads.head_b);
            matmul_wt(&s.probs, bn, vocab, &params.head_w, d, &mut s.tmp1,
                      false);
        }
    }
    layernorm_bwd(&s.tmp1, &params.ln_f_g, &s.lnf, &mut grads.ln_f_g,
                  &mut grads.ln_f_b, &mut s.dx);

    // block stack in reverse
    for l in (0..cfg.n_layers).rev() {
        let bp = &params.blocks[l];
        let gb = &mut grads.blocks[l];
        let lc = &s.layers[l];
        // MLP path: x_out = x_mid + W₂·relu(W₁·LN₂(x_mid)+b₁)+b₂
        colsum_acc(&s.dx, d, &mut gb.mlp_b2);
        matmul_xt_acc(&lc.hid, bn, 2 * d, &s.dx, d, &mut gb.mlp_w2);
        matmul_wt(&s.dx, bn, d, &bp.mlp_w2, 2 * d, &mut s.dhid, false);
        for (dv, &hv) in s.dhid.iter_mut().zip(&lc.hid) {
            if hv <= 0.0 {
                *dv = 0.0;
            }
        }
        colsum_acc(&s.dhid, 2 * d, &mut gb.mlp_b1);
        matmul_xt_acc(&lc.xn2, bn, d, &s.dhid, 2 * d, &mut gb.mlp_w1);
        matmul_wt(&s.dhid, bn, 2 * d, &bp.mlp_w1, d, &mut s.tmp1, false);
        layernorm_bwd(&s.tmp1, &bp.ln2_g, &lc.ln2, &mut gb.ln2_g,
                      &mut gb.ln2_b, &mut s.tmp3);
        for (xv, &tv) in s.dx.iter_mut().zip(s.tmp3.iter()) {
            *xv += tv;
        }
        // mixer path: x_mid = x_in + mix(LN₁(x_in))
        mixer::train::bwd(cfg, l, &bp.mixer, &mut gb.mixer, lc, b, &s.dx,
                          &mut s.tmp2, &mut s.tmp1, &mut s.tmp3, &mut s.zs,
                          &mut s.znh, &mut s.dqh, &mut s.dkh,
                          &mut s.dvh)?;
        layernorm_bwd(&s.tmp2, &bp.ln1_g, &lc.ln1, &mut gb.ln1_g,
                      &mut gb.ln1_b, &mut s.tmp3);
        for (xv, &tv) in s.dx.iter_mut().zip(s.tmp3.iter()) {
            *xv += tv;
        }
    }

    // embedding backward
    match (&cfg.task, &mut grads.embed) {
        (&TaskKind::Vit { patch_size, n_channels, .. },
         EmbedParams::Vit { embed_w, embed_b }) => {
            colsum_acc(&s.dx, d, embed_b);
            let pd = patch_size * patch_size * n_channels;
            matmul_xt_acc(&s.patches, bn, pd, &s.dx, d, embed_w);
        }
        (TaskKind::Lm { .. }, EmbedParams::Lm { tok_emb }) => {
            for (&tok, dxrow) in s.tokens.iter().zip(s.dx.chunks_exact(d)) {
                let erow = &mut tok_emb[tok as usize * d..][..d];
                for (ev, &dv) in erow.iter_mut().zip(dxrow) {
                    *ev += dv;
                }
            }
        }
        _ => bail!("embed/task mismatch"),
    }
    for bi in 0..b {
        for i in 0..n {
            let dxrow = &s.dx[(bi * n + i) * d..][..d];
            let prow = &mut grads.pos[i * d..(i + 1) * d];
            for (pv, &dv) in prow.iter_mut().zip(dxrow) {
                *pv += dv;
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// the trainable model
// ---------------------------------------------------------------------------

/// A trainable native CAT model: parameters + gradients + step scratch.
/// Fully deterministic in `(config, seed, batch stream)` — bit-identical
/// loss curves regardless of pool width.
pub struct TrainModel {
    cfg: TrainConfig,
    n_params: usize,
    params: ModelParams,
    grads: ModelParams,
    scratch: Scratch,
}

impl TrainModel {
    pub fn new(cfg: TrainConfig, seed: u64) -> Result<TrainModel> {
        cfg.validate()?;
        let mut params = ModelParams::init(&cfg, seed);
        let n_params = params.n_params();
        let grads = params.zeros_like();
        Ok(TrainModel {
            cfg,
            n_params,
            params,
            grads,
            scratch: Scratch::default(),
        })
    }

    pub fn cfg(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Total learnable scalars.
    pub fn param_count(&self) -> usize {
        self.n_params
    }

    /// Forward + loss + metric ingredients; caches activations so a
    /// subsequent [`Self::backward`] can run.
    pub fn forward_eval(&mut self, batch: &TrainBatch) -> Result<EvalOut> {
        let TrainModel { cfg, params, scratch, .. } = self;
        forward_pass(cfg, params, scratch, batch)
    }

    /// Reverse pass over the cached step; gradients are zeroed first.
    pub fn backward(&mut self) -> Result<()> {
        for (_, g, _) in self.grads.tensors_mut() {
            g.fill(0.0);
        }
        let TrainModel { cfg, params, grads, scratch, .. } = self;
        backward_pass(cfg, params, grads, scratch)
    }

    /// One forward+backward; returns the loss.
    pub fn loss_and_grad(&mut self, batch: &TrainBatch) -> Result<f32> {
        let out = self.forward_eval(batch)?;
        self.backward()?;
        Ok(out.loss)
    }

    /// `(param, grad, decays)` tensor pairs in the fixed visitor order —
    /// the optimizer's contract ([`super::optim::AdamW::step`]).
    pub fn opt_tensors(&mut self)
                       -> Vec<(&mut Vec<f32>, &mut Vec<f32>, bool)> {
        let TrainModel { params, grads, .. } = self;
        params
            .tensors_mut()
            .into_iter()
            .zip(grads.tensors_mut())
            .map(|((_, p, decay), (_, g, _))| (p, g, decay))
            .collect()
    }

    /// `(name, tensor)` pairs in the fixed visitor order — the
    /// checkpoint serializer's contract (`train::NativeTrainer::
    /// save_checkpoint`).
    pub fn tensors_for_io(&mut self) -> Vec<(&'static str, &mut Vec<f32>)> {
        self.params
            .tensors_mut()
            .into_iter()
            .map(|(name, t, _)| (name, t))
            .collect()
    }

    /// Tensor names + lengths in visitor order (grad-check indexing).
    pub fn tensor_infos(&mut self) -> Vec<(&'static str, usize)> {
        self.params
            .tensors_mut()
            .iter()
            .map(|(name, t, _)| (*name, t.len()))
            .collect()
    }

    /// Nudge one parameter scalar (finite-difference probes).
    pub fn perturb(&mut self, tensor: usize, elem: usize, delta: f32) {
        let mut ts = self.params.tensors_mut();
        ts[tensor].1[elem] += delta;
    }

    /// Read one parameter scalar (exact restore after probing).
    pub fn param_at(&mut self, tensor: usize, elem: usize) -> f32 {
        let ts = self.params.tensors_mut();
        ts[tensor].1[elem]
    }

    /// Read one gradient scalar after [`Self::backward`].
    pub fn grad_at(&mut self, tensor: usize, elem: usize) -> f32 {
        let ts = self.grads.tensors_mut();
        ts[tensor].1[elem]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn softmax_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut p: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        softmax_in_place(&mut p);
        p
    }

    fn randv(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.normal()).collect()
    }

    #[test]
    fn corr_forward_matches_naive_gather() {
        let (n, dh) = (16usize, 3usize);
        let p = softmax_vec(n, 1);
        let v = randv(dh * n, 2);
        let got = corr_forward(&p, &v, dh);
        for c in 0..dh {
            for i in 0..n {
                let mut want = 0.0f32;
                for (k, &pv) in p.iter().enumerate() {
                    want += pv * v[c * n + (i + k) % n];
                }
                assert!((got[c * n + i] - want).abs() < 1e-5,
                        "c={c} i={i}: {} vs {want}", got[c * n + i]);
            }
        }
    }

    #[test]
    fn causal_forward_is_causal_and_matches_naive() {
        let (n, dh) = (8usize, 2usize);
        let p = softmax_vec(n, 3);
        let v = randv(dh * n, 4);
        let got = causal_corr_forward(&p, &v, dh);
        for c in 0..dh {
            for i in 0..n {
                let mut want = 0.0f32;
                for j in 0..=i {
                    want += p[i - j] * v[c * n + j];
                }
                assert!((got[c * n + i] - want).abs() < 1e-5,
                        "c={c} i={i}");
            }
        }
        // causality: changing v beyond position i0 must not move out[..=i0]
        let i0 = 3;
        let mut v2 = v.clone();
        for c in 0..dh {
            for j in (i0 + 1)..n {
                v2[c * n + j] += 10.0;
            }
        }
        let got2 = causal_corr_forward(&p, &v2, dh);
        for c in 0..dh {
            for i in 0..=i0 {
                assert!((got[c * n + i] - got2[c * n + i]).abs() < 1e-5,
                        "future leak at c={c} i={i}");
            }
        }
    }

    #[test]
    fn corr_backward_matches_finite_difference() {
        let (n, dh) = (8usize, 2usize);
        let p = softmax_vec(n, 5);
        let v = randv(dh * n, 6);
        let dout = randv(dh * n, 7);
        let loss = |p: &[f32], v: &[f32]| -> f64 {
            corr_forward(p, v, dh)
                .iter()
                .zip(&dout)
                .map(|(&o, &w)| (o * w) as f64)
                .sum()
        };
        let (dp, dv) = corr_backward(&p, &v, &dout, dh);
        let eps = 1e-3f32;
        for j in 0..n {
            let mut pp = p.clone();
            pp[j] += eps;
            let lp = loss(&pp, &v);
            pp[j] -= 2.0 * eps;
            let lm = loss(&pp, &v);
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!((fd - dp[j]).abs() <= 1e-2 * fd.abs().max(dp[j].abs()).max(0.05),
                    "dp[{j}]: fd {fd} vs analytic {}", dp[j]);
        }
        for j in 0..dh * n {
            let mut vv = v.clone();
            vv[j] += eps;
            let lp = loss(&p, &vv);
            vv[j] -= 2.0 * eps;
            let lm = loss(&p, &vv);
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!((fd - dv[j]).abs() <= 1e-2 * fd.abs().max(dv[j].abs()).max(0.05),
                    "dv[{j}]: fd {fd} vs analytic {}", dv[j]);
        }
    }

    fn tiny_vit_batch(cfg: &TrainConfig, seed: u64) -> TrainBatch {
        let TaskKind::Vit { image_size, n_channels, n_classes, .. } =
            cfg.task
        else {
            panic!("vit cfg expected")
        };
        let b = cfg.batch_size;
        let image_len = n_channels * image_size * image_size;
        let mut rng = Rng::new(seed);
        TrainBatch::Vit {
            images: (0..b * image_len)
                .map(|_| rng.range_f32(-1.0, 1.0))
                .collect(),
            labels: (0..b).map(|i| (i % n_classes) as i32).collect(),
        }
    }

    #[test]
    fn vit_step_is_finite_and_deterministic() {
        let cfg = TrainConfig::tiny();
        let batch = tiny_vit_batch(&cfg, 11);
        let mut m1 = TrainModel::new(cfg, 42).unwrap();
        let mut m2 = TrainModel::new(cfg, 42).unwrap();
        let l1 = m1.loss_and_grad(&batch).unwrap();
        let l2 = m2.loss_and_grad(&batch).unwrap();
        assert!(l1.is_finite() && l1 > 0.0);
        assert_eq!(l1, l2, "same seed + batch must give identical loss");
        let infos = m1.tensor_infos();
        assert_eq!(infos, m2.tensor_infos());
        let mut nonzero = 0usize;
        for (t, (_, len)) in infos.iter().enumerate() {
            for e in 0..*len {
                let g1 = m1.grad_at(t, e);
                assert_eq!(g1, m2.grad_at(t, e));
                assert!(g1.is_finite());
                if g1 != 0.0 {
                    nonzero += 1;
                }
            }
        }
        assert!(nonzero > m1.param_count() / 4,
                "gradients are mostly zero: {nonzero}");
        // loss ~ ln(10) at init (untrained, 10 classes)
        assert!((l1 - 10.0f32.ln()).abs() < 1.0, "odd init loss {l1}");
    }

    #[test]
    fn lm_step_masked_and_causal_are_finite() {
        for causal in [false, true] {
            let cfg = TrainConfig {
                d_model: 16,
                n_heads: 2,
                n_layers: 2,
                batch_size: 2,
                mixer: Mixer::CatFft,
                alternate: true, // covers the attention mixer too
                fnet_truncate: false,
                task: TaskKind::Lm { vocab: 64, seq_len: 16, causal },
            };
            let mut m = TrainModel::new(cfg, 9).unwrap();
            let n = cfg.n_tokens();
            let b = cfg.batch_size;
            let mut rng = Rng::new(13);
            let tokens: Vec<i32> =
                (0..b * n).map(|_| rng.below(64) as i32).collect();
            let targets: Vec<i32> =
                (0..b * n).map(|_| rng.below(64) as i32).collect();
            let weights: Vec<f32> = (0..b * n)
                .map(|i| if i % 3 == 0 { 1.0 } else { 0.0 })
                .collect();
            let batch = TrainBatch::Lm { tokens, targets, weights };
            let loss = m.loss_and_grad(&batch).unwrap();
            assert!(loss.is_finite() && loss > 0.0,
                    "causal={causal} loss {loss}");
            // ~ln(64) at init
            assert!((loss - 64.0f32.ln()).abs() < 1.5,
                    "causal={causal} odd init loss {loss}");
        }
    }

    #[test]
    fn param_and_grad_trees_stay_in_sync() {
        for cfg in [
            TrainConfig::vit(Mixer::CatFft, true),
            TrainConfig::lm(Mixer::Attention, true, false),
        ] {
            let mut m = TrainModel::new(cfg, 0).unwrap();
            let p: Vec<(&str, usize)> = m
                .params
                .tensors_mut()
                .iter()
                .map(|(n, t, _)| (*n, t.len()))
                .collect();
            let g: Vec<(&str, usize)> = m
                .grads
                .tensors_mut()
                .iter()
                .map(|(n, t, _)| (*n, t.len()))
                .collect();
            assert_eq!(p, g, "param/grad visitor order diverged");
            assert_eq!(m.param_count(),
                       p.iter().map(|(_, l)| l).sum::<usize>());
        }
    }

    #[test]
    fn cat_param_budget_matches_paper() {
        // one CAT block's mixer budget is (d+h)·d vs attention's 3d²
        let d = 64;
        let h = 4;
        let mut cat = TrainModel::new(
            TrainConfig::vit(Mixer::CatFft, false), 0).unwrap();
        let mut attn = TrainModel::new(
            TrainConfig::vit(Mixer::Attention, false), 0).unwrap();
        let cat_mix: usize = cat
            .tensor_infos()
            .iter()
            .filter(|(n, _)| *n == "w_a" || *n == "w_v")
            .map(|(_, l)| l)
            .sum();
        let attn_mix: usize = attn
            .tensor_infos()
            .iter()
            .filter(|(n, _)| matches!(*n, "w_q" | "w_k" | "w_v"))
            .map(|(_, l)| l)
            .sum();
        assert_eq!(cat_mix, 2 * (d + h) * d); // two layers
        assert_eq!(attn_mix, 2 * 3 * d * d);
        assert!(cat.param_count() < attn.param_count());
    }

    #[test]
    fn batched_causal_stripes_bit_match_per_row_reference() {
        for (n, dh) in [(4usize, 1usize), (8, 3), (16, 4), (32, 2)] {
            let p = softmax_vec(n, 21);
            let v = randv(dh * n, 22);
            let dout = randv(dh * n, 23);
            assert_eq!(causal_corr_forward(&p, &v, dh),
                       causal_corr_forward_batched(&p, &v, dh),
                       "n={n} dh={dh} forward");
            assert_eq!(causal_corr_backward(&p, &v, &dout, dh),
                       causal_corr_backward_batched(&p, &v, &dout, dh),
                       "n={n} dh={dh} backward");
        }
    }

    #[test]
    fn panel_attention_backward_bit_matches_row_reference() {
        for (n, dh, causal) in
            [(7usize, 3usize, false), (33, 5, true), (64, 16, false),
             (97, 8, true)] {
            let q = randv(n * dh, 31);
            let k = randv(n * dh, 32);
            let v = randv(n * dh, 33);
            let dout = randv(n * dh, 34);
            // softmax probe rows exactly as the forward caches them
            let scale = 1.0 / (dh as f32).sqrt();
            let mut probs = vec![0.0f32; n * n];
            for i in 0..n {
                let lim = if causal { i + 1 } else { n };
                let prow = &mut probs[i * n..(i + 1) * n];
                for (j, slot) in prow.iter_mut().take(lim).enumerate() {
                    let mut dot = 0.0f32;
                    for c in 0..dh {
                        dot += q[i * dh + c] * k[j * dh + c];
                    }
                    *slot = dot * scale;
                }
                softmax_in_place(&mut prow[..lim]);
                prow[lim..].fill(0.0);
            }
            let tiled = attention_backward(&q, &k, &v, &probs, &dout, n,
                                           dh, causal, true);
            let rows = attention_backward(&q, &k, &v, &probs, &dout, n,
                                          dh, causal, false);
            assert_eq!(tiled, rows, "n={n} dh={dh} causal={causal}");
        }
    }

    #[test]
    fn tiled_xt_matches_naive_on_both_strategies() {
        // (rows, inner, cols) spanning: serial tiled, k-parallel (wide),
        // and the narrow row-block partial strategy
        for (rows, inner, cols, tol) in [
            (37usize, 5usize, 9usize, 0.0f32),   // serial: bit-identical
            (300, 96, 96, 0.0),                  // k-parallel: bit-identical
            (3000, 48, 32, 1e-4),                // partials: 2-level tree
        ] {
            let x = randv(rows * inner, 41);
            let dy = randv(rows * cols, 42);
            let mut want = randv(inner * cols, 43); // accumulate semantics
            let mut got = want.clone();
            matmul_xt_acc_naive(&x, rows, inner, &dy, cols, &mut want);
            matmul_xt_acc(&x, rows, inner, &dy, cols, &mut got);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                let bound = tol * a.abs().max(b.abs()).max(1.0);
                assert!((a - b).abs() <= bound,
                        "rows={rows} inner={inner} cols={cols} \
                         elem {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn parallel_colsum_matches_naive() {
        let (rows, cols) = (2048usize, 600usize);
        let dy = randv(rows * cols, 51);
        let mut want = vec![0.5f32; cols];
        let mut got = want.clone();
        colsum_acc_naive(&dy, cols, &mut want);
        colsum_acc(&dy, cols, &mut got);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(b.abs()).max(1.0),
                    "col {i}: {a} vs {b}");
        }
    }

    #[test]
    fn tiled_reductions_are_pool_width_invariant() {
        // the 2-level partial trees must not depend on how chunks land
        // on workers: forced-inline vs fanned-out runs are bit-identical
        let (rows, inner, cols) = (3000usize, 48usize, 64usize);
        let x = randv(rows * inner, 61);
        let dy = randv(rows * cols, 62);
        // big enough that colsum_acc takes its parallel-partials path
        let (crows, ccols) = (2048usize, 600usize);
        let dy2 = randv(crows * ccols, 63);
        let run = |inline: bool| -> (Vec<f32>, Vec<f32>) {
            if inline {
                pool::set_force_inline(true);
            }
            let mut dw = vec![0.0f32; inner * cols];
            matmul_xt_acc(&x, rows, inner, &dy, cols, &mut dw);
            let mut db = vec![0.0f32; ccols];
            colsum_acc(&dy2, ccols, &mut db);
            if inline {
                pool::set_force_inline(false);
            }
            (dw, db)
        };
        assert_eq!(run(false), run(true),
                   "pool width changed the tiled reduction results");
    }
}
