//! Manifest parsing: the contract with `python/compile/aot.py`.
//!
//! `artifacts/manifest.json` describes every AOT-lowered entry point — file
//! name, ordered input/output tensor specs, parameter flattening — plus the
//! model configuration it was lowered from. Parsed with the in-tree JSON
//! substrate ([`crate::json`]); this module is pure data, the PJRT plumbing
//! lives in [`super::client`].

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context};

use crate::json::Json;
use crate::tensor::DType;
use crate::Result;

/// One tensor in an entry signature (call order is the Vec order).
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: v.req("dtype")?.as_str()?.to_string(),
        })
    }

    pub fn dtype(&self) -> Result<DType> {
        DType::from_manifest(&self.dtype)
    }

    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered entry point (init / forward / train_step / train_k8).
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl EntryMeta {
    fn from_json(v: &Json) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.req(key)?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(Self {
            file: v.req("file")?.as_str()?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// Model configuration echoed into the manifest by aot.py.
#[derive(Debug, Clone)]
pub struct ConfigMeta {
    pub task: String,
    pub mechanism: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub n_tokens: usize,
    pub pool: String,
    pub image_size: usize,
    pub patch_size: usize,
    pub n_classes: usize,
    pub n_channels: usize,
    pub vocab_size: usize,
    pub cat_impl: String,
    pub batch_size: usize,
    pub grad_clip: f64,
    pub weight_decay: f64,
    pub causal: bool,
    pub param_count: usize,
    pub params: Vec<TensorSpec>,
    pub entries: BTreeMap<String, EntryMeta>,
}

impl ConfigMeta {
    fn from_json(v: &Json) -> Result<Self> {
        let entries = v.req("entries")?
            .as_obj()?
            .iter()
            .map(|(k, e)| Ok((k.clone(), EntryMeta::from_json(e)?)))
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Self {
            task: v.req("task")?.as_str()?.to_string(),
            mechanism: v.req("mechanism")?.as_str()?.to_string(),
            d_model: v.req("d_model")?.as_usize()?,
            n_heads: v.req("n_heads")?.as_usize()?,
            n_layers: v.req("n_layers")?.as_usize()?,
            seq_len: v.req("seq_len")?.as_usize()?,
            n_tokens: v.req("n_tokens")?.as_usize()?,
            pool: v.req("pool")?.as_str()?.to_string(),
            image_size: v.req("image_size")?.as_usize()?,
            patch_size: v.req("patch_size")?.as_usize()?,
            n_classes: v.req("n_classes")?.as_usize()?,
            n_channels: v.req("n_channels")?.as_usize()?,
            vocab_size: v.req("vocab_size")?.as_usize()?,
            cat_impl: v.req("cat_impl")?.as_str()?.to_string(),
            batch_size: v.req("batch_size")?.as_usize()?,
            grad_clip: v.req("grad_clip")?.as_f64()?,
            weight_decay: v.req("weight_decay")?.as_f64()?,
            causal: v.req("causal")?.as_bool()?,
            param_count: v.req("param_count")?.as_usize()?,
            params: v.req("params")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntryMeta> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("entry '{name}' not in manifest"))
    }

    pub fn is_vit(&self) -> bool {
        self.task == "vit"
    }

    pub fn is_lm(&self) -> bool {
        self.task.starts_with("lm_")
    }

    /// Number of flattened parameter leaves.
    pub fn n_param_leaves(&self) -> usize {
        self.params.len()
    }
}

/// The whole artifact registry.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub configs: BTreeMap<String, ConfigMeta>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = crate::json::parse(text).context("parsing manifest.json")?;
        let configs = v.req("configs")?
            .as_obj()?
            .iter()
            .map(|(name, c)| {
                let meta = ConfigMeta::from_json(c)
                    .with_context(|| format!("config '{name}'"))?;
                Ok((name.clone(), meta))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Self {
            version: v.req("version")?.as_usize()? as u32,
            configs,
        })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make \
                                      artifacts`"))?;
        Self::parse(&text)
    }

    pub fn config(&self, name: &str) -> Result<&ConfigMeta> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("config '{name}' not in manifest \
                                    ({} known)", self.configs.len()))
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.configs.keys()
    }

    /// Absolute path of one entry's HLO text file.
    pub fn hlo_path(&self, dir: &Path, config: &str, entry: &str)
                    -> Result<PathBuf> {
        let c = self.config(config)?;
        let e = c.entry(entry)?;
        Ok(dir.join(&e.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "configs": {
        "m": {
          "task": "vit", "mechanism": "cat", "d_model": 64,
          "n_heads": 4, "n_layers": 2, "seq_len": 0, "n_tokens": 64,
          "pool": "avg", "image_size": 32, "patch_size": 4,
          "n_classes": 10, "n_channels": 3, "vocab_size": 1024,
          "cat_impl": "fft", "batch_size": 8, "grad_clip": 0.0,
          "weight_decay": 0.0001, "causal": false, "param_count": 123,
          "params": [{"name": "['a']", "shape": [2, 3], "dtype": "f32"}],
          "entries": {
            "forward": {
              "file": "m.forward.hlo.txt",
              "inputs": [{"name": "['a']", "shape": [2,3], "dtype": "f32"},
                         {"name": "images", "shape": [8,3,32,32],
                          "dtype": "f32"}],
              "outputs": [{"name": "logits", "shape": [8,10],
                           "dtype": "f32"}]
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let c = m.config("m").unwrap();
        assert!(c.is_vit());
        assert_eq!(c.n_param_leaves(), 1);
        let e = c.entry("forward").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.outputs[0].num_elements(), 80);
        assert!(m.config("nope").is_err());
        assert!(c.entry("nope").is_err());
    }

    #[test]
    fn dtype_roundtrip() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let spec = &m.config("m").unwrap().params[0];
        assert_eq!(spec.dtype().unwrap(), DType::F32);
    }

    #[test]
    fn missing_key_reports_config_name() {
        let bad = r#"{"version": 1, "configs": {"broken": {"task": "vit"}}}"#;
        let err = Manifest::parse(bad).unwrap_err().to_string();
        assert!(err.contains("broken"), "{err}");
    }
}
