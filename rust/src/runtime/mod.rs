//! PJRT runtime layer: artifact manifest, executable cache, training state.
//!
//! ```no_run
//! use cat::runtime::Runtime;
//! let rt = Runtime::from_env().unwrap();
//! let fwd = rt.load("vit_b_avg_cat", "forward").unwrap();
//! ```

pub mod artifact;
pub mod client;
pub mod params;
pub mod validate;

pub use artifact::{ConfigMeta, EntryMeta, Manifest, TensorSpec};
pub use client::{Executable, Runtime};
pub use params::TrainState;
pub use validate::validate;
