//! Runtime layer: backend selection plus the PJRT execution stack.
//!
//! The artifact manifest ([`artifact`]) is always available — it is pure
//! data. The PJRT pieces (executable cache, training state, deep
//! validation) compile only with the `pjrt` feature; without it the
//! coordinator runs on [`crate::native`], selected through [`Backend`].
//!
//! ```no_run
//! # #[cfg(feature = "pjrt")] {
//! use cat::runtime::Runtime;
//! let rt = Runtime::from_env().unwrap();
//! let fwd = rt.load("vit_b_avg_cat", "forward").unwrap();
//! # }
//! ```

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod params;
#[cfg(feature = "pjrt")]
pub mod validate;

pub use artifact::{ConfigMeta, EntryMeta, Manifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use client::{Executable, Runtime};
#[cfg(feature = "pjrt")]
pub use params::TrainState;
#[cfg(feature = "pjrt")]
pub use validate::validate;

/// Which execution engine computes forward passes.
///
/// * [`Backend::Pjrt`] — AOT-compiled HLO artifacts through the PJRT CPU
///   client (feature `pjrt`; needs `make artifacts`).
/// * [`Backend::Native`] — the in-crate Rust CAT executor
///   ([`crate::native`]); hermetic, no artifacts, no Python anywhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Pjrt,
    Native,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "pjrt" => Some(Backend::Pjrt),
            "native" => Some(Backend::Native),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Pjrt => "pjrt",
            Backend::Native => "native",
        }
    }

    /// Pick the best available backend: PJRT when it is compiled in *and*
    /// an artifact manifest exists under `artifacts`, else native.
    pub fn detect(artifacts: &std::path::Path) -> Backend {
        if cfg!(feature = "pjrt")
            && artifacts.join("manifest.json").exists() {
            Backend::Pjrt
        } else {
            Backend::Native
        }
    }

    /// [`Backend::detect`] over the default artifact directory.
    pub fn detect_env() -> Backend {
        Backend::detect(&crate::artifacts_dir())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_roundtrip() {
        for b in [Backend::Pjrt, Backend::Native] {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        assert_eq!(Backend::parse("tpu"), None);
    }

    #[test]
    fn detect_falls_back_to_native() {
        let dir = std::env::temp_dir().join("cat_no_artifacts_here");
        assert_eq!(Backend::detect(&dir), Backend::Native);
    }
}
