//! Artifact validation: the preflight a deployment runs after `make
//! artifacts` (`cat validate`). Checks, per manifest config:
//!
//! * every referenced HLO file exists and is non-empty;
//! * entry signatures are self-consistent (train-step arity, init outputs
//!   == parameter specs, forward batch dims match the config);
//! * parameter counts match `param_count`;
//! * (optionally, `deep=true`) each entry's HLO parses and compiles on
//!   the PJRT client — expensive, catches text corruption.

use std::path::Path;

use super::artifact::{ConfigMeta, Manifest};
use super::client::Runtime;
use crate::Result;

/// One finding; `fatal` distinguishes errors from advisories.
#[derive(Debug, Clone)]
pub struct Finding {
    pub config: String,
    pub message: String,
    pub fatal: bool,
}

/// Validation report over the whole registry.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub configs_checked: usize,
    pub entries_checked: usize,
}

impl Report {
    pub fn ok(&self) -> bool {
        !self.findings.iter().any(|f| f.fatal)
    }

    fn err(&mut self, config: &str, message: String) {
        self.findings.push(Finding { config: config.into(), message,
                                     fatal: true });
    }

    fn warn(&mut self, config: &str, message: String) {
        self.findings.push(Finding { config: config.into(), message,
                                     fatal: false });
    }

    pub fn render(&self) -> String {
        let mut s = format!("validated {} configs / {} entries: {}\n",
                            self.configs_checked, self.entries_checked,
                            if self.ok() { "OK" } else { "FAILED" });
        for f in &self.findings {
            s.push_str(&format!("  [{}] {}: {}\n",
                                if f.fatal { "ERROR" } else { "warn" },
                                f.config, f.message));
        }
        s
    }
}

fn check_config(report: &mut Report, dir: &Path, name: &str,
                meta: &ConfigMeta) {
    // parameter count consistency
    let declared: usize = meta.params.iter().map(|p| p.num_elements()).sum();
    if declared != meta.param_count {
        report.err(name, format!(
            "param specs sum to {declared}, param_count says {}",
            meta.param_count));
    }
    for (entry, em) in &meta.entries {
        report.entries_checked += 1;
        let path = dir.join(&em.file);
        match std::fs::metadata(&path) {
            Err(e) => {
                report.err(name, format!("{entry}: missing {path:?}: {e}"));
                continue;
            }
            Ok(md) if md.len() == 0 => {
                report.err(name, format!("{entry}: empty {path:?}"));
                continue;
            }
            Ok(_) => {}
        }
        match entry.as_str() {
            "init" => {
                if em.outputs.len() != meta.params.len() {
                    report.err(name, format!(
                        "init outputs {} != {} param leaves",
                        em.outputs.len(), meta.params.len()));
                }
                for (o, p) in em.outputs.iter().zip(&meta.params) {
                    if o.shape != p.shape {
                        report.err(name, format!(
                            "init output '{}' shape {:?} != param {:?}",
                            o.name, o.shape, p.shape));
                    }
                }
            }
            "forward" => {
                let n = meta.params.len();
                if em.inputs.len() != n + 1 {
                    report.err(name, format!(
                        "forward inputs {} != params+1 ({})",
                        em.inputs.len(), n + 1));
                } else if meta.task != "mixer" {
                    let b = em.inputs[n].shape.first().copied().unwrap_or(0);
                    if b != meta.batch_size {
                        report.err(name, format!(
                            "forward batch dim {b} != batch_size {}",
                            meta.batch_size));
                    }
                }
            }
            e if e.starts_with("train") => {
                let n = meta.params.len();
                let nbatch = if meta.is_vit() { 2 } else { 3 };
                let want = 3 * n + 1 + nbatch + 1;
                if em.inputs.len() != want {
                    report.err(name, format!(
                        "{e}: {} inputs, expected {want}", em.inputs.len()));
                }
                if em.outputs.len() != 3 * n + 2 {
                    report.err(name, format!(
                        "{e}: {} outputs, expected {}", em.outputs.len(),
                        3 * n + 2));
                }
                if em.outputs.last().map(|o| o.name.as_str())
                    != Some("loss")
                    && em.outputs.last().map(|o| o.name.as_str())
                        != Some("losses") {
                    report.warn(name, format!(
                        "{e}: last output is not loss/losses"));
                }
            }
            other => {
                report.warn(name, format!("unknown entry kind '{other}'"));
            }
        }
    }
}

/// Validate the manifest + files under `dir`. `deep` additionally
/// compiles every entry on the PJRT client.
pub fn validate(dir: &Path, deep: bool) -> Result<Report> {
    let manifest = Manifest::load(dir)?;
    let mut report = Report::default();
    for (name, meta) in &manifest.configs {
        report.configs_checked += 1;
        check_config(&mut report, dir, name, meta);
    }
    if deep && report.ok() {
        let rt = Runtime::new(dir.to_path_buf())?;
        for name in manifest.configs.keys() {
            for entry in manifest.configs[name].entries.keys() {
                if let Err(e) = rt.load(name, entry) {
                    report.err(name, format!("{entry}: compile failed: {e}"));
                }
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{EntryMeta, TensorSpec};
    use std::collections::BTreeMap;

    fn spec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(),
                     dtype: "f32".into() }
    }

    fn tiny_meta(dir: &Path) -> ConfigMeta {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("m.init.hlo.txt"), "HloModule m").unwrap();
        std::fs::write(dir.join("m.forward.hlo.txt"), "HloModule m").unwrap();
        let mut entries = BTreeMap::new();
        entries.insert("init".to_string(), EntryMeta {
            file: "m.init.hlo.txt".into(),
            inputs: vec![TensorSpec { name: "seed".into(), shape: vec![],
                                      dtype: "i32".into() }],
            outputs: vec![spec("['w']", &[2, 3])],
        });
        entries.insert("forward".to_string(), EntryMeta {
            file: "m.forward.hlo.txt".into(),
            inputs: vec![spec("['w']", &[2, 3]),
                         spec("images", &[8, 3, 32, 32])],
            outputs: vec![spec("logits", &[8, 10])],
        });
        ConfigMeta {
            task: "vit".into(), mechanism: "cat".into(), d_model: 64,
            n_heads: 4, n_layers: 1, seq_len: 0, n_tokens: 64,
            pool: "avg".into(), image_size: 32, patch_size: 4,
            n_classes: 10, n_channels: 3, vocab_size: 1024,
            cat_impl: "fft".into(), batch_size: 8, grad_clip: 0.0,
            weight_decay: 1e-4, causal: false, param_count: 6,
            params: vec![spec("['w']", &[2, 3])],
            entries,
        }
    }

    #[test]
    fn consistent_config_passes() {
        let dir = std::env::temp_dir().join("cat_validate_ok");
        let meta = tiny_meta(&dir);
        let mut report = Report::default();
        check_config(&mut report, &dir, "m", &meta);
        assert!(report.ok(), "{}", report.render());
    }

    #[test]
    fn bad_param_count_flagged() {
        let dir = std::env::temp_dir().join("cat_validate_pc");
        let mut meta = tiny_meta(&dir);
        meta.param_count = 999;
        let mut report = Report::default();
        check_config(&mut report, &dir, "m", &meta);
        assert!(!report.ok());
        assert!(report.render().contains("param_count"));
    }

    #[test]
    fn missing_file_flagged() {
        let dir = std::env::temp_dir().join("cat_validate_missing");
        let mut meta = tiny_meta(&dir);
        meta.entries.get_mut("forward").unwrap().file = "nope.hlo.txt".into();
        let mut report = Report::default();
        check_config(&mut report, &dir, "m", &meta);
        assert!(!report.ok());
    }

    #[test]
    fn batch_dim_mismatch_flagged() {
        let dir = std::env::temp_dir().join("cat_validate_batch");
        let mut meta = tiny_meta(&dir);
        meta.batch_size = 16;
        let mut report = Report::default();
        check_config(&mut report, &dir, "m", &meta);
        assert!(!report.ok());
        assert!(report.render().contains("batch dim"));
    }

    #[test]
    fn init_shape_mismatch_flagged() {
        let dir = std::env::temp_dir().join("cat_validate_init");
        let mut meta = tiny_meta(&dir);
        meta.entries.get_mut("init").unwrap().outputs =
            vec![spec("['w']", &[9, 9])];
        let mut report = Report::default();
        check_config(&mut report, &dir, "m", &meta);
        assert!(!report.ok());
    }
}
