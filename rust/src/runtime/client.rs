//! The PJRT runtime: loads HLO-text artifacts, compiles them once on the
//! CPU PJRT client, caches executables, and executes with host tensors.
//!
//! Mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`;
//! outputs come back as one tuple literal (aot.py lowers with
//! `return_tuple=True`) which we decompose into per-output literals.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context};

use super::artifact::{ConfigMeta, EntryMeta, Manifest};
use crate::metrics::lock_recovering;
use crate::tensor::HostTensor;
use crate::Result;

/// A compiled entry point plus its manifest signature.
pub struct Executable {
    pub config: String,
    pub entry: String,
    pub meta: EntryMeta,
    exe: xla::PjRtLoadedExecutable,
    /// cumulative execute statistics (count, total seconds)
    stats: Mutex<(u64, f64)>,
}

impl Executable {
    /// Execute with literal inputs (owned or borrowed); returns decomposed
    /// output literals.
    pub fn execute_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self, inputs: &[L]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!("{}.{}: got {} inputs, manifest says {}",
                  self.config, self.entry, inputs.len(),
                  self.meta.inputs.len());
        }
        let t0 = Instant::now();
        let result = self.exe.execute::<L>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        let dt = t0.elapsed().as_secs_f64();
        let mut s = lock_recovering(&self.stats);
        s.0 += 1;
        s.1 += dt;
        if outs.len() != self.meta.outputs.len() {
            bail!("{}.{}: got {} outputs, manifest says {}",
                  self.config, self.entry, outs.len(),
                  self.meta.outputs.len());
        }
        Ok(outs)
    }

    /// Execute with host tensors (convenience for data-pipeline callers).
    pub fn execute(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let outs = self.execute_literals(&lits)?;
        outs.iter().map(HostTensor::from_literal).collect()
    }

    /// (calls, total seconds) since creation.
    pub fn exec_stats(&self) -> (u64, f64) {
        *lock_recovering(&self.stats)
    }
}

/// Owns the PJRT client and an executable cache keyed by (config, entry).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<(String, String), Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact directory.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self { client, manifest, dir, cache: Mutex::new(HashMap::new()) })
    }

    /// Create from the default artifact directory (env `CAT_ARTIFACTS`).
    pub fn from_env() -> Result<Self> {
        Self::new(crate::artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn config(&self, name: &str) -> Result<&ConfigMeta> {
        self.manifest.config(name)
    }

    /// Compile (or fetch from cache) one entry point.
    pub fn load(&self, config: &str, entry: &str) -> Result<Arc<Executable>> {
        let key = (config.to_string(), entry.to_string());
        if let Some(e) = lock_recovering(&self.cache).get(&key) {
            return Ok(e.clone());
        }
        let meta = self.manifest.config(config)?.entry(entry)?.clone();
        let path = self.dir.join(&meta.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("utf-8 artifact path"))
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))
            .context("run `make artifacts`?")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {config}.{entry}: {e}"))?;
        let compiled = Arc::new(Executable {
            config: config.to_string(),
            entry: entry.to_string(),
            meta,
            exe,
            stats: Mutex::new((0, 0.0)),
        });
        crate::obs::log::log_fields(
            crate::obs::log::Level::Info, "runtime", "compiled entry",
            &[("config", config), ("entry", entry),
              ("seconds",
               &format!("{:.2}", t0.elapsed().as_secs_f64()))]);
        lock_recovering(&self.cache).insert(key, compiled.clone());
        Ok(compiled)
    }

    /// Number of cached executables (diagnostics).
    pub fn cached(&self) -> usize {
        lock_recovering(&self.cache).len()
    }
}
