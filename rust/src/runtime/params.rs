//! Training-state management: parameter/optimizer literals, initialization
//! through the AOT `init` artifact, and binary checkpointing.
//!
//! The state layout mirrors the train_step signature from aot.py:
//! `[params..., m..., v..., step]` — all `xla::Literal`s, fed to the
//! executable in manifest order and replaced wholesale by its outputs.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::bail;

use super::artifact::ConfigMeta;
use super::client::Runtime;
use crate::tensor::HostTensor;
use crate::Result;

/// Mutable training state for one model.
pub struct TrainState {
    /// flattened parameter leaves (manifest order)
    pub params: Vec<xla::Literal>,
    /// AdamW first-moment leaves
    pub m: Vec<xla::Literal>,
    /// AdamW second-moment leaves
    pub v: Vec<xla::Literal>,
    /// step counter (f32 scalar, advanced inside the executable)
    pub step: xla::Literal,
}

impl TrainState {
    /// Initialize parameters by executing the `init` artifact with `seed`,
    /// and zero optimizer moments host-side from the manifest shapes.
    pub fn init(rt: &Runtime, config: &str, seed: i32) -> Result<Self> {
        let meta = rt.config(config)?.clone();
        let init = rt.load(config, "init")?;
        let seed_lit = HostTensor::scalar_i32(seed).to_literal()?;
        let params = init.execute_literals(&[seed_lit])?;
        if params.len() != meta.n_param_leaves() {
            bail!("init returned {} leaves, manifest says {}",
                  params.len(), meta.n_param_leaves());
        }
        let zeros = Self::zero_moments(&meta)?;
        Ok(Self {
            params,
            m: zeros.0,
            v: zeros.1,
            step: HostTensor::scalar_f32(0.0).to_literal()?,
        })
    }

    fn zero_moments(meta: &ConfigMeta)
                    -> Result<(Vec<xla::Literal>, Vec<xla::Literal>)> {
        let mut m = Vec::with_capacity(meta.params.len());
        let mut v = Vec::with_capacity(meta.params.len());
        for spec in &meta.params {
            let z = HostTensor::zeros_f32(spec.shape.clone()).to_literal()?;
            m.push(z);
            let z = HostTensor::zeros_f32(spec.shape.clone()).to_literal()?;
            v.push(z);
        }
        Ok((m, v))
    }

    /// Current step counter value.
    pub fn step_value(&self) -> Result<f32> {
        HostTensor::from_literal(&self.step)?.scalar_value_f32()
    }

    /// Assemble the leading `[params, m, v, step]` segment of a
    /// train_step/train_k8 argument list.
    pub fn opt_inputs(&self) -> Vec<&xla::Literal> {
        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(3 * self.params.len() + 1);
        args.extend(self.params.iter());
        args.extend(self.m.iter());
        args.extend(self.v.iter());
        args.push(&self.step);
        args
    }

    /// Replace state from train_step outputs
    /// `[params..., m..., v..., step, loss]`; returns the trailing
    /// non-state outputs (step', loss — loss may be a (K,) vector for
    /// the fused K-step artifact).
    pub fn absorb(&mut self, mut outs: Vec<xla::Literal>)
                  -> Result<Vec<xla::Literal>> {
        let n = self.params.len();
        if outs.len() < 3 * n + 2 {
            bail!("train outputs too short: {} < {}", outs.len(), 3 * n + 2);
        }
        let rest = outs.split_off(3 * n);
        let mut outs = outs;
        self.v = outs.split_off(2 * n);
        self.m = outs.split_off(n);
        self.params = outs;
        let mut rest = rest;
        let tail = rest.split_off(1);
        self.step = rest.pop().expect("step literal");
        Ok(tail)
    }

    /// Copy parameters out as host tensors (checkpointing / inspection).
    pub fn params_host(&self) -> Result<Vec<HostTensor>> {
        self.params.iter().map(HostTensor::from_literal).collect()
    }

    // -- checkpointing ------------------------------------------------------
    //
    // Format: magic, version, step, then for each of params/m/v in manifest
    // order: rank, dims..., f32 payload. Little-endian throughout.

    const MAGIC: &'static [u8; 8] = b"CATCKPT1";

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(Self::MAGIC)?;
        w.write_all(&self.step_value()?.to_le_bytes())?;
        for group in [&self.params, &self.m, &self.v] {
            w.write_all(&(group.len() as u32).to_le_bytes())?;
            for lit in group.iter() {
                let t = HostTensor::from_literal(lit)?;
                let data = t.as_f32()?;
                w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
                for &d in &t.shape {
                    w.write_all(&(d as u64).to_le_bytes())?;
                }
                for &x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            bail!("{path:?} is not a CAT checkpoint");
        }
        let mut f4 = [0u8; 4];
        r.read_exact(&mut f4)?;
        let step = f32::from_le_bytes(f4);
        let mut groups: Vec<Vec<xla::Literal>> = Vec::with_capacity(3);
        for _ in 0..3 {
            r.read_exact(&mut f4)?;
            let count = u32::from_le_bytes(f4) as usize;
            let mut group = Vec::with_capacity(count);
            for _ in 0..count {
                r.read_exact(&mut f4)?;
                let rank = u32::from_le_bytes(f4) as usize;
                let mut shape = Vec::with_capacity(rank);
                let mut d8 = [0u8; 8];
                for _ in 0..rank {
                    r.read_exact(&mut d8)?;
                    shape.push(u64::from_le_bytes(d8) as usize);
                }
                let n: usize = shape.iter().product();
                let mut data = vec![0f32; n];
                for x in data.iter_mut() {
                    r.read_exact(&mut f4)?;
                    *x = f32::from_le_bytes(f4);
                }
                group.push(HostTensor::f32(shape, data)?.to_literal()?);
            }
            groups.push(group);
        }
        let v = groups.pop().expect("v group");
        let m = groups.pop().expect("m group");
        let params = groups.pop().expect("params group");
        Ok(Self {
            params,
            m,
            v,
            step: HostTensor::scalar_f32(step).to_literal()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lit(vals: &[f32], shape: &[usize]) -> xla::Literal {
        HostTensor::f32(shape.to_vec(), vals.to_vec())
            .unwrap()
            .to_literal()
            .unwrap()
    }

    fn tiny_state() -> TrainState {
        TrainState {
            params: vec![lit(&[1.0, 2.0], &[2]), lit(&[3.0], &[1])],
            m: vec![lit(&[0.1, 0.2], &[2]), lit(&[0.3], &[1])],
            v: vec![lit(&[0.01, 0.02], &[2]), lit(&[0.03], &[1])],
            step: HostTensor::scalar_f32(5.0).to_literal().unwrap(),
        }
    }

    #[test]
    fn absorb_splits_outputs() {
        let mut st = tiny_state();
        let outs = vec![
            lit(&[10.0, 20.0], &[2]), lit(&[30.0], &[1]),   // params
            lit(&[1.1, 2.2], &[2]), lit(&[3.3], &[1]),      // m
            lit(&[0.5, 0.6], &[2]), lit(&[0.7], &[1]),      // v
            HostTensor::scalar_f32(6.0).to_literal().unwrap(), // step
            HostTensor::scalar_f32(0.25).to_literal().unwrap(), // loss
        ];
        let tail = st.absorb(outs).unwrap();
        assert_eq!(st.step_value().unwrap(), 6.0);
        let loss = HostTensor::from_literal(&tail[0]).unwrap();
        assert_eq!(loss.scalar_value_f32().unwrap(), 0.25);
        let p0 = HostTensor::from_literal(&st.params[0]).unwrap();
        assert_eq!(p0.as_f32().unwrap(), &[10.0, 20.0]);
        let v1 = HostTensor::from_literal(&st.v[1]).unwrap();
        assert_eq!(v1.as_f32().unwrap(), &[0.7]);
    }

    #[test]
    fn absorb_rejects_short_output() {
        let mut st = tiny_state();
        assert!(st.absorb(vec![lit(&[0.0], &[1])]).is_err());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let st = tiny_state();
        let dir = std::env::temp_dir().join("cat_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.ckpt");
        st.save(&path).unwrap();
        let st2 = TrainState::load(&path).unwrap();
        assert_eq!(st2.step_value().unwrap(), 5.0);
        let a = HostTensor::from_literal(&st.params[0]).unwrap();
        let b = HostTensor::from_literal(&st2.params[0]).unwrap();
        assert_eq!(a, b);
        std::fs::remove_file(path).ok();
    }
}
