//! Host-side metrics: classification accuracy, masked/causal perplexity,
//! loss curves, latency histograms for the serving path, and the
//! process-wide lock-poison recovery counter.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::data::Truth;
use crate::tensor::HostTensor;
use crate::Result;
use anyhow::bail;

/// Poisoned mutex guards recovered instead of cascading the panic
/// (see [`lock_recovering`]).
static LOCK_POISON_RECOVERIES: AtomicU64 = AtomicU64::new(0);

/// Lock a mutex, recovering a poisoned guard instead of panicking. A
/// worker that panicked while holding a stats lock must not take
/// `/metrics` scrapes or `shutdown()` down with it — the guarded data
/// (counters, histograms) is valid at every intermediate state, so the
/// recovery is safe. Every recovery bumps a process-wide counter
/// ([`lock_poison_recoveries`], exported as
/// `cat_lock_poison_recoveries_total`) so silent poisoning is still
/// observable.
pub fn lock_recovering<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| {
        LOCK_POISON_RECOVERIES.fetch_add(1, Ordering::Relaxed);
        poisoned.into_inner()
    })
}

/// Process-wide count of poisoned locks recovered by
/// [`lock_recovering`].
pub fn lock_poison_recoveries() -> u64 {
    LOCK_POISON_RECOVERIES.load(Ordering::Relaxed)
}

/// Top-1 accuracy from (B, C) logits and (B,) labels.
pub fn accuracy(logits: &HostTensor, labels: &[i32]) -> Result<f64> {
    let [b, c] = logits.shape[..] else {
        bail!("accuracy expects rank-2 logits, got {:?}", logits.shape)
    };
    if b != labels.len() {
        bail!("batch mismatch: {b} logits vs {} labels", labels.len());
    }
    let data = logits.as_f32()?;
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        let row = &data[i * c..(i + 1) * c];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).expect("finite logits"))
            .map(|(j, _)| j)
            .expect("non-empty row");
        if argmax == label as usize {
            correct += 1;
        }
    }
    Ok(correct as f64 / b as f64)
}

/// Weighted token cross-entropy from (B, N, V) logits; returns
/// (total_nll, total_weight). Perplexity = exp(total_nll / total_weight).
pub fn token_nll(logits: &HostTensor, targets: &[i32], weights: &[f32])
                 -> Result<(f64, f64)> {
    let [b, n, v] = logits.shape[..] else {
        bail!("token_nll expects rank-3 logits, got {:?}", logits.shape)
    };
    if b * n != targets.len() || targets.len() != weights.len() {
        bail!("target/weight length mismatch");
    }
    let data = logits.as_f32()?;
    let mut nll = 0.0f64;
    let mut wsum = 0.0f64;
    for i in 0..b * n {
        let w = weights[i] as f64;
        if w == 0.0 {
            continue;
        }
        let row = &data[i * v..(i + 1) * v];
        // stable log-softmax at the target index
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let lse: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum();
        let logp = (row[targets[i] as usize] as f64) - m - lse.ln();
        nll -= w * logp;
        wsum += w;
    }
    Ok((nll, wsum))
}

/// Accumulates evaluation over batches; reports accuracy or word PPL.
#[derive(Debug, Default, Clone)]
pub struct EvalAccumulator {
    correct_frac_sum: f64,
    batches: usize,
    nll: f64,
    weight: f64,
}

impl EvalAccumulator {
    pub fn update(&mut self, logits: &HostTensor, truth: &Truth<'_>)
                  -> Result<()> {
        match truth {
            Truth::Labels(labels) => {
                self.correct_frac_sum += accuracy(logits, labels)?;
                self.batches += 1;
            }
            Truth::Tokens { targets, weights } => {
                let (nll, w) = token_nll(logits, targets, weights)?;
                self.nll += nll;
                self.weight += w;
                self.batches += 1;
            }
        }
        Ok(())
    }

    pub fn accuracy(&self) -> Option<f64> {
        (self.batches > 0 && self.weight == 0.0)
            .then(|| self.correct_frac_sum / self.batches as f64)
    }

    pub fn perplexity(&self) -> Option<f64> {
        (self.weight > 0.0).then(|| (self.nll / self.weight).exp())
    }

    /// The headline metric, whichever task this is.
    pub fn headline(&self) -> Option<(&'static str, f64)> {
        self.accuracy()
            .map(|a| ("acc", a))
            .or_else(|| self.perplexity().map(|p| ("ppl", p)))
    }
}

/// Simple power-of-two latency histogram (microseconds).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: std::time::Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize).min(31);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Zero every bucket and total, keeping the backing allocation —
    /// lets a scrape-path scratch histogram be reused per `/metrics`
    /// render instead of reallocated (DESIGN.md §13).
    pub fn reset(&mut self) {
        self.buckets.iter_mut().for_each(|b| *b = 0);
        self.count = 0;
        self.sum_us = 0;
        self.max_us = 0;
    }

    /// Fold another histogram into this one (replica-stats aggregation:
    /// buckets and totals add, max takes the larger).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum_us as f64 / self.count as f64 }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Total recorded microseconds (Prometheus `_sum`).
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Cumulative buckets for Prometheus histogram exposition:
    /// `(upper_bound_us, cumulative_count)` pairs in ascending bound
    /// order. Bound `i` is `2^i` µs (bucket `i` holds samples in
    /// `(2^(i-1), 2^i]`); counts are monotone non-decreasing and the
    /// last equals [`Self::count`], so a renderer appends `+Inf` with
    /// the same total. Stable: empty buckets are included, so series
    /// never appear or vanish between scrapes.
    pub fn cumulative_buckets(&self)
                              -> impl Iterator<Item = (u64, u64)> + '_ {
        self.buckets.iter().scan(0u64, |acc, &c| {
            *acc += c;
            Some(*acc)
        }).enumerate().map(|(i, cum)| (1u64 << i, cum))
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << i;
            }
        }
        self.max_us
    }
}

/// Running loss curve with EMA smoothing for progress logs.
#[derive(Debug, Clone)]
pub struct LossCurve {
    pub steps: Vec<u64>,
    pub losses: Vec<f32>,
    ema: Option<f64>,
    alpha: f64,
}

impl Default for LossCurve {
    fn default() -> Self {
        Self { steps: vec![], losses: vec![], ema: None, alpha: 0.05 }
    }
}

impl LossCurve {
    pub fn push(&mut self, step: u64, loss: f32) {
        self.steps.push(step);
        self.losses.push(loss);
        let l = loss as f64;
        self.ema = Some(match self.ema {
            None => l,
            Some(e) => e + self.alpha * (l - e),
        });
    }

    pub fn ema(&self) -> Option<f64> {
        self.ema
    }

    pub fn last(&self) -> Option<f32> {
        self.losses.last().copied()
    }

    pub fn is_finite(&self) -> bool {
        self.losses.iter().all(|l| l.is_finite())
    }

    /// First step at which loss became non-finite (divergence detection,
    /// used by the Sec. 5.5 linear-attention instability experiment).
    pub fn first_divergence(&self) -> Option<u64> {
        self.steps
            .iter()
            .zip(&self.losses)
            .find(|(_, l)| !l.is_finite())
            .map(|(s, _)| *s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn accuracy_counts_argmax() {
        let logits = HostTensor::f32(
            vec![2, 3], vec![0.1, 0.9, 0.0, 0.5, 0.2, 0.3]).unwrap();
        assert_eq!(accuracy(&logits, &[1, 0]).unwrap(), 1.0);
        assert_eq!(accuracy(&logits, &[0, 0]).unwrap(), 0.5);
    }

    #[test]
    fn token_nll_uniform_logits() {
        // uniform logits over V=4 -> nll = ln 4 per weighted token
        let logits = HostTensor::f32(vec![1, 2, 4], vec![0.0; 8]).unwrap();
        let (nll, w) = token_nll(&logits, &[0, 3], &[1.0, 1.0]).unwrap();
        assert!((nll / w - (4f64).ln()).abs() < 1e-9);
        let (_, w0) = token_nll(&logits, &[0, 3], &[0.0, 1.0]).unwrap();
        assert_eq!(w0, 1.0);
    }

    #[test]
    fn eval_accumulator_ppl() {
        let logits = HostTensor::f32(vec![1, 2, 4], vec![0.0; 8]).unwrap();
        let mut acc = EvalAccumulator::default();
        let targets = [0, 1];
        let weights = [1.0, 1.0];
        acc.update(&logits, &Truth::Tokens { targets: &targets,
                                             weights: &weights }).unwrap();
        let ppl = acc.perplexity().unwrap();
        assert!((ppl - 4.0).abs() < 1e-9);
        assert_eq!(acc.headline().unwrap().0, "ppl");
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::default();
        for us in [10u64, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 4);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn histogram_merge_adds_counts_and_keeps_max() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(1000));
        b.record(Duration::from_micros(50));
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max_us(), 1000);
        assert!((a.mean_us() - (10.0 + 1000.0 + 50.0) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = LatencyHistogram::default();
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.sum_us(), 0);
        let buckets: Vec<(u64, u64)> = h.cumulative_buckets().collect();
        assert_eq!(buckets.len(), 32);
        assert!(buckets.iter().all(|&(_, c)| c == 0));
        assert_eq!(buckets.last().unwrap().1, h.count());
    }

    #[test]
    fn single_sample_cumulative_buckets() {
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(300)); // 256 < 300 <= 512 = 2^9
        let buckets: Vec<(u64, u64)> = h.cumulative_buckets().collect();
        // bounds are the powers of two, in order
        assert!(buckets.iter().enumerate().all(|(i, &(b, _))| b == 1 << i));
        // cumulative count steps from 0 to 1 exactly at bound 512
        for &(bound, cum) in &buckets {
            assert_eq!(cum, u64::from(bound >= 512), "bound {bound}");
        }
        assert_eq!(buckets.last().unwrap().1, h.count());
        assert_eq!(h.sum_us(), 300);
    }

    #[test]
    fn merged_histogram_cumulative_buckets_stay_monotone() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        for us in [5u64, 80, 3000] {
            a.record(Duration::from_micros(us));
        }
        for us in [1u64, 80, 1_000_000] {
            b.record(Duration::from_micros(us));
        }
        a.merge(&b);
        let buckets: Vec<(u64, u64)> = a.cumulative_buckets().collect();
        assert!(buckets.windows(2).all(|w| w[0].1 <= w[1].1),
                "cumulative counts must be monotone: {buckets:?}");
        assert_eq!(buckets.last().unwrap().1, 6);
        assert_eq!(a.sum_us(), 5 + 80 + 3000 + 1 + 80 + 1_000_000);
    }

    #[test]
    fn lock_recovering_survives_poison_and_counts() {
        let m = std::sync::Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let before = lock_poison_recoveries();
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock on purpose");
        })
        .join();
        assert!(m.is_poisoned());
        *lock_recovering(&m) += 1;
        assert_eq!(*lock_recovering(&m), 8);
        assert!(lock_poison_recoveries() >= before + 1);
    }

    #[test]
    fn loss_curve_divergence() {
        let mut c = LossCurve::default();
        c.push(1, 2.0);
        c.push(2, f32::NAN);
        assert!(!c.is_finite());
        assert_eq!(c.first_divergence(), Some(2));
    }
}
