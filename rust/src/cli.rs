//! Tiny argument parser (clap replacement for the offline build): GNU-ish
//! `--flag value` / `--switch` parsing with typed getters and an auto
//! usage string. Subcommand = first non-flag argument.

use std::collections::BTreeMap;

use crate::Result;
use anyhow::{anyhow, bail};

/// Parsed command line: subcommand, flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

/// Flags that take a value (everything else `--x` is a boolean switch).
pub fn parse_with(valued: &[&str], raw: impl Iterator<Item = String>)
                  -> Result<Args> {
    let mut args = Args::default();
    let raw: Vec<String> = raw.collect();
    let mut i = 0;
    while i < raw.len() {
        let a = &raw[i];
        if let Some(name) = a.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                args.flags.insert(k.to_string(), v.to_string());
            } else if valued.contains(&name) {
                let v = raw.get(i + 1)
                    .ok_or_else(|| anyhow!("--{name} needs a value"))?;
                args.flags.insert(name.to_string(), v.clone());
                i += 1;
            } else {
                args.switches.push(name.to_string());
            }
        } else if args.command.is_none() && args.positional.is_empty() {
            args.command = Some(a.clone());
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Parse std::env::args (skipping argv[0]).
pub fn parse(valued: &[&str]) -> Result<Args> {
    parse_with(valued, std::env::args().skip(1))
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T)
                                          -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>()
                .map_err(|_| anyhow!("--{name}: cannot parse '{v}'")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required flag --{name}"))
    }

    /// Error on any switch or valued flag outside the given lists — so a
    /// typoed `--chekc` fails loudly instead of silently running the
    /// default behaviour (every bench validates its args through this,
    /// via [`crate::bench::bench_args`]).
    pub fn expect_no_unknown(&self, switches: &[&str], valued: &[&str])
                             -> Result<()> {
        for s in &self.switches {
            if !switches.contains(&s.as_str()) {
                bail!("unknown flag --{s} (known switches: {switches:?}, \
                       valued flags: {valued:?})");
            }
        }
        for k in self.flags.keys() {
            if !valued.contains(&k.as_str()) {
                bail!("--{k} does not take a value here (valued flags: \
                       {valued:?})");
            }
        }
        Ok(())
    }

    /// Error on unknown command (help text for the caller to print).
    pub fn expect_command(&self, known: &[&str]) -> Result<&str> {
        match &self.command {
            Some(c) if known.contains(&c.as_str()) => Ok(c),
            Some(c) => bail!("unknown command '{c}'; known: {known:?}"),
            None => bail!("missing command; known: {known:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_vec(valued: &[&str], v: &[&str]) -> Args {
        parse_with(valued, v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse_vec(&["steps", "config"],
                          &["train", "--steps", "100", "--fused",
                            "--config", "vit_b_avg_cat"]);
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("config"), Some("vit_b_avg_cat"));
        assert!(a.has("fused"));
        assert!(!a.has("nope"));
    }

    #[test]
    fn equals_form() {
        let a = parse_vec(&[], &["run", "--steps=42"]);
        assert_eq!(a.parse_or("steps", 0u64).unwrap(), 42);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse_with(&["x"], ["--x"].iter().map(|s| s.to_string()))
            .is_err());
    }

    #[test]
    fn typed_defaults() {
        let a = parse_vec(&[], &["cmd"]);
        assert_eq!(a.parse_or("steps", 7u64).unwrap(), 7);
        assert!(a.require("config").is_err());
        assert_eq!(a.get_or("mode", "fast"), "fast");
    }

    #[test]
    fn expect_command_validates() {
        let a = parse_vec(&[], &["list"]);
        assert_eq!(a.expect_command(&["list", "train"]).unwrap(), "list");
        assert!(a.expect_command(&["train"]).is_err());
    }

    #[test]
    fn unknown_flags_are_rejected() {
        let a = parse_vec(&["steps"], &["--smoke", "--steps", "5"]);
        assert!(a.expect_no_unknown(&["smoke"], &["steps"]).is_ok());
        // the classic typo: --chekc must error, not silently no-op
        let b = parse_vec(&["steps"], &["--smoke", "--chekc"]);
        assert!(b.expect_no_unknown(&["smoke"], &["steps"]).is_err());
        // a switch given a value through = form is rejected too
        let c = parse_vec(&[], &["--smoke=1"]);
        assert!(c.expect_no_unknown(&["smoke"], &[]).is_err());
    }
}
