//! Minimal JSON substrate: parser + writer for `manifest.json` and the
//! experiment-row dumps.
//!
//! Written from scratch because this build is fully offline/vendored (no
//! serde in the vendor snapshot). Supports the complete JSON grammar we
//! produce and consume: objects, arrays, strings (with \uXXXX escapes),
//! f64 numbers, bools, null. Object key order is preserved (the manifest's
//! parameter ordering is the rust<->python contract).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::Result;
use anyhow::{anyhow, bail};

/// A JSON value. Objects keep insertion order via a Vec of pairs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v),
            _ => None,
        }
    }

    /// Like `get` but an error mentioning the key (manifest diagnostics).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing key '{key}' in JSON object"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Ok(v),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Object entries as a map (for key-order-insensitive lookups).
    pub fn to_map(&self) -> Result<BTreeMap<&str, &Json>> {
        Ok(self.as_obj()?
            .iter()
            .map(|(k, v)| (k.as_str(), v))
            .collect())
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

/// Nesting cap: recursion in `value()` is bounded so hostile inputs
/// (e.g. 100k `[`s) report an error instead of overflowing the stack.
/// Deep enough for every structure this crate produces by an order of
/// magnitude.
const MAX_DEPTH: usize = 128;

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current `value()` recursion depth (capped at [`MAX_DEPTH`]).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}, found {:?}",
                  b as char, self.pos, self.peek().map(|c| c as char))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("JSON nested deeper than {MAX_DEPTH} (byte {})", self.pos);
        }
        let v = match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}",
                           other.map(|c| c as char), self.pos),
        };
        self.depth -= 1;
        v
    }

    fn keyword(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("bad keyword at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => bail!("expected ',' or '}}' at byte {}, got {:?}",
                               self.pos, other.map(|c| c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']' at byte {}, got {:?}",
                               self.pos, other.map(|c| c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?, 16)?;
                            out.push(char::from_u32(code)
                                .unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}",
                                       other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])?;
                    let c = s.chars().next().expect("nonempty");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-') {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n = text.parse::<f64>()
            .map_err(|e| anyhow!("bad number '{text}': {e}"))?;
        // `"1e999".parse::<f64>()` succeeds with ±inf; JSON has no
        // non-finite literals and the writer could not round-trip one
        if !n.is_finite() {
            bail!("number '{text}' overflows f64");
        }
        Ok(Json::Num(n))
    }
}

// convenience constructors
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req("a").unwrap().as_arr().unwrap()[1]
                       .req("b").unwrap().as_str().unwrap(), "x");
        assert!(!v.req("c").unwrap().as_bool().unwrap());
    }

    #[test]
    fn roundtrip_preserves_structure() {
        let src = r#"{"z":1,"a":[true,null,"s\"q"],"n":-0.125}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        // pretty output also round-trips
        let v3 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn object_key_order_preserved() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap()
            .iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""Aé""#).unwrap(),
                   Json::Str("Aé".into()));
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        // comfortably inside the cap
        let ok = format!("{}0{}", "[".repeat(100), "]".repeat(100));
        assert!(parse(&ok).is_ok());
        // hostile depth: typed error, not a stack overflow
        let deep = format!("{}0{}", "[".repeat(100_000),
                           "]".repeat(100_000));
        let err = parse(&deep).unwrap_err();
        assert!(format!("{err}").contains("nested deeper"));
        // objects recurse through the same guard
        let objs = "{\"k\":".repeat(100_000);
        assert!(parse(&objs).is_err());
    }

    #[test]
    fn non_finite_numbers_rejected() {
        assert!(parse("1e999").is_err());
        assert!(parse("-1e999").is_err());
        // large but finite still parses
        assert_eq!(parse("1e308").unwrap(), Json::Num(1e308));
    }

    #[test]
    fn as_usize_validates() {
        assert_eq!(parse("7").unwrap().as_usize().unwrap(), 7);
        assert!(parse("-1").unwrap().as_usize().is_err());
        assert!(parse("1.5").unwrap().as_usize().is_err());
    }
}
