//! Fault injection for the serving stack (DESIGN.md §11).
//!
//! A [`FaultInjector`] wraps any [`BatchExecutor`] and misbehaves on
//! command — *after* the request has been accepted, mid-stream, which
//! is exactly where production failures live and where unit tests of
//! the parser or router can't reach:
//!
//! * **delay** — every batch sleeps first (slow replica / long batch:
//!   drives deadline-504 and overflow-429 paths deterministically);
//! * **poison** — the next N batches return an executor error (clients
//!   see `Failed` → HTTP 502; the replica survives);
//! * **kill** — the next batch panics the worker thread (the replica
//!   dies mid-request: in-flight clients get a typed 502, the router
//!   marks the replica dead, `/healthz` degrades, and — when
//!   supervision is on — the supervisor respawns it). Also available
//!   periodically ([`FaultPlan::kill_every`]) and as a seeded random
//!   rate ([`FaultPlan::kill_rate`]) for chaos soaks;
//! * **panic_next** — like kill but with a distinct one-shot payload,
//!   for asserting the `catch_unwind` capture path specifically.
//!
//! The seam composes with PR 5's `spawn_with`: [`injected_factory`]
//! decorates any inner [`ExecutorFactory`] (including the production
//! one, [`crate::coordinator::default_factory`]), so the full router +
//! batcher + executor stack runs under fault — nothing is mocked.
//! `cat serve --fault-delay-ms` exposes the delay knob so the CI HTTP
//! smoke can hold workers busy long enough to overflow queues.
//!
//! A [`FaultPlan`] is a cheap clone sharing one atomic control block;
//! tests hold one side and flip faults while the server runs.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{BatchExecutor, ExecutorFactory, ServeOptions,
                         WorkerSpec};
use crate::tensor::HostTensor;
use crate::Result;

#[derive(Debug, Default)]
struct FaultState {
    /// Sleep this long before every batch (0 = off).
    delay_us: AtomicU64,
    /// Fail this many upcoming batches with an executor error.
    poison_next: AtomicUsize,
    /// Panic the worker on its next batch (one-shot).
    kill_next: AtomicBool,
    /// Panic the worker on its next batch with a distinct payload
    /// (one-shot); exercises the `catch_unwind` capture path.
    panic_next: AtomicBool,
    /// Panic the worker on every `n`-th batch (0 = off).
    kill_every: AtomicUsize,
    /// Batches seen since `kill_every` was armed.
    batch_counter: AtomicUsize,
    /// Per-batch kill probability as `f64` bits (0 = off).
    kill_rate_bits: AtomicU64,
    /// splitmix64 state for the seeded kill-rate draws.
    rng_state: AtomicU64,
}

/// One splitmix64 step over a shared atomic state; returns a uniform
/// draw in `[0, 1)`. Good enough for chaos scheduling and fully
/// reproducible from the seed.
fn splitmix_unit(state: &AtomicU64) -> f64 {
    let mut z = state
        .fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed)
        .wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

/// Shared remote control over every executor built from one
/// [`injected_factory`]. Clones address the same faults.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    state: Arc<FaultState>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Delay every subsequent batch by `d` (replica-is-slow fault).
    pub fn set_delay(&self, d: Duration) {
        self.state.delay_us.store(d.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn clear_delay(&self) {
        self.state.delay_us.store(0, Ordering::Relaxed);
    }

    /// Fail the next `n` batches with an executor error (502 path).
    pub fn poison_next(&self, n: usize) {
        self.state.poison_next.store(n, Ordering::Relaxed);
    }

    /// Panic the executing worker on its next batch (dead-replica path).
    pub fn kill_next(&self) {
        self.state.kill_next.store(true, Ordering::Relaxed);
    }

    /// Panic the worker on its next batch with a payload distinct from
    /// [`FaultPlan::kill_next`], so tests can assert which capture path
    /// (the worker's `catch_unwind`) surfaced the message.
    pub fn panic_next(&self) {
        self.state.panic_next.store(true, Ordering::Relaxed);
    }

    /// Panic the worker on every `n`-th batch from now on (`n = 0`
    /// disarms). The period counts batches across all executors sharing
    /// this plan.
    pub fn kill_every(&self, n: usize) {
        self.state.batch_counter.store(0, Ordering::Relaxed);
        self.state.kill_every.store(n, Ordering::Relaxed);
    }

    /// Kill each batch independently with probability `rate` (clamped
    /// to `[0, 1]`; `0.0` disarms), drawn from a splitmix64 stream
    /// seeded with `seed` — the chaos schedule is reproducible.
    pub fn kill_rate(&self, rate: f64, seed: u64) {
        self.state.rng_state.store(seed, Ordering::Relaxed);
        let clamped = rate.clamp(0.0, 1.0);
        self.state
            .kill_rate_bits
            .store(clamped.to_bits(), Ordering::Relaxed);
    }
}

/// A [`BatchExecutor`] decorator that applies the faults armed in its
/// [`FaultPlan`] before delegating to the real executor.
pub struct FaultInjector {
    inner: Box<dyn BatchExecutor>,
    plan: FaultPlan,
}

impl FaultInjector {
    pub fn new(inner: Box<dyn BatchExecutor>, plan: FaultPlan)
               -> FaultInjector {
        FaultInjector { inner, plan }
    }
}

impl BatchExecutor for FaultInjector {
    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn infer_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let s = &self.plan.state;
        if s.kill_next.swap(false, Ordering::Relaxed) {
            // the worker thread dies exactly like a real executor crash:
            // in-flight requests get typed failures, the queue
            // disconnects, the router marks the replica dead
            panic!("fault injection: replica killed mid-request");
        }
        if s.panic_next.swap(false, Ordering::Relaxed) {
            panic!("fault injection: worker panic");
        }
        let every = s.kill_every.load(Ordering::Relaxed);
        if every > 0 {
            let seen = s.batch_counter.fetch_add(1, Ordering::Relaxed) + 1;
            if seen % every == 0 {
                panic!("fault injection: periodic kill (batch {seen})");
            }
        }
        let rate = f64::from_bits(s.kill_rate_bits.load(Ordering::Relaxed));
        if rate > 0.0 && splitmix_unit(&s.rng_state) < rate {
            panic!("fault injection: random kill (rate {rate})");
        }
        let delay = s.delay_us.load(Ordering::Relaxed);
        if delay > 0 {
            std::thread::sleep(Duration::from_micros(delay));
        }
        let poisoned = s.poison_next
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed,
                          |n| n.checked_sub(1))
            .is_ok();
        if poisoned {
            anyhow::bail!("fault injection: poisoned batch");
        }
        self.inner.infer_batch(inputs)
    }

    fn shard_stats(&self) -> Option<crate::coordinator::ShardStatsSnapshot> {
        self.inner.shard_stats()
    }
}

/// Wrap `inner` so every executor it builds obeys `plan`. The returned
/// factory plugs into `Server::spawn_with` unchanged.
pub fn injected_factory(plan: &FaultPlan, inner: ExecutorFactory)
                        -> ExecutorFactory {
    let plan = plan.clone();
    Arc::new(move |spec: &WorkerSpec, opts: &ServeOptions| {
        let exec = inner(spec, opts)?;
        Ok(Box::new(FaultInjector::new(exec, plan.clone()))
            as Box<dyn BatchExecutor>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    struct Echo;

    impl BatchExecutor for Echo {
        fn max_batch(&self) -> usize {
            4
        }

        fn infer_batch(&self, inputs: &[&HostTensor])
                       -> Result<Vec<HostTensor>> {
            Ok(inputs.iter().map(|t| (*t).clone()).collect())
        }
    }

    fn injector() -> (FaultInjector, FaultPlan) {
        let plan = FaultPlan::new();
        (FaultInjector::new(Box::new(Echo), plan.clone()), plan)
    }

    #[test]
    fn passes_through_when_unarmed() {
        let (inj, _plan) = injector();
        let t = HostTensor::scalar_f32(1.5);
        let rows = inj.infer_batch(&[&t]).unwrap();
        assert_eq!(rows[0], t);
        assert_eq!(inj.max_batch(), 4);
    }

    #[test]
    fn delay_applies_and_clears() {
        let (inj, plan) = injector();
        plan.set_delay(Duration::from_millis(30));
        let t = HostTensor::scalar_f32(0.0);
        let start = Instant::now();
        inj.infer_batch(&[&t]).unwrap();
        assert!(start.elapsed() >= Duration::from_millis(30));
        plan.clear_delay();
        let start = Instant::now();
        inj.infer_batch(&[&t]).unwrap();
        assert!(start.elapsed() < Duration::from_millis(30));
    }

    #[test]
    fn poison_fails_exactly_n_batches() {
        let (inj, plan) = injector();
        plan.poison_next(2);
        let t = HostTensor::scalar_f32(0.0);
        assert!(inj.infer_batch(&[&t]).is_err());
        assert!(inj.infer_batch(&[&t]).is_err());
        assert!(inj.infer_batch(&[&t]).is_ok());
    }

    #[test]
    fn kill_panics_once() {
        let (inj, plan) = injector();
        plan.kill_next();
        let t = HostTensor::scalar_f32(0.0);
        let died = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let _ = inj.infer_batch(&[&t]);
            }))
            .is_err();
        assert!(died, "armed kill must panic the executing thread");
        // one-shot: the kill disarms itself, the next batch runs
        assert!(inj.infer_batch(&[&t]).is_ok());
    }

    /// `true` iff one `infer_batch` call on `inj` panics.
    fn batch_dies(inj: &FaultInjector, t: &HostTensor) -> bool {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = inj.infer_batch(&[t]);
        }))
        .is_err()
    }

    #[test]
    fn panic_next_is_one_shot_with_distinct_payload() {
        let (inj, plan) = injector();
        plan.panic_next();
        let t = HostTensor::scalar_f32(0.0);
        let payload = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let _ = inj.infer_batch(&[&t]);
            }))
            .expect_err("armed panic_next must panic");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "fault injection: worker panic");
        assert!(inj.infer_batch(&[&t]).is_ok(), "one-shot: disarms");
    }

    #[test]
    fn kill_every_panics_periodically() {
        let (inj, plan) = injector();
        plan.kill_every(3);
        let t = HostTensor::scalar_f32(0.0);
        let deaths: Vec<bool> =
            (0..9).map(|_| batch_dies(&inj, &t)).collect();
        assert_eq!(deaths, [false, false, true,
                            false, false, true,
                            false, false, true]);
        plan.kill_every(0);
        assert!(!batch_dies(&inj, &t), "kill_every(0) disarms");
    }

    #[test]
    fn kill_rate_extremes_always_and_never() {
        let (inj, plan) = injector();
        let t = HostTensor::scalar_f32(0.0);
        plan.kill_rate(1.0, 42);
        for _ in 0..5 {
            assert!(batch_dies(&inj, &t), "rate 1.0 kills every batch");
        }
        plan.kill_rate(0.0, 42);
        for _ in 0..5 {
            assert!(!batch_dies(&inj, &t), "rate 0.0 never kills");
        }
    }

    #[test]
    fn kill_rate_schedule_is_seed_reproducible() {
        let t = HostTensor::scalar_f32(0.0);
        let run = |seed: u64| -> Vec<bool> {
            let (inj, plan) = injector();
            plan.kill_rate(0.5, seed);
            (0..32).map(|_| batch_dies(&inj, &t)).collect()
        };
        assert_eq!(run(7), run(7), "same seed, same chaos schedule");
        let a = run(7);
        assert!(a.iter().any(|d| *d) && a.iter().any(|d| !*d),
                "rate 0.5 should mix kills and survivals over 32 draws");
    }

    #[test]
    fn factory_wraps_inner_executors() {
        let plan = FaultPlan::new();
        let inner: ExecutorFactory = Arc::new(|_s: &WorkerSpec,
                                               _o: &ServeOptions| {
            Ok(Box::new(Echo) as Box<dyn BatchExecutor>)
        });
        let factory = injected_factory(&plan, inner);
        let spec = WorkerSpec { model: "m".into(), params: None, seed: 0 };
        let exec = factory(&spec, &ServeOptions::default()).unwrap();
        plan.poison_next(1);
        let t = HostTensor::scalar_f32(0.0);
        assert!(exec.infer_batch(&[&t]).is_err());
        assert!(exec.infer_batch(&[&t]).is_ok());
    }
}
