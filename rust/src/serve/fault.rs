//! Fault injection for the serving stack (DESIGN.md §11).
//!
//! A [`FaultInjector`] wraps any [`BatchExecutor`] and misbehaves on
//! command — *after* the request has been accepted, mid-stream, which
//! is exactly where production failures live and where unit tests of
//! the parser or router can't reach:
//!
//! * **delay** — every batch sleeps first (slow replica / long batch:
//!   drives deadline-504 and overflow-429 paths deterministically);
//! * **poison** — the next N batches return an executor error (clients
//!   see `Failed` → HTTP 502; the replica survives);
//! * **kill** — the next batch panics the worker thread (the replica
//!   dies mid-request: in-flight clients see "worker dropped request",
//!   the router marks the replica dead, `/healthz` degrades).
//!
//! The seam composes with PR 5's `spawn_with`: [`injected_factory`]
//! decorates any inner [`ExecutorFactory`] (including the production
//! one, [`crate::coordinator::default_factory`]), so the full router +
//! batcher + executor stack runs under fault — nothing is mocked.
//! `cat serve --fault-delay-ms` exposes the delay knob so the CI HTTP
//! smoke can hold workers busy long enough to overflow queues.
//!
//! A [`FaultPlan`] is a cheap clone sharing one atomic control block;
//! tests hold one side and flip faults while the server runs.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{BatchExecutor, ExecutorFactory, ServeOptions,
                         WorkerSpec};
use crate::tensor::HostTensor;
use crate::Result;

#[derive(Debug, Default)]
struct FaultState {
    /// Sleep this long before every batch (0 = off).
    delay_us: AtomicU64,
    /// Fail this many upcoming batches with an executor error.
    poison_next: AtomicUsize,
    /// Panic the worker on its next batch (one-shot).
    kill_next: AtomicBool,
}

/// Shared remote control over every executor built from one
/// [`injected_factory`]. Clones address the same faults.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    state: Arc<FaultState>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Delay every subsequent batch by `d` (replica-is-slow fault).
    pub fn set_delay(&self, d: Duration) {
        self.state.delay_us.store(d.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn clear_delay(&self) {
        self.state.delay_us.store(0, Ordering::Relaxed);
    }

    /// Fail the next `n` batches with an executor error (502 path).
    pub fn poison_next(&self, n: usize) {
        self.state.poison_next.store(n, Ordering::Relaxed);
    }

    /// Panic the executing worker on its next batch (dead-replica path).
    pub fn kill_next(&self) {
        self.state.kill_next.store(true, Ordering::Relaxed);
    }
}

/// A [`BatchExecutor`] decorator that applies the faults armed in its
/// [`FaultPlan`] before delegating to the real executor.
pub struct FaultInjector {
    inner: Box<dyn BatchExecutor>,
    plan: FaultPlan,
}

impl FaultInjector {
    pub fn new(inner: Box<dyn BatchExecutor>, plan: FaultPlan)
               -> FaultInjector {
        FaultInjector { inner, plan }
    }
}

impl BatchExecutor for FaultInjector {
    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn infer_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let s = &self.plan.state;
        if s.kill_next.swap(false, Ordering::Relaxed) {
            // the worker thread dies exactly like a real executor crash:
            // in-flight requests are dropped, the queue disconnects, the
            // router marks the replica dead
            panic!("fault injection: replica killed mid-request");
        }
        let delay = s.delay_us.load(Ordering::Relaxed);
        if delay > 0 {
            std::thread::sleep(Duration::from_micros(delay));
        }
        let poisoned = s.poison_next
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed,
                          |n| n.checked_sub(1))
            .is_ok();
        if poisoned {
            anyhow::bail!("fault injection: poisoned batch");
        }
        self.inner.infer_batch(inputs)
    }

    fn shard_stats(&self) -> Option<crate::coordinator::ShardStatsSnapshot> {
        self.inner.shard_stats()
    }
}

/// Wrap `inner` so every executor it builds obeys `plan`. The returned
/// factory plugs into `Server::spawn_with` unchanged.
pub fn injected_factory(plan: &FaultPlan, inner: ExecutorFactory)
                        -> ExecutorFactory {
    let plan = plan.clone();
    Arc::new(move |spec: &WorkerSpec, opts: &ServeOptions| {
        let exec = inner(spec, opts)?;
        Ok(Box::new(FaultInjector::new(exec, plan.clone()))
            as Box<dyn BatchExecutor>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    struct Echo;

    impl BatchExecutor for Echo {
        fn max_batch(&self) -> usize {
            4
        }

        fn infer_batch(&self, inputs: &[&HostTensor])
                       -> Result<Vec<HostTensor>> {
            Ok(inputs.iter().map(|t| (*t).clone()).collect())
        }
    }

    fn injector() -> (FaultInjector, FaultPlan) {
        let plan = FaultPlan::new();
        (FaultInjector::new(Box::new(Echo), plan.clone()), plan)
    }

    #[test]
    fn passes_through_when_unarmed() {
        let (inj, _plan) = injector();
        let t = HostTensor::scalar_f32(1.5);
        let rows = inj.infer_batch(&[&t]).unwrap();
        assert_eq!(rows[0], t);
        assert_eq!(inj.max_batch(), 4);
    }

    #[test]
    fn delay_applies_and_clears() {
        let (inj, plan) = injector();
        plan.set_delay(Duration::from_millis(30));
        let t = HostTensor::scalar_f32(0.0);
        let start = Instant::now();
        inj.infer_batch(&[&t]).unwrap();
        assert!(start.elapsed() >= Duration::from_millis(30));
        plan.clear_delay();
        let start = Instant::now();
        inj.infer_batch(&[&t]).unwrap();
        assert!(start.elapsed() < Duration::from_millis(30));
    }

    #[test]
    fn poison_fails_exactly_n_batches() {
        let (inj, plan) = injector();
        plan.poison_next(2);
        let t = HostTensor::scalar_f32(0.0);
        assert!(inj.infer_batch(&[&t]).is_err());
        assert!(inj.infer_batch(&[&t]).is_err());
        assert!(inj.infer_batch(&[&t]).is_ok());
    }

    #[test]
    fn kill_panics_once() {
        let (inj, plan) = injector();
        plan.kill_next();
        let t = HostTensor::scalar_f32(0.0);
        let died = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let _ = inj.infer_batch(&[&t]);
            }))
            .is_err();
        assert!(died, "armed kill must panic the executing thread");
        // one-shot: the kill disarms itself, the next batch runs
        assert!(inj.infer_batch(&[&t]).is_ok());
    }

    #[test]
    fn factory_wraps_inner_executors() {
        let plan = FaultPlan::new();
        let inner: ExecutorFactory = Arc::new(|_s: &WorkerSpec,
                                               _o: &ServeOptions| {
            Ok(Box::new(Echo) as Box<dyn BatchExecutor>)
        });
        let factory = injected_factory(&plan, inner);
        let spec = WorkerSpec { model: "m".into(), params: None, seed: 0 };
        let exec = factory(&spec, &ServeOptions::default()).unwrap();
        plan.poison_next(1);
        let t = HostTensor::scalar_f32(0.0);
        assert!(exec.infer_batch(&[&t]).is_err());
        assert!(exec.infer_batch(&[&t]).is_ok());
    }
}
