//! Prometheus text exposition (version 0.0.4) for `GET /metrics`.
//!
//! Renders the router counters, per-replica live state, and the merged
//! request-latency histogram from [`StatsHandle`], plus the HTTP
//! layer's own counters. Histograms follow the Prometheus contract:
//! cumulative `_bucket{le=...}` series in ascending bound order ending
//! with `le="+Inf"` equal to `_count` (the stable cumulative iterator
//! is `LatencyHistogram::cumulative_buckets`, pinned by regression
//! tests in `crate::metrics`).

use std::fmt::Write as _;

use crate::coordinator::{ReplicaPhase, StatsHandle};
use crate::metrics::{lock_poison_recoveries, LatencyHistogram};
use crate::native::arena::arena_high_water_bytes;
use crate::native::pool;
use crate::obs::trace::stage_snapshots;

use super::HttpSnapshot;

/// Escape a label value per the exposition format.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Reusable `/metrics` render state: the output buffer and the merged
/// latency histogram keep their capacity across scrapes, so a warm
/// scrape loop does not grow the heap (pinned by the zero-heap-growth
/// regression test in `tests/http_serving.rs`). One per connection,
/// owned by `routes::ConnScratch`.
#[derive(Default)]
pub struct RenderScratch {
    buf: String,
    merged: LatencyHistogram,
}

impl RenderScratch {
    pub fn new() -> RenderScratch {
        RenderScratch::default()
    }

    /// The last rendered payload.
    pub fn buf(&self) -> &str {
        &self.buf
    }
}

/// Render the full `/metrics` payload into fresh buffers. Prefer
/// [`render_into`] on a hot path — this wrapper allocates per call.
pub fn render(stats: &StatsHandle, http: &HttpSnapshot) -> String {
    let mut scratch = RenderScratch::new();
    render_into(&mut scratch, stats, http);
    scratch.buf
}

/// Render the full `/metrics` payload, reusing `scratch`'s buffers.
pub fn render_into(scratch: &mut RenderScratch, stats: &StatsHandle,
                   http: &HttpSnapshot) {
    let RenderScratch { buf: out, merged } = scratch;
    out.clear();
    if out.capacity() < 4096 {
        out.reserve(4096 - out.capacity());
    }
    let router = stats.router();

    let counter = |out: &mut String, name: &str, help: &str, v: u64| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
    };

    counter(out, "cat_router_dispatched_total",
            "Requests handed to a replica queue.", router.dispatched);
    counter(out, "cat_router_busy_rejected_total",
            "Requests rejected with backpressure (HTTP 429).",
            router.busy_rejected);
    counter(out, "cat_router_replicas_died_total",
            "Replicas discovered dead.", router.replicas_died);
    counter(out, "cat_router_pings_ok_total",
            "Health pings answered in time.", router.pings_ok);
    counter(out, "cat_router_pings_missed_total",
            "Health pings that timed out.", router.pings_missed);
    counter(out, "cat_replica_restarts_total",
            "Replica workers respawned by the supervisor.",
            router.replicas_restarted);
    counter(out, "cat_lock_poison_recoveries_total",
            "Poisoned mutexes recovered instead of cascading panics.",
            lock_poison_recoveries());

    counter(out, "cat_http_connections_accepted_total",
            "TCP connections accepted.", http.accepted);
    counter(out, "cat_http_connections_shed_total",
            "Connections shed at the accept-side limit (HTTP 503).",
            http.shed);
    counter(out, "cat_http_requests_total",
            "HTTP requests parsed off accepted connections.",
            http.requests);
    counter(out, "cat_http_responses_2xx_total",
            "Successful HTTP responses.", http.status_2xx);
    counter(out, "cat_http_responses_4xx_total",
            "Client-error HTTP responses.", http.status_4xx);
    counter(out, "cat_http_responses_5xx_total",
            "Server-error HTTP responses.", http.status_5xx);

    let replicas = stats.replicas();

    let _ = writeln!(out, "# HELP cat_replica_up Replica liveness \
                           (0 = worker dead).");
    let _ = writeln!(out, "# TYPE cat_replica_up gauge");
    for r in &replicas {
        let _ = writeln!(out,
                         "cat_replica_up{{model=\"{}\",replica=\"{}\"}} {}",
                         escape_label(&r.model), r.replica,
                         u8::from(r.alive));
    }

    let _ = writeln!(out, "# HELP cat_replica_state Replica supervision \
                           phase (one series per phase, 1 = current).");
    let _ = writeln!(out, "# TYPE cat_replica_state gauge");
    for r in &replicas {
        for phase in ReplicaPhase::all() {
            let _ = writeln!(
                out,
                "cat_replica_state{{model=\"{}\",replica=\"{}\",\
                 state=\"{}\"}} {}",
                escape_label(&r.model), r.replica, phase.as_str(),
                u8::from(r.phase == phase));
        }
    }

    let _ = writeln!(out, "# HELP cat_replica_outstanding Dispatched \
                           requests not yet completed.");
    let _ = writeln!(out, "# TYPE cat_replica_outstanding gauge");
    for r in &replicas {
        let _ = writeln!(
            out,
            "cat_replica_outstanding{{model=\"{}\",replica=\"{}\"}} {}",
            escape_label(&r.model), r.replica, r.outstanding);
    }

    let _ = writeln!(out, "# HELP cat_replica_requests_total Requests \
                           completed by this replica.");
    let _ = writeln!(out, "# TYPE cat_replica_requests_total counter");
    for r in &replicas {
        let _ = writeln!(
            out,
            "cat_replica_requests_total{{model=\"{}\",replica=\"{}\"}} {}",
            escape_label(&r.model), r.replica, r.requests);
    }

    let _ = writeln!(out, "# HELP cat_replica_batches_total Batches \
                           executed by this replica.");
    let _ = writeln!(out, "# TYPE cat_replica_batches_total counter");
    for r in &replicas {
        let _ = writeln!(
            out,
            "cat_replica_batches_total{{model=\"{}\",replica=\"{}\"}} {}",
            escape_label(&r.model), r.replica, r.batches);
    }

    // one merged latency histogram across all replicas: queue-to-reply
    // time per request, in microseconds (merged in the reusable scratch
    // histogram — no per-scrape rebuild)
    merged.reset();
    for r in &replicas {
        merged.merge(&r.latency);
    }
    let name = "cat_request_latency_us";
    let _ = writeln!(out, "# HELP {name} Request latency (enqueue to \
                           reply) in microseconds.");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (bound, cum) in merged.cumulative_buckets() {
        let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}",
                     merged.count());
    let _ = writeln!(out, "{name}_sum {}", merged.sum_us());
    let _ = writeln!(out, "{name}_count {}", merged.count());

    // time-to-recovery: supervisor-observed death → dispatch readmission
    let recovery = stats.recovery_latency();
    let name = "cat_recovery_time_us";
    let _ = writeln!(out, "# HELP {name} Replica time-to-recovery \
                           (death observed to dispatch readmission) in \
                           microseconds.");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (bound, cum) in recovery.cumulative_buckets() {
        let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}",
                     recovery.count());
    let _ = writeln!(out, "{name}_sum {}", recovery.sum_us());
    let _ = writeln!(out, "{name}_count {}", recovery.count());

    // per-stage latency attribution (DESIGN.md §13): one histogram
    // family, one series set per pipeline stage. Families render even
    // while empty so dashboards can pin all eight stages from boot.
    let name = "cat_stage_duration_us";
    let _ = writeln!(out, "# HELP {name} Time spent per request \
                           pipeline stage in microseconds.");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (stage, snap) in stage_snapshots() {
        let label = stage.as_str();
        for (bound, cum) in snap.cumulative_buckets() {
            let _ = writeln!(
                out,
                "{name}_bucket{{stage=\"{label}\",le=\"{bound}\"}} {cum}");
        }
        let _ = writeln!(out,
                         "{name}_bucket{{stage=\"{label}\",le=\"+Inf\"}} {}",
                         snap.count);
        let _ = writeln!(out, "{name}_sum{{stage=\"{label}\"}} {}",
                         snap.sum_us);
        let _ = writeln!(out, "{name}_count{{stage=\"{label}\"}} {}",
                         snap.count);
    }

    // compute-pool and arena health: flat gauges at steady state, so a
    // moving value is itself the signal (thread churn / arena growth)
    let pstats = pool::stats();
    let gauge = |out: &mut String, name: &str, help: &str, v: u64| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {v}");
    };
    gauge(out, "cat_pool_workers",
          "Worker threads in the global compute pool.",
          pstats.workers as u64);
    gauge(out, "cat_pool_threads_spawned",
          "OS threads ever spawned by the compute pools (global + \
           dedicated); flat once warm.",
          pstats.threads_spawned + pstats.dedicated_threads_spawned);
    gauge(out, "cat_arena_high_water_bytes",
          "Largest single bump-arena backing store ever reached, in \
           bytes.",
          arena_high_water_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_label_handles_specials() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape_label("plain"), "plain");
    }
}
