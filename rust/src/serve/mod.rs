//! Fault-tolerant HTTP/1.1 serving layer over the sharded router
//! (DESIGN.md §11): `cat serve --listen` binds this front end to a
//! [`crate::coordinator::Server`].
//!
//! Hermetic by construction — std `TcpListener` + the in-repo JSON, no
//! new dependencies — and hardened at every layer:
//!
//! * **Parser** ([`http`]): hard caps on request line / headers / body,
//!   typed 4xx for every malformed input, allocation never proportional
//!   to attacker-claimed sizes.
//! * **Deadlines**: every connection read runs under a [`DeadlineReader`]
//!   (slowloris → 408), every inference under
//!   `ServeHandle::infer_deadline` (expiry → 504) — an accept thread can
//!   not be wedged by a slow client or a slow replica.
//! * **Load shedding**: beyond `max_conns` concurrent connections the
//!   acceptor answers 503 inline and closes — queues never build behind
//!   the limit. Router backpressure surfaces as 429 + `Retry-After`;
//!   dead replicas as 502 while `/healthz` reports degradation (503).
//! * **Graceful shutdown**: the shutdown flag stops the acceptor,
//!   in-flight requests drain against `drain_timeout`, stragglers are
//!   unblocked by shutting their sockets down, every connection thread
//!   is joined — and only then does the caller tear down the router
//!   ([`HttpServer::shutdown`] guarantees no `ServeHandle` clone
//!   outlives it, which `Server::shutdown` requires).
//!
//! Fault injection ([`fault`]) wraps executors behind the same router,
//! so integration tests drive delays, poisoned batches, and mid-request
//! replica death through real sockets.

pub mod fault;
pub mod http;
pub mod prometheus;
pub mod routes;

use std::collections::HashMap;
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Context as _;

use crate::metrics::lock_recovering;
use crate::obs::log::{self as obs_log, Level};
use crate::obs::trace::{self as obs_trace, Stage};
use crate::Result;

use http::{error_response, read_request, HttpLimits, Response};
use routes::{AppState, ConnScratch};

/// HTTP-layer counters (accepts, sheds, responses by class), shared
/// between the acceptor, every connection thread, and `/metrics`.
#[derive(Debug, Clone, Default)]
pub struct HttpCounters {
    inner: Arc<HttpCountersInner>,
}

#[derive(Debug, Default)]
struct HttpCountersInner {
    accepted: AtomicU64,
    shed: AtomicU64,
    requests: AtomicU64,
    status_2xx: AtomicU64,
    status_4xx: AtomicU64,
    status_5xx: AtomicU64,
}

/// Point-in-time copy of [`HttpCounters`].
#[derive(Debug, Clone, Copy, Default)]
pub struct HttpSnapshot {
    pub accepted: u64,
    pub shed: u64,
    pub requests: u64,
    pub status_2xx: u64,
    pub status_4xx: u64,
    pub status_5xx: u64,
}

impl HttpCounters {
    pub fn new() -> HttpCounters {
        HttpCounters::default()
    }

    fn note_accepted(&self) {
        self.inner.accepted.fetch_add(1, Ordering::Relaxed);
    }

    fn note_shed(&self) {
        self.inner.shed.fetch_add(1, Ordering::Relaxed);
    }

    fn note_request(&self) {
        self.inner.requests.fetch_add(1, Ordering::Relaxed);
    }

    fn note_status(&self, status: u16) {
        let c = match status {
            200..=299 => &self.inner.status_2xx,
            400..=499 => &self.inner.status_4xx,
            _ => &self.inner.status_5xx,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HttpSnapshot {
        let i = &self.inner;
        HttpSnapshot {
            accepted: i.accepted.load(Ordering::Relaxed),
            shed: i.shed.load(Ordering::Relaxed),
            requests: i.requests.load(Ordering::Relaxed),
            status_2xx: i.status_2xx.load(Ordering::Relaxed),
            status_4xx: i.status_4xx.load(Ordering::Relaxed),
            status_5xx: i.status_5xx.load(Ordering::Relaxed),
        }
    }
}

/// Registry of live connection sockets (duplicated handles). On a
/// drain-deadline overrun, [`ConnRegistry::shutdown_all`] shuts every
/// socket down so blocked reads/writes in connection threads return
/// immediately — the join that follows is bounded, never wedged on a
/// client that stopped talking.
#[derive(Clone, Default)]
struct ConnRegistry {
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    next_id: Arc<AtomicU64>,
}

impl ConnRegistry {
    fn register(&self, stream: &TcpStream) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(dup) = stream.try_clone() {
            lock_recovering(&self.conns).insert(id, dup);
        }
        id
    }

    fn deregister(&self, id: u64) {
        lock_recovering(&self.conns).remove(&id);
    }

    fn shutdown_all(&self) {
        let conns = lock_recovering(&self.conns);
        for stream in conns.values() {
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// `Read` adapter enforcing an absolute deadline over a `TcpStream` by
/// reading in short `set_read_timeout` slices. Between slices it also
/// observes the server shutdown flag: a connection that has not started
/// a request yet (`started == false`) reports clean EOF so idle
/// keep-alive threads exit promptly during drain, while a mid-request
/// read keeps its full deadline (the in-flight request is drained, not
/// dropped). Deadline expiry surfaces as `TimedOut`, which the parser
/// maps to 408.
struct DeadlineReader<'a> {
    stream: &'a TcpStream,
    deadline: Instant,
    shutdown: &'a AtomicBool,
    /// When the first byte of the current request arrived — the
    /// request's trace anchor (`http_parse` starts here).
    first_byte: Option<Instant>,
}

/// Granularity of deadline/shutdown checks while blocked in `read`.
const READ_SLICE: Duration = Duration::from_millis(50);

impl<'a> DeadlineReader<'a> {
    fn new(stream: &'a TcpStream, deadline: Instant,
           shutdown: &'a AtomicBool) -> DeadlineReader<'a> {
        DeadlineReader { stream, deadline, shutdown, first_byte: None }
    }

    /// Has the current request started (any byte read)?
    fn started(&self) -> bool {
        self.first_byte.is_some()
    }
}

impl Read for DeadlineReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            if !self.started() && self.shutdown.load(Ordering::Relaxed) {
                return Ok(0); // draining: close idle connections cleanly
            }
            let left = self.deadline.saturating_duration_since(
                Instant::now());
            if left.is_zero() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut, "read deadline"));
            }
            // never pass zero: set_read_timeout(Some(0)) is an error
            let slice = left.min(READ_SLICE)
                .max(Duration::from_millis(1));
            self.stream.set_read_timeout(Some(slice))?;
            match self.stream.read(buf) {
                Ok(0) => return Ok(0),
                Ok(n) => {
                    if self.first_byte.is_none() {
                        self.first_byte = Some(Instant::now());
                    }
                    return Ok(n);
                }
                Err(e) if matches!(e.kind(),
                                   std::io::ErrorKind::TimedOut
                                   | std::io::ErrorKind::WouldBlock) => {
                    // slice expired: loop to re-check deadline/shutdown
                }
                Err(e) => return Err(e),
            }
        }
    }
}

/// Configuration of the HTTP front end (`cat serve --listen ...`).
#[derive(Debug, Clone)]
pub struct HttpServerConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (`:0` picks a free port).
    pub listen: String,
    /// Concurrent-connection cap; the acceptor sheds beyond it (503).
    pub max_conns: usize,
    pub limits: HttpLimits,
    /// Per-request deadline: bounds both the request read (408) and
    /// the inference wait (504).
    pub request_timeout: Duration,
    /// How long shutdown waits for in-flight connections to finish
    /// before forcing their sockets closed.
    pub drain_timeout: Duration,
}

impl HttpServerConfig {
    pub fn new(listen: impl Into<String>) -> HttpServerConfig {
        HttpServerConfig {
            listen: listen.into(),
            max_conns: 64,
            limits: HttpLimits::default(),
            request_timeout: Duration::from_secs(10),
            drain_timeout: Duration::from_secs(5),
        }
    }
}

/// The running HTTP front end: one nonblocking acceptor thread +
/// bounded per-connection threads.
pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    acceptor: std::thread::JoinHandle<()>,
}

impl HttpServer {
    /// Bind and start serving `state` at `cfg.listen`.
    pub fn start(cfg: HttpServerConfig, state: AppState)
                 -> Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("bind {}", cfg.listen))?;
        let addr = listener.local_addr().context("local_addr")?;
        listener.set_nonblocking(true).context("set_nonblocking")?;
        obs_log::log_fields(Level::Info, "http", "http front end up",
                            &[("addr", &addr.to_string()),
                              ("max_conns", &cfg.max_conns.to_string())]);
        let shutdown = Arc::new(AtomicBool::new(false));
        let acceptor = {
            let shutdown = shutdown.clone();
            std::thread::spawn(move || {
                accept_loop(listener, cfg, state, shutdown);
            })
        };
        Ok(HttpServer { addr, shutdown, acceptor })
    }

    /// The bound address (with the real port when `:0` was requested).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shutdown flag: setting it is equivalent to starting
    /// [`HttpServer::shutdown`] (the acceptor notices within one poll
    /// tick). Exposed so signal handlers can request shutdown from a
    /// context that can't call methods.
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Graceful shutdown: stop accepting, drain in-flight connections
    /// against the drain deadline, force-close stragglers, join every
    /// thread. On return no connection thread (and therefore no
    /// `ServeHandle` clone held by one) survives — safe to proceed to
    /// `Server::shutdown`.
    pub fn shutdown(self) {
        self.shutdown.store(true, Ordering::Relaxed);
        let _ = self.acceptor.join();
    }
}

/// Poll cadence of the nonblocking accept loop.
const ACCEPT_POLL: Duration = Duration::from_millis(2);

fn accept_loop(listener: TcpListener, cfg: HttpServerConfig,
               state: AppState, shutdown: Arc<AtomicBool>) {
    let active = Arc::new(AtomicUsize::new(0));
    let registry = ConnRegistry::default();
    let mut conn_threads: Vec<std::thread::JoinHandle<()>> = Vec::new();

    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                state.http.note_accepted();
                if active.load(Ordering::Relaxed) >= cfg.max_conns {
                    shed(&stream, &state);
                    continue;
                }
                // reap finished threads so the handle list stays small
                conn_threads.retain(|t| !t.is_finished());
                active.fetch_add(1, Ordering::Relaxed);
                let id = registry.register(&stream);
                let state = state.clone();
                let cfg = cfg.clone();
                let shutdown = shutdown.clone();
                let active = active.clone();
                let registry = registry.clone();
                conn_threads.push(std::thread::spawn(move || {
                    serve_connection(stream, &cfg, &state, &shutdown);
                    registry.deregister(id);
                    active.fetch_sub(1, Ordering::Relaxed);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => {
                // transient accept errors (e.g. aborted handshake)
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }

    // drain phase: no new connections; in-flight requests run to
    // completion (connection threads see the flag and close after
    // their current request) until the drain deadline
    let deadline = Instant::now() + cfg.drain_timeout;
    while active.load(Ordering::Relaxed) > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    // force any stragglers off their sockets, then the joins are bounded
    let stragglers = active.load(Ordering::Relaxed);
    registry.shutdown_all();
    for t in conn_threads {
        let _ = t.join();
    }
    obs_log::log_fields(Level::Debug, "http",
                        "drain complete; connection threads joined",
                        &[("forced_closed", &stragglers.to_string())]);
}

/// Answer 503 inline on the acceptor thread (bounded by a short write
/// timeout so a non-reading client can't stall accepts) and close.
fn shed(stream: &TcpStream, state: &AppState) {
    state.http.note_shed();
    let resp = Response::json(
        503, "{\"error\":\"connection limit reached\"}".to_string())
        .closing();
    let _ = stream.set_write_timeout(Some(Duration::from_millis(100)));
    let mut w = stream;
    let _ = resp.write_to(&mut w);
    state.http.note_status(503);
}

/// One connection: keep-alive request loop under per-request deadlines.
/// Every parsed request gets a trace (DESIGN.md §13): anchored at its
/// first byte, `http_parse` and `serialize` timed here, router/kernel
/// spans folded in by `routes::classify`, committed to the flight
/// recorder once the response hits the socket.
fn serve_connection(stream: TcpStream, cfg: &HttpServerConfig,
                    state: &AppState, shutdown: &AtomicBool) {
    let _ = stream.set_nodelay(true);
    // a response write may not block past the request budget either
    let _ = stream.set_write_timeout(Some(cfg.request_timeout
        .max(Duration::from_millis(100))));
    let mut scratch = ConnScratch::new();
    loop {
        let deadline = Instant::now() + cfg.request_timeout;
        let mut reader = DeadlineReader::new(&stream, deadline, shutdown);
        let outcome = read_request(&mut reader, &cfg.limits);
        // idle connections that never started a request time out
        // quietly (no 408 spam into an empty pipe)
        let idle_timeout = !reader.started()
            && matches!(outcome, Err(http::ParseError::Timeout));
        match outcome {
            Ok(None) => break, // client closed between requests
            _ if idle_timeout => break,
            Ok(Some(req)) => {
                state.http.note_request();
                let parse_end = Instant::now();
                let start = reader.first_byte.unwrap_or(parse_end);
                scratch.trace.begin(req.header("x-request-id"), start);
                scratch.trace.span(Stage::HttpParse, start, parse_end);
                obs_trace::record_stage_us(
                    Stage::HttpParse,
                    parse_end.saturating_duration_since(start)
                        .as_micros() as u64);
                let mut resp =
                    routes::handle_request(state, &req, &mut scratch);
                // drain: finish this response, then close
                resp.close = resp.close
                    || req.wants_close()
                    || shutdown.load(Ordering::Relaxed);
                resp = resp.with_header("X-Request-Id",
                                        scratch.trace.id().to_string());
                state.http.note_status(resp.status);
                let ser_start = Instant::now();
                let mut w = &stream;
                let write_ok = resp.write_to(&mut w).is_ok();
                let ser_end = Instant::now();
                scratch.trace.span(Stage::Serialize, ser_start, ser_end);
                obs_trace::record_stage_us(
                    Stage::Serialize,
                    ser_end.saturating_duration_since(ser_start)
                        .as_micros() as u64);
                let total_us = scratch.trace.finish(ser_end);
                state.recorder.commit(scratch.trace.id(), resp.status,
                                      total_us, scratch.trace.spans());
                let slow_us = state.slow_request.as_micros() as u64;
                if slow_us > 0 && total_us > slow_us {
                    obs_log::log_fields(
                        Level::Warn, "http", "slow request",
                        &[("id", scratch.trace.id()),
                          ("status", &resp.status.to_string()),
                          ("total_us", &total_us.to_string()),
                          ("path", &req.path)]);
                }
                if !write_ok || resp.close {
                    break;
                }
            }
            Err(e) => {
                // stream position unknown after a malformed request:
                // answer and close
                let resp = error_response(&e);
                state.http.note_status(resp.status);
                let mut w = &stream;
                let _ = resp.write_to(&mut w);
                break;
            }
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_snapshot_tracks_classes() {
        let c = HttpCounters::new();
        c.note_accepted();
        c.note_request();
        c.note_status(200);
        c.note_status(404);
        c.note_status(502);
        c.note_shed();
        let s = c.snapshot();
        assert_eq!(s.accepted, 1);
        assert_eq!(s.requests, 1);
        assert_eq!(s.status_2xx, 1);
        assert_eq!(s.status_4xx, 1);
        assert_eq!(s.status_5xx, 1);
        assert_eq!(s.shed, 1);
    }

    #[test]
    fn registry_registers_and_forgets() {
        let reg = ConnRegistry::default();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let id = reg.register(&client);
        assert_eq!(reg.conns.lock().unwrap().len(), 1);
        reg.deregister(id);
        assert!(reg.conns.lock().unwrap().is_empty());
        // shutdown_all on an empty registry is a no-op
        reg.shutdown_all();
    }

    #[test]
    fn deadline_reader_times_out_on_silent_peer() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let stop = AtomicBool::new(false);
        let mut r = DeadlineReader::new(
            &server_side, Instant::now() + Duration::from_millis(120),
            &stop);
        let mut buf = [0u8; 16];
        let start = Instant::now();
        let err = r.read(&mut buf).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
        assert!(start.elapsed() >= Duration::from_millis(100));
    }

    #[test]
    fn deadline_reader_honors_shutdown_before_first_byte() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let stop = AtomicBool::new(true);
        let mut r = DeadlineReader::new(
            &server_side, Instant::now() + Duration::from_secs(30), &stop);
        let mut buf = [0u8; 16];
        // idle + shutdown = clean EOF, immediately
        assert_eq!(r.read(&mut buf).unwrap(), 0);
    }
}
