//! Typed routes over the router: request → [`ServeHandle`] → response,
//! with every [`ServeError`] mapped to its HTTP status (DESIGN.md §11):
//!
//! | condition                         | status             |
//! |-----------------------------------|--------------------|
//! | inference complete                | 200                |
//! | malformed body / wrong shape      | 400                |
//! | unknown path                      | 404                |
//! | known path, wrong method          | 405 (+ `Allow`)    |
//! | `Busy { retry_after }`            | 429 (+ `Retry-After`) |
//! | replica dead / executor error     | 502                |
//! | degraded (dead replica) `/healthz`| 503                |
//! | per-request deadline expired      | 504                |

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{ServeError, ServeHandle, StatsHandle};
use crate::json::{self, Json};
use crate::obs::trace::{Stage, StageCells, TraceBuilder};
use crate::obs::FlightRecorder;
use crate::tensor::HostTensor;

use super::http::{Request, Response};
use super::{prometheus, HttpCounters};

/// Everything a connection thread needs to answer requests. Cheap to
/// clone (handles + Arcs).
#[derive(Clone)]
pub struct AppState {
    pub handle: ServeHandle,
    pub stats: StatsHandle,
    pub http: HttpCounters,
    /// Default model for `/v1/classify` when the body names none.
    pub model: String,
    /// Expected input tensor shape (`pixels` length must match its
    /// product). The native default is `[3, 32, 32]`; tests shrink it.
    pub input_shape: Vec<usize>,
    /// Per-request inference deadline (`--request-timeout-ms`).
    pub request_timeout: Duration,
    /// Ring of completed request traces (`/debug/traces`).
    pub recorder: Arc<FlightRecorder>,
    /// Requests slower than this are logged at warn with their span
    /// breakdown (`--slow-request-ms`; zero disables).
    pub slow_request: Duration,
}

/// Per-connection reusable scratch: the request trace and the
/// `/metrics` render buffers keep their capacity across requests, so a
/// warm keep-alive connection answers without heap growth.
#[derive(Default)]
pub struct ConnScratch {
    pub trace: TraceBuilder,
    pub prom: prometheus::RenderScratch,
}

impl ConnScratch {
    pub fn new() -> ConnScratch {
        ConnScratch::default()
    }
}

fn err_body(msg: &str) -> String {
    Json::Obj(vec![("error".to_string(), Json::from(msg))]).to_string()
}

/// Dispatch one parsed request. Never panics; every outcome is a
/// well-formed response.
pub fn handle_request(state: &AppState, req: &Request,
                      scratch: &mut ConnScratch) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET" | "HEAD", "/healthz") => {
            // degraded-permanent (a replica is dead for good: restart
            // budget exhausted or supervision off) is distinguished
            // from degraded-recovering (supervisor mid-backoff or
            // probation): both are 503, but orchestrators should only
            // replace the process on "permanent"
            let (status, body) = if state.stats.degraded_permanent() {
                (503, "{\"status\":\"degraded\",\"mode\":\"permanent\"}")
            } else if state.stats.degraded_recovering() {
                (503, "{\"status\":\"degraded\",\"mode\":\"recovering\"}")
            } else {
                (200, "{\"status\":\"ok\"}")
            };
            let body = if req.method == "HEAD" { "" } else { body };
            Response::json(status, body.to_string())
        }
        ("GET", "/metrics") => {
            prometheus::render_into(&mut scratch.prom, &state.stats,
                                    &state.http.snapshot());
            Response {
                status: 200,
                content_type: "text/plain; version=0.0.4",
                body: scratch.prom.buf().as_bytes().to_vec(),
                headers: Vec::new(),
                close: false,
            }
        }
        ("GET", "/debug/traces") => {
            let rec = &state.recorder;
            Response::json(200, rec.dump_json(&rec.recent()).to_string())
        }
        ("GET", "/debug/slowest") => {
            let rec = &state.recorder;
            Response::json(200, rec.dump_json(&rec.slowest()).to_string())
        }
        ("POST", "/v1/classify") => {
            classify(state, req, &mut scratch.trace)
        }
        (_, "/healthz") => method_not_allowed("GET, HEAD"),
        (_, "/metrics") => method_not_allowed("GET"),
        (_, "/debug/traces") => method_not_allowed("GET"),
        (_, "/debug/slowest") => method_not_allowed("GET"),
        (_, "/v1/classify") => method_not_allowed("POST"),
        _ => Response::json(404, err_body("no such route")),
    }
}

fn method_not_allowed(allow: &str) -> Response {
    Response::json(405, err_body("method not allowed"))
        .with_header("Allow", allow.to_string())
}

/// Fold worker-attributed stage durations ([`StageCells`]) into the
/// request's trace as back-to-back spans laid out from the moment the
/// request was handed to the router. The worker reports durations, not
/// absolute instants, so the spans are synthesized in execution order
/// (`queue_wait` → `batch_assembly` → `scatter` → `fft` →
/// `mixer_matmul` → `gather`); each starts where the previous ended,
/// keeping the trace monotone with the stage sum bounded by the
/// request's wall time (every batched request waited for its whole
/// batch).
fn fold_worker_spans(trace: &mut TraceBuilder, cells: &StageCells,
                     infer_start: Instant) {
    let mut cursor = trace.offset_us(infer_start);
    for stage in &Stage::all()[Stage::QueueWait.index()
                               ..=Stage::Gather.index()] {
        let d = cells.get_us(*stage);
        if d > 0 {
            trace.span_us(*stage, cursor, d);
            cursor += d;
        }
    }
}

/// `POST /v1/classify`: `{"pixels": [f32; prod(input_shape)],
/// "model"?: "name"}` → `{"model", "argmax", "logits"}`.
fn classify(state: &AppState, req: &Request,
            trace: &mut TraceBuilder) -> Response {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => {
            return Response::json(400, err_body("body is not utf-8"));
        }
    };
    let parsed = match json::parse(text) {
        Ok(v) => v,
        Err(e) => {
            return Response::json(
                400, err_body(&format!("invalid JSON body: {e}")));
        }
    };
    let model = match parsed.get("model") {
        None => state.model.clone(),
        Some(v) => match v.as_str() {
            Ok(s) => s.to_string(),
            Err(_) => {
                return Response::json(
                    400, err_body("\"model\" must be a string"));
            }
        },
    };
    let want: usize = state.input_shape.iter().product();
    let pixels = match parsed.get("pixels").map(|p| p.as_arr()) {
        Some(Ok(arr)) => arr,
        Some(Err(_)) => {
            return Response::json(
                400, err_body("\"pixels\" must be an array of numbers"));
        }
        None => {
            return Response::json(
                400, err_body("missing \"pixels\" array"));
        }
    };
    if pixels.len() != want {
        return Response::json(400, err_body(&format!(
            "\"pixels\" has {} values, expected {} (shape {:?})",
            pixels.len(), want, state.input_shape)));
    }
    let mut data = Vec::with_capacity(want);
    for p in pixels {
        match p.as_f64() {
            Ok(v) => data.push(v as f32),
            Err(_) => {
                return Response::json(
                    400, err_body("\"pixels\" must be all numbers"));
            }
        }
    }
    let input = match HostTensor::f32(state.input_shape.clone(), data) {
        Ok(t) => t,
        Err(e) => {
            return Response::json(400, err_body(&format!("{e}")));
        }
    };

    let deadline = Instant::now() + state.request_timeout;
    let timing = if trace.active() {
        Some(StageCells::new())
    } else {
        None
    };
    let infer_start = Instant::now();
    let result = state.handle.infer_deadline_traced(&model, input,
                                                    deadline,
                                                    timing.clone());
    if let Some(cells) = &timing {
        fold_worker_spans(trace, cells, infer_start);
    }
    match result {
        Ok(row) => {
            let logits = match row.as_f32() {
                Ok(l) => l,
                Err(e) => {
                    return Response::json(
                        502, err_body(&format!("bad logits row: {e}")));
                }
            };
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            let body = Json::Obj(vec![
                ("model".to_string(), Json::from(model.as_str())),
                ("argmax".to_string(), Json::from(argmax)),
                ("logits".to_string(),
                 Json::Arr(logits.iter()
                     .map(|&v| Json::Num(v as f64))
                     .collect())),
            ]);
            Response::json(200, body.to_string())
        }
        Err(ServeError::Busy { retry_after }) => {
            // Retry-After is whole seconds; round up so clients never
            // retry sooner than the hint
            let secs = retry_after.as_secs_f64().ceil().max(1.0) as u64;
            let body = Json::Obj(vec![
                ("error".to_string(), Json::from("server busy")),
                ("retry_after_ms".to_string(),
                 Json::from(retry_after.as_millis() as usize)),
            ]);
            Response::json(429, body.to_string())
                .with_header("Retry-After", secs.to_string())
        }
        Err(ServeError::DeadlineExceeded) => {
            Response::json(504, err_body("inference deadline exceeded"))
        }
        Err(ServeError::Failed(msg)) => {
            Response::json(502, err_body(&format!(
                "inference failed: {msg}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn err_body_is_json() {
        let v = json::parse(&err_body("boo\"m")).unwrap();
        assert_eq!(v.req("error").unwrap().as_str().unwrap(), "boo\"m");
    }
}
