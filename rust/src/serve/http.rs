//! Hardened incremental HTTP/1.1 request parser + response writer.
//!
//! The parser is the trust boundary of the serving layer: everything on
//! the other side of the socket is hostile until proven otherwise
//! (DESIGN.md §11). Hardening discipline, ported from mik-sdk's
//! request-parsing proptests:
//!
//! * **Hard caps before allocation.** The request head accumulates into
//!   a buffer capped at `max_request_line + max_header_bytes`; the body
//!   buffer is only allocated after `Content-Length` has been validated
//!   against `max_body`. No attacker-controlled value ever sizes an
//!   allocation — memory use is bounded by the configured limits, never
//!   proportional to claimed input.
//! * **Every malformed input is a typed 4xx**, never a panic, a hang,
//!   or silent acceptance: oversized request line → 414, oversized or
//!   too-many headers → 431, oversized body → 413, missing
//!   `Content-Length` on a body-bearing method → 411,
//!   `Transfer-Encoding` → 501 (chunked bodies are unsupported, and
//!   ignoring the header would desync the connection), everything else
//!   malformed → 400.
//! * **Read deadlines are the caller's job** (see `DeadlineReader` in
//!   [`super`]): this module maps `TimedOut`/`WouldBlock` I/O errors to
//!   [`ParseError::Timeout`] (→ 408) so slowloris writers are evicted.
//!
//! The parser reads from any `Read`, one buffered chunk at a time, and
//! is insensitive to how the bytes are split across reads — pinned by
//! proptests feeding 1-byte chunks (`tests/proptests.rs`).

use std::io::{ErrorKind, Read, Write};

/// Hard limits enforced while parsing a request. Defaults are generous
/// for the classify payload (a 3·32·32 image as JSON floats is ~30 KiB)
/// yet small enough that a saturating attacker costs ~1 MiB per
/// connection, bounded by the accept-side connection cap.
#[derive(Debug, Clone, Copy)]
pub struct HttpLimits {
    /// Max bytes in the request line (`METHOD SP PATH SP VERSION CRLF`).
    pub max_request_line: usize,
    /// Max total header bytes (after the request line, before the body).
    pub max_header_bytes: usize,
    /// Max number of header fields.
    pub max_headers: usize,
    /// Max declared (and therefore allocated) body size.
    pub max_body: usize,
}

impl Default for HttpLimits {
    fn default() -> Self {
        Self {
            max_request_line: 8 * 1024,
            max_header_bytes: 16 * 1024,
            max_headers: 64,
            max_body: 1024 * 1024,
        }
    }
}

/// Why a request failed to parse; [`ParseError::status`] maps each case
/// to the HTTP status the connection handler answers with before
/// closing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParseError {
    /// Malformed syntax, truncated stream, conflicting lengths… → 400.
    BadRequest(String),
    /// Request line exceeded `max_request_line` → 414.
    RequestLineTooLong,
    /// Headers exceeded `max_header_bytes` or `max_headers` → 431.
    HeadersTooLarge,
    /// Declared `Content-Length` exceeded `max_body` → 413.
    BodyTooLarge,
    /// Body-bearing method without a `Content-Length` → 411.
    LengthRequired,
    /// `Transfer-Encoding` present: unsupported, must not be ignored
    /// (desyncs the connection) → 501.
    UnsupportedEncoding,
    /// The read deadline expired mid-request (slowloris) → 408.
    Timeout,
}

impl ParseError {
    pub fn status(&self) -> u16 {
        match self {
            ParseError::BadRequest(_) => 400,
            ParseError::RequestLineTooLong => 414,
            ParseError::HeadersTooLarge => 431,
            ParseError::BodyTooLarge => 413,
            ParseError::LengthRequired => 411,
            ParseError::UnsupportedEncoding => 501,
            ParseError::Timeout => 408,
        }
    }

    fn bad(msg: impl Into<String>) -> ParseError {
        ParseError::BadRequest(msg.into())
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::BadRequest(m) => write!(f, "bad request: {m}"),
            ParseError::RequestLineTooLong => {
                f.write_str("request line too long")
            }
            ParseError::HeadersTooLarge => f.write_str("headers too large"),
            ParseError::BodyTooLarge => f.write_str("body too large"),
            ParseError::LengthRequired => f.write_str("length required"),
            ParseError::UnsupportedEncoding => {
                f.write_str("transfer-encoding unsupported")
            }
            ParseError::Timeout => f.write_str("request read timed out"),
        }
    }
}

/// A parsed request: method + path + lowercased headers + body.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path as sent (query string not split off; routes don't use one).
    pub path: String,
    /// `(name, value)` pairs; names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First header with this (lowercase) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    /// Did the client ask to close the connection after this exchange?
    pub fn wants_close(&self) -> bool {
        self.header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }
}

/// Read one request off `r`.
///
/// * `Ok(Some(req))` — a complete request.
/// * `Ok(None)` — clean EOF before any byte (client closed an idle
///   keep-alive connection); not an error.
/// * `Err(e)` — malformed/hostile input or a read timeout; the caller
///   answers `e.status()` and closes.
pub fn read_request(r: &mut dyn Read, limits: &HttpLimits)
                    -> Result<Option<Request>, ParseError> {
    let head_cap = limits.max_request_line + limits.max_header_bytes;
    let mut head: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    // accumulate until the blank line ending the head
    let head_end = loop {
        if let Some(pos) = find_head_end(&head) {
            break pos;
        }
        if head.len() > head_cap {
            // no terminator within the cap: decide which limit to blame
            return Err(oversized_head(&head));
        }
        let n = match r.read(&mut chunk) {
            Ok(n) => n,
            Err(e) => return Err(io_to_parse(e)),
        };
        if n == 0 {
            if head.is_empty() {
                return Ok(None); // idle connection closed cleanly
            }
            return Err(ParseError::bad("truncated request head"));
        }
        head.extend_from_slice(&chunk[..n]);
    };

    let mut rest = head.split_off(head_end + 4); // bytes after CRLFCRLF
    head.truncate(head_end); // head now ends before the blank line

    let (method, path) = parse_request_line(&head, limits)?;
    let headers = parse_headers(&head, limits)?;

    // body: only with a validated Content-Length
    let mut content_length: Option<usize> = None;
    for (name, value) in &headers {
        match name.as_str() {
            "content-length" => {
                let n: usize = value
                    .parse()
                    .map_err(|_| ParseError::bad("bad content-length"))?;
                if let Some(prev) = content_length {
                    if prev != n {
                        return Err(ParseError::bad(
                            "conflicting content-length headers"));
                    }
                }
                content_length = Some(n);
            }
            "transfer-encoding" => {
                return Err(ParseError::UnsupportedEncoding);
            }
            _ => {}
        }
    }

    let body = match content_length {
        Some(n) if n > limits.max_body => {
            return Err(ParseError::BodyTooLarge);
        }
        Some(n) => {
            // cap validated: allocating n is now bounded by max_body
            if rest.len() > n {
                // bytes past the declared body would desync keep-alive
                return Err(ParseError::bad("body longer than declared"));
            }
            let mut body = rest;
            body.reserve(n - body.len());
            while body.len() < n {
                let want = (n - body.len()).min(chunk.len());
                let got = match r.read(&mut chunk[..want]) {
                    Ok(0) => {
                        return Err(ParseError::bad("truncated body"));
                    }
                    Ok(got) => got,
                    Err(e) => return Err(io_to_parse(e)),
                };
                body.extend_from_slice(&chunk[..got]);
            }
            body
        }
        None if method == "POST" || method == "PUT" => {
            return Err(ParseError::LengthRequired);
        }
        None => {
            if !rest.is_empty() {
                return Err(ParseError::bad("unexpected body"));
            }
            rest
        }
    };

    Ok(Some(Request { method, path, headers, body }))
}

/// Position of the `\r\n\r\n` separating head from body.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// An oversized head with no terminator: blame the request line if the
/// first line itself never ended within its cap, else the headers.
fn oversized_head(head: &[u8]) -> ParseError {
    match head.iter().position(|&b| b == b'\n') {
        None => ParseError::RequestLineTooLong,
        Some(_) => ParseError::HeadersTooLarge,
    }
}

fn io_to_parse(e: std::io::Error) -> ParseError {
    match e.kind() {
        ErrorKind::TimedOut | ErrorKind::WouldBlock => ParseError::Timeout,
        // a reset mid-request is indistinguishable from truncation
        _ => ParseError::bad(format!("read failed: {}", e.kind())),
    }
}

/// Parse and validate `METHOD SP PATH SP HTTP/1.x` (first line of
/// `head`, CRLF-terminated).
fn parse_request_line(head: &[u8], limits: &HttpLimits)
                      -> Result<(String, String), ParseError> {
    let line_end = head
        .windows(2)
        .position(|w| w == b"\r\n")
        .unwrap_or(head.len());
    if line_end > limits.max_request_line {
        return Err(ParseError::RequestLineTooLong);
    }
    let line = &head[..line_end];
    if line.iter().any(|&b| b < 0x20 || b == 0x7f) {
        return Err(ParseError::bad("control bytes in request line"));
    }
    let line = std::str::from_utf8(line)
        .map_err(|_| ParseError::bad("request line is not utf-8"))?;
    let mut parts = line.split(' ');
    let (method, path, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v), None)
                if !m.is_empty() && !p.is_empty() => (m, p, v),
            _ => return Err(ParseError::bad("malformed request line")),
        };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(ParseError::bad("malformed method"));
    }
    if !path.starts_with('/') {
        return Err(ParseError::bad("path must be absolute"));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(ParseError::bad("unsupported http version"));
    }
    Ok((method.to_string(), path.to_string()))
}

/// Parse the header block (everything after the first CRLF of `head`).
fn parse_headers(head: &[u8], limits: &HttpLimits)
                 -> Result<Vec<(String, String)>, ParseError> {
    let block_start = match head.windows(2).position(|w| w == b"\r\n") {
        Some(p) => p + 2,
        None => return Ok(Vec::new()), // head was just the request line
    };
    let block = &head[block_start..];
    if block.len() > limits.max_header_bytes {
        return Err(ParseError::HeadersTooLarge);
    }
    let mut headers = Vec::new();
    for raw in block.split(|&b| b == b'\n') {
        let raw = raw.strip_suffix(b"\r").unwrap_or(raw);
        if raw.is_empty() {
            continue;
        }
        if headers.len() >= limits.max_headers {
            return Err(ParseError::HeadersTooLarge);
        }
        if raw.iter().any(|&b| b < 0x20 || b == 0x7f) {
            return Err(ParseError::bad("control bytes in header"));
        }
        let line = std::str::from_utf8(raw)
            .map_err(|_| ParseError::bad("header is not utf-8"))?;
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| ParseError::bad("header missing colon"))?;
        if name.is_empty()
            || name.contains(' ')
            || name.contains('\t')
        {
            return Err(ParseError::bad("malformed header name"));
        }
        headers.push((name.to_ascii_lowercase(),
                      value.trim().to_string()));
    }
    Ok(headers)
}

/// An HTTP response staged for writing.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra headers (e.g. `Retry-After`, `Allow`).
    pub headers: Vec<(String, String)>,
    /// Force `Connection: close` after this response.
    pub close: bool,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into_bytes(),
            headers: Vec::new(),
            close: false,
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
            headers: Vec::new(),
            close: false,
        }
    }

    pub fn with_header(mut self, name: &str, value: String) -> Response {
        self.headers.push((name.to_string(), value));
        self
    }

    pub fn closing(mut self) -> Response {
        self.close = true;
        self
    }

    /// Serialize status line + headers + body to `w`.
    pub fn write_to(&self, w: &mut dyn Write) -> std::io::Result<()> {
        let mut out = Vec::with_capacity(128 + self.body.len());
        out.extend_from_slice(
            format!("HTTP/1.1 {} {}\r\n", self.status,
                    reason_phrase(self.status)).as_bytes());
        out.extend_from_slice(
            format!("Content-Type: {}\r\n", self.content_type).as_bytes());
        out.extend_from_slice(
            format!("Content-Length: {}\r\n", self.body.len()).as_bytes());
        for (name, value) in &self.headers {
            out.extend_from_slice(
                format!("{name}: {value}\r\n").as_bytes());
        }
        if self.close {
            out.extend_from_slice(b"Connection: close\r\n");
        }
        out.extend_from_slice(b"\r\n");
        out.extend_from_slice(&self.body);
        w.write_all(&out)?;
        w.flush()
    }
}

/// The error response for a parse failure (always closes: the stream
/// position is unknown after a malformed request).
pub fn error_response(err: &ParseError) -> Response {
    let msg = crate::json::Json::Str(err.to_string()).to_string();
    Response::json(err.status(), format!("{{\"error\":{msg}}}")).closing()
}

pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        414 => "URI Too Long",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> Result<Option<Request>, ParseError> {
        read_request(&mut Cursor::new(raw.to_vec()),
                     &HttpLimits::default())
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
        assert!(!req.wants_close());
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            b"POST /v1/classify HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd")
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn clean_eof_is_none_truncated_is_400() {
        assert!(parse(b"").unwrap().is_none());
        let err = parse(b"GET / HTTP/1.1\r\nHost").unwrap_err();
        assert_eq!(err.status(), 400);
        let err = parse(
            b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc")
            .unwrap_err();
        assert_eq!(err.status(), 400);
    }

    #[test]
    fn post_without_length_is_411() {
        let err = parse(b"POST /v1/classify HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(err, ParseError::LengthRequired);
    }

    #[test]
    fn declared_body_over_cap_is_413_without_allocation() {
        // a huge claimed length must be rejected from the header alone
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n";
        // usize::try overflow path: absurd length is either a parse
        // error (400) on 32-bit or 413 on 64-bit; both are 4xx
        let err = parse(raw).unwrap_err();
        assert!(err.status() == 413 || err.status() == 400);
        let raw =
            b"POST / HTTP/1.1\r\nContent-Length: 2000000\r\n\r\n";
        assert_eq!(parse(raw).unwrap_err(), ParseError::BodyTooLarge);
    }

    #[test]
    fn transfer_encoding_is_501() {
        let raw =
            b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        assert_eq!(parse(raw).unwrap_err(),
                   ParseError::UnsupportedEncoding);
    }

    #[test]
    fn oversized_request_line_is_414() {
        let mut raw = b"GET /".to_vec();
        raw.extend(vec![b'a'; 40 * 1024]);
        assert_eq!(parse(&raw).unwrap_err(),
                   ParseError::RequestLineTooLong);
    }

    #[test]
    fn oversized_headers_are_431() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..4000 {
            raw.extend_from_slice(format!("X-H{i}: aaaaaaaa\r\n").as_bytes());
        }
        raw.extend_from_slice(b"\r\n");
        assert_eq!(parse(&raw).unwrap_err(), ParseError::HeadersTooLarge);
        // too many headers (but under the byte cap) also 431
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..100 {
            raw.extend_from_slice(format!("H{i}: a\r\n").as_bytes());
        }
        raw.extend_from_slice(b"\r\n");
        assert_eq!(parse(&raw).unwrap_err(), ParseError::HeadersTooLarge);
    }

    #[test]
    fn conflicting_content_lengths_rejected() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 3\r\n\
                    Content-Length: 4\r\n\r\nabc";
        assert_eq!(parse(raw).unwrap_err().status(), 400);
        // duplicate-but-equal is tolerated
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 3\r\n\
                    Content-Length: 3\r\n\r\nabc";
        assert!(parse(raw).unwrap().is_some());
    }

    #[test]
    fn malformed_lines_rejected() {
        for raw in [
            b"GARBAGE\r\n\r\n".to_vec(),
            b"GET /x HTTP/2.0\r\n\r\n".to_vec(),
            b"get /x HTTP/1.1\r\n\r\n".to_vec(),
            b"GET x HTTP/1.1\r\n\r\n".to_vec(),
            b"GET /x HTTP/1.1 extra\r\n\r\n".to_vec(),
            b"GET /x HTTP/1.1\r\nNoColonHere\r\n\r\n".to_vec(),
            b"GET /\x01 HTTP/1.1\r\n\r\n".to_vec(),
        ] {
            let err = parse(&raw).unwrap_err();
            assert_eq!(err.status(), 400, "input {:?} -> {err:?}", raw);
        }
    }

    #[test]
    fn split_across_reads_equivalent() {
        // 1-byte-at-a-time reader must parse identically to one chunk
        struct OneByte(Vec<u8>, usize);
        impl Read for OneByte {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.1 >= self.0.len() {
                    return Ok(0);
                }
                buf[0] = self.0[self.1];
                self.1 += 1;
                Ok(1)
            }
        }
        let raw =
            b"POST /v1/classify HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        let whole = parse(raw).unwrap().unwrap();
        let split = read_request(&mut OneByte(raw.to_vec(), 0),
                                 &HttpLimits::default())
            .unwrap()
            .unwrap();
        assert_eq!(whole.method, split.method);
        assert_eq!(whole.path, split.path);
        assert_eq!(whole.body, split.body);
    }

    #[test]
    fn timeout_io_maps_to_408() {
        struct TimesOut;
        impl Read for TimesOut {
            fn read(&mut self, _buf: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(ErrorKind::TimedOut, "deadline"))
            }
        }
        let err = read_request(&mut TimesOut, &HttpLimits::default())
            .unwrap_err();
        assert_eq!(err, ParseError::Timeout);
        assert_eq!(err.status(), 408);
    }

    #[test]
    fn response_writes_wire_format() {
        let resp = Response::json(429, "{\"e\":1}".into())
            .with_header("Retry-After", "1".into())
            .closing();
        let mut out = Vec::new();
        resp.write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.contains("Content-Length: 7\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"e\":1}"));
    }
}
