//! Table 3 / Fig. 2 driver: the circular-parameterization ablation
//! (qkv Averaged-Key / qv CAT / q-only / v-only vs standard attention)
//! on the ViT-L proxy with avg pooling — accuracy + parameter budget.
//!
//!   cargo run --release --example ablation -- [--steps 300]

use cat::harness;
use cat::runtime::Runtime;

fn main() -> cat::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |flag: &str| -> Option<String> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let steps: u64 = get("--steps").and_then(|s| s.parse().ok()).unwrap_or(300);
    let seed: u64 = get("--seed").and_then(|s| s.parse().ok()).unwrap_or(0);

    let rt = Runtime::from_env()?;
    let names = harness::table3_names();
    let rows = harness::run_grid(&rt, &names, steps, seed, 16)?;
    print!("{}", harness::render_table(
        "Table 3 / Fig. 2 — circular qkv ablation (ViT-L proxy, avg pool)",
        &rows));

    // parameter budgets measured from the manifest, Fig.-2 style
    println!("\nmeasured parameter budgets (mixing layers only excluded — \
              whole model):");
    for name in &names {
        let c = rt.config(name)?;
        println!("  {name:<22} {:>10} params", c.param_count);
    }
    if let Some(path) = get("--json") {
        std::fs::write(&path,
                       harness::rows_to_json(&rows).to_string_pretty())?;
        eprintln!("rows -> {path}");
    }
    Ok(())
}
