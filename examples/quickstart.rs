//! Quickstart: the 60-second tour of the public API.
//!
//! 1. open the artifact registry (PJRT CPU runtime),
//! 2. initialize a CAT ViT from its AOT `init` artifact,
//! 3. run one forward pass on a synthetic image batch,
//! 4. take 20 training steps and watch the loss fall.
//!
//! Run with: `cargo run --release --example quickstart`
//! (after `make artifacts`)

use cat::data::{BatchSource, ShapeDataset};
use cat::runtime::Runtime;
use cat::tensor::HostTensor;
use cat::train::{Schedule, TrainOptions, Trainer};

const MODEL: &str = "vit_b_avg_cat";

fn main() -> cat::Result<()> {
    // 1. runtime over ./artifacts (env CAT_ARTIFACTS overrides)
    let rt = Runtime::from_env()?;
    println!("PJRT platform: {}", rt.platform());
    let meta = rt.config(MODEL)?;
    println!("{MODEL}: d={} heads={} layers={} params={}",
             meta.d_model, meta.n_heads, meta.n_layers, meta.param_count);

    // 2-3. init params + one forward pass
    let mut trainer = Trainer::new(&rt, MODEL, 0)?;
    let ds = ShapeDataset::new(7);
    let mut pixels = Vec::new();
    let mut labels = Vec::new();
    ds.fill_batch(0, meta.batch_size, &mut pixels, &mut labels);
    let images = HostTensor::f32(
        vec![meta.batch_size, 3, 32, 32], pixels)?;
    let fwd = rt.load(MODEL, "forward")?;
    let mut args: Vec<&xla::Literal> = trainer.state.params.iter().collect();
    let img_lit = images.to_literal()?;
    args.push(&img_lit);
    let outs = fwd.execute_literals(&args)?;
    let logits = HostTensor::from_literal(&outs[0])?;
    println!("forward: logits shape {:?}, first row {:?}",
             logits.shape,
             &logits.as_f32()?[..meta.n_classes.min(4)]);

    // 4. a short training run
    let opts = TrainOptions {
        steps: 20,
        schedule: Schedule::constant(1e-3),
        log_every: 5,
        eval_batches: 4,
        ..Default::default()
    };
    let report = trainer.run(&opts)?;
    println!("loss: {:.4} -> {:.4} over {} steps ({:.2} steps/s)",
             report.curve.losses[0],
             report.curve.last().expect("nonempty curve"),
             report.steps_done, report.steps_per_sec());
    if let Some((k, v)) = report.final_metric() {
        println!("held-out {k}: {v:.4}");
    }
    println!("quickstart OK");
    Ok(())
}
