//! End-to-end driver (Table 1 + Sec. 5.5): trains the ViT grid and
//! prints the paper-style table. Hermetic by default — the native
//! training subsystem (gradients through the FFT, AdamW) needs no
//! artifacts; `--backend pjrt` (or any of the PJRT-era flags
//! `--table1` / `--fast` / `--mechanism`) drives the AOT grid instead
//! (feature `pjrt` + `make artifacts`).
//!
//!   cargo run --release --example train_vit -- --steps 150
//!   cargo run --release --example train_vit -- --config native_vit_cat
//!   cargo run --release --example train_vit -- --backend pjrt --table1
//!   cargo run --release --example train_vit -- --mechanism linear
//!       (Sec. 5.5 linear-attention instability probe; PJRT build)
//!
//! Both paths run through the shared `TrainBackend` loop
//! (`cat::train::run_training`), so their reports are comparable.

use cat::cli;
use cat::harness;

fn main() -> cat::Result<()> {
    let args = cli::parse(&["steps", "seed", "config", "json", "backend",
                            "mechanism"])?;
    let steps: u64 = args.parse_or("steps", 150)?;
    let seed: u64 = args.parse_or("seed", 0)?;

    // PJRT-era invocations keep their old meaning instead of silently
    // running the native grid
    if args.get("backend") == Some("pjrt") || args.has("mechanism")
        || args.has("table1") || args.has("fast") {
        return pjrt_grid(&args, steps, seed);
    }

    let names: Vec<String> = if let Some(cfg) = args.get("config") {
        vec![cfg.to_string()]
    } else {
        vec!["native_vit_attention".into(), "native_vit_cat".into(),
             "native_vit_cat_alter".into()]
    };
    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let rows = harness::run_native_grid(&name_refs, steps, seed, 16)?;
    print!("{}", harness::render_table(
        "Table 1 — ImageNet-proxy ViT grid, native training (accuracy up)",
        &rows));
    if let Some(path) = args.get("json") {
        std::fs::write(path,
                       harness::rows_to_json(&rows).to_string_pretty())?;
        eprintln!("rows -> {path}");
    }
    Ok(())
}

/// The original PJRT grid (+ Sec. 5.5 linear-instability probe).
#[cfg(feature = "pjrt")]
fn pjrt_grid(args: &cli::Args, steps: u64, seed: u64) -> cat::Result<()> {
    use cat::runtime::Runtime;
    use cat::train::{Schedule, TrainOptions, Trainer};

    let rt = Runtime::from_env()?;

    if args.get("mechanism") == Some("linear") {
        // Sec. 5.5: linear attention under a hot LR the softmax models
        // tolerate; reports divergence step or the final gap vs CAT.
        println!("Sec 5.5 — linear attention instability probe");
        for (name, lr) in [("vit_l_avg_linear", 3e-3f32),
                           ("vit_l_avg_cat", 3e-3)] {
            let mut trainer = Trainer::new(&rt, name, seed)?;
            let opts = TrainOptions {
                steps,
                schedule: Schedule::constant(lr),
                seed,
                log_every: (steps / 5).max(1),
                stop_on_divergence: true,
                eval_batches: 8,
                ..Default::default()
            };
            let report = trainer.run(&opts)?;
            match report.diverged_at {
                Some(s) => println!(
                    "{name:<18} lr={lr:.0e}  DIVERGED at step {s} (NaN \
                     loss) — matches the paper's reported instability"),
                None => println!(
                    "{name:<18} lr={lr:.0e}  stable; final loss {:.4}, \
                     {} = {:.4}",
                    report.curve.last().unwrap_or(f32::NAN),
                    report.final_metric().map(|m| m.0).unwrap_or("-"),
                    report.final_metric().map(|m| m.1).unwrap_or(f64::NAN)),
            }
        }
        return Ok(());
    }

    let names: Vec<String> = if let Some(cfg) = args.get("config") {
        vec![cfg.to_string()]
    } else {
        harness::table1_names(args.has("fast"))
    };
    let rows = harness::run_grid(&rt, &names, steps, seed, 16)?;
    print!("{}", harness::render_table(
        "Table 1 — ImageNet-proxy ViT grid (accuracy up)", &rows));
    if let Some(path) = args.get("json") {
        std::fs::write(path,
                       harness::rows_to_json(&rows).to_string_pretty())?;
        eprintln!("rows -> {path}");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_grid(_args: &cli::Args, _steps: u64, _seed: u64) -> cat::Result<()> {
    anyhow::bail!("this invocation names the PJRT path (--backend pjrt / \
                   --table1 / --fast / --mechanism), which needs a build \
                   with `--features pjrt` plus `make artifacts`; the \
                   default native path runs hermetically")
}
