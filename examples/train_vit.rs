//! End-to-end driver (Table 1 + Sec. 5.5): trains the ViT grid on the
//! synthetic ImageNet substitute and prints the paper-style table.
//!
//!   cargo run --release --example train_vit -- --table1 --steps 300
//!   cargo run --release --example train_vit -- --mechanism linear
//!       (the Sec. 5.5 linear-attention instability probe: trains with an
//!        aggressive LR and reports where/whether the loss diverges)
//!   cargo run --release --example train_vit -- --config vit_l_avg_cat
//!
//! This is the EXPERIMENTS.md §Table-1 end-to-end run: all three layers
//! compose — rust data pipeline -> AOT train step (Pallas kernels inside)
//! -> rust metrics.

use cat::harness;
use cat::runtime::Runtime;
use cat::train::{Schedule, TrainOptions, Trainer};

fn main() -> cat::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |flag: &str| -> Option<String> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let has = |flag: &str| args.iter().any(|a| a == flag);
    let steps: u64 = get("--steps").and_then(|s| s.parse().ok()).unwrap_or(300);
    let seed: u64 = get("--seed").and_then(|s| s.parse().ok()).unwrap_or(0);

    let rt = Runtime::from_env()?;

    if has("--mechanism") && get("--mechanism").as_deref() == Some("linear") {
        return linear_instability(&rt, steps, seed);
    }

    let names: Vec<String> = if let Some(cfg) = get("--config") {
        vec![cfg]
    } else {
        harness::table1_names(has("--fast"))
    };
    let rows = harness::run_grid(&rt, &names, steps, seed, 16)?;
    print!("{}", harness::render_table(
        "Table 1 — ImageNet-proxy ViT grid (accuracy up)", &rows));
    if let Some(path) = get("--json") {
        std::fs::write(&path,
                       harness::rows_to_json(&rows).to_string_pretty())?;
        eprintln!("rows -> {path}");
    }
    Ok(())
}

/// Sec. 5.5: linear attention under the shared recipe, pushed with a hot
/// LR the softmax models tolerate. Reports divergence step (NaN) or the
/// final gap vs CAT — reproducing "repeated training instabilities".
fn linear_instability(rt: &Runtime, steps: u64, seed: u64) -> cat::Result<()> {
    println!("Sec 5.5 — linear attention instability probe (ViT-L proxy)");
    for (name, lr) in [("vit_l_avg_linear", 3e-3), ("vit_l_avg_cat", 3e-3)] {
        let mut trainer = Trainer::new(rt, name, seed)?;
        let opts = TrainOptions {
            steps,
            schedule: Schedule::constant(lr),
            seed,
            log_every: (steps / 5).max(1),
            stop_on_divergence: true,
            eval_batches: 8,
            ..Default::default()
        };
        let report = trainer.run(&opts)?;
        match report.diverged_at {
            Some(s) => println!(
                "{name:<18} lr={lr:.0e}  DIVERGED at step {s} (NaN loss) — \
                 matches the paper's reported instability"),
            None => println!(
                "{name:<18} lr={lr:.0e}  stable; final loss {:.4}, \
                 {} = {:.4}",
                report.curve.last().unwrap_or(f32::NAN),
                report.final_metric().map(|m| m.0).unwrap_or("-"),
                report.final_metric().map(|m| m.1).unwrap_or(f64::NAN)),
        }
    }
    Ok(())
}
