//! Hermetic serving example: router + dynamic batcher over the native
//! Rust CAT-FFT backend. No artifacts, no PJRT, no Python — runs in a
//! fresh checkout:
//!
//!   cargo run --release --example native_serve -- [--requests 512]
//!
//! Fires concurrent traffic from client threads and reports latency
//! percentiles, throughput, and batching occupancy, mirroring
//! `examples/serve.rs` (the PJRT version, which additionally trains).

use cat::coordinator::{ServeOptions, Server};
use cat::data::ShapeDataset;
use cat::native::NativeVitConfig;
use cat::runtime::Backend;
use cat::tensor::HostTensor;

const MODEL: &str = "native_cat_vit";

fn main() -> cat::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |flag: &str| -> Option<u64> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .and_then(|s| s.parse().ok())
    };
    let requests = get("--requests").unwrap_or(512) as usize;

    let cfg = NativeVitConfig::default();
    eprintln!("serving {MODEL}: native CAT-FFT, d={} h={} L={} tokens={}",
              cfg.d_model, cfg.n_heads, cfg.n_layers, cfg.n_tokens());

    let opts = ServeOptions {
        backend: Backend::Native,
        native: cfg,
        ..Default::default()
    };
    let server = Server::spawn(cat::artifacts_dir(), &[MODEL.to_string()],
                               opts, 0)?;
    let handle = server.handle();
    let ds = ShapeDataset::new(123);
    let t0 = std::time::Instant::now();
    let n_clients = 8usize;
    let per_client = requests / n_clients;
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let h = handle.clone();
        let ds = ds.clone();
        clients.push(std::thread::spawn(move || -> cat::Result<usize> {
            let mut correct = 0usize;
            for i in 0..per_client {
                let sample = ds.sample((c * per_client + i) as u64);
                let input = HostTensor::f32(vec![3, 32, 32], sample.pixels)?;
                let logits = h.infer(MODEL, input)?;
                let row = logits.as_f32()?;
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).expect("finite"))
                    .map(|(j, _)| j as i32)
                    .expect("nonempty");
                correct += (pred == sample.label) as usize;
            }
            Ok(correct)
        }));
    }
    let mut correct = 0usize;
    for c in clients {
        correct += c.join().expect("client thread")?;
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(handle);
    let stats = server.shutdown();
    let served = n_clients * per_client;
    println!("served {served} requests in {wall:.2}s ({:.1} req/s)",
             served as f64 / wall);
    println!("accuracy (untrained init; chance = 0.1): {:.3}",
             correct as f64 / served as f64);
    for s in stats {
        println!("worker {}: {} reqs / {} batches, occupancy {:.2}, \
                  p50 {}us p99 {}us max {}us",
                 s.model, s.requests, s.batches, s.mean_occupancy,
                 s.latency.quantile_us(0.5), s.latency.quantile_us(0.99),
                 s.latency.max_us());
    }
    Ok(())
}
