//! End-to-end driver (Table 2): masked + causal language modeling on the
//! synthetic WikiText substitute, reporting word perplexity per
//! mechanism. Hermetic by default — the native training subsystem trains
//! through the (zero-padded, for causal) FFT with AdamW and needs no
//! artifacts; `--backend pjrt` (or the PJRT-era flags `--fused` /
//! `--table2` / `--fast`) drives the AOT grid / fused-K demo instead
//! (feature `pjrt` + `make artifacts`).
//!
//!   cargo run --release --example train_lm -- --steps 120
//!   cargo run --release --example train_lm -- --config native_lm_causal_cat
//!   cargo run --release --example train_lm -- --fused   (train_k8, pjrt)
//!
//! Both paths run through the shared `TrainBackend` loop
//! (`cat::train::run_training`), so their reports are comparable.

use cat::cli;
use cat::harness;

fn main() -> cat::Result<()> {
    let args = cli::parse(&["steps", "seed", "config", "json", "backend"])?;
    let steps: u64 = args.parse_or("steps", 120)?;
    let seed: u64 = args.parse_or("seed", 0)?;

    // PJRT-era invocations keep their old meaning instead of silently
    // running the native grid
    if args.get("backend") == Some("pjrt") || args.has("fused")
        || args.has("table2") || args.has("fast") {
        return pjrt_grid(&args, steps, seed);
    }

    let names: Vec<String> = if let Some(cfg) = args.get("config") {
        vec![cfg.to_string()]
    } else {
        vec!["native_lm_masked_attention".into(),
             "native_lm_masked_cat".into(),
             "native_lm_masked_cat_alter".into(),
             "native_lm_causal_attention".into(),
             "native_lm_causal_cat".into()]
    };
    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let rows = harness::run_native_grid(&name_refs, steps, seed, 8)?;
    print!("{}", harness::render_table(
        "Table 2 — WikiText-proxy LM grid, native training (word PPL down)",
        &rows));
    if let Some(path) = args.get("json") {
        std::fs::write(path,
                       harness::rows_to_json(&rows).to_string_pretty())?;
        eprintln!("rows -> {path}");
    }
    Ok(())
}

/// The original PJRT grid + the fused-K-step demo.
#[cfg(feature = "pjrt")]
fn pjrt_grid(args: &cli::Args, steps: u64, seed: u64) -> cat::Result<()> {
    use cat::runtime::Runtime;
    use cat::train::{Schedule, TrainOptions, Trainer};

    let rt = Runtime::from_env()?;

    if args.has("fused") {
        // fused-K-step demo: identical math, fewer host<->device round
        // trips (EXPERIMENTS.md §Perf quantifies the gain)
        let name = "lm_gpt2_masked_cat";
        let opts = TrainOptions {
            steps,
            schedule: Schedule::new(2.5e-4, steps / 10, steps),
            seed,
            eval_batches: 8,
            ..Default::default()
        };
        let mut t_seq = Trainer::new(&rt, name, seed)?;
        let seq = t_seq.run(&opts)?;
        let mut t_fused = Trainer::new(&rt, name, seed)?;
        let fused = t_fused.run_fused(&opts, 8)?;
        println!("sequential: {:.2} steps/s; fused(K=8): {:.2} steps/s \
                  ({:.2}x)",
                 seq.steps_per_sec(), fused.steps_per_sec(),
                 fused.steps_per_sec() / seq.steps_per_sec());
        println!("final ppl  sequential {:.3}  fused {:.3}",
                 seq.final_metric().map(|m| m.1).unwrap_or(f64::NAN),
                 fused.final_metric().map(|m| m.1).unwrap_or(f64::NAN));
        return Ok(());
    }

    let names: Vec<String> = if let Some(cfg) = args.get("config") {
        vec![cfg.to_string()]
    } else {
        harness::table2_names(args.has("fast"))
    };
    let rows = harness::run_grid(&rt, &names, steps, seed, 8)?;
    print!("{}", harness::render_table(
        "Table 2 — WikiText-proxy LM grid (word PPL down)", &rows));
    if let Some(path) = args.get("json") {
        std::fs::write(path,
                       harness::rows_to_json(&rows).to_string_pretty())?;
        eprintln!("rows -> {path}");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_grid(_args: &cli::Args, _steps: u64, _seed: u64) -> cat::Result<()> {
    anyhow::bail!("this invocation names the PJRT path (--backend pjrt / \
                   --fused / --table2 / --fast), which needs a build with \
                   `--features pjrt` plus `make artifacts`; the default \
                   native path runs hermetically")
}
