//! End-to-end driver (Table 2): masked + causal language modeling on the
//! synthetic WikiText substitute, reporting word perplexity per mechanism.
//!
//!   cargo run --release --example train_lm -- --table2 --steps 200
//!   cargo run --release --example train_lm -- --config lm_gpt2_masked_cat
//!   cargo run --release --example train_lm -- --fused   (train_k8 path)

use cat::harness;
use cat::runtime::Runtime;
use cat::train::{Schedule, TrainOptions, Trainer};

fn main() -> cat::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |flag: &str| -> Option<String> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let has = |flag: &str| args.iter().any(|a| a == flag);
    let steps: u64 = get("--steps").and_then(|s| s.parse().ok()).unwrap_or(200);
    let seed: u64 = get("--seed").and_then(|s| s.parse().ok()).unwrap_or(0);

    let rt = Runtime::from_env()?;

    if has("--fused") {
        // fused-K-step demo: identical math, fewer host<->device round
        // trips (EXPERIMENTS.md §Perf quantifies the gain)
        let name = "lm_gpt2_masked_cat";
        let opts = TrainOptions {
            steps,
            schedule: Schedule::new(2.5e-4, steps / 10, steps),
            seed,
            eval_batches: 8,
            ..Default::default()
        };
        let mut t_seq = Trainer::new(&rt, name, seed)?;
        let seq = t_seq.run(&opts)?;
        let mut t_fused = Trainer::new(&rt, name, seed)?;
        let fused = t_fused.run_fused(&opts, 8)?;
        println!("sequential: {:.2} steps/s; fused(K=8): {:.2} steps/s \
                  ({:.2}x)",
                 seq.steps_per_sec(), fused.steps_per_sec(),
                 fused.steps_per_sec() / seq.steps_per_sec());
        println!("final ppl  sequential {:.3}  fused {:.3}",
                 seq.final_metric().map(|m| m.1).unwrap_or(f64::NAN),
                 fused.final_metric().map(|m| m.1).unwrap_or(f64::NAN));
        return Ok(());
    }

    let names: Vec<String> = if let Some(cfg) = get("--config") {
        vec![cfg]
    } else {
        harness::table2_names(has("--fast"))
    };
    let rows = harness::run_grid(&rt, &names, steps, seed, 8)?;
    print!("{}", harness::render_table(
        "Table 2 — WikiText-proxy LM grid (word PPL down)", &rows));
    if let Some(path) = get("--json") {
        std::fs::write(&path,
                       harness::rows_to_json(&rows).to_string_pretty())?;
        eprintln!("rows -> {path}");
    }
    Ok(())
}
