//! Serving example: train a small CAT ViT briefly, then serve it through
//! the router + dynamic batcher and fire concurrent traffic from client
//! threads, reporting latency percentiles, throughput, batching occupancy
//! — and accuracy, proving the served parameters are the trained ones.
//!
//!   cargo run --release --example serve -- [--requests 512] [--steps 100]

use cat::coordinator::{server::WorkerSpec, ServeOptions, Server};
use cat::data::ShapeDataset;
use cat::runtime::Runtime;
use cat::tensor::HostTensor;
use cat::train::{Schedule, TrainOptions, Trainer};

const MODEL: &str = "vit_b_avg_cat";

fn main() -> cat::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |flag: &str| -> Option<u64> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .and_then(|s| s.parse().ok())
    };
    let requests = get("--requests").unwrap_or(512) as usize;
    let steps = get("--steps").unwrap_or(100);

    let rt = Runtime::from_env()?;

    // 1. train briefly so serving has real parameters
    eprintln!("training {MODEL} for {steps} steps...");
    let mut trainer = Trainer::new(&rt, MODEL, 0)?;
    let report = trainer.run(&TrainOptions {
        steps,
        schedule: Schedule::new(1e-3, steps / 10, steps),
        eval_batches: 8,
        ..Default::default()
    })?;
    let (k, v) = report.final_metric().expect("metric");
    eprintln!("trained: {k}={v:.3} at {:.2} steps/s", report.steps_per_sec());

    // 2. serve the *trained* parameters (host copies cross the thread
    //    boundary; the worker rebuilds literals in its own PJRT runtime)
    let trained = trainer.state.params_host()?;
    drop(trainer);
    drop(rt);
    let server = Server::spawn_specs(
        cat::artifacts_dir(),
        vec![WorkerSpec { model: MODEL.to_string(), params: Some(trained),
                          seed: 0 }],
        ServeOptions {
            // trained checkpoints serve through PJRT; the hermetic native
            // demo is examples/native_serve.rs
            backend: cat::runtime::Backend::Pjrt,
            ..Default::default()
        })?;
    let handle = server.handle();

    // held-out traffic from 8 concurrent client threads
    let ds = ShapeDataset::new(999);
    let n_clients = 8usize;
    let per_client = requests / n_clients;
    let t0 = std::time::Instant::now();
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let h = handle.clone();
        let ds = ds.clone();
        clients.push(std::thread::spawn(move || -> cat::Result<usize> {
            let mut correct = 0usize;
            for i in 0..per_client {
                let sample = ds.sample((c * per_client + i) as u64);
                let input = HostTensor::f32(vec![3, 32, 32], sample.pixels)?;
                let logits = h.infer(MODEL, input)?;
                let row = logits.as_f32()?;
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).expect("finite"))
                    .map(|(j, _)| j as i32)
                    .expect("nonempty");
                correct += (pred == sample.label) as usize;
            }
            Ok(correct)
        }));
    }
    let mut correct = 0usize;
    for t in clients {
        correct += t.join().expect("client thread")?;
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(handle);
    let stats = server.shutdown();
    let served = n_clients * per_client;

    println!("\nserved {served} requests in {wall:.2}s = {:.1} req/s",
             served as f64 / wall);
    println!("served-model accuracy: {:.3} (trained {k}={v:.3})",
             correct as f64 / served as f64);
    for s in &stats {
        println!("worker {}: {} requests / {} batches (occupancy {:.2})",
                 s.model, s.requests, s.batches, s.mean_occupancy);
        println!("latency p50 {}us p90 {}us p99 {}us max {}us mean {:.0}us",
                 s.latency.quantile_us(0.5), s.latency.quantile_us(0.9),
                 s.latency.quantile_us(0.99), s.latency.max_us(),
                 s.latency.mean_us());
    }
    Ok(())
}
