//! Fig. 1 / complexity claim: measured forward wallclock of one mixing
//! layer across N ∈ {64..2048} for attention (O(N^2)), CAT-gather (O(N^2),
//! no qk matmul) and CAT-FFT (O(N log N)), next to the analytic FLOP
//! model from `cat::complexity`.

use cat::bench::Bench;
use cat::complexity::{layer_cost, Mechanism};
use cat::data::Rng;
use cat::runtime::Runtime;
use cat::tensor::HostTensor;

const NS: [usize; 6] = [64, 128, 256, 512, 1024, 2048];

fn inputs_for(rt: &Runtime, name: &str) -> Vec<xla::Literal> {
    let entry = rt.config(name).expect("cfg").entry("forward").expect("fwd");
    let mut rng = Rng::new(7);
    entry
        .inputs
        .iter()
        .map(|spec| {
            let data: Vec<f32> = (0..spec.num_elements())
                .map(|_| 0.05 * rng.normal())
                .collect();
            HostTensor::f32(spec.shape.clone(), data)
                .expect("t")
                .to_literal()
                .expect("lit")
        })
        .collect()
}

fn main() {
    let rt = Runtime::from_env().expect("artifacts present?");
    let mut bench = Bench::new("scaling (one mixing layer, d=256 h=8)");
    bench.warmup = 1;
    bench.samples = 5;

    for &n in &NS {
        for mech in ["attention", "cat_fft", "cat_gather"] {
            let name = format!("scale_{n}_{mech}");
            let exe = rt.load(&name, "forward").expect("load");
            let inputs = inputs_for(&rt, &name);
            bench.case(&name, || {
                exe.execute_literals(&inputs.iter().collect::<Vec<_>>())
                    .expect("exec");
            });
        }
    }
    print!("{}", bench.report());

    println!("\nFig. 1 series: measured ms (and modeled GFLOP) per forward");
    println!("{:>6} {:>12} {:>12} {:>12}   {:>10} {:>10} {:>10}",
             "N", "attn ms", "catfft ms", "catgthr ms",
             "attn GF", "catfft GF", "gthr GF");
    for &n in &NS {
        let ms = |m: &str| bench
            .median_of(&format!("scale_{n}_{m}"))
            .map(|t| t * 1e3)
            .unwrap_or(f64::NAN);
        let gf = |m: Mechanism| layer_cost(m, n, 256, 8).flops / 1e9;
        println!("{n:>6} {:>12.3} {:>12.3} {:>12.3}   {:>10.3} {:>10.3} \
                  {:>10.3}",
                 ms("attention"), ms("cat_fft"), ms("cat_gather"),
                 gf(Mechanism::Attention), gf(Mechanism::CatFft),
                 gf(Mechanism::CatGather));
    }
}
