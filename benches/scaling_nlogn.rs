//! Fig. 1 / complexity claim on real hardware: measured forward wallclock
//! of one mixing layer for attention (O(N²)), CAT-gather (O(N²), no qk
//! matmul) and CAT-FFT (O(N log N)), next to the analytic FLOP model from
//! `cat::complexity`. Also measures the serving-relevant batched case
//! (batch 8 across the persistent worker pool) and reports FFT-path
//! throughput in sequences/second.
//!
//! Runs hermetically on the native Rust backend — no artifacts, no PJRT —
//! and additionally times the AOT executables when the crate is built with
//! `--features pjrt` and `artifacts/` exists. Emits `BENCH_scaling.json`.
//!
//! Each CAT-FFT point is also re-timed with the vector layer forced
//! onto its scalar oracles (`simd::set_force_scalar_global`, DESIGN.md
//! §15) — the per-layer simd-vs-scalar margin.
//!
//!   cargo bench --bench scaling_nlogn              # full sweep
//!   cargo bench --bench scaling_nlogn -- --smoke   # CI smoke (small N)
//!   ... -- --smoke --check   # CI gate: exit 1 unless FFT beats gather
//!                            # at N=1024 and the simd kernels are no
//!                            # slower than scalar at every N
//!
//! The batch-8 series is the PR-2 acceptance surface: ≥1.5× FFT-path
//! throughput at N≥1024 vs the PR-1 baseline (per-call thread spawns,
//! scalar AoS FFT, per-channel gather/scatter).

use cat::bench::Bench;
use cat::complexity::{crossover_n, layer_cost, Mechanism};
use cat::data::Rng;
use cat::json::Json;
use cat::native::{pool, simd, AttentionLayer, CatImpl, CatLayer};

const D: usize = 256;
const H: usize = 8;
/// Batch size of the serving-shaped throughput cases.
const B8: usize = 8;

fn layer_input(b: usize, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(n as u64 ^ 0xF16);
    (0..b * n * D).map(|_| 0.05 * rng.normal()).collect()
}

fn gflop(mech: Mechanism, n: usize) -> f64 {
    layer_cost(mech, n, D, H).flops / 1e9
}

fn main() {
    let args = cat::bench::bench_args("scaling_nlogn",
                                      &["smoke", "check"], &[]);
    let smoke = args.has("smoke");
    let check = args.has("check");
    let ns: &[usize] = if smoke {
        &[256, 512, 1024]
    } else {
        &[256, 512, 1024, 2048, 4096, 8192]
    };
    // the quadratic baselines get unbearably slow past this point; CAT-FFT
    // runs the full sweep (that asymmetry is the paper's whole argument)
    let quad_cap = if smoke { 1024 } else { 2048 };

    let mut rng = Rng::new(7);
    let cat = CatLayer::init(D, H, &mut rng);
    let attn = AttentionLayer::init(D, H, &mut rng);

    let mut bench =
        Bench::new("native scaling (one mixing layer, d=256 h=8)");
    bench.warmup = 1;
    bench.samples = if smoke { 2 } else { 3 };

    for &n in ns {
        let x = layer_input(1, n);
        bench.case(&format!("native_{n}_cat_fft"), || {
            cat.forward(&x, 1, n, CatImpl::Fft).expect("cat_fft forward");
        });
        // same layer, same input, vector kernels pinned to their scalar
        // oracles (pool workers included) — the simd-vs-scalar column
        simd::set_force_scalar_global(true);
        bench.case(&format!("native_{n}_cat_fft_scalar"), || {
            cat.forward(&x, 1, n, CatImpl::Fft)
                .expect("cat_fft scalar forward");
        });
        simd::set_force_scalar_global(false);
        if n >= 1024 {
            // serving-shaped batched case: one call, B8 sequences
            let xb = layer_input(B8, n);
            bench.case(&format!("native_{n}_cat_fft_b8"), || {
                cat.forward(&xb, B8, n, CatImpl::Fft)
                    .expect("cat_fft b8 forward");
            });
        }
        if n <= quad_cap {
            bench.case(&format!("native_{n}_cat_gather"), || {
                cat.forward(&x, 1, n, CatImpl::Gather)
                    .expect("cat_gather forward");
            });
            bench.case(&format!("native_{n}_attention"), || {
                attn.forward(&x, 1, n).expect("attention forward");
            });
        }
    }
    print!("{}", bench.report());

    println!("\nFig. 1 series: measured native ms (and modeled GFLOP) per \
              forward");
    println!("{:>6} {:>12} {:>12} {:>12}   {:>10} {:>10} {:>10}",
             "N", "attn ms", "catfft ms", "catgthr ms",
             "attn GF", "catfft GF", "gthr GF");
    for &n in ns {
        let ms = |mech: &str| bench
            .median_of(&format!("native_{n}_{mech}"))
            .map(|t| t * 1e3)
            .unwrap_or(f64::NAN);
        println!("{n:>6} {:>12.3} {:>12.3} {:>12.3}   {:>10.3} {:>10.3} \
                  {:>10.3}",
                 ms("attention"), ms("cat_fft"), ms("cat_gather"),
                 gflop(Mechanism::Attention, n), gflop(Mechanism::CatFft, n),
                 gflop(Mechanism::CatGather, n));
    }

    println!("\nsimd-vs-scalar margin, cat_fft forward [backend: {}]:",
             simd::backend_name());
    for &n in ns {
        if let (Some(v), Some(s)) =
            (bench.median_of(&format!("native_{n}_cat_fft")),
             bench.median_of(&format!("native_{n}_cat_fft_scalar")))
        {
            println!("  N={n:<5} simd {:>9.3} ms   scalar {:>9.3} ms   \
                      {:.2}x", v * 1e3, s * 1e3, s / v);
        }
    }

    println!("\nbatched FFT-path throughput (batch {B8}, the serving shape):");
    for &n in ns.iter().filter(|&&n| n >= 1024) {
        if let Some(t) = bench.median_of(&format!("native_{n}_cat_fft_b8")) {
            println!("  N={n:<5} {:>9.3} ms/call  {:>9.1} seq/s",
                     t * 1e3, B8 as f64 / t);
        }
    }
    let ps = pool::stats();
    println!("pool: {} workers, {} threads ever spawned, {} par sections, \
              {} chunks", ps.workers, ps.threads_spawned, ps.par_sections,
             ps.chunks_executed);

    println!();
    if let (Some(t4k), Some(t8k)) =
        (bench.median_of("native_4096_cat_fft"),
         bench.median_of("native_8192_cat_fft")) {
        println!("cat_fft growth 4096 -> 8192: {:.2}x  (sub-quadratic \
                  target: < 3x)", t8k / t4k);
    }
    if let (Some(fft), Some(gather)) =
        (bench.median_of(&format!("native_{quad_cap}_cat_fft")),
         bench.median_of(&format!("native_{quad_cap}_cat_gather"))) {
        println!("cat_fft vs gather at N={quad_cap}: {:.2}x faster",
                 gather / fft);
    }
    match crossover_n(D, H) {
        Some(n) => println!("modeled FLOP crossover (cat_fft < attention): \
                             N = {n}"),
        None => println!("modeled FLOP crossover: none below 2^23"),
    }

    let pjrt = pjrt_series(ns);

    let mut obj = vec![
        ("bench".to_string(), Json::from("scaling_nlogn")),
        ("d".to_string(), Json::Num(D as f64)),
        ("h".to_string(), Json::Num(H as f64)),
        ("batch_b8".to_string(), Json::Num(B8 as f64)),
        ("smoke".to_string(), Json::Bool(smoke)),
        ("simd_backend".to_string(), Json::from(simd::backend_name())),
        ("native".to_string(), bench.to_json()),
        ("simd_vs_scalar".to_string(), Json::Arr(
            ns.iter()
                .filter_map(|&n| {
                    let v = bench
                        .median_of(&format!("native_{n}_cat_fft"))?;
                    let s = bench
                        .median_of(&format!("native_{n}_cat_fft_scalar"))?;
                    Some(Json::Obj(vec![
                        ("n".to_string(), Json::Num(n as f64)),
                        ("simd_ms".to_string(), Json::Num(v * 1e3)),
                        ("scalar_ms".to_string(), Json::Num(s * 1e3)),
                        ("speedup".to_string(), Json::Num(s / v)),
                    ]))
                })
                .collect())),
        ("fft_throughput_seq_per_s".to_string(), Json::Arr(
            ns.iter()
                .filter(|&&n| n >= 1024)
                .filter_map(|&n| {
                    bench.median_of(&format!("native_{n}_cat_fft_b8"))
                        .map(|t| Json::Obj(vec![
                            ("n".to_string(), Json::Num(n as f64)),
                            ("seq_per_s".to_string(),
                             Json::Num(B8 as f64 / t)),
                        ]))
                })
                .collect())),
        ("pool".to_string(), Json::Obj(vec![
            ("workers".to_string(), Json::Num(ps.workers as f64)),
            ("threads_spawned".to_string(),
             Json::Num(ps.threads_spawned as f64)),
            ("par_sections".to_string(), Json::Num(ps.par_sections as f64)),
        ])),
        ("modeled_gflop".to_string(), Json::Arr(
            ns.iter()
                .map(|&n| Json::Obj(vec![
                    ("n".to_string(), Json::Num(n as f64)),
                    ("attention".to_string(),
                     Json::Num(gflop(Mechanism::Attention, n))),
                    ("cat_gather".to_string(),
                     Json::Num(gflop(Mechanism::CatGather, n))),
                    ("cat_fft".to_string(),
                     Json::Num(gflop(Mechanism::CatFft, n))),
                ]))
                .collect())),
    ];
    if let Some(p) = pjrt {
        obj.push(("pjrt".to_string(), p));
    }
    let out = Json::Obj(obj).to_string_pretty();
    std::fs::write("BENCH_scaling.json", out)
        .expect("write BENCH_scaling.json");
    eprintln!("results -> BENCH_scaling.json");

    if check {
        // CI perf gate: at N=1024 the O(N log N) path must beat the
        // O(N²) gather outright, or the sub-quadratic claim regressed
        let fft = bench.median_of("native_1024_cat_fft");
        let gather = bench.median_of("native_1024_cat_gather");
        match (fft, gather) {
            (Some(f), Some(g)) if f < g => {
                eprintln!("perf gate OK: cat_fft {:.3} ms < cat_gather \
                           {:.3} ms at N=1024", f * 1e3, g * 1e3);
            }
            (Some(f), Some(g)) => {
                eprintln!("perf gate FAILED: cat_fft {:.3} ms >= cat_gather \
                           {:.3} ms at N=1024", f * 1e3, g * 1e3);
                std::process::exit(1);
            }
            _ => {
                eprintln!("perf gate FAILED: N=1024 cases missing");
                std::process::exit(1);
            }
        }

        // simd gate: the vector kernels must be no slower than their
        // scalar oracles at every measured N. Throughput-space margin
        // matching the trainstep gate: simd must reach 97% of scalar
        // (a shared-runner noise grace, not a license to regress).
        const SIMD_GATE_MARGIN: f64 = 0.97;
        let mut simd_regressions = Vec::new();
        for &n in ns {
            if let (Some(v), Some(s)) =
                (bench.median_of(&format!("native_{n}_cat_fft")),
                 bench.median_of(&format!("native_{n}_cat_fft_scalar")))
            {
                if v * SIMD_GATE_MARGIN >= s {
                    simd_regressions.push(format!(
                        "N={n} (simd {:.3} ms vs scalar {:.3} ms)",
                        v * 1e3, s * 1e3));
                }
            }
        }
        if simd_regressions.is_empty() {
            eprintln!("simd gate OK: vector kernels no slower than \
                       forced-scalar at every measured N [{}]",
                      simd::backend_name());
        } else {
            eprintln!("simd gate FAILED: {simd_regressions:?}");
            std::process::exit(1);
        }
    }
}

/// Time the AOT `scale_{n}_{mech}` artifacts when available (pjrt builds
/// with `make artifacts` done); None otherwise.
#[cfg(feature = "pjrt")]
fn pjrt_series(ns: &[usize]) -> Option<Json> {
    use cat::runtime::Runtime;

    let rt = match Runtime::from_env() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("[pjrt series skipped: {e:#}]");
            return None;
        }
    };
    let mut bench = Bench::new("pjrt scaling (AOT mixing layer)");
    bench.warmup = 1;
    bench.samples = 3;
    for &n in ns.iter().filter(|&&n| n <= 2048) {
        for mech in ["attention", "cat_fft", "cat_gather"] {
            let name = format!("scale_{n}_{mech}");
            let Ok(meta) = rt.config(&name) else { continue };
            let entry = meta.entry("forward").expect("forward entry").clone();
            let exe = rt.load(&name, "forward").expect("load");
            let inputs = cat::bench::entry_inputs(&entry, 7);
            bench.case(&name, || {
                exe.execute_literals(&inputs.iter().collect::<Vec<_>>())
                    .expect("exec");
            });
        }
    }
    print!("{}", bench.report());
    Some(bench.to_json())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_series(_ns: &[usize]) -> Option<Json> {
    None
}
