//! L3 coordinator microbenches: the pure-rust hot paths that wrap every
//! PJRT call — dynamic batcher push/poll/take, batch assembly from the
//! synthetic substrates, logits post-processing. These must be negligible
//! next to the executable runtime (EXPERIMENTS.md §Perf verifies).

use std::time::Duration;

use cat::bench::Bench;
use cat::coordinator::DynamicBatcher;
use cat::data::{Rng, ShapeDataset, TextCorpus};
use cat::metrics::{accuracy, token_nll};
use cat::tensor::HostTensor;

fn main() {
    // no flags — but a typoed one must still error, not pass silently
    let _args = cat::bench::bench_args("coordinator", &[], &[]);
    let mut bench = Bench::new("coordinator hot paths");
    bench.warmup = 2;
    bench.samples = 20;

    bench.case("batcher_push_take_64", || {
        let mut batcher = DynamicBatcher::new(8, Duration::from_millis(1));
        for i in 0..64u32 {
            batcher.push(i);
        }
        let mut total = 0usize;
        while !batcher.is_empty() {
            total += batcher.take(8).len();
        }
        assert_eq!(total, 64);
    });

    let ds = ShapeDataset::new(1);
    let mut pixels = Vec::new();
    let mut labels = Vec::new();
    let mut start = 0u64;
    bench.case("image_batch_8", || {
        ds.fill_batch(start, 8, &mut pixels, &mut labels);
        start += 8;
    });

    let corpus = TextCorpus::new(1024, 1);
    let mut s = 0u64;
    bench.case("lm_masked_batch_8x256", || {
        let lb = corpus.masked_batch(s, 8, 256, 0.15);
        s += 8;
        assert_eq!(lb.tokens.len(), 8 * 256);
    });

    let mut rng = Rng::new(3);
    let logits = HostTensor::f32(
        vec![8, 256, 1024],
        (0..8 * 256 * 1024).map(|_| rng.normal()).collect())
        .expect("logits");
    let targets: Vec<i32> = (0..8 * 256).map(|i| (i % 1024) as i32).collect();
    let weights = vec![1.0f32; 8 * 256];
    bench.case("token_nll_8x256x1024", || {
        token_nll(&logits, &targets, &weights).expect("nll");
    });

    let cls = HostTensor::f32(
        vec![256, 10], (0..2560).map(|_| rng.normal()).collect())
        .expect("cls");
    let lab: Vec<i32> = (0..256).map(|i| (i % 10) as i32).collect();
    bench.case("accuracy_256x10", || {
        accuracy(&cls, &lab).expect("acc");
    });

    // end-to-end native serving: router + batcher + native CAT-FFT model,
    // 64 requests from 4 client threads (hermetic — no artifacts)
    bench.samples = 5;
    bench.case("native_serve_64_reqs", || {
        use cat::coordinator::{ServeOptions, Server};
        use cat::runtime::Backend;

        let opts = ServeOptions {
            backend: Backend::Native,
            ..Default::default()
        };
        let server = Server::spawn(cat::artifacts_dir(),
                                   &["bench_native".to_string()], opts, 0)
            .expect("spawn native server");
        let handle = server.handle();
        let ds = ShapeDataset::new(5);
        let mut clients = Vec::new();
        for c in 0..4u64 {
            let h = handle.clone();
            let ds = ds.clone();
            clients.push(std::thread::spawn(move || {
                for i in 0..16u64 {
                    let sample = ds.sample(c * 16 + i);
                    let input =
                        HostTensor::f32(vec![3, 32, 32], sample.pixels)
                            .expect("input");
                    h.infer("bench_native", input).expect("infer");
                }
            }));
        }
        for c in clients {
            c.join().expect("client");
        }
        drop(handle);
        let stats = server.shutdown();
        assert_eq!(stats.iter().map(|s| s.requests).sum::<u64>(), 64);
    });

    // steady-state serving: ONE long-lived server, requests issued from
    // this thread — after warmup, a request must spawn zero threads (the
    // forward fans out over the persistent pool; PR 1 spawned scoped
    // threads per parallel section). Asserted via the pool spawn counter.
    {
        use cat::coordinator::{ServeOptions, Server};
        use cat::native::{pool, NativeVitConfig};
        use cat::runtime::Backend;

        // big enough that forwards genuinely engage the pool
        let native = NativeVitConfig {
            d_model: 128,
            n_heads: 8,
            patch_size: 2, // 256 tokens
            ..Default::default()
        };
        let opts = ServeOptions {
            backend: Backend::Native,
            native,
            ..Default::default()
        };
        let server = Server::spawn(cat::artifacts_dir(),
                                   &["steady_native".to_string()], opts, 0)
            .expect("spawn steady native server");
        let handle = server.handle();
        let ds = ShapeDataset::new(9);
        let mut send = |tag: u64| {
            let sample = ds.sample(tag);
            let input = HostTensor::f32(vec![3, 32, 32], sample.pixels)
                .expect("input");
            handle.infer("steady_native", input).expect("infer");
        };
        for i in 0..8u64 {
            send(i); // warmup: pool threads spawn here at the latest
        }
        let spawned_before = pool::stats().threads_spawned;
        bench.case("native_serve_persistent_64_reqs", || {
            for i in 0..64u64 {
                send(1000 + i);
            }
        });
        let spawned_after = pool::stats().threads_spawned;
        assert_eq!(spawned_after, spawned_before,
                   "steady-state requests spawned threads: {spawned_before} \
                    -> {spawned_after}");
        println!("steady-state serving: 0 thread spawns across {} pooled \
                  requests (pool workers: {})",
                 64 * (bench.warmup + bench.samples),
                 pool::stats().workers);
        drop(handle);
        server.shutdown();
    }

    print!("{}", bench.report());
}
