//! L3 coordinator microbenches: the pure-rust hot paths that wrap every
//! PJRT call — dynamic batcher push/poll/take, batch assembly from the
//! synthetic substrates, logits post-processing — plus the sharded
//! serving steady state (head-parallel shards × data-parallel replicas,
//! DESIGN.md §10): asserts sharded == unsharded outputs bit-exactly,
//! zero per-request thread spawns, and backpressure engaging under
//! queue overflow. Emits `BENCH_coordinator.json` with the shard
//! counters (CI's perf-smoke runs `--smoke` and uploads it).

use std::sync::Arc;
use std::time::Duration;

use cat::bench::Bench;
use cat::coordinator::{aggregate_stats, BatchExecutor, DynamicBatcher,
                       ExecutorFactory, ServeError, ServeOptions, Server,
                       WorkerSpec};
use cat::data::{Rng, ShapeDataset, TextCorpus};
use cat::json::Json;
use cat::metrics::{accuracy, token_nll};
use cat::native::pool;
use cat::runtime::Backend;
use cat::tensor::HostTensor;

fn main() {
    let args = cat::bench::bench_args("coordinator", &["smoke"], &[]);
    let smoke = args.has("smoke");
    let mut bench = Bench::new("coordinator hot paths");
    bench.warmup = 2;
    bench.samples = if smoke { 5 } else { 20 };

    bench.case("batcher_push_take_64", || {
        let mut batcher = DynamicBatcher::new(8, Duration::from_millis(1));
        for i in 0..64u32 {
            batcher.push(i);
        }
        let mut total = 0usize;
        while !batcher.is_empty() {
            total += batcher.take(8).len();
        }
        assert_eq!(total, 64);
    });

    let ds = ShapeDataset::new(1);
    let mut pixels = Vec::new();
    let mut labels = Vec::new();
    let mut start = 0u64;
    bench.case("image_batch_8", || {
        ds.fill_batch(start, 8, &mut pixels, &mut labels);
        start += 8;
    });

    let corpus = TextCorpus::new(1024, 1);
    let mut s = 0u64;
    bench.case("lm_masked_batch_8x256", || {
        let lb = corpus.masked_batch(s, 8, 256, 0.15);
        s += 8;
        assert_eq!(lb.tokens.len(), 8 * 256);
    });

    let mut rng = Rng::new(3);
    let logits = HostTensor::f32(
        vec![8, 256, 1024],
        (0..8 * 256 * 1024).map(|_| rng.normal()).collect())
        .expect("logits");
    let targets: Vec<i32> = (0..8 * 256).map(|i| (i % 1024) as i32).collect();
    let weights = vec![1.0f32; 8 * 256];
    bench.case("token_nll_8x256x1024", || {
        token_nll(&logits, &targets, &weights).expect("nll");
    });

    let cls = HostTensor::f32(
        vec![256, 10], (0..2560).map(|_| rng.normal()).collect())
        .expect("cls");
    let lab: Vec<i32> = (0..256).map(|i| (i % 10) as i32).collect();
    bench.case("accuracy_256x10", || {
        accuracy(&cls, &lab).expect("acc");
    });

    // end-to-end native serving: router + batcher + native CAT-FFT model,
    // 64 requests from 4 client threads (hermetic — no artifacts)
    bench.samples = 5;
    bench.case("native_serve_64_reqs", || {
        let opts = ServeOptions {
            backend: Backend::Native,
            ..Default::default()
        };
        let server = Server::spawn(cat::artifacts_dir(),
                                   &["bench_native".to_string()], opts, 0)
            .expect("spawn native server");
        let handle = server.handle();
        let ds = ShapeDataset::new(5);
        let mut clients = Vec::new();
        for c in 0..4u64 {
            let h = handle.clone();
            let ds = ds.clone();
            clients.push(std::thread::spawn(move || {
                for i in 0..16u64 {
                    let sample = ds.sample(c * 16 + i);
                    let input =
                        HostTensor::f32(vec![3, 32, 32], sample.pixels)
                            .expect("input");
                    h.infer("bench_native", input).expect("infer");
                }
            }));
        }
        for c in clients {
            c.join().expect("client");
        }
        drop(handle);
        let stats = server.shutdown();
        assert_eq!(stats.iter().map(|s| s.requests).sum::<u64>(), 64);
    });

    // steady-state serving: ONE long-lived server, requests issued from
    // this thread — after warmup, a request must spawn zero threads (the
    // forward fans out over the persistent pool; PR 1 spawned scoped
    // threads per parallel section). Asserted via the pool spawn counter.
    {
        use cat::native::NativeVitConfig;

        // big enough that forwards genuinely engage the pool
        let native = NativeVitConfig {
            d_model: 128,
            n_heads: 8,
            patch_size: 2, // 256 tokens
            ..Default::default()
        };
        let opts = ServeOptions {
            backend: Backend::Native,
            native,
            ..Default::default()
        };
        let server = Server::spawn(cat::artifacts_dir(),
                                   &["steady_native".to_string()], opts, 0)
            .expect("spawn steady native server");
        let handle = server.handle();
        let ds = ShapeDataset::new(9);
        let mut send = |tag: u64| {
            let sample = ds.sample(tag);
            let input = HostTensor::f32(vec![3, 32, 32], sample.pixels)
                .expect("input");
            handle.infer("steady_native", input).expect("infer");
        };
        for i in 0..8u64 {
            send(i); // warmup: pool threads spawn here at the latest
        }
        let spawned_before = pool::stats().threads_spawned;
        bench.case("native_serve_persistent_64_reqs", || {
            for i in 0..64u64 {
                send(1000 + i);
            }
        });
        let spawned_after = pool::stats().threads_spawned;
        assert_eq!(spawned_after, spawned_before,
                   "steady-state requests spawned threads: {spawned_before} \
                    -> {spawned_after}");
        println!("steady-state serving: 0 thread spawns across {} pooled \
                  requests (pool workers: {})",
                 64 * (bench.warmup + bench.samples),
                 pool::stats().workers);
        drop(handle);
        server.shutdown();
    }

    // sharded steady state (DESIGN.md §10): K=2 head shards × R=2
    // replicas. Pins the acceptance criteria: sharded == unsharded
    // outputs bit-exactly on the hermetic eval inputs, and zero
    // per-request thread spawns (global AND dedicated pools) across
    // steady-state traffic. Shard counters land in the JSON below.
    let shard_json = {
        let ds = ShapeDataset::new(77);
        let eval_inputs: Vec<HostTensor> = (0..16)
            .map(|i| {
                let s = ds.sample(i);
                HostTensor::f32(vec![3, 32, 32], s.pixels).expect("input")
            })
            .collect();

        let unsharded_opts = ServeOptions {
            backend: Backend::Native,
            ..Default::default()
        };
        let plain = Server::spawn(cat::artifacts_dir(),
                                  &["flat".to_string()], unsharded_opts, 0)
            .expect("spawn unsharded server");
        let want: Vec<HostTensor> = {
            let h = plain.handle();
            let rows = eval_inputs.iter()
                .map(|t| h.infer("flat", t.clone()).expect("flat infer"))
                .collect();
            drop(h);
            rows
        };
        plain.shutdown();

        let opts = ServeOptions {
            backend: Backend::Native,
            shards: 2,
            replicas: 2,
            ..Default::default()
        };
        let server = Server::spawn(cat::artifacts_dir(),
                                   &["sharded".to_string()], opts, 0)
            .expect("spawn sharded server");
        let handle = server.handle();
        for (i, input) in eval_inputs.iter().enumerate() {
            let got = handle.infer("sharded", input.clone())
                .expect("sharded infer");
            assert_eq!(got, want[i],
                       "sharded (K=2,R=2) logits diverged from unsharded \
                        on eval input {i}");
        }
        let before = pool::stats();
        let reqs_per_iter = if smoke { 32u64 } else { 64 };
        bench.case("sharded_serve_steady_k2_r2", || {
            for i in 0..reqs_per_iter {
                let input = eval_inputs[(i % 16) as usize].clone();
                handle.infer("sharded", input).expect("sharded infer");
            }
        });
        let after = pool::stats();
        assert_eq!(after.threads_spawned, before.threads_spawned,
                   "sharded steady state spawned global-pool threads");
        assert_eq!(after.dedicated_threads_spawned,
                   before.dedicated_threads_spawned,
                   "sharded steady state spawned dedicated-pool threads");
        println!("sharded steady state: 0 thread spawns across {} \
                  requests (K=2 shards, R=2 replicas)",
                 reqs_per_iter * (bench.warmup + bench.samples) as u64);
        drop(handle);
        let router = server.router_stats();
        let stats = server.shutdown();
        let agg = aggregate_stats(&stats);
        assert_eq!(agg[0].requests as usize,
                   16 + reqs_per_iter as usize * (bench.warmup
                                                  + bench.samples));

        let mut replicas = Vec::new();
        for s in &stats {
            let sh = s.shard.expect("sharded replica stats");
            assert_eq!(sh.inline_fallbacks, 0,
                       "healthy shards must never fall back inline");
            replicas.push(Json::Obj(vec![
                ("replica".into(), Json::from(s.replica)),
                ("requests".into(), Json::Num(s.requests as f64)),
                ("batches".into(), Json::Num(s.batches as f64)),
                ("shards".into(), Json::from(sh.shards)),
                ("workers_per_shard".into(),
                 Json::from(sh.workers_per_shard)),
                ("shard_threads_spawned".into(),
                 Json::Num(sh.threads_spawned as f64)),
                ("shard_jobs".into(), Json::Num(sh.jobs as f64)),
                ("scatters".into(), Json::Num(sh.scatters as f64)),
                ("gathers".into(), Json::Num(sh.gathers as f64)),
                ("inline_fallbacks".into(),
                 Json::Num(sh.inline_fallbacks as f64)),
            ]));
        }
        Json::Obj(vec![
            ("shards".into(), Json::from(2usize)),
            ("replicas".into(), Json::from(2usize)),
            ("sharded_equals_unsharded".into(), Json::from(true)),
            ("steady_state_thread_spawns".into(), Json::from(0usize)),
            ("dispatched".into(), Json::Num(router.dispatched as f64)),
            ("busy_rejected".into(),
             Json::Num(router.busy_rejected as f64)),
            ("pings_ok".into(), Json::Num(router.pings_ok as f64)),
            ("pings_missed".into(), Json::Num(router.pings_missed as f64)),
            ("per_replica".into(), Json::Arr(replicas)),
        ])
    };

    // backpressure: a deliberately slow executor behind a depth-1 queue
    // must reject overflow with Busy + retry-after, engaging the
    // explicit backpressure path rather than queueing unboundedly
    let backpressure_json = {
        struct SlowExec;
        impl BatchExecutor for SlowExec {
            fn max_batch(&self) -> usize {
                1
            }
            fn infer_batch(&self, inputs: &[&HostTensor])
                           -> cat::Result<Vec<HostTensor>> {
                std::thread::sleep(Duration::from_millis(20));
                Ok(inputs.iter()
                    .map(|_| HostTensor::scalar_f32(0.0))
                    .collect())
            }
        }
        let factory: ExecutorFactory =
            Arc::new(|_s: &WorkerSpec, _o: &ServeOptions| {
                Ok(Box::new(SlowExec) as Box<dyn BatchExecutor>)
            });
        let opts = ServeOptions {
            backend: Backend::Native,
            queue_depth: 1,
            ..Default::default()
        };
        let server = Server::spawn_with(
            cat::artifacts_dir(),
            vec![WorkerSpec { model: "slow".into(), params: None, seed: 0 }],
            opts, Some(factory))
            .expect("spawn slow server");
        let handle = server.handle();
        let mut busy = 0u64;
        let mut served = 0u64;
        let clients: Vec<_> = (0..8)
            .map(|_| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    match h.try_infer("slow", HostTensor::scalar_f32(1.0)) {
                        Ok(_) => (1u64, 0u64),
                        Err(ServeError::Busy { .. }) => (0, 1),
                        Err(e) => panic!("unexpected overload error: {e}"),
                    }
                })
            })
            .collect();
        for c in clients {
            let (s, b) = c.join().expect("client");
            served += s;
            busy += b;
        }
        assert!(busy > 0,
                "8 concurrent clients against a depth-1 queue and a 20ms \
                 executor must trip backpressure (served {served})");
        drop(handle);
        let router = server.router_stats();
        server.shutdown();
        println!("backpressure: {busy} Busy rejections / {served} served \
                  under deliberate overflow");
        Json::Obj(vec![
            ("clients".into(), Json::from(8usize)),
            ("served".into(), Json::Num(served as f64)),
            ("busy_rejected_observed".into(), Json::Num(busy as f64)),
            ("busy_rejected_router".into(),
             Json::Num(router.busy_rejected as f64)),
        ])
    };

    print!("{}", bench.report());
    let out = Json::Obj(vec![
        ("bench".into(), Json::from("coordinator")),
        ("timing".into(), bench.to_json()),
        ("sharded_steady_state".into(), shard_json),
        ("backpressure".into(), backpressure_json),
    ]);
    std::fs::write("BENCH_coordinator.json", out.to_string_pretty())
        .expect("write BENCH_coordinator.json");
    eprintln!("results -> BENCH_coordinator.json");
}
