//! Table 3 / Fig. 2 (fast proxy): forward-pass cost of each circular
//! parameterization (qkv / qv / q / v) on the ViT-L proxy, plus their
//! parameter budgets — the cost side of the ablation; the accuracy side is
//! `examples/ablation`.

use cat::bench::Bench;
use cat::runtime::{Runtime, TrainState};
use cat::tensor::HostTensor;

fn main() {
    let rt = Runtime::from_env().expect("artifacts present?");
    let mut bench = Bench::new("table3 forward (ViT-L proxy)");
    bench.warmup = 1;
    bench.samples = 5;

    let mechs = ["attention", "cat_qkv", "cat", "cat_q", "cat_v"];
    let mut budgets = Vec::new();
    for mech in mechs {
        let name = format!("vit_l_avg_{mech}");
        let meta = rt.config(&name).expect("cfg").clone();
        let exe = rt.load(&name, "forward").expect("load");
        let state = TrainState::init(&rt, &name, 0).expect("init");
        let images = HostTensor::zeros_f32(
            vec![meta.batch_size, 3, 32, 32]).to_literal().expect("lit");
        bench.case(&name, || {
            let mut args: Vec<&xla::Literal> = state.params.iter().collect();
            args.push(&images);
            exe.execute_literals(&args).expect("exec");
        });
        budgets.push((name, meta.param_count));
    }
    print!("{}", bench.report());

    println!("\nTable 3 parameter budgets (whole model):");
    for (name, params) in &budgets {
        let t = bench.median_of(name).expect("case");
        println!("  {name:<24} {params:>10} params {:>9.2} ms/fwd",
                 t * 1e3);
    }
}
