//! Table 3 / Fig. 2, hermetic: the circular-parameterization ablation,
//! trained natively. The grid covers the mechanism axis (softmax
//! attention vs the merged-CAT apply via FFT vs the O(N²) gather
//! reference — identical math, so their accuracies should agree — plus
//! the registry's zoo rows: parameter-free FNet, the 3d²-budget
//! circulant-attention variant, and the conv-augmented CAT hybrid)
//! and the head-count axis (h ∈ {2, 4, 8},
//! which moves the `(d+h)·d` budget), reporting accuracy + whole-model
//! parameter counts. No artifacts.
//!
//!   cargo bench --bench table3_ablation              # full proxy run
//!   cargo bench --bench table3_ablation -- --smoke   # CI smoke
//!
//! Always emits `BENCH_table3.json`. With `--features pjrt` + artifacts
//! it additionally times the AOT forward per paper parameterization.

use cat::harness;
use cat::native::{Mixer, TrainConfig};

fn main() {
    let args = cat::bench::bench_args("table3_ablation", &["smoke"],
                                      &["steps", "seed"]);
    let smoke = args.has("smoke");
    let steps: u64 = args
        .parse_or("steps", if smoke { 30 } else { 150 })
        .expect("--steps");
    let seed: u64 = args.parse_or("seed", 0).expect("--seed");
    let eval_batches = if smoke { 4 } else { 16 };

    let mut grid: Vec<(String, TrainConfig, Option<&str>)> = vec![
        ("native_vit_attention".into(),
         TrainConfig::vit(Mixer::Attention, false),
         Some("vit_b_avg_attention")),
        ("native_vit_cat".into(), TrainConfig::vit(Mixer::CatFft, false),
         Some("vit_b_avg_cat")),
        ("native_vit_cat_gather".into(),
         TrainConfig::vit(Mixer::CatGather, false), None),
        // registry zoo rows: in the smoke grid too, so CI's
        // BENCH_table3.json always carries their accuracy + budgets
        ("native_vit_fnet".into(), TrainConfig::vit(Mixer::Fnet, false),
         None),
        ("native_vit_circulant".into(),
         TrainConfig::vit(Mixer::Circulant, false), None),
        ("native_vit_cat_conv".into(),
         TrainConfig::vit(Mixer::CatConv, false), None),
    ];
    if !smoke {
        for heads in [2usize, 8] {
            let mut cfg = TrainConfig::vit(Mixer::CatFft, false);
            cfg.n_heads = heads;
            grid.push((format!("native_vit_cat_h{heads}"), cfg, None));
        }
    }

    let rows = harness::run_native_cfgs(&grid, steps, seed, eval_batches)
        .expect("native table3 grid");
    print!("{}", harness::render_table(
        "Table 3 / Fig. 2 — mechanism + head-count ablation, native \
         training",
        &rows));
    println!("\nparameter budgets (whole model):");
    for ((label, _, _), row) in grid.iter().zip(&rows) {
        println!("  {label:<26} {:>10} params  {} {:.4}",
                 row.params, row.metric_name, row.metric);
    }
    harness::write_bench_json("BENCH_table3.json", "table3_ablation",
                              smoke, steps, &rows)
        .expect("write BENCH_table3.json");

    pjrt_series();
}

/// AOT forward wallclock per paper parameterization when artifacts exist.
#[cfg(feature = "pjrt")]
fn pjrt_series() {
    use cat::bench::Bench;
    use cat::runtime::{Runtime, TrainState};
    use cat::tensor::HostTensor;

    let rt = match Runtime::from_env() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("[pjrt series skipped: {e:#}]");
            return;
        }
    };
    let mut bench = Bench::new("table3 forward (ViT-L proxy, pjrt)");
    bench.warmup = 1;
    bench.samples = 5;
    for mech in ["attention", "cat_qkv", "cat", "cat_q", "cat_v"] {
        let name = format!("vit_l_avg_{mech}");
        let Ok(meta) = rt.config(&name).cloned() else { continue };
        let exe = rt.load(&name, "forward").expect("load");
        let state = TrainState::init(&rt, &name, 0).expect("init");
        let images = HostTensor::zeros_f32(
            vec![meta.batch_size, 3, 32, 32]).to_literal().expect("lit");
        bench.case(&name, || {
            let mut args: Vec<&xla::Literal> = state.params.iter().collect();
            args.push(&images);
            exe.execute_literals(&args).expect("exec");
        });
    }
    print!("{}", bench.report());
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_series() {}
