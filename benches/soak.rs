//! Chaos soak (DESIGN.md §12): sustained Zipf workload against a
//! supervised K-model × R-replica server while faults fire mid-stream —
//! kills, poisoned batches, injected delay. The gate is the PR-7
//! acceptance contract: every request gets a definitive answer (200-
//! shaped Ok / Busy / Failed / DeadlineExceeded — never a hang), killed
//! replicas respawn through backoff + probation, and time-to-recovery
//! is bounded. Emits `BENCH_soak.json` (goodput, latency quantiles,
//! Busy rate, recovery histogram); CI's perf-smoke runs
//! `--smoke --check` and fails the build on any violated gate.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cat::coordinator::{ArrivalSampler, Arrivals, BatchExecutor,
                       ExecutorFactory, ReplicaPhase, ServeError,
                       ServeHandle, ServeOptions, Server, StatsHandle,
                       WorkerSpec};
use cat::data::{Rng, Zipf};
use cat::json::Json;
use cat::metrics::LatencyHistogram;
use cat::serve::fault::{injected_factory, FaultPlan};
use cat::tensor::HostTensor;

/// Cheap deterministic stand-in executor: the soak stresses the
/// supervision + routing machinery, not the model math.
struct SoakModel;

impl BatchExecutor for SoakModel {
    fn max_batch(&self) -> usize {
        4
    }

    fn infer_batch(&self, inputs: &[&HostTensor])
                   -> cat::Result<Vec<HostTensor>> {
        inputs
            .iter()
            .map(|t| {
                let s: f32 = t.as_f32()?.iter().sum();
                HostTensor::f32(vec![4], vec![s, 0.5 * s, -s, 1.0])
            })
            .collect()
    }
}

/// Per-client outcome tally: every issued request lands in exactly one
/// bucket — `unanswered` (issued minus the buckets) must end at zero.
#[derive(Default)]
struct Tally {
    issued: u64,
    ok: u64,
    busy: u64,
    failed: u64,
    deadline: u64,
    latency: LatencyHistogram,
}

/// One closed-loop client: Poisson arrivals, Zipf-popular inputs over
/// two models, 500ms per-request deadline.
fn client(handle: ServeHandle, models: Vec<String>, stop: Arc<AtomicBool>,
          rate: f64, seed: u64) -> Tally {
    let mut tally = Tally::default();
    let mut arrivals = ArrivalSampler::new(Arrivals::Poisson { rate },
                                           seed);
    let zipf = Zipf::new(64, 1.1);
    let mut rng = Rng::new(seed ^ 0xD1CE);
    let inputs: Vec<HostTensor> = (0..zipf.len())
        .map(|i| {
            let x = (i as f32).mul_add(0.25, 1.0);
            HostTensor::f32(vec![4], vec![x, -x, 0.5 * x, 2.0])
                .expect("soak input tensor")
        })
        .collect();
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(arrivals.next_gap());
        let idx = zipf.sample(&mut rng);
        let model = &models[idx % models.len()];
        let t0 = Instant::now();
        let deadline = t0 + Duration::from_millis(500);
        tally.issued += 1;
        match handle.infer_deadline(model, inputs[idx].clone(), deadline) {
            Ok(_) => {
                tally.ok += 1;
                tally.latency.record(t0.elapsed());
            }
            Err(ServeError::Busy { .. }) => tally.busy += 1,
            Err(ServeError::DeadlineExceeded) => tally.deadline += 1,
            Err(ServeError::Failed(_)) => tally.failed += 1,
        }
    }
    tally
}

/// Poll until every replica is routable again (phase `Live`).
fn await_all_live(stats: &StatsHandle, patience: Duration) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < patience {
        if stats
            .replicas()
            .iter()
            .all(|r| r.alive && r.phase == ReplicaPhase::Live)
        {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    false
}

fn main() {
    let args = cat::bench::bench_args("soak", &["smoke", "check"], &[]);
    let smoke = args.has("smoke");
    let check = args.has("check");

    let opts = ServeOptions {
        replicas: 2,
        queue_depth: 64,
        max_delay: Duration::from_millis(1),
        health_every: Duration::from_millis(20),
        ping_timeout: Duration::from_millis(200),
        restart_budget: 32,
        restart_base: Duration::from_millis(10),
        probation_pings: 2,
        ..Default::default()
    };
    let models = vec!["soak_a".to_string(), "soak_b".to_string()];
    let specs: Vec<WorkerSpec> = models
        .iter()
        .map(|m| WorkerSpec { model: m.clone(), params: None, seed: 0 })
        .collect();
    let plan = FaultPlan::new();
    let inner: ExecutorFactory = Arc::new(|_s: &WorkerSpec,
                                           _o: &ServeOptions| {
        Ok(Box::new(SoakModel) as Box<dyn BatchExecutor>)
    });
    let factory = injected_factory(&plan, inner);
    let server = Server::spawn_with(cat::artifacts_dir(), specs, opts,
                                    Some(factory))
        .expect("spawn soak server");
    let stats = server.stats_handle();

    // sustained load: 4 closed-loop clients
    let stop = Arc::new(AtomicBool::new(false));
    let per_client_rate = if smoke { 40.0 } else { 150.0 };
    let clients: Vec<_> = (0..4)
        .map(|i| {
            let handle = server.handle();
            let models = models.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                client(handle, models, stop, per_client_rate,
                       0xCA7 + i as u64)
            })
        })
        .collect();
    let t_start = Instant::now();

    // the chaos schedule: two explicit kills with full recovery waits
    // (the gated path), plus poison + delay riding along, plus — in the
    // full run — a periodic-kill window for sustained churn
    let settle = Duration::from_millis(if smoke { 250 } else { 1000 });
    let patience = Duration::from_secs(5);
    std::thread::sleep(settle);

    plan.kill_next();
    let healed_1 = await_all_live(&stats, patience);
    eprintln!("[soak] kill #1 healed: {healed_1}");

    plan.poison_next(3);
    std::thread::sleep(settle / 2);
    plan.set_delay(Duration::from_millis(2));
    std::thread::sleep(settle / 2);
    plan.clear_delay();

    plan.kill_next();
    let healed_2 = await_all_live(&stats, patience);
    eprintln!("[soak] kill #2 healed: {healed_2}");

    if !smoke {
        // every 200th batch dies for a while: overlapping outages
        plan.kill_every(200);
        std::thread::sleep(Duration::from_secs(3));
        plan.kill_every(0);
        let healed = await_all_live(&stats, patience);
        eprintln!("[soak] periodic-kill window healed: {healed}");
    }

    std::thread::sleep(settle);
    stop.store(true, Ordering::Relaxed);
    let mut total = Tally::default();
    for c in clients {
        let t = c.join().expect("client thread");
        total.issued += t.issued;
        total.ok += t.ok;
        total.busy += t.busy;
        total.failed += t.failed;
        total.deadline += t.deadline;
        total.latency.merge(&t.latency);
    }
    let elapsed = t_start.elapsed().as_secs_f64();
    let healed_final = await_all_live(&stats, patience);

    let router = stats.router();
    let recovery = stats.recovery_latency();
    let answered =
        total.ok + total.busy + total.failed + total.deadline;
    let unanswered = total.issued - answered;
    let goodput = total.ok as f64 / elapsed;
    let busy_rate = total.busy as f64 / total.issued.max(1) as f64;

    eprintln!("\n== chaos soak ==");
    eprintln!("  requests {:>8}  ok {} busy {} failed {} deadline {}",
              total.issued, total.ok, total.busy, total.failed,
              total.deadline);
    eprintln!("  goodput  {goodput:>8.1} req/s   busy rate {:.4}",
              busy_rate);
    eprintln!("  latency  p50 {}us  p99 {}us  max {}us",
              total.latency.quantile_us(0.5),
              total.latency.quantile_us(0.99), total.latency.max_us());
    eprintln!("  deaths {}  restarts {}  recoveries {} (p50 {}us, max \
               {}us)",
              router.replicas_died, router.replicas_restarted,
              recovery.count(), recovery.quantile_us(0.5),
              recovery.max_us());

    let out = Json::Obj(vec![
        ("bench".into(), Json::from("soak")),
        ("smoke".into(), Json::Bool(smoke)),
        ("elapsed_s".into(), Json::Num(elapsed)),
        ("requests".into(), Json::Num(total.issued as f64)),
        ("ok".into(), Json::Num(total.ok as f64)),
        ("busy".into(), Json::Num(total.busy as f64)),
        ("failed".into(), Json::Num(total.failed as f64)),
        ("deadline".into(), Json::Num(total.deadline as f64)),
        ("unanswered".into(), Json::Num(unanswered as f64)),
        ("goodput_rps".into(), Json::Num(goodput)),
        ("busy_rate".into(), Json::Num(busy_rate)),
        ("latency_us".into(), Json::Obj(vec![
            ("p50".into(),
             Json::Num(total.latency.quantile_us(0.5) as f64)),
            ("p99".into(),
             Json::Num(total.latency.quantile_us(0.99) as f64)),
            ("max".into(), Json::Num(total.latency.max_us() as f64)),
        ])),
        ("kills".into(), Json::Num(router.replicas_died as f64)),
        ("restarts".into(),
         Json::Num(router.replicas_restarted as f64)),
        ("recovery_us".into(), Json::Obj(vec![
            ("count".into(), Json::Num(recovery.count() as f64)),
            ("p50".into(),
             Json::Num(recovery.quantile_us(0.5) as f64)),
            ("max".into(), Json::Num(recovery.max_us() as f64)),
        ])),
        ("healed_final".into(), Json::Bool(healed_final)),
    ]);
    std::fs::write("BENCH_soak.json", out.to_string_pretty())
        .expect("write BENCH_soak.json");
    eprintln!("results -> BENCH_soak.json");

    server.shutdown();

    if check {
        let mut violations = Vec::new();
        if unanswered != 0 {
            violations.push(format!("{unanswered} requests unanswered"));
        }
        if total.ok == 0 {
            violations.push("no request ever succeeded".to_string());
        }
        if router.replicas_died == 0 {
            violations.push("no replica ever died (faults not \
                             injected?)".to_string());
        }
        if router.replicas_restarted == 0 {
            violations.push("supervisor never restarted a \
                             replica".to_string());
        }
        if recovery.count() == 0 {
            violations.push("no recovery was ever recorded".to_string());
        }
        if recovery.count() > 0 && recovery.max_us() > 5_000_000 {
            violations.push(format!(
                "worst time-to-recovery {}us exceeds the 5s bound",
                recovery.max_us()));
        }
        if !healed_final {
            violations.push("server did not heal to all-Live by the \
                             end".to_string());
        }
        if violations.is_empty() {
            eprintln!("soak --check: all gates passed");
        } else {
            for v in &violations {
                eprintln!("soak --check FAILED: {v}");
            }
            std::process::exit(1);
        }
    }
}
