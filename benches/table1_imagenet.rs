//! Table 1 (fast proxy): per-mechanism ViT *training-step* throughput on
//! the ImageNet substitute. The full-accuracy grid is `examples/train_vit
//! --table1`; this bench times the end-to-end train step — data generation
//! + PJRT execute + state absorb — for each Table-1 mechanism.

use cat::bench::Bench;
use cat::runtime::Runtime;
use cat::train::Trainer;

fn main() {
    let rt = Runtime::from_env().expect("artifacts present?");
    let mut bench = Bench::new("table1 train step (ViT-B proxy)");
    bench.warmup = 1;
    bench.samples = 5;

    let mechs = ["attention", "cat", "cat_alter"];
    for mech in mechs {
        let name = format!("vit_b_avg_{mech}");
        let mut trainer = Trainer::new(&rt, &name, 0).expect("trainer");
        bench.case(&name, || {
            trainer.step(1e-3).expect("step");
        });
    }
    print!("{}", bench.report());

    let attn = bench.median_of("vit_b_avg_attention").expect("attn");
    println!("\nTable 1 training-step wallclock (ViT-B proxy):");
    for mech in mechs {
        let name = format!("vit_b_avg_{mech}");
        let t = bench.median_of(&name).expect("case");
        println!("  {name:<24} {:>8.1} ms/step   vs attention {:.2}x",
                 t * 1e3, attn / t);
    }
}
