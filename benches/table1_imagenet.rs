//! Table 1, hermetic: trains the ViT mechanism grid (attention / cat /
//! cat_alter) end-to-end on the native training subsystem — patch embed →
//! CAT/attention blocks → pool → classify, gradients through the FFT —
//! on the procedural ImageNet substitute, and prints the paper-style
//! table with the paper's numbers alongside. No artifacts, no PJRT.
//!
//!   cargo bench --bench table1_imagenet              # full proxy run
//!   cargo bench --bench table1_imagenet -- --smoke   # CI smoke
//!
//! Always emits `BENCH_table1.json` (rows + config). With
//! `--features pjrt` and `artifacts/` present it additionally times the
//! AOT train step per mechanism (the original PR-0 timing series).

use cat::harness;

const NAMES: [&str; 3] =
    ["native_vit_attention", "native_vit_cat", "native_vit_cat_alter"];

fn main() {
    let args = cat::bench::bench_args("table1_imagenet", &["smoke"],
                                      &["steps", "seed"]);
    let smoke = args.has("smoke");
    let steps: u64 = args
        .parse_or("steps", if smoke { 30 } else { 150 })
        .expect("--steps");
    let seed: u64 = args.parse_or("seed", 0).expect("--seed");
    let eval_batches = if smoke { 4 } else { 16 };

    let rows = harness::run_native_grid(&NAMES, steps, seed, eval_batches)
        .expect("native table1 grid");
    print!("{}", harness::render_table(
        "Table 1 — ImageNet-proxy ViT grid, native training (accuracy up)",
        &rows));
    harness::write_bench_json("BENCH_table1.json", "table1_imagenet",
                              smoke, steps, &rows)
        .expect("write BENCH_table1.json");

    pjrt_series();
}

/// AOT train-step wallclock per mechanism when artifacts exist.
#[cfg(feature = "pjrt")]
fn pjrt_series() {
    use cat::bench::Bench;
    use cat::runtime::Runtime;
    use cat::train::Trainer;

    let rt = match Runtime::from_env() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("[pjrt series skipped: {e:#}]");
            return;
        }
    };
    let mut bench = Bench::new("table1 train step (ViT-B proxy, pjrt)");
    bench.warmup = 1;
    bench.samples = 5;
    for mech in ["attention", "cat", "cat_alter"] {
        let name = format!("vit_b_avg_{mech}");
        let Ok(mut trainer) = Trainer::new(&rt, &name, 0) else { continue };
        bench.case(&name, || {
            trainer.step(1e-3).expect("step");
        });
    }
    print!("{}", bench.report());
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_series() {}
