//! Table 2, hermetic: trains the LM mechanism grid (masked + causal ×
//! attention / cat) end-to-end on the native training subsystem against
//! the Zipf-Markov WikiText substitute and reports word perplexity. The
//! causal CAT rows exercise the zero-padded FFT causal convolution (this
//! repo's sub-quadratic extension — the paper's causal CAT is O(N²)),
//! including its backward. No artifacts, no PJRT.
//!
//!   cargo bench --bench table2_wikitext              # full proxy run
//!   cargo bench --bench table2_wikitext -- --smoke   # CI smoke
//!
//! Always emits `BENCH_table2.json`. With `--features pjrt` + artifacts
//! it additionally times the AOT train step per config.

use cat::harness;

fn main() {
    let args = cat::bench::bench_args("table2_wikitext", &["smoke"],
                                      &["steps", "seed"]);
    let smoke = args.has("smoke");
    let steps: u64 = args
        .parse_or("steps", if smoke { 25 } else { 120 })
        .expect("--steps");
    let seed: u64 = args.parse_or("seed", 0).expect("--seed");
    let eval_batches = if smoke { 2 } else { 8 };
    let names: Vec<&str> = if smoke {
        vec!["native_lm_masked_attention", "native_lm_masked_cat",
             "native_lm_causal_attention", "native_lm_causal_cat"]
    } else {
        vec!["native_lm_masked_attention", "native_lm_masked_cat",
             "native_lm_masked_cat_alter", "native_lm_causal_attention",
             "native_lm_causal_cat"]
    };

    let rows = harness::run_native_grid(&names, steps, seed, eval_batches)
        .expect("native table2 grid");
    print!("{}", harness::render_table(
        "Table 2 — WikiText-proxy LM grid, native training (word PPL down)",
        &rows));
    harness::write_bench_json("BENCH_table2.json", "table2_wikitext",
                              smoke, steps, &rows)
        .expect("write BENCH_table2.json");

    pjrt_series();
}

/// AOT train-step wallclock per config when artifacts exist.
#[cfg(feature = "pjrt")]
fn pjrt_series() {
    use cat::bench::Bench;
    use cat::runtime::Runtime;
    use cat::train::Trainer;

    let rt = match Runtime::from_env() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("[pjrt series skipped: {e:#}]");
            return;
        }
    };
    let mut bench = Bench::new("table2 train step (GPT-2 proxy, pjrt)");
    bench.warmup = 1;
    bench.samples = 3;
    for task in ["masked", "causal"] {
        for mech in ["attention", "cat"] {
            let name = format!("lm_gpt2_{task}_{mech}");
            let Ok(mut trainer) = Trainer::new(&rt, &name, 0) else {
                continue;
            };
            bench.case(&name, || {
                trainer.step(1e-3).expect("step");
            });
        }
    }
    print!("{}", bench.report());
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_series() {}
