//! Table 2 (fast proxy): LM training-step throughput for masked and causal
//! settings across mechanisms, on the WikiText substitute. Full PPL grid:
//! `examples/train_lm --table2`. The causal rows exercise the zero-padded
//! FFT causal CAT (our sub-quadratic extension; the paper's causal CAT is
//! O(N^2)).

use cat::bench::Bench;
use cat::runtime::Runtime;
use cat::train::Trainer;

fn main() {
    let rt = Runtime::from_env().expect("artifacts present?");
    let mut bench = Bench::new("table2 train step (GPT-2 proxy, N=256)");
    bench.warmup = 1;
    bench.samples = 3;

    for task in ["masked", "causal"] {
        for mech in ["attention", "cat"] {
            let name = format!("lm_gpt2_{task}_{mech}");
            let mut trainer = Trainer::new(&rt, &name, 0).expect("trainer");
            bench.case(&name, || {
                trainer.step(1e-3).expect("step");
            });
        }
    }
    print!("{}", bench.report());
}
