//! Training-throughput bench: steps/s of the full native train step
//! (forward + backward + AdamW) per mixer × sequence length, with the
//! tiled backward (blocked `xᵀ·dy`, fused softmax-bwd, stripe-batched
//! causal FFT, panel-blocked attention backward — DESIGN.md §9) timed
//! against the PR-3 naive reference kernels on identical models. The
//! naive kernels are also the equivalence oracles of the tiled paths
//! (`tests/proptests.rs`), so this bench measures exactly the pair that
//! is proven numerically interchangeable.
//!
//! A third column re-times the tiled backward with the vector layer
//! forced onto its scalar oracles (`simd::set_force_scalar_global`,
//! DESIGN.md §15) — the simd-vs-scalar margin of the whole train step.
//!
//!   cargo bench --bench trainstep              # full mixer × N grid
//!   cargo bench --bench trainstep -- --smoke   # CI grid (small N)
//!   ... -- --smoke --check   # CI gate: exit 1 unless the tiled
//!                            # backward beats naive AND the simd
//!                            # kernels are no slower than scalar
//!                            # at every config
//!
//! Always emits `BENCH_trainstep.json`.

use cat::bench::Bench;
use cat::json::Json;
use cat::native::{pool, set_naive_backward, simd, Mixer, TaskKind,
                  TrainConfig};
use cat::train::{NativeTrainer, TrainBackend};

/// Table-2-shaped LM trunk (d=64, h=4, L=2, batch 8) at sequence length
/// `n` — the N axis moves both the FFT stripes and the O(N²) attention
/// work, and the vocab-512 head keeps the `xᵀ·dy` block honest.
fn lm_cfg(mixer: Mixer, causal: bool, n: usize) -> TrainConfig {
    TrainConfig {
        d_model: 64,
        n_heads: 4,
        n_layers: 2,
        batch_size: 8,
        mixer,
        alternate: false,
        fnet_truncate: false,
        task: TaskKind::Lm { vocab: 512, seq_len: n, causal },
    }
}

struct Case {
    label: String,
    cfg: TrainConfig,
}

fn main() {
    let args = cat::bench::bench_args("trainstep", &["smoke", "check"],
                                      &["steps"]);
    let smoke = args.has("smoke");
    let check = args.has("check");
    let ns: &[usize] = if smoke { &[128, 256] } else { &[128, 256, 512] };
    let steps_per_sample: u64 = args
        .parse_or("steps", if smoke { 4 } else { 8 })
        .expect("--steps");

    let mut cases = Vec::new();
    for &n in ns {
        cases.push(Case {
            label: format!("cat_n{n}"),
            cfg: lm_cfg(Mixer::CatFft, false, n),
        });
        cases.push(Case {
            label: format!("cat_causal_n{n}"),
            cfg: lm_cfg(Mixer::CatFft, true, n),
        });
        cases.push(Case {
            label: format!("attention_n{n}"),
            cfg: lm_cfg(Mixer::Attention, false, n),
        });
    }

    let mut bench =
        Bench::new("native train step (LM trunk d=64 h=4 L=2 b=8)");
    bench.warmup = 1;
    bench.samples = if smoke { 3 } else { 5 };

    // one noisy sample on a loaded shared runner must not fail CI: a
    // losing config gets one re-measure, and the gate carries a small
    // noise grace (same spirit as the crossover test's retry + wide
    // band in tests/native_backend.rs). Raw medians land in the JSON.
    const GATE_MARGIN: f64 = 0.97;

    let mut measure = |case: &Case, tag: &str| -> [f64; 3] {
        // [tiled, naive, tiled w/ forced-scalar kernels] steps/s
        let mut out = [0.0f64; 3];
        for (slot, naive, scalar) in [(0usize, false, false),
                                      (1usize, true, false),
                                      (2usize, false, true)] {
            set_naive_backward(naive);
            simd::set_force_scalar_global(scalar);
            let mut t =
                NativeTrainer::from_config(&case.label, case.cfg, 0)
                    .expect("trainer");
            // warm the plan caches / arenas / pool out of the timing
            let warm = t.train_step(1e-3).expect("warm step");
            assert!(warm.is_finite(), "{}: non-finite loss", case.label);
            let mode = if naive {
                "naive"
            } else if scalar {
                "scalar"
            } else {
                "tiled"
            };
            let sample =
                bench.case(&format!("{}_{mode}{tag}", case.label), || {
                    for _ in 0..steps_per_sample {
                        t.train_step(1e-3).expect("train step");
                    }
                });
            out[slot] = steps_per_sample as f64 / sample.median();
        }
        set_naive_backward(false);
        simd::set_force_scalar_global(false);
        out
    };

    println!("steps/s per mixer × N: tiled backward vs the naive \
              reference kernels, and the same tiled step with the \
              vector layer forced scalar [simd backend: {}]:",
             simd::backend_name());
    let mut rows = Vec::new();
    let mut regressions = Vec::new();
    for case in &cases {
        let mut steps_per_s = measure(case, "");
        if steps_per_s[0] <= steps_per_s[1]
            || steps_per_s[0] <= steps_per_s[2]
        {
            eprintln!("  {}: tiled {:.2} steps/s vs naive {:.2} / scalar \
                       {:.2} — noisy sample? re-measuring once",
                      case.label, steps_per_s[0], steps_per_s[1],
                      steps_per_s[2]);
            steps_per_s = measure(case, "_retry");
        }
        let speedup = steps_per_s[0] / steps_per_s[1];
        let simd_speedup = steps_per_s[0] / steps_per_s[2];
        let tiled_ok = steps_per_s[0] > steps_per_s[1] * GATE_MARGIN;
        let simd_ok = steps_per_s[0] > steps_per_s[2] * GATE_MARGIN;
        println!("  {:<18} tiled {:>8.2} steps/s   naive {:>8.2}   \
                  scalar {:>8.2}   vs-naive {:.2}x   vs-scalar {:.2}x{}",
                 case.label, steps_per_s[0], steps_per_s[1],
                 steps_per_s[2], speedup, simd_speedup,
                 if tiled_ok && simd_ok { "" } else { "  [REGRESSION]" });
        if !tiled_ok {
            regressions.push(format!("{} (tiled vs naive)", case.label));
        }
        if !simd_ok {
            regressions.push(format!("{} (simd vs scalar)", case.label));
        }
        rows.push(Json::Obj(vec![
            ("config".to_string(), Json::Str(case.label.clone())),
            ("mixer".to_string(), Json::Str(case.cfg.mechanism())),
            ("causal".to_string(), Json::Bool(case.cfg.causal())),
            ("n".to_string(), Json::Num(case.cfg.n_tokens() as f64)),
            ("tiled_steps_per_s".to_string(), Json::Num(steps_per_s[0])),
            ("naive_steps_per_s".to_string(), Json::Num(steps_per_s[1])),
            ("scalar_steps_per_s".to_string(), Json::Num(steps_per_s[2])),
            ("speedup".to_string(), Json::Num(speedup)),
            ("simd_speedup".to_string(), Json::Num(simd_speedup)),
            ("gate_pass".to_string(), Json::Bool(tiled_ok && simd_ok)),
        ]));
    }
    print!("{}", bench.report());

    let ps = pool::stats();
    let obj = Json::Obj(vec![
        ("bench".to_string(), Json::from("trainstep")),
        ("simd_backend".to_string(), Json::from(simd::backend_name())),
        ("smoke".to_string(), Json::Bool(smoke)),
        ("steps_per_sample".to_string(),
         Json::Num(steps_per_sample as f64)),
        ("configs".to_string(), Json::Arr(rows)),
        ("pool".to_string(), Json::Obj(vec![
            ("workers".to_string(), Json::Num(ps.workers as f64)),
            ("threads_spawned".to_string(),
             Json::Num(ps.threads_spawned as f64)),
            ("par_sections".to_string(),
             Json::Num(ps.par_sections as f64)),
        ])),
        ("timings".to_string(), bench.to_json()),
    ]);
    std::fs::write("BENCH_trainstep.json", obj.to_string_pretty())
        .expect("write BENCH_trainstep.json");
    eprintln!("results -> BENCH_trainstep.json");

    if check {
        if regressions.is_empty() {
            eprintln!("perf gate OK: tiled backward beat the naive \
                       reference and the simd kernels were no slower \
                       than forced-scalar at every measured config");
        } else {
            eprintln!("perf gate FAILED at {regressions:?}");
            std::process::exit(1);
        }
    }
}
